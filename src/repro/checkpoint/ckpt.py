"""Sharded checkpointing: atomic, async-capable, reshard-on-restore.

Format: one directory per step containing
    manifest.json       — step, leaf paths, shapes, dtypes, spec strings
    <leaf-path>.npy     — one file per leaf (this process's view)

On a multi-host cluster each host writes only its addressable shards and the
manifest records the shard grid; in this single-process container every leaf
is fully addressable so files hold global arrays.  Restore works onto ANY
mesh: arrays are device_put with the target shardings, so a checkpoint taken
on [2,2,4]x16DP restores onto [4,4,1]x8DP etc. (elastic rescale path).

Durability: writes go to ``<dir>/.tmp-<step>`` and are atomically renamed;
a ``latest`` pointer file is updated last.  A crash mid-write never corrupts
the previous checkpoint (fault-tolerance requirement).
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from pathlib import Path

import numpy as np
import jax


class CheckpointCorruptError(RuntimeError):
    """A checkpoint failed its integrity check (checksum mismatch,
    truncated leaf, unreadable manifest).  Restore falls back to the next
    older durable checkpoint (``restore_latest``) instead of feeding the
    optimizer silently-corrupted state."""


def _flatten(tree, prefix=""):
    if isinstance(tree, dict):
        out = {}
        for k in sorted(tree):
            out.update(_flatten(tree[k], f"{prefix}{k}/"))
        return out
    return {prefix.rstrip("/"): tree}


def _unflatten(flat: dict):
    root: dict = {}
    for path, v in flat.items():
        parts = path.split("/")
        d = root
        for p in parts[:-1]:
            d = d.setdefault(p, {})
        d[parts[-1]] = v
    return root


class CheckpointManager:
    def __init__(self, directory, keep: int = 3, async_save: bool = True):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self.async_save = async_save
        self._thread: threading.Thread | None = None
        self._error: BaseException | None = None
        self._error_step: int | None = None

    # ------------------------------------------------------------- save
    def save(self, step: int, state: dict, blocking: bool = False,
             meta: dict | None = None):
        """state: pytree of jax Arrays (fully-addressable).

        ``meta`` is an optional JSON-able dict stored in the manifest —
        the train loop records the ZeRO-1 optimizer-state layout there
        (``StepBundle.opt_layouts_json()``) so restore can re-shard across
        dp-degree or layout changes.

        A failure inside a previous async save is re-raised here (or in
        ``wait()``) — a checkpoint that silently never landed would turn
        the next restore into silent data loss."""
        flat = _flatten(state)
        host = {k: np.asarray(v) for k, v in flat.items()}
        self.wait()  # one in-flight save at a time; re-raises async errors
        if self.async_save and not blocking:
            self._thread = threading.Thread(
                target=self._write_guarded, args=(step, host, meta),
                daemon=True)
            self._thread.start()
        else:
            self._write(step, host, meta)

    def _write_guarded(self, step: int, host: dict, meta=None):
        try:
            self._write(step, host, meta)
        except BaseException as e:  # surfaced on the next wait()/save()
            self._error = e
            self._error_step = step

    def _write(self, step: int, host: dict, meta=None):
        tmp = self.dir / f".tmp-{step}"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)
        manifest = {"step": step, "time": time.time(), "leaves": {},
                    **({"meta": meta} if meta else {})}
        import zlib
        for path, arr in host.items():
            fn = path.replace("/", "__") + ".npy"
            # store raw bytes so ml_dtypes (bfloat16 etc.) round-trip
            raw = arr.reshape(-1).view(np.uint8)
            np.save(tmp / fn, raw)
            # checksum of the PAYLOAD (not the .npy header): bit flips and
            # truncation are both caught on restore (DESIGN.md §11)
            manifest["leaves"][path] = {
                "file": fn, "shape": list(arr.shape), "dtype": str(arr.dtype),
                "nbytes": int(raw.nbytes),
                "crc32": int(zlib.crc32(raw.tobytes()))}
        (tmp / "manifest.json").write_text(json.dumps(manifest))
        final = self.dir / f"step_{step:08d}"
        if final.exists():
            shutil.rmtree(final)
        os.rename(tmp, final)
        (self.dir / "latest.tmp").write_text(str(step))
        os.replace(self.dir / "latest.tmp", self.dir / "latest")
        self._gc()

    def wait(self):
        """Join the in-flight async save; re-raise its failure if it died."""
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            step, self._error_step = self._error_step, None
            raise RuntimeError(
                f"async checkpoint save for step {step} failed: "
                f"{type(err).__name__}: {err}") from err

    def _gc(self):
        steps = sorted(self.all_steps())
        for s in steps[: -self.keep]:
            shutil.rmtree(self.dir / f"step_{s:08d}", ignore_errors=True)

    # ---------------------------------------------------------- restore
    def all_steps(self):
        out = []
        for p in self.dir.glob("step_*"):
            if (p / "manifest.json").exists():
                out.append(int(p.name.split("_")[1]))
        return sorted(out)

    def latest_step(self):
        lp = self.dir / "latest"
        if lp.exists():
            try:
                s = int(lp.read_text().strip())
                if (self.dir / f"step_{s:08d}" / "manifest.json").exists():
                    return s
            except ValueError:
                pass
        steps = self.all_steps()
        return steps[-1] if steps else None

    def latest_valid_step(self) -> int | None:
        """Newest step that passes a full integrity check (``verify``), or
        None.  The train loop's restart-budget window uses this — a save
        that LANDED but is corrupt must not count as durable progress."""
        candidates = sorted(self.all_steps(), reverse=True)
        latest = self.latest_step()
        if latest is not None and latest in candidates:
            candidates.remove(latest)
            candidates.insert(0, latest)
        for step in candidates:
            try:
                self.verify(step)
                return step
            except CheckpointCorruptError:
                continue
        return None

    def _manifest(self, step: int) -> dict:
        d = self.dir / f"step_{step:08d}"
        try:
            return json.loads((d / "manifest.json").read_text())
        except (OSError, json.JSONDecodeError) as e:
            raise CheckpointCorruptError(
                f"step {step}: unreadable manifest: {e}") from e

    def _load_leaf(self, step: int, path: str, meta: dict) -> np.ndarray:
        """Read + integrity-check one leaf (length and crc32 of the raw
        payload vs the manifest).  Checkpoints written before checksums
        carry no crc32 field and skip the check (back-compat)."""
        import zlib
        d = self.dir / f"step_{step:08d}"
        try:
            raw = np.load(d / meta["file"])
        except (OSError, ValueError) as e:
            raise CheckpointCorruptError(
                f"step {step}: leaf {path!r} unreadable "
                f"({type(e).__name__}: {e})") from e
        if "nbytes" in meta and int(raw.nbytes) != meta["nbytes"]:
            raise CheckpointCorruptError(
                f"step {step}: leaf {path!r} truncated: {raw.nbytes} bytes "
                f"on disk, manifest says {meta['nbytes']}")
        if "crc32" in meta and zlib.crc32(raw.tobytes()) != meta["crc32"]:
            raise CheckpointCorruptError(
                f"step {step}: leaf {path!r} failed its checksum (bit "
                f"flip / partial write)")
        return raw.view(np.dtype(meta["dtype"])).reshape(meta["shape"])

    def verify(self, step: int) -> None:
        """Integrity-check every leaf of ``step`` without materializing the
        state on devices; raises CheckpointCorruptError on damage."""
        manifest = self._manifest(step)
        for path, meta in manifest["leaves"].items():
            self._load_leaf(step, path, meta)

    def restore(self, step: int, abstract_state, shardings, convert=None):
        """Restore onto the target mesh/shardings (reshard-on-restore).

        ``convert(path, arr, manifest_meta) -> arr`` (optional) transforms
        each host array before the shape check — the hook the ZeRO-1
        optimizer-state resharder uses to move checkpoints across dp-degree
        changes and between the replicated and sharded layouts
        (``optim/zero.make_ckpt_converter``).

        Every leaf is checksummed against the manifest as it is read; a
        corrupt checkpoint raises CheckpointCorruptError BEFORE any state
        reaches a device."""
        manifest = self._manifest(step)
        mf_meta = manifest.get("meta") or {}
        flat_abs = _flatten(abstract_state)
        flat_sh = _flatten(shardings)
        host = {}
        for path, ab in flat_abs.items():
            if path not in manifest["leaves"]:
                raise CheckpointCorruptError(
                    f"step {step}: leaf {path!r} missing from manifest")
            arr = self._load_leaf(step, path, manifest["leaves"][path])
            if convert is not None:
                arr = convert(path, arr, mf_meta)
            if tuple(arr.shape) != tuple(ab.shape):
                raise ValueError(f"{path}: ckpt {arr.shape} != expected {ab.shape}")
            if str(arr.dtype) != str(ab.dtype):
                arr = arr.astype(ab.dtype)
            host[path] = arr
        out = {path: jax.device_put(arr, flat_sh[path])
               for path, arr in host.items()}
        return _unflatten(out)

    def restore_latest(self, abstract_state, shardings, convert=None):
        """Restore the newest checkpoint that passes integrity checks,
        falling back across corrupted ones (newest -> oldest).  Returns
        ``(state, step)`` or ``(None, None)`` when no durable checkpoint
        exists.  Surfaces how many corrupt candidates were skipped via the
        ``.fallbacks`` attribute of the return step (an int subclass is
        overkill — callers read ``self.last_fallbacks`` instead)."""
        self.last_fallbacks = 0
        candidates = sorted(self.all_steps(), reverse=True)
        latest = self.latest_step()
        if latest is not None and latest in candidates:
            # honor the ``latest`` pointer first, then recency
            candidates.remove(latest)
            candidates.insert(0, latest)
        for step in candidates:
            try:
                return self.restore(step, abstract_state, shardings,
                                    convert=convert), step
            except CheckpointCorruptError as e:
                print(f"[ckpt] {e}; falling back to an older checkpoint")
                self.last_fallbacks += 1
        return None, None
