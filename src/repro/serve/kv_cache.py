"""Mesh-sharded paged KV cache (DESIGN.md §7).

The pool is a global array ``[L, P, bs, Hkv, D]`` whose physical-block axis
P is sharded over the decode plan's *KV group* axes (``core.ops.
kv_group_axes``: ``(data, depth, row)`` for the tesseract decode layout) and
whose KV heads are sharded over ``col`` — the same device placement as the
dense decode cache.  Devices sharing one coordinate along the group axes
form a KV group; the allocator hands each batch slot blocks exclusively
from the slot's own group partition, so every cache read and write in the
decode step is device-local (no cross-group collectives), exactly like the
dense layout — the paging only virtualizes the *sequence* dimension.

Block id convention: ids are GLOBAL (`group * blocks_per_group + local`);
the paged decode step subtracts the group offset inside ``shard_map``.
Local block 0 of every group is reserved as a scratch block: retired or
empty batch slots point their whole table at it (fixed-shape math, the
garbage is masked by per-request lengths and overwritten on reuse).
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from ..core.ops import Plan, kv_group_axes


@dataclass(frozen=True)
class PagedCacheConfig:
    num_blocks: int          # global physical blocks (multiple of n_groups)
    block_size: int = 8      # positions per block
    max_seq_len: int = 256   # bounds the block-table width


class BlockPool:
    """Pure-python per-group freelist accounting (no devices needed).

    Allocation and liberation are O(1) list ops; ids are global.  The
    scheduler uses ``available`` for admission and preemption decisions.

    Blocks are refcounted so the prefix cache can share pages between
    requests: ``alloc`` hands out blocks at refcount 1, ``ref`` adds a
    holder, and ``free`` drops one holder — the block returns to the
    freelist only when the last holder releases it.  The legacy
    single-owner flow (alloc -> free) is the refcount-1 special case and
    behaves exactly as before, including the double-free guard.
    """

    def __init__(self, n_groups: int, blocks_per_group: int):
        if blocks_per_group < 2:
            raise ValueError(
                f"need >= 2 blocks per group (1 is the scratch block), got "
                f"{blocks_per_group}")
        self.n_groups = n_groups
        self.blocks_per_group = blocks_per_group
        # local id 0 is the group's scratch block — never allocated
        self._free = [list(range(g * blocks_per_group + 1,
                                 (g + 1) * blocks_per_group))
                      for g in range(n_groups)]
        self._rc = {}            # block id -> live holder count (absent == 0)

    def available(self, group: int) -> int:
        return len(self._free[group])

    def capacity(self, group: int) -> int:
        return self.blocks_per_group - 1

    def scratch(self, group: int) -> int:
        return group * self.blocks_per_group

    def group_of(self, block_id: int) -> int:
        return block_id // self.blocks_per_group

    def refcount(self, block_id: int) -> int:
        return self._rc.get(block_id, 0)

    def alloc(self, group: int, n: int):
        """Pop ``n`` blocks from ``group``'s freelist; None if they don't fit."""
        free = self._free[group]
        if n > len(free):
            return None
        out = free[:n]
        del free[:n]
        for b in out:
            self._rc[b] = 1
        return out

    def ref(self, block_ids) -> None:
        """Add a holder to already-allocated blocks (prefix-cache sharing)."""
        for b in block_ids:
            if self._rc.get(b, 0) < 1:
                raise ValueError(f"ref of unallocated block {b}")
            self._rc[b] += 1

    def free(self, block_ids) -> None:
        """Drop one holder per block; last holder returns it to the freelist."""
        for b in block_ids:
            g = self.group_of(b)
            if b == self.scratch(g):
                raise ValueError(f"cannot free scratch block {b}")
            rc = self._rc.get(b, 0)
            if rc < 1:
                raise ValueError(f"double free of block {b}")
            if rc == 1:
                del self._rc[b]
                self._free[g].append(b)
            else:
                self._rc[b] = rc - 1


class PagedKVCache:
    """Pool layout + allocator for one (model, mesh, decode plan) triple."""

    def __init__(self, model, mesh, plan: Plan, cfg: PagedCacheConfig):
        ctx = model.ctx
        self.model, self.mesh, self.plan, self.cfg = model, mesh, plan, cfg
        self.group_axes = kv_group_axes(ctx, plan)
        sizes = dict(data=ctx.data, depth=ctx.depth, row=ctx.rows,
                     col=ctx.cols)
        self.n_groups = 1
        for a in self.group_axes:
            self.n_groups *= sizes[a]
        if cfg.num_blocks % self.n_groups:
            raise ValueError(
                f"num_blocks={cfg.num_blocks} must divide over "
                f"{self.n_groups} KV groups")
        self.block_size = cfg.block_size
        self.max_blocks = -(-cfg.max_seq_len // cfg.block_size)
        self.pool = BlockPool(self.n_groups,
                              cfg.num_blocks // self.n_groups)
        self.sds, self.specs = model.paged_cache_abstract(
            cfg.num_blocks, cfg.block_size, plan)

    # ------------------------------------------------------------- arrays
    def shardings(self):
        import jax
        from jax.sharding import NamedSharding, PartitionSpec
        return jax.tree.map(lambda sp: NamedSharding(self.mesh, sp),
                            self.specs,
                            is_leaf=lambda x: isinstance(x, PartitionSpec))

    def init_arrays(self):
        """Zero-initialized global pool arrays with the pool sharding."""
        import jax
        import jax.numpy as jnp
        f = jax.jit(
            lambda: jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                                 self.sds),
            out_shardings=self.shardings())
        return f()

    # ---------------------------------------------------------- accounting
    def blocks_for(self, n_positions: int) -> int:
        return -(-n_positions // self.block_size)

    def fits(self, n_positions: int) -> bool:
        """Can a sequence of this length ever be resident (table + pool)?"""
        need = self.blocks_for(n_positions)
        return (need <= self.max_blocks
                and need <= self.pool.capacity(0))

    def make_table(self, slot_blocks, slot_groups) -> np.ndarray:
        """[n_slots, max_blocks] int32 of GLOBAL ids, scratch-padded.

        slot_blocks: per-slot list of allocated block ids (empty for free /
        retired slots); slot_groups: per-slot KV group index."""
        n = len(slot_blocks)
        t = np.zeros((n, self.max_blocks), np.int32)
        for s, (blocks, g) in enumerate(zip(slot_blocks, slot_groups)):
            t[s, :] = self.pool.scratch(g)
            if blocks:
                t[s, :len(blocks)] = blocks
        return t
