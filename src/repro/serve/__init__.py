"""Continuous-batching inference engine on the Tesseract [q, q, d] mesh.

Public surface:

    EngineConfig, InferenceEngine     — engine loop (serve/engine.py)
    SamplingParams                    — per-request sampling (serve/sampling.py)
    Request, Scheduler                — admission/preemption (serve/scheduler.py)
    PagedCacheConfig, PagedKVCache    — mesh-sharded block pool (serve/kv_cache.py)
    RadixPrefixCache, PrefixHit       — shared-prompt index (serve/prefix_cache.py)
"""
from .engine import (EngineConfig, EngineStats, InferenceEngine,
                     QueueFullError)
from .kv_cache import BlockPool, PagedCacheConfig, PagedKVCache
from .prefix_cache import PrefixHit, RadixPrefixCache
from .sampling import SamplingParams, sample_tokens
from .scheduler import Request, Scheduler

__all__ = [
    "BlockPool", "EngineConfig", "EngineStats", "InferenceEngine",
    "PagedCacheConfig", "PagedKVCache", "PrefixHit", "QueueFullError",
    "RadixPrefixCache", "Request", "SamplingParams", "Scheduler",
    "sample_tokens",
]
