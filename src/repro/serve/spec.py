"""Speculative-decoding proposers for the serving engine (DESIGN.md §14).

Two proposal sources feed the engine's verify round:

- ``NgramProposer`` — model-free prompt-lookup: the longest recent n-gram
  suffix of the request's own token history is matched against its earlier
  occurrences and the continuation is proposed verbatim.  Zero extra
  compute or memory; acceptance is high exactly when decode output echoes
  the prompt (extraction, summarization, code edits).
- ``DraftRunner`` — a small config from ``src/repro/configs`` drafting on
  the SAME [data, depth, row, col] mesh: it keeps a parallel paged pool
  ([L_d, P, bs, Hkv_d, D_d]) indexed by the SAME global block ids and
  tables as the target pool, so there is no second allocator and no extra
  scheduling — capacity reserved for the target automatically covers the
  draft.  Per request a ``draft_cached`` watermark tracks how much of the
  sequence the draft pool has materialized; catch-up runs as the draft's
  own chunked prefill, then k greedy paged-decode steps emit proposals.

The draft pool is disposable state: preemption resets ``draft_cached`` to
0 and an elastic replan simply zeroes the whole pool — the next round
re-prefills it.  Target-side correctness never depends on draft contents
(rejection sampling / greedy verification gate every committed token), so
staleness can only cost acceptance rate, never parity.
"""
from __future__ import annotations

import numpy as np


class NgramProposer:
    """Prompt-lookup proposer: longest-suffix n-gram match over the
    request's own resident tokens (prompt + generated)."""

    def __init__(self, max_n: int = 3, min_n: int = 1):
        if max_n < min_n or min_n < 1:
            raise ValueError(f"bad n-gram range [{min_n}, {max_n}]")
        self.max_n, self.min_n = max_n, min_n

    def propose(self, tokens, k: int):
        """Up to ``k`` proposed continuation tokens (possibly empty)."""
        if k <= 0 or len(tokens) < self.min_n + 1:
            return []
        tokens = list(tokens)
        for n in range(min(self.max_n, len(tokens) - 1), self.min_n - 1, -1):
            suffix = tokens[-n:]
            # most recent earlier occurrence wins (local context beats
            # distant repeats)
            for i in range(len(tokens) - n - 1, -1, -1):
                if tokens[i:i + n] == suffix:
                    cont = tokens[i + n:i + n + k]
                    if cont:
                        return cont
        return []


class DraftRunner:
    """Draft-model executor sharing the target engine's block tables.

    Owns the draft pool arrays and two step bundles (paged decode + fixed-
    width chunked prefill) built from the draft model on the target's mesh
    with the target's (n_slots, num_blocks, block_size, max_blocks) — the
    pool's physical-block axis lines up 1:1 with the target pool, so any
    table the engine builds addresses both."""

    #: catch-up chunk width (single compile; gap loops over it)
    CHUNK = 16

    def __init__(self, model, mesh, params, n_slots: int, num_blocks: int,
                 block_size: int, max_blocks: int):
        import jax
        import jax.numpy as jnp
        from ..runtime.steps import (build_chunk_prefill_step,
                                     build_paged_decode_step)
        self.model, self.mesh, self.params = model, mesh, params
        self.n_slots = n_slots
        self.dec = build_paged_decode_step(model, mesh, n_slots, num_blocks,
                                           block_size, max_blocks)
        self.chunk = build_chunk_prefill_step(model, mesh, n_slots,
                                              self.CHUNK, num_blocks,
                                              block_size, max_blocks)
        self.params = jax.device_put(params, self.dec.in_shardings[0])
        self._pool_init = jax.jit(
            lambda: jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                                 self.dec.abstract_inputs[1]),
            out_shardings=self.dec.in_shardings[1])
        self.pool = self._pool_init()

    def reset(self) -> None:
        """Drop all draft KV (elastic replan / full invalidation)."""
        self.pool = self._pool_init()

    # ------------------------------------------------------------- propose
    def _catch_up(self, reqs, tables) -> None:
        """Chunk-prefill the draft pool from each request's draft_cached
        watermark to its target num_cached (0 tokens for caught-up slots)."""
        import jax.numpy as jnp
        n = self.n_slots
        while True:
            behind = [r for r in reqs if r.draft_cached < r.num_cached]
            if not behind:
                return
            ids = np.zeros((n, self.CHUNK), np.int32)
            pos = np.zeros((n,), np.int32)
            lens = np.zeros((n,), np.int32)
            for r in behind:
                s = r.slot
                t = min(self.CHUNK, r.num_cached - r.draft_cached)
                ids[s, :t] = r.seq_tokens[r.draft_cached:r.draft_cached + t]
                pos[s] = r.draft_cached
                lens[s] = t
            _, self.pool = self.chunk.fn(self.params, self.pool,
                                         jnp.asarray(tables),
                                         jnp.asarray(pos), jnp.asarray(lens),
                                         jnp.asarray(ids))
            for r in behind:
                r.draft_cached += min(self.CHUNK,
                                      r.num_cached - r.draft_cached)

    def propose(self, reqs, tables, k_eff: dict):
        """Greedy draft proposals per request: {rid: [tokens...]}.

        reqs: running requests with last_token set; tables: the engine's
        [n_slots, max_blocks] GLOBAL table (capacity for num_cached +
        k_eff + 1 already reserved); k_eff: rid -> proposal budget.  Slots
        whose budget is exhausted stay in the fixed-shape batch frozen at
        their last (pos, token) — the rewrite is idempotent, so no
        per-step table rebuild is needed."""
        import jax.numpy as jnp
        self._catch_up(reqs, tables)
        props = {r.rid: [] for r in reqs}
        kmax = max(k_eff.values(), default=0)
        if kmax == 0:
            return props
        n = self.n_slots
        cur_id = np.zeros((n, 1), np.int32)
        cur_pos = np.zeros((n,), np.int32)
        for r in reqs:
            cur_id[r.slot, 0] = r.last_token
            cur_pos[r.slot] = r.num_cached
        tables = jnp.asarray(tables)
        for j in range(kmax):
            lg, self.pool = self.dec.fn(self.params, self.pool, tables,
                                        jnp.asarray(cur_pos),
                                        jnp.asarray(cur_id))
            nxt = np.asarray(lg).argmax(-1)
            for r in reqs:
                if j < k_eff[r.rid]:
                    t = int(nxt[r.slot])
                    props[r.rid].append(t)
                    cur_id[r.slot, 0] = t
                    cur_pos[r.slot] += 1
        return props
