"""Per-request token sampling over full-vocab decode logits.

The step functions return ``[B, v_pad]`` float32 logits with padded vocab
masked to -inf (``OpSet.head_logits``).  Sampling is one jitted, vmapped
function over the fixed-shape slot batch: each slot carries its own
(temperature, top_k, top_p, seed) and the PRNG is ``fold_in(PRNGKey(seed),
position)`` so a request's random stream depends only on its seed and the
absolute position of the token being sampled — preemption + re-prefill
replays the identical trajectory.

``temperature == 0`` rows take the greedy path: a plain argmax over the
gathered logits, bit-identical to the dense loop's ``distributed_argmax``
(same per-shard values, ties broken toward the smallest vocab id in both).
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class SamplingParams:
    temperature: float = 0.0     # 0 = greedy
    top_k: int = 0               # 0 = off
    top_p: float = 1.0           # 1 = off
    seed: int = 0
    max_new_tokens: int = 16


def mask_top_k(logits, k):
    """Keep the k highest logits of one row; k <= 0 keeps all."""
    v = logits.shape[-1]
    order = jnp.argsort(-logits)
    ranks = jnp.argsort(order)                  # rank of each vocab entry
    kk = jnp.where(k <= 0, v, k)
    return jnp.where(ranks < kk, logits, -jnp.inf)


def mask_top_p(logits, p):
    """Nucleus: keep the smallest prefix of the sorted distribution whose
    probability mass reaches p; p >= 1 keeps all.

    Boundary contract (ISSUE 9): token i (in sorted order) is kept iff the
    EXCLUSIVE prefix mass before it is < p, computed from the shifted
    cumsum — not ``cum - probs``, whose per-element cancellation error
    flips tokens sitting exactly on a cumsum edge.  The first token whose
    cumulative probability crosses p is therefore always kept, the top
    token is kept even when p <= probs[0] (p=0 degenerates to greedy, not
    to an empty support), and ties at equal logits resolve deterministically
    toward the smaller vocab id (stable argsort)."""
    order = jnp.argsort(-logits, stable=True)
    sorted_logits = logits[order]
    probs = jax.nn.softmax(sorted_logits)
    cum = jnp.cumsum(probs)
    excl = jnp.concatenate([jnp.zeros((1,), cum.dtype), cum[:-1]])
    keep_sorted = excl < p                      # first crossing included
    keep_sorted = keep_sorted.at[0].set(True)   # never empty support
    keep = jnp.zeros(logits.shape[-1], bool).at[order].set(keep_sorted)
    keep = keep | (p >= 1.0)
    return jnp.where(keep, logits, -jnp.inf)


def _sample_one(logits, temperature, top_k, top_p, seed, position):
    greedy = jnp.argmax(logits, axis=-1)
    lg = logits / jnp.maximum(temperature, 1e-6)
    lg = mask_top_k(lg, top_k)
    lg = mask_top_p(lg, top_p)
    key = jax.random.fold_in(jax.random.PRNGKey(seed), position)
    g = jax.random.gumbel(key, lg.shape, jnp.float32)
    sampled = jnp.argmax(lg + g, axis=-1)       # gumbel-max == categorical
    return jnp.where(temperature <= 0.0, greedy, sampled).astype(jnp.int32)


@partial(jax.jit, static_argnums=())
def sample_tokens(logits, temperature, top_k, top_p, seed, position):
    """logits [B, v_pad] f32; the rest are [B] per-slot arrays.

    position: absolute sequence position each sampled token will occupy
    (the PRNG fold step).  Returns [B] int32 token ids."""
    return jax.vmap(_sample_one)(logits, temperature, top_k, top_p, seed,
                                 position)


def slot_arrays(params_list):
    """Stack per-slot SamplingParams into the sampler's input arrays."""
    import numpy as np
    return (np.array([p.temperature for p in params_list], np.float32),
            np.array([p.top_k for p in params_list], np.int32),
            np.array([p.top_p for p in params_list], np.float32),
            np.array([p.seed for p in params_list], np.int32))


# ---------------------------------------------------------------- speculation
# Rejection sampling for speculative decoding (Leviathan et al. 2023): the
# committed token at every position is marginally distributed EXACTLY as the
# plain sampler's token at that position.  The target distribution is the
# same temperature -> top_k -> top_p chain _sample_one draws through, made
# explicit as probabilities; proposals from a point-mass proposer (n-gram
# lookup) are the q = e_d special case.  All draws are keyed on
# (seed, absolute position) like _sample_one, so eviction + re-prefill
# replays the identical accept/reject trajectory.

def _masked_probs_one(logits, temperature, top_k, top_p):
    lg = logits / jnp.maximum(temperature, 1e-6)
    lg = mask_top_k(lg, top_k)
    lg = mask_top_p(lg, top_p)
    return jax.nn.softmax(lg)


@partial(jax.jit, static_argnums=())
def spec_target_probs(logits, temperature, top_k, top_p):
    """logits [R, v_pad] -> [R, v_pad] post-mask sampling distributions.

    Row r is the categorical _sample_one draws from at temperature>0 —
    the target p of the accept/reject test.  Scalars broadcast per row."""
    R = logits.shape[0]
    b = lambda a: jnp.broadcast_to(jnp.asarray(a), (R,))
    return jax.vmap(_masked_probs_one)(logits, b(temperature), b(top_k),
                                       b(top_p))


def _spec_key(seed, position, tag):
    """Sub-key for the accept (tag 1) / residual (tag 2) draws — distinct
    from the bare (seed, position) key _sample_one consumes."""
    k = jax.random.fold_in(jax.random.PRNGKey(seed), position)
    return jax.random.fold_in(k, tag)


def spec_accept(row_probs, proposals, draft_probs, seed, pos0):
    """Host-side accept/reject over one slot's verify rows.

    row_probs: [C, V] float target distributions (row c governs the token
    at absolute position pos0 + c + 1); proposals: length-(C-1) int draft
    tokens (proposal c is judged by row c); draft_probs: None for
    point-mass proposers, else [C-1, V] draft distributions q.

    Returns (tokens, n_accepted): ``tokens`` commits one token per judged
    row up to and including the first rejection — accepted proposals
    verbatim, then one token from the residual max(p - q, 0)/Z.  When every
    proposal is accepted the caller appends the bonus token drawn by the
    plain sampler from the final row.  Accept draws use sub-key tag 1 and
    residual draws tag 2 at the committed token's own position, so the
    stream is independent of the bonus-token stream and replay-stable."""
    import numpy as np
    tokens, n_acc = [], 0
    for c, d in enumerate(proposals):
        d = int(d)
        p = np.asarray(row_probs[c], np.float64)
        q_d = 1.0 if draft_probs is None else float(draft_probs[c][d])
        position = int(pos0) + c + 1
        u = float(jax.random.uniform(_spec_key(seed, position, 1)))
        if u * q_d < p[d] or q_d <= 0.0:
            tokens.append(d)
            n_acc += 1
            continue
        # rejected: draw the correction from the residual distribution
        if draft_probs is None:
            r = p.copy()
            r[d] = 0.0
        else:
            r = np.maximum(p - np.asarray(draft_probs[c], np.float64), 0.0)
        z = r.sum()
        if z <= 0.0:
            # p == q numerically: any p-distributed draw is correct
            r, z = p, p.sum()
        gkey = _spec_key(seed, position, 2)
        g = np.asarray(jax.random.gumbel(gkey, (r.shape[0],), jnp.float32),
                       np.float64)
        logr = np.where(r > 0.0, np.log(np.maximum(r / z, 1e-300)), -np.inf)
        tokens.append(int(np.argmax(logr + g)))
        break
    return tokens, n_acc
