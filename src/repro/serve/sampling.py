"""Per-request token sampling over full-vocab decode logits.

The step functions return ``[B, v_pad]`` float32 logits with padded vocab
masked to -inf (``OpSet.head_logits``).  Sampling is one jitted, vmapped
function over the fixed-shape slot batch: each slot carries its own
(temperature, top_k, top_p, seed) and the PRNG is ``fold_in(PRNGKey(seed),
position)`` so a request's random stream depends only on its seed and the
absolute position of the token being sampled — preemption + re-prefill
replays the identical trajectory.

``temperature == 0`` rows take the greedy path: a plain argmax over the
gathered logits, bit-identical to the dense loop's ``distributed_argmax``
(same per-shard values, ties broken toward the smallest vocab id in both).
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class SamplingParams:
    temperature: float = 0.0     # 0 = greedy
    top_k: int = 0               # 0 = off
    top_p: float = 1.0           # 1 = off
    seed: int = 0
    max_new_tokens: int = 16


def mask_top_k(logits, k):
    """Keep the k highest logits of one row; k <= 0 keeps all."""
    v = logits.shape[-1]
    order = jnp.argsort(-logits)
    ranks = jnp.argsort(order)                  # rank of each vocab entry
    kk = jnp.where(k <= 0, v, k)
    return jnp.where(ranks < kk, logits, -jnp.inf)


def mask_top_p(logits, p):
    """Nucleus: keep the smallest prefix of the sorted distribution whose
    probability mass reaches p; p >= 1 keeps all."""
    order = jnp.argsort(-logits)
    sorted_logits = logits[order]
    probs = jax.nn.softmax(sorted_logits)
    cum = jnp.cumsum(probs)
    keep_sorted = (cum - probs) < p             # first crossing included
    keep = jnp.zeros(logits.shape[-1], bool).at[order].set(keep_sorted)
    keep = keep | (p >= 1.0)
    return jnp.where(keep, logits, -jnp.inf)


def _sample_one(logits, temperature, top_k, top_p, seed, position):
    greedy = jnp.argmax(logits, axis=-1)
    lg = logits / jnp.maximum(temperature, 1e-6)
    lg = mask_top_k(lg, top_k)
    lg = mask_top_p(lg, top_p)
    key = jax.random.fold_in(jax.random.PRNGKey(seed), position)
    g = jax.random.gumbel(key, lg.shape, jnp.float32)
    sampled = jnp.argmax(lg + g, axis=-1)       # gumbel-max == categorical
    return jnp.where(temperature <= 0.0, greedy, sampled).astype(jnp.int32)


@partial(jax.jit, static_argnums=())
def sample_tokens(logits, temperature, top_k, top_p, seed, position):
    """logits [B, v_pad] f32; the rest are [B] per-slot arrays.

    position: absolute sequence position each sampled token will occupy
    (the PRNG fold step).  Returns [B] int32 token ids."""
    return jax.vmap(_sample_one)(logits, temperature, top_k, top_p, seed,
                                 position)


def slot_arrays(params_list):
    """Stack per-slot SamplingParams into the sampler's input arrays."""
    import numpy as np
    return (np.array([p.temperature for p in params_list], np.float32),
            np.array([p.top_k for p in params_list], np.int32),
            np.array([p.top_p for p in params_list], np.float32),
            np.array([p.seed for p in params_list], np.int32))
