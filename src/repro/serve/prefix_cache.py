"""Radix prefix cache over the paged block pool (DESIGN.md §12).

Multi-tenant traffic shares long prompt prefixes (system prompts, few-shot
templates).  This module maps those shared prefixes to *shared pages* in
the existing ``BlockPool``: a per-KV-group radix tree whose edges are
full-block token keys (tuples of ``block_size`` token ids) and whose nodes
hold one physical block id each.  A request whose prompt walks q full
edges reuses those q pages verbatim — the pool refcount tracks every
holder, so a page is only returned to the freelist when the last request
AND the cache itself have released it.

Copy-on-write: when the common prefix ends *inside* a cached block (r
tokens into it, 0 < r < block_size), the block cannot be shared — the
request will write its own tokens into positions r.. of that block.  The
lookup reports the cached block as a COW *donor* (``cow_src``/``cow_len``)
and the engine copies the donor page into a freshly-allocated private
block before prefilling the suffix.  Shared pages are therefore never
mutated: decode only ever appends at positions >= len(prompt), which live
in the request's private tail blocks, and divergent prefixes write into
private COW copies.

Eviction: leaves whose page has refcount 1 (the cache is the only holder)
are reclaimable, oldest ``last_use`` first.  Interior nodes become leaves
as their children go; pages still referenced by running requests are
never candidates — eviction respects refcounts by construction (the
``serve.prefix`` fault site drives this under test).  ``flush`` drops the
whole index (elastic replans rebuild the pool, so cached ids die with it).
"""
from __future__ import annotations

from dataclasses import dataclass, field


class _Node:
    __slots__ = ("key", "block", "parent", "children", "last_use")

    def __init__(self, key, block, parent):
        self.key = key            # tuple of block_size token ids
        self.block = block        # global physical block id
        self.parent = parent
        self.children = {}        # key tuple -> _Node
        self.last_use = 0


@dataclass
class PrefixHit:
    """Result of a lookup: how much of a prompt the cache can supply."""
    tokens: int                   # cached positions usable by this request
    full_blocks: list             # shared page ids covering tokens // bs
    cow_src: int | None = None    # donor page for a partial tail block
    cow_len: int = 0              # valid positions inside the donor
    nodes: list = field(default_factory=list)   # tree path (for LRU touch)


class RadixPrefixCache:
    """Per-group radix index of prompt prefixes -> refcounted pool pages."""

    def __init__(self, pool, block_size: int):
        self.pool = pool
        self.block_size = block_size
        self._roots = [{} for _ in range(pool.n_groups)]  # key -> _Node
        self._clock = 0
        # counters (engine folds these into EngineStats)
        self.evictions = 0
        self.flushes = 0

    # ------------------------------------------------------------ queries
    def __len__(self):
        n = 0
        stack = [c for root in self._roots for c in root.values()]
        while stack:
            node = stack.pop()
            n += 1
            stack.extend(node.children.values())
        return n

    def cached_blocks(self, group: int):
        out = []
        stack = list(self._roots[group].values())
        while stack:
            node = stack.pop()
            out.append(node.block)
            stack.extend(node.children.values())
        return out

    def lookup(self, group: int, tokens, limit: int) -> PrefixHit:
        """Longest cached prefix of ``tokens``, capped at ``limit`` positions.

        ``limit`` is len(seq) - 1 in practice: the engine must run at least
        one real position through the model to produce the next token, so a
        whole-prompt hit is clamped — the clamp may demote the last fully
        matched block to a COW donor.
        """
        self._clock += 1
        bs = self.block_size
        node_map = self._roots[group]
        matched = []                       # full-block path nodes
        q = 0
        while (q + 1) * bs <= len(tokens):
            key = tuple(tokens[q * bs:(q + 1) * bs])
            child = node_map.get(key)
            if child is None:
                break
            matched.append(child)
            node_map = child.children
            q += 1
        # best partial continuation: a child sharing r > 0 leading tokens
        # with the next (possibly short) prompt segment
        seg = tuple(tokens[q * bs:(q + 1) * bs])
        partial, r = None, 0
        if seg:
            for key, child in node_map.items():
                m = 0
                for a, b in zip(key, seg):
                    if a != b:
                        break
                    m += 1
                if m > r:
                    partial, r = child, m
        raw = q * bs + r
        hit_tokens = min(raw, limit)
        if hit_tokens <= 0:
            return PrefixHit(tokens=0, full_blocks=[])
        n_full = hit_tokens // bs
        cow_len = hit_tokens - n_full * bs
        if cow_len:
            donor = matched[n_full] if n_full < len(matched) else partial
            cow_src = donor.block
            path = matched[:n_full] + [donor]
        else:
            cow_src = None
            path = matched[:n_full]
        now = self._clock
        for nd in path:
            nd.last_use = now
        return PrefixHit(tokens=hit_tokens,
                         full_blocks=[nd.block for nd in matched[:n_full]],
                         cow_src=cow_src, cow_len=cow_len, nodes=path)

    # ------------------------------------------------------------ updates
    def insert(self, group: int, tokens, block_ids) -> int:
        """Index a fully-prefilled prompt's full blocks; returns new nodes.

        ``block_ids`` are the request's resident pages, position-aligned
        with ``tokens``.  Existing nodes win (the request's duplicate page
        stays private to it); new nodes take a cache-owned reference on the
        request's page, so it survives the request's retirement.
        """
        self._clock += 1
        bs = self.block_size
        node_map, parent = self._roots[group], None
        added = 0
        n_full = min(len(tokens) // bs, len(block_ids))
        for i in range(n_full):
            key = tuple(tokens[i * bs:(i + 1) * bs])
            child = node_map.get(key)
            if child is None:
                child = _Node(key, block_ids[i], parent)
                self.pool.ref([child.block])
                node_map[key] = child
                added += 1
            child.last_use = self._clock
            node_map, parent = child.children, child
        return added

    def evict(self, group: int, want: int, protect=()) -> int:
        """Free up to ``want`` pool blocks by dropping cold shareable leaves.

        Only leaves whose page refcount is 1 (cache-only holder) return
        capacity; shared pages are left alone — eviction can never pull a
        page out from under a running request.  ``protect`` pins block ids
        (a just-looked-up hit path) against eviction.  Returns blocks freed.
        """
        freed = 0
        while freed < want:
            victim = None
            stack = [(None, k, n) for k, n in self._roots[group].items()]
            while stack:
                pmap_owner, key, node = stack.pop()
                if not node.children:
                    if (self.pool.refcount(node.block) == 1
                            and node.block not in protect
                            and (victim is None
                                 or node.last_use < victim[2].last_use)):
                        victim = (pmap_owner, key, node)
                else:
                    stack.extend((node, k, c)
                                 for k, c in node.children.items())
            if victim is None:
                break
            owner, key, node = victim
            (owner.children if owner is not None
             else self._roots[group]).pop(key)
            self.pool.free([node.block])
            self.evictions += 1
            freed += 1
        return freed

    def flush(self) -> int:
        """Drop the whole index, releasing every cache-held page reference."""
        dropped = 0
        for g in range(self.pool.n_groups):
            for b in self.cached_blocks(g):
                self.pool.free([b])
                dropped += 1
            self._roots[g] = {}
        self.flushes += 1
        return dropped
