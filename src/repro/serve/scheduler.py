"""Continuous-batching scheduler: admission, slot assignment, preemption.

Policy (vLLM-style, adapted to the mesh-sharded pool):

- The decode batch is ``n_slots`` fixed shape slots, split contiguously
  across the KV groups (slot s belongs to group ``s // slots_per_group`` —
  the same contiguous split the token-sharding collectives use, so a slot's
  activations and its pages land on the same devices).
- **Admission**: a free slot takes the oldest waiting request whose whole
  resident sequence (prompt + already-generated tokens after a preemption)
  fits the slot's group freelist.  FCFS with holes: a younger short request
  may pass an older one that doesn't fit yet.
- **Growth**: before each decode step every running request that is about
  to cross a block boundary gets one more block from its group.
- **Preemption by eviction**: if the group freelist is empty, the
  youngest-admitted running request in that group is evicted — its blocks
  are freed, its generated-so-far tokens are folded into its prompt, and it
  re-enters the FRONT of the waiting queue for a later re-prefill.  The
  sampler's position-keyed PRNG makes the replayed trajectory identical.
"""
from __future__ import annotations

import itertools
from collections import deque

from .kv_cache import PagedKVCache
from .sampling import SamplingParams

_RID = itertools.count()

WAITING, RUNNING, FINISHED, FAILED = "waiting", "running", "finished", "failed"


class Request:
    def __init__(self, prompt, sampling: SamplingParams | None = None,
                 eos_id: int = -1, rid=None, deadline_s: float | None = None,
                 ttft_budget_s: float | None = None, arrival_t: float = 0.0):
        if len(prompt) == 0:
            raise ValueError("empty prompt")
        self.rid = rid if rid is not None else next(_RID)
        self.prompt = [int(t) for t in prompt]  # grows on preemption
        self.orig_prompt_len = len(self.prompt)
        # default is constructed per call: a shared default instance would
        # alias sampling state across every request created without one
        self.sampling = SamplingParams() if sampling is None else sampling
        self.eos_id = eos_id
        # --- SLO guardrails (DESIGN.md §11): wall-clock budgets the engine
        # enforces with its own clock; None = no budget
        self.deadline_s = deadline_s         # total completion budget
        self.ttft_budget_s = ttft_budget_s   # time-to-first-token budget
        self.arrival_t = arrival_t           # engine clock at add_request
        self.first_token_t: float | None = None
        self.last_emit_t: float | None = None
        self.nan_retries = 0                 # quarantine -> re-prefill count
        self.fail_reason = ""                # set when state == FAILED
        self.out_tokens: list = []   # generated since last (re-)prefill
        self.state = WAITING
        self.slot = None
        self.block_ids: list = []
        self.num_cached = 0          # positions materialized in the pool
        self.last_token = None       # next decode step's input token
        self.preemptions = 0
        self.admit_seq = -1          # admission order (preemption priority)
        self.prefix_hit = None       # PrefixHit consumed by the engine
        # --- speculative decoding (DESIGN.md §14) ---
        # positions the DRAFT pool has materialized; disposable (reset on
        # preemption — the draft re-prefills, target parity never depends
        # on it)
        self.draft_cached = 0
        # positions the next decode round will write (1 = plain decode;
        # 1 + k proposals when the engine speculates) — capacity accounting
        self.spec_lookahead = 1
        # --- prefill accounting (ISSUE 9 satellite): prompt positions
        # already counted into EngineStats.prefix_tokens_* (once per
        # request, not per admission) and the highest position ever
        # materialized (survives preemption — replayed chunks are not new
        # work).  Neither is reset by preempt().
        self.prefill_counted = 0
        self.prefill_high = 0

    @property
    def seq_tokens(self):
        """Full resident sequence (prompt + generated) — re-prefill input."""
        return self.prompt + self.out_tokens

    @property
    def generated(self):
        """All tokens generated for this request, across preemptions."""
        return self.seq_tokens[self.orig_prompt_len:]

    @property
    def target_len(self) -> int:
        return self.orig_prompt_len + self.sampling.max_new_tokens

    @property
    def finished(self) -> bool:
        g = self.generated
        return (len(g) >= self.sampling.max_new_tokens
                or (self.eos_id >= 0 and bool(g) and g[-1] == self.eos_id))


class Scheduler:
    def __init__(self, cache: PagedKVCache, n_slots: int):
        if n_slots % cache.n_groups:
            raise ValueError(
                f"n_slots={n_slots} must divide over {cache.n_groups} "
                f"KV groups")
        self.cache = cache
        self.n_slots = n_slots
        self.slots_per_group = n_slots // cache.n_groups
        self.slots: list = [None] * n_slots
        self.waiting: deque = deque()
        self._admit_clock = 0
        # Admission cap <= n_slots: the engine lowers it (graceful decode-
        # batch shrink) after repeated pool-OOM preemption storms and raises
        # it back once the pool calms down.  Only gates NEW admissions —
        # requests already running are never evicted by a cap change.
        self.max_active = n_slots
        # Optional RadixPrefixCache (engine attaches it): admission then
        # consults the cache for shared prefix pages.  None keeps the
        # legacy slot-major admission byte-for-byte.
        self.prefix_cache = None
        # Requests FAILED at admission (prompt can never be resident, e.g.
        # after an elastic shrink); the engine drains this list.
        self.admission_failures: list = []

    # ------------------------------------------------------------- helpers
    def group_of_slot(self, slot: int) -> int:
        return slot // self.slots_per_group

    @property
    def running(self):
        return [r for r in self.slots if r is not None]

    @property
    def has_work(self) -> bool:
        return bool(self.waiting) or any(self.slots)

    # ------------------------------------------------------------ lifecycle
    def add(self, req: Request) -> Request:
        # target_len + 1: the final sampled token's position is written by
        # the decode step that produces it.
        if not self.cache.fits(req.target_len):
            raise ValueError(
                f"request {req.rid}: target length {req.target_len} can "
                f"never be resident (max_seq_len / pool capacity)")
        self.waiting.append(req)
        return req

    def admit(self):
        """Fill free slots from the waiting queue; returns admitted requests
        (the engine prefills them and sets num_cached/last_token)."""
        # A request whose resident sequence can never fit the pool (possible
        # after an elastic shrink rebuilt a smaller cache) would otherwise
        # sit unadmittable forever and wedge the engine loop: FAIL it here
        # with a clear reason.  On an unchanged cache this never fires —
        # add() already gated fits(target_len) >= fits(len(seq)+1).
        for req in [r for r in self.waiting
                    if not self.cache.fits(len(r.seq_tokens) + 1)]:
            self.waiting.remove(req)
            req.state = FAILED
            req.fail_reason = (
                f"prompt of {len(req.seq_tokens)} tokens can never be "
                f"resident: needs {self.cache.blocks_for(len(req.seq_tokens) + 1)} "
                f"blocks, pool capacity is {self.cache.pool.capacity(0)} "
                f"blocks/group")
            self.admission_failures.append(req)
        if self.prefix_cache is not None:
            return self._admit_with_prefix_cache()
        admitted = []
        for slot in range(self.n_slots):
            if len(self.running) >= self.max_active:
                break
            if self.slots[slot] is not None:
                continue
            g = self.group_of_slot(slot)
            pick = None
            for req in self.waiting:
                # +1: the first decode step after prefill writes position
                # len(seq); reserving it now avoids paying a full prefill
                # only to self-evict in the same engine step when the
                # prompt exactly fills its blocks and the freelist is dry.
                if self.cache.blocks_for(len(req.seq_tokens) + 1) \
                        <= self.cache.pool.available(g):
                    pick = req
                    break
            if pick is None:
                continue
            self.waiting.remove(pick)
            blocks = self.cache.pool.alloc(
                g, self.cache.blocks_for(len(pick.seq_tokens) + 1))
            assert blocks is not None
            pick.block_ids = blocks
            pick.slot = slot
            pick.state = RUNNING
            pick.admit_seq = self._admit_clock
            self._admit_clock += 1
            self.slots[slot] = pick
            admitted.append(pick)
        return admitted

    def _admit_with_prefix_cache(self):
        """Admission consulting the radix cache: a hit's full blocks are
        shared (pool.ref), only the remainder is freshly allocated, and a
        dry freelist first evicts cold cache leaves before giving up on a
        candidate.  Same FCFS-with-holes policy as the legacy loop."""
        pc = self.prefix_cache
        admitted = []
        for slot in range(self.n_slots):
            if len(self.running) >= self.max_active:
                break
            if self.slots[slot] is not None:
                continue
            g = self.group_of_slot(slot)
            pick = hit = None
            for req in self.waiting:
                seq = req.seq_tokens
                h = pc.lookup(g, seq, len(seq) - 1)
                need_new = (self.cache.blocks_for(len(seq) + 1)
                            - len(h.full_blocks))
                short = need_new - self.cache.pool.available(g)
                if short > 0:
                    # cold shareable leaves first; the hit path is pinned
                    pc.evict(g, short,
                             protect=set(h.full_blocks)
                             | ({h.cow_src} if h.cow_src is not None
                                else set()))
                    short = need_new - self.cache.pool.available(g)
                if short <= 0:
                    pick, hit = req, h
                    break
            if pick is None:
                continue
            self.waiting.remove(pick)
            need_new = (self.cache.blocks_for(len(pick.seq_tokens) + 1)
                        - len(hit.full_blocks))
            fresh = self.cache.pool.alloc(g, need_new)
            assert fresh is not None
            self.cache.pool.ref(hit.full_blocks)   # request's own hold
            pick.block_ids = list(hit.full_blocks) + fresh
            pick.prefix_hit = hit
            pick.slot = slot
            pick.state = RUNNING
            pick.admit_seq = self._admit_clock
            self._admit_clock += 1
            self.slots[slot] = pick
            admitted.append(pick)
        return admitted

    def preempt(self, req: Request) -> None:
        """Evict: free pages, fold generated tokens into the prompt, requeue
        at the front for re-prefill."""
        self.cache.pool.free(req.block_ids)
        req.block_ids = []
        # generated-so-far tokens fold into the re-prefill prompt; the
        # request's identity (orig_prompt_len, sampling, target_len) is
        # untouched, so completion accounting and the position-keyed PRNG
        # replay the identical trajectory.
        req.prompt = req.seq_tokens
        req.out_tokens = []
        req.slot = None
        req.num_cached = 0
        req.last_token = None
        req.prefix_hit = None
        req.draft_cached = 0         # draft pages may be reallocated
        req.spec_lookahead = 1
        req.state = WAITING
        req.preemptions += 1
        self.waiting.appendleft(req)

    def ensure_decode_capacity(self):
        """Give every running request room for its next position(s);
        preempt youngest-first inside a group when its freelist runs dry.
        ``spec_lookahead`` is the number of positions the next round may
        write (1 = plain decode, 1 + k when the engine speculates — the k
        in-flight draft tokens need resident pages before verification).
        Returns the requests preempted this round."""
        preempted = []
        for slot in range(self.n_slots):
            req = self.slots[slot]
            if req is None:
                continue
            need = self.cache.blocks_for(req.num_cached
                                         + max(1, req.spec_lookahead))
            while need > len(req.block_ids):
                g = self.group_of_slot(slot)
                got = self.cache.pool.alloc(g, 1)
                if got is None and self.prefix_cache is not None \
                        and self.prefix_cache.evict(g, 1):
                    got = self.cache.pool.alloc(g, 1)
                if got is not None:
                    req.block_ids.extend(got)
                    continue
                victim = max(
                    (r for r in self.running
                     if self.group_of_slot(r.slot) == g),
                    key=lambda r: r.admit_seq)
                vslot = victim.slot
                self.slots[vslot] = None
                self.preempt(victim)
                preempted.append(victim)
                if victim is req:
                    break
        return preempted

    def retire(self, req: Request) -> None:
        self.cache.pool.free(req.block_ids)
        req.block_ids = []
        self.slots[req.slot] = None
        req.slot = None
        req.prefix_hit = None
        req.state = FINISHED
