"""Continuous-batching inference engine over the Tesseract mesh.

One ``InferenceEngine.step`` is: admit waiting requests into free slots,
prefill them (bucketed fixed shapes, per-request true lengths), reshard the
prefill cache into the paged pool, run ONE fixed-shape paged decode step for
the whole slot batch (mixed lengths, block-table gather/scatter), sample
per-request, retire finished sequences in place.  See DESIGN.md §7.

The decode batch shape never changes across steps — batch composition does:
retired slots point at their group's scratch block until re-admission, so
the step function compiles exactly once per engine.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field

import numpy as np

from ..configs.base import ShapeSpec
from ..runtime.steps import (build_paged_decode_step, build_paged_reshard,
                             build_prefill_step, make_plan)
from .kv_cache import PagedCacheConfig, PagedKVCache
from .sampling import SamplingParams, sample_tokens, slot_arrays
from .scheduler import Request, Scheduler


@dataclass(frozen=True)
class EngineConfig:
    n_slots: int = 4
    block_size: int = 8
    num_blocks: int = 64         # global, across all KV groups
    max_seq_len: int = 256
    prefill_batch: int = 0       # 0 -> ctx.data (smallest valid)
    eos_id: int = -1


@dataclass
class EngineStats:
    steps: int = 0
    prefills: int = 0
    preemptions: int = 0
    tokens: int = 0
    token_times: list = field(default_factory=list)  # seconds per emitted token
    wall: float = 0.0

    def tokens_per_s(self) -> float:
        return self.tokens / self.wall if self.wall else 0.0

    def latency_percentiles(self):
        if not self.token_times:
            return {"p50_ms": 0.0, "p95_ms": 0.0}
        t = np.array(self.token_times) * 1e3
        return {"p50_ms": float(np.percentile(t, 50)),
                "p95_ms": float(np.percentile(t, 95))}


class InferenceEngine:
    def __init__(self, model, mesh, params, cfg: EngineConfig):
        self.model, self.mesh, self.params, self.cfg = model, mesh, params, cfg
        self._build()

    # ---------------------------------------------------------------- build
    def _build(self):
        model, mesh, cfg = self.model, self.mesh, self.cfg
        ctx = model.ctx
        if not hasattr(model, "decode_paged"):
            raise NotImplementedError(
                f"{type(model).__name__} has no paged decode path")
        # resolved attention data path (DESIGN.md §10) — surfaced so
        # operators can see which decode kernel a serve process runs;
        # elastic replans re-resolve (replace() preserves ctx.attn_impl)
        from ..kernels.ops import effective_attn_impl
        self.attn_impl = effective_attn_impl(ctx.attn_impl)
        self.plan = make_plan(ctx, ShapeSpec("serve", 1, cfg.n_slots,
                                             "decode"))
        if self.plan.kind == "decode" and cfg.n_slots % ctx.batch_shards:
            raise ValueError(
                f"n_slots={cfg.n_slots} must divide over "
                f"{ctx.batch_shards} token shards (or be < them to "
                f"downgrade the plan)")
        self.cache = PagedKVCache(
            model, mesh, self.plan,
            PagedCacheConfig(num_blocks=cfg.num_blocks,
                             block_size=cfg.block_size,
                             max_seq_len=cfg.max_seq_len))
        self.sched = Scheduler(self.cache, cfg.n_slots)
        self.pool = self.cache.init_arrays()
        self.dec = build_paged_decode_step(
            model, mesh, cfg.n_slots, cfg.num_blocks, cfg.block_size,
            self.cache.max_blocks)
        self._prefill_bundles = {}   # bucket_len -> (prefill, reshard)
        self._b_pre = cfg.prefill_batch or max(1, ctx.data)
        if self._b_pre % max(1, ctx.data):
            raise ValueError("prefill_batch must divide over data")
        # sequence-shard divisor for prefill buckets
        if ctx.mode == "megatron1d":
            self._seq_div = ctx.cols
        else:
            self._seq_div = ctx.depth * ctx.rows
        if not hasattr(self, "stats"):      # survives replan rebuilds
            self.stats = EngineStats()
            self.requests = []

    def _bucket(self, n: int) -> int:
        """Prefill bucket covering ``n`` tokens: power-of-two multiples of
        lcm(block_size, seq shards) — divisible by both the reshard's block
        split and the sequence sharding — clamped to the pool's maximum
        resident length (Scheduler.add guarantees n fits that)."""
        import math
        base = math.lcm(self.cfg.block_size, self._seq_div)
        cap = -(-self.cache.max_blocks * self.cfg.block_size // base) * base
        b = base
        while b < n and b < cap:
            b = min(b * 2, cap)
        return b

    def _prefill_for(self, bucket: int):
        if bucket not in self._prefill_bundles:
            shape = ShapeSpec("ep", bucket, self._b_pre, "prefill")
            pre = build_prefill_step(self.model, self.mesh, shape,
                                     with_lengths=True)
            reshard = build_paged_reshard(
                self.model, self.mesh, self._b_pre, bucket,
                self.cfg.num_blocks, self.cfg.block_size, self.plan)
            self._prefill_bundles[bucket] = (pre, reshard)
        return self._prefill_bundles[bucket]

    # ------------------------------------------------------------- requests
    def add_request(self, prompt, sampling: SamplingParams = SamplingParams(),
                    rid=None) -> Request:
        req = Request(prompt, sampling, eos_id=self.cfg.eos_id, rid=rid)
        self.requests.append(req)
        return self.sched.add(req)

    # -------------------------------------------------------------- prefill
    def _run_prefills(self, admitted):
        """Bucketed, batched prefill of newly admitted requests + reshard of
        their caches into the paged pool.  Returns the number of tokens
        emitted (one per request — counted here because a same-step
        preemption folds out_tokens away before step()'s accounting)."""
        admitted = sorted(admitted, key=lambda r: len(r.seq_tokens))
        for i in range(0, len(admitted), self._b_pre):
            chunk = admitted[i:i + self._b_pre]
            bucket = self._bucket(max(len(r.seq_tokens) for r in chunk))
            pre, reshard = self._prefill_for(bucket)
            tokens = np.zeros((self._b_pre, bucket), np.int32)
            lengths = np.ones((self._b_pre,), np.int32)
            nb_bucket = bucket // self.cfg.block_size
            # scatter table: rows/blocks without a real target hit scratch
            tables = np.zeros((self._b_pre, nb_bucket), np.int32)
            tables[:, :] = self.cache.pool.scratch(0)
            for j, req in enumerate(chunk):
                seq = req.seq_tokens
                tokens[j, :len(seq)] = seq
                lengths[j] = len(seq)
                nb_req = self.cache.blocks_for(len(seq))
                tables[j, :nb_req] = req.block_ids[:nb_req]
            logits, pcache = pre.fn(self.params,
                                    {"tokens": tokens, "lengths": lengths})
            self.pool = reshard(self.pool, pcache, tables)
            temps, ks, ps, seeds = slot_arrays([r.sampling for r in chunk]
                                               + [SamplingParams()]
                                               * (self._b_pre - len(chunk)))
            toks = np.asarray(sample_tokens(logits, temps, ks, ps, seeds,
                                            lengths))
            for j, req in enumerate(chunk):
                req.num_cached = len(req.seq_tokens)
                tok = int(toks[j])
                req.out_tokens.append(tok)
                req.last_token = tok
            self.stats.prefills += 1
        # a prefilled request may already be done (max_new_tokens == 1 after
        # a late preemption, or eos right away)
        for req in admitted:
            if req.finished:
                self.sched.retire(req)
        return len(admitted)

    # ---------------------------------------------------------------- step
    def step(self):
        """One engine iteration; returns [(rid, token)] emitted this step."""
        t0 = time.perf_counter()
        admitted = self.sched.admit()
        prefill_emitted = self._run_prefills(admitted) if admitted else 0
        preempted = self.sched.ensure_decode_capacity()
        self.stats.preemptions += len(preempted)
        running = self.sched.running
        emitted = []
        if running:
            n = self.cfg.n_slots
            ids = np.zeros((n, 1), np.int32)
            pos = np.zeros((n,), np.int32)
            slot_blocks = [[] for _ in range(n)]
            groups = [self.sched.group_of_slot(s) for s in range(n)]
            samplings = [SamplingParams()] * n
            for req in running:
                s = req.slot
                ids[s, 0] = req.last_token
                pos[s] = req.num_cached
                slot_blocks[s] = req.block_ids
                samplings[s] = req.sampling
            tables = self.cache.make_table(slot_blocks, groups)
            logits, self.pool = self.dec.fn(self.params, self.pool, tables,
                                            pos, ids)
            temps, ks, ps, seeds = slot_arrays(samplings)
            toks = np.asarray(sample_tokens(logits, temps, ks, ps, seeds,
                                            pos + 1))
            for req in running:
                req.num_cached += 1
                tok = int(toks[req.slot])
                req.out_tokens.append(tok)
                req.last_token = tok
                emitted.append((req.rid, tok))
                if req.finished:
                    self.sched.retire(req)
        dt = time.perf_counter() - t0
        self.stats.steps += 1
        self.stats.wall += dt
        new_tokens = len(emitted) + prefill_emitted
        self.stats.tokens += new_tokens
        if new_tokens:
            self.stats.token_times.extend([dt / new_tokens] * new_tokens)
        return emitted

    def run(self, max_steps: int = 100000):
        """Drive until every request finishes; returns {rid: out_tokens} for
        every request this engine has ever accepted."""
        for _ in range(max_steps):
            if not self.sched.has_work:
                break
            self.step()
        else:
            raise RuntimeError("engine did not drain (stuck scheduler?)")
        return {r.rid: list(r.generated) for r in self.requests}

    # -------------------------------------------------------------- elastic
    def replan_to(self, n_devices: int):
        """Rebuild the mesh for ``n_devices`` and reshard live KV blocks.

        Uses runtime.elastic.replan (TP group is atomic; data shrinks),
        copies every running request's resident blocks into its new group's
        partition, and recompiles the serve steps.  Waiting requests and all
        request state survive untouched."""
        import jax
        from ..core.mesh import logical_mesh
        from ..models.registry import build_model
        from ..runtime.elastic import replan

        from jax.sharding import NamedSharding, PartitionSpec as P
        from ..core.ops import make_ops

        rp = replan(n_devices, self.model.ctx,
                    global_batch=self.cfg.n_slots)
        old_sched = self.sched
        old_pool_np = {k: np.asarray(v) for k, v in self.pool.items()}
        params_np = jax.tree.map(np.asarray, self.params)

        self.model = build_model(self.model.cfg, rp.ctx, self.model.run)
        self.mesh = logical_mesh(rp.ctx, jax.devices()[:rp.n_used])
        self._build()    # stats/requests survive (guarded init in _build)

        # re-place params on the shrunken mesh
        specs = self.model.specs(make_ops(rp.ctx, self.plan))
        shardings = jax.tree.map(lambda sp: NamedSharding(self.mesh, sp),
                                 specs, is_leaf=lambda x: isinstance(x, P))
        self.params = jax.tree.map(jax.device_put, params_np, shardings)

        # carry scheduler state over; reallocate pages in the new groups.
        # The admit clock must carry too: carried residents keep their old
        # admit_seq, and a reset clock would make every post-replan
        # admission look "older" than them, inverting eviction priority.
        self.sched.waiting = old_sched.waiting
        self.sched._admit_clock = old_sched._admit_clock
        new_pool_np = {k: np.array(v) for k, v in self.pool.items()}
        for slot in range(min(len(old_sched.slots), self.cfg.n_slots)):
            req = old_sched.slots[slot]
            if req is None:
                continue
            g = self.sched.group_of_slot(slot)
            old_blocks = req.block_ids
            blocks = self.cache.pool.alloc(g, len(old_blocks))
            if blocks is None:
                # shrunken pool can't host it: evict + re-prefill later
                req.block_ids = []
                self.sched.preempt(req)
                continue
            for leaf in ("k", "v"):
                new_pool_np[leaf][:, blocks] = old_pool_np[leaf][:, old_blocks]
            req.block_ids = blocks
            req.slot = slot
            self.sched.slots[slot] = req
        self.pool = jax.tree.map(jax.device_put, new_pool_np,
                                 dict(self.cache.shardings()))
        return rp
