"""Continuous-batching inference engine over the Tesseract mesh.

One ``InferenceEngine.step`` is: admit waiting requests into free slots,
prefill them (bucketed fixed shapes, per-request true lengths), reshard the
prefill cache into the paged pool, run ONE fixed-shape paged decode step for
the whole slot batch (mixed lengths, block-table gather/scatter), sample
per-request, retire finished sequences in place.  See DESIGN.md §7.

The decode batch shape never changes across steps — batch composition does:
retired slots point at their group's scratch block until re-admission, so
the step function compiles exactly once per engine.

SLO guardrails + chaos hardening (DESIGN.md §11): bounded admission queue
(QueueFullError), per-request deadlines and TTFT budgets enforced against
an injectable engine clock, a NaN/Inf logit guard that quarantines only
the poisoned slot (re-prefill via the position-keyed PRNG replay keeps its
tokens bit-exact), graceful decode-batch shrink after repeated pool-OOM
preemption storms, and a healthy/degraded state in EngineStats.  A
``runtime/faults.FaultInjector`` (default: ``model.run.fault_plan``) drives
all of it deterministically at the ``serve.step`` / ``serve.logits`` sites.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field

import numpy as np

from ..configs.base import ShapeSpec
from ..runtime import faults as faults_mod
from ..runtime.steps import (build_chunk_prefill_step, build_page_copy,
                             build_paged_decode_step, build_paged_reshard,
                             build_prefill_step, make_plan)
from .kv_cache import PagedCacheConfig, PagedKVCache
from .prefix_cache import RadixPrefixCache
from .sampling import (SamplingParams, sample_tokens, slot_arrays,
                       spec_accept, spec_target_probs)
from .scheduler import FAILED, RUNNING, WAITING, Request, Scheduler


class QueueFullError(RuntimeError):
    """Bounded admission queue is full — the caller must back off or shed
    load upstream (admission control beats queueing collapse under the
    ROADMAP's 'heavy traffic' regime)."""


@dataclass(frozen=True)
class EngineConfig:
    n_slots: int = 4
    block_size: int = 8
    num_blocks: int = 64         # global, across all KV groups
    max_seq_len: int = 256
    prefill_batch: int = 0       # 0 -> ctx.data (smallest valid)
    eos_id: int = -1
    # --- SLO / resilience knobs (DESIGN.md §11) ---
    max_waiting: int = 0         # bound on the waiting queue (0 = unbounded)
    nan_retry_limit: int = 2     # quarantine->re-prefill rounds before FAILED
    oom_shrink_after: int = 2    # consecutive preemption-storm steps -> shrink
    oom_recover_after: int = 8   # consecutive calm steps -> grow back
    # --- shared-prompt serving (DESIGN.md §12) ---
    prefix_cache: bool = False   # radix prefix index over the block pool
    prefill_chunk: int = 0       # chunked prefill width (0 = monolithic;
    #                              prefix_cache implies the chunked path
    #                              with an auto-sized chunk)
    # --- speculative decoding (DESIGN.md §14) ---
    spec_k: int = 0              # proposals per round (0 = plain decode)
    spec_mode: str = "auto"      # auto | draft | ngram: auto takes draft
    #                              when a draft model is attached, else the
    #                              model-free n-gram prompt-lookup fallback
    spec_ngram_max: int = 3      # longest n-gram the fallback matches


def _pcts(vals, qs=(50, 95, 99)):
    if not vals:
        return {f"p{q}_ms": 0.0 for q in qs}
    t = np.array(vals) * 1e3
    return {f"p{q}_ms": float(np.percentile(t, q)) for q in qs}


@dataclass
class EngineStats:
    steps: int = 0
    prefills: int = 0
    preemptions: int = 0
    tokens: int = 0
    token_times: list = field(default_factory=list)  # seconds per emitted token
    wall: float = 0.0
    # --- SLO latency breakdown (engine clock) ---
    ttfts: list = field(default_factory=list)    # arrival -> first token
    itls: list = field(default_factory=list)     # inter-token latencies
    # --- resilience counters (DESIGN.md §11) ---
    health: str = "healthy"      # healthy | degraded
    shed: int = 0                # deadline / TTFT-budget sheds
    failed: int = 0              # requests terminally FAILED (incl. sheds)
    nan_quarantines: int = 0     # poisoned-slot quarantine -> re-prefill
    batch_shrinks: int = 0       # max_active reductions after OOM storms
    pool_exhaust_events: int = 0 # injected KV-pool exhaustion windows
    dropped_steps: int = 0       # injected lost engine iterations
    # --- shared-prompt serving (DESIGN.md §12) ---
    prefix_lookups: int = 0      # admissions that consulted the radix cache
    prefix_hits: int = 0         # admissions that reused cached pages
    prefix_tokens_reused: int = 0  # prompt positions served from shared pages
    prefix_tokens_total: int = 0   # prompt positions admitted while cache on
    cow_splits: int = 0          # copy-on-write donor-page copies
    cache_evictions: int = 0     # cold cache leaves dropped for capacity
    prefill_chunks: int = 0      # chunked-prefill steps doing NEW work
    #                              (replays after eviction don't count)
    # --- speculative decoding (DESIGN.md §14) ---
    spec_rounds: int = 0         # verify-step invocations
    spec_proposed: int = 0       # draft tokens judged by the target
    spec_accepted: int = 0       # draft tokens accepted verbatim
    spec_committed: int = 0      # tokens committed by verify rounds
    spec_slot_rounds: int = 0    # per-slot verify participations

    def tokens_per_s(self) -> float:
        return self.tokens / self.wall if self.wall else 0.0

    def acceptance_rate(self) -> float:
        """Fraction of judged proposals accepted verbatim."""
        return (self.spec_accepted / self.spec_proposed
                if self.spec_proposed else 0.0)

    def tokens_per_round(self) -> float:
        """Mean committed tokens per slot per verify round — the decode
        speedup factor: a plain decode step commits exactly 1 token per
        active slot, a verify round commits 1 + accepted (+ bonus)."""
        return (self.spec_committed / self.spec_slot_rounds
                if self.spec_slot_rounds else 0.0)

    def cache_hit_rate(self) -> float:
        """Fraction of admitted prompt tokens served from shared pages."""
        return (self.prefix_tokens_reused / self.prefix_tokens_total
                if self.prefix_tokens_total else 0.0)

    def latency_percentiles(self):
        return _pcts(self.token_times)

    def ttft_percentiles(self):
        return _pcts(self.ttfts)

    def itl_percentiles(self):
        return _pcts(self.itls)


class InferenceEngine:
    def __init__(self, model, mesh, params, cfg: EngineConfig,
                 injector=None, clock=None, draft_model=None,
                 draft_params=None):
        self.model, self.mesh, self.params, self.cfg = model, mesh, params, cfg
        # injectable wall clock: deadline/TTFT tests drive a fake clock
        self.clock = clock or time.perf_counter
        self.injector = injector if injector is not None else \
            faults_mod.injector_from_run(model.run, sites=("serve",))
        self._hostage = None     # injected pool-exhaustion hold
        self._oom_streak = 0     # consecutive steps with preemptions
        self._calm_streak = 0    # consecutive steps without
        self._evict_carry = 0    # cache evictions from pre-replan cache objs
        # speculative decoding: the draft rides the same mesh; its params
        # are kept as host arrays so elastic replans can re-place them on
        # the rebuilt mesh exactly like the target's
        self.draft_model = draft_model
        self._draft_params_host = None
        if draft_model is not None and cfg.spec_k > 0:
            import jax
            self._draft_params_host = jax.tree.map(np.asarray, draft_params)
        self._build()

    # ---------------------------------------------------------------- build
    def _build(self):
        model, mesh, cfg = self.model, self.mesh, self.cfg
        ctx = model.ctx
        if not hasattr(model, "decode_paged"):
            raise NotImplementedError(
                f"{type(model).__name__} has no paged decode path")
        # resolved attention data path (DESIGN.md §10) — surfaced so
        # operators can see which decode kernel a serve process runs;
        # elastic replans re-resolve (replace() preserves ctx.attn_impl)
        from ..kernels.ops import effective_attn_impl
        self.attn_impl = effective_attn_impl(ctx.attn_impl)
        self.plan = make_plan(ctx, ShapeSpec("serve", 1, cfg.n_slots,
                                             "decode"))
        if self.plan.kind == "decode" and cfg.n_slots % ctx.batch_shards:
            raise ValueError(
                f"n_slots={cfg.n_slots} must divide over "
                f"{ctx.batch_shards} token shards (or be < them to "
                f"downgrade the plan)")
        self.cache = PagedKVCache(
            model, mesh, self.plan,
            PagedCacheConfig(num_blocks=cfg.num_blocks,
                             block_size=cfg.block_size,
                             max_seq_len=cfg.max_seq_len))
        self.sched = Scheduler(self.cache, cfg.n_slots)
        self.pool = self.cache.init_arrays()
        self.dec = build_paged_decode_step(
            model, mesh, cfg.n_slots, cfg.num_blocks, cfg.block_size,
            self.cache.max_blocks)
        self._prefill_bundles = {}   # bucket_len -> (prefill, reshard)
        self._b_pre = cfg.prefill_batch or max(1, ctx.data)
        if self._b_pre % max(1, ctx.data):
            raise ValueError("prefill_batch must divide over data")
        # sequence-shard divisor for prefill buckets
        if ctx.mode == "megatron1d":
            self._seq_div = ctx.cols
        else:
            self._seq_div = ctx.depth * ctx.rows
        # shared-prompt serving (DESIGN.md §12): the prefix cache implies
        # the chunked paged prefill path — a hit resumes mid-prompt, which
        # the monolithic bucketed prefill cannot do without rewriting the
        # shared pages it is supposed to reuse.
        self._chunked = bool(cfg.prefix_cache or cfg.prefill_chunk > 0)
        self.prefix = None
        self._page_copy = None
        if cfg.prefix_cache:
            self.prefix = RadixPrefixCache(self.cache.pool, cfg.block_size)
            self.sched.prefix_cache = self.prefix
            self._page_copy = build_page_copy(
                model, mesh, cfg.num_blocks, cfg.block_size, self.plan)
        self._chunk_bundles = {}     # chunk width -> StepBundle
        # speculative decoding (DESIGN.md §14): one verify bundle of fixed
        # width spec_k + 1 plus either a DraftRunner (parallel draft pool
        # over the SAME block tables) or the n-gram fallback proposer
        self._spec_on = cfg.spec_k > 0
        self._draft = None
        self._ngram = None
        self._verify = None
        if self._spec_on:
            from ..runtime.steps import build_spec_verify_step
            from .spec import DraftRunner, NgramProposer
            mode = cfg.spec_mode
            if mode == "auto":
                mode = "draft" if self.draft_model is not None else "ngram"
            if mode not in ("draft", "ngram"):
                raise ValueError(f"spec_mode={cfg.spec_mode!r} not in "
                                 f"(auto, draft, ngram)")
            if mode == "draft" and self.draft_model is None:
                raise ValueError("spec_mode='draft' needs a draft model")
            self.spec_mode = mode
            self._verify = build_spec_verify_step(
                model, mesh, cfg.n_slots, cfg.spec_k + 1, cfg.num_blocks,
                cfg.block_size, self.cache.max_blocks)
            if mode == "draft":
                if self.draft_model.cfg.vocab_size != model.cfg.vocab_size:
                    raise ValueError(
                        f"draft vocab {self.draft_model.cfg.vocab_size} != "
                        f"target vocab {model.cfg.vocab_size}")
                self._draft = DraftRunner(
                    self.draft_model, mesh, self._draft_params_host,
                    cfg.n_slots, cfg.num_blocks, cfg.block_size,
                    self.cache.max_blocks)
            else:
                self._ngram = NgramProposer(max_n=cfg.spec_ngram_max)
        if not hasattr(self, "stats"):      # survives replan rebuilds
            self.stats = EngineStats()
            self.requests = []
        elif self._spec_on:
            # replan rebuild: the draft pool is fresh (zeroed) and block
            # ids moved — every draft watermark is stale
            for r in self.requests:
                r.draft_cached = 0

    def _bucket(self, n: int) -> int:
        """Prefill bucket covering ``n`` tokens: power-of-two multiples of
        lcm(block_size, seq shards) — divisible by both the reshard's block
        split and the sequence sharding — clamped to the pool's maximum
        resident length (Scheduler.add guarantees n fits that)."""
        import math
        base = math.lcm(self.cfg.block_size, self._seq_div)
        cap = -(-self.cache.max_blocks * self.cfg.block_size // base) * base
        b = base
        while b < n and b < cap:
            b = min(b * 2, cap)
        return b

    def _prefill_for(self, bucket: int):
        if bucket not in self._prefill_bundles:
            shape = ShapeSpec("ep", bucket, self._b_pre, "prefill")
            pre = build_prefill_step(self.model, self.mesh, shape,
                                     with_lengths=True)
            reshard = build_paged_reshard(
                self.model, self.mesh, self._b_pre, bucket,
                self.cfg.num_blocks, self.cfg.block_size, self.plan)
            self._prefill_bundles[bucket] = (pre, reshard)
        return self._prefill_bundles[bucket]

    # ------------------------------------------------------------- requests
    def add_request(self, prompt, sampling: SamplingParams | None = None,
                    rid=None, deadline_s: float | None = None,
                    ttft_budget_s: float | None = None) -> Request:
        """sampling defaults PER CALL (None -> fresh SamplingParams(); a
        shared default instance would alias state across requests).
        Raises QueueFullError when cfg.max_waiting bounds the queue."""
        if self.cfg.max_waiting and \
                len(self.sched.waiting) >= self.cfg.max_waiting:
            raise QueueFullError(
                f"admission queue full ({self.cfg.max_waiting} waiting)")
        req = Request(prompt, sampling, eos_id=self.cfg.eos_id, rid=rid,
                      deadline_s=deadline_s, ttft_budget_s=ttft_budget_s,
                      arrival_t=self.clock())
        self.requests.append(req)
        return self.sched.add(req)

    def _fail(self, req: Request, reason: str) -> None:
        """Terminally fail one request, releasing whatever it holds."""
        if req.state == RUNNING:
            self.cache.pool.free(req.block_ids)
            req.block_ids = []
            self.sched.slots[req.slot] = None
            req.slot = None
        elif req.state == WAITING and req in self.sched.waiting:
            self.sched.waiting.remove(req)
        req.state = FAILED
        req.fail_reason = reason
        self.stats.failed += 1

    def _shed_expired(self) -> None:
        """Deadline / TTFT-budget enforcement: shed ONLY the expired
        requests (waiting or running); survivors are untouched."""
        now = self.clock()
        for req in list(self.sched.waiting) + self.sched.running:
            age = now - req.arrival_t
            if req.deadline_s is not None and age > req.deadline_s:
                self._fail(req, f"deadline ({req.deadline_s:g}s) exceeded")
                self.stats.shed += 1
            elif (req.ttft_budget_s is not None and req.first_token_t is None
                  and age > req.ttft_budget_s):
                self._fail(req, f"ttft budget ({req.ttft_budget_s:g}s) "
                                f"exceeded")
                self.stats.shed += 1

    def _record_emit(self, req: Request, now: float | None = None) -> None:
        """TTFT / inter-token latency accounting on the engine clock.

        ``now`` is the emit stamp read ONCE per engine step, immediately
        after the sampled tokens of the completing chunk / decode batch
        materialize (the device sync point).  Stamping inside the
        per-request loop instead would leak admission bookkeeping, COW
        copies and radix inserts of EARLIER slots into LATER slots' TTFT
        (ISSUE 9 satellite): all tokens of one batch are produced by the
        same computation and must carry the same stamp."""
        if now is None:
            now = self.clock()
        if req.first_token_t is None:
            req.first_token_t = now
            self.stats.ttfts.append(now - req.arrival_t)
        elif req.last_emit_t is not None:
            self.stats.itls.append(now - req.last_emit_t)
        req.last_emit_t = now

    def _quarantine(self, req: Request) -> None:
        """NaN/Inf logits in this request's slot: evict ONLY that slot and
        re-prefill it later — the position-keyed PRNG replays its trajectory
        bit-exactly.  Bounded by cfg.nan_retry_limit, then FAILED."""
        req.nan_retries += 1
        self.stats.nan_quarantines += 1
        if req.nan_retries > self.cfg.nan_retry_limit:
            self._fail(req, f"non-finite logits persisted through "
                            f"{self.cfg.nan_retry_limit} re-prefills")
            return
        self.sched.slots[req.slot] = None
        self.sched.preempt(req)

    @staticmethod
    def _finite_rows(logits) -> np.ndarray:
        """(rows,) bool: row i of the logit batch is sane.  -inf is a LEGIT
        logit value (vocab-shard padding, top-k/top-p masks); only NaN and
        +inf mark a poisoned row."""
        lg = np.asarray(logits)
        bad = np.isnan(lg) | np.isposinf(lg)
        return ~bad.any(axis=tuple(range(1, lg.ndim)))

    # -------------------------------------------------------------- prefill
    def _run_prefills(self, admitted):
        """Bucketed, batched prefill of newly admitted requests + reshard of
        their caches into the paged pool.  Returns the number of tokens
        emitted (one per request — counted here because a same-step
        preemption folds out_tokens away before step()'s accounting)."""
        admitted = sorted(admitted, key=lambda r: len(r.seq_tokens))
        emitted = 0
        for i in range(0, len(admitted), self._b_pre):
            chunk = admitted[i:i + self._b_pre]
            bucket = self._bucket(max(len(r.seq_tokens) for r in chunk))
            pre, reshard = self._prefill_for(bucket)
            tokens = np.zeros((self._b_pre, bucket), np.int32)
            lengths = np.ones((self._b_pre,), np.int32)
            nb_bucket = bucket // self.cfg.block_size
            # scatter table: rows/blocks without a real target hit scratch
            tables = np.zeros((self._b_pre, nb_bucket), np.int32)
            tables[:, :] = self.cache.pool.scratch(0)
            for j, req in enumerate(chunk):
                seq = req.seq_tokens
                tokens[j, :len(seq)] = seq
                lengths[j] = len(seq)
                nb_req = self.cache.blocks_for(len(seq))
                tables[j, :nb_req] = req.block_ids[:nb_req]
            logits, pcache = pre.fn(self.params,
                                    {"tokens": tokens, "lengths": lengths})
            self.pool = reshard(self.pool, pcache, tables)
            temps, ks, ps, seeds = slot_arrays([r.sampling for r in chunk]
                                               + [SamplingParams()]
                                               * (self._b_pre - len(chunk)))
            toks = np.asarray(sample_tokens(logits, temps, ks, ps, seeds,
                                            lengths))
            ok = self._finite_rows(logits)
            now = self.clock()    # one stamp for the whole sampled batch
            for j, req in enumerate(chunk):
                if not ok[j]:
                    # poisoned prefill: quarantine just this request; its
                    # pages are freed and a later re-prefill replays it
                    self._quarantine(req)
                    continue
                req.num_cached = len(req.seq_tokens)
                req.prefill_high = max(req.prefill_high, req.num_cached)
                tok = int(toks[j])
                req.out_tokens.append(tok)
                req.last_token = tok
                self._record_emit(req, now)
                emitted += 1
            self.stats.prefills += 1
        # a prefilled request may already be done (max_new_tokens == 1 after
        # a late preemption, or eos right away)
        for req in admitted:
            if req.state == RUNNING and req.finished:
                self.sched.retire(req)
        return emitted

    # ---------------------------------------------------- chunked prefill
    def _chunk_width(self, remaining: int) -> int:
        """Chunked-prefill width: the configured chunk, or (auto, when the
        prefix cache turned chunking on) the smallest power-of-two multiple
        of block_size covering the longest pending suffix, capped at the
        pool's maximum resident length."""
        if self.cfg.prefill_chunk > 0:
            return self.cfg.prefill_chunk
        cap = self.cache.max_blocks * self.cfg.block_size
        c = self.cfg.block_size
        while c < remaining and c < cap:
            c = min(c * 2, cap)
        return c

    def _chunk_for(self, width: int):
        if width not in self._chunk_bundles:
            self._chunk_bundles[width] = build_chunk_prefill_step(
                self.model, self.mesh, self.cfg.n_slots, width,
                self.cfg.num_blocks, self.cfg.block_size,
                self.cache.max_blocks)
        return self._chunk_bundles[width]

    def _apply_prefix_hits(self, admitted) -> None:
        """Consume the PrefixHit the scheduler attached at admission: count
        reuse, copy the COW donor page into the request's first private
        block, and mark the shared prefix as already materialized so the
        chunked prefill starts at the divergence point."""
        for req in admitted:
            hit = req.prefix_hit
            self.stats.prefix_lookups += 1
            # Once-per-request token accounting (ISSUE 9 satellite): a
            # request evicted mid-chunk-prefill re-enters admission with
            # the same prompt positions — counting them again would
            # double-count the replayed work in prefix_tokens_total (and
            # let reuse of positions this request itself already paid for
            # inflate the hit rate past 1).  prefill_counted is the
            # per-request high-water mark of positions already counted;
            # only growth beyond it is new.
            seq_len = len(req.seq_tokens)
            self.stats.prefix_tokens_total += max(
                0, seq_len - req.prefill_counted)
            if hit is None or hit.tokens == 0:
                req.prefill_counted = max(req.prefill_counted, seq_len)
                continue
            if hit.cow_len:
                # the suffix prefill overwrites positions >= cow_len; the
                # causal mask hides the stale donor tail until then
                dst = req.block_ids[len(hit.full_blocks)]
                self.pool = self._page_copy(
                    self.pool, np.array([hit.cow_src], np.int32),
                    np.array([dst], np.int32))
                self.stats.cow_splits += 1
            req.num_cached = hit.tokens
            self.stats.prefix_hits += 1
            self.stats.prefix_tokens_reused += max(
                0, hit.tokens - req.prefill_counted)
            req.prefill_counted = max(req.prefill_counted, seq_len)

    def _run_chunk_prefills(self) -> int:
        """One fixed-shape chunked-prefill step for every mid-prefill slot
        (running requests with no last_token yet).  Interleaves with
        decode: each engine step advances every pending prompt by one
        chunk, then the decode batch runs for the slots that already hold
        a token.  Prompts that complete this chunk sample their first
        token (at position len(seq), like the monolithic prefill) and are
        indexed into the radix tree."""
        pending = [r for r in self.sched.running if r.last_token is None]
        if not pending:
            return 0
        n = self.cfg.n_slots
        width = self._chunk_width(
            max(len(r.seq_tokens) - r.num_cached for r in pending))
        ids = np.zeros((n, width), np.int32)
        pos = np.zeros((n,), np.int32)
        lens = np.zeros((n,), np.int32)
        slot_blocks = [[] for _ in range(n)]
        groups = [self.sched.group_of_slot(s) for s in range(n)]
        samplings = [SamplingParams()] * n
        take = {}
        for req in pending:
            s = req.slot
            seq = req.seq_tokens
            t = min(width, len(seq) - req.num_cached)
            ids[s, :t] = seq[req.num_cached:req.num_cached + t]
            pos[s] = req.num_cached
            lens[s] = t
            slot_blocks[s] = req.block_ids
            samplings[s] = req.sampling
            take[req.rid] = t
        tables = self.cache.make_table(slot_blocks, groups)
        bundle = self._chunk_for(width)
        logits, self.pool = bundle.fn(self.params, self.pool, tables,
                                      pos, lens, ids)
        # A chunk step counts as prefill work only when some slot advances
        # past its prefill_high watermark: a slot evicted mid-prefill and
        # re-admitted REPLAYS positions it already materialized once
        # (restarting from the prefix-cache hit point), and those replayed
        # chunks must not double-count (ISSUE 9 satellite).
        if any(req.num_cached + take[req.rid] > req.prefill_high
               for req in pending):
            self.stats.prefill_chunks += 1
        finishing = [r for r in pending
                     if r.num_cached + take[r.rid] == len(r.seq_tokens)]
        emitted = 0
        if finishing:
            ok = self._finite_rows(logits)
            temps, ks, ps, seeds = slot_arrays(samplings)
            toks = np.asarray(sample_tokens(logits, temps, ks, ps, seeds,
                                            pos + lens))
            # stamp ONCE at the completing chunk's sampled tokens — before
            # the per-slot retire/radix-insert bookkeeping below, so later
            # slots' TTFT doesn't absorb earlier slots' host work
            now = self.clock()
        for req in pending:
            if req not in finishing:
                req.num_cached += take[req.rid]
                req.prefill_high = max(req.prefill_high, req.num_cached)
                continue
            if not ok[req.slot]:
                # poisoned chunk: quarantine just this request (bounded
                # re-prefill replay); every other slot proceeds
                self._quarantine(req)
                continue
            req.num_cached = len(req.seq_tokens)
            req.prefill_high = max(req.prefill_high, req.num_cached)
            if self.prefix is not None:
                # only fully-covered prompt blocks are indexed (insert
                # stops at len // block_size), so decode's appends at
                # positions >= len(seq) never touch a shared page
                self.prefix.insert(groups[req.slot], req.seq_tokens,
                                   req.block_ids)
            tok = int(toks[req.slot])
            req.out_tokens.append(tok)
            req.last_token = tok
            self._record_emit(req, now)
            emitted += 1
        for req in finishing:
            if req.state == RUNNING and req.finished:
                self.sched.retire(req)
        return emitted

    # ------------------------------------------------------ fault plumbing
    def _exhaust_pool(self, idx: int, hold_steps: int) -> None:
        """Injected KV-pool exhaustion: take every free block hostage for
        ``hold_steps`` engine steps (drives the preemption-storm -> batch-
        shrink recovery path)."""
        held = []
        for g in range(self.cache.n_groups):
            n = self.cache.pool.available(g)
            if n:
                held.extend(self.cache.pool.alloc(g, n))
        self._hostage = {"blocks": held, "until": idx + max(1, hold_steps)}
        self.stats.pool_exhaust_events += 1

    def _release_hostages(self, idx: int) -> None:
        if self._hostage is not None and idx >= self._hostage["until"]:
            self.cache.pool.free(self._hostage["blocks"])
            self._hostage = None

    def _fire_step_faults(self, idx: int):
        """Run the serve.step injections due at engine step ``idx``;
        returns True when this iteration is dropped entirely."""
        dropped = False
        for spec in self.injector.fire("serve.step", idx):
            if spec.kind == "drop_step":
                dropped = True
            elif spec.kind == "straggler":
                time.sleep(spec.arg)
            elif spec.kind == "pool_exhaust":
                self._exhaust_pool(idx, int(spec.arg))
            elif spec.kind == "device_loss":
                print(f"[fault] serve step {idx}: device loss -> replan to "
                      f"{int(spec.arg)} devices")
                self.replan_to(int(spec.arg))
        for spec in self.injector.fire("serve.prefix", idx):
            if self.prefix is None:
                continue
            if spec.kind == "flush":
                n = self.prefix.flush()
                print(f"[fault] serve step {idx}: prefix-cache flush "
                      f"dropped {n} pages")
            elif spec.kind == "evict":
                # forced eviction pressure: only refcount-1 leaves may go,
                # so pages shared with running requests must survive this
                want = max(1, int(spec.arg))
                for g in range(self.cache.n_groups):
                    self.prefix.evict(g, want)
        return dropped

    def _poison_logits(self, logits, idx: int):
        """serve.logits injections: overwrite the target slot's logit row
        with NaN/Inf (what a flaky accelerator hands the sampler)."""
        specs = self.injector.fire("serve.logits", idx)
        if not specs:
            return logits
        lg = np.array(logits)
        for spec in specs:
            lg[int(spec.arg) % lg.shape[0]] = \
                np.nan if spec.kind == "nan" else np.inf
        return lg

    def _update_health(self) -> None:
        degraded = (self.sched.max_active < self.cfg.n_slots
                    or self._hostage is not None)
        self.stats.health = "degraded" if degraded else "healthy"
        if self.prefix is not None:
            self.stats.cache_evictions = (self._evict_carry
                                          + self.prefix.evictions)

    # --------------------------------------------------------- speculation
    def _judge(self, req, rows, toks, proposals):
        """Accept/reject one slot's verify rows -> (committed, n_accepted).

        rows: [W, v_pad] target logits (row c governs position
        num_cached + c + 1); toks: [W] the plain sampler's draw at each
        row's position (the identical jitted code path plain decode uses,
        so greedy acceptance is bit-exact by construction); proposals:
        the judged draft tokens.

        Greedy: accept while proposal c equals the argmax draw, commit the
        first mismatching draw as the correction, bonus-commit the final
        row's draw on full acceptance.  temperature > 0: Leviathan
        rejection sampling against the post-mask target distribution
        (point-mass proposals — the draft proposes greedily), residual
        resampling on rejection; the committed token at every position is
        marginally EXACTLY the plain sampler's distribution."""
        if req.sampling.temperature <= 0.0:
            committed, m = [], 0
            for c, d in enumerate(proposals):
                t = int(toks[c])
                committed.append(t)
                if t != int(d):
                    return committed, m
                m += 1
            committed.append(int(toks[len(proposals)]))
            return committed, m
        if not proposals:
            return [int(toks[0])], 0
        sp = req.sampling
        probs = np.asarray(spec_target_probs(
            np.asarray(rows[:len(proposals)]), sp.temperature, sp.top_k,
            sp.top_p))
        committed, m = spec_accept(probs, proposals, None, sp.seed,
                                   req.num_cached)
        if m == len(proposals):
            committed.append(int(toks[len(proposals)]))
        return committed, m

    def _spec_round(self, running, idx: int):
        """One speculative decode round: propose k tokens per slot, verify
        them all in ONE batched multi-token forward over the block tables,
        commit the accepted prefix (+1 correction/bonus token) in place.

        Rollback is implicit: a rejected suffix's K/V stays in the pool
        but num_cached never advances past the rejection point, so it is
        masked by position and overwritten by the next round's writes —
        the same replay argument the scheduler's eviction parity proves.
        Returns the [(rid, token)] list step() reports."""
        n = self.cfg.n_slots
        W = self.cfg.spec_k + 1
        groups = [self.sched.group_of_slot(s) for s in range(n)]
        slot_blocks = [[] for _ in range(n)]
        for r in running:
            slot_blocks[r.slot] = r.block_ids
        tables = self.cache.make_table(slot_blocks, groups)
        k_eff = {r.rid: max(0, r.spec_lookahead - 1) for r in running}
        if self._draft is not None:
            props = self._draft.propose(running, tables, k_eff)
        else:
            props = {r.rid: self._ngram.propose(r.seq_tokens,
                                                k_eff[r.rid])
                     for r in running}
        ids = np.zeros((n, W), np.int32)
        pos = np.zeros((n,), np.int32)
        lens = np.zeros((n,), np.int32)
        samplings = [SamplingParams()] * n
        for r in running:
            s = r.slot
            pr = [int(t) for t in props[r.rid][:k_eff[r.rid]]]
            props[r.rid] = pr
            ids[s, 0] = r.last_token
            if pr:
                ids[s, 1:1 + len(pr)] = pr
            pos[s] = r.num_cached
            lens[s] = 1 + len(pr)
            samplings[s] = r.sampling
        logits, self.pool = self._verify.fn(self.params, self.pool, tables,
                                            pos, lens, ids)
        if self.injector is not None:
            logits = self._poison_logits(logits, idx)
        ok = self._finite_rows(logits)
        lg = np.asarray(logits)                       # [n, W, v_pad]
        temps, ks, ps, seeds = slot_arrays(samplings)
        posmat = pos[:, None] + 1 + np.arange(W, dtype=np.int32)[None, :]
        toks = np.asarray(sample_tokens(
            lg.reshape(n * W, -1), np.repeat(temps, W), np.repeat(ks, W),
            np.repeat(ps, W), np.repeat(seeds, W),
            posmat.reshape(-1))).reshape(n, W)
        now = self.clock()    # one stamp for the whole verified batch
        emitted = []
        for r in running:
            s = r.slot
            if not ok[s]:
                self._quarantine(r)
                continue
            pr = props[r.rid]
            committed, m_acc = self._judge(r, lg[s], toks[s], pr)
            self.stats.spec_proposed += len(pr)
            self.stats.spec_accepted += m_acc
            self.stats.spec_slot_rounds += 1
            n0 = r.num_cached
            for t in committed:
                r.num_cached += 1
                t = int(t)
                r.out_tokens.append(t)
                r.last_token = t
                self._record_emit(r, now)
                emitted.append((r.rid, t))
                self.stats.spec_committed += 1
                if r.finished:
                    break     # eos / budget: drop the committed tail
            # draft watermark: positions <= n0 + m_acc hold draft K/V for
            # the tokens actually committed; the correction token's
            # position does not (the draft wrote the REJECTED proposal
            # there), and position n0 + k_eff was never draft-written
            r.draft_cached = min(n0 + m_acc + 1, r.num_cached,
                                 n0 + k_eff[r.rid])
            if r.finished and r.state == RUNNING:
                if self.prefix is not None:
                    # accepted tokens that completed full blocks become
                    # shareable prefix pages; insert stops at
                    # len // block_size, and rolled-back proposals never
                    # enter seq_tokens, so a rejected branch is never
                    # indexed.  [:-1]: the final committed token's K/V is
                    # the never-written pending position — it must not
                    # land inside an indexed block.
                    self.prefix.insert(self.sched.group_of_slot(r.slot),
                                       r.seq_tokens[:-1], r.block_ids)
                self.sched.retire(r)
        self.stats.spec_rounds += 1
        return emitted

    # ---------------------------------------------------------------- step
    def step(self):
        """One engine iteration; returns [(rid, token)] emitted this step."""
        t0 = time.perf_counter()
        idx = self.stats.steps
        self._release_hostages(idx)
        dropped = (self._fire_step_faults(idx)
                   if self.injector is not None else False)
        self._shed_expired()
        if dropped:
            # a lost engine iteration: no admission, no decode — survivors
            # just resume next step (position-keyed sampling keeps parity)
            self.stats.dropped_steps += 1
            self.stats.steps += 1
            self.stats.wall += time.perf_counter() - t0
            self._update_health()
            return []
        admitted = self.sched.admit()
        if self.sched.admission_failures:
            self.stats.failed += len(self.sched.admission_failures)
            self.sched.admission_failures.clear()
        if self._chunked:
            if self.prefix is not None and admitted:
                self._apply_prefix_hits(admitted)
            prefill_emitted = self._run_chunk_prefills()
        else:
            prefill_emitted = self._run_prefills(admitted) if admitted else 0
        if self._spec_on:
            # declare this round's write window BEFORE capacity runs: the
            # k in-flight draft tokens per slot need resident pages
            for r in self.sched.running:
                if r.last_token is not None:
                    remaining = (r.sampling.max_new_tokens
                                 - len(r.generated))
                    r.spec_lookahead = 1 + max(
                        0, min(self.cfg.spec_k, remaining - 1))
        preempted = self.sched.ensure_decode_capacity()
        self.stats.preemptions += len(preempted)
        # mid-chunk-prefill requests (last_token still None) sit out the
        # decode batch; their slots degrade to scratch like retired ones
        running = [r for r in self.sched.running
                   if r.last_token is not None]
        emitted = []
        if running and self._spec_on:
            emitted = self._spec_round(running, idx)
        elif running:
            n = self.cfg.n_slots
            ids = np.zeros((n, 1), np.int32)
            pos = np.zeros((n,), np.int32)
            slot_blocks = [[] for _ in range(n)]
            groups = [self.sched.group_of_slot(s) for s in range(n)]
            samplings = [SamplingParams()] * n
            for req in running:
                s = req.slot
                ids[s, 0] = req.last_token
                pos[s] = req.num_cached
                slot_blocks[s] = req.block_ids
                samplings[s] = req.sampling
            tables = self.cache.make_table(slot_blocks, groups)
            logits, self.pool = self.dec.fn(self.params, self.pool, tables,
                                            pos, ids)
            if self.injector is not None:
                logits = self._poison_logits(logits, idx)
            ok = self._finite_rows(logits)
            temps, ks, ps, seeds = slot_arrays(samplings)
            toks = np.asarray(sample_tokens(logits, temps, ks, ps, seeds,
                                            pos + 1))
            now = self.clock()    # one stamp for the whole decode batch
            for req in running:
                if not ok[req.slot]:
                    # poisoned slot: quarantine ONLY this request (bounded
                    # re-prefill replay); every other slot proceeds
                    self._quarantine(req)
                    continue
                req.num_cached += 1
                tok = int(toks[req.slot])
                req.out_tokens.append(tok)
                req.last_token = tok
                self._record_emit(req, now)
                emitted.append((req.rid, tok))
                if req.finished:
                    self.sched.retire(req)
        # pool-OOM pressure control: repeated preemption storms shrink the
        # admission cap (graceful decode-batch shrink); calm steps grow it
        # back toward n_slots
        if preempted:
            self._oom_streak += 1
            self._calm_streak = 0
        else:
            self._calm_streak += 1
            self._oom_streak = 0
        if (self._oom_streak >= self.cfg.oom_shrink_after
                and self.sched.max_active > 1):
            self.sched.max_active -= 1
            self.stats.batch_shrinks += 1
            self._oom_streak = 0
        if (self._calm_streak >= self.cfg.oom_recover_after
                and self.sched.max_active < self.cfg.n_slots):
            self.sched.max_active += 1
            self._calm_streak = 0
        dt = time.perf_counter() - t0
        self.stats.steps += 1
        self.stats.wall += dt
        new_tokens = len(emitted) + prefill_emitted
        self.stats.tokens += new_tokens
        if new_tokens:
            self.stats.token_times.extend([dt / new_tokens] * new_tokens)
        self._update_health()
        return emitted

    def run(self, max_steps: int = 100000):
        """Drive until every request finishes; returns {rid: out_tokens} for
        every request this engine has ever accepted."""
        for _ in range(max_steps):
            if not self.sched.has_work:
                break
            self.step()
        else:
            raise RuntimeError("engine did not drain (stuck scheduler?)")
        return {r.rid: list(r.generated) for r in self.requests}

    # -------------------------------------------------------------- elastic
    def replan_to(self, n_devices: int):
        """Rebuild the mesh for ``n_devices`` and reshard live KV blocks.

        Uses runtime.elastic.replan (TP group is atomic; data shrinks),
        copies every running request's resident blocks into its new group's
        partition, and recompiles the serve steps.  Waiting requests and all
        request state survive untouched."""
        import jax
        from ..core.mesh import logical_mesh
        from ..models.registry import build_model
        from ..runtime.elastic import replan

        from jax.sharding import NamedSharding, PartitionSpec as P
        from ..core.ops import make_ops

        rp = replan(n_devices, self.model.ctx,
                    global_batch=self.cfg.n_slots)
        # injected pool-exhaustion hostages hold OLD pool block ids — drop
        # them rather than freeing stale ids into the rebuilt pool
        self._hostage = None
        # cached page ids die with the old pool: the rebuilt cache starts
        # empty; its eviction count carries into the stats
        if self.prefix is not None:
            self._evict_carry += self.prefix.evictions
        old_sched = self.sched
        old_pool_np = {k: np.asarray(v) for k, v in self.pool.items()}
        params_np = jax.tree.map(np.asarray, self.params)

        self.model = build_model(self.model.cfg, rp.ctx, self.model.run)
        if self.draft_model is not None:
            # the draft rides the same mesh: rebuild it for the new ctx;
            # _build re-places its host params and zeroes its pool (draft
            # KV is disposable — watermarks reset, parity unaffected)
            self.draft_model = build_model(self.draft_model.cfg, rp.ctx,
                                           self.draft_model.run)
        self.mesh = logical_mesh(rp.ctx, jax.devices()[:rp.n_used])
        self._build()    # stats/requests survive (guarded init in _build)

        # re-place params on the shrunken mesh
        specs = self.model.specs(make_ops(rp.ctx, self.plan))
        shardings = jax.tree.map(lambda sp: NamedSharding(self.mesh, sp),
                                 specs, is_leaf=lambda x: isinstance(x, P))
        self.params = jax.tree.map(jax.device_put, params_np, shardings)

        # carry scheduler state over; reallocate pages in the new groups.
        # The admit clock must carry too: carried residents keep their old
        # admit_seq, and a reset clock would make every post-replan
        # admission look "older" than them, inverting eviction priority.
        self.sched.waiting = old_sched.waiting
        self.sched._admit_clock = old_sched._admit_clock
        self.sched.max_active = old_sched.max_active
        self.sched.admission_failures = old_sched.admission_failures
        new_pool_np = {k: np.array(v) for k, v in self.pool.items()}
        for slot in range(min(len(old_sched.slots), self.cfg.n_slots)):
            req = old_sched.slots[slot]
            if req is None:
                continue
            g = self.sched.group_of_slot(slot)
            old_blocks = req.block_ids
            blocks = self.cache.pool.alloc(g, len(old_blocks))
            if blocks is None:
                # shrunken pool can't host it: evict + re-prefill later
                req.block_ids = []
                self.sched.preempt(req)
                continue
            for leaf in ("k", "v"):
                new_pool_np[leaf][:, blocks] = old_pool_np[leaf][:, old_blocks]
            req.block_ids = blocks
            req.slot = slot
            self.sched.slots[slot] = req
        self.pool = jax.tree.map(jax.device_put, new_pool_np,
                                 dict(self.cache.shardings()))
        return rp
