"""llama3-405b: 126L d_model=16384 128H (GQA kv=8) d_ff=53248 vocab=128256 —
GQA 128k vocab [arXiv:2407.21783; unverified]."""
from .base import ArchConfig, ModelConfig

CONFIG = ArchConfig(
    model=ModelConfig(
        name="llama3-405b", family="dense",
        num_layers=126, d_model=16384, num_heads=128, num_kv_heads=8,
        d_ff=53248, vocab_size=128256, mlp_act="silu", mlp_glu=True,
        rope_theta=5e5),
)


def reduced() -> ArchConfig:
    return ArchConfig(model=ModelConfig(
        name="llama3-405b-reduced", family="dense",
        num_layers=2, d_model=64, num_heads=8, num_kv_heads=2,
        d_ff=160, vocab_size=251, mlp_act="silu", mlp_glu=True))
