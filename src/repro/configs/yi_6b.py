"""yi-6b: 32L d_model=4096 32H (GQA kv=4) d_ff=11008 vocab=64000 —
llama-arch GQA [arXiv:2403.04652; hf]."""
from .base import ArchConfig, ModelConfig

CONFIG = ArchConfig(
    model=ModelConfig(
        name="yi-6b", family="dense",
        num_layers=32, d_model=4096, num_heads=32, num_kv_heads=4,
        d_ff=11008, vocab_size=64000, mlp_act="silu", mlp_glu=True,
        rope_theta=5e6),
    notes="llama-style dense GQA; kv=4 heads are replicated across col when "
          "q does not divide 4 (q=2 shards them 2-way).",
)


def reduced() -> ArchConfig:
    return ArchConfig(model=ModelConfig(
        name="yi-6b-reduced", family="dense",
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
        d_ff=128, vocab_size=503, mlp_act="silu", mlp_glu=True))
