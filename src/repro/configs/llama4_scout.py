"""llama4-scout-17b-a16e: 48L d_model=5120 40H (GQA kv=8) d_ff=8192
vocab=202048, MoE 16e top-1 — MoE, early fusion
[hf:meta-llama/Llama-4-Scout-17B-16E; unverified]."""
from .base import ArchConfig, ModelConfig

CONFIG = ArchConfig(
    model=ModelConfig(
        name="llama4-scout-17b-a16e", family="moe",
        num_layers=48, d_model=5120, num_heads=40, num_kv_heads=8,
        d_ff=8192, vocab_size=202048, mlp_act="silu", mlp_glu=True,
        moe_num_experts=16, moe_top_k=1, moe_d_ff=8192,
        moe_shared_experts=1, rope_theta=5e5),
    notes="16 routed experts top-1 + 1 shared expert per layer (hf config); "
          "experts sharded over the tesseract depth axis (EP=d).",
)


def reduced() -> ArchConfig:
    return ArchConfig(model=ModelConfig(
        name="llama4-scout-reduced", family="moe",
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
        d_ff=96, vocab_size=251, mlp_act="silu", mlp_glu=True,
        moe_num_experts=4, moe_top_k=1, moe_d_ff=96, moe_shared_experts=1))
