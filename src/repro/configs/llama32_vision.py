"""llama-3.2-vision-11b: 40L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=128256 — cross-attn image layers
[hf:meta-llama/Llama-3.2-11B-Vision; unverified].

Backbone only; the vision frontend is a STUB (input_specs provides
precomputed patch embeddings [B, 1601, 1280]).  Cross-attention layers are
placed one per 5-layer superblock (the hf checkpoint uses layers
3,8,...,38 — same count/pattern)."""
from .base import ArchConfig, ModelConfig

CONFIG = ArchConfig(
    model=ModelConfig(
        name="llama-3.2-vision-11b", family="vlm",
        num_layers=40, d_model=4096, num_heads=32, num_kv_heads=8,
        d_ff=14336, vocab_size=128256, mlp_act="silu", mlp_glu=True,
        cross_attn_every=5, vision_dim=1280, vision_tokens=1601,
        rope_theta=5e5),
)


def reduced() -> ArchConfig:
    return ArchConfig(model=ModelConfig(
        name="llama32-vision-reduced", family="vlm",
        num_layers=4, d_model=64, num_heads=4, num_kv_heads=2,
        d_ff=128, vocab_size=251, mlp_act="silu", mlp_glu=True,
        cross_attn_every=2, vision_dim=32, vision_tokens=9))
