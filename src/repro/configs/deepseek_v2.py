"""deepseek-v2-236b: 60L d_model=5120 128H (MLA kv_lora=512) per-expert
d_ff=1536 vocab=102400, MoE 160e top-6, 2 shared + first layer dense
[arXiv:2405.04434; hf]."""
from .base import ArchConfig, ModelConfig

CONFIG = ArchConfig(
    model=ModelConfig(
        name="deepseek-v2-236b", family="moe",
        num_layers=60, d_model=5120, num_heads=128, num_kv_heads=128,
        d_ff=12288,  # first dense layer intermediate (hf config)
        vocab_size=102400, mlp_act="silu", mlp_glu=True,
        moe_num_experts=160, moe_top_k=6, moe_d_ff=1536,
        moe_shared_experts=2, first_dense=1,
        mla_kv_lora=512, mla_q_lora=1536,
        qk_nope_dim=128, qk_rope_dim=64, v_head_dim=128,
        head_dim=192, rope_theta=1e4),
    notes="MLA: compressed kv cache (512+64 per token); absorbed decode. "
          "160 routed experts top-6 (EP over depth=4 -> 40/slice) + 2 shared "
          "experts tesseract-sharded; first layer dense d_ff=12288.",
)


def reduced() -> ArchConfig:
    return ArchConfig(model=ModelConfig(
        name="deepseek-v2-reduced", family="moe",
        num_layers=3, d_model=64, num_heads=4, num_kv_heads=4,
        d_ff=128, vocab_size=251, mlp_act="silu", mlp_glu=True,
        moe_num_experts=4, moe_top_k=2, moe_d_ff=48,
        moe_shared_experts=2, first_dense=1,
        mla_kv_lora=16, mla_q_lora=32,
        qk_nope_dim=16, qk_rope_dim=8, v_head_dim=16, head_dim=24))
