"""nemotron-4-340b: 96L d_model=18432 96H (GQA kv=8) d_ff=73728 vocab=256000
— GQA, squared-ReLU [arXiv:2402.16819; unverified]."""
from .base import ArchConfig, ModelConfig

CONFIG = ArchConfig(
    model=ModelConfig(
        name="nemotron-4-340b", family="dense",
        num_layers=96, d_model=18432, num_heads=96, num_kv_heads=8,
        d_ff=73728, vocab_size=256000, mlp_act="relu2", mlp_glu=False,
        norm="layernorm", rope_theta=1e4),
    notes="squared-ReLU non-GLU MLP, layernorm (nemotron-4 uses layernorm1p; "
          "our (1+scale) rms/layernorm parameterization matches that).",
)


def reduced() -> ArchConfig:
    return ArchConfig(model=ModelConfig(
        name="nemotron-4-reduced", family="dense",
        num_layers=2, d_model=96, num_heads=4, num_kv_heads=2,
        d_ff=192, vocab_size=251, mlp_act="relu2", mlp_glu=False,
        norm="layernorm"))
