"""recurrentgemma-9b: 38L d_model=4096 16H (GQA kv=1) d_ff=12288 vocab=256000
— RG-LRU + local attn, 1:2 [arXiv:2402.19427; unverified]."""
from .base import ArchConfig, ModelConfig

CONFIG = ArchConfig(
    model=ModelConfig(
        name="recurrentgemma-9b", family="hybrid",
        num_layers=38, d_model=4096, num_heads=16, num_kv_heads=1,
        d_ff=12288, vocab_size=256000, mlp_act="gelu", mlp_glu=True,
        lru_width=4096, local_window=2048, head_dim=256,
        block_pattern=("rec", "rec", "attn"), rope_theta=1e4),
    notes="12 superblocks of (rec,rec,attn) + 2 trailing rec blocks = 38L; "
          "MQA (kv=1) local attention window 2048; RG-LRU gates diagonal "
          "(simplified from block-diagonal, see models/recurrent.py).",
)


def reduced() -> ArchConfig:
    return ArchConfig(model=ModelConfig(
        name="recurrentgemma-reduced", family="hybrid",
        num_layers=5, d_model=64, num_heads=4, num_kv_heads=1,
        d_ff=128, vocab_size=251, mlp_act="gelu", mlp_glu=True,
        lru_width=64, local_window=8, head_dim=16,
        block_pattern=("rec", "rec", "attn")))
