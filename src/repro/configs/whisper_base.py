"""whisper-base: 6L d_model=512 8H d_ff=2048 vocab=51865 — enc-dec, conv
frontend (stub) [arXiv:2212.04356; unverified]."""
from .base import ArchConfig, ModelConfig

CONFIG = ArchConfig(
    model=ModelConfig(
        name="whisper-base", family="audio",
        num_layers=6, d_model=512, num_heads=8, num_kv_heads=8,
        d_ff=2048, vocab_size=51865, mlp_act="gelu", mlp_glu=False,
        norm="layernorm", use_bias=True, use_rope=False,
        enc_layers=6, enc_seq=1500),
    notes="conv frontend stubbed (input_specs provides frame embeddings); "
          "sinusoidal positions on both stacks (learned 448-entry decoder "
          "table replaced so the synthetic 32k cells are well-defined).",
)


def reduced() -> ArchConfig:
    return ArchConfig(model=ModelConfig(
        name="whisper-reduced", family="audio",
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=4,
        d_ff=128, vocab_size=251, mlp_act="gelu", mlp_glu=False,
        norm="layernorm", use_bias=True, use_rope=False,
        enc_layers=2, enc_seq=12))
