"""Config dataclasses: model architecture, run options, shape grid.

One file per assigned architecture lives next to this module; each exports
``CONFIG: ArchConfig`` (full published config) and ``reduced() -> ArchConfig``
(a tiny same-family config for CPU smoke tests).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                  # dense | moe | vlm | hybrid | ssm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0            # 0 -> d_model // num_heads
    mlp_act: str = "silu"        # silu (glu) | relu2 | gelu
    mlp_glu: bool = True
    use_bias: bool = False
    norm: str = "rmsnorm"        # rmsnorm | layernorm
    rope_theta: float = 5e5
    use_rope: bool = True
    norm_eps: float = 1e-5
    # --- MoE ---
    moe_num_experts: int = 0
    moe_top_k: int = 0
    moe_shared_experts: int = 0  # deepseek-style always-on experts
    moe_d_ff: int = 0            # per-expert ffn width (routed)
    moe_every: int = 1           # apply MoE every k-th layer (1 = all)
    first_dense: int = 0         # leading dense layers (deepseek: 1)
    # --- MLA (deepseek) ---
    mla_kv_lora: int = 0
    mla_q_lora: int = 0
    qk_rope_dim: int = 0
    qk_nope_dim: int = 0
    v_head_dim: int = 0
    # --- VLM (llama-3.2-vision) ---
    cross_attn_every: int = 0    # one cross-attn block per k self-attn blocks
    vision_dim: int = 0
    vision_tokens: int = 0
    # --- hybrid (recurrentgemma) ---
    lru_width: int = 0
    local_window: int = 0
    block_pattern: tuple = ()    # e.g. ("rec", "rec", "attn")
    # --- ssm (mamba2) ---
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_chunk: int = 256
    ssm_conv: int = 4
    # --- audio (whisper) ---
    enc_layers: int = 0
    enc_seq: int = 0             # stub frontend frames (whisper-base: 1500)

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or (self.d_model // max(self.num_heads, 1))

    def param_count(self) -> int:
        """Total parameters N (for MODEL_FLOPS = 6*N*D accounting)."""
        h, v, L = self.d_model, self.vocab_size, self.num_layers
        d = self.resolved_head_dim
        n = 2 * v * h  # embed + head
        att = h * self.num_heads * d + 2 * h * self.num_kv_heads * d \
            + self.num_heads * d * h
        if self.mla_kv_lora:
            qd = self.qk_nope_dim + self.qk_rope_dim
            att = (h * self.mla_q_lora + self.mla_q_lora * self.num_heads * qd
                   + h * (self.mla_kv_lora + self.qk_rope_dim)
                   + self.mla_kv_lora * self.num_heads * (self.qk_nope_dim + self.v_head_dim)
                   + self.num_heads * self.v_head_dim * h)
        mlp_mult = 3 if self.mlp_glu else 2
        if self.family == "ssm":
            di = self.ssm_expand * h
            heads = di // self.ssm_head_dim
            per = (h * (2 * di + 2 * self.ssm_state * 1 + heads) + di * h)
            n += L * per + L * 2 * h
            return n
        mlp = mlp_mult * h * self.d_ff
        if self.moe_num_experts:
            moe = self.moe_num_experts * mlp_mult * h * self.moe_d_ff \
                + self.moe_shared_experts * mlp_mult * h * self.moe_d_ff \
                + h * self.moe_num_experts
            n_moe_layers = max(0, (L - self.first_dense)) // max(self.moe_every, 1)
            n += n_moe_layers * (att + moe + 2 * h) \
                + (L - n_moe_layers) * (att + mlp + 2 * h)
        else:
            n += L * (att + mlp + 2 * h)
        if self.cross_attn_every:
            n_cross = L // self.cross_attn_every
            cross = (self.vision_dim * 2 * self.num_kv_heads * d
                     + h * self.num_heads * d + self.num_heads * d * h + 2 * h)
            n += n_cross * cross
        if self.enc_layers:
            n += self.enc_layers * (att + mlp + 2 * h)
        return n

    def active_param_count(self) -> int:
        """Active params per token (MoE: routed top-k + shared only)."""
        if not self.moe_num_experts:
            return self.param_count()
        full = self.param_count()
        mlp_mult = 3 if self.mlp_glu else 2
        h = self.d_model
        n_moe_layers = max(0, (self.num_layers - self.first_dense)) // max(self.moe_every, 1)
        all_experts = n_moe_layers * self.moe_num_experts * mlp_mult * h * self.moe_d_ff
        active_experts = n_moe_layers * self.moe_top_k * mlp_mult * h * self.moe_d_ff
        return full - all_experts + active_experts


# RunConfig fields that are intentionally no longer consumed anywhere in
# src/repro (kept for config-file compatibility).  Every OTHER field must be
# read somewhere — enforced by tests/test_config.py.
DEPRECATED_RUN_FIELDS: frozenset = frozenset()

_DTYPES = ("float32", "bfloat16", "float16")


@dataclass(frozen=True)
class RunConfig:
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    remat: str = "full"          # none | full | dots
    loss_chunk: int = 512        # per-device tokens per CE chunk
    q_chunk: int = 512
    kv_chunk: int = 1024
    use_pallas: bool = False
    capacity_factor: float = 1.25
    scan_blocks: bool = True
    lr: float = 3e-4
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    optimizer: str = "adamw"     # adamw | lamb
    zero1: bool = False          # shard optimizer state over data axis
    # ZeRO stage (DESIGN.md §9): 0 = replicated optimizer state, 1 = state
    # sharded over the leaf's replicated DP axes (equivalent to zero1=True;
    # either knob enables it).  Stages 2/3 (grad / param sharding) are not
    # implemented.
    zero_stage: int = 0
    # Static loss scaling for low-precision compute: the loss is multiplied
    # by loss_scale before the backward and gradients are unscaled before
    # clipping/optimizer — a numerics lever for float16 (bf16's exponent
    # range usually needs none; keep 1.0 there).
    loss_scale: float = 1.0
    grad_compression: str = "none"  # none | bf16
    # MoE expert-weight layout: "2d" = paper-style SUMMA sharding per expert
    # over (row,col); "local" = expert weights local to their depth slice,
    # tokens split over col (beyond-paper; trades weight gathers for much
    # smaller token gathers — see EXPERIMENTS.md §Perf)
    moe_expert_layout: str = "2d"
    # SUMMA execution schedule of the Tesseract matmuls ("fused" | "ring" |
    # "auto"); the config-surface default that launchers apply to
    # ParallelContext (the per-op dispatch lives on ctx.matmul_schedule,
    # DESIGN.md §2b; "auto" resolves per-op from the token-block size).
    matmul_schedule: str = "fused"
    # Attention data path ("jnp" | "pallas" | "auto"); like matmul_schedule
    # this is the config surface that launchers copy onto
    # ParallelContext.attn_impl, where the per-op dispatch lives
    # (DESIGN.md §10).  "auto" resolves per backend: fused kernels on TPU,
    # jnp elsewhere; "pallas" forces the kernels (interpret mode off-TPU).
    attn_impl: str = "jnp"
    # --- long-context sequence sharding (DESIGN.md §15) ---
    # Number of sequence-axis shards: launchers copy this onto
    # ParallelContext.seq, adding the "seq" mesh axis when > 1 so train
    # activations are time-sharded and attention rings K/V around the seq
    # axis.  Incompatible with pipe_stages > 1 (core/mesh.py rejects it).
    seq_shards: int = 1
    # Attention SCHEDULE across seq shards ("local" | "ring" | "striped" |
    # "auto"); the config surface for ParallelContext.attn_schedule.
    # "auto" resolves to striped causal rings for training; with
    # seq_shards == 1 "ring"/"auto" also switch seq-sharded prefill from
    # gather-full-KV to a (depth, row) ring.
    attn_schedule: str = "local"
    # --- pipeline / accumulation knobs (DESIGN.md §8) ---
    # Pipeline-parallel stage count: launchers build the 5-axis
    # [pipe x data x depth x row x col] mesh when > 1 and
    # runtime/steps.build_train_step switches to the 1F1B schedule.
    pipe_stages: int = 1
    # Microbatches per 1F1B flush (0 -> 2 * pipe_stages).  The bubble
    # fraction is (S-1)/(M+S-1); more microbatches amortize it.
    pipeline_microbatches: int = 0
    # Default gradient-accumulation factor for the train loop; elastic
    # re-plans (runtime/elastic.Replan.accum_steps) override it so a device
    # shrink preserves the global batch per optimizer step.
    accum_steps: int = 1
    # --- deterministic fault injection (DESIGN.md §11) ---
    # Compact FaultPlan DSL ("" = no injection), e.g.
    # "train.grads@5:nan;ckpt.write@9:corrupt(0,bit_flip)" — parsed by
    # runtime/faults.FaultPlan.parse and executed at the registered hook
    # points in the train loop and serve engine.
    fault_plan: str = ""
    # Seed for FaultPlan.random schedules and corruption byte positions;
    # the whole fault sequence is a pure function of (fault_seed, site,
    # kind, step), so a rerun replays identically.
    fault_seed: int = 0
    # Consecutive non-finite (NaN/Inf) update skips tolerated per step
    # before the train loop backs off loss_scale / raises (§11 ladder).
    nan_skip_limit: int = 2

    def __post_init__(self):
        if self.param_dtype not in _DTYPES:
            raise ValueError(f"param_dtype must be one of {_DTYPES}, "
                             f"got {self.param_dtype!r}")
        if self.compute_dtype not in _DTYPES:
            raise ValueError(f"compute_dtype must be one of {_DTYPES}, "
                             f"got {self.compute_dtype!r}")
        if self.zero_stage not in (0, 1):
            raise ValueError(f"zero_stage must be 0 or 1 (stage 2/3 grad/"
                             f"param sharding not implemented), got "
                             f"{self.zero_stage}")
        if not self.loss_scale > 0:
            raise ValueError(f"loss_scale must be > 0, got {self.loss_scale}")
        if self.optimizer not in ("adamw", "lamb"):
            raise ValueError(f"optimizer must be 'adamw' or 'lamb', "
                             f"got {self.optimizer!r}")
        if self.attn_impl not in ("jnp", "pallas", "auto"):
            raise ValueError(f"attn_impl must be 'jnp', 'pallas' or 'auto', "
                             f"got {self.attn_impl!r}")
        if self.attn_schedule not in ("local", "ring", "striped", "auto"):
            raise ValueError(f"attn_schedule must be 'local', 'ring', "
                             f"'striped' or 'auto', got "
                             f"{self.attn_schedule!r}")
        if self.seq_shards < 1:
            raise ValueError(f"seq_shards must be >= 1, "
                             f"got {self.seq_shards}")
        if self.nan_skip_limit < 0:
            raise ValueError(f"nan_skip_limit must be >= 0, "
                             f"got {self.nan_skip_limit}")
        if self.fault_plan:
            from ..runtime.faults import FaultPlan
            FaultPlan.parse(self.fault_plan)   # validate sites/kinds early

    @property
    def zero_enabled(self) -> bool:
        """ZeRO-1 optimizer-state sharding on (either knob)."""
        return self.zero1 or self.zero_stage >= 1

    @property
    def master_weights(self) -> bool:
        """fp32 master copies are kept whenever params are low-precision."""
        return self.param_dtype != "float32"


@dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str                    # train | prefill | decode


SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}

# archs that may run long_500k (sub-quadratic temporal mixing)
LONG_CONTEXT_OK = ("mamba2-1.3b", "recurrentgemma-9b")


@dataclass(frozen=True)
class ArchConfig:
    model: ModelConfig
    shapes: tuple = ("train_4k", "prefill_32k", "decode_32k", "long_500k")
    notes: str = ""

    def shape_list(self):
        out = []
        for s in self.shapes:
            if s == "long_500k" and self.model.name not in LONG_CONTEXT_OK:
                continue
            out.append(SHAPES[s])
        return out

    def skipped_shapes(self):
        return [s for s in self.shapes
                if s == "long_500k" and self.model.name not in LONG_CONTEXT_OK]


def round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m
