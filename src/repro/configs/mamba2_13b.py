"""mamba2-1.3b: 48L d_model=2048 (attn-free) vocab=50280, ssm_state=128 —
SSD (state-space duality) [arXiv:2405.21060; unverified]."""
from .base import ArchConfig, ModelConfig

CONFIG = ArchConfig(
    model=ModelConfig(
        name="mamba2-1.3b", family="ssm",
        num_layers=48, d_model=2048, num_heads=0, num_kv_heads=0,
        d_ff=0, vocab_size=50280, ssm_state=128, ssm_head_dim=64,
        ssm_expand=2, ssm_chunk=256, ssm_conv=4),
    notes="attention-free; long_500k runs (constant state). Projections are "
          "tesseract-sharded; SSD temporal mixing is a chunked scan "
          "(see DESIGN.md §6).",
)


def reduced() -> ArchConfig:
    return ArchConfig(model=ModelConfig(
        name="mamba2-reduced", family="ssm",
        num_layers=2, d_model=64, num_heads=0, num_kv_heads=0,
        d_ff=0, vocab_size=251, ssm_state=16, ssm_head_dim=16,
        ssm_expand=2, ssm_chunk=8, ssm_conv=4))
