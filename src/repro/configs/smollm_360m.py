"""smollm-360m: 32L d_model=960 15H (GQA kv=5) d_ff=2560 vocab=49152 —
llama-arch small [hf:HuggingFaceTB/SmolLM-360M; hf].

15 heads % q != 0 exercises head padding; kv=5 exercises replicated KV."""
from .base import ArchConfig, ModelConfig

CONFIG = ArchConfig(
    model=ModelConfig(
        name="smollm-360m", family="dense",
        num_layers=32, d_model=960, num_heads=15, num_kv_heads=5,
        d_ff=2560, vocab_size=49152, mlp_act="silu", mlp_glu=True,
        rope_theta=1e4),
    notes="15 q-heads padded to 16 under q=2 (padded heads are exactly "
          "zeroed); 5 KV heads replicated within col groups.",
)


def reduced() -> ArchConfig:
    return ArchConfig(model=ModelConfig(
        name="smollm-360m-reduced", family="dense",
        num_layers=2, d_model=60, num_heads=3, num_kv_heads=1,
        d_ff=96, vocab_size=257, head_dim=20, mlp_act="silu", mlp_glu=True))
