"""ZeRO stage-1 optimizer-state sharding (DESIGN.md §9).

The AdamW moments (and the fp32 master copy under mixed precision) are the
largest fully *replicated* state in the trainer: every device of the
``data`` axis (and, for depth-replicated leaves, the ``depth`` axis) holds
an identical fp32 copy.  ZeRO-1 partitions that state so each device owns a
1/dp slice, trading one parameter all-gather per step for a dp-fold memory
cut (PAPERS.md: ZeRO / ZeRO-Infinity; Eq. 8's "lowers the memory required
for each GPU" extended to optimizer state).

Partitioning rule (per leaf, not global):

* A leaf may be *sharded* over some mesh axes (its PartitionSpec) and
  *replicated* over the rest.  Only the replicated DP-like axes — the
  candidates ``("data", "depth")``, plus ``"pipe"`` for stage-replicated
  leaves on a pipeline mesh — are safe to partition optimizer state over:
  partitioning over an axis the leaf is sharded on would orphan chunks
  (e.g. ``head`` is sharded over ``depth`` via ``P(("depth","row","col"))``
  and must keep its state depth-local).  ``zaxes(leaf) = candidates \
  spec_axes(leaf)``.
* The device-local shard (under the leaf's own spec) is flattened,
  zero-padded to a multiple of ``zn = prod(|zaxes|)`` and cut into ``zn``
  equal slices of length ``k`` — flat-index partitioning, so uneven leaves
  (ln vectors, padded vocab rows) work without per-shape cases.
* The global optimizer leaf is ``[n_slices, k]`` with dim 0 laid out
  lexicographically as ``(zaxes..., spec_axes...)`` — each device owns
  exactly one row.

Collective sequence per step (runtime/steps.py):

  grads (partial sums over zaxes) --psum_scatter--> grad slice [k]
  AdamW on the slice (m/v/master all [k], fp32)
  new param slice --cast to param_dtype--> all_gather over zaxes -> leaf

The host-side helpers below re-slice checkpointed optimizer state across
dp-degree changes (elastic 8 -> 4 replans) and between the replicated and
ZeRO layouts; layout metadata rides the checkpoint manifest.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np
import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from ..core import collectives as col

# Axes whose replicated copies of optimizer state are partitioned away.
# "pipe" joins on pipeline meshes (stage-replicated embed/head leaves).
ZERO_CANDIDATE_AXES = ("data", "depth")


def spec_dim_axes(spec: P) -> tuple:
    """Per-dimension tuple of mesh-axis names from a PartitionSpec."""
    out = []
    for entry in spec:
        if entry is None:
            out.append(())
        elif isinstance(entry, (tuple, list)):
            out.append(tuple(entry))
        else:
            out.append((entry,))
    return tuple(out)


@dataclass(frozen=True)
class LeafLayout:
    """Static per-leaf ZeRO-1 layout (hashable: usable inside jit)."""
    param_shape: tuple          # global param shape
    dim_axes: tuple             # per-dim tuple of sharding axis names
    zaxes: tuple                # state-partition axes (replicated DP axes)
    sizes: tuple                # ((axis, size), ...) for every involved axis

    # ---- derived ----
    @property
    def _sz(self) -> dict:
        return dict(self.sizes)

    @property
    def extra_axes(self) -> tuple:
        """Leaf's own sharding axes, flattened in spec order."""
        return tuple(a for dim in self.dim_axes for a in dim)

    @property
    def local_shape(self) -> tuple:
        sz = self._sz
        out = []
        for d, axes in zip(self.param_shape, self.dim_axes):
            f = 1
            for a in axes:
                f *= sz[a]
            if d % f:
                raise ValueError(
                    f"dim {d} of {self.param_shape} not divisible by its "
                    f"sharding axes {axes} (x{f})")
            out.append(d // f)
        return tuple(out)

    @property
    def zn(self) -> int:
        sz = self._sz
        n = 1
        for a in self.zaxes:
            n *= sz[a]
        return n

    @property
    def k(self) -> int:
        loc = 1
        for d in self.local_shape:
            loc *= d
        return -(-loc // self.zn)

    @property
    def n_extra(self) -> int:
        sz = self._sz
        n = 1
        for a in self.extra_axes:
            n *= sz[a]
        return n

    @property
    def n_slices(self) -> int:
        return self.zn * self.n_extra

    def state_spec(self) -> P:
        """PartitionSpec of the [n_slices, k] global optimizer leaf."""
        entries = self.zaxes + self.extra_axes
        return P(entries if entries else None, None)

    def abstract(self):
        return jax.ShapeDtypeStruct((self.n_slices, self.k), jnp.float32)

    # ---- (de)serialization for checkpoint manifests ----
    def to_json(self) -> dict:
        return {"param_shape": list(self.param_shape),
                "dim_axes": [list(d) for d in self.dim_axes],
                "zaxes": list(self.zaxes),
                "sizes": [list(s) for s in self.sizes]}

    @staticmethod
    def from_json(d: dict) -> "LeafLayout":
        return LeafLayout(
            param_shape=tuple(d["param_shape"]),
            dim_axes=tuple(tuple(x) for x in d["dim_axes"]),
            zaxes=tuple(d["zaxes"]),
            sizes=tuple((a, int(n)) for a, n in d["sizes"]))


def layout_for(spec: P, shape: tuple, axis_sizes: dict,
               candidates: tuple = ZERO_CANDIDATE_AXES) -> LeafLayout:
    """Layout of one leaf: partition its optimizer state over the candidate
    axes the leaf is NOT sharded on (its true replication axes)."""
    dim_axes = spec_dim_axes(spec)
    used = {a for dim in dim_axes for a in dim}
    zaxes = tuple(a for a in candidates if a not in used)
    involved = tuple(dict.fromkeys(zaxes + tuple(a for dim in dim_axes
                                                 for a in dim)))
    sizes = tuple((a, int(axis_sizes[a])) for a in involved)
    return LeafLayout(param_shape=tuple(shape), dim_axes=dim_axes,
                      zaxes=zaxes, sizes=sizes)


def build_layouts(specs_tree, abs_params, axis_sizes: dict,
                  candidates: tuple = ZERO_CANDIDATE_AXES):
    """Tree of LeafLayout matching a specs tree + abstract param tree."""
    return jax.tree.map(
        lambda sp, ab: layout_for(sp, ab.shape, axis_sizes, candidates),
        specs_tree, abs_params, is_leaf=lambda x: isinstance(x, P))


def layouts_to_json(layouts_tree) -> dict:
    """Flat {'a/b/c': layout-json} dict (checkpoint manifest metadata)."""
    flat = {}

    def rec(tree, prefix):
        if isinstance(tree, dict):
            for k in sorted(tree):
                rec(tree[k], f"{prefix}{k}/")
        else:
            flat[prefix.rstrip("/")] = tree.to_json()
    rec(layouts_tree, "")
    return flat


def zero_opt_init(bundle):
    """Fresh ZeRO-1 optimizer state for a train-step bundle: every slice
    starts at zero (the fp32 master slices are lazily adopted from the
    params at step 0 inside the step — runtime/steps.py)."""
    return jax.tree.map(lambda sd: jnp.zeros(sd.shape, sd.dtype),
                        bundle.abstract_inputs[1])


# ---------------------------------------------------------------------------
# device-side helpers (inside shard_map; x is the leaf's LOCAL shard)
# ---------------------------------------------------------------------------

def _pad_flat(x, lay: LeafLayout):
    k, zn = lay.k, lay.zn
    flat = x.reshape(-1)
    pad = k * zn - flat.size
    if pad:
        flat = jnp.pad(flat, (0, pad))
    return flat


def zslice(x, lay: LeafLayout):
    """This device's [k] slice of an already-reduced local value."""
    flat = _pad_flat(x, lay)
    if lay.zn == 1:
        return flat
    i = col.axis_linear_index(lay.zaxes)
    return lax.dynamic_slice_in_dim(flat, i * lay.k, lay.k, axis=0)


def zreduce_scatter(g, lay: LeafLayout, compress: str = "none"):
    """reduce_scatter of a gradient that is a PARTIAL SUM over ``zaxes``:
    each member contributes its padded flat grad, receives the fully
    reduced [k] slice it owns — the ZeRO-1 replacement for the data-axis
    grad psum (same wire bytes as the psum's reduce-scatter phase, no
    all-gather phase)."""
    flat = _pad_flat(g, lay)
    if lay.zn == 1:
        return flat
    if compress == "bf16" and flat.dtype == jnp.float32:
        return lax.psum_scatter(flat.astype(jnp.bfloat16), lay.zaxes,
                                scatter_dimension=0,
                                tiled=True).astype(jnp.float32)
    return lax.psum_scatter(flat, lay.zaxes, scatter_dimension=0, tiled=True)


def zgather(sl, lay: LeafLayout, dtype=None):
    """all_gather the updated slices back into the leaf's local shard.

    ``dtype`` casts BEFORE the gather (bf16 params ride the wire in bf16 —
    half the gather bytes of the fp32 master)."""
    if dtype is not None:
        sl = sl.astype(dtype)
    flat = (col.all_gather_inv(sl, lay.zaxes, tiled=True, axis=0)
            if lay.zn > 1 else sl)
    loc = lay.local_shape
    n = 1
    for d in loc:
        n *= d
    return flat[:n].reshape(loc)


# ---------------------------------------------------------------------------
# host-side re-sharding (checkpoint restore across layouts / dp degrees)
# ---------------------------------------------------------------------------

def _extra_strides(lay: LeafLayout):
    sizes = lay._sz
    axes = lay.extra_axes
    dims = [sizes[a] for a in axes]
    return axes, dims


def _block_slices(lay: LeafLayout, coords: dict):
    """Global-array slices of the local block at the given axis coords."""
    out = []
    for d, axes, loc in zip(lay.param_shape, lay.dim_axes, lay.local_shape):
        idx = 0
        for a in axes:
            idx = idx * lay._sz[a] + coords[a]
        out.append(slice(idx * loc, (idx + 1) * loc))
    return tuple(out)


def host_shard(full: np.ndarray, lay: LeafLayout) -> np.ndarray:
    """Full fp32 global array -> [n_slices, k] ZeRO layout (numpy)."""
    full = np.asarray(full)
    if tuple(full.shape) != lay.param_shape:
        raise ValueError(f"{full.shape} != layout {lay.param_shape}")
    zn, k, n_e = lay.zn, lay.k, lay.n_extra
    axes, dims = _extra_strides(lay)
    out = np.zeros((lay.n_slices, k), full.dtype)
    for lin_e, e in enumerate(np.ndindex(*dims) if dims else [()]):
        coords = dict(zip(axes, e))
        blk = full[_block_slices(lay, coords)].reshape(-1)
        flat = np.zeros(zn * k, full.dtype)
        flat[:blk.size] = blk
        out[np.arange(zn) * n_e + lin_e] = flat.reshape(zn, k)
    return out


def host_unshard(z: np.ndarray, lay: LeafLayout) -> np.ndarray:
    """[n_slices, k] ZeRO layout -> full global array (numpy)."""
    z = np.asarray(z)
    if tuple(z.shape) != (lay.n_slices, lay.k):
        raise ValueError(f"{z.shape} != layout ({lay.n_slices}, {lay.k})")
    zn, k, n_e = lay.zn, lay.k, lay.n_extra
    axes, dims = _extra_strides(lay)
    full = np.zeros(lay.param_shape, z.dtype)
    loc_n = 1
    for d in lay.local_shape:
        loc_n *= d
    for lin_e, e in enumerate(np.ndindex(*dims) if dims else [()]):
        coords = dict(zip(axes, e))
        flat = z[np.arange(zn) * n_e + lin_e].reshape(-1)
        full[_block_slices(lay, coords)] = \
            flat[:loc_n].reshape(lay.local_shape)
    return full


def convert_leaf(arr: np.ndarray, old_lay: LeafLayout | None,
                 new_lay: LeafLayout | None) -> np.ndarray:
    """Re-shard one optimizer leaf between layouts (None = replicated)."""
    if old_lay is None and new_lay is None:
        return arr
    if old_lay is not None and new_lay is not None \
            and old_lay.to_json() == new_lay.to_json():
        return arr
    full = host_unshard(arr, old_lay) if old_lay is not None else arr
    return host_shard(full, new_lay) if new_lay is not None else full


def make_ckpt_converter(target_layouts_json: dict | None,
                        state_key: str = "opt"):
    """``convert(path, arr, manifest_meta)`` for CheckpointManager.restore:
    re-shards ``opt/{m,v,master}/...`` leaves between the manifest's saved
    ZeRO layout and the restoring bundle's — across dp-degree changes
    (elastic replans) and to/from the replicated layout."""
    prefix = state_key + "/"

    def convert(path: str, arr, meta):
        if not path.startswith(prefix):
            return arr
        group, _, ppath = path[len(prefix):].partition("/")
        if group not in ("m", "v", "master") or not ppath:
            return arr
        old_json = ((meta or {}).get("opt_layout") or {}).get(ppath)
        new_json = (target_layouts_json or {}).get(ppath)
        if old_json == new_json:
            return arr
        old = LeafLayout.from_json(old_json) if old_json else None
        new = LeafLayout.from_json(new_json) if new_json else None
        return convert_leaf(np.asarray(arr), old, new)

    return convert
