"""Optimizers in pure JAX: AdamW and LAMB (paper cites LAMB/LARS for large
batch training).  All updates are elementwise on local shards, so they are
layout-oblivious — they run inside shard_map on whatever partitioning the
params use.  Master fp32 copies are kept when params are low-precision.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def _zeros_like_f32(p):
    return jnp.zeros(p.shape, jnp.float32)


def adamw_init(params, *, master: bool = False):
    st = {
        "m": jax.tree.map(_zeros_like_f32, params),
        "v": jax.tree.map(_zeros_like_f32, params),
        "step": jnp.zeros((), jnp.int32),
    }
    if master:
        st["master"] = jax.tree.map(lambda p: p.astype(jnp.float32), params)
    return st


def _check_state_f32(state):
    """The moments (and master copy) must stay fp32: a bf16 m/v silently
    destroys the running second moment (eps^2-scale values underflow).
    Raised at trace time — dtypes are static."""
    for name in ("m", "v", "master"):
        if name not in state:
            continue
        for path, leaf in jax.tree_util.tree_flatten_with_path(
                state[name])[0]:
            if leaf.dtype != jnp.float32:
                raise TypeError(
                    f"optimizer state {name}{jax.tree_util.keystr(path)} is "
                    f"{leaf.dtype}, must be float32 — a low-precision "
                    f"moment/master accumulates silent rounding error")


def adamw_update(params, grads, state, *, lr, b1=0.9, b2=0.95, eps=1e-8,
                 weight_decay=0.0):
    _check_state_f32(state)
    step = state["step"] + 1
    sf = step.astype(jnp.float32)
    c1 = 1.0 - b1 ** sf
    c2 = 1.0 - b2 ** sf
    gf = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
    new_m = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g, state["m"], gf)
    new_v = jax.tree.map(lambda v, g: b2 * v + (1 - b2) * g * g, state["v"], gf)
    master = state.get("master")
    pf = master if master is not None else jax.tree.map(
        lambda p: p.astype(jnp.float32), params)
    new_pf = jax.tree.map(
        lambda p, m, v: p - lr * ((m / c1) / (jnp.sqrt(v / c2) + eps)
                                  + weight_decay * p),
        pf, new_m, new_v)
    new_p = jax.tree.map(lambda p0, p: p.astype(p0.dtype), params, new_pf)
    new_state = dict(state, m=new_m, v=new_v, step=step)
    if master is not None:
        new_state["master"] = new_pf
    return new_p, new_state


def lamb_update(params, grads, state, *, lr, b1=0.9, b2=0.999, eps=1e-6,
                weight_decay=0.0, norm_fn=None):
    """LAMB: Adam update scaled by the per-leaf trust ratio ||p|| / ||u||.

    norm_fn(leaf) must return the *global* L2 norm of a (possibly sharded)
    leaf — the caller provides a layout-aware implementation (the default is
    only correct for unsharded leaves).
    """
    _check_state_f32(state)
    step = state["step"] + 1
    sf = step.astype(jnp.float32)
    c1 = 1.0 - b1 ** sf
    c2 = 1.0 - b2 ** sf
    if norm_fn is None:
        norm_fn = lambda leaf: jnp.sqrt(jnp.sum(leaf.astype(jnp.float32) ** 2))
    gf = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
    new_m = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g, state["m"], gf)
    new_v = jax.tree.map(lambda v, g: b2 * v + (1 - b2) * g * g, state["v"], gf)
    master = state.get("master")
    pf = master if master is not None else jax.tree.map(
        lambda p: p.astype(jnp.float32), params)
    upd = jax.tree.map(
        lambda p, m, v: (m / c1) / (jnp.sqrt(v / c2) + eps) + weight_decay * p,
        pf, new_m, new_v)

    def apply(p, u):
        pn, un = norm_fn(p), norm_fn(u)
        trust = jnp.where((pn > 0) & (un > 0), pn / un, 1.0)
        return p - lr * trust * u

    new_pf = jax.tree.map(apply, pf, upd)
    new_p = jax.tree.map(lambda p0, p: p.astype(p0.dtype), params, new_pf)
    new_state = dict(state, m=new_m, v=new_v, step=step)
    if master is not None:
        new_state["master"] = new_pf
    return new_p, new_state


def cosine_lr(step, *, base_lr, warmup: int, total: int, min_frac: float = 0.1):
    s = step.astype(jnp.float32)
    warm = base_lr * s / jnp.maximum(warmup, 1)
    prog = jnp.clip((s - warmup) / jnp.maximum(total - warmup, 1), 0.0, 1.0)
    cos = base_lr * (min_frac + (1 - min_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog)))
    return jnp.where(s < warmup, warm, cos)
