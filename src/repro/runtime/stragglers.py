"""Straggler detection for multi-host training.

On real clusters per-host step times are collected (e.g. via the coordination
service); here the monitor is host-agnostic logic unit-tested with injected
timings.  Policy: a host is flagged when its trailing-window mean exceeds the
fleet median by ``threshold`` x the fleet MAD (robust to a single outlier
skewing the mean).  Flagged hosts are candidates for preemptive eviction /
re-mesh (runtime/elastic.py).
"""
from __future__ import annotations

from collections import defaultdict, deque

import numpy as np


class StragglerMonitor:
    def __init__(self, window: int = 20, threshold: float = 4.0,
                 min_samples: int = 5, min_abs_dev: float = 1e-3,
                 min_rel_dev: float = 0.02):
        """min_abs_dev/min_rel_dev floor the robust scale estimate: on a
        healthy fleet the MAD is ~0 and a bare 1e-9 floor amplifies
        microsecond noise into "stragglers".  A host must now exceed the
        median by threshold x max(1.4826*MAD, min_abs_dev, min_rel_dev*med)
        — i.e. be meaningfully slower in absolute seconds AND relative
        terms before it is flagged."""
        self.window = window
        self.threshold = threshold
        self.min_samples = min_samples
        self.min_abs_dev = min_abs_dev
        self.min_rel_dev = min_rel_dev
        self._times = defaultdict(lambda: deque(maxlen=window))

    def record(self, host_id, step_time: float):
        self._times[host_id].append(step_time)

    def host_means(self):
        return {h: float(np.mean(t)) for h, t in self._times.items()
                if len(t) >= self.min_samples}

    def stragglers(self):
        means = self.host_means()
        if len(means) < 2:
            return []
        vals = np.array(list(means.values()))
        med = np.median(vals)
        mad = np.median(np.abs(vals - med))
        scale = max(1.4826 * mad, self.min_abs_dev, self.min_rel_dev * med)
        return [h for h, m in means.items()
                if (m - med) / scale > self.threshold]
