"""Elastic re-meshing: pick a valid parallel layout for whatever devices
survive a failure.

Policy (matches the paper's composition, Fig. 6): the tensor-parallel group
[q, q, d] is the atomic unit — a TP group that lost a member is dropped
whole — and the data axis absorbs the shrink.  The global batch is kept by
consuming ``Replan.accum_steps`` in the train loop (runtime/train_loop.py
passes it to ``build_train_step``): each optimizer step still sees the full
step-keyed batch, accumulated over ``accum_steps`` microbatches so
per-device activation memory stays constant and no tokens are dropped.
"""
from __future__ import annotations

from dataclasses import dataclass

from ..core.api import ParallelContext


@dataclass
class Replan:
    ctx: ParallelContext
    n_used: int
    n_idle: int
    accum_steps: int


def replan(n_devices: int, ctx: ParallelContext, *, global_batch: int,
           seq_sharded: bool = False) -> Replan:
    """Largest valid layout with the same TP factorization.

    Raises RuntimeError when the TP group no longer fits and ValueError when
    no surviving data-parallel width divides the global batch (an invalid
    plan must never be returned silently).
    """
    tp = ctx.tp
    if n_devices < tp:
        raise RuntimeError(
            f"cannot fit a [{ctx.rows},{ctx.cols},{ctx.depth}] TP group in "
            f"{n_devices} devices; reduce q/d in the config")
    shard_factor = 1 if seq_sharded else ctx.depth * ctx.rows
    for data in range(n_devices // tp, 0, -1):
        shards = data * shard_factor
        if global_batch % shards:
            continue
        # ceil: a non-divisible shrink (e.g. 8 -> 3 replicas) must round the
        # accumulation UP or each optimizer step would drop tokens.
        accum = -(-ctx.data // data)
        # accum microbatches must evenly split each shard's batch rows
        rows_per_shard = global_batch // shards
        while accum <= rows_per_shard and rows_per_shard % accum:
            accum += 1
        if accum > rows_per_shard:
            continue
        new_ctx = ctx.replace(data=data)
        used = data * tp
        return Replan(ctx=new_ctx, n_used=used, n_idle=n_devices - used,
                      accum_steps=accum)
    raise ValueError(
        f"no data-parallel width in [1, {n_devices // tp}] x "
        f"shard_factor={shard_factor} divides global_batch={global_batch}; "
        f"cannot produce a valid elastic plan")
