"""Elastic re-meshing: pick a valid parallel layout for whatever devices
survive a failure.

Policy (matches the paper's composition, Fig. 6): the tensor-parallel group
[q, q, d] is the atomic unit — a TP group that lost a member is dropped
whole — and the data axis absorbs the shrink.  The global batch is kept by
raising per-replica batch (grad accumulation if it no longer divides).
"""
from __future__ import annotations

from dataclasses import dataclass

from ..core.api import ParallelContext


@dataclass
class Replan:
    ctx: ParallelContext
    n_used: int
    n_idle: int
    accum_steps: int


def replan(n_devices: int, ctx: ParallelContext, *, global_batch: int,
           seq_sharded: bool = False) -> Replan:
    """Largest valid layout with the same TP factorization."""
    tp = ctx.tp
    if n_devices < tp:
        raise RuntimeError(
            f"cannot fit a [{ctx.rows},{ctx.cols},{ctx.depth}] TP group in "
            f"{n_devices} devices; reduce q/d in the config")
    data = n_devices // tp
    # token sharding must divide the global batch
    while data > 0:
        shards = data * (ctx.depth * ctx.rows if not seq_sharded else 1)
        if shards and global_batch % shards == 0:
            break
        data -= 1
    if data == 0:
        data = 1
    new_ctx = ctx.replace(data=data)
    used = data * tp
    # keep global batch via accumulation if batch-per-step shrank
    accum = max(1, ctx.data // data)
    return Replan(ctx=new_ctx, n_used=used, n_idle=n_devices - used,
                  accum_steps=accum)
