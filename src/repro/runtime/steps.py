"""Step builders: wire a model + ParallelContext + shape into jit-able
train / prefill / decode step functions (shard_map inside jit).

Gradient synchronization design (see DESIGN.md §2 and core/summa.py):

- Replication axes of every param leaf except ``data`` are handled by
  ``pvary`` at the loss boundary — its transpose inserts one fused psum per
  (stacked) leaf per step.
- The ``data`` (DP) axis is synced explicitly after grad computation so it
  can be compressed (bf16 wire format) — a distributed-optimization lever.
- ``ctx.reduce_dgrad_in_op=True`` switches the Tesseract matmul weights to
  the paper's literal per-op all-reduce schedule (baseline measurements).
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import NamedSharding, PartitionSpec as P

from ..configs.base import RunConfig, ShapeSpec
from ..core.api import LOGICAL_AXES, ParallelContext
from ..core.collectives import pvary, grad_sync, axis_size, shard_map
from ..core.ops import Plan, make_ops
from ..optim import adamw


# ---------------------------------------------------------------------------
# spec utilities
# ---------------------------------------------------------------------------

def spec_axes(spec: P) -> tuple:
    out = []
    for entry in spec:
        if entry is None:
            continue
        if isinstance(entry, (tuple, list)):
            out.extend(entry)
        else:
            out.append(entry)
    return tuple(out)


def replicated_axes(spec: P, axes: tuple = LOGICAL_AXES) -> tuple:
    """Mesh axes (from ``axes``) a leaf with partition ``spec`` is
    replicated over.  Pass ``ctx.mesh_axes`` so the seq axis (when active)
    counts as a replication axis for every param leaf."""
    used = set(spec_axes(spec))
    return tuple(a for a in axes if a not in used)


def rep_factor(ctx: ParallelContext, spec: P) -> int:
    sizes = dict(data=ctx.data, seq=ctx.seq, depth=ctx.depth, row=ctx.rows,
                 col=ctx.cols)
    f = 1
    for a in replicated_axes(spec, ctx.mesh_axes):
        f *= sizes[a]
    return f


def mark_by_name(tree, names: set, default=False):
    """Bool tree: True where any dict key on the leaf's path is in ``names``."""
    def f(path, _leaf):
        for p in path:
            key = getattr(p, "key", None)
            if key in names:
                return True
        return default
    return jax.tree_util.tree_map_with_path(f, tree)


def make_plan(ctx: ParallelContext, shape: ShapeSpec) -> Plan:
    return Plan.for_shape(shape.kind, global_batch=shape.global_batch,
                          batch_shards=ctx.batch_shards, data=ctx.data)


# ---------------------------------------------------------------------------
# bundles
# ---------------------------------------------------------------------------

@dataclass
class StepBundle:
    fn: Callable                 # jitted
    abstract_inputs: tuple       # trees of ShapeDtypeStruct (global shapes)
    in_shardings: tuple
    out_shardings: Any
    mesh: Any
    plan: Plan
    pipe_info: Any = None        # 1F1B schedule stats (pipelined steps only)
    # ZeRO-1 per-param-leaf optimizer-state layouts (optim/zero.LeafLayout
    # tree; None when the optimizer state is replicated).  Checkpoints store
    # layouts_to_json(opt_layouts) in their manifest so restore can re-shard
    # across dp-degree changes (checkpoint/ckpt.py + optim/zero.py).
    opt_layouts: Any = None
    # Ground truth for repro.analysis.shardcheck (train steps only): the
    # fused grad reductions the traced jaxpr must contain per axis set,
    # plus per-leaf layout facts for the zaxes-overlap rule.  See
    # _shardcheck_meta below for the schema.
    shardcheck_meta: Any = None

    def opt_layouts_json(self):
        from ..optim import zero as zopt
        return (zopt.layouts_to_json(self.opt_layouts)
                if self.opt_layouts is not None else None)


def _shardings(mesh, spec_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))


def _shardcheck_meta(mesh, specs, red_tree, is_tess, layouts):
    """StepBundle.shardcheck_meta for a train step: what the deferred
    grad-sync machinery promises the traced jaxpr will contain, derived
    from the same trees the step builder wires into grad_sync /
    zreduce_scatter (so the analyzer checks the implementation against the
    builder's intent, not against a re-derivation of it).

    Schema:
      mesh_axes / axis_sizes  — the declared mesh
      grad_psum_axes          — {sorted axis tuple: n leaves} fused grad
                                psums (grad_sync bwd / pipeline red())
      grad_rs_axes            — {sorted axis tuple: n leaves} ZeRO-1
                                zreduce_scatter calls (zn > 1 leaves only)
      leaves                  — per-leaf {name, spec_axes, reduce_axes,
                                zaxes, tess} for the layout rules
    """
    is_p = lambda x: isinstance(x, P)
    kps = jax.tree_util.tree_flatten_with_path(specs, is_leaf=is_p)[0]
    red_l = jax.tree_util.tree_leaves(
        red_tree, is_leaf=lambda x: isinstance(x, tuple))
    tess_l = jax.tree_util.tree_leaves(is_tess)
    lay_l = (jax.tree_util.tree_leaves(layouts)
             if layouts is not None else [None] * len(kps))
    psums: dict = {}
    rs: dict = {}
    leaves = []
    for (kp, spec), red, tess, lay in zip(kps, red_l, tess_l, lay_l):
        red = tuple(sorted(red))
        if red:
            psums[red] = psums.get(red, 0) + 1
        zaxes = tuple(sorted(lay.zaxes)) if lay is not None else ()
        if lay is not None and not tess and lay.zn > 1:
            rs[zaxes] = rs.get(zaxes, 0) + 1
        leaves.append({
            "name": jax.tree_util.keystr(kp),
            "spec_axes": tuple(spec_axes(spec)),
            "reduce_axes": red,
            "zaxes": zaxes,
            "tess": bool(tess),
        })
    return {
        "mesh_axes": tuple(str(a) for a in mesh.axis_names),
        "axis_sizes": dict(zip([str(a) for a in mesh.axis_names],
                               mesh.devices.shape)),
        "grad_psum_axes": psums,
        "grad_rs_axes": rs,
        "leaves": leaves,
    }


def batch_abstract(ops, shape: ShapeSpec, ctx: ParallelContext, model=None):
    """Global ShapeDtypeStructs + specs for the host-layout token batch."""
    B, S = shape.global_batch, shape.seq_len
    sds = jax.ShapeDtypeStruct
    if shape.kind == "train":
        t = sds((B, S), jnp.int32)
        shapes = {"tokens": t, "labels": t}
        specs = {"tokens": ops.spec_tokens_in(), "labels": ops.spec_tokens_in()}
    elif shape.kind == "prefill":
        t = sds((B, S), jnp.int32)
        shapes, specs = {"tokens": t}, {"tokens": ops.spec_tokens_in()}
    else:
        raise ValueError(shape.kind)
    if model is not None:
        for name, (sd, sp) in model.batch_extras(shape).items():
            shapes[name] = sd
            specs[name] = sp
    return shapes, specs


# ---------------------------------------------------------------------------
# ZeRO-1 optimizer section (shared by the flat and pipelined train steps)
# ---------------------------------------------------------------------------

def zero_optimizer_step(params, opt_state, grads, *, layouts, is_tess,
                        specs, axis_sizes, run, update_fn, lr, gnorm_axes,
                        mesh_axes=LOGICAL_AXES):
    """ZeRO-1 update inside shard_map (DESIGN.md §9): reduce_scatter the
    zaxes-partial grads into each device's [k] state slice (in-op tesseract
    weights arrive reduced: plain slice), clip on the slices, run the
    optimizer on the fp32 m/v/master slices (master lazily adopted from the
    params at step 0), and all_gather the new param slices back — cast to
    param dtype FIRST so bf16 params ride the wire in bf16.

    Returns (new_params, new_opt_state, grad_norm)."""
    from ..optim import zero as zopt

    g_sl = jax.tree.map(
        lambda g, lay, t: (zopt.zslice(g, lay) if t else
                           zopt.zreduce_scatter(g, lay,
                                                run.grad_compression)),
        grads, layouts, is_tess)

    # --- global grad-norm clip on the slices (every element counted once
    # across the zaxes groups; the leaf's remaining replication divided out
    # as in the dense path) ---
    def slice_sq(sl, lay, s):
        rem = tuple(a for a in replicated_axes(s, mesh_axes)
                    if a not in lay.zaxes)
        rep = 1
        for a in rem:
            rep *= axis_sizes[a]
        val = jnp.sum(sl.astype(jnp.float32) ** 2) / rep
        return pvary(val, rem)
    sq = sum(jax.tree.leaves(jax.tree.map(slice_sq, g_sl, layouts, specs)))
    gnorm = jnp.sqrt(lax.psum(sq, gnorm_axes))
    scale = jnp.minimum(1.0, run.grad_clip / (gnorm + 1e-6))
    g_sl = jax.tree.map(lambda g: g * scale, g_sl)

    p_sl = jax.tree.map(zopt.zslice, params, layouts)
    sq_ = lambda t: jax.tree.map(lambda x: x[0], t)  # [1, k] -> [k]
    st = {"step": opt_state["step"], "m": sq_(opt_state["m"]),
          "v": sq_(opt_state["v"])}
    if "master" in opt_state:
        # lazy master init: step 0 adopts the param slice
        is0 = (opt_state["step"] == 0)
        st["master"] = jax.tree.map(
            lambda m, pp: jnp.where(is0, pp.astype(jnp.float32), m),
            sq_(opt_state["master"]), p_sl)
    new_psl, new_state = update_fn(p_sl, g_sl, st, lr=lr,
                                   weight_decay=run.weight_decay)
    un = lambda t: jax.tree.map(lambda x: x[None], t)  # [k] -> [1, k]
    new_state = {"step": new_state["step"], "m": un(new_state["m"]),
                 "v": un(new_state["v"]),
                 **({"master": un(new_state["master"])}
                    if "master" in new_state else {})}
    new_params = jax.tree.map(
        lambda sl, p0, lay: zopt.zgather(sl, lay, p0.dtype),
        new_psl, params, layouts)
    return new_params, new_state, gnorm


# ---------------------------------------------------------------------------
# train step
# ---------------------------------------------------------------------------

def build_train_step(model, mesh, shape: ShapeSpec, *, accum_steps: int = 1,
                     fault_port: bool = False):
    """Build the jitted train step.

    accum_steps > 1 accumulates gradients over that many microbatches split
    from the (step-keyed) global batch before the single optimizer update —
    the knob ``runtime/elastic.Replan.accum_steps`` feeds so an elastic
    shrink keeps the global batch (and per-device activation memory)
    constant.  On a mesh with a ``pipe`` axis of size > 1 the pipelined
    1F1B builder is used instead (accum_steps folds into its microbatch
    count).

    Every step carries the non-finite update guard (DESIGN.md §11): when
    the loss or any gradient is NaN/Inf, the optimizer update is
    where-selected away — params and opt state come back bit-identical and
    ``metrics["skipped"]`` reads 1 — so one poisoned step can never corrupt
    the training state; the train loop retries/backs off the loss scale.

    fault_port=True adds a reserved scalar batch leaf ``fault_scale``
    multiplied into the gradients, the deterministic injection point
    ``runtime/faults.py`` uses to exercise that guard end-to-end (NaN/Inf
    grads by (seed, step), replayable).  Off by default: the compiled step
    and its batch schema are unchanged for normal runs.
    """
    if accum_steps < 1:
        raise ValueError(f"accum_steps must be >= 1, got {accum_steps}")
    if "pipe" in mesh.axis_names:
        # any mesh carrying a pipe axis trains through the 1F1B schedule —
        # a pipe=1 mesh is the exact 1-stage baseline of the same code path
        return _build_pipeline_train_step(model, mesh, shape, accum_steps,
                                          fault_port=fault_port)
    ctx: ParallelContext = model.ctx
    run: RunConfig = model.run
    if ctx.seq > 1:
        if not getattr(model, "supports_seq_shard", False):
            raise NotImplementedError(
                f"{type(model).__name__} does not support sequence-axis "
                f"sharding (supports_seq_shard=False): every time-mixing "
                f"op must be ring-able")
        if shape.seq_len % ctx.seq:
            raise ValueError(
                f"seq_len={shape.seq_len} not divisible by seq shards "
                f"{ctx.seq}")
        if model.batch_extras(shape):
            raise NotImplementedError(
                "seq-sharded training with modality extras is not "
                "supported (extra batch leaves would need seq striping)")
    maxes = ctx.mesh_axes
    plan = make_plan(ctx, shape)
    ops = make_ops(ctx, plan)

    specs = model.specs(ops)
    tess_names = getattr(model, "tess_weight_names", lambda: set())()
    inop = ctx.reduce_dgrad_in_op and ctx.mode in ("tesseract", "summa2d")
    is_tess = (mark_by_name(specs, tess_names) if inop
               else jax.tree.map(lambda _: False, specs))

    rep_tree = jax.tree.map(lambda s: rep_factor(ctx, s), specs)
    from ..core import collectives as col_mod
    from ..optim import zero as zopt

    use_zero = run.zero_enabled
    opt_master = run.master_weights
    if run.optimizer == "lamb":
        if use_zero:
            raise NotImplementedError(
                "optimizer='lamb' with ZeRO-1 is not wired: the trust "
                "ratios need unsharded per-leaf norms")
        def _leaf_norm(x):
            # global L2 of a sharded leaf.  On pre-vma jax psum_v reduces
            # replicated axes too (x the rep factor) — it cancels in LAMB's
            # ||p||/||u|| trust ratio because p and u share a layout.
            from ..core.collectives import psum_v
            return jnp.sqrt(psum_v(jnp.sum(x.astype(jnp.float32) ** 2),
                                   maxes))
        update_fn = partial(adamw.lamb_update, norm_fn=_leaf_norm)
    else:
        update_fn = adamw.adamw_update

    # ---- ZeRO-1 (DESIGN.md §9): per-leaf optimizer-state partitioning ----
    # Each leaf's state is partitioned over the DP-like axes the leaf is
    # REPLICATED on (zaxes = (data, depth) minus the leaf's own sharding
    # axes — head/experts are depth-sharded and keep their state
    # depth-local).  The data/depth grad psum is replaced by a
    # reduce_scatter onto the flat-index slice; the update runs on the
    # slice and one all_gather per leaf (in param dtype — bf16 wire under
    # mixed precision) rebuilds the params.
    axis_sizes = dict(data=ctx.data, depth=ctx.depth, row=ctx.rows,
                      col=ctx.cols, **(dict(seq=ctx.seq) if ctx.seq > 1
                                       else {}))
    abs_params = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    layouts = (zopt.build_layouts(specs, abs_params, axis_sizes)
               if use_zero else None)

    def pvary_axes(s, t):
        if t:  # in-op tesseract weight: custom bwd reduces (data, depth
            # [, seq]) — summa._dgrad_axes covers the seq axis in-op
            return ()
        ax = replicated_axes(s, maxes)
        if use_zero:
            # the leaf's zaxes stay UNREDUCED here: zreduce_scatter below
            # reduces them into the device-local state slice instead
            ax = tuple(a for a in ax if a not in zopt.ZERO_CANDIDATE_AXES)
        return ax

    ls = run.loss_scale

    def local_step(params, opt_state, batch):
        fscale = None
        if fault_port:
            batch = dict(batch)
            fscale = batch.pop("fault_scale")

        def loss_fn(p, mb):
            # grad_sync: fwd pvary / bwd fused (optionally bf16-compressed)
            # psum over each leaf's replication axes — the deferred form of
            # the paper's depth all-reduce, plus the DP reduction (under
            # ZeRO-1 the DP reduction moves to the reduce_scatter below).
            pv = jax.tree.map(
                lambda x, s, t: grad_sync(x, pvary_axes(s, t),
                                          run.grad_compression),
                p, specs, is_tess)
            out = model.loss(pv, mb, ops)
            return out * ls if ls != 1.0 else out

        if accum_steps == 1:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        else:
            # microbatch gradient accumulation: split every batch leaf's
            # local batch dim into accum_steps slices and scan, so only one
            # microbatch's activations are ever live.  Equal-sized
            # microbatches -> mean-of-means == full-batch mean CE.
            mbs = jax.tree.map(
                lambda x: x.reshape((accum_steps, x.shape[0] // accum_steps)
                                    + x.shape[1:]), batch)

            def micro(carry, mb):
                c_loss, c_grads = carry
                l, g = jax.value_and_grad(loss_fn)(params, mb)
                return (c_loss + l, jax.tree.map(jnp.add, c_grads, g)), None

            init = (jnp.float32(0),
                    jax.tree.map(lambda p: p * 0, params))
            (loss, grads), _ = lax.scan(micro, init, mbs)
            loss = loss / accum_steps
            grads = jax.tree.map(lambda g: g / accum_steps, grads)

        if ls != 1.0:  # static loss scaling: unscale before clip/optimizer
            loss = loss / ls
            grads = jax.tree.map(lambda g: g / ls, grads)
        if fscale is not None:
            grads = jax.tree.map(lambda g: g * fscale, grads)

        if not col_mod.HAS_VMA:
            # Pre-vma jax seeds ALL p replicated copies of the loss scalar
            # (psum transposes to psum), so value_and_grad returns exactly
            # p x the true gradient for every leaf; vma jax seeds the one
            # invariant scalar and needs no correction.
            p_rep = ctx.data * ctx.seq * ctx.depth * ctx.rows * ctx.cols
            if p_rep > 1:
                grads = jax.tree.map(lambda g: g / p_rep, grads)

        lr = adamw.cosine_lr(opt_state["step"], base_lr=run.lr,
                             warmup=100, total=10000)
        if use_zero:
            new_params, new_state, gnorm = zero_optimizer_step(
                params, opt_state, grads, layouts=layouts, is_tess=is_tess,
                specs=specs, axis_sizes=axis_sizes, run=run,
                update_fn=update_fn, lr=lr, gnorm_axes=maxes,
                mesh_axes=maxes)
        else:
            # --- global grad-norm clip (layout aware) ---
            def leaf_sq(g, rep, s):
                val = jnp.sum(g.astype(jnp.float32) ** 2) / rep
                return pvary(val, replicated_axes(s, maxes))
            sq = sum(jax.tree.leaves(jax.tree.map(leaf_sq, grads, rep_tree,
                                                  specs)))
            gnorm = jnp.sqrt(lax.psum(sq, maxes))
            scale = jnp.minimum(1.0, run.grad_clip / (gnorm + 1e-6))
            grads = jax.tree.map(lambda g: g * scale, grads)
            new_params, new_state = update_fn(
                params, grads, opt_state, lr=lr,
                weight_decay=run.weight_decay)
        # non-finite update guard: any NaN/Inf grad poisons gnorm (sum of
        # squares), so one scalar predicate covers every leaf; the select
        # keeps params/opt bit-identical on a poisoned step
        finite = jnp.isfinite(loss) & jnp.isfinite(gnorm)
        new_params = jax.tree.map(lambda n, o: jnp.where(finite, n, o),
                                  new_params, params)
        new_state = jax.tree.map(lambda n, o: jnp.where(finite, n, o),
                                 new_state, opt_state)
        metrics = {"loss": loss, "grad_norm": gnorm, "lr": lr,
                   "skipped": 1.0 - finite.astype(jnp.float32)}
        return new_params, new_state, metrics

    if use_zero:
        # opt leaves: [n_slices, k] with dim0 mapped over the leaf's zaxes
        # PLUS its own sharded axes (row-replicated leaves must stay
        # row-replicated in their opt slices or the reconstructed param's
        # vma would spuriously vary over row).
        zspec_tree = jax.tree.map(lambda lay: lay.state_spec(), layouts)
        opt_specs = {"m": zspec_tree, "v": zspec_tree, "step": P(),
                     **({"master": zspec_tree} if opt_master else {})}
    else:
        opt_specs = {
            "m": specs, "v": specs, "step": P(),
            **({"master": specs} if opt_master else {}),
        }
    batch_sds, batch_specs_ = batch_abstract(ops, shape, ctx, model)
    if accum_steps > 1:
        # tokens/labels are additionally split over row by embed's
        # reduce-scatter, so each microbatch must keep that divisible too
        row_factor = ctx.rows if ctx.mode != "megatron1d" else 1
        for name, sd in batch_sds.items():
            loc0 = NamedSharding(mesh, batch_specs_[name]).shard_shape(
                tuple(sd.shape))[0]
            rf = row_factor if name in ("tokens", "labels", "mask") else 1
            if loc0 % accum_steps or (loc0 // accum_steps) % rf:
                raise ValueError(
                    f"accum_steps={accum_steps} does not evenly split batch "
                    f"leaf {name!r}: local batch {loc0} (global "
                    f"{sd.shape[0]}) must divide into accum_steps "
                    f"microbatches of a multiple of the row factor {rf}; "
                    f"pick accum_steps dividing global_batch/"
                    f"(data*depth*row) or re-plan")
    if fault_port:
        batch_sds = dict(batch_sds,
                         fault_scale=jax.ShapeDtypeStruct((), jnp.float32))
        batch_specs_ = dict(batch_specs_, fault_scale=P())
    metric_specs = {"loss": P(), "grad_norm": P(), "lr": P(),
                    "skipped": P()}

    smapped = shard_map(
        local_step, mesh=mesh,
        in_specs=(specs, opt_specs, batch_specs_),
        out_specs=(specs, opt_specs, metric_specs))
    if ctx.seq > 1 and ctx.train_attn_schedule() == "striped":
        # Striped ring attention (DESIGN.md §15): permute the TIME dim of
        # the host-layout batch inside jit, before shard_map, so seq shard
        # r receives global positions r, r+seq, r+2*seq, ... .  Labels ride
        # the same permutation (they are per-position), ops.positions()
        # emits the matching striped RoPE positions, and the ring mask in
        # core/ring_attention.py assumes exactly this placement.
        from ..core.ring_attention import stripe_permutation
        perm = jnp.asarray(stripe_permutation(shape.seq_len, ctx.seq))
        inner = smapped

        def smapped(params, opt_state, batch):
            batch = {k: (v[:, perm] if k in ("tokens", "labels", "mask")
                         else v) for k, v in batch.items()}
            return inner(params, opt_state, batch)

    in_sh = (_shardings(mesh, specs), _shardings(mesh, opt_specs),
             _shardings(mesh, batch_specs_))
    out_sh = (_shardings(mesh, specs), _shardings(mesh, opt_specs),
              _shardings(mesh, metric_specs))
    fn = jax.jit(smapped, donate_argnums=(0, 1), in_shardings=in_sh,
                 out_shardings=out_sh)

    if use_zero:
        zt = jax.tree.map(lambda lay: lay.abstract(), layouts)
        abs_opt = {"m": zt, "v": zt,
                   "step": jax.ShapeDtypeStruct((), jnp.int32),
                   **({"master": zt} if opt_master else {})}
    else:
        abs_opt = jax.eval_shape(partial(adamw.adamw_init, master=opt_master),
                                 abs_params)
    red_tree = jax.tree.map(
        lambda s, t: tuple(sorted(pvary_axes(s, t))), specs, is_tess)
    return StepBundle(
        fn=fn,
        abstract_inputs=(abs_params, abs_opt, batch_sds),
        in_shardings=in_sh, out_shardings=out_sh, mesh=mesh, plan=plan,
        opt_layouts=layouts,
        shardcheck_meta=_shardcheck_meta(mesh, specs, red_tree, is_tess,
                                         layouts))


# ---------------------------------------------------------------------------
# pipelined train step (1F1B over a [pipe x data x depth x row x col] mesh)
# ---------------------------------------------------------------------------

def _build_pipeline_train_step(model, mesh, shape: ShapeSpec,
                               accum_steps: int = 1,
                               fault_port: bool = False):
    """Train step with pipeline parallelism OUTSIDE the Tesseract TP group
    (paper §3.4): stage-sharded block params/opt state over the mesh's
    ``pipe`` axis, 1F1B microbatch schedule (runtime/pipeline.py), loss and
    grad reduction on the last stage, deferred replication-axis grad psums
    extended with the pipe axis for the stage-replicated leaves (embed /
    head / final norm).  ``accum_steps`` folds into the microbatch count —
    in PP, gradient accumulation IS more microbatches through the same
    flush, which also shrinks the bubble.
    """
    from ..core import collectives as col_mod
    from .pipeline import pipeline_1f1b_grads

    ctx: ParallelContext = model.ctx
    run: RunConfig = model.run
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    S_pipe = int(sizes["pipe"])
    if shape.kind != "train":
        raise ValueError(f"pipeline step only supports train shapes, "
                         f"got {shape.kind!r}")
    if not getattr(model, "supports_pipeline", False):
        raise NotImplementedError(
            f"{type(model).__name__} does not support the pipeline stage "
            f"API (supports_pipeline=False)")
    if model.batch_extras(shape):
        raise NotImplementedError("pipelined training with modality extras "
                                  "is not supported")
    if run.optimizer != "adamw":
        raise NotImplementedError("pipelined training supports "
                                  "optimizer='adamw' only")
    if ctx.mode not in ("tesseract", "summa2d"):
        raise NotImplementedError(f"pipeline requires a tesseract/summa2d "
                                  f"TP group, got {ctx.mode!r}")
    L = model.cfg.num_layers
    if L % S_pipe:
        raise ValueError(f"num_layers={L} not divisible by pipe={S_pipe}")
    M = (run.pipeline_microbatches or 2 * S_pipe) * accum_steps
    B, S_seq = shape.global_batch, shape.seq_len
    tok_shards = ctx.data * ctx.depth   # host-layout batch-dim sharding
    if B % (tok_shards * M):
        raise ValueError(
            f"global_batch={B} not divisible by data*depth*microbatches="
            f"{tok_shards}*{M}")
    mb_host = B // (tok_shards * M)
    if mb_host % ctx.rows:
        raise ValueError(f"microbatch rows {mb_host} not divisible by the "
                         f"row factor {ctx.rows} (embed reduce-scatter)")
    if model.cfg.d_model % max(ctx.cols, 1):
        raise ValueError(f"d_model={model.cfg.d_model} not divisible by "
                         f"cols={ctx.cols}")

    plan = make_plan(ctx, shape)
    ops = make_ops(ctx, plan)
    specs = model.specs(ops)
    tess_names = getattr(model, "tess_weight_names", lambda: set())()
    inop = ctx.reduce_dgrad_in_op and ctx.mode in ("tesseract", "summa2d")
    is_tess = (mark_by_name(specs, tess_names) if inop
               else jax.tree.map(lambda _: False, specs))
    pipe_sharded = mark_by_name(specs, {"blocks"})

    def _pipe_spec(sp):
        entries = tuple(sp)
        if not entries or entries[0] is not None:
            raise ValueError(f"block spec {sp} is not stacked (dim0 must be "
                             f"the layer dim)")
        return P(*(("pipe",) + entries[1:]))

    pspecs = dict(specs)
    pspecs["blocks"] = jax.tree.map(_pipe_spec, specs["blocks"],
                                    is_leaf=lambda x: isinstance(x, P))
    rep_tree = jax.tree.map(
        lambda s, psh: rep_factor(ctx, s) * (1 if psh else S_pipe),
        specs, pipe_sharded)

    from ..optim import zero as zopt
    use_zero = run.zero_enabled
    # ZeRO-1 on the pipe mesh: "pipe" joins the candidate partition axes, so
    # stage-replicated leaves (embed/head/final norm) shard their state over
    # (data, depth, pipe) while stage-sharded blocks shard over (data,
    # depth) within their stage (DESIGN.md §9).
    zcand = zopt.ZERO_CANDIDATE_AXES + ("pipe",)
    axis_sizes = dict(data=ctx.data, depth=ctx.depth, row=ctx.rows,
                      col=ctx.cols, pipe=S_pipe)
    abs_params = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    layouts = (zopt.build_layouts(pspecs, abs_params, axis_sizes,
                                  candidates=zcand) if use_zero else None)

    # deferred grad reductions: replication axes of each leaf, plus pipe for
    # the stage-replicated leaves; in-op tesseract weights already reduced
    # (data, depth) inside the matmul bwd and are stage-sharded -> ().
    # Under ZeRO-1 the leaf's zaxes are left UNREDUCED here — the
    # reduce_scatter in the optimizer section reduces them.
    def _red_axes(s, t, psh):
        ax = () if t else replicated_axes(s)
        ax = ax if psh else ax + ("pipe",)
        if use_zero:
            ax = tuple(a for a in ax if a not in zcand)
        return ax
    red_axes = jax.tree.map(_red_axes, specs, is_tess, pipe_sharded)

    mb_can = mb_host // ctx.rows
    h_loc = model.cfg.d_model // ctx.cols
    cdt = model.cdt
    opt_master = run.master_weights
    from .pipeline import schedule_1f1b
    sched = schedule_1f1b(M, S_pipe)   # simulated once, shared with the step

    def local_step(params, opt_state, batch):
        fscale = batch["fault_scale"] if fault_port else None
        tokens, labels = batch["tokens"], batch["labels"]
        tok_mb = tokens.reshape((M, tokens.shape[0] // M) + tokens.shape[1:])
        lab_mb = labels.reshape((M, labels.shape[0] // M) + labels.shape[1:])
        # CE count is label-count (no mask on this path): static, so the
        # backward seed 1/total is available before the first fwd finishes.
        # run.loss_scale folds into the seed; grads are unscaled below.
        seed = jnp.float32(run.loss_scale) / jnp.float32(B * S_seq)

        def stage_step(p, a, m_idx):
            tok = lax.dynamic_index_in_dim(tok_mb, m_idx, 0, keepdims=False)
            lab = lax.dynamic_index_in_dim(lab_mb, m_idx, 0, keepdims=False)
            x0 = model.pipe_embed(p, tok, ops)
            sid = lax.axis_index("pipe")
            x_in = jnp.where(sid == 0, x0, a)
            y = model.pipe_blocks(p, x_in, ops)
            ls, cnt = model.pipe_loss_sums(p, y, lab, ops)
            return y, ls, cnt

        a_proto = jnp.zeros((mb_can, S_seq, h_loc), cdt)
        loss_sum, cnt_sum, grads, _ = pipeline_1f1b_grads(
            stage_step, params, a_proto, M, axis="pipe", loss_seed=seed,
            schedule=sched)
        loss_sum = lax.psum(loss_sum, (ctx.axis_data, "pipe"))
        cnt = lax.psum(cnt_sum, (ctx.axis_data, "pipe"))
        loss = loss_sum / jnp.maximum(cnt, 1.0)

        if not col_mod.HAS_VMA:
            # Pre-vma jax: every model-group member seeds its own replicated
            # copy of the last stage's loss sums (psum transposes to psum),
            # so grads arrive scaled by the model-group size.  The data axis
            # is NOT included here: its reduction happens outside the vjp.
            corr = ctx.depth * ctx.rows * ctx.cols
            if corr > 1:
                grads = jax.tree.map(lambda g: g / corr, grads)

        def red(g, ax):
            if not ax:
                return g
            if run.grad_compression == "bf16":
                return lax.psum(g.astype(jnp.bfloat16),
                                tuple(ax)).astype(g.dtype)
            return lax.psum(g, tuple(ax))
        grads = jax.tree.map(red, grads, red_axes)
        if run.loss_scale != 1.0:
            grads = jax.tree.map(lambda g: g / run.loss_scale, grads)
        if fscale is not None:
            grads = jax.tree.map(lambda g: g * fscale, grads)

        lr = adamw.cosine_lr(opt_state["step"], base_lr=run.lr,
                             warmup=100, total=10000)
        if use_zero:
            new_params, new_state, gnorm = zero_optimizer_step(
                params, opt_state, grads, layouts=layouts, is_tess=is_tess,
                specs=specs, axis_sizes=axis_sizes, run=run,
                update_fn=adamw.adamw_update, lr=lr,
                gnorm_axes=LOGICAL_AXES + ("pipe",))
        else:
            # --- global grad-norm clip (layout + stage aware) ---
            def leaf_sq(g, rep, s, psh):
                val = jnp.sum(g.astype(jnp.float32) ** 2) / rep
                return pvary(val, replicated_axes(s) + (() if psh
                                                        else ("pipe",)))
            sq = sum(jax.tree.leaves(jax.tree.map(
                leaf_sq, grads, rep_tree, specs, pipe_sharded)))
            gnorm = jnp.sqrt(lax.psum(sq, LOGICAL_AXES + ("pipe",)))
            scale = jnp.minimum(1.0, run.grad_clip / (gnorm + 1e-6))
            grads = jax.tree.map(lambda g: g * scale, grads)
            new_params, new_state = adamw.adamw_update(
                params, grads, opt_state, lr=lr,
                weight_decay=run.weight_decay)
        # non-finite update guard (same contract as the flat-mesh step)
        finite = jnp.isfinite(loss) & jnp.isfinite(gnorm)
        new_params = jax.tree.map(lambda n, o: jnp.where(finite, n, o),
                                  new_params, params)
        new_state = jax.tree.map(lambda n, o: jnp.where(finite, n, o),
                                 new_state, opt_state)
        metrics = {"loss": loss, "grad_norm": gnorm, "lr": lr,
                   "skipped": 1.0 - finite.astype(jnp.float32)}
        return new_params, new_state, metrics

    if use_zero:
        zspec_tree = jax.tree.map(lambda lay: lay.state_spec(), layouts)
        opt_specs = {"m": zspec_tree, "v": zspec_tree, "step": P(),
                     **({"master": zspec_tree} if opt_master else {})}
    else:
        opt_specs = {
            "m": pspecs, "v": pspecs, "step": P(),
            **({"master": pspecs} if opt_master else {}),
        }
    batch_sds, batch_specs_ = batch_abstract(ops, shape, ctx, model)
    if fault_port:
        batch_sds = dict(batch_sds,
                         fault_scale=jax.ShapeDtypeStruct((), jnp.float32))
        batch_specs_ = dict(batch_specs_, fault_scale=P())
    metric_specs = {"loss": P(), "grad_norm": P(), "lr": P(),
                    "skipped": P()}

    smapped = shard_map(
        local_step, mesh=mesh,
        in_specs=(pspecs, opt_specs, batch_specs_),
        out_specs=(pspecs, opt_specs, metric_specs))
    in_sh = (_shardings(mesh, pspecs), _shardings(mesh, opt_specs),
             _shardings(mesh, batch_specs_))
    out_sh = (_shardings(mesh, pspecs), _shardings(mesh, opt_specs),
              _shardings(mesh, metric_specs))
    fn = jax.jit(smapped, donate_argnums=(0, 1), in_shardings=in_sh,
                 out_shardings=out_sh)
    if use_zero:
        zt = jax.tree.map(lambda lay: lay.abstract(), layouts)
        abs_opt = {"m": zt, "v": zt,
                   "step": jax.ShapeDtypeStruct((), jnp.int32),
                   **({"master": zt} if opt_master else {})}
    else:
        abs_opt = jax.eval_shape(partial(adamw.adamw_init, master=opt_master),
                                 abs_params)
    return StepBundle(
        fn=fn,
        abstract_inputs=(abs_params, abs_opt, batch_sds),
        in_shardings=in_sh, out_shardings=out_sh, mesh=mesh, plan=plan,
        pipe_info=sched[3], opt_layouts=layouts,
        shardcheck_meta=_shardcheck_meta(mesh, pspecs, red_axes, is_tess,
                                         layouts))


# ---------------------------------------------------------------------------
# serve steps
# ---------------------------------------------------------------------------

def build_prefill_step(model, mesh, shape: ShapeSpec, *,
                       with_lengths: bool = False):
    """Prefill step.  With ``with_lengths=True`` the batch gains a
    ``lengths`` [B] input (true prompt lengths of right-padded prompts) and
    the first output is full-vocab LOGITS at each request's own last
    position instead of greedy ids — the serve engine's bucketed prefill."""
    ctx = model.ctx
    plan = make_plan(ctx, shape)
    ops = make_ops(ctx, plan)
    specs = model.specs(ops)

    def local_step(params, batch):
        ids, cache = model.prefill(params, batch, ops)
        if ids.ndim == 1:
            ids = ids[:, None]
        return ids, cache

    # prefill-layout cache: [L, B/data(loc), S, kvh_loc, D]
    cache_specs = model.prefill_cache_specs(ops)
    ids_spec = P("data", None) if plan.kind != "long_decode" else P(None, None)
    batch_sds, batch_specs_ = batch_abstract(ops, shape, ctx, model)
    if with_lengths:
        batch_sds["lengths"] = jax.ShapeDtypeStruct((shape.global_batch,),
                                                    jnp.int32)
        batch_specs_["lengths"] = P("data")

    in_sh = (_shardings(mesh, specs), _shardings(mesh, batch_specs_))
    out_sh = (NamedSharding(mesh, ids_spec), _shardings(mesh, cache_specs))
    smapped = shard_map(local_step, mesh=mesh,
                            in_specs=(specs, batch_specs_),
                            out_specs=(ids_spec, cache_specs))
    fn = jax.jit(smapped, in_shardings=in_sh, out_shardings=out_sh)
    abs_params = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    return StepBundle(fn=fn, abstract_inputs=(abs_params, batch_sds),
                      in_shardings=in_sh, out_shardings=out_sh,
                      mesh=mesh, plan=plan)


def build_decode_step(model, mesh, shape: ShapeSpec):
    ctx = model.ctx
    plan = make_plan(ctx, shape)
    ops = make_ops(ctx, plan)
    specs = model.specs(ops)
    cache_sds, cache_specs = model.cache_abstract(shape.global_batch,
                                                  shape.seq_len, plan)

    def local_step(params, cache, ids, pos):
        nids, new_cache = model.decode(params, cache, ids, pos, ops)
        nids = unshard_ids(ops, ctx, nids, plan)
        return nids, new_cache

    ids_sds = jax.ShapeDtypeStruct((shape.global_batch, 1), jnp.int32)
    ids_spec = ops.spec_tokens_in()
    pos_sds = jax.ShapeDtypeStruct((), jnp.int32)

    in_sh = (_shardings(mesh, specs), _shardings(mesh, cache_specs),
             NamedSharding(mesh, ids_spec), NamedSharding(mesh, P()))
    out_sh = (NamedSharding(mesh, ids_spec), _shardings(mesh, cache_specs))
    smapped = shard_map(local_step, mesh=mesh,
                            in_specs=(specs, cache_specs, ids_spec, P()),
                            out_specs=(ids_spec, cache_specs))
    fn = jax.jit(smapped, donate_argnums=(1,), in_shardings=in_sh,
                 out_shardings=out_sh)
    abs_params = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    return StepBundle(fn=fn,
                      abstract_inputs=(abs_params, cache_sds, ids_sds, pos_sds),
                      in_shardings=in_sh, out_shardings=out_sh,
                      mesh=mesh, plan=plan)


# ---------------------------------------------------------------------------
# paged serving steps (serve/ continuous batching; DESIGN.md §7)
# ---------------------------------------------------------------------------

def _group_spec(gaxes, *extra):
    return P(gaxes if gaxes else None, *extra)


def build_paged_decode_step(model, mesh, n_slots: int, num_blocks: int,
                            block_size: int, max_blocks: int):
    """Decode step against a mesh-sharded paged KV pool.

    fn(params, pool, tables, pos, ids) -> (logits, pool)

    - pool: {"k","v": [L, P, bs, Hkv, D]} (donated), block axis sharded over
      the plan's KV group axes, heads over col.
    - tables: [n_slots, max_blocks] int32 GLOBAL block ids (each slot's
      entries point into its own group's partition; the local step subtracts
      the group offset).
    - pos: [n_slots] int32 per-request positions (mixed lengths).
    - ids: [n_slots, 1] int32 host-layout input tokens.
    - logits: [n_slots, v_pad] float32 full-vocab rows for the sampler.

    Attention data path per ctx.attn_impl (DESIGN.md §10): the jnp fallback
    gathers each slot's table view per layer; "pallas" walks the LOCAL
    tables inside the block-table decode kernel (scalar-prefetched, pages
    stream HBM->VMEM, no gather) — the offset subtraction below keeps the
    kernel's local-id contract on every KV group.
    """
    from ..core.ops import kv_group_axes
    from ..core import collectives as col_mod

    ctx = model.ctx
    plan = make_plan(ctx, ShapeSpec("paged", 1, n_slots, "decode"))
    ops = make_ops(ctx, plan)
    specs = model.specs(ops)
    pool_sds, pool_specs = model.paged_cache_abstract(num_blocks, block_size,
                                                      plan)
    gaxes = kv_group_axes(ctx, plan)
    sizes = dict(data=ctx.data, depth=ctx.depth, row=ctx.rows, col=ctx.cols)
    n_groups = 1
    for a in gaxes:
        n_groups *= sizes[a]
    bpg = num_blocks // n_groups

    table_spec = _group_spec(gaxes, None)
    pos_spec = _group_spec(gaxes)
    logits_spec = _group_spec(gaxes, None)
    ids_spec = ops.spec_tokens_in()

    def local_step(params, pool, tables, pos, ids):
        if gaxes:
            tables = tables - col_mod.axis_linear_index(gaxes) * bpg
        logits, new_pool = model.decode_paged(params, pool, tables, ids,
                                              pos, ops)
        return logits, new_pool

    tables_sds = jax.ShapeDtypeStruct((n_slots, max_blocks), jnp.int32)
    pos_sds = jax.ShapeDtypeStruct((n_slots,), jnp.int32)
    ids_sds = jax.ShapeDtypeStruct((n_slots, 1), jnp.int32)

    in_specs = (specs, pool_specs, table_spec, pos_spec, ids_spec)
    out_specs = (logits_spec, pool_specs)
    in_sh = (_shardings(mesh, specs), _shardings(mesh, pool_specs),
             NamedSharding(mesh, table_spec), NamedSharding(mesh, pos_spec),
             NamedSharding(mesh, ids_spec))
    out_sh = (NamedSharding(mesh, logits_spec), _shardings(mesh, pool_specs))
    smapped = shard_map(local_step, mesh=mesh, in_specs=in_specs,
                        out_specs=out_specs)
    fn = jax.jit(smapped, donate_argnums=(1,), in_shardings=in_sh,
                 out_shardings=out_sh)
    abs_params = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    return StepBundle(fn=fn,
                      abstract_inputs=(abs_params, pool_sds, tables_sds,
                                       pos_sds, ids_sds),
                      in_shardings=in_sh, out_shardings=out_sh,
                      mesh=mesh, plan=plan)


def build_chunk_prefill_step(model, mesh, n_slots: int, chunk: int,
                             num_blocks: int, block_size: int,
                             max_blocks: int):
    """Chunked prefill against the SAME mesh-sharded paged pool as decode.

    fn(params, pool, tables, pos, lens, ids) -> (logits, pool)

    - ids: [n_slots, chunk] int32 host-layout prompt tokens (0-padded).
    - pos: [n_slots] int32 chunk start (== tokens already cached).
    - lens: [n_slots] int32 valid positions this chunk (0 = idle slot).
    - logits: [n_slots, v_pad] rows taken at each slot's last valid chunk
      position — the sampler reads them only for slots whose prompt
      completes this chunk.

    One compile per chunk width; the engine reuses the decode plan's
    sharding (tables/pos/lens group-sharded, ids over the token axes), so
    interleaving chunk and decode steps never reshards the pool.
    """
    from ..core.ops import kv_group_axes
    from ..core import collectives as col_mod

    ctx = model.ctx
    plan = make_plan(ctx, ShapeSpec("paged", 1, n_slots, "decode"))
    ops = make_ops(ctx, plan)
    specs = model.specs(ops)
    pool_sds, pool_specs = model.paged_cache_abstract(num_blocks, block_size,
                                                      plan)
    gaxes = kv_group_axes(ctx, plan)
    sizes = dict(data=ctx.data, depth=ctx.depth, row=ctx.rows, col=ctx.cols)
    n_groups = 1
    for a in gaxes:
        n_groups *= sizes[a]
    bpg = num_blocks // n_groups

    table_spec = _group_spec(gaxes, None)
    pos_spec = _group_spec(gaxes)
    logits_spec = _group_spec(gaxes, None)
    ids_spec = ops.spec_tokens_in()

    def local_step(params, pool, tables, pos, lens, ids):
        if gaxes:
            tables = tables - col_mod.axis_linear_index(gaxes) * bpg
        logits, new_pool = model.prefill_chunk_paged(params, pool, tables,
                                                     ids, pos, lens, ops)
        return logits, new_pool

    tables_sds = jax.ShapeDtypeStruct((n_slots, max_blocks), jnp.int32)
    pos_sds = jax.ShapeDtypeStruct((n_slots,), jnp.int32)
    lens_sds = jax.ShapeDtypeStruct((n_slots,), jnp.int32)
    ids_sds = jax.ShapeDtypeStruct((n_slots, chunk), jnp.int32)

    in_specs = (specs, pool_specs, table_spec, pos_spec, pos_spec, ids_spec)
    out_specs = (logits_spec, pool_specs)
    in_sh = (_shardings(mesh, specs), _shardings(mesh, pool_specs),
             NamedSharding(mesh, table_spec), NamedSharding(mesh, pos_spec),
             NamedSharding(mesh, pos_spec), NamedSharding(mesh, ids_spec))
    out_sh = (NamedSharding(mesh, logits_spec), _shardings(mesh, pool_specs))
    smapped = shard_map(local_step, mesh=mesh, in_specs=in_specs,
                        out_specs=out_specs)
    fn = jax.jit(smapped, donate_argnums=(1,), in_shardings=in_sh,
                 out_shardings=out_sh)
    abs_params = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    return StepBundle(fn=fn,
                      abstract_inputs=(abs_params, pool_sds, tables_sds,
                                       pos_sds, lens_sds, ids_sds),
                      in_shardings=in_sh, out_shardings=out_sh,
                      mesh=mesh, plan=plan)


def build_spec_verify_step(model, mesh, n_slots: int, width: int,
                           num_blocks: int, block_size: int,
                           max_blocks: int):
    """Batched multi-token speculative VERIFY over the paged pool.

    fn(params, pool, tables, pos, lens, ids) -> (logits, pool)

    - ids: [n_slots, width] int32 — per slot [last_token, draft_1..draft_k]
      (0-padded; width = spec_k + 1).
    - pos: [n_slots] int32 — first write position (== num_cached).
    - lens: [n_slots] int32 — 1 + proposals this round (0 = idle slot).
    - logits: [n_slots, width, v_pad] — row c is the target distribution
      for the token at position pos+c+1, bit-matching what a plain decode
      step at that position would produce (the spec_decode mdcheck pins
      this).

    The trunk is prefill_chunk_paged's (update-then-attend), so accepted
    tokens' K/V are ALREADY committed in-place when the host reads the
    logits; rollback is just not advancing cur_pos past the rejection
    point (position masking + later overwrites make the stale suffix
    unobservable — the eviction-replay argument).  Sharding is identical
    to the chunk-prefill step; only the logits keep the chunk axis.
    """
    from ..core.ops import kv_group_axes
    from ..core import collectives as col_mod

    ctx = model.ctx
    plan = make_plan(ctx, ShapeSpec("paged", 1, n_slots, "decode"))
    ops = make_ops(ctx, plan)
    specs = model.specs(ops)
    pool_sds, pool_specs = model.paged_cache_abstract(num_blocks, block_size,
                                                      plan)
    gaxes = kv_group_axes(ctx, plan)
    sizes = dict(data=ctx.data, depth=ctx.depth, row=ctx.rows, col=ctx.cols)
    n_groups = 1
    for a in gaxes:
        n_groups *= sizes[a]
    bpg = num_blocks // n_groups

    table_spec = _group_spec(gaxes, None)
    pos_spec = _group_spec(gaxes)
    logits_spec = _group_spec(gaxes, None, None)
    ids_spec = ops.spec_tokens_in()

    def local_step(params, pool, tables, pos, lens, ids):
        if gaxes:
            tables = tables - col_mod.axis_linear_index(gaxes) * bpg
        logits, new_pool = model.verify_chunk_paged(params, pool, tables,
                                                    ids, pos, lens, ops)
        return logits, new_pool

    tables_sds = jax.ShapeDtypeStruct((n_slots, max_blocks), jnp.int32)
    pos_sds = jax.ShapeDtypeStruct((n_slots,), jnp.int32)
    lens_sds = jax.ShapeDtypeStruct((n_slots,), jnp.int32)
    ids_sds = jax.ShapeDtypeStruct((n_slots, width), jnp.int32)

    in_specs = (specs, pool_specs, table_spec, pos_spec, pos_spec, ids_spec)
    out_specs = (logits_spec, pool_specs)
    in_sh = (_shardings(mesh, specs), _shardings(mesh, pool_specs),
             NamedSharding(mesh, table_spec), NamedSharding(mesh, pos_spec),
             NamedSharding(mesh, pos_spec), NamedSharding(mesh, ids_spec))
    out_sh = (NamedSharding(mesh, logits_spec), _shardings(mesh, pool_specs))
    smapped = shard_map(local_step, mesh=mesh, in_specs=in_specs,
                        out_specs=out_specs)
    fn = jax.jit(smapped, donate_argnums=(1,), in_shardings=in_sh,
                 out_shardings=out_sh)
    abs_params = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    return StepBundle(fn=fn,
                      abstract_inputs=(abs_params, pool_sds, tables_sds,
                                       pos_sds, lens_sds, ids_sds),
                      in_shardings=in_sh, out_shardings=out_sh,
                      mesh=mesh, plan=plan)


def build_page_copy(model, mesh, num_blocks: int, block_size: int,
                    decode_plan):
    """Device-side COW page copy: pool pages ``src`` -> pages ``dst``.

    Returns copy(pool, src, dst) -> pool with
    ``pool[leaf][:, dst] = pool[leaf][:, src]`` (every layer at once).
    src/dst are [n] GLOBAL block ids replicated to every device; a src/dst
    pair lives inside ONE KV group, whose shard performs the real copy —
    on every other group the pair falls outside the local block range and
    degenerates to a scratch->scratch no-op.  The prefix cache uses this
    to clone a shared donor page into a request's private block before the
    divergent suffix overwrites it.
    """
    from jax.sharding import PartitionSpec as P

    from ..core.ops import kv_group_axes
    from ..core import collectives as col_mod

    ctx = model.ctx
    _, pool_specs = model.paged_cache_abstract(num_blocks, block_size,
                                               decode_plan)
    gaxes = kv_group_axes(ctx, decode_plan)
    sizes = dict(data=ctx.data, depth=ctx.depth, row=ctx.rows, col=ctx.cols)
    n_groups = 1
    for a in gaxes:
        n_groups *= sizes[a]
    bpg = num_blocks // n_groups
    ids_spec = P()

    def local_copy(pool, src, dst):
        if gaxes:
            off = col_mod.axis_linear_index(gaxes) * bpg
            src = src - off
            dst = dst - off
            mine = (dst >= 0) & (dst < bpg) & (src >= 0) & (src < bpg)
            src = jnp.where(mine, src, 0)
            dst = jnp.where(mine, dst, 0)
        return jax.tree.map(lambda a: a.at[:, dst].set(a[:, src]), pool)

    in_sh = (_shardings(mesh, pool_specs), NamedSharding(mesh, ids_spec),
             NamedSharding(mesh, ids_spec))
    smapped = shard_map(local_copy, mesh=mesh,
                       in_specs=(pool_specs, ids_spec, ids_spec),
                       out_specs=pool_specs)
    return jax.jit(smapped, donate_argnums=(0,), in_shardings=in_sh,
                   out_shardings=_shardings(mesh, pool_specs))


def build_paged_reshard(model, mesh, n_pre: int, bucket: int,
                        num_blocks: int, block_size: int, decode_plan):
    """Prefill->paged-pool cache reshard (replaces the prompt-replay hack).

    Returns reshard(pool, prefill_cache, tables) -> pool: scatters the
    prefill-layout cache [L, B, S_bucket, Hkv, D] into the paged pool
    through per-request scatter tables [B, S_bucket/bs] of GLOBAL block ids
    (rows/tail blocks without a real target point at a scratch block).  A
    plain jitted global scatter: XLA inserts the cross-layout collectives,
    exactly one compile per prefill bucket.
    """
    ctx = model.ctx
    pplan = make_plan(ctx, ShapeSpec("pre", bucket, n_pre, "prefill"))
    pops = make_ops(ctx, pplan)
    pcache_specs = model.prefill_cache_specs(pops)
    pool_sds, pool_specs = model.paged_cache_abstract(num_blocks, block_size,
                                                      decode_plan)
    nb = bucket // block_size
    L = model.cfg.num_layers

    def f(pool, pcache, tables):
        idx = tables.reshape(-1)                        # [B*nb]
        out = dict(pool)
        for leaf in ("k", "v"):
            src = pcache[leaf].reshape((L, n_pre * nb, block_size)
                                       + pool[leaf].shape[3:])
            out[leaf] = pool[leaf].at[:, idx].set(
                src.astype(pool[leaf].dtype))
        return out

    in_sh = (_shardings(mesh, pool_specs), _shardings(mesh, pcache_specs),
             NamedSharding(mesh, P(None, None)))
    out_sh = _shardings(mesh, pool_specs)
    return jax.jit(f, donate_argnums=(0,), in_shardings=in_sh,
                   out_shardings=out_sh)


def build_dense_cache_reshard(model, mesh, prefill_shape: ShapeSpec,
                              total_len: int):
    """Prefill->dense-decode cache reshard for the static decode loop.

    Returns reshard(prefill_cache) -> decode cache [L, B, total_len, ...]:
    the prompt K/V land in positions [0, S_prompt) of a zeroed decode-layout
    cache; decode then continues from pos = S_prompt instead of replaying
    the prompt token by token (examples/serve_decode.py).
    """
    ctx = model.ctx
    pplan = make_plan(ctx, prefill_shape)
    pops = make_ops(ctx, pplan)
    pcache_specs = model.prefill_cache_specs(pops)
    B = prefill_shape.global_batch
    dplan = make_plan(ctx, ShapeSpec("d", total_len, B, "decode"))
    cache_sds, cache_specs = model.cache_abstract(B, total_len, dplan)
    S_p = prefill_shape.seq_len

    def f(pcache):
        out = {}
        for leaf in ("k", "v"):
            z = jnp.zeros(cache_sds[leaf].shape, cache_sds[leaf].dtype)
            out[leaf] = z.at[:, :, :S_p].set(
                pcache[leaf].astype(z.dtype))
        return out

    in_sh = (_shardings(mesh, pcache_specs),)
    out_sh = _shardings(mesh, cache_specs)
    return jax.jit(f, in_shardings=in_sh, out_shardings=out_sh), dplan


def unshard_ids(ops, ctx, ids, plan):
    """[B_loc] canonical-sharded -> [B', 1] host token layout.

    Uses a zero-padded psum over row rather than all_gather so the result is
    vma-invariant over row (all_gather conservatively keeps axes varying)."""
    if plan.kind in ("long_decode", "decode_dp") or ctx.mode == "megatron1d":
        return ids[:, None]
    b_loc = ids.shape[0]
    buf = jnp.zeros((b_loc * ctx.rows,), ids.dtype)
    i = lax.axis_index(ctx.axis_row)
    buf = lax.dynamic_update_slice_in_dim(buf, ids, i * b_loc, 0)
    buf = lax.psum(buf, ctx.axis_row)
    return buf[:, None]
