"""Step builders: wire a model + ParallelContext + shape into jit-able
train / prefill / decode step functions (shard_map inside jit).

Gradient synchronization design (see DESIGN.md §2 and core/summa.py):

- Replication axes of every param leaf except ``data`` are handled by
  ``pvary`` at the loss boundary — its transpose inserts one fused psum per
  (stacked) leaf per step.
- The ``data`` (DP) axis is synced explicitly after grad computation so it
  can be compressed (bf16 wire format) — a distributed-optimization lever.
- ``ctx.reduce_dgrad_in_op=True`` switches the Tesseract matmul weights to
  the paper's literal per-op all-reduce schedule (baseline measurements).
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import NamedSharding, PartitionSpec as P

from ..configs.base import RunConfig, ShapeSpec
from ..core.api import LOGICAL_AXES, ParallelContext
from ..core.collectives import pvary, grad_sync, axis_size, shard_map
from ..core.ops import Plan, make_ops
from ..optim import adamw


# ---------------------------------------------------------------------------
# spec utilities
# ---------------------------------------------------------------------------

def spec_axes(spec: P) -> tuple:
    out = []
    for entry in spec:
        if entry is None:
            continue
        if isinstance(entry, (tuple, list)):
            out.extend(entry)
        else:
            out.append(entry)
    return tuple(out)


def replicated_axes(spec: P) -> tuple:
    used = set(spec_axes(spec))
    return tuple(a for a in LOGICAL_AXES if a not in used)


def rep_factor(ctx: ParallelContext, spec: P) -> int:
    sizes = dict(data=ctx.data, depth=ctx.depth, row=ctx.rows, col=ctx.cols)
    f = 1
    for a in replicated_axes(spec):
        f *= sizes[a]
    return f


def mark_by_name(tree, names: set, default=False):
    """Bool tree: True where any dict key on the leaf's path is in ``names``."""
    def f(path, _leaf):
        for p in path:
            key = getattr(p, "key", None)
            if key in names:
                return True
        return default
    return jax.tree_util.tree_map_with_path(f, tree)


def make_plan(ctx: ParallelContext, shape: ShapeSpec) -> Plan:
    return Plan.for_shape(shape.kind, global_batch=shape.global_batch,
                          batch_shards=ctx.batch_shards, data=ctx.data)


# ---------------------------------------------------------------------------
# bundles
# ---------------------------------------------------------------------------

@dataclass
class StepBundle:
    fn: Callable                 # jitted
    abstract_inputs: tuple       # trees of ShapeDtypeStruct (global shapes)
    in_shardings: tuple
    out_shardings: Any
    mesh: Any
    plan: Plan
    pipe_info: Any = None        # 1F1B schedule stats (pipelined steps only)


def _shardings(mesh, spec_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))


def batch_abstract(ops, shape: ShapeSpec, ctx: ParallelContext, model=None):
    """Global ShapeDtypeStructs + specs for the host-layout token batch."""
    B, S = shape.global_batch, shape.seq_len
    sds = jax.ShapeDtypeStruct
    if shape.kind == "train":
        t = sds((B, S), jnp.int32)
        shapes = {"tokens": t, "labels": t}
        specs = {"tokens": ops.spec_tokens_in(), "labels": ops.spec_tokens_in()}
    elif shape.kind == "prefill":
        t = sds((B, S), jnp.int32)
        shapes, specs = {"tokens": t}, {"tokens": ops.spec_tokens_in()}
    else:
        raise ValueError(shape.kind)
    if model is not None:
        for name, (sd, sp) in model.batch_extras(shape).items():
            shapes[name] = sd
            specs[name] = sp
    return shapes, specs


# ---------------------------------------------------------------------------
# train step
# ---------------------------------------------------------------------------

def build_train_step(model, mesh, shape: ShapeSpec, *, accum_steps: int = 1):
    """Build the jitted train step.

    accum_steps > 1 accumulates gradients over that many microbatches split
    from the (step-keyed) global batch before the single optimizer update —
    the knob ``runtime/elastic.Replan.accum_steps`` feeds so an elastic
    shrink keeps the global batch (and per-device activation memory)
    constant.  On a mesh with a ``pipe`` axis of size > 1 the pipelined
    1F1B builder is used instead (accum_steps folds into its microbatch
    count).
    """
    if accum_steps < 1:
        raise ValueError(f"accum_steps must be >= 1, got {accum_steps}")
    if "pipe" in mesh.axis_names:
        # any mesh carrying a pipe axis trains through the 1F1B schedule —
        # a pipe=1 mesh is the exact 1-stage baseline of the same code path
        return _build_pipeline_train_step(model, mesh, shape, accum_steps)
    ctx: ParallelContext = model.ctx
    run: RunConfig = model.run
    plan = make_plan(ctx, shape)
    ops = make_ops(ctx, plan)

    specs = model.specs(ops)
    tess_names = getattr(model, "tess_weight_names", lambda: set())()
    inop = ctx.reduce_dgrad_in_op and ctx.mode in ("tesseract", "summa2d")
    is_tess = (mark_by_name(specs, tess_names) if inop
               else jax.tree.map(lambda _: False, specs))

    rep_tree = jax.tree.map(lambda s: rep_factor(ctx, s), specs)

    def pvary_axes(s, t):
        if t:  # in-op tesseract weight: custom bwd reduces (data, depth)
            return ()
        return replicated_axes(s)

    opt_master = run.param_dtype != "float32"

    # ---- ZeRO-1: optimizer state sharded over (data, depth) ----
    # Each leaf's LOCAL (row,col)-shard is flattened, zero-padded to a
    # multiple of data*depth and sliced (free: grads are replicated over
    # those axes after the sync); the update runs on the slice and fresh
    # params are re-assembled with one all-gather per leaf — the classic
    # ZeRO-1 trade of a weight gather for 1/(data*depth) m/v/master memory.
    import numpy as _np
    from ..core import collectives as col_mod
    zero_axes = (ctx.axis_data, ctx.axis_depth)
    zero_n = ctx.data * ctx.depth

    def _shard_elems(spec, shp):
        return int(_np.prod(NamedSharding(mesh, spec).shard_shape(tuple(shp))))

    def zslice(x):
        k = -(-x.size // zero_n)
        flat = jnp.pad(x.reshape(-1), (0, k * zero_n - x.size))
        i = col_mod.axis_linear_index(zero_axes)
        return lax.dynamic_slice_in_dim(flat, i * k, k, axis=0)

    def zunslice(slice_, shp):
        flat = col_mod.all_gather_inv(slice_, zero_axes, tiled=True, axis=0)
        n = 1
        for d in shp:
            n *= d
        return flat[:n].reshape(shp)

    def local_step(params, opt_state, batch):
        def loss_fn(p, mb):
            # grad_sync: fwd pvary / bwd fused (optionally bf16-compressed)
            # psum over each leaf's replication axes — the deferred form of
            # the paper's depth all-reduce, plus the DP reduction.
            pv = jax.tree.map(
                lambda x, s, t: grad_sync(x, pvary_axes(s, t),
                                          run.grad_compression),
                p, specs, is_tess)
            return model.loss(pv, mb, ops)

        if accum_steps == 1:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        else:
            # microbatch gradient accumulation: split every batch leaf's
            # local batch dim into accum_steps slices and scan, so only one
            # microbatch's activations are ever live.  Equal-sized
            # microbatches -> mean-of-means == full-batch mean CE.
            mbs = jax.tree.map(
                lambda x: x.reshape((accum_steps, x.shape[0] // accum_steps)
                                    + x.shape[1:]), batch)

            def micro(carry, mb):
                c_loss, c_grads = carry
                l, g = jax.value_and_grad(loss_fn)(params, mb)
                return (c_loss + l, jax.tree.map(jnp.add, c_grads, g)), None

            init = (jnp.float32(0),
                    jax.tree.map(lambda p: p * 0, params))
            (loss, grads), _ = lax.scan(micro, init, mbs)
            loss = loss / accum_steps
            grads = jax.tree.map(lambda g: g / accum_steps, grads)

        if not col_mod.HAS_VMA:
            # Pre-vma jax seeds ALL p replicated copies of the loss scalar
            # (psum transposes to psum), so value_and_grad returns exactly
            # p x the true gradient for every leaf; vma jax seeds the one
            # invariant scalar and needs no correction.
            p_rep = ctx.data * ctx.depth * ctx.rows * ctx.cols
            if p_rep > 1:
                grads = jax.tree.map(lambda g: g / p_rep, grads)

        # --- global grad-norm clip (layout aware) ---
        def leaf_sq(g, rep, s):
            val = jnp.sum(g.astype(jnp.float32) ** 2) / rep
            return pvary(val, replicated_axes(s))
        sq = sum(jax.tree.leaves(jax.tree.map(leaf_sq, grads, rep_tree, specs)))
        gnorm = jnp.sqrt(lax.psum(sq, LOGICAL_AXES))
        scale = jnp.minimum(1.0, run.grad_clip / (gnorm + 1e-6))
        grads = jax.tree.map(lambda g: g * scale, grads)

        lr = adamw.cosine_lr(opt_state["step"], base_lr=run.lr,
                             warmup=100, total=10000)
        if run.zero1:
            g_sl = jax.tree.map(zslice, grads)
            p_sl = jax.tree.map(zslice, params)
            sq = lambda t: jax.tree.map(lambda x: x[0], t)  # [1,k] -> [k]
            st = {"step": opt_state["step"], "m": sq(opt_state["m"]),
                  "v": sq(opt_state["v"])}
            if "master" in opt_state:
                # lazy master init: step 0 adopts the param slice
                is0 = (opt_state["step"] == 0)
                st["master"] = jax.tree.map(
                    lambda m, pp: jnp.where(is0, pp.astype(jnp.float32), m),
                    sq(opt_state["master"]), p_sl)
            new_psl, new_state = adamw.adamw_update(
                p_sl, g_sl, st, lr=lr, weight_decay=run.weight_decay)
            un = lambda t: jax.tree.map(lambda x: x[None], t)  # [k] -> [1,k]
            new_state = {"step": new_state["step"], "m": un(new_state["m"]),
                         "v": un(new_state["v"]),
                         **({"master": un(new_state["master"])}
                            if "master" in new_state else {})}
            new_params = jax.tree.map(
                lambda sl, p0: zunslice(sl, p0.shape).astype(p0.dtype),
                new_psl, params)
        else:
            new_params, new_state = adamw.adamw_update(
                params, grads, opt_state, lr=lr, weight_decay=run.weight_decay)
        metrics = {"loss": loss, "grad_norm": gnorm, "lr": lr}
        return new_params, new_state, metrics

    if run.zero1:
        # opt leaves: [n_slices, k] with dim0 mapped over (data, depth) PLUS
        # the leaf's own sharded axes (row-replicated leaves must stay
        # row-replicated in their opt slices or the reconstructed param's
        # vma would spuriously vary over row).
        def zspec_of(sp):
            extra = tuple(a for a in spec_axes(sp)
                          if a not in (ctx.axis_data, ctx.axis_depth))
            return P((ctx.axis_data, ctx.axis_depth) + extra, None)
        zspec_tree = jax.tree.map(zspec_of, specs)
        opt_specs = {"m": zspec_tree, "v": zspec_tree, "step": P(),
                     **({"master": zspec_tree} if opt_master else {})}
    else:
        opt_specs = {
            "m": specs, "v": specs, "step": P(),
            **({"master": specs} if opt_master else {}),
        }
    batch_sds, batch_specs_ = batch_abstract(ops, shape, ctx, model)
    if accum_steps > 1:
        # tokens/labels are additionally split over row by embed's
        # reduce-scatter, so each microbatch must keep that divisible too
        row_factor = ctx.rows if ctx.mode != "megatron1d" else 1
        for name, sd in batch_sds.items():
            loc0 = NamedSharding(mesh, batch_specs_[name]).shard_shape(
                tuple(sd.shape))[0]
            rf = row_factor if name in ("tokens", "labels", "mask") else 1
            if loc0 % accum_steps or (loc0 // accum_steps) % rf:
                raise ValueError(
                    f"accum_steps={accum_steps} does not evenly split batch "
                    f"leaf {name!r}: local batch {loc0} (global "
                    f"{sd.shape[0]}) must divide into accum_steps "
                    f"microbatches of a multiple of the row factor {rf}; "
                    f"pick accum_steps dividing global_batch/"
                    f"(data*depth*row) or re-plan")
    metric_specs = {"loss": P(), "grad_norm": P(), "lr": P()}

    smapped = shard_map(
        local_step, mesh=mesh,
        in_specs=(specs, opt_specs, batch_specs_),
        out_specs=(specs, opt_specs, metric_specs))
    in_sh = (_shardings(mesh, specs), _shardings(mesh, opt_specs),
             _shardings(mesh, batch_specs_))
    out_sh = (_shardings(mesh, specs), _shardings(mesh, opt_specs),
              _shardings(mesh, metric_specs))
    fn = jax.jit(smapped, donate_argnums=(0, 1), in_shardings=in_sh,
                 out_shardings=out_sh)

    abs_params = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    if run.zero1:
        sizes = dict(data=ctx.data, depth=ctx.depth, row=ctx.rows,
                     col=ctx.cols)
        def zleaf(ab, sp):
            k = -(-_shard_elems(sp, ab.shape) // zero_n)
            n_slices = zero_n
            for a in spec_axes(sp):
                if a not in (ctx.axis_data, ctx.axis_depth):
                    n_slices *= sizes[a]
            return jax.ShapeDtypeStruct((n_slices, k), jnp.float32)
        zt = jax.tree.map(zleaf, abs_params, specs)
        abs_opt = {"m": zt, "v": zt,
                   "step": jax.ShapeDtypeStruct((), jnp.int32),
                   **({"master": zt} if opt_master else {})}
    else:
        abs_opt = jax.eval_shape(partial(adamw.adamw_init, master=opt_master),
                                 abs_params)
    return StepBundle(
        fn=fn,
        abstract_inputs=(abs_params, abs_opt, batch_sds),
        in_shardings=in_sh, out_shardings=out_sh, mesh=mesh, plan=plan)


# ---------------------------------------------------------------------------
# pipelined train step (1F1B over a [pipe x data x depth x row x col] mesh)
# ---------------------------------------------------------------------------

def _build_pipeline_train_step(model, mesh, shape: ShapeSpec,
                               accum_steps: int = 1):
    """Train step with pipeline parallelism OUTSIDE the Tesseract TP group
    (paper §3.4): stage-sharded block params/opt state over the mesh's
    ``pipe`` axis, 1F1B microbatch schedule (runtime/pipeline.py), loss and
    grad reduction on the last stage, deferred replication-axis grad psums
    extended with the pipe axis for the stage-replicated leaves (embed /
    head / final norm).  ``accum_steps`` folds into the microbatch count —
    in PP, gradient accumulation IS more microbatches through the same
    flush, which also shrinks the bubble.
    """
    from ..core import collectives as col_mod
    from .pipeline import pipeline_1f1b_grads

    ctx: ParallelContext = model.ctx
    run: RunConfig = model.run
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    S_pipe = int(sizes["pipe"])
    if shape.kind != "train":
        raise ValueError(f"pipeline step only supports train shapes, "
                         f"got {shape.kind!r}")
    if not getattr(model, "supports_pipeline", False):
        raise NotImplementedError(
            f"{type(model).__name__} does not support the pipeline stage "
            f"API (supports_pipeline=False)")
    if model.batch_extras(shape):
        raise NotImplementedError("pipelined training with modality extras "
                                  "is not supported")
    if run.zero1:
        raise NotImplementedError("zero1 + pipeline is not wired yet; the "
                                  "stage shard already divides opt memory")
    if ctx.mode not in ("tesseract", "summa2d"):
        raise NotImplementedError(f"pipeline requires a tesseract/summa2d "
                                  f"TP group, got {ctx.mode!r}")
    L = model.cfg.num_layers
    if L % S_pipe:
        raise ValueError(f"num_layers={L} not divisible by pipe={S_pipe}")
    M = (run.pipeline_microbatches or 2 * S_pipe) * accum_steps
    B, S_seq = shape.global_batch, shape.seq_len
    tok_shards = ctx.data * ctx.depth   # host-layout batch-dim sharding
    if B % (tok_shards * M):
        raise ValueError(
            f"global_batch={B} not divisible by data*depth*microbatches="
            f"{tok_shards}*{M}")
    mb_host = B // (tok_shards * M)
    if mb_host % ctx.rows:
        raise ValueError(f"microbatch rows {mb_host} not divisible by the "
                         f"row factor {ctx.rows} (embed reduce-scatter)")
    if model.cfg.d_model % max(ctx.cols, 1):
        raise ValueError(f"d_model={model.cfg.d_model} not divisible by "
                         f"cols={ctx.cols}")

    plan = make_plan(ctx, shape)
    ops = make_ops(ctx, plan)
    specs = model.specs(ops)
    tess_names = getattr(model, "tess_weight_names", lambda: set())()
    inop = ctx.reduce_dgrad_in_op and ctx.mode in ("tesseract", "summa2d")
    is_tess = (mark_by_name(specs, tess_names) if inop
               else jax.tree.map(lambda _: False, specs))
    pipe_sharded = mark_by_name(specs, {"blocks"})

    def _pipe_spec(sp):
        entries = tuple(sp)
        if not entries or entries[0] is not None:
            raise ValueError(f"block spec {sp} is not stacked (dim0 must be "
                             f"the layer dim)")
        return P(*(("pipe",) + entries[1:]))

    pspecs = dict(specs)
    pspecs["blocks"] = jax.tree.map(_pipe_spec, specs["blocks"],
                                    is_leaf=lambda x: isinstance(x, P))
    rep_tree = jax.tree.map(
        lambda s, psh: rep_factor(ctx, s) * (1 if psh else S_pipe),
        specs, pipe_sharded)
    # deferred grad reductions: replication axes of each leaf, plus pipe for
    # the stage-replicated leaves; in-op tesseract weights already reduced
    # (data, depth) inside the matmul bwd and are stage-sharded -> ().
    def _red_axes(s, t, psh):
        ax = () if t else replicated_axes(s)
        return ax if psh else ax + ("pipe",)
    red_axes = jax.tree.map(_red_axes, specs, is_tess, pipe_sharded)

    mb_can = mb_host // ctx.rows
    h_loc = model.cfg.d_model // ctx.cols
    cdt = model.cdt
    opt_master = run.param_dtype != "float32"
    from .pipeline import schedule_1f1b
    sched = schedule_1f1b(M, S_pipe)   # simulated once, shared with the step

    def local_step(params, opt_state, batch):
        tokens, labels = batch["tokens"], batch["labels"]
        tok_mb = tokens.reshape((M, tokens.shape[0] // M) + tokens.shape[1:])
        lab_mb = labels.reshape((M, labels.shape[0] // M) + labels.shape[1:])
        # CE count is label-count (no mask on this path): static, so the
        # backward seed 1/total is available before the first fwd finishes.
        seed = jnp.float32(1.0) / jnp.float32(B * S_seq)

        def stage_step(p, a, m_idx):
            tok = lax.dynamic_index_in_dim(tok_mb, m_idx, 0, keepdims=False)
            lab = lax.dynamic_index_in_dim(lab_mb, m_idx, 0, keepdims=False)
            x0 = model.pipe_embed(p, tok, ops)
            sid = lax.axis_index("pipe")
            x_in = jnp.where(sid == 0, x0, a)
            y = model.pipe_blocks(p, x_in, ops)
            ls, cnt = model.pipe_loss_sums(p, y, lab, ops)
            return y, ls, cnt

        a_proto = jnp.zeros((mb_can, S_seq, h_loc), cdt)
        loss_sum, cnt_sum, grads, _ = pipeline_1f1b_grads(
            stage_step, params, a_proto, M, axis="pipe", loss_seed=seed,
            schedule=sched)
        loss_sum = lax.psum(loss_sum, (ctx.axis_data, "pipe"))
        cnt = lax.psum(cnt_sum, (ctx.axis_data, "pipe"))
        loss = loss_sum / jnp.maximum(cnt, 1.0)

        if not col_mod.HAS_VMA:
            # Pre-vma jax: every model-group member seeds its own replicated
            # copy of the last stage's loss sums (psum transposes to psum),
            # so grads arrive scaled by the model-group size.  The data axis
            # is NOT included here: its reduction happens outside the vjp.
            corr = ctx.depth * ctx.rows * ctx.cols
            if corr > 1:
                grads = jax.tree.map(lambda g: g / corr, grads)

        def red(g, ax):
            if not ax:
                return g
            if run.grad_compression == "bf16":
                return lax.psum(g.astype(jnp.bfloat16),
                                tuple(ax)).astype(g.dtype)
            return lax.psum(g, tuple(ax))
        grads = jax.tree.map(red, grads, red_axes)

        # --- global grad-norm clip (layout + stage aware) ---
        def leaf_sq(g, rep, s, psh):
            val = jnp.sum(g.astype(jnp.float32) ** 2) / rep
            return pvary(val, replicated_axes(s) + (() if psh
                                                    else ("pipe",)))
        sq = sum(jax.tree.leaves(jax.tree.map(
            leaf_sq, grads, rep_tree, specs, pipe_sharded)))
        gnorm = jnp.sqrt(lax.psum(sq, LOGICAL_AXES + ("pipe",)))
        scale = jnp.minimum(1.0, run.grad_clip / (gnorm + 1e-6))
        grads = jax.tree.map(lambda g: g * scale, grads)

        lr = adamw.cosine_lr(opt_state["step"], base_lr=run.lr,
                             warmup=100, total=10000)
        new_params, new_state = adamw.adamw_update(
            params, grads, opt_state, lr=lr, weight_decay=run.weight_decay)
        metrics = {"loss": loss, "grad_norm": gnorm, "lr": lr}
        return new_params, new_state, metrics

    opt_specs = {
        "m": pspecs, "v": pspecs, "step": P(),
        **({"master": pspecs} if opt_master else {}),
    }
    batch_sds, batch_specs_ = batch_abstract(ops, shape, ctx, model)
    metric_specs = {"loss": P(), "grad_norm": P(), "lr": P()}

    smapped = shard_map(
        local_step, mesh=mesh,
        in_specs=(pspecs, opt_specs, batch_specs_),
        out_specs=(pspecs, opt_specs, metric_specs))
    in_sh = (_shardings(mesh, pspecs), _shardings(mesh, opt_specs),
             _shardings(mesh, batch_specs_))
    out_sh = (_shardings(mesh, pspecs), _shardings(mesh, opt_specs),
              _shardings(mesh, metric_specs))
    fn = jax.jit(smapped, donate_argnums=(0, 1), in_shardings=in_sh,
                 out_shardings=out_sh)
    abs_params = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    abs_opt = jax.eval_shape(partial(adamw.adamw_init, master=opt_master),
                             abs_params)
    return StepBundle(
        fn=fn,
        abstract_inputs=(abs_params, abs_opt, batch_sds),
        in_shardings=in_sh, out_shardings=out_sh, mesh=mesh, plan=plan,
        pipe_info=sched[3])


# ---------------------------------------------------------------------------
# serve steps
# ---------------------------------------------------------------------------

def build_prefill_step(model, mesh, shape: ShapeSpec, *,
                       with_lengths: bool = False):
    """Prefill step.  With ``with_lengths=True`` the batch gains a
    ``lengths`` [B] input (true prompt lengths of right-padded prompts) and
    the first output is full-vocab LOGITS at each request's own last
    position instead of greedy ids — the serve engine's bucketed prefill."""
    ctx = model.ctx
    plan = make_plan(ctx, shape)
    ops = make_ops(ctx, plan)
    specs = model.specs(ops)

    def local_step(params, batch):
        ids, cache = model.prefill(params, batch, ops)
        if ids.ndim == 1:
            ids = ids[:, None]
        return ids, cache

    # prefill-layout cache: [L, B/data(loc), S, kvh_loc, D]
    cache_specs = model.prefill_cache_specs(ops)
    ids_spec = P("data", None) if plan.kind != "long_decode" else P(None, None)
    batch_sds, batch_specs_ = batch_abstract(ops, shape, ctx, model)
    if with_lengths:
        batch_sds["lengths"] = jax.ShapeDtypeStruct((shape.global_batch,),
                                                    jnp.int32)
        batch_specs_["lengths"] = P("data")

    in_sh = (_shardings(mesh, specs), _shardings(mesh, batch_specs_))
    out_sh = (NamedSharding(mesh, ids_spec), _shardings(mesh, cache_specs))
    smapped = shard_map(local_step, mesh=mesh,
                            in_specs=(specs, batch_specs_),
                            out_specs=(ids_spec, cache_specs))
    fn = jax.jit(smapped, in_shardings=in_sh, out_shardings=out_sh)
    abs_params = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    return StepBundle(fn=fn, abstract_inputs=(abs_params, batch_sds),
                      in_shardings=in_sh, out_shardings=out_sh,
                      mesh=mesh, plan=plan)


def build_decode_step(model, mesh, shape: ShapeSpec):
    ctx = model.ctx
    plan = make_plan(ctx, shape)
    ops = make_ops(ctx, plan)
    specs = model.specs(ops)
    cache_sds, cache_specs = model.cache_abstract(shape.global_batch,
                                                  shape.seq_len, plan)

    def local_step(params, cache, ids, pos):
        nids, new_cache = model.decode(params, cache, ids, pos, ops)
        nids = unshard_ids(ops, ctx, nids, plan)
        return nids, new_cache

    ids_sds = jax.ShapeDtypeStruct((shape.global_batch, 1), jnp.int32)
    ids_spec = ops.spec_tokens_in()
    pos_sds = jax.ShapeDtypeStruct((), jnp.int32)

    in_sh = (_shardings(mesh, specs), _shardings(mesh, cache_specs),
             NamedSharding(mesh, ids_spec), NamedSharding(mesh, P()))
    out_sh = (NamedSharding(mesh, ids_spec), _shardings(mesh, cache_specs))
    smapped = shard_map(local_step, mesh=mesh,
                            in_specs=(specs, cache_specs, ids_spec, P()),
                            out_specs=(ids_spec, cache_specs))
    fn = jax.jit(smapped, donate_argnums=(1,), in_shardings=in_sh,
                 out_shardings=out_sh)
    abs_params = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    return StepBundle(fn=fn,
                      abstract_inputs=(abs_params, cache_sds, ids_sds, pos_sds),
                      in_shardings=in_sh, out_shardings=out_sh,
                      mesh=mesh, plan=plan)


# ---------------------------------------------------------------------------
# paged serving steps (serve/ continuous batching; DESIGN.md §7)
# ---------------------------------------------------------------------------

def _group_spec(gaxes, *extra):
    return P(gaxes if gaxes else None, *extra)


def build_paged_decode_step(model, mesh, n_slots: int, num_blocks: int,
                            block_size: int, max_blocks: int):
    """Decode step against a mesh-sharded paged KV pool.

    fn(params, pool, tables, pos, ids) -> (logits, pool)

    - pool: {"k","v": [L, P, bs, Hkv, D]} (donated), block axis sharded over
      the plan's KV group axes, heads over col.
    - tables: [n_slots, max_blocks] int32 GLOBAL block ids (each slot's
      entries point into its own group's partition; the local step subtracts
      the group offset).
    - pos: [n_slots] int32 per-request positions (mixed lengths).
    - ids: [n_slots, 1] int32 host-layout input tokens.
    - logits: [n_slots, v_pad] float32 full-vocab rows for the sampler.
    """
    from ..core.ops import kv_group_axes
    from ..core import collectives as col_mod

    ctx = model.ctx
    plan = make_plan(ctx, ShapeSpec("paged", 1, n_slots, "decode"))
    ops = make_ops(ctx, plan)
    specs = model.specs(ops)
    pool_sds, pool_specs = model.paged_cache_abstract(num_blocks, block_size,
                                                      plan)
    gaxes = kv_group_axes(ctx, plan)
    sizes = dict(data=ctx.data, depth=ctx.depth, row=ctx.rows, col=ctx.cols)
    n_groups = 1
    for a in gaxes:
        n_groups *= sizes[a]
    bpg = num_blocks // n_groups

    table_spec = _group_spec(gaxes, None)
    pos_spec = _group_spec(gaxes)
    logits_spec = _group_spec(gaxes, None)
    ids_spec = ops.spec_tokens_in()

    def local_step(params, pool, tables, pos, ids):
        if gaxes:
            tables = tables - col_mod.axis_linear_index(gaxes) * bpg
        logits, new_pool = model.decode_paged(params, pool, tables, ids,
                                              pos, ops)
        return logits, new_pool

    tables_sds = jax.ShapeDtypeStruct((n_slots, max_blocks), jnp.int32)
    pos_sds = jax.ShapeDtypeStruct((n_slots,), jnp.int32)
    ids_sds = jax.ShapeDtypeStruct((n_slots, 1), jnp.int32)

    in_specs = (specs, pool_specs, table_spec, pos_spec, ids_spec)
    out_specs = (logits_spec, pool_specs)
    in_sh = (_shardings(mesh, specs), _shardings(mesh, pool_specs),
             NamedSharding(mesh, table_spec), NamedSharding(mesh, pos_spec),
             NamedSharding(mesh, ids_spec))
    out_sh = (NamedSharding(mesh, logits_spec), _shardings(mesh, pool_specs))
    smapped = shard_map(local_step, mesh=mesh, in_specs=in_specs,
                        out_specs=out_specs)
    fn = jax.jit(smapped, donate_argnums=(1,), in_shardings=in_sh,
                 out_shardings=out_sh)
    abs_params = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    return StepBundle(fn=fn,
                      abstract_inputs=(abs_params, pool_sds, tables_sds,
                                       pos_sds, ids_sds),
                      in_shardings=in_sh, out_shardings=out_sh,
                      mesh=mesh, plan=plan)


def build_paged_reshard(model, mesh, n_pre: int, bucket: int,
                        num_blocks: int, block_size: int, decode_plan):
    """Prefill->paged-pool cache reshard (replaces the prompt-replay hack).

    Returns reshard(pool, prefill_cache, tables) -> pool: scatters the
    prefill-layout cache [L, B, S_bucket, Hkv, D] into the paged pool
    through per-request scatter tables [B, S_bucket/bs] of GLOBAL block ids
    (rows/tail blocks without a real target point at a scratch block).  A
    plain jitted global scatter: XLA inserts the cross-layout collectives,
    exactly one compile per prefill bucket.
    """
    ctx = model.ctx
    pplan = make_plan(ctx, ShapeSpec("pre", bucket, n_pre, "prefill"))
    pops = make_ops(ctx, pplan)
    pcache_specs = model.prefill_cache_specs(pops)
    pool_sds, pool_specs = model.paged_cache_abstract(num_blocks, block_size,
                                                      decode_plan)
    nb = bucket // block_size
    L = model.cfg.num_layers

    def f(pool, pcache, tables):
        idx = tables.reshape(-1)                        # [B*nb]
        out = dict(pool)
        for leaf in ("k", "v"):
            src = pcache[leaf].reshape((L, n_pre * nb, block_size)
                                       + pool[leaf].shape[3:])
            out[leaf] = pool[leaf].at[:, idx].set(
                src.astype(pool[leaf].dtype))
        return out

    in_sh = (_shardings(mesh, pool_specs), _shardings(mesh, pcache_specs),
             NamedSharding(mesh, P(None, None)))
    out_sh = _shardings(mesh, pool_specs)
    return jax.jit(f, donate_argnums=(0,), in_shardings=in_sh,
                   out_shardings=out_sh)


def build_dense_cache_reshard(model, mesh, prefill_shape: ShapeSpec,
                              total_len: int):
    """Prefill->dense-decode cache reshard for the static decode loop.

    Returns reshard(prefill_cache) -> decode cache [L, B, total_len, ...]:
    the prompt K/V land in positions [0, S_prompt) of a zeroed decode-layout
    cache; decode then continues from pos = S_prompt instead of replaying
    the prompt token by token (examples/serve_decode.py).
    """
    ctx = model.ctx
    pplan = make_plan(ctx, prefill_shape)
    pops = make_ops(ctx, pplan)
    pcache_specs = model.prefill_cache_specs(pops)
    B = prefill_shape.global_batch
    dplan = make_plan(ctx, ShapeSpec("d", total_len, B, "decode"))
    cache_sds, cache_specs = model.cache_abstract(B, total_len, dplan)
    S_p = prefill_shape.seq_len

    def f(pcache):
        out = {}
        for leaf in ("k", "v"):
            z = jnp.zeros(cache_sds[leaf].shape, cache_sds[leaf].dtype)
            out[leaf] = z.at[:, :, :S_p].set(
                pcache[leaf].astype(z.dtype))
        return out

    in_sh = (_shardings(mesh, pcache_specs),)
    out_sh = _shardings(mesh, cache_specs)
    return jax.jit(f, in_shardings=in_sh, out_shardings=out_sh), dplan


def unshard_ids(ops, ctx, ids, plan):
    """[B_loc] canonical-sharded -> [B', 1] host token layout.

    Uses a zero-padded psum over row rather than all_gather so the result is
    vma-invariant over row (all_gather conservatively keeps axes varying)."""
    if plan.kind in ("long_decode", "decode_dp") or ctx.mode == "megatron1d":
        return ids[:, None]
    b_loc = ids.shape[0]
    buf = jnp.zeros((b_loc * ctx.rows,), ids.dtype)
    i = lax.axis_index(ctx.axis_row)
    buf = lax.dynamic_update_slice_in_dim(buf, ids, i * b_loc, 0)
    buf = lax.psum(buf, ctx.axis_row)
    return buf[:, None]
