"""Seeded, deterministic fault injection (DESIGN.md §11).

A ``FaultPlan`` is an immutable schedule of ``FaultSpec`` entries, each
pinned to a *site* (a registered hook point in the train loop, the serve
engine, or the checkpoint layer) and a *step*.  Whether a fault fires at
``(site, step)`` is a pure function of the plan — for random plans, a pure
function of ``(seed, site, kind, step)`` via a stable crc32-keyed digest
(never ``hash()``: str hashing is salted per process) — so the exact same
fault sequence replays from the same seed, across restarts and across
processes.  That replayability is what lets tests assert recovery
invariants (bit-exact survivor parity, trajectory rejoin, bounded retries)
instead of merely "it didn't crash".

Sites and the kinds each accepts:

    train.step   device_loss(n) | straggler(seconds)
    train.grads  nan | inf            (NaN/Inf scaled into the step's grads
                                       through the step bundle's fault port)
    ckpt.write   corrupt(leaf_index; mode=bit_flip|truncate|manifest)
    serve.step   device_loss(n) | straggler(seconds) | drop_step
                 | pool_exhaust(n_steps)
    serve.logits nan(slot) | inf(slot)

An ``injector`` (``FaultInjector``) wraps a plan with once-per-occurrence
semantics: each spec fires on its first ``attempts`` executions of its
(site, step) and is then spent, so a restart that replays the step recovers
instead of re-dying forever.  A *fresh* injector (a rerun from the same
seed) reproduces the identical fired log — the determinism contract the
chaos tests assert.
"""
from __future__ import annotations

import re
import zlib
from dataclasses import dataclass, field, replace

import numpy as np

# site -> kinds accepted there
SITES = {
    "train.step": ("device_loss", "straggler"),
    "train.grads": ("nan", "inf"),
    "ckpt.write": ("corrupt",),
    "serve.step": ("device_loss", "straggler", "drop_step", "pool_exhaust"),
    "serve.logits": ("nan", "inf"),
    "serve.prefix": ("evict", "flush"),
}


@dataclass(frozen=True)
class FaultSpec:
    """One scheduled fault occurrence.

    ``arg`` is the kind-specific number (surviving device count for
    device_loss, seconds for straggler, slot for serve.logits, held steps
    for pool_exhaust, leaf index for corrupt); ``mode`` the kind-specific
    string (corruption flavor).  ``attempts`` is how many executions of
    (site, step) the fault fires on before it is spent — attempts=1 is a
    transient fault a retry/replay survives, a large value models a
    persistent one."""
    site: str
    step: int
    kind: str
    arg: float = 0.0
    mode: str = ""
    attempts: int = 1

    def __post_init__(self):
        if self.site not in SITES:
            raise ValueError(f"unknown fault site {self.site!r}; "
                             f"registered: {sorted(SITES)}")
        if self.kind not in SITES[self.site]:
            raise ValueError(f"kind {self.kind!r} not valid at {self.site!r} "
                             f"(accepts {SITES[self.site]})")
        if self.step < 0 or self.attempts < 1:
            raise ValueError(f"step >= 0 and attempts >= 1 required, got "
                             f"step={self.step} attempts={self.attempts}")

    def compact(self) -> str:
        s = f"{self.site}@{self.step}:{self.kind}"
        extra = []
        if self.arg:
            extra.append(f"{self.arg:g}")
        if self.mode:
            extra.append(self.mode)
        if extra:
            s += "(" + ",".join(extra) + ")"
        if self.attempts != 1:
            s += f"x{self.attempts}"
        return s


def _parse_spec(text: str) -> FaultSpec:
    """``site@step:kind[(arg[,mode])][xattempts]`` — e.g.
    ``train.grads@5:nan``, ``ckpt.write@4:corrupt(0,bit_flip)``,
    ``serve.logits@3:nan(1)x2``."""
    t = text.strip()
    attempts = 1
    # only a trailing x<digits> is an attempts suffix — an "x" inside a
    # site or kind name (serve.prefix, flush) is plain spelling
    m = re.search(r"x(\d+)$", t)
    if m:
        attempts = int(m.group(1))
        t = t[:m.start()]
    loc, _, rest = t.partition(":")
    site, _, step = loc.partition("@")
    kind, arg, mode = rest, 0.0, ""
    if "(" in rest:
        kind, _, args = rest.partition("(")
        args = args.rstrip(")")
        parts = [p.strip() for p in args.split(",") if p.strip()]
        for p in parts:
            try:
                arg = float(p)
            except ValueError:
                mode = p
    return FaultSpec(site=site.strip(), step=int(step), kind=kind.strip(),
                     arg=arg, mode=mode, attempts=attempts)


def _unit(seed: int, site: str, kind: str, step: int) -> float:
    """Uniform [0,1) digest, pure in (seed, site, kind, step)."""
    key = (seed, zlib.crc32(site.encode()), zlib.crc32(kind.encode()), step)
    return float(np.random.default_rng(key).random())


@dataclass(frozen=True)
class FaultPlan:
    """Immutable, hashable fault schedule (safe to hang off frozen configs)."""
    specs: tuple = ()
    seed: int = 0

    @classmethod
    def parse(cls, text: str, seed: int = 0) -> "FaultPlan":
        """Parse the compact ``;``-separated DSL (RunConfig.fault_plan)."""
        specs = tuple(_parse_spec(p) for p in text.split(";") if p.strip())
        return cls(specs=specs, seed=seed)

    @classmethod
    def random(cls, seed: int, horizon: int, rates: dict) -> "FaultPlan":
        """Bernoulli schedule: ``rates`` maps ``"site/kind"`` -> per-step
        probability.  Whether (site, kind) fires at step s depends only on
        (seed, site, kind, s) — adding sites or extending the horizon never
        reshuffles earlier draws."""
        specs = []
        for key, p in sorted(rates.items()):
            site, _, kind = key.partition("/")
            if site not in SITES or kind not in SITES[site]:
                raise ValueError(f"unknown rate key {key!r}")
            for step in range(horizon):
                if _unit(seed, site, kind, step) < p:
                    specs.append(FaultSpec(site=site, step=step, kind=kind))
        return cls(specs=tuple(specs), seed=seed)

    def at(self, site: str, step: int):
        return tuple(s for s in self.specs
                     if s.site == site and s.step == step)

    def sites(self):
        return sorted({s.site for s in self.specs})

    def compact(self) -> str:
        return ";".join(s.compact() for s in self.specs)


class FaultInjector:
    """Stateful executor of a FaultPlan: fires each spec on its first
    ``attempts`` executions of (site, step), logs every firing.  Two fresh
    injectors over the same plan produce identical logs for identical
    execution sequences — the (seed, step) determinism contract."""

    def __init__(self, plan: FaultPlan):
        self.plan = plan
        self._remaining = {id(s): s.attempts for s in plan.specs}
        self.fired: list = []        # (site, step, kind) in firing order

    def fire(self, site: str, step: int):
        """Specs due at (site, step) on this execution; spends one attempt
        per returned spec."""
        out = []
        for s in self.plan.at(site, step):
            if self._remaining[id(s)] > 0:
                self._remaining[id(s)] -= 1
                self.fired.append((s.site, s.step, s.kind))
                out.append(s)
        return out

    @property
    def exhausted(self) -> bool:
        return all(v == 0 for v in self._remaining.values())


def injector_from_run(run, sites=None):
    """Build an injector from RunConfig.fault_plan / fault_seed (the config
    surface the launchers thread through); None when no plan is set.
    ``sites`` filters to the subsystem's own hook points so one plan string
    can drive a trainer and an engine without cross-firing."""
    if not getattr(run, "fault_plan", ""):
        return None
    plan = FaultPlan.parse(run.fault_plan, seed=run.fault_seed)
    if sites is not None:
        plan = replace(plan, specs=tuple(
            s for s in plan.specs
            if s.site.split(".")[0] in sites or s.site in sites))
    return FaultInjector(plan) if plan.specs else None


# ---------------------------------------------------------------------------
# checkpoint corruption (the ckpt.write fault body)
# ---------------------------------------------------------------------------

def corrupt_checkpoint(ckpt_dir, step: int, *, mode: str = "bit_flip",
                       leaf_index: int = 0, seed: int = 0) -> str:
    """Deterministically damage the DURABLE checkpoint for ``step``.

    bit_flip  — flip one bit of one leaf file (byte position keyed by seed)
    truncate  — cut a leaf file to half its length
    manifest  — truncate manifest.json mid-JSON

    Returns the damaged file's path.  The checksummed manifest
    (checkpoint/ckpt.py) must detect all three on restore."""
    import json
    import pathlib
    d = pathlib.Path(ckpt_dir) / f"step_{step:08d}"
    if mode == "manifest":
        mf = d / "manifest.json"
        mf.write_text(mf.read_text()[: max(1, mf.stat().st_size // 2)])
        return str(mf)
    manifest = json.loads((d / "manifest.json").read_text())
    leaves = sorted(manifest["leaves"])
    path = d / manifest["leaves"][leaves[leaf_index % len(leaves)]]["file"]
    raw = bytearray(path.read_bytes())
    if mode == "truncate":
        path.write_bytes(bytes(raw[: len(raw) // 2]))
    elif mode == "bit_flip":
        # flip a bit inside the payload (past the .npy header, which the
        # loader might tolerate or re-derive)
        pos = 128 + int(_unit(seed, "ckpt", "bit_flip", step)
                        * max(1, len(raw) - 129))
        raw[pos] ^= 0x20
        path.write_bytes(bytes(raw))
    else:
        raise ValueError(f"unknown corruption mode {mode!r}")
    return str(path)


class DeviceLostError(RuntimeError):
    """A (simulated) device/host loss: recovery needs an elastic re-plan,
    not a same-mesh restart, so the train loop re-raises it past the
    restart budget for the driver to handle (runtime/elastic.replan)."""

    def __init__(self, n_surviving: int, msg: str = ""):
        self.n_surviving = int(n_surviving)
        super().__init__(msg or f"device loss: {n_surviving} devices survive")
