"""Pipeline parallelism (paper §3.4: Tesseract composes with PP outermost).

Two schedules over a dedicated ``pipe`` mesh axis:

* ``pipeline_apply`` — the GPipe scan kept as the differentiable *reference*
  oracle: a single lax.scan of M + S - 1 ticks whose reverse-mode transpose
  is the backward pipeline (all forwards, then all backwards).  Simple, but
  it holds all M microbatches' activations live through the flush.

* ``pipeline_1f1b_grads`` — the production 1F1B (PipeDream-flush) schedule
  used by ``runtime/steps.build_train_step`` on a [pipe x data x depth x row
  x col] mesh.  The schedule is simulated host-side (``schedule_1f1b``) into
  per-tick (stage -> microbatch) tables; the device program is one lax.scan
  over 2(M+S-1) ticks in which every stage runs one forward unit and one
  backward unit per tick (masked when its table entry is idle).  Backward
  units rematerialize their stage forward from the saved *input* activation
  (the same trade as run.remat="full"), so in-flight storage is bounded by
  the 1F1B window (<= S microbatch inputs per stage) instead of GPipe's M.
  Activations move stage-to-stage with collective_permute; cotangents ride
  the reverse permute.

The measured bubble fraction of the simulated schedule is asserted against
the analytic ``bubble_fraction(M, S)`` at build time (within 10%).
"""
from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp
from jax import lax


def pipeline_apply(stage_fn, stage_params, x_mb, *, axis: str = "pipe"):
    """Run ``stage_fn(params, x)`` as an S-stage GPipe pipeline over M
    microbatches (reference schedule; reverse-mode AD trains it).

    stage_params : this stage's params (stage-sharded over ``axis``)
    x_mb         : [M, mb, ...] microbatch inputs (used on stage 0; other
                   stages ignore their copy)
    Returns [M, mb, ...] outputs, valid on the LAST stage (replicated there
    via the caller's reduction; other stages hold garbage).
    """
    from ..core.collectives import axis_size1
    S = axis_size1(axis)
    sid = lax.axis_index(axis)
    M = x_mb.shape[0]
    T = M + S - 1
    fwd_perm = [(i, i + 1) for i in range(S - 1)]

    def tick(carry, t):
        buf, outputs = carry
        mb_i = jnp.clip(t, 0, M - 1)
        inject = lax.dynamic_index_in_dim(x_mb, mb_i, 0, keepdims=False)
        inp = jnp.where(sid == 0, inject, buf)
        y = stage_fn(stage_params, inp)
        out_i = jnp.clip(t - (S - 1), 0, M - 1)
        take = (sid == S - 1) & (t >= S - 1)
        outputs = lax.dynamic_update_index_in_dim(
            outputs,
            jnp.where(take, y, lax.dynamic_index_in_dim(outputs, out_i, 0,
                                                        keepdims=False)),
            out_i, 0)
        buf_next = lax.ppermute(y, axis, fwd_perm)
        return (buf_next, outputs), None

    buf0 = jnp.zeros(x_mb.shape[1:], x_mb.dtype)
    outs0 = jnp.zeros_like(x_mb)
    # seed vma so the carry matches the loop body: the pipeline buffer varies
    # over the pipe axis (stage params differ per stage, ppermute shifts)
    from ..models.common import vma_like
    seed = jax.tree.leaves(stage_params)[0]
    buf0 = vma_like(buf0, x_mb, seed)
    outs0 = vma_like(outs0, x_mb, seed)
    (_, outputs), _ = lax.scan(tick, (buf0, outs0), jnp.arange(T))
    return outputs


def bubble_fraction(n_micro: int, n_stages: int) -> float:
    """Pipeline bubble overhead (S-1)/(M+S-1).

    Identical for GPipe and 1F1B when a backward unit costs the same as a
    forward unit (the schedules differ in peak activation memory, not in
    flush length); 1F1B's measured tick tables reproduce it exactly."""
    return (n_stages - 1) / (n_micro + n_stages - 1)


# ---------------------------------------------------------------------------
# 1F1B (PipeDream-flush) schedule
# ---------------------------------------------------------------------------

def schedule_1f1b(n_micro: int, n_stages: int):
    """Simulate the 1F1B schedule into per-tick dispatch tables.

    Per stage s the action list is the classic PipeDream-flush order:
    W = min(S-1-s, M) warmup forwards, then (M - W) steady [fwd, bwd]
    pairs, then W cooldown backwards.  Each tick every stage attempts the
    head of its list and idles unless its dependency completed at a
    *strictly earlier* tick (activations/cotangents arrive at end-of-tick).

    Returns (fwd_tbl, bwd_tbl, n_slots, info):
      fwd_tbl/bwd_tbl : [T, S] int32, microbatch index or -1 (idle)
      n_slots         : in-flight buffer depth K needed by the executor
                        (the 1F1B memory bound, <= S+1; GPipe would need M)
      info            : dict with n_ticks / measured_bubble / predicted_bubble
    """
    M, S = n_micro, n_stages
    if M < 1 or S < 1:
        raise ValueError(f"need n_micro >= 1 and n_stages >= 1, got {M}, {S}")
    actions = []
    for s in range(S):
        W = min(S - 1 - s, M)
        acts = [("F", m) for m in range(W)]
        for m in range(W, M):
            acts.append(("F", m))
            acts.append(("B", m - W))
        for m in range(M - W, M):
            acts.append(("B", m))
        actions.append(acts)

    ptr = [0] * S
    t_fwd: dict = {}
    t_bwd: dict = {}
    fwd_rows, bwd_rows = [], []
    t = 0
    while any(ptr[s] < len(actions[s]) for s in range(S)):
        frow, brow = [-1] * S, [-1] * S
        for s in range(S):
            if ptr[s] >= len(actions[s]):
                continue
            kind, m = actions[s][ptr[s]]
            if kind == "F":
                ready = s == 0 or t_fwd.get((s - 1, m), t) < t
            else:
                if s == S - 1:
                    ready = t_fwd.get((s, m), t) < t
                else:
                    ready = t_bwd.get((s + 1, m), t) < t
            if ready:
                (frow if kind == "F" else brow)[s] = m
        progressed = False
        for s in range(S):
            if frow[s] >= 0:
                t_fwd[(s, frow[s])] = t
                ptr[s] += 1
                progressed = True
            elif brow[s] >= 0:
                t_bwd[(s, brow[s])] = t
                ptr[s] += 1
                progressed = True
        if not progressed:
            raise AssertionError(f"1F1B schedule deadlock at tick {t} "
                                 f"(M={M}, S={S})")
        fwd_rows.append(frow)
        bwd_rows.append(brow)
        t += 1
        if t > 4 * (M + S) + 8:
            raise AssertionError(f"1F1B schedule did not drain (M={M}, S={S})")

    T = len(fwd_rows)
    # in-flight input-activation window per stage: a microbatch's saved input
    # is live from the upstream forward (receive) until this stage's backward
    n_slots = 1
    for s in range(S):
        src = s - 1 if s > 0 else s
        for tt in range(T):
            live = [m for m in range(M)
                    if t_fwd[(src, m)] <= tt <= t_bwd[(s, m)]]
            if live:
                n_slots = max(n_slots, max(live) - min(live) + 1)

    busy = 2 * M * S
    info = {
        "n_ticks": T,
        "n_micro": M,
        "n_stages": S,
        "n_slots": n_slots,
        "measured_bubble": 1.0 - busy / (T * S),
        "predicted_bubble": bubble_fraction(M, S),
    }
    return (np.asarray(fwd_rows, np.int32), np.asarray(bwd_rows, np.int32),
            n_slots, info)


def expected_ring_transfers(schedule) -> dict:
    """Pipe-axis transfer counts implied by a ``schedule_1f1b`` result.

    The 1F1B executor below issues exactly TWO ppermutes per tick (one
    activation forward, one cotangent backward, unconditionally — masked
    ticks still permute garbage slots), so a traced step must contain
    ``2 * n_ticks`` pipe-axis ppermute occurrences once the executing scan's
    multiplicity is unrolled.  repro.analysis.shardcheck diffs the extracted
    IR against this; a drift means the schedule tables and the device
    program disagree."""
    fwd_tbl, bwd_tbl, _k, info = schedule
    return {
        "n_ticks": int(info["n_ticks"]),
        "ppermutes": 2 * int(info["n_ticks"]),
        "busy_fwd": int((np.asarray(fwd_tbl) >= 0).sum()),
        "busy_bwd": int((np.asarray(bwd_tbl) >= 0).sum()),
    }


def pipeline_1f1b_grads(stage_step, params, a_proto, n_micro: int, *,
                        axis: str = "pipe", loss_seed=1.0, schedule=None):
    """Value-and-grad of an S-stage 1F1B pipeline (manual per-stage vjp).

    stage_step(params, a, m) -> (y, loss_sum_m, cnt_m)
        the uniform per-stage forward: ``a`` is the previous stage's
        activation (stage 0 re-derives its input from microbatch index ``m``
        and ignores ``a``), ``y`` is the activation handed downstream, and
        (loss_sum_m, cnt_m) are this stage's local CE sums for microbatch
        ``m`` (meaningful on the last stage; garbage elsewhere).
    params    : stage-local param tree (pipe-sharded leaves already local)
    a_proto   : zeros template of the activation's local shape/dtype
    n_micro   : number of microbatches M
    loss_seed : dL/d(loss_sum_m) — 1/total_token_count for a mean CE
    schedule  : optional precomputed ``schedule_1f1b(n_micro, S)`` result
                (the builder passes it so the simulation runs once)
    Returns (loss_sum, cnt_sum, grads, info): the sums accumulate the LAST
    stage's microbatch losses (zero elsewhere; caller psums over ``axis`` and
    the data axis), grads are this stage's summed raw contributions
    (unreduced over replication axes — the caller applies the deferred
    psums), info is the schedule stats dict from ``schedule_1f1b``.

    Backward units recompute their stage forward from the saved input
    activation (rematerialization), so per-stage live state is K = S-ish
    microbatch inputs + cotangents, never all M (the 1F1B memory bound).
    """
    from ..core import collectives as col

    S = col.axis_size1(axis)
    M = int(n_micro)
    fwd_tbl, bwd_tbl, K, info = schedule or schedule_1f1b(M, S)
    if info["n_micro"] != M or info["n_stages"] != S:
        raise ValueError(f"schedule was built for (M={info['n_micro']}, "
                         f"S={info['n_stages']}), executing (M={M}, S={S})")
    if info["measured_bubble"] > info["predicted_bubble"] + 0.10:
        raise AssertionError(
            f"1F1B schedule bubble {info['measured_bubble']:.3f} exceeds "
            f"prediction {info['predicted_bubble']:.3f} + 10% "
            f"(M={M}, S={S})")
    sid = lax.axis_index(axis)
    is_last = sid == S - 1
    fwd_perm = [(i, i + 1) for i in range(S - 1)]
    bwd_perm = [(i + 1, i) for i in range(S - 1)]

    if col.HAS_VMA:
        # vma discipline: learn every carried leaf's varying axes from one
        # throwaway forward + zero-cotangent vjp (exact zeros, right vma).
        a0 = col.pvary(a_proto, (axis,) + tuple(
            a for a in ("data", "depth", "row", "col")))
        out0 = stage_step(params, a0, jnp.int32(0))
        seeds0 = jax.tree.map(
            lambda o: col.pvary(jnp.zeros(o.shape, o.dtype),
                                tuple(col.vma_of(o))), out0)
        _, pull0 = jax.vjp(lambda p, a: stage_step(p, a, jnp.int32(0)),
                           params, a0)
        grads0, cot0 = pull0(seeds0)
        a_store = jnp.zeros((K,) + a_proto.shape, a_proto.dtype) \
            + (a0 * 0)[None]
        cot_store = jnp.zeros((K,) + cot0.shape, cot0.dtype) + (cot0 * 0)[None]
        zero_ld = col.pvary(jnp.float32(0), ("data", axis))
        loss_acc, cnt_acc = zero_ld, zero_ld
    else:
        a_store = jnp.zeros((K,) + a_proto.shape, a_proto.dtype)
        cot_store = jnp.zeros((K,) + a_proto.shape, a_proto.dtype)
        grads0 = jax.tree.map(jnp.zeros_like, params)
        loss_acc = jnp.float32(0)
        cnt_acc = jnp.float32(0)

    seed_val = jnp.float32(loss_seed)

    def tick(carry, xs):
        a_store, cot_store, loss_acc, cnt_acc, grads = carry
        mf_row, mb_row = xs

        # ---- forward unit ----
        mf = mf_row[sid]
        act_f = mf >= 0
        mfc = jnp.clip(mf, 0, M - 1)
        a_in = lax.dynamic_index_in_dim(a_store, mfc % K, 0, keepdims=False)
        y, ls, cnt = stage_step(params, a_in, mfc)
        take = act_f & is_last
        loss_acc = loss_acc + jnp.where(take, ls, 0.0)
        cnt_acc = cnt_acc + jnp.where(take, cnt, 0.0)

        # ---- backward unit (remat: re-linearize from the saved input) ----
        mb = mb_row[sid]
        act_b = mb >= 0
        mbc = jnp.clip(mb, 0, M - 1)
        a_sav = lax.dynamic_index_in_dim(a_store, mbc % K, 0, keepdims=False)
        dy = lax.dynamic_index_in_dim(cot_store, mbc % K, 0, keepdims=False)
        dy = jnp.where(is_last, jnp.zeros_like(dy), dy)
        dls = jnp.where(act_b & is_last, seed_val, 0.0)
        dls = col.pvary(dls, tuple(col.vma_of(ls)))
        dcnt = col.pvary(jnp.zeros_like(cnt), tuple(col.vma_of(cnt)))
        _, pull = jax.vjp(lambda p, a: stage_step(p, a, mbc), params, a_sav)
        dp, da = pull((dy, dls, dcnt))
        grads = jax.tree.map(
            lambda g, d: g + jnp.where(act_b, d, jnp.zeros_like(d)),
            grads, dp)

        # ---- communicate (end of tick) ----
        if S > 1:
            y_recv = lax.ppermute(y, axis, fwd_perm)
            da_recv = lax.ppermute(da, axis, bwd_perm)
            # what did my neighbours dispatch this tick?
            m_left = mf_row[jnp.clip(sid - 1, 0, S - 1)]
            wr_a = (sid > 0) & (m_left >= 0)
            slot_a = jnp.clip(m_left, 0, M - 1) % K
            old_a = lax.dynamic_index_in_dim(a_store, slot_a, 0,
                                             keepdims=False)
            a_store = lax.dynamic_update_index_in_dim(
                a_store, jnp.where(wr_a, y_recv.astype(a_store.dtype), old_a),
                slot_a, 0)
            m_right = mb_row[jnp.clip(sid + 1, 0, S - 1)]
            wr_c = (sid < S - 1) & (m_right >= 0)
            slot_c = jnp.clip(m_right, 0, M - 1) % K
            old_c = lax.dynamic_index_in_dim(cot_store, slot_c, 0,
                                             keepdims=False)
            cot_store = lax.dynamic_update_index_in_dim(
                cot_store,
                jnp.where(wr_c, da_recv.astype(cot_store.dtype), old_c),
                slot_c, 0)
        return (a_store, cot_store, loss_acc, cnt_acc, grads), None

    xs = (jnp.asarray(fwd_tbl), jnp.asarray(bwd_tbl))
    (a_store, cot_store, loss_acc, cnt_acc, grads), _ = lax.scan(
        tick, (a_store, cot_store, loss_acc, cnt_acc, grads0), xs)
    return loss_acc, cnt_acc, grads, info
