"""Pipeline parallelism (paper §3.4: Tesseract composes with PP outermost).

GPipe-style microbatch pipeline expressed *inside* shard_map on a dedicated
``pipe`` mesh axis: each stage holds its own params (stage-sharded in_specs),
activations move stage-to-stage with collective_permute, and the schedule is
a single lax.scan of M + S - 1 ticks.  Reverse-mode AD through the scan +
ppermute yields the backward pipeline automatically (ppermute transposes to
the reverse shift), so the same wrapper trains.

The 40-cell dry-run grid runs without PP (the production mesh dedicates all
16 model chips to Tesseract); examples/pipeline_tesseract.py and
tests/test_pipeline.py exercise a [pipe x data x depth x row x col] mesh.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax


def pipeline_apply(stage_fn, stage_params, x_mb, *, axis: str = "pipe"):
    """Run ``stage_fn(params, x)`` as an S-stage pipeline over M microbatches.

    stage_params : this stage's params (stage-sharded over ``axis``)
    x_mb         : [M, mb, ...] microbatch inputs (used on stage 0; other
                   stages ignore their copy)
    Returns [M, mb, ...] outputs, valid on the LAST stage (replicated there
    via the caller's reduction; other stages hold garbage).
    """
    from ..core.collectives import axis_size1
    S = axis_size1(axis)
    sid = lax.axis_index(axis)
    M = x_mb.shape[0]
    T = M + S - 1
    fwd_perm = [(i, i + 1) for i in range(S - 1)]

    def tick(carry, t):
        buf, outputs = carry
        mb_i = jnp.clip(t, 0, M - 1)
        inject = lax.dynamic_index_in_dim(x_mb, mb_i, 0, keepdims=False)
        inp = jnp.where(sid == 0, inject, buf)
        y = stage_fn(stage_params, inp)
        out_i = jnp.clip(t - (S - 1), 0, M - 1)
        take = (sid == S - 1) & (t >= S - 1)
        outputs = lax.dynamic_update_index_in_dim(
            outputs,
            jnp.where(take, y, lax.dynamic_index_in_dim(outputs, out_i, 0,
                                                        keepdims=False)),
            out_i, 0)
        buf_next = lax.ppermute(y, axis, fwd_perm)
        return (buf_next, outputs), None

    buf0 = jnp.zeros(x_mb.shape[1:], x_mb.dtype)
    outs0 = jnp.zeros_like(x_mb)
    # seed vma so the carry matches the loop body: the pipeline buffer varies
    # over the pipe axis (stage params differ per stage, ppermute shifts)
    from ..models.common import vma_like
    seed = jax.tree.leaves(stage_params)[0]
    buf0 = vma_like(buf0, x_mb, seed)
    outs0 = vma_like(outs0, x_mb, seed)
    (_, outputs), _ = lax.scan(tick, (buf0, outs0), jnp.arange(T))
    return outputs


def bubble_fraction(n_micro: int, n_stages: int) -> float:
    """GPipe bubble overhead: (S-1)/(M+S-1)."""
    return (n_stages - 1) / (n_micro + n_stages - 1)
