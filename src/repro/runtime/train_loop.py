"""Fault-tolerant training loop.

Responsibilities at fleet scale, all exercised by tests on this container:
  * checkpoint/restart: periodic async checkpoints; on failure, rebuild the
    step and restore the latest checkpoint (reshard-on-restore supports a
    different mesh after an elastic re-plan)
  * deterministic data: the stream is keyed by step, so a restart replays
    exactly the batches after the restored step
  * straggler monitoring hooks (per-step timing -> StragglerMonitor)
  * retry budget so a poisoned batch / flaky host cannot loop forever
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field

import jax
import numpy as np

from ..checkpoint.ckpt import CheckpointManager
from ..data.pipeline import Prefetcher, SyntheticLMStream
from ..optim.adamw import adamw_init
from .steps import build_train_step
from .stragglers import StragglerMonitor


@dataclass
class TrainResult:
    losses: list = field(default_factory=list)
    restarts: int = 0
    last_step: int = -1
    step_times: list = field(default_factory=list)


def train(model, mesh, shape, *, steps: int, ckpt_dir=None, ckpt_every: int = 50,
          log_every: int = 10, max_restarts: int = 3, fault_hook=None,
          seed: int = 0, stream=None, monitor=None,
          accum_steps: int | None = None) -> TrainResult:
    """Run ``steps`` optimizer steps with checkpoint/restart fault tolerance.

    fault_hook(step) may raise to simulate a failure (tests use this).
    accum_steps (default ``model.run.accum_steps``) accumulates gradients
    over that many microbatches per optimizer step — the knob an elastic
    re-plan (``runtime/elastic.replan(...).accum_steps``) supplies so a
    device shrink keeps the global batch and the loss trajectory intact
    under the step-keyed data stream.
    """
    if accum_steps is None:
        accum_steps = model.run.accum_steps
    bundle = build_train_step(model, mesh, shape, accum_steps=accum_steps)
    mgr = CheckpointManager(ckpt_dir) if ckpt_dir else None
    # ZeRO-1: record the optimizer-state layout in every checkpoint and
    # re-shard on restore (dp-degree changes after an elastic replan, or a
    # replicated <-> ZeRO layout switch).
    from ..optim.zero import make_ckpt_converter
    opt_layout_meta = bundle.opt_layouts_json()
    save_meta = {"opt_layout": opt_layout_meta} if opt_layout_meta else None
    opt_convert = make_ckpt_converter(opt_layout_meta)
    monitor = monitor or StragglerMonitor()
    result = TrainResult()

    batch_sh = bundle.in_shardings[2]
    if stream is None:
        extras = {k: (sd, sp) for k, (sd, sp) in model.batch_extras(shape).items()}
        stream = SyntheticLMStream(model.cfg.vocab_size, shape.global_batch,
                                   shape.seq_len, seed=seed, extras=extras)

    def init_state():
        import jax.numpy as jnp
        params = model.init(jax.random.PRNGKey(seed))
        params = jax.device_put(params, bundle.in_shardings[0])
        if model.run.zero_enabled:
            from ..optim.zero import zero_opt_init
            opt = zero_opt_init(bundle)
        else:
            opt = adamw_init(params, master=model.run.master_weights)
        opt = jax.device_put(opt, bundle.in_shardings[1])
        return params, opt

    def restore_or_init():
        if mgr is not None:
            try:
                mgr.wait()   # flush an in-flight async save before reading
            except RuntimeError as e:
                print(f"[ckpt] pending async save failed: {e}")
            last = mgr.latest_step()
            if last is not None:
                abs_p, abs_o, _ = bundle.abstract_inputs
                state = mgr.restore(last, {"params": abs_p, "opt": abs_o},
                                    {"params": bundle.in_shardings[0],
                                     "opt": bundle.in_shardings[1]},
                                    convert=opt_convert)
                return state["params"], state["opt"], last + 1
        p, o = init_state()
        return p, o, 0

    params, opt, start = restore_or_init()
    step = start
    budget_used = 0        # restarts within the current replay window
    window_start = start   # where the last restore landed us
    while step < steps:
        try:
            pf = Prefetcher(stream, batch_sh, start_step=step)
            try:
                while step < steps:
                    got_step, batch = pf.next()
                    assert got_step == step
                    if fault_hook is not None:
                        fault_hook(step)
                    t0 = time.time()
                    params, opt, metrics = bundle.fn(params, opt, batch)
                    loss = float(metrics["loss"])  # sync point
                    dt = time.time() - t0
                    monitor.record(jax.process_index(), dt)
                    result.step_times.append(dt)
                    if not np.isfinite(loss):
                        raise FloatingPointError(f"non-finite loss at {step}")
                    result.losses.append(loss)
                    result.last_step = step
                    if log_every and step % log_every == 0:
                        print(f"step {step} loss {loss:.4f} "
                              f"gnorm {float(metrics['grad_norm']):.3f} "
                              f"({dt*1e3:.0f} ms)")
                    step += 1
                    if mgr is not None and step % ckpt_every == 0:
                        mgr.save(step - 1, {"params": params, "opt": opt},
                                 meta=save_meta)
            finally:
                pf.stop()
        except (FloatingPointError, RuntimeError, ValueError) as e:
            result.restarts += 1
            if mgr is not None:
                # A checkpoint that LANDED since the last restore starts a
                # fresh replay window, so N spread-out recovered faults over
                # a long run never add up to a fatal max_restarts.  Judged
                # by the durable latest_step (after flushing the async
                # writer), never by save() calls having been made: a
                # persistently failing checkpoint dir plus a recurring
                # fault must still trip the budget, not loop forever.
                try:
                    mgr.wait()
                except RuntimeError as werr:
                    print(f"[ckpt] pending async save failed: {werr}")
                latest = mgr.latest_step()
                if latest is not None and latest + 1 > window_start:
                    budget_used = 0
                    window_start = latest + 1
            budget_used += 1
            print(f"[fault] step {step}: {type(e).__name__}: {e}; "
                  f"restart {budget_used}/{max_restarts} in this replay "
                  f"window ({result.restarts} total)")
            if budget_used > max_restarts:
                raise
            params, opt, step = restore_or_init()
    if mgr is not None:
        mgr.save(steps - 1, {"params": params, "opt": opt},
                 blocking=True, meta=save_meta)
        mgr.wait()
    return result
