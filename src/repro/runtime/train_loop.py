"""Fault-tolerant training loop.

Responsibilities at fleet scale, all exercised by tests on this container:
  * checkpoint/restart: periodic async checkpoints; on failure, rebuild the
    step and restore the latest checkpoint (reshard-on-restore supports a
    different mesh after an elastic re-plan)
  * crash-consistent recovery: manifests are checksummed (checkpoint/
    ckpt.py), restore falls back across corrupted checkpoints to the last
    durable one instead of dying or silently loading garbage
  * non-finite step recovery: the step bundle's where-select guard keeps
    params/opt bit-identical on a NaN/Inf step; the loop retries the same
    (step-keyed) batch a bounded number of times, then backs the loss scale
    off (halving run.loss_scale, the §9 mixed-precision lever), then falls
    back to restore-and-replay
  * deterministic data: the stream is keyed by step, so a restart replays
    exactly the batches after the restored step
  * deterministic fault injection (runtime/faults.py): hook points
    ``train.step`` (device loss, straggler delay), ``train.grads`` (NaN/Inf
    grads via the step bundle's fault port) and ``ckpt.write`` (checkpoint
    corruption) fire replayably by (seed, step)
  * straggler monitoring hooks (per-step timing -> StragglerMonitor)
  * retry budget so a poisoned batch / flaky host cannot loop forever
"""
from __future__ import annotations

import dataclasses
import time
from dataclasses import dataclass, field

import jax
import numpy as np

from ..checkpoint.ckpt import CheckpointManager
from ..data.pipeline import Prefetcher, SyntheticLMStream
from ..optim.adamw import adamw_init
from . import faults as faults_mod
from .faults import DeviceLostError
from .steps import build_train_step
from .stragglers import StragglerMonitor


@dataclass
class TrainResult:
    losses: list = field(default_factory=list)
    restarts: int = 0
    last_step: int = -1
    step_times: list = field(default_factory=list)
    # resilience accounting (DESIGN.md §11)
    nan_skips: int = 0             # non-finite steps where-selected away
    loss_scale_backoffs: int = 0   # loss-scale halvings after skip storms
    ckpt_fallbacks: int = 0        # corrupt checkpoints skipped on restore
    fault_log: list = field(default_factory=list)  # injector firing order


def train(model, mesh, shape, *, steps: int, ckpt_dir=None, ckpt_every: int = 50,
          log_every: int = 10, max_restarts: int = 3, fault_hook=None,
          seed: int = 0, stream=None, monitor=None,
          accum_steps: int | None = None, injector=None) -> TrainResult:
    """Run ``steps`` optimizer steps with checkpoint/restart fault tolerance.

    fault_hook(step) may raise to simulate a failure (tests use this).
    accum_steps (default ``model.run.accum_steps``) accumulates gradients
    over that many microbatches per optimizer step — the knob an elastic
    re-plan (``runtime/elastic.replan(...).accum_steps``) supplies so a
    device shrink keeps the global batch and the loss trajectory intact
    under the step-keyed data stream.

    injector (``runtime/faults.FaultInjector``) enables deterministic
    chaos: defaults to the plan on ``model.run.fault_plan`` / ``fault_seed``
    (the launcher config surface), restricted to the train/ckpt sites.  A
    ``device_loss`` firing raises DeviceLostError THROUGH the restart
    budget — recovery needs an elastic re-plan by the driver, not a
    same-mesh restart.
    """
    run = model.run
    if accum_steps is None:
        accum_steps = run.accum_steps
    if injector is None:
        injector = faults_mod.injector_from_run(run, sites=("train", "ckpt"))
    fault_port = injector is not None
    loss_scale = run.loss_scale

    def make_bundle(scale):
        m = model
        if scale != run.loss_scale:
            from ..models.registry import build_model
            m = build_model(model.cfg, model.ctx,
                            dataclasses.replace(run, loss_scale=scale))
        return build_train_step(m, mesh, shape, accum_steps=accum_steps,
                                fault_port=fault_port)

    bundle = make_bundle(loss_scale)
    mgr = CheckpointManager(ckpt_dir) if ckpt_dir else None
    # ZeRO-1: record the optimizer-state layout in every checkpoint and
    # re-shard on restore (dp-degree changes after an elastic replan, or a
    # replicated <-> ZeRO layout switch).
    from ..optim.zero import make_ckpt_converter
    opt_layout_meta = bundle.opt_layouts_json()
    save_meta = {"opt_layout": opt_layout_meta} if opt_layout_meta else None
    opt_convert = make_ckpt_converter(opt_layout_meta)
    monitor = monitor or StragglerMonitor()
    result = TrainResult()
    if injector is not None:
        result.fault_log = injector.fired   # live view, shared list

    batch_sh = bundle.in_shardings[2]
    if stream is None:
        extras = {k: (sd, sp) for k, (sd, sp) in model.batch_extras(shape).items()}
        stream = SyntheticLMStream(model.cfg.vocab_size, shape.global_batch,
                                   shape.seq_len, seed=seed, extras=extras)

    def init_state():
        import jax.numpy as jnp
        params = model.init(jax.random.PRNGKey(seed))
        params = jax.device_put(params, bundle.in_shardings[0])
        if model.run.zero_enabled:
            from ..optim.zero import zero_opt_init
            opt = zero_opt_init(bundle)
        else:
            opt = adamw_init(params, master=model.run.master_weights)
        opt = jax.device_put(opt, bundle.in_shardings[1])
        return params, opt

    def restore_or_init():
        if mgr is not None:
            try:
                mgr.wait()   # flush an in-flight async save before reading
            except RuntimeError as e:
                print(f"[ckpt] pending async save failed: {e}")
            abs_p, abs_o, _ = bundle.abstract_inputs
            # newest-first with integrity checks: a corrupted checkpoint
            # (bit flip, truncation, torn manifest) is skipped, not loaded
            state, last = mgr.restore_latest(
                {"params": abs_p, "opt": abs_o},
                {"params": bundle.in_shardings[0],
                 "opt": bundle.in_shardings[1]},
                convert=opt_convert)
            result.ckpt_fallbacks += mgr.last_fallbacks
            if state is not None:
                return state["params"], state["opt"], last + 1
        p, o = init_state()
        return p, o, 0

    def run_step(params, opt, batch, step):
        """One optimizer step with bounded non-finite retry + loss-scale
        backoff.  Returns (params, opt, metrics, loss_scale)."""
        nonlocal bundle, loss_scale
        attempts = 0
        while True:
            fb = batch
            if fault_port:
                g = 1.0
                for spec in injector.fire("train.grads", step):
                    g = np.nan if spec.kind == "nan" else np.inf
                fb = dict(batch, fault_scale=np.float32(g))
            params, opt, metrics = bundle.fn(params, opt, fb)
            if not float(metrics.get("skipped", 0.0)):   # sync point
                return params, opt, metrics
            # non-finite step: params/opt came back bit-identical (the
            # in-step guard) — retry the SAME step-keyed batch
            attempts += 1
            result.nan_skips += 1
            print(f"[fault] step {step}: non-finite grads/loss, update "
                  f"skipped (retry {attempts}/{run.nan_skip_limit}, "
                  f"loss_scale={loss_scale:g})")
            if attempts <= run.nan_skip_limit:
                continue
            if loss_scale > 1.0:
                # mixed-precision overflow: halve the static loss scale
                # (rebuild the step — the scale is folded into the jit)
                loss_scale = max(1.0, loss_scale / 2.0)
                result.loss_scale_backoffs += 1
                print(f"[fault] step {step}: backing loss_scale off to "
                      f"{loss_scale:g} and rebuilding the step")
                bundle = make_bundle(loss_scale)
                attempts = 0
                continue
            raise FloatingPointError(
                f"non-finite grads persist at step {step} after "
                f"{run.nan_skip_limit} retries and loss-scale backoff")

    params, opt, start = restore_or_init()
    step = start
    budget_used = 0        # restarts within the current replay window
    window_start = start   # where the last restore landed us
    while step < steps:
        try:
            pf = Prefetcher(stream, batch_sh, start_step=step)
            try:
                while step < steps:
                    got_step, batch = pf.next()
                    assert got_step == step
                    if fault_hook is not None:
                        fault_hook(step)
                    if injector is not None:
                        for spec in injector.fire("train.step", step):
                            if spec.kind == "device_loss":
                                raise DeviceLostError(
                                    int(spec.arg),
                                    f"injected device loss at step {step}: "
                                    f"{int(spec.arg)} devices survive")
                            elif spec.kind == "straggler":
                                time.sleep(spec.arg)
                    t0 = time.time()
                    params, opt, metrics = run_step(params, opt, batch, step)
                    loss = float(metrics["loss"])  # sync point
                    dt = time.time() - t0
                    monitor.record(jax.process_index(), dt)
                    result.step_times.append(dt)
                    if not np.isfinite(loss):
                        raise FloatingPointError(f"non-finite loss at {step}")
                    result.losses.append(loss)
                    result.last_step = step
                    if log_every and step % log_every == 0:
                        print(f"step {step} loss {loss:.4f} "
                              f"gnorm {float(metrics['grad_norm']):.3f} "
                              f"({dt*1e3:.0f} ms)")
                    step += 1
                    if mgr is not None and step % ckpt_every == 0:
                        mgr.save(step - 1, {"params": params, "opt": opt},
                                 meta=save_meta)
                        if injector is not None:
                            for spec in injector.fire("ckpt.write", step - 1):
                                mgr.wait()   # corrupt the DURABLE artifact
                                p = faults_mod.corrupt_checkpoint(
                                    ckpt_dir, step - 1,
                                    mode=spec.mode or "bit_flip",
                                    leaf_index=int(spec.arg),
                                    seed=injector.plan.seed)
                                print(f"[fault] injected ckpt corruption "
                                      f"({spec.mode or 'bit_flip'}): {p}")
            finally:
                pf.stop()
        except DeviceLostError as e:
            # a lost device cannot be fixed by a same-mesh restart: the
            # driver must elastic-replan (runtime/elastic.replan) onto the
            # survivors and call train() again on the new mesh (passing the
            # same injector so spent faults stay spent)
            result.restarts += 1
            e.partial_result = result
            raise
        except (FloatingPointError, RuntimeError, ValueError) as e:
            result.restarts += 1
            if mgr is not None:
                # A checkpoint that LANDED since the last restore starts a
                # fresh replay window, so N spread-out recovered faults over
                # a long run never add up to a fatal max_restarts.  Judged
                # by the durable latest VALID step (after flushing the
                # async writer), never by save() calls having been made: a
                # persistently failing/corrupting checkpoint dir plus a
                # recurring fault must still trip the budget, not loop
                # forever.
                try:
                    mgr.wait()
                except RuntimeError as werr:
                    print(f"[ckpt] pending async save failed: {werr}")
                latest = mgr.latest_valid_step()
                if latest is not None and latest + 1 > window_start:
                    budget_used = 0
                    window_start = latest + 1
            budget_used += 1
            print(f"[fault] step {step}: {type(e).__name__}: {e}; "
                  f"restart {budget_used}/{max_restarts} in this replay "
                  f"window ({result.restarts} total)")
            if budget_used > max_restarts:
                raise
            params, opt, step = restore_or_init()
    if mgr is not None:
        mgr.save(steps - 1, {"params": params, "opt": opt},
                 blocking=True, meta=save_meta)
        mgr.wait()
    return result
