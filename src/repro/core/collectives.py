"""Shared collective helpers used by the op sets and models.

Everything here runs *inside* ``jax.shard_map`` and operates on per-device
local views, communicating via named mesh axes.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

try:  # varying -> invariant gather (precise vma; values are identical copies)
    from jax._src.lax.parallel import all_gather_invariant as _agi
except ImportError:  # pragma: no cover - older jax
    _agi = None

# Does this jax track varying-manifest axes (vma) on avals?  Pre-vma releases
# (<= 0.4.x) have neither jax.typeof nor lax.pvary; the *_v helpers below fall
# back to full physical reductions there (every call site in this repo reduces
# values that physically vary over the listed axes, so the fallback is exact).
HAS_VMA = hasattr(jax, "typeof") and hasattr(lax, "pvary")
_HAS_VMA = HAS_VMA  # back-compat alias


# ---------------------------------------------------------------------------
# shard_map compat: jax.shard_map (new) -> jax.sharding.shard_map ->
# jax.experimental.shard_map.shard_map (<= 0.4.x)
# ---------------------------------------------------------------------------

def _resolve_shard_map():
    fn = getattr(jax, "shard_map", None)
    if fn is not None:
        return fn, True
    fn = getattr(jax.sharding, "shard_map", None)
    if fn is not None:
        return fn, True
    from jax.experimental.shard_map import shard_map as fn  # noqa: PLC0415
    return fn, False


_SHARD_MAP_IMPL, _SHARD_MAP_NEW = _resolve_shard_map()


def shard_map(f, *, mesh, in_specs, out_specs, **kw):
    """Version-portable ``jax.shard_map``.

    On pre-vma jax the experimental implementation is used with
    ``check_rep=False``: this codebase is written against vma semantics
    (custom_vjp collectives, psum-of-masked-value broadcasts) for which the
    old replication checker has no rules.
    """
    if _SHARD_MAP_NEW:
        return _SHARD_MAP_IMPL(f, mesh=mesh, in_specs=in_specs,
                               out_specs=out_specs, **kw)
    kw.pop("check_vma", None)
    kw.setdefault("check_rep", False)
    return _SHARD_MAP_IMPL(f, mesh=mesh, in_specs=in_specs,
                           out_specs=out_specs, **kw)


def all_gather_inv(x, axes, *, axis=0, tiled=False):
    """all_gather whose output is vma-INVARIANT over the gathered axes
    (every member of the group holds the same gathered value).  Falls back
    to plain all_gather on jax versions without the primitive."""
    if isinstance(axes, str):
        axes = (axes,)
    if _agi is not None:
        vma = vma_of(x)
        ax = tuple(a for a in axes if a in vma)
        if not ax:
            return x
        return _agi(x, ax, axis=axis, tiled=tiled)
    return lax.all_gather(x, tuple(axes), axis=axis, tiled=tiled)


def pvary(x, axes):
    """Mark ``x`` as varying over ``axes`` (compat shim for jax>=0.8).

    Used at the step level on replicated params: pvary's transpose is a psum
    over ``axes``, which is exactly the deferred (fused) gradient reduction —
    one collective per (stacked) param leaf per step.
    """
    if isinstance(axes, str):
        axes = (axes,)
    if not _HAS_VMA:
        return x  # no vma tracking: the annotation is a numerical no-op
    axes = tuple(a for a in axes if a not in vma_of(x))  # idempotent
    if not axes:
        return x
    try:
        return lax.pcast(x, tuple(axes), to="varying")
    except (AttributeError, TypeError):
        return lax.pvary(x, tuple(axes))


def tree_pvary(tree, axes_tree):
    """pvary each leaf over its (possibly empty) axes tuple."""
    return jax.tree.map(lambda x, a: pvary(x, a), tree, axes_tree,
                        is_leaf=lambda t: t is None)


from functools import partial as _partial


@_partial(jax.custom_vjp, nondiff_argnums=(1, 2))
def grad_sync(x, axes: tuple, compress: str = "none"):
    """Replication boundary for params: forward is a vma-only pvary; backward
    is ONE fused psum of the cotangent over ``axes`` (the deferred Tesseract
    depth reduction + DP all-reduce), optionally in a compressed wire format.

    Applied to scan-stacked param leaves this reduces all layers' grads in a
    single collective per leaf — the fused alternative to the paper's
    per-layer all_reduce (see EXPERIMENTS.md §Perf).
    """
    return pvary(x, axes)


def _gs_fwd(x, axes, compress):
    return pvary(x, axes), None


def _gs_bwd(axes, compress, _res, g):
    if not axes:
        return (g,)
    if compress == "bf16":
        return (lax.psum(g.astype(jnp.bfloat16), tuple(axes)).astype(g.dtype),)
    return (lax.psum(g, tuple(axes)),)


grad_sync.defvjp(_gs_fwd, _gs_bwd)


def vma_of(x) -> frozenset:
    try:
        return jax.typeof(x).vma
    except AttributeError:
        return frozenset()


def axis_size1(a) -> int:
    """Static size of one named mesh axis (portable across jax versions)."""
    try:
        return lax.axis_size(a)
    except AttributeError:  # pre-0.5 jax: psum of a literal folds to the size
        return lax.psum(1, a)


def _vary_axes(x, axes) -> tuple:
    """Subset of ``axes`` that x varies on; all of them on pre-vma jax.

    On pre-vma jax physical variance cannot be queried, so the reductions run
    over every listed axis.  That is exact at every call site in this repo:
    the psum_v inputs are genuine partial sums over those axes, and max / min
    / mean of identical replicated copies are the copies themselves."""
    if isinstance(axes, str):
        axes = (axes,)
    if not _HAS_VMA:
        return tuple(axes)
    vma = vma_of(x)
    return tuple(a for a in axes if a in vma)


def psum_v(x, axes):
    """psum over the subset of ``axes`` that x actually varies on.

    Ops stay correct whether params were pvary'd (train: grad_sync boundary)
    or not (serve steps): reducing over an axis the value is replicated on
    would either error (vma) or double-count."""
    ax = _vary_axes(x, axes)
    return lax.psum(x, ax) if ax else x


def pmax_v(x, axes):
    ax = _vary_axes(x, axes)
    return lax.pmax(x, ax) if ax else x


def pmin_v(x, axes):
    ax = _vary_axes(x, axes)
    return lax.pmin(x, ax) if ax else x


def pmean_v(x, axes):
    ax = _vary_axes(x, axes)
    return lax.pmean(x, ax) if ax else x


def axis_size(axes):
    if isinstance(axes, str):
        axes = (axes,)
    s = 1
    for a in axes:
        s *= axis_size1(a)
    return s


def axis_linear_index(axes):
    """Lexicographic device index over a tuple of axes (first axis major)."""
    if isinstance(axes, str):
        axes = (axes,)
    idx = jnp.int32(0)
    for a in axes:
        idx = idx * axis_size1(a) + lax.axis_index(a)
    return idx


def all_gather_cat(x, axes, axis=0):
    """all_gather over (possibly multiple) axes, concatenated along ``axis``.

    Gathered order is lexicographic in ``axes`` (first axis outermost),
    matching the (data, depth, row) token ordering used framework-wide.
    """
    if isinstance(axes, str):
        axes = (axes,)
    return all_gather_inv(x, axes, tiled=True, axis=axis)


def psum_scatter_dim(x, axes, dim):
    """reduce-scatter over ``axes`` tiling dimension ``dim``."""
    if isinstance(axes, str):
        axes = (axes,)
    return lax.psum_scatter(x, tuple(axes), scatter_dimension=dim, tiled=True)


def last_shard_value(x, axes):
    """Return the value held by the LAST shard (lexicographic) of ``axes``,
    replicated (vma-invariant) over those axes — used for recurrent final
    states in sequence-sharded prefill."""
    if isinstance(axes, str):
        axes = (axes,)
    n = axis_size(axes)
    idx = axis_linear_index(axes)
    keep = (idx == n - 1).astype(x.dtype)
    return lax.psum(x * keep, tuple(axes))


def unvary_concat(x, axes, dim: int):
    """Concatenate shards along ``dim`` across ``axes`` like a tiled
    all_gather, but via a zero-padded psum so the result is vma-INVARIANT
    over ``axes`` (all_gather conservatively keeps axes varying).  Costs
    ~2x all_gather bytes; use only for small tensors that must satisfy a
    replicated out_spec (e.g. decode-cache writes)."""
    if isinstance(axes, str):
        axes = (axes,)
    n = axis_size(axes)
    idx = axis_linear_index(axes)
    shape = list(x.shape)
    shape[dim] = shape[dim] * n
    buf = jnp.zeros(shape, x.dtype)
    start = [0] * x.ndim
    zero = jnp.int32(0)
    starts = [zero] * x.ndim
    starts[dim] = idx * x.shape[dim]
    buf = lax.dynamic_update_slice(buf, x, tuple(starts))
    return lax.psum(buf, tuple(axes))


def halo_exchange_left(x, axes, halo: int, axis: int):
    """Fetch the last ``halo`` elements (along ``axis``) from the previous
    shard in the lexicographic (axes) order; first shard receives zeros.

    Used by: depthwise causal conv across sequence shards (mamba2) and
    windowed local attention (recurrentgemma).
    """
    if isinstance(axes, str):
        axes = (axes,)
    sizes = [axis_size1(a) for a in axes]
    n = 1
    for s in sizes:
        n *= s
    tail = lax.slice_in_dim(x, x.shape[axis] - halo, x.shape[axis], axis=axis)
    # linearize the multi-axis shard index into a chain 0 -> 1 -> ... -> n-1
    # and shift the tail forward by one position along the chain.
    # Implemented as a sequence of ppermutes on the factored axes.
    idx = axis_linear_index(axes)
    flat_perm_src = [(i, i + 1) for i in range(n - 1)]
    recv = _ppermute_linear(tail, axes, flat_perm_src)
    is_first = (idx == 0)
    recv = jnp.where(is_first, jnp.zeros_like(recv), recv)
    return recv


def _ppermute_linear(x, axes, perm):
    """ppermute over the linearized index of a tuple of mesh axes.

    jax.lax.ppermute accepts a single axis name or a tuple; with a tuple the
    permutation indices refer to the lexicographic linear index.
    """
    return lax.ppermute(x, tuple(axes), perm)


# ---------------------------------------------------------------------------
# Distributed linear recurrence:  h_t = a_t * h_{t-1} + b_t   (elementwise)
# across sequence shards on ``axes`` — used by RG-LRU and Mamba2 inter-chunk
# state passing when the sequence is sharded (prefill / long-context).
# ---------------------------------------------------------------------------

def distributed_linear_scan_carry(a_prod, b_red, axes):
    """Given per-shard cumulative coefficients, return the incoming carry.

    a_prod : product of a_t over this shard's steps  [...]
    b_red  : reduced rhs over this shard: sum_t (prod_{s>t} a_s) b_t  [...]
    Returns h_in, the state entering this shard (zeros for the first shard).

    Comm: one all_gather of the (tiny) per-shard summaries over ``axes``,
    then a local exclusive prefix combine.
    """
    if isinstance(axes, str):
        axes = (axes,)
    ap = all_gather_inv(a_prod, axes)          # [n, ...]
    bp = all_gather_inv(b_red, axes)           # [n, ...]
    n = ap.shape[0]

    def combine(carry, xs):
        a_i, b_i = xs
        h = carry
        return a_i * h + b_i, h  # emit the state *entering* shard i

    _, h_ins = lax.scan(combine, b_red * 0, (ap, bp))
    idx = axis_linear_index(axes)
    return lax.dynamic_index_in_dim(h_ins, idx, axis=0, keepdims=False)


# ---------------------------------------------------------------------------
# Distributed categorical sampling over a sharded vocab (gumbel-max).
# ---------------------------------------------------------------------------

def distributed_argmax(values, index_offset, axes):
    """argmax over the last dim of ``values`` where each device holds a
    distinct shard; returns global indices, *invariant* over ``axes``.

    values: [..., v_loc]; index_offset: scalar global offset of this shard.
    Implemented with pmax/pmin (which clear the varying-manifest axes, unlike
    all_gather); ties broken toward the smallest global index.
    """
    if isinstance(axes, str):
        axes = (axes,)
    axes = tuple(axes)
    loc_val = jnp.max(values, axis=-1)
    loc_idx = jnp.argmax(values, axis=-1) + index_offset
    gmax = pmax_v(loc_val, axes)
    big = jnp.iinfo(jnp.int32).max
    cand = jnp.where(loc_val >= gmax, loc_idx.astype(jnp.int32), big)
    return pmin_v(cand, axes)
