"""Operation sets: layout-aware primitives that models are written against.

A model never touches mesh axes directly; it calls methods on an ``OpSet``.
Each parallelization mode (the paper's Tesseract + the baselines it compares
against) implements the same interface:

    TesseractOps   — paper's 2.5-D scheme (covers summa2d via depth=1)
    MegatronOps    — 1-D baseline (column/row split + all-reduce)

Canonical activation layout (per-device local views inside shard_map):

    tesseract : [B_loc, S_loc, h/q]   tokens over (data, depth, row), h over col
    megatron  : [B_loc, S_loc, h]     tokens over (data) [seq over col if SP]

``Plan`` describes how the token dims are laid out for a given shape kind
(train / prefill / decode) — see DESIGN.md §4.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from .api import ParallelContext
from . import collectives as col
from .summa import tesseract_matmul


@dataclass(frozen=True)
class Plan:
    kind: str = "train"          # train | prefill | decode
    seq_sharded: bool = False    # shard sequence (not batch) over (depth,row)

    @staticmethod
    def for_shape(kind: str, *, global_batch: int = 0, batch_shards: int = 1,
                  data: int = 1) -> "Plan":
        if kind == "train":
            return Plan("train", seq_sharded=False)
        if kind == "prefill":
            return Plan("prefill", seq_sharded=True)
        if kind in ("decode", "long_decode", "decode_dp"):
            if kind == "decode" and global_batch and global_batch < batch_shards:
                if data > 1 and global_batch >= data and global_batch % data == 0:
                    kind = "decode_dp"      # batch shards over data only
                else:
                    kind = "long_decode"    # batch too small to shard (b=1)
            return Plan(kind, seq_sharded=False)
        raise ValueError(kind)


def kv_group_axes(ctx: ParallelContext, plan: Plan) -> tuple:
    """Mesh axes sharding the decode-layout KV batch/pool dim for ``plan``.

    Devices sharing one coordinate along these axes form a *KV group*: a
    paged pool's block axis is sharded over them, and a batch slot's pages
    live entirely inside its group's shard (serve/kv_cache.py allocates
    from the co-located freelist, so cache reads never cross groups).
    """
    if plan.kind == "decode":
        return ctx.token_axes
    if plan.kind == "decode_dp":
        return (ctx.axis_data,)
    return ()                                 # long_decode: replicated pool


def _f32_einsum(subs, *args, out_dtype):
    return jnp.einsum(subs, *args, preferred_element_type=jnp.float32).astype(out_dtype)


# ===========================================================================
# Tesseract (2.5-D) op set — the paper's scheme
# ===========================================================================

class TesseractOps:
    mode_family = "tesseract"

    def __init__(self, ctx: ParallelContext, plan: Plan):
        self.ctx = ctx
        self.plan = plan

    # ---------------- specs (global param partitioning) ----------------
    def spec_w2d(self, stacked: bool = False):
        s = ("row", "col")
        return P(*((None,) + s if stacked else s))

    def spec_vec(self, stacked: bool = False):
        # bias / norm scale: sharded over col, replicated elsewhere
        return P(None, "col") if stacked else P("col")

    # norm scales / canonical-output biases: canonical features are
    # col-sharded in tesseract
    spec_norm = spec_vec
    spec_bias_up = spec_vec
    spec_bias_down = spec_vec

    def spec_vec_replicated(self, stacked: bool = False):
        return P(None, None) if stacked else P(None)

    def spec_w_down(self, stacked: bool = False):
        return self.spec_w2d(stacked)

    def spec_w_to_replicated(self, stacked: bool = False):
        # [F, G] with F over col (matching x's feature sharding), G full
        return P(None, "col", None) if stacked else P("col", None)

    def spec_replicated(self, stacked: bool = False):
        return P(None, None) if stacked else P(None)

    def spec_embed(self):
        return P("row", "col")

    def spec_head(self):
        return P(("depth", "row", "col"), None)

    def spec_expert(self, stacked: bool = False):
        # [n_experts, F, G]: experts over depth, F over row, G over col
        s = ("depth", "row", "col")
        return P(*((None,) + s if stacked else s))

    def spec_act(self):
        if self.plan.kind == "long_decode":
            return P(None, None, "col")  # batch=1: no token sharding
        if self.plan.kind == "decode_dp":
            return P("data", None, "col")  # batch over data only
        if self.plan.seq_sharded:
            return P("data", ("depth", "row"), "col")
        if self.plan.kind == "train" and self.ctx.seq > 1:
            # long-context train: time over the seq ring (DESIGN.md §15)
            return P(("data", "depth", "row"), "seq", "col")
        return P(("data", "depth", "row"), None, "col")

    def spec_tokens_in(self):
        # ids/labels as fed from the host: sharded over (data, depth) only;
        # the row factor is applied by embed()'s reduce-scatter.
        if self.plan.kind == "long_decode":
            return P(None, None)
        if self.plan.kind == "decode_dp":
            return P("data", None)
        if self.plan.seq_sharded:
            return P("data", "depth")
        if self.plan.kind == "train" and self.ctx.seq > 1:
            return P(("data", "depth"), "seq")
        return P(("data", "depth"), None)

    # ---------------- shape helpers ----------------
    @property
    def feature_shards(self) -> int:
        return self.ctx.cols

    @property
    def token_shards(self) -> int:
        return self.ctx.data * self.ctx.depth * self.ctx.rows

    def vocab_pad_multiple(self) -> int:
        return self.ctx.depth * self.ctx.rows * self.ctx.cols

    # ---------------- core ops (inside shard_map) ----------------
    def seq_gather_in(self, x):
        return x  # canonical tesseract activations stay sharded through blocks

    def linear(self, x, w, b=None):
        # ctx.matmul_schedule picks the SUMMA execution schedule inside the
        # op: "fused" all-gathers, or the overlapped "ring" (DESIGN.md §2b).
        y = tesseract_matmul(self.ctx, x, w)
        if b is not None:
            y = y + b
        return y

    # up/down aliases: in tesseract the canonical activation is already
    # feature-sharded, so both directions are the same op.
    linear_up = linear
    linear_down = linear

    def linear_replicated(self, x, w, b=None):
        """Small matmul with a fully replicated weight [F_glob_over_col, G].

        x has features over col; gather then local matmul. Used for tiny
        projections (routers) where sharding would waste collectives.
        """
        xg = col.all_gather_inv(x, self.ctx.axis_col, tiled=True, axis=x.ndim - 1)
        y = _f32_einsum("...f,fg->...g", xg, w, out_dtype=x.dtype)
        if b is not None:
            y = y + b
        return y

    def linear_to_replicated(self, x, w, b=None):
        """[.., F_loc] x [F_loc, G] -> psum(col) -> [.., G] replicated over col.

        Used for small outputs that must be whole on every device (e.g.
        replicated GQA KV heads when kv_heads % q != 0)."""
        y = _f32_einsum("...f,fg->...g", x, w, out_dtype=x.dtype)
        y = lax.psum(y, self.ctx.axis_col)
        if b is not None:
            y = y + b
        return y

    @property
    def head_shards(self) -> int:
        """How many ways attention heads are sharded (over col)."""
        return self.ctx.cols

    def _scatter_dim(self, has_batch_and_seq: bool = True):
        # which token dim the row-factor is applied to
        return 1 if self.plan.seq_sharded else 0

    def embed(self, ids, table):
        """ids: [B', S'] per (data, depth) group, replicated over (row, col).
        table: local [v_pad/q, h/q] (vocab over row, h over col).
        Returns canonical activation [B_loc, S_loc, h/q]."""
        ctx = self.ctx
        v_loc = table.shape[0]
        v_off = lax.axis_index(ctx.axis_row) * v_loc
        local = ids - v_off
        valid = (local >= 0) & (local < v_loc)
        safe = jnp.clip(local, 0, v_loc - 1)
        emb = jnp.take(table, safe, axis=0)              # [B', S', h/q]
        emb = jnp.where(valid[..., None], emb, jnp.zeros_like(emb))
        if self.plan.kind in ("long_decode", "decode_dp"):
            # tokens not sharded over (depth,row): sum vocab-shard partials.
            return lax.psum(emb, ctx.axis_row)
        # reduce-scatter over row: sums the vocab-shard partials and applies
        # the final row factor of the token sharding (paper Fig. 4 layout).
        dim = self._scatter_dim()
        return col.psum_scatter_dim(emb, ctx.axis_row, dim)

    def shard_tokens(self, t):
        """Slice host-layout ids/labels [B', S'] to this device's token block
        (the non-summing analogue of embed's reduce-scatter)."""
        if self.plan.kind in ("long_decode", "decode_dp"):
            return t
        ctx = self.ctx
        dim = self._scatter_dim()
        n = t.shape[dim] // ctx.rows
        i = lax.axis_index(ctx.axis_row)
        return lax.dynamic_slice_in_dim(t, i * n, n, axis=dim)

    def rmsnorm(self, x, scale, eps=1e-5):
        ctx = self.ctx
        xf = x.astype(jnp.float32)
        ssq = jnp.sum(xf * xf, axis=-1, keepdims=True)
        ssq = lax.psum(ssq, ctx.axis_col)
        h = x.shape[-1] * ctx.cols
        inv = lax.rsqrt(ssq / h + eps)
        return ((xf * inv) * (1.0 + scale.astype(jnp.float32))).astype(x.dtype)

    def layernorm(self, x, scale, bias, eps=1e-5):
        # paper §3.2.2: compute X and X^2 partial sums, all_reduce along the
        # feature-sharding axis, then normalize locally.
        ctx = self.ctx
        xf = x.astype(jnp.float32)
        s1 = lax.psum(jnp.sum(xf, -1, keepdims=True), ctx.axis_col)
        s2 = lax.psum(jnp.sum(xf * xf, -1, keepdims=True), ctx.axis_col)
        h = x.shape[-1] * ctx.cols
        mean = s1 / h
        var = s2 / h - mean * mean
        y = (xf - mean) * lax.rsqrt(var + eps)
        y = y * scale.astype(jnp.float32)
        if bias is not None:
            y = y + bias.astype(jnp.float32)
        return y.astype(x.dtype)

    # ---------------- token/seq info ----------------
    def seq_shard_index(self):
        ctx = self.ctx
        return lax.axis_index(ctx.axis_depth) * ctx.rows + lax.axis_index(ctx.axis_row)

    def positions(self, seq_loc: int):
        """Global position ids [seq_loc] for this device's sequence block."""
        if self.plan.seq_sharded:
            return self.seq_shard_index() * seq_loc + jnp.arange(seq_loc)
        if self.plan.kind == "train" and self.ctx.seq > 1:
            # seq-ring train: contiguous (ring) or round-robin (striped)
            # global rows — must agree with the token permutation applied in
            # runtime/steps.py and the ring mask in core/ring_attention.py
            from .ring_attention import shard_positions
            return shard_positions(seq_loc, self.ctx.seq,
                                   lax.axis_index(self.ctx.axis_seq),
                                   self.ctx.train_attn_schedule())
        return jnp.arange(seq_loc)

    def gather_seq(self, x, axis: int):
        """Gather a seq-sharded tensor to full length (for KV in attention)."""
        if not self.plan.seq_sharded:
            return x
        return col.all_gather_cat(x, (self.ctx.axis_depth, self.ctx.axis_row), axis=axis)

    # --- attention layout contract (differs between 2.5-D and 1-D SP) ---
    def positions_q(self, t_gathered: int):
        """Positions of the q rows coming out of seq_gather_in+linear_up."""
        return self.positions(t_gathered)

    def kv_full(self, k, axis: int = 1):
        """K/V (as produced by the projections) -> full-sequence K/V."""
        return self.gather_seq(k, axis)

    def kv_local_slice(self, k, axis: int = 1):
        """K/V (as produced by the projections) -> this device's seq shard
        (prefill cache layout)."""
        return k

    # ---------------- losses / heads ----------------
    def ce_loss(self, x, w_head, labels, *, vocab_real: int, loss_chunk: int = 512,
                label_mask=None):
        """Chunked cross-entropy with the head weight sharded
        [v_pad/(d·q²), h] over (depth,row,col) — full logits never materialize.

        x: canonical activation [B_loc, S_loc, h/q]
        labels: host layout [B', S'] per (data, depth) group
        Returns (sum_loss, sum_count): replicated over the model group,
        still varying over data (caller psums over data).
        """
        ctx = self.ctx
        dq = ctx.depth * ctx.rows
        E_loc = x.shape[0] * x.shape[1]
        xf = x.reshape(E_loc, x.shape[-1])
        lab = self.shard_tokens(labels).reshape(E_loc)
        if label_mask is not None:
            lm = self.shard_tokens(label_mask).reshape(E_loc)
        else:
            lm = jnp.ones((E_loc,), jnp.float32)

        c_loc = max(1, min(loss_chunk, E_loc))
        while E_loc % c_loc:
            c_loc -= 1
        n_chunks = E_loc // c_loc

        v_loc = w_head.shape[0]
        v_off = col.axis_linear_index((ctx.axis_depth, ctx.axis_row, ctx.axis_col)) * v_loc
        model_axes = (ctx.axis_depth, ctx.axis_row, ctx.axis_col)
        gather_axes = (ctx.axis_depth, ctx.axis_row)

        xc = xf.reshape(n_chunks, c_loc, xf.shape[-1])
        lc = lab.reshape(n_chunks, c_loc)
        mc = lm.reshape(n_chunks, c_loc)

        @jax.checkpoint
        def chunk_loss(xw, chunk):
            x_chunk, l_chunk, m_chunk = chunk
            # gather this chunk's tokens across (depth,row) and features
            # across col -> [C, h] with C = c_loc * dq
            xg = col.all_gather_cat(x_chunk, gather_axes, axis=0)
            xg = col.all_gather_inv(xg, ctx.axis_col, tiled=True, axis=xg.ndim - 1)
            lg = col.all_gather_cat(l_chunk, gather_axes, axis=0)
            logits = _f32_einsum("ch,vh->cv", xg, xw, out_dtype=jnp.float32)
            vmask = (v_off + jnp.arange(v_loc)) < vocab_real
            logits = jnp.where(vmask[None, :], logits, -jnp.inf)
            m_loc = jnp.max(logits, axis=-1)
            m = lax.pmax(lax.stop_gradient(m_loc), model_axes)
            se = lax.psum(jnp.sum(jnp.exp(logits - m[:, None]), axis=-1), model_axes)
            lse = jnp.log(se) + m
            ll_idx = lg - v_off
            lvalid = (ll_idx >= 0) & (ll_idx < v_loc)
            safe = jnp.clip(ll_idx, 0, v_loc - 1)
            ll_part = jnp.take_along_axis(logits, safe[:, None], axis=1)[:, 0]
            ll = lax.psum(jnp.where(lvalid, ll_part, 0.0), model_axes)
            loss_full = lse - ll                         # [C], varying data only
            # apply the loss mask on this device's own token block and reduce
            # once over (depth,row) — keeps the result vma-invariant there.
            i = col.axis_linear_index(gather_axes)
            mine = lax.dynamic_slice_in_dim(loss_full, i * x_chunk.shape[0],
                                            x_chunk.shape[0], axis=0)
            ls = lax.psum(jnp.sum(mine * m_chunk), gather_axes)
            cs = lax.psum(jnp.sum(m_chunk), gather_axes)
            return ls, cs

        def body(carry, chunk):
            s, n = carry
            ls, cs = chunk_loss(w_head, chunk)
            return (s + ls, n + cs), None

        zero_axes = ((ctx.axis_data, ctx.axis_seq) if ctx.seq > 1
                     else (ctx.axis_data,))
        zero = col.pvary(jnp.float32(0), zero_axes)
        (loss_sum, count), _ = lax.scan(body, (zero, zero), (xc, lc, mc))
        return loss_sum, count

    def _sharded_logits(self, x, w_head, vocab_real, tokens_sharded):
        """Per-shard decode logits [B(_dd), v_loc] (pad masked -inf) + this
        shard's global vocab offset.  The single head implementation that
        both head_sample's distributed argmax and head_logits' gathered
        full-vocab rows reduce — their bit-parity contract rests on it."""
        ctx = self.ctx
        gather_axes = (ctx.axis_depth, ctx.axis_row)
        model_axes = (ctx.axis_depth, ctx.axis_row, ctx.axis_col)
        xg = col.all_gather_inv(x[:, 0, :], ctx.axis_col, tiled=True, axis=1)
        if tokens_sharded:
            xg = col.all_gather_cat(xg, gather_axes, axis=0)        # [B_dd, h]
        logits = _f32_einsum("bh,vh->bv", xg, w_head, out_dtype=jnp.float32)
        v_loc = w_head.shape[0]
        v_off = col.axis_linear_index(model_axes) * v_loc
        vmask = (v_off + jnp.arange(v_loc)) < vocab_real
        return jnp.where(vmask[None, :], logits, -jnp.inf), v_off

    def head_sample(self, x, w_head, *, vocab_real: int, temperature: float = 0.0,
                    rng=None, tokens_sharded: bool = None):
        """Decode-time next-token selection. x: [B_loc, 1, h/q].
        Returns ids [B_loc] (token-sharded like the canonical layout).

        tokens_sharded: whether x's batch dim is sharded over (depth,row)
        (decode plan) or replicated (prefill last-token / long_decode)."""
        ctx = self.ctx
        if tokens_sharded is None:
            tokens_sharded = self.plan.kind == "decode"
        model_axes = (ctx.axis_depth, ctx.axis_row, ctx.axis_col)
        logits, v_off = self._sharded_logits(x, w_head, vocab_real,
                                             tokens_sharded)
        if temperature > 0.0 and rng is not None:
            g = jax.random.gumbel(rng, logits.shape, jnp.float32)
            logits = logits / temperature + g
        ids = col.distributed_argmax(logits, v_off, model_axes)  # [B_dd]
        if not tokens_sharded:
            return ids
        # keep this device's batch block
        i = self.seq_shard_index()
        b_loc = x.shape[0]
        return lax.dynamic_slice_in_dim(ids, i * b_loc, b_loc, axis=0)

    def head_logits(self, x, w_head, *, vocab_real: int, tokens_sharded=None):
        """Full-vocab decode logits for the serve sampler. x: [B_loc, 1, h/q].

        Returns [B_loc, v_pad] float32, padded vocab masked to -inf; the
        greedy argmax of a row is bit-identical to head_sample's distributed
        argmax (same per-shard values, ties toward the smallest index)."""
        ctx = self.ctx
        if tokens_sharded is None:
            tokens_sharded = self.plan.kind == "decode"
        model_axes = (ctx.axis_depth, ctx.axis_row, ctx.axis_col)
        logits, _ = self._sharded_logits(x, w_head, vocab_real,
                                         tokens_sharded)
        # vocab shards are laid out lexicographically over (depth, row, col),
        # matching all_gather_cat's concatenation order.
        full = col.all_gather_cat(logits, model_axes, axis=1)       # [B_dd, V]
        if not tokens_sharded:
            return full
        i = self.seq_shard_index()
        b_loc = x.shape[0]
        return lax.dynamic_slice_in_dim(full, i * b_loc, b_loc, axis=0)


# ===========================================================================
# Megatron-LM (1-D) op set — the paper's main baseline
# ===========================================================================

class MegatronOps:
    mode_family = "megatron"

    def __init__(self, ctx: ParallelContext, plan: Plan):
        assert ctx.rows == 1 and ctx.depth == 1
        self.ctx = ctx
        self.plan = plan
        # depth/row are size-1 in 1-D mode; including them in every TP
        # reduction is numerically free and keeps vma bookkeeping clean
        # (params are pvary'd over them at the step boundary).
        self.tp_axes = (ctx.axis_depth, ctx.axis_row, ctx.axis_col)

    # ---------------- specs ----------------
    def spec_w2d(self, stacked: bool = False):
        # used for "up" weights [F, G]: G over col.  "down" weights use
        # spec_w2d_down.  Models store both with these two specs.
        return P(None, None, "col") if stacked else P(None, "col")

    def spec_w2d_down(self, stacked: bool = False):
        return P(None, "col", None) if stacked else P("col", None)

    spec_w_down = spec_w2d_down

    def spec_vec(self, stacked: bool = False):
        return P(None, "col") if stacked else P("col")

    spec_bias_up = spec_vec

    def spec_vec_full(self, stacked: bool = False):
        return P(None, None) if stacked else P(None)

    # canonical features are full in megatron: norms/down-biases replicated
    spec_norm = spec_vec_full
    spec_bias_down = spec_vec_full
    spec_vec_replicated = spec_vec_full

    def spec_w_to_replicated(self, stacked: bool = False):
        return P(None, None, None) if stacked else P(None, None)

    def spec_replicated(self, stacked: bool = False):
        return P(None, None) if stacked else P(None)

    def spec_embed(self):
        return P("col", None)

    def spec_head(self):
        return P("col", None)

    def spec_expert(self, stacked: bool = False):
        s = ("col", None, None)
        return P(*((None,) + s if stacked else s))

    def spec_act(self):
        if self.plan.kind == "long_decode":
            return P(None, None, None)
        if self.plan.seq_sharded:
            return P("data", "col", None)
        return P(("data",), None, None)  # decode_dp == decode for 1-D

    def spec_tokens_in(self):
        if self.plan.kind == "long_decode":
            return P(None, None)
        return P("data", None)  # decode_dp == decode for 1-D

    @property
    def feature_shards(self) -> int:
        return 1  # canonical activation carries full features

    @property
    def token_shards(self) -> int:
        return self.ctx.data * (self.ctx.cols if self.plan.seq_sharded else 1)

    def vocab_pad_multiple(self) -> int:
        return self.ctx.cols

    # ---------------- core ops ----------------
    def seq_gather_in(self, x):
        """Megatron-SP entry gather: call once before the up-projections of a
        block (the scatter happens inside linear_down)."""
        if self.plan.seq_sharded:
            return col.all_gather_cat(x, self.ctx.axis_col, axis=1)
        return x

    def _maybe_scatter_seq_out(self, y, reduce: bool):
        if self.plan.seq_sharded:
            return col.psum_scatter_dim(y, self.ctx.axis_col, 1)
        return col.psum_v(y, self.tp_axes) if reduce else y

    def linear_up(self, x, w, b=None):
        """Column-parallel: [.., F] x [F, G/p] -> [.., G/p].

        In SP mode the caller must have applied seq_gather_in() first."""
        y = _f32_einsum("...f,fg->...g", x, w, out_dtype=x.dtype)
        if b is not None:
            y = y + b
        return y

    def linear_down(self, h, w, b=None):
        """Row-parallel: [.., G/p] x [G/p, F] -> psum -> [.., F]."""
        y = _f32_einsum("...g,gf->...f", h, w, out_dtype=h.dtype)
        y = self._maybe_scatter_seq_out(y, reduce=True)
        if b is not None:
            y = y + b
        return y

    def linear(self, x, w, b=None):
        # canonical -> canonical full-feature matmul: column then implicit
        # gather is wasteful; use replicated weight for such (rare) cases.
        return self.linear_replicated(x, w, b)

    def linear_replicated(self, x, w, b=None):
        y = _f32_einsum("...f,fg->...g", x, w, out_dtype=x.dtype)
        if b is not None:
            y = y + b
        return y

    def linear_to_replicated(self, x, w, b=None):
        return self.linear_replicated(x, w, b)

    @property
    def head_shards(self) -> int:
        return self.ctx.cols

    def embed(self, ids, table):
        ctx = self.ctx
        v_loc = table.shape[0]
        v_off = lax.axis_index(ctx.axis_col) * v_loc
        local = ids - v_off
        valid = (local >= 0) & (local < v_loc)
        emb = jnp.take(table, jnp.clip(local, 0, v_loc - 1), axis=0)
        emb = jnp.where(valid[..., None], emb, jnp.zeros_like(emb))
        if self.plan.seq_sharded:
            emb = col.psum_scatter_dim(emb, ctx.axis_col, 1)
            return col.psum_v(emb, (ctx.axis_depth, ctx.axis_row))
        return col.psum_v(emb, self.tp_axes)

    def shard_tokens(self, t):
        if not self.plan.seq_sharded:
            return t
        ctx = self.ctx
        n = t.shape[1] // ctx.cols
        i = lax.axis_index(ctx.axis_col)
        return lax.dynamic_slice_in_dim(t, i * n, n, axis=1)

    def rmsnorm(self, x, scale, eps=1e-5):
        xf = x.astype(jnp.float32)
        inv = lax.rsqrt(jnp.mean(xf * xf, -1, keepdims=True) + eps)
        return ((xf * inv) * (1.0 + scale.astype(jnp.float32))).astype(x.dtype)

    def layernorm(self, x, scale, bias, eps=1e-5):
        xf = x.astype(jnp.float32)
        mean = jnp.mean(xf, -1, keepdims=True)
        var = jnp.mean(xf * xf, -1, keepdims=True) - mean * mean
        y = (xf - mean) * lax.rsqrt(var + eps)
        y = y * scale.astype(jnp.float32)
        if bias is not None:
            y = y + bias.astype(jnp.float32)
        return y.astype(x.dtype)

    def seq_shard_index(self):
        return lax.axis_index(self.ctx.axis_col)

    def positions(self, seq_loc: int):
        if self.plan.seq_sharded:
            return self.seq_shard_index() * seq_loc + jnp.arange(seq_loc)
        return jnp.arange(seq_loc)

    def gather_seq(self, x, axis: int):
        if not self.plan.seq_sharded:
            return x
        return col.all_gather_cat(x, self.ctx.axis_col, axis=axis)

    # --- attention layout contract: megatron-SP projects on the *gathered*
    # sequence, so q/k/v are already full-length per device ---
    def positions_q(self, t_gathered: int):
        return jnp.arange(t_gathered)

    def kv_full(self, k, axis: int = 1):
        return k

    def kv_local_slice(self, k, axis: int = 1):
        if not self.plan.seq_sharded:
            return k
        n = k.shape[axis] // self.ctx.cols
        i = lax.axis_index(self.ctx.axis_col)
        return lax.dynamic_slice_in_dim(k, i * n, n, axis=axis)

    def ce_loss(self, x, w_head, labels, *, vocab_real: int, loss_chunk: int = 512,
                label_mask=None):
        ctx = self.ctx
        E_loc = x.shape[0] * x.shape[1]
        xf = x.reshape(E_loc, x.shape[-1])
        lab = self.shard_tokens(labels).reshape(E_loc)
        lm = (self.shard_tokens(label_mask).reshape(E_loc)
              if label_mask is not None else jnp.ones((E_loc,), jnp.float32))

        c_loc = max(1, min(loss_chunk, E_loc))
        while E_loc % c_loc:
            c_loc -= 1
        n_chunks = E_loc // c_loc
        v_loc = w_head.shape[0]
        v_off = lax.axis_index(ctx.axis_col) * v_loc
        sp = self.plan.seq_sharded  # tokens sharded over col too -> gather

        xc = xf.reshape(n_chunks, c_loc, xf.shape[-1])
        lc = lab.reshape(n_chunks, c_loc)
        mc = lm.reshape(n_chunks, c_loc)

        @jax.checkpoint
        def chunk_loss(xw, chunk):
            x_chunk, l_chunk, m_chunk = chunk
            if sp:
                # SP: col devices hold different tokens; replicate the chunk
                # within the TP group before the vocab-sharded matmul (the
                # loss mask stays local: the final reduction slices back).
                x_chunk = col.all_gather_cat(x_chunk, ctx.axis_col, axis=0)
                l_chunk = col.all_gather_cat(l_chunk, ctx.axis_col, axis=0)
            logits = _f32_einsum("ch,vh->cv", x_chunk, xw, out_dtype=jnp.float32)
            vmask = (v_off + jnp.arange(v_loc)) < vocab_real
            logits = jnp.where(vmask[None, :], logits, -jnp.inf)
            m_l = jnp.max(logits, -1)
            m = col.pmax_v(lax.stop_gradient(m_l), self.tp_axes)
            se = col.psum_v(jnp.sum(jnp.exp(logits - m[:, None]), -1), self.tp_axes)
            lse = jnp.log(se) + m
            idx = l_chunk - v_off
            valid = (idx >= 0) & (idx < v_loc)
            safe = jnp.clip(idx, 0, v_loc - 1)
            ll_p = jnp.take_along_axis(logits, safe[:, None], 1)[:, 0]
            ll = col.psum_v(jnp.where(valid, ll_p, 0.0), self.tp_axes)
            loss_full = lse - ll
            if sp:
                i = lax.axis_index(ctx.axis_col)
                mine = lax.dynamic_slice_in_dim(loss_full, i * c_loc, c_loc, 0)
                return (lax.psum(jnp.sum(mine * m_chunk), ctx.axis_col),
                        lax.psum(jnp.sum(m_chunk), ctx.axis_col))
            return jnp.sum(loss_full * m_chunk), jnp.sum(m_chunk)

        def body(carry, chunk):
            s, n = carry
            ls, cs = chunk_loss(w_head, chunk)
            return (s + ls, n + cs), None

        zero = col.pvary(jnp.float32(0), (ctx.axis_data,))
        (loss_sum, count), _ = lax.scan(body, (zero, zero), (xc, lc, mc))
        return loss_sum, count

    def _sharded_logits(self, x, w_head, vocab_real):
        """Per-shard decode logits + vocab offset (see TesseractOps)."""
        xg = x[:, 0, :]                                   # [B_loc, h]
        logits = _f32_einsum("bh,vh->bv", xg, w_head, out_dtype=jnp.float32)
        v_loc = w_head.shape[0]
        v_off = col.axis_linear_index(self.tp_axes) * v_loc
        vmask = (v_off + jnp.arange(v_loc)) < vocab_real
        return jnp.where(vmask[None, :], logits, -jnp.inf), v_off

    def head_sample(self, x, w_head, *, vocab_real: int, temperature: float = 0.0,
                    rng=None, tokens_sharded: bool = None):
        logits, v_off = self._sharded_logits(x, w_head, vocab_real)
        if temperature > 0.0 and rng is not None:
            g = jax.random.gumbel(rng, logits.shape, jnp.float32)
            logits = logits / temperature + g
        return col.distributed_argmax(logits, v_off, self.tp_axes)

    def head_logits(self, x, w_head, *, vocab_real: int, tokens_sharded=None):
        """Full-vocab decode logits [B_loc, v_pad] (see TesseractOps)."""
        del tokens_sharded  # 1-D decode batch is only ever sharded over data
        logits, _ = self._sharded_logits(x, w_head, vocab_real)
        return col.all_gather_cat(logits, self.tp_axes, axis=1)


def make_ops(ctx: ParallelContext, plan: Plan):
    if ctx.mode in ("tesseract", "summa2d"):
        return TesseractOps(ctx, plan)
    if ctx.mode == "megatron1d":
        return MegatronOps(ctx, plan)
    raise ValueError(f"no OpSet for mode {ctx.mode!r}")
