"""Tesseract matrix multiplication (the paper's Algorithm 3 + Eq. 3), TPU-native.

Layout (inside ``jax.shard_map`` over the logical mesh, per-device views):

    activations A : [..., E_loc, F_loc]   E sharded over (data, depth, row),
                                          F sharded over col
    weights     W : [F_loc, G_loc]        F over row, G over col,
                                          replicated over (data, depth)
    output      C : [..., E_loc, G_loc]   same layout class as A

Two execution schedules implement the same math (DESIGN.md §2 / §2b,
selected by ``ParallelContext.matmul_schedule``):

``fused`` — the paper's q broadcasts of A along each row of the [q, q] grid
are fused into one ``all_gather`` over ``col``; the q broadcasts of W along
each column fuse into one ``all_gather`` over ``row``; the SUMMA
accumulation loop becomes a single local einsum over the gathered block
index t (identical bytes, one fused collective instead of q serialized
broadcasts).  Peak gathered-operand memory: O(q · block).

``ring`` — Cannon-style skewed double ring: after one skew ppermute per
operand, each of the q SUMMA steps contracts the resident (A, W) block pair
while ``lax.ppermute`` streams the next pair around the ``col`` / ``row``
rings (double buffering; on TPU the async collective-permute overlaps the
MXU).  The C accumulator stays in fp32 and only TWO blocks per operand are
ever resident — O(2 · block) peak.  The backward contractions ride the same
rings: dA and dW partials are accumulated with shift-and-add rings (the ring
form of reduce-scatter), so no q×-gathered operand materializes in bwd
either.

Backward follows the paper exactly:
    A' = C' W^T  : gather W over row, contract, reduce_scatter over col
    W' = A^T C'  : gather A over col, contract, reduce_scatter over row,
                   then all_reduce over depth ("processors with same row and
                   column but different depth") — optionally deferred to the
                   step-level gradient sync (perf lever).
"""
from __future__ import annotations

from functools import lru_cache, partial

import jax
import jax.numpy as jnp
from jax import lax

from .api import ParallelContext
from .collectives import all_gather_inv


def _maybe_f32(ctx: ParallelContext):
    return jnp.float32 if ctx.accum_fp32 else None


def effective_schedule(ctx: ParallelContext, e_loc: int) -> str:
    """Resolve ``matmul_schedule`` for one op from its local token-block rows.

    "auto" picks per-op: the ring schedule only pays when each of its q steps
    has enough arithmetic to hide a skew/shift (DESIGN.md §2b: q >= 4 and
    enough local rows); a decode step's token block (E_loc = a handful of
    requests) never does, so serve decode falls back to the fused gathers
    while train/prefill matmuls on the same ParallelContext ride the ring.
    Forward and backward resolve identically because E_loc is a static shape
    shared by A and dC.

    On a seq-sharded mesh (ctx.seq > 1) the local token block is already
    1/seq of the sequence AND the links are busy streaming ring-attention
    K/V, so the rows-per-ring-step threshold scales with seq: blocks that
    look decode-shaped only because the sequence was sharded stay on the
    fused schedule instead of regressing to a ring that can't hide its
    shifts (DESIGN.md §15).
    """
    s = ctx.matmul_schedule
    if s != "auto":
        return s
    return "ring" if ctx.q >= 4 and e_loc >= 2 * ctx.q * ctx.seq else "fused"


def _einsum(subs, *args, ctx: ParallelContext, out_dtype):
    acc = _maybe_f32(ctx)
    out = jnp.einsum(subs, *args, preferred_element_type=acc)
    return out.astype(out_dtype)


# --------------------------------------------------------------------------
# Exact per-op wire-byte model (repro.analysis.shardcheck rule d).
#
# benchmarks/comm_model.py models the *asymptotic* schedules for roofline
# curves; the functions below count the bytes this file's implementations
# actually move, collective by collective, under the same ring cost model as
# roofline/hlo.py (all_gather/psum_scatter over n devices move (n-1)/n of
# the gathered/scattered payload per device; ppermute moves the payload).
# shardcheck traces each schedule and requires byte-exact agreement, so any
# edit to the collective structure above must be mirrored here (that is the
# point: the model IS the reviewed comm contract).
# --------------------------------------------------------------------------

def matmul_comm_bytes(ctx: ParallelContext, e_loc: int, f_loc: int,
                      g_loc: int, *, batch: int = 1, train: bool = True,
                      itemsize: int = 4, schedule: str | None = None) -> dict:
    """Wire bytes per device for ONE ``tesseract_matmul`` call.

    ``e_loc``/``f_loc``/``g_loc`` are the LOCAL block dims of A ([batch,
    E_loc, F_loc]) and W ([F_loc, G_loc]); ``itemsize`` is the compute-dtype
    width.  Returns {"fwd", "bwd", "total"} (bwd = 0 when not train).
    """
    q = ctx.q
    sched = schedule or effective_schedule(ctx, e_loc)
    a = batch * e_loc * f_loc * itemsize          # local A block bytes
    w = f_loc * g_loc * itemsize                  # local W block bytes
    w_rs = f_loc * g_loc * (2 if ctx.dgrad_rs_bf16 else itemsize)
    if sched == "ring":
        fwd = 0 if q == 1 else q * (a + w)        # skew + (q-1) shifts each
        # pass 1: W stream (q shifts) + dA accumulator ring ((q-1) shifts +
        # final shift + unskew); pass 2: A stream + dW accumulator ring.
        bwd = 0 if q == 1 else (q * w + (q + 1) * a + q * a
                                + (q + 1) * w_rs)
    else:
        fwd = (q - 1) * (a + w)                   # fused gathers of A and W
        regather = ((0 if ctx.cache_act_gather else (q - 1) * a)
                    + (0 if ctx.cache_weight_gather else (q - 1) * w))
        # psum_scatter of the [q, ...] dA / dW partial stacks
        bwd = regather + (q - 1) * a + (q - 1) * w_rs
    if train and ctx.reduce_dgrad_in_op:
        ndd = ctx.data * ctx.depth * ctx.seq      # in-op dW all-reduce
        bwd += 2 * w_rs * (ndd - 1) / ndd if ndd > 1 else 0
    if not train:
        bwd = 0
    return {"fwd": float(fwd), "bwd": float(bwd), "total": float(fwd + bwd)}


def ring_vs_fused(ctx: ParallelContext, e_loc: int, f_loc: int, g_loc: int,
                  *, batch: int = 1, train: bool = True,
                  itemsize: int = 4) -> dict:
    """Implementation-exact {schedule: {"fwd","bwd","total"}} byte table for
    one matmul — the tight reference shardcheck diffs traced bytes against
    (benchmarks/comm_model.ring_vs_fused stays the asymptotic roofline)."""
    return {s: matmul_comm_bytes(ctx, e_loc, f_loc, g_loc, batch=batch,
                                 train=train, itemsize=itemsize, schedule=s)
            for s in ("ring", "fused")}


def _dgrad_axes(ctx):
    """Axes the in-op dW reduction must cover: data + depth, plus seq when
    the sequence axis is active (params are replicated over seq as well)."""
    if ctx.seq > 1:
        return (ctx.axis_data, ctx.axis_depth, ctx.axis_seq)
    return (ctx.axis_data, ctx.axis_depth)


# --------------------------------------------------------------------------
# Ring schedule machinery (matmul_schedule="ring", DESIGN.md §2b).
#
# Permutations over the [q, q] (row, col) grid.  ppermute over the axis
# tuple ("row", "col") takes linearized indices i*q + j (first axis major).
# The skews give device (i, j) the SUMMA block with feature index
# t = (i + j) % q so that after s synchronized ring shifts BOTH resident
# operands carry t = (i + j + s) % q — Cannon's initial alignment, which is
# what lets a uniform ppermute replace the paper's per-step broadcasts.
# --------------------------------------------------------------------------

@lru_cache(maxsize=None)
def _perm_shift(q):
    """Ring step: receive from the next device ((j+1) -> j)."""
    return tuple((j, (j - 1) % q) for j in range(q))


@lru_cache(maxsize=None)
def _perm_skew_a(q):
    """dst (i, j) <- src (i, (i+j) % q): row i rotates left by i."""
    return tuple((i * q + (i + j) % q, i * q + j)
                 for i in range(q) for j in range(q))


@lru_cache(maxsize=None)
def _perm_unskew_a(q):
    return tuple((i * q + j, i * q + (i + j) % q)
                 for i in range(q) for j in range(q))


@lru_cache(maxsize=None)
def _perm_skew_w(q):
    """dst (i, j) <- src ((i+j) % q, j): column j rotates up by j."""
    return tuple((((i + j) % q) * q + j, i * q + j)
                 for i in range(q) for j in range(q))


@lru_cache(maxsize=None)
def _perm_unskew_w(q):
    return tuple((i * q + j, ((i + j) % q) * q + j)
                 for i in range(q) for j in range(q))


def _rc(ctx):
    return (ctx.axis_row, ctx.axis_col)


def _skew_a(ctx, x):
    return x if ctx.q == 1 else lax.ppermute(x, _rc(ctx), _perm_skew_a(ctx.q))


def _unskew_a(ctx, x):
    return x if ctx.q == 1 else lax.ppermute(x, _rc(ctx), _perm_unskew_a(ctx.q))


def _skew_w(ctx, x):
    return x if ctx.q == 1 else lax.ppermute(x, _rc(ctx), _perm_skew_w(ctx.q))


def _unskew_w(ctx, x):
    return x if ctx.q == 1 else lax.ppermute(x, _rc(ctx), _perm_unskew_w(ctx.q))


def _shift_col(ctx, x):
    return x if ctx.q == 1 else lax.ppermute(x, ctx.axis_col, _perm_shift(ctx.q))


def _shift_row(ctx, x):
    return x if ctx.q == 1 else lax.ppermute(x, ctx.axis_row, _perm_shift(ctx.q))


def _ring_fwd(ctx, a, w, subs_step):
    """C = sum_t A_t W_t via the skewed double ring; fp32 accumulator.

    Per step: launch the next-block ppermutes, contract the resident pair
    (XLA overlaps the async collective-permute with the einsum on TPU),
    accumulate.  Only two blocks per operand are live at any time."""
    q = ctx.q
    a_cur = _skew_a(ctx, a)
    w_cur = _skew_w(ctx, w)
    acc = None
    for s in range(q):
        a_nxt = _shift_col(ctx, a_cur) if s < q - 1 else None
        w_nxt = _shift_row(ctx, w_cur) if s < q - 1 else None
        part = jnp.einsum(subs_step, a_cur, w_cur,
                          preferred_element_type=_maybe_f32(ctx))
        acc = part if acc is None else acc + part
        a_cur, w_cur = a_nxt, w_nxt
    return acc.astype(a.dtype)


def _ring_bwd(ctx, a, w, dc, da_subs, dw_subs):
    """dA and dW on the same rings (transpose of _ring_fwd), TWO passes.

    The per-step cotangent pieces are pushed around shift-and-add
    accumulator rings — the ring form of the fused schedule's
    psum_scatters — so each device ends holding exactly its own dA / dW
    block and no [q, ...] partial stack is ever resident.  Running the dA
    pass (W stream) and the dW pass (A stream) sequentially keeps the peak
    at two live blocks per operand (stream + accumulator), vs. the fused
    backward's simultaneous re-gathered A and [q, ...] dA stack.  Final
    single-shift + unskew undo the Cannon alignment."""
    q = ctx.q
    rs_dtype = jnp.bfloat16 if ctx.dgrad_rs_bf16 else jnp.float32

    # pass 1 — dA: stream W around the row ring, dA pieces ride a col
    # accumulator ring.
    w_cur = _skew_w(ctx, w)
    b_da = None
    for s in range(q):
        w_nxt = _shift_row(ctx, w_cur) if s < q - 1 else None
        g = _einsum(da_subs, dc, w_cur, ctx=ctx, out_dtype=dc.dtype)
        b_da = g if b_da is None else _shift_col(ctx, b_da) + g
        w_cur = w_nxt
    da = _unskew_a(ctx, _shift_col(ctx, b_da))

    # pass 2 — dW: stream A around the col ring, dW pieces ride a row
    # accumulator ring.
    a_cur = _skew_a(ctx, a)
    b_dw = None
    for s in range(q):
        a_nxt = _shift_col(ctx, a_cur) if s < q - 1 else None
        h = _einsum(dw_subs, a_cur, dc, ctx=ctx, out_dtype=rs_dtype)
        b_dw = h if b_dw is None else _shift_row(ctx, b_dw) + h
        a_cur = a_nxt
    dw = _unskew_w(ctx, _shift_row(ctx, b_dw))
    return da, dw


# --------------------------------------------------------------------------
# Core: C = A @ W
# --------------------------------------------------------------------------

@partial(jax.custom_vjp, nondiff_argnums=(0,))
def tesseract_matmul(ctx: ParallelContext, a, w):
    """Distributed C = A @ W per Tesseract Algorithm 3 (local view)."""
    c, _ = _tess_fwd(ctx, a, w)
    return c


def _gather_a(ctx, a):
    # A_{h,t} for all t: the q row-broadcasts of Algorithm 3, fused.
    return all_gather_inv(a, ctx.axis_col)          # [q, ..., E_loc, F_loc]


def _gather_w(ctx, w):
    # W_{t,j} for all t: the q column-broadcasts of Algorithm 3, fused.
    return all_gather_inv(w, ctx.axis_row)          # [q, F_loc, G_loc]


def _tess_fwd(ctx: ParallelContext, a, w):
    if effective_schedule(ctx, a.shape[-2]) == "ring":
        # Blocks stay resident; nothing gathered, nothing worth caching.
        return _ring_fwd(ctx, a, w, "...ef,fg->...eg"), (a, w)
    ag = _gather_a(ctx, a)
    wg = _gather_w(ctx, w)
    # C_{h,j} = sum_t A_{h,t} W_{t,j}
    c = _einsum("t...ef,tfg->...eg", ag, wg, ctx=ctx, out_dtype=a.dtype)
    res = (ag if ctx.cache_act_gather else a,
           wg if ctx.cache_weight_gather else w)
    return c, res


def _tess_bwd(ctx: ParallelContext, res, dc):
    ar, wr = res
    if effective_schedule(ctx, dc.shape[-2]) == "ring":
        da, dw = _ring_bwd(ctx, ar, wr, dc,
                           "...eg,fg->...ef", "...ef,...eg->fg")
    else:
        ag = ar if ctx.cache_act_gather else _gather_a(ctx, ar)
        wg = wr if ctx.cache_weight_gather else _gather_w(ctx, wr)
        # dA_{h,t} = sum_j dC_{h,j} W_{t,j}^T   (paper's C = A * B^T form)
        da_part = _einsum("...eg,tfg->t...ef", dc, wg, ctx=ctx,
                          out_dtype=dc.dtype)
        da = lax.psum_scatter(da_part, ctx.axis_col, scatter_dimension=0,
                              tiled=False)
        # dW_{t,j} = sum_h A_{h,t}^T dC_{h,j}   (paper's C = A^T * B form)
        rs_dtype = jnp.bfloat16 if ctx.dgrad_rs_bf16 else jnp.float32
        dw_part = _einsum("t...ef,...eg->tfg", ag, dc, ctx=ctx,
                          out_dtype=rs_dtype)
        dw = lax.psum_scatter(dw_part, ctx.axis_row, scatter_dimension=0,
                              tiled=False)
    if ctx.reduce_dgrad_in_op:
        # Paper-faithful per-op reduction: "all_reduce after the computation
        # of B' on processors with same row and column but different depth"
        # (+ the data axis when DP is fused in).  In deferred mode the same
        # reduction happens once per step at the pvary boundary instead.
        # Params are replicated over the seq axis too, so the in-op reduce
        # must cover it (in-op-reduced weights skip the step-level pvary).
        dw = lax.psum(dw, _dgrad_axes(ctx))
    return da, dw.astype(wr.dtype)  # wr dtype == w dtype in both cache modes


tesseract_matmul.defvjp(_tess_fwd, _tess_bwd)


# --------------------------------------------------------------------------
# Expert-batched variant: C[n] = A[n] @ W[n] for n local experts (MoE).
# A: [N, T, F_loc], W: [N, F_loc, G_loc] — the expert dim N is already local
# (experts sharded over depth); row/col collectives are identical to the
# plain op.  Grad sync over (data,) happens at the grad_sync boundary (EP
# weights are only replicated over data), so no in-op reduction flag here.
# --------------------------------------------------------------------------

@partial(jax.custom_vjp, nondiff_argnums=(0,))
def tesseract_matmul_experts(ctx: ParallelContext, a, w):
    c, _ = _tess_exp_fwd(ctx, a, w)
    return c


def _tess_exp_fwd(ctx, a, w):
    if effective_schedule(ctx, a.shape[-2]) == "ring":
        return _ring_fwd(ctx, a, w, "nef,nfg->neg"), (a, w)
    ag = all_gather_inv(a, ctx.axis_col)      # [q, N, T, F_loc]
    wg = all_gather_inv(w, ctx.axis_row)      # [q, N, F_loc, G_loc]
    c = _einsum("tnef,tnfg->neg", ag, wg, ctx=ctx, out_dtype=a.dtype)
    res = (ag if ctx.cache_act_gather else a,
           wg if ctx.cache_weight_gather else w)
    return c, res


def _tess_exp_bwd(ctx, res, dc):
    ar, wr = res
    if effective_schedule(ctx, dc.shape[-2]) == "ring":
        da, dw = _ring_bwd(ctx, ar, wr, dc,
                           "neg,nfg->nef", "nef,neg->nfg")
        return da, dw.astype(wr.dtype)
    ag = ar if ctx.cache_act_gather else all_gather_inv(ar, ctx.axis_col)
    wg = wr if ctx.cache_weight_gather else all_gather_inv(wr, ctx.axis_row)
    da_part = _einsum("neg,tnfg->tnef", dc, wg, ctx=ctx, out_dtype=dc.dtype)
    da = lax.psum_scatter(da_part, ctx.axis_col, scatter_dimension=0, tiled=False)
    rs_dtype = jnp.bfloat16 if ctx.dgrad_rs_bf16 else jnp.float32
    dw_part = _einsum("tnef,neg->tnfg", ag, dc, ctx=ctx, out_dtype=rs_dtype)
    dw = lax.psum_scatter(dw_part, ctx.axis_row, scatter_dimension=0, tiled=False)
    return da, dw.astype(wr.dtype)


tesseract_matmul_experts.defvjp(_tess_exp_fwd, _tess_exp_bwd)


# --------------------------------------------------------------------------
# Transposed variant: C = A @ W^T (used by tied heads / down-projections that
# store weights in [out, in] layout).  W: [G_loc(row), F_loc(col)].
# --------------------------------------------------------------------------

@partial(jax.custom_vjp, nondiff_argnums=(0,))
def tesseract_matmul_wt(ctx: ParallelContext, a, w):
    c, _ = _tess_wt_fwd(ctx, a, w)
    return c


def _ring_wt_fwd(ctx, a, w):
    """C = A @ W^T on the ring: W streams around the row ring while the
    output blocks ride a col accumulator ring (the ring form of the fused
    schedule's psum_scatter).  The final unskew+shift undoes the Cannon
    alignment so each device ends with its own C block."""
    q = ctx.q
    w_cur = _skew_w(ctx, w)
    b = None
    for s in range(q):
        w_nxt = _shift_row(ctx, w_cur) if s < q - 1 else None
        part = _einsum("...ef,gf->...eg", a, w_cur, ctx=ctx, out_dtype=a.dtype)
        b = part if b is None else _shift_col(ctx, b) + part
        w_cur = w_nxt
    return _unskew_a(ctx, _shift_col(ctx, b))


def _ring_wt_bwd(ctx, a, w, dc):
    """dA accumulates locally off the synchronized (dC, W) streams; dW
    partials ride a row accumulator ring (ring reduce-scatter over row)."""
    q = ctx.q
    rs_dtype = jnp.bfloat16 if ctx.dgrad_rs_bf16 else jnp.float32
    # dC blocks live at their own col index (like A blocks): same skew.
    dc_cur = _skew_a(ctx, dc)
    w_cur = _skew_w(ctx, w)
    acc_da = None
    b_dw = None
    for s in range(q):
        dc_nxt = _shift_col(ctx, dc_cur) if s < q - 1 else None
        w_nxt = _shift_row(ctx, w_cur) if s < q - 1 else None
        part = jnp.einsum("...eg,gf->...ef", dc_cur, w_cur,
                          preferred_element_type=_maybe_f32(ctx))
        acc_da = part if acc_da is None else acc_da + part
        h = _einsum("...eg,...ef->gf", dc_cur, a, ctx=ctx, out_dtype=rs_dtype)
        b_dw = h if b_dw is None else _shift_row(ctx, b_dw) + h
        dc_cur, w_cur = dc_nxt, w_nxt
    da = acc_da.astype(dc.dtype)
    dw = _unskew_w(ctx, _shift_row(ctx, b_dw))
    return da, dw


def _tess_wt_fwd(ctx, a, w):
    if effective_schedule(ctx, a.shape[-2]) == "ring":
        return _ring_wt_fwd(ctx, a, w), (a, w)
    # C_{h,t} = sum_j A_{h,j} W_{t,j}^T : broadcast W within its column,
    # compute, then reduce partial C within the row (paper 3.1, C = A*B^T).
    wg = all_gather_inv(w, ctx.axis_row)            # [q(t), G_loc, F_loc]
    part = _einsum("...ef,tgf->t...eg", a, wg, ctx=ctx, out_dtype=a.dtype)
    c = lax.psum_scatter(part, ctx.axis_col, scatter_dimension=0, tiled=False)
    res = (a, wg if ctx.cache_weight_gather else w)
    return c, res


def _tess_wt_bwd(ctx, res, dc):
    a, wr = res
    if effective_schedule(ctx, dc.shape[-2]) == "ring":
        da, dw = _ring_wt_bwd(ctx, a, wr, dc)
    else:
        wg = wr if ctx.cache_weight_gather else all_gather_inv(wr, ctx.axis_row)
        dcg = all_gather_inv(dc, ctx.axis_col)      # [q(t), ..., E, G_loc]
        da = _einsum("t...eg,tgf->...ef", dcg, wg, ctx=ctx, out_dtype=dc.dtype)
        rs_dtype = jnp.bfloat16 if ctx.dgrad_rs_bf16 else jnp.float32
        dw_part = _einsum("t...eg,...ef->tgf", dcg, a, ctx=ctx,
                          out_dtype=rs_dtype)
        dw = lax.psum_scatter(dw_part, ctx.axis_row, scatter_dimension=0,
                              tiled=False)
    if ctx.reduce_dgrad_in_op:
        dw = lax.psum(dw, _dgrad_axes(ctx))
    return da, dw.astype(wr.dtype)


tesseract_matmul_wt.defvjp(_tess_wt_fwd, _tess_wt_bwd)
