"""Tesseract matrix multiplication (the paper's Algorithm 3 + Eq. 3), TPU-native.

Layout (inside ``jax.shard_map`` over the logical mesh, per-device views):

    activations A : [..., E_loc, F_loc]   E sharded over (data, depth, row),
                                          F sharded over col
    weights     W : [F_loc, G_loc]        F over row, G over col,
                                          replicated over (data, depth)
    output      C : [..., E_loc, G_loc]   same layout class as A

The paper's q broadcasts of A along each row of the [q, q] grid are fused into
one ``all_gather`` over ``col``; the q broadcasts of W along each column fuse
into one ``all_gather`` over ``row``; the SUMMA accumulation loop becomes a
single local einsum over the gathered block index t (identical bytes, one
fused collective instead of q serialized broadcasts — see DESIGN.md §2).

Backward follows the paper exactly:
    A' = C' W^T  : gather W over row, contract, reduce_scatter over col
    W' = A^T C'  : gather A over col, contract, reduce_scatter over row,
                   then all_reduce over depth ("processors with same row and
                   column but different depth") — optionally deferred to the
                   step-level gradient sync (perf lever).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax import lax

from .api import ParallelContext
from .collectives import all_gather_inv


def _maybe_f32(ctx: ParallelContext):
    return jnp.float32 if ctx.accum_fp32 else None


def _einsum(subs, *args, ctx: ParallelContext, out_dtype):
    acc = _maybe_f32(ctx)
    out = jnp.einsum(subs, *args, preferred_element_type=acc)
    return out.astype(out_dtype)


# --------------------------------------------------------------------------
# Core: C = A @ W
# --------------------------------------------------------------------------

@partial(jax.custom_vjp, nondiff_argnums=(0,))
def tesseract_matmul(ctx: ParallelContext, a, w):
    """Distributed C = A @ W per Tesseract Algorithm 3 (local view)."""
    c, _ = _tess_fwd(ctx, a, w)
    return c


def _gather_a(ctx, a):
    # A_{h,t} for all t: the q row-broadcasts of Algorithm 3, fused.
    return all_gather_inv(a, ctx.axis_col)          # [q, ..., E_loc, F_loc]


def _gather_w(ctx, w):
    # W_{t,j} for all t: the q column-broadcasts of Algorithm 3, fused.
    return all_gather_inv(w, ctx.axis_row)          # [q, F_loc, G_loc]


def _tess_fwd(ctx: ParallelContext, a, w):
    ag = _gather_a(ctx, a)
    wg = _gather_w(ctx, w)
    # C_{h,j} = sum_t A_{h,t} W_{t,j}
    c = _einsum("t...ef,tfg->...eg", ag, wg, ctx=ctx, out_dtype=a.dtype)
    res = (ag if ctx.cache_act_gather else a,
           wg if ctx.cache_weight_gather else w)
    return c, res


def _tess_bwd(ctx: ParallelContext, res, dc):
    ar, wr = res
    ag = ar if ctx.cache_act_gather else _gather_a(ctx, ar)
    wg = wr if ctx.cache_weight_gather else _gather_w(ctx, wr)
    # dA_{h,t} = sum_j dC_{h,j} W_{t,j}^T   (paper's C = A * B^T form)
    da_part = _einsum("...eg,tfg->t...ef", dc, wg, ctx=ctx, out_dtype=dc.dtype)
    da = lax.psum_scatter(da_part, ctx.axis_col, scatter_dimension=0,
                          tiled=False)
    # dW_{t,j} = sum_h A_{h,t}^T dC_{h,j}   (paper's C = A^T * B form)
    rs_dtype = jnp.bfloat16 if ctx.dgrad_rs_bf16 else jnp.float32
    dw_part = _einsum("t...ef,...eg->tfg", ag, dc, ctx=ctx, out_dtype=rs_dtype)
    dw = lax.psum_scatter(dw_part, ctx.axis_row, scatter_dimension=0,
                          tiled=False)
    if ctx.reduce_dgrad_in_op:
        # Paper-faithful per-op reduction: "all_reduce after the computation
        # of B' on processors with same row and column but different depth"
        # (+ the data axis when DP is fused in).  In deferred mode the same
        # reduction happens once per step at the pvary boundary instead.
        dw = lax.psum(dw, (ctx.axis_data, ctx.axis_depth))
    return da, dw.astype(wr.dtype)  # wr dtype == w dtype in both cache modes


tesseract_matmul.defvjp(_tess_fwd, _tess_bwd)


# --------------------------------------------------------------------------
# Expert-batched variant: C[n] = A[n] @ W[n] for n local experts (MoE).
# A: [N, T, F_loc], W: [N, F_loc, G_loc] — the expert dim N is already local
# (experts sharded over depth); row/col collectives are identical to the
# plain op.  Grad sync over (data,) happens at the grad_sync boundary (EP
# weights are only replicated over data), so no in-op reduction flag here.
# --------------------------------------------------------------------------

@partial(jax.custom_vjp, nondiff_argnums=(0,))
def tesseract_matmul_experts(ctx: ParallelContext, a, w):
    c, _ = _tess_exp_fwd(ctx, a, w)
    return c


def _tess_exp_fwd(ctx, a, w):
    ag = all_gather_inv(a, ctx.axis_col)      # [q, N, T, F_loc]
    wg = all_gather_inv(w, ctx.axis_row)      # [q, N, F_loc, G_loc]
    c = _einsum("tnef,tnfg->neg", ag, wg, ctx=ctx, out_dtype=a.dtype)
    res = (ag if ctx.cache_act_gather else a,
           wg if ctx.cache_weight_gather else w)
    return c, res


def _tess_exp_bwd(ctx, res, dc):
    ar, wr = res
    ag = ar if ctx.cache_act_gather else all_gather_inv(ar, ctx.axis_col)
    wg = wr if ctx.cache_weight_gather else all_gather_inv(wr, ctx.axis_row)
    da_part = _einsum("neg,tnfg->tnef", dc, wg, ctx=ctx, out_dtype=dc.dtype)
    da = lax.psum_scatter(da_part, ctx.axis_col, scatter_dimension=0, tiled=False)
    rs_dtype = jnp.bfloat16 if ctx.dgrad_rs_bf16 else jnp.float32
    dw_part = _einsum("tnef,neg->tnfg", ag, dc, ctx=ctx, out_dtype=rs_dtype)
    dw = lax.psum_scatter(dw_part, ctx.axis_row, scatter_dimension=0, tiled=False)
    return da, dw.astype(wr.dtype)


tesseract_matmul_experts.defvjp(_tess_exp_fwd, _tess_exp_bwd)


# --------------------------------------------------------------------------
# Transposed variant: C = A @ W^T (used by tied heads / down-projections that
# store weights in [out, in] layout).  W: [G_loc(row), F_loc(col)].
# --------------------------------------------------------------------------

@partial(jax.custom_vjp, nondiff_argnums=(0,))
def tesseract_matmul_wt(ctx: ParallelContext, a, w):
    c, _ = _tess_wt_fwd(ctx, a, w)
    return c


def _tess_wt_fwd(ctx, a, w):
    # C_{h,t} = sum_j A_{h,j} W_{t,j}^T : broadcast W within its column,
    # compute, then reduce partial C within the row (paper 3.1, C = A*B^T).
    wg = all_gather_inv(w, ctx.axis_row)            # [q(t), G_loc, F_loc]
    part = _einsum("...ef,tgf->t...eg", a, wg, ctx=ctx, out_dtype=a.dtype)
    c = lax.psum_scatter(part, ctx.axis_col, scatter_dimension=0, tiled=False)
    res = (a, wg if ctx.cache_weight_gather else w)
    return c, res


def _tess_wt_bwd(ctx, res, dc):
    a, wr = res
    wg = wr if ctx.cache_weight_gather else all_gather_inv(wr, ctx.axis_row)
    dcg = all_gather_inv(dc, ctx.axis_col)          # [q(t), ..., E, G_loc]
    da = _einsum("t...eg,tgf->...ef", dcg, wg, ctx=ctx, out_dtype=dc.dtype)
    rs_dtype = jnp.bfloat16 if ctx.dgrad_rs_bf16 else jnp.float32
    dw_part = _einsum("t...eg,...ef->tgf", dcg, a, ctx=ctx, out_dtype=rs_dtype)
    dw = lax.psum_scatter(dw_part, ctx.axis_row, scatter_dimension=0,
                          tiled=False)
    if ctx.reduce_dgrad_in_op:
        dw = lax.psum(dw, (ctx.axis_data, ctx.axis_depth))
    return da, dw.astype(wr.dtype)


tesseract_matmul_wt.defvjp(_tess_wt_fwd, _tess_wt_bwd)
