"""Logical mesh construction.

The production mesh (launch/mesh.py) is fixed by the cluster:
(16, 16) = ("data", "model") per pod, or (2, 16, 16) = ("pod", "data", "model").

The framework reshapes that device array into the logical mesh

    ("data", "depth", "row", "col")

where the contiguous "model" axis is factorized into (depth, row, col) —
Tesseract's [q, q, d] — and "pod" (if present) folds into "data".  Keeping the
model group contiguous maps (row, col) onto the innermost ICI links and
"depth" onto the outer ones, matching the paper's placement of the
least-communicating axis on the slowest links.
"""
from __future__ import annotations

import numpy as np
import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec

from .api import LOGICAL_AXES, LOGICAL_AXES_SEQ, ParallelContext


def _axis_types(n):
    try:
        return (jax.sharding.AxisType.Auto,) * n
    except AttributeError:  # older jax
        return None


def make_mesh(shape, axes):
    kw = {}
    at = _axis_types(len(axes))
    if at is not None:
        kw["axis_types"] = at
    return jax.make_mesh(tuple(shape), tuple(axes), **kw)


def logical_mesh(ctx: ParallelContext, devices=None) -> Mesh:
    """Build the ("data","depth","row","col") mesh from a flat device list.

    With ctx.seq > 1 the mesh gains a "seq" axis between "data" and the TP
    group — ("data","seq","depth","row","col") — so each sequence shard owns
    a contiguous [depth x row x col] sub-mesh and ring neighbors along "seq"
    are adjacent device blocks (DESIGN.md §15)."""
    if devices is None:
        devices = jax.devices()
    flat = np.asarray(devices).reshape(-1)
    need = ctx.data * ctx.seq * ctx.depth * ctx.rows * ctx.cols
    if flat.size != need:
        raise ValueError(
            f"need {need} devices for data={ctx.data} x seq={ctx.seq} x "
            f"[q={ctx.rows},{ctx.cols},d={ctx.depth}], got {flat.size}")
    if ctx.seq > 1:
        arr = flat.reshape(ctx.data, ctx.seq, ctx.depth, ctx.rows, ctx.cols)
        axes = LOGICAL_AXES_SEQ
    else:
        arr = flat.reshape(ctx.data, ctx.depth, ctx.rows, ctx.cols)
        axes = LOGICAL_AXES
    kw = {}
    at = _axis_types(len(axes))
    if at is not None:
        kw["axis_types"] = at
    return Mesh(arr, axes, **kw)


def pipeline_mesh(ctx: ParallelContext, pipe: int, devices=None, *,
                  keep_pipe_axis: bool = False) -> Mesh:
    """Build the ("pipe","data","depth","row","col") mesh: pipeline stages
    OUTERMOST (paper §3.4 composes PP outside the Tesseract TP group), each
    stage owning a full [data x q x q x d] sub-mesh on contiguous devices.

    pipe == 1 returns the plain 4-axis mesh (flat train step) unless
    ``keep_pipe_axis`` is set, which keeps the size-1 pipe axis so
    ``build_train_step`` runs the same 1F1B code path as a 1-stage
    baseline (the bit-parity oracle of the pipeline tests)."""
    if pipe < 1:
        raise ValueError(f"pipe must be >= 1, got {pipe}")
    if ctx.seq > 1 and (pipe > 1 or keep_pipe_axis):
        raise ValueError(
            "seq-axis sharding (ctx.seq > 1) does not compose with the "
            "pipeline mesh; use pipe=1 without keep_pipe_axis")
    if pipe == 1 and not keep_pipe_axis:
        return logical_mesh(ctx, devices)
    if devices is None:
        devices = jax.devices()
    flat = np.asarray(devices).reshape(-1)
    need = pipe * ctx.data * ctx.depth * ctx.rows * ctx.cols
    if flat.size != need:
        raise ValueError(
            f"need {need} devices for pipe={pipe} x data={ctx.data} x "
            f"[q={ctx.rows},{ctx.cols},d={ctx.depth}], got {flat.size}")
    arr = flat.reshape(pipe, ctx.data, ctx.depth, ctx.rows, ctx.cols)
    axes = ("pipe",) + LOGICAL_AXES
    kw = {}
    at = _axis_types(5)
    if at is not None:
        kw["axis_types"] = at
    return Mesh(arr, axes, **kw)


def logical_from_production(prod_mesh: Mesh, ctx: ParallelContext) -> Mesh:
    """Reshape the harness-defined production mesh into the logical mesh.

    The trailing mesh axis of the production mesh is "model" (size 16); it must
    equal depth*rows*cols.  Leading axes ("pod", "data") fold into "data".
    """
    devs = prod_mesh.devices
    model = devs.shape[-1]
    if model != ctx.tp:
        raise ValueError(f"model axis {model} != depth*rows*cols {ctx.tp}")
    data_total = int(np.prod(devs.shape[:-1]))
    if data_total != ctx.data:
        raise ValueError(f"data axes {devs.shape[:-1]} != ctx.data {ctx.data}")
    return logical_mesh(ctx, devs.reshape(-1))


def named_sharding(mesh: Mesh, *spec) -> NamedSharding:
    return NamedSharding(mesh, PartitionSpec(*spec))
