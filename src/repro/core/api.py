"""Parallel context: the single source of truth for how the model axis is factorized.

Tesseract (the paper) arranges the tensor-parallel group as a [q, q, d] grid
(`rows`, `cols`, `depth`).  The same abstraction covers the paper's baselines:

- ``tesseract``  : rows=cols=q, depth=d  (p = d*q^2)     [paper, 2.5-D]
- ``summa2d``    : depth=1               (Optimus, 2-D)
- ``megatron1d`` : rows=depth=1, cols=p  (Megatron-LM, 1-D)
- ``gspmd``      : same math as plain einsums + sharding constraints; XLA picks
                   the collective schedule (beyond-paper comparison mode).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass

AXIS_DATA = "data"
AXIS_DEPTH = "depth"
AXIS_ROW = "row"
AXIS_COL = "col"
AXIS_SEQ = "seq"
LOGICAL_AXES = (AXIS_DATA, AXIS_DEPTH, AXIS_ROW, AXIS_COL)
# Mesh axis order when the sequence axis is active (ctx.seq > 1): "seq" sits
# between "data" and the TP group so each seq shard owns a contiguous
# [depth, row, col] sub-mesh (ring neighbors are physical neighbors).
LOGICAL_AXES_SEQ = (AXIS_DATA, AXIS_SEQ, AXIS_DEPTH, AXIS_ROW, AXIS_COL)


@dataclass(frozen=True)
class ParallelContext:
    """Hashable parallelism descriptor (usable as a custom_vjp nondiff arg)."""

    mode: str = "tesseract"  # tesseract | summa2d | megatron1d | gspmd
    data: int = 1
    depth: int = 1
    rows: int = 1
    cols: int = 1
    # Sequence-axis shards (ring/striped flash attention, DESIGN.md §15).
    # seq > 1 adds a "seq" mesh axis between "data" and the TP group and
    # shards the time dimension of train activations; attention then streams
    # K/V around the seq ring instead of holding the full sequence.
    seq: int = 1
    # --- knobs (perf levers; defaults are the paper-faithful choices) ---
    # Cache the row-gathered weight blocks from fwd as residuals for bwd
    # ("store the parameter matrices inside each processor", paper 3.2.1).
    cache_weight_gather: bool = True
    # Cache the col-gathered activations (paper does not; costs memory).
    cache_act_gather: bool = False
    # Reduce dW over the depth axis inside each op (paper: "all_reduce after
    # the computation of B'") vs. deferring to one fused step-level reduction.
    reduce_dgrad_in_op: bool = True
    # Accumulate matmuls in fp32 regardless of compute dtype.
    accum_fp32: bool = True
    # Wire format of the dW reduce-scatter / depth all-reduce inside the
    # matmul bwd: True reduces in bf16 (halves those collective bytes; the
    # local partial products are still fp32-accumulated).  Beyond-paper lever.
    dgrad_rs_bf16: bool = False
    # SUMMA execution schedule of the Tesseract matmuls (DESIGN.md §2b):
    #   "fused" — one all_gather per operand, then a single local einsum
    #             (q× gathered-operand peak memory, zero overlap);
    #   "ring"  — Cannon-style skewed double ring over (row, col): one
    #             ppermute'd block per step contracted while the next block
    #             is in flight (O(2·block) peak, comm/compute overlap);
    #   "auto"  — per-op: ring for training/prefill-sized token blocks on
    #             q >= 4 grids, fused for decode-sized ones (a single-token
    #             step can't hide the skew/shift latency — DESIGN.md §2b/§7).
    matmul_schedule: str = "fused"
    # Attention data path (DESIGN.md §10): "jnp" = the pure-jnp streaming
    # reference, "pallas" = the fused flash / paged-decode kernels (interpret
    # mode off-TPU, so parity checks exercise the kernel math on CPU),
    # "auto" = kernels on TPU, jnp elsewhere (per-backend resolution,
    # kernels/ops.py::effective_attn_impl).
    attn_impl: str = "jnp"
    # Attention SCHEDULE (orthogonal to attn_impl, which picks the data path):
    #   "local"   — every device holds the full sequence (the pre-seq-axis
    #               behavior; required when seq == 1 ... unless "ring"/"auto"
    #               is requested for seq-sharded prefill, see below);
    #   "ring"    — contiguous seq shards; K/V stream around the seq ring via
    #               ppermute, merged with a stable logsumexp combine;
    #   "striped" — like ring, but tokens are round-robin striped across
    #               shards so causal work stays balanced per rank (train-only);
    #   "auto"    — striped for causal full-window training, ring otherwise.
    # With seq == 1, "ring"/"auto" additionally switch seq-sharded PREFILL
    # attention from gather-full-KV to a ring over (depth, row).
    attn_schedule: str = "local"

    # axis names (fixed; kept here so ops never hard-code strings)
    axis_data: str = AXIS_DATA
    axis_depth: str = AXIS_DEPTH
    axis_row: str = AXIS_ROW
    axis_col: str = AXIS_COL
    axis_seq: str = AXIS_SEQ

    def __post_init__(self):
        if self.mode in ("tesseract", "summa2d"):
            if self.rows != self.cols:
                raise ValueError(f"tesseract requires square q: {self.rows}x{self.cols}")
            if self.mode == "summa2d" and self.depth != 1:
                raise ValueError("summa2d is tesseract with depth=1")
        elif self.mode == "megatron1d":
            if self.rows != 1 or self.depth != 1:
                raise ValueError("megatron1d uses rows=depth=1, cols=p")
        elif self.mode != "gspmd":
            raise ValueError(f"unknown mode {self.mode!r}")
        if self.matmul_schedule not in ("fused", "ring", "auto"):
            raise ValueError(
                f"matmul_schedule must be 'fused', 'ring' or 'auto', "
                f"got {self.matmul_schedule!r}")
        if self.matmul_schedule in ("ring", "auto") and self.mode == "megatron1d":
            raise ValueError(
                f"matmul_schedule={self.matmul_schedule!r} is a SUMMA "
                "schedule selector; megatron1d has no [q, q] grid to ring over")
        if self.attn_impl not in ("jnp", "pallas", "auto"):
            raise ValueError(
                f"attn_impl must be 'jnp', 'pallas' or 'auto', "
                f"got {self.attn_impl!r}")
        if self.attn_schedule not in ("local", "ring", "striped", "auto"):
            raise ValueError(
                f"attn_schedule must be 'local', 'ring', 'striped' or "
                f"'auto', got {self.attn_schedule!r}")
        if self.seq < 1:
            raise ValueError(f"seq must be >= 1, got {self.seq}")
        if self.seq > 1:
            if self.mode not in ("tesseract", "summa2d"):
                raise ValueError(
                    f"seq={self.seq} sharding requires mode 'tesseract' or "
                    f"'summa2d', got {self.mode!r}")
            if self.attn_schedule == "local":
                raise ValueError(
                    "seq > 1 shards the sequence; attn_schedule must be "
                    "'ring', 'striped' or 'auto' (got 'local')")

    # ---- derived sizes ----
    @property
    def q(self) -> int:
        return self.cols

    @property
    def tp(self) -> int:
        """Size of the tensor-parallel group (the 'model' mesh axis)."""
        return self.depth * self.rows * self.cols

    @property
    def dq(self) -> int:
        """Number of activation row-blocks within the TP group (paper: d*q)."""
        return self.depth * self.rows

    @property
    def batch_shards(self) -> int:
        """How many ways the token dim is sharded in the canonical layout."""
        return self.data * self.depth * self.rows

    def replace(self, **kw) -> "ParallelContext":
        return dataclasses.replace(self, **kw)

    # ---- axis groups ----
    @property
    def mesh_axes(self) -> tuple:
        """Logical mesh axis names for this context (excl. any pipe axis)."""
        return LOGICAL_AXES_SEQ if self.seq > 1 else LOGICAL_AXES

    def train_attn_schedule(self) -> str:
        """Resolve attn_schedule for the seq-sharded TRAIN path.

        "auto" means striped: it balances causal work per rank at no extra
        comm.  Models with a sliding window must ask for "ring" explicitly
        (striping breaks window contiguity; ring_attention raises if asked).
        The resolution must not depend on the model so that token striping
        (runtime/steps.py), RoPE positions (core/ops.py) and the ring mask
        (core/ring_attention.py) always agree."""
        if self.seq == 1:
            return "local"
        return "striped" if self.attn_schedule == "auto" else self.attn_schedule

    @property
    def token_axes(self) -> tuple:
        """Mesh axes that shard the token (batch*seq) dim of activations."""
        if self.mode == "megatron1d":
            return (self.axis_data,)
        return (self.axis_data, self.axis_depth, self.axis_row)

    @property
    def seq_shard_axes(self) -> tuple:
        """Axes used for sequence sharding in small-batch (prefill) layouts."""
        if self.mode == "megatron1d":
            return (self.axis_col,)
        return (self.axis_depth, self.axis_row)

    @property
    def model_axes(self) -> tuple:
        return (self.axis_depth, self.axis_row, self.axis_col)
