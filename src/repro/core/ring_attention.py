"""Ring / striped flash attention over a mesh-axis ring (DESIGN.md §15).

Sequence-sharded attention: each device keeps its resident Q shard and the
K/V shards stream around the ring via the same Cannon-style double-buffered
``ppermute`` shift the SUMMA matmul schedule uses (core/summa.py).  Every
ring step is one flash call (kernels/flash_attention.py per-step entries)
whose ``(out, logsumexp)`` output is exactly the online-softmax carry the
ring needs: partial outputs merge with a numerically-stable pairwise
logsumexp combine

    lse  = logaddexp(lse_a, lse_b)
    out  = out_a * exp(lse_a - lse) + out_b * exp(lse_b - lse)

(fully-masked steps produce exact-zero out and a floored finite lse, so the
merge is NaN-free).  Backward is a full ``custom_vjp`` single-pass ring:
K/V re-stream exactly as forward while per-shard dK/dV partials ride
shift-and-add accumulator rings that deliver each shard's gradient back to
its home device — dQ accumulates locally, so lse/delta never leave the
device.  Per layer that is 2(n-1) K/V ppermutes per direction plus the
accumulator ring (2(n-1) in-loop shifts + 2 final deliveries).

Two sharding variants (``variant``):

- ``ring``:    contiguous shards; shard r holds global rows r*L..(r+1)*L-1.
  Causal masking is positional (traced relative positions, no static block
  skipping), so late ranks do ~n/2 more mask-visible work than early ones.
- ``striped``: round-robin shards; shard r holds global rows r + n*arange(L)
  (tokens pre-permuted by ``stripe_permutation``).  For q from shard a and
  kv from shard b the causal test  a + n*i >= b + n*k  collapses to the
  LOCAL triangle  i >= k + (1 if b > a else 0), so every (q-shard, kv-shard)
  step does the same (full lower triangle, +-one row) amount of work AND the
  static ``q_start=0`` block-skip bounds of the flash kernel stay valid —
  causal load balance without giving up block skipping.  Train-only
  (causal, no window).
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional

import numpy as np

import jax
import jax.numpy as jnp
from jax import lax

from .collectives import _ppermute_linear, axis_linear_index, axis_size
from .summa import _perm_shift
from ..kernels.flash_attention import (
    _M_FLOOR, NEG_INF, flash_dkv_step, flash_dq_step, flash_fwd_step)

_F32 = jnp.float32


# ---------------------------------------------------------------------------
# striped permutation (host-side; applied to tokens/labels before shard_map)
# ---------------------------------------------------------------------------

def stripe_permutation(T: int, n: int) -> np.ndarray:
    """Gather indices such that ``x[..., perm]`` round-robins T rows over n
    contiguous shards: permuted row r*L + i holds original row i*n + r, i.e.
    shard r (the r-th contiguous L-slice) holds global positions
    r + n*arange(L)."""
    if T % n:
        raise ValueError(f"stripe: T={T} not divisible by n={n}")
    return np.arange(T).reshape(T // n, n).T.reshape(-1)


def unstripe_permutation(T: int, n: int) -> np.ndarray:
    """Inverse of ``stripe_permutation``: x[perm][inv] == x."""
    if T % n:
        raise ValueError(f"unstripe: T={T} not divisible by n={n}")
    return np.arange(T).reshape(n, T // n).T.reshape(-1)


def shard_positions(L: int, n: int, rank, variant: str):
    """Global row positions of a shard ([L] int32; ``rank`` may be traced)."""
    ar = jnp.arange(L, dtype=jnp.int32)
    if variant == "striped":
        return rank + n * ar
    return rank * L + ar


# ---------------------------------------------------------------------------
# static spec (hashable: rides custom_vjp nondiff)
# ---------------------------------------------------------------------------

class RingSpec(NamedTuple):
    axes: tuple            # mesh axes forming the ring (lexicographic order)
    n: int                 # ring size == prod(axis sizes)
    variant: str           # "ring" | "striped"
    causal: bool
    window: int            # 0 = unbounded (striped requires 0)
    scale: Optional[float]
    impl: str              # "jnp" | "pallas"
    interpret: bool


def _step_mask_args(spec: RingSpec, L: int, Lk: int, rank, src):
    """(q_pos, q_start) for the step attending q@rank against kv@src."""
    ar = jnp.arange(L, dtype=jnp.int32)
    if spec.variant == "striped":
        # local triangle, strict when the kv shard is a later stripe; static
        # q_start=0 keeps the kernel's causal block-skip bounds valid
        return ar - (src > rank).astype(jnp.int32), 0
    # contiguous: traced relative positions (kv cols live at 0..Lk-1)
    return (rank - src) * Lk + ar, None


# ---------------------------------------------------------------------------
# per-step attention (pallas kernel or jnp reference), matching kernel
# conventions: fp32 scores, exact-zero masked rows, floored finite lse
# ---------------------------------------------------------------------------

def _jnp_sp(spec: RingSpec, q, k, q_pos):
    """Masked fp32 score matrix for one step ([B, Hq, L, Lk])."""
    g = q.shape[1] // k.shape[1]
    ke = jnp.repeat(k, g, axis=1) if g > 1 else k
    scale = spec.scale if spec.scale is not None else 1.0 / np.sqrt(q.shape[-1])
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(_F32), ke.astype(_F32)) * scale
    cols = jnp.arange(k.shape[2], dtype=jnp.int32)
    mask = jnp.ones((q.shape[2], k.shape[2]), dtype=bool)
    if spec.causal:
        mask = mask & (q_pos[:, None] >= cols[None, :])
    if spec.window > 0:
        mask = mask & (cols[None, :] > q_pos[:, None] - spec.window)
    return jnp.where(mask[None, None], s, NEG_INF)


def _step_fwd(spec: RingSpec, q, k, v, rank, src):
    q_pos, q_start = _step_mask_args(spec, q.shape[2], k.shape[2], rank, src)
    if spec.impl == "pallas":
        return flash_fwd_step(
            q, k, v, causal=spec.causal, local_window=spec.window,
            q_pos=q_pos, q_start=q_start, softmax_scale=spec.scale,
            interpret=spec.interpret)
    s = _jnp_sp(spec, q, k, q_pos)
    m = jnp.maximum(jnp.max(s, axis=-1), _M_FLOOR)
    p = jnp.exp(s - m[..., None])
    l = jnp.sum(p, axis=-1)
    ls = jnp.where(l == 0.0, 1.0, l)
    g = q.shape[1] // v.shape[1]
    ve = jnp.repeat(v, g, axis=1) if g > 1 else v
    o = jnp.einsum("bhqk,bhkd->bhqd", p, ve.astype(_F32)) / ls[..., None]
    return o.astype(q.dtype), m + jnp.log(ls)


def _step_bwd(spec: RingSpec, q, k, v, dout, lse, delta, rank, src):
    """(dq, dk, dv) contributions of one (q@rank, kv@src) step, given the
    GLOBAL merged lse and delta = sum(dout*out) — the standard flash bwd
    identities hold per KV partition with global normalizers."""
    q_pos, q_start = _step_mask_args(spec, q.shape[2], k.shape[2], rank, src)
    if spec.impl == "pallas":
        kw = dict(causal=spec.causal, local_window=spec.window, q_pos=q_pos,
                  q_start=q_start, softmax_scale=spec.scale,
                  interpret=spec.interpret)
        dq = flash_dq_step(q, k, v, dout, lse, delta, **kw)
        dk, dv = flash_dkv_step(q, k, v, dout, lse, delta, **kw)
        return dq, dk, dv
    scale = spec.scale if spec.scale is not None else 1.0 / np.sqrt(q.shape[-1])
    g = q.shape[1] // k.shape[1]
    ke = jnp.repeat(k, g, axis=1) if g > 1 else k
    ve = jnp.repeat(v, g, axis=1) if g > 1 else v
    s = _jnp_sp(spec, q, k, q_pos)
    p = jnp.exp(s - lse[..., None])            # globally-normalized probs
    do = dout.astype(_F32)
    dv = jnp.einsum("bhqk,bhqd->bhkd", p, do)
    dp = jnp.einsum("bhqd,bhkd->bhqk", do, ve.astype(_F32))
    ds = p * (dp - delta[..., None])
    dq = jnp.einsum("bhqk,bhkd->bhqd", ds, ke.astype(_F32)) * scale
    dk = jnp.einsum("bhqk,bhqd->bhkd", ds, q.astype(_F32)) * scale
    if g > 1:
        B, Hq, Lk, D = dk.shape[0], dk.shape[1], dk.shape[2], dk.shape[3]
        dk = dk.reshape(B, Hq // g, g, Lk, D).sum(axis=2)
        dv = dv.reshape(B, Hq // g, g, Lk, dv.shape[-1]).sum(axis=2)
    return dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype)


def _merge(o, lse, ot, lt):
    """Pairwise logsumexp combine of two normalized partials (fp32 o)."""
    lnew = jnp.logaddexp(lse, lt)
    o = (o * jnp.exp(lse - lnew)[..., None]
         + ot.astype(_F32) * jnp.exp(lt - lnew)[..., None])
    return o, lnew


# ---------------------------------------------------------------------------
# the ring custom_vjp
# ---------------------------------------------------------------------------

def _shift(spec: RingSpec, x):
    return _ppermute_linear(x, spec.axes, _perm_shift(spec.n))


def _ring_fwd_impl(spec: RingSpec, q, k, v):
    rank = axis_linear_index(spec.axes)
    n = spec.n
    o = lse = None
    kc, vc = k, v
    for t in range(n):
        if t < n - 1:               # issue the shift before the compute so
            kn = _shift(spec, kc)   # the next shard is in flight while this
            vn = _shift(spec, vc)   # step's flash call runs (summa idiom)
        ot, lt = _step_fwd(spec, q, kc, vc, rank, (rank + t) % n)
        if t == 0:
            o, lse = ot.astype(_F32), lt
        else:
            o, lse = _merge(o, lse, ot, lt)
        if t < n - 1:
            kc, vc = kn, vn
    return o.astype(q.dtype), lse


@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _ring(spec: RingSpec, q, k, v):
    out, _ = _ring_fwd_impl(spec, q, k, v)
    return out


def _ring_vjp_fwd(spec, q, k, v):
    out, lse = _ring_fwd_impl(spec, q, k, v)
    return out, (q, k, v, out, lse)


def _ring_vjp_bwd(spec, res, dout):
    q, k, v, out, lse = res
    n, rank = spec.n, axis_linear_index(spec.axes)
    delta = jnp.sum(dout.astype(_F32) * out.astype(_F32), axis=-1)
    dq = jnp.zeros(q.shape, _F32)
    kc, vc = k, v
    dka = dva = None
    for t in range(n):
        if t < n - 1:
            kn, vn = _shift(spec, kc), _shift(spec, vc)
        src = (rank + t) % n
        dqt, dkt, dvt = _step_bwd(spec, q, kc, vc, dout, lse, delta,
                                  rank, src)
        dq = dq + dqt.astype(_F32)
        if t == 0:
            dka, dva = dkt.astype(_F32), dvt.astype(_F32)
        else:
            # the accumulator ring travels WITH the K/V shards: after this
            # shift the partial for shard s sits wherever shard s's K/V just
            # left, so each device adds its own contribution to s in turn
            dka = _shift(spec, dka) + dkt.astype(_F32)
            dva = _shift(spec, dva) + dvt.astype(_F32)
        if t < n - 1:
            kc, vc = kn, vn
    if n > 1:                       # one last hop delivers shard r's dK/dV
        dka, dva = _shift(spec, dka), _shift(spec, dva)
    return dq.astype(q.dtype), dka.astype(k.dtype), dva.astype(v.dtype)


_ring.defvjp(_ring_vjp_fwd, _ring_vjp_bwd)


# ---------------------------------------------------------------------------
# public entry
# ---------------------------------------------------------------------------

def ring_attention(q, k, v, *, axes, variant: str = "ring", causal: bool = True,
                   local_window: int = 0, softmax_scale=None,
                   impl: str = "jnp", interpret: bool = True):
    """Seq-sharded attention over the ring formed by mesh ``axes``.

    q: [B, Hq, L, D], k: [B, Hkv, Lk, D], v: [B, Hkv, Lk, Dv] — the LOCAL
    shards, kernel layout, inside shard_map.  Shard r holds global rows
    r*L..(r+1)*L-1 (``variant="ring"``) or r + n*arange(L) (``"striped"``,
    tokens pre-permuted with ``stripe_permutation``).  Returns the local
    [B, Hq, L, Dv] output shard; differentiable (full custom_vjp).
    """
    if variant not in ("ring", "striped"):
        raise ValueError(f"ring_attention variant must be 'ring' or "
                         f"'striped', got {variant!r}")
    if variant == "striped" and (not causal or local_window > 0):
        raise ValueError("striped ring attention requires causal=True and "
                         "local_window=0 (window breaks the stripe balance)")
    if q.shape[2] != k.shape[2]:
        raise ValueError(f"ring_attention needs equal q/kv shard lengths, "
                         f"got {q.shape[2]} vs {k.shape[2]}")
    n = axis_size(axes)
    spec = RingSpec(axes=tuple(axes), n=int(n), variant=variant,
                    causal=bool(causal), window=int(local_window),
                    scale=(None if softmax_scale is None
                           else float(softmax_scale)),
                    impl=impl, interpret=bool(interpret))
    return _ring(spec, q, k, v)


# ---------------------------------------------------------------------------
# comm model hooks (roofline/analysis.py and analysis/shardcheck.py gate
# against these EXACT counts/bytes)
# ---------------------------------------------------------------------------

def ring_ppermute_counts(n: int, *, train: bool = True,
                         remat_replay: bool = True) -> dict:
    """ppermute issue counts per attention call (per layer, per device).

    fwd: (n-1) shifts of {K, V}.  bwd: the same K/V re-stream, plus the
    dK/dV accumulator rings — (n-1) in-loop shifts and 1 final delivery
    each — plus (with remat) the fwd replay."""
    fwd = 2 * (n - 1)
    if not train:
        return dict(fwd=fwd, bwd=0, total=fwd)
    bwd = 2 * (n - 1) + 2 * (n - 1) + (2 if n > 1 else 0)
    if remat_replay:
        bwd += fwd
    return dict(fwd=fwd, bwd=bwd, total=fwd + bwd)


def ring_ppermute_bytes(n: int, *, kv_block_bytes: int, acc_block_bytes: int,
                        train: bool = True, remat_replay: bool = True) -> dict:
    """Wire bytes per attention call (per layer, per device), matching the
    collective-IR convention that a ppermute moves its full operand.

    ``kv_block_bytes``: bytes of ONE K (== one V) local shard in the compute
    dtype; ``acc_block_bytes``: bytes of one fp32 dK (== dV) accumulator."""
    fwd = 2 * (n - 1) * kv_block_bytes
    if not train:
        return dict(fwd=fwd, bwd=0, total=fwd)
    bwd = 2 * (n - 1) * kv_block_bytes
    bwd += (2 * (n - 1) + (2 if n > 1 else 0)) * acc_block_bytes
    if remat_replay:
        bwd += fwd
    return dict(fwd=fwd, bwd=bwd, total=fwd + bwd)
