"""GSPMD comparison mode: the same dense-LM math written as plain global
einsums + with_sharding_constraint, letting XLA's auto-partitioner pick the
collective schedule — the beyond-paper control for the explicit Tesseract
shard_map implementation (DESIGN.md §2, EXPERIMENTS.md §Perf appendix).

Dense decoder family only (the comparison target); same param pytree and
partition specs as the shard_map path, so the two lower from identical
inputs.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import NamedSharding, PartitionSpec as P

from ..models import common as cm
from ..optim import adamw

ACT = P(("data", "depth", "row"), None, "col")


def _wsc(x, mesh, spec):
    return lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def build_gspmd_train_step(model, mesh, shape):
    """Train step for a DenseLM with GSPMD auto-partitioning.

    Returns an object with .fn and .abstract_inputs like StepBundle.
    """
    from ..runtime.steps import StepBundle, batch_abstract, make_plan
    from ..core.ops import make_ops

    cfg, run, ctx = model.cfg, model.run, model.ctx
    plan = make_plan(ctx, shape)
    ops = make_ops(ctx, plan)
    specs = model.specs(ops)
    cdt = model.cdt
    Hp, D = model.Hp, model.D
    kvh = cfg.num_kv_heads

    def block(p, x):
        h = rms(x, p["ln1"])
        q = jnp.einsum("bsh,hd->bsd", h, p["wq"].astype(cdt))
        k = jnp.einsum("bsh,hd->bsd", h, p["wk"].astype(cdt))
        v = jnp.einsum("bsh,hd->bsd", h, p["wv"].astype(cdt))
        B, S = x.shape[:2]
        q = _wsc(q.reshape(B, S, Hp, D), mesh,
                 P(("data", "depth", "row"), None, "col", None))
        k = k.reshape(B, S, kvh, D)
        v = v.reshape(B, S, kvh, D)
        pos = jnp.arange(S)
        if cfg.use_rope:
            q = cm.apply_rope(q, pos, cfg.rope_theta)
            k = cm.apply_rope(k, pos, cfg.rope_theta)
        out = cm.blockwise_attention(q, k, v, q_pos=pos, kv_pos=pos,
                                     causal=True, q_chunk=run.q_chunk,
                                     kv_chunk=run.kv_chunk)
        out = out.reshape(B, S, Hp * D)
        x = x + jnp.einsum("bsd,dh->bsh", out, p["wo"].astype(cdt))
        x = _wsc(x, mesh, ACT)
        h2 = rms(x, p["ln2"])
        g = jax.nn.silu(jnp.einsum("bsh,hf->bsf", h2, p["w_gate"].astype(cdt)))
        u = jnp.einsum("bsh,hf->bsf", h2, p["w_up"].astype(cdt))
        x = x + jnp.einsum("bsf,fh->bsh", g * u, p["w_down"].astype(cdt))
        return _wsc(x, mesh, ACT)

    def rms(x, s):
        xf = x.astype(jnp.float32)
        inv = lax.rsqrt(jnp.mean(xf * xf, -1, keepdims=True) + cfg.norm_eps)
        return (xf * inv * (1 + s.astype(jnp.float32))).astype(x.dtype)

    def loss_fn(params, batch):
        tok = batch["tokens"]
        x = jnp.take(params["embed"].astype(cdt), tok, axis=0)
        x = _wsc(x, mesh, ACT)
        body = jax.checkpoint(lambda xx, bp: (block(bp, xx), None))
        x, _ = lax.scan(body, x, params["blocks"])
        x = rms(x, params["ln_f"])
        # chunked CE (global math; GSPMD shards the vocab reduction)
        B, S = tok.shape
        E = B * S
        c = max(1, min(run.loss_chunk * 8, E))
        while E % c:
            c -= 1
        xf = x.reshape(E // c, c, -1)
        lab = jnp.roll(tok, -1, 1).reshape(E // c, c) if "labels" not in batch \
            else batch["labels"].reshape(E // c, c)
        head = params["head"].astype(cdt)

        @jax.checkpoint
        def chunk(hw, xs):
            xc, lc = xs
            logits = jnp.einsum("ch,vh->cv", xc, hw,
                                preferred_element_type=jnp.float32)
            vmask = jnp.arange(logits.shape[-1]) < cfg.vocab_size
            logits = jnp.where(vmask[None], logits, -jnp.inf)
            lse = jax.nn.logsumexp(logits, axis=-1)
            ll = jnp.take_along_axis(logits, lc[:, None], 1)[:, 0]
            return jnp.sum(lse - ll)

        def body2(acc, xs):
            return acc + chunk(head, xs), None

        tot, _ = lax.scan(body2, jnp.float32(0), (xf, lab))
        return tot / E

    def step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        lr = adamw.cosine_lr(opt_state["step"], base_lr=run.lr,
                             warmup=100, total=10000)
        new_p, new_s = adamw.adamw_update(params, grads, opt_state, lr=lr,
                                          weight_decay=run.weight_decay)
        return new_p, new_s, {"loss": loss}

    shardings = jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                             is_leaf=lambda x: isinstance(x, P))
    opt_master = run.param_dtype != "float32"
    opt_sh = {"m": shardings, "v": shardings,
              "step": NamedSharding(mesh, P()),
              **({"master": shardings} if opt_master else {})}
    batch_sds, batch_specs = batch_abstract(ops, shape, ctx, model)
    batch_sh = jax.tree.map(lambda s: NamedSharding(mesh, s), batch_specs,
                            is_leaf=lambda x: isinstance(x, P))
    fn = jax.jit(step, in_shardings=(shardings, opt_sh, batch_sh),
                 donate_argnums=(0, 1))
    abs_params = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    abs_opt = jax.eval_shape(partial(adamw.adamw_init, master=opt_master),
                             abs_params)
    return StepBundle(fn=fn, abstract_inputs=(abs_params, abs_opt, batch_sds),
                      in_shardings=(shardings, opt_sh, batch_sh),
                      out_shardings=None, mesh=mesh, plan=plan)
