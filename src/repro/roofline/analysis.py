"""Three-term roofline model from a compiled dry-run artifact.

Target hardware (TPU v5e-class, per harness):
    peak bf16 compute : 197 TFLOP/s per chip
    HBM bandwidth     : 819 GB/s per chip
    ICI link bandwidth: ~50 GB/s per link

    compute_term    = HLO_FLOPs_per_device / peak
    memory_term     = HLO_bytes_per_device / HBM_bw
    collective_term = collective_wire_bytes_per_device / link_bw

cost_analysis() of the partitioned executable is per-device, so no division
by chip count is needed.  MODEL_FLOPS = 6*N*D (dense) or 6*N_active*D (MoE)
is divided by chips for the per-device comparison.
"""
from __future__ import annotations

from dataclasses import dataclass, asdict

PEAK_FLOPS = 197e12
HBM_BW = 819e9
LINK_BW = 50e9


@dataclass
class Roofline:
    arch: str
    shape: str
    mode: str
    mesh: str
    chips: int
    hlo_flops: float            # per device
    hlo_bytes: float            # per device
    coll_operand_bytes: float   # per device
    coll_wire_bytes: float      # per device
    model_flops_total: float    # 6*N*D for the step
    per_device_bytes: int       # argument+temp memory (memory_analysis)
    compute_term_s: float = 0.0
    memory_term_s: float = 0.0
    collective_term_s: float = 0.0
    dominant: str = ""
    useful_flops_frac: float = 0.0
    collectives: dict = None
    # Overlap-aware view (DESIGN.md §2b): with a pipelined collective
    # schedule ("ring"), per-step wire time hides behind the MXU and only
    # max(0, comm - compute) is exposed; "fused" exposes every wire byte.
    matmul_schedule: str = "fused"
    exposed_collective_term_s: float = 0.0

    def finalize(self):
        self.compute_term_s = self.hlo_flops / PEAK_FLOPS
        self.memory_term_s = self.hlo_bytes / HBM_BW
        self.collective_term_s = self.coll_wire_bytes / LINK_BW
        self.exposed_collective_term_s = exposed_collective_term(
            self.compute_term_s, self.collective_term_s,
            self.matmul_schedule)
        terms = {"compute": self.compute_term_s,
                 "memory": self.memory_term_s,
                 "collective": self.collective_term_s}
        self.dominant = max(terms, key=terms.get)
        total_hlo = self.hlo_flops * self.chips
        self.useful_flops_frac = (self.model_flops_total / total_hlo
                                  if total_hlo else 0.0)
        return self

    def row(self):
        return (f"| {self.arch} | {self.shape} | {self.mode} | {self.mesh} | "
                f"{self.compute_term_s*1e3:.2f} | {self.memory_term_s*1e3:.2f} | "
                f"{self.collective_term_s*1e3:.2f} | {self.dominant} | "
                f"{self.useful_flops_frac:.2f} | "
                f"{self.per_device_bytes/2**30:.1f} |")

    def to_dict(self):
        return asdict(self)


def wire_time_s(wire_bytes: float, *, link_bw: float = LINK_BW) -> float:
    """Ring-model seconds on the interconnect for a per-device byte count.

    The bridge between repro.analysis.shardcheck's extracted wire bytes and
    this module's collective_term_s: the analyzer records each swept entry's
    jaxpr-level bytes through THIS conversion so the SHARDCHECK.json
    baseline and the roofline tables share one clock."""
    return wire_bytes / link_bw


def exposed_collective_term(compute_s: float, collective_s: float,
                            schedule: str = "fused") -> float:
    """Exposed (non-overlapped) collective time for a step.

    "fused": the gathers serialize with the einsums — all wire time is
    exposed.  "ring": the per-step permutes pipeline against the MXU, so
    steady-state exposure is max(0, comm - compute); the residual pipeline
    fill is second-order and absorbed into the max() bound."""
    if schedule == "ring":
        return max(0.0, collective_s - compute_s)
    return collective_s


def optimizer_state_bytes(n_params: int, *, tp: int = 1, data: int = 1,
                          depth: int = 1, zero_stage: int = 0,
                          master: bool = True, moments: int = 2) -> float:
    """Eq. 8 extended with the optimizer-state term (DESIGN.md §9).

    The paper's per-device memory (ab + bcd + ac)/p counts activations,
    weights and outputs only; a training step also carries fp32 AdamW
    moments (and the fp32 master copy under mixed precision), which follow
    the WEIGHT layout: sharded 1/tp over the TP group but replicated over
    the ``data`` (and, for most leaves, ``depth``) replica axes.

        M_opt = (moments + master) * 4 bytes * N / tp            (ZeRO-0)
        M_opt = (moments + master) * 4 bytes * N / (tp*data*depth)  (ZeRO-1)

    ZeRO-1 partitions each leaf's state over the axes it is REPLICATED on;
    depth-sharded leaves (head, experts) only divide by ``data``, so the
    dp-factor is exact on depth=1 meshes and a close upper bound otherwise
    (flat-index padding adds <= data*depth*4 bytes per leaf).
    """
    words = moments + (1 if master else 0)
    per_device = words * 4.0 * n_params / tp
    if zero_stage >= 1:
        per_device /= (data * depth)
    return per_device


def eq8_train_state_bytes(a: int, b: int, c: int, *, q: int, d: int,
                          data: int = 1, zero_stage: int = 0,
                          master: bool = True,
                          param_bytes: int = 4) -> dict:
    """Per-device Eq. 8 memory terms for one [a,b]x[b,c] layer, extended
    with gradient + optimizer-state terms: the memory model backing the
    ``zero1`` benchmark case and tests/test_memory_model.py."""
    p = q * q * d
    act = a * b / p * param_bytes
    weight = b * c * d / p * param_bytes
    out = a * c / p * param_bytes
    n_w = b * c  # weight elements of the layer (d-fold replication is the
    #              paper's own Eq. 8 term; grads/opt state follow it)
    grad = weight
    opt = optimizer_state_bytes(n_w, tp=p // d, data=data, depth=d,
                                zero_stage=zero_stage, master=master)
    return {"activations": act, "weights": weight, "outputs": out,
            "grads": grad, "opt_state": opt,
            "total": act + weight + out + grad + opt}


def flash_attention_traffic(B: int, H: int, Tq: int, Tk: int, D: int, *,
                            bq: int = 256, bk: int = 256,
                            causal: bool = True, itemsize: int = 2) -> dict:
    """Per-device HBM bytes of one attention forward (DESIGN.md §10).

    ``materialized``: the unfused reference writes the [Tq, Tk] score matrix
    and reads it back twice (softmax pass + PV contraction) on top of the
    q/k/v/out streams.  ``flash``: q and out move once; each of the nq query
    blocks re-streams K and V (the causal walk halves that), and the scores
    never leave VMEM.  The ratio is the kernel's roofline win whenever
    Tk * itemsize >> D — i.e. every long-context shape.
    """
    nq = max(1, -(-Tq // bq))
    qo = B * H * Tq * D * itemsize * 2                 # q read + out write
    kv = B * H * Tk * D * itemsize * 2                 # one full K+V stream
    walk = 0.5 if (causal and Tq == Tk) else 1.0       # block-skipped walk
    scores = B * H * Tq * Tk * itemsize
    return {
        "materialized_bytes": qo + kv + 3 * scores,    # write + 2 reads
        "flash_bytes": qo + kv * nq * walk,
        "n_q_blocks": nq,
    }


def ring_attention_traffic(B: int, Hq: int, Hkv: int, T: int, D: int, *,
                           seq: int, num_layers: int = 1,
                           compute_itemsize: int = 2,
                           train: bool = True, remat_replay: bool = True,
                           causal: bool = True,
                           link_bw: float = LINK_BW) -> dict:
    """Per-device seq-ring comm model for ring/striped flash attention
    (DESIGN.md §15), byte-consistent with the traced ppermutes.

    ``B`` is the LOCAL batch rows on one seq shard, ``T`` the GLOBAL
    sequence (each shard holds L = T/seq positions), ``Hkv`` the local KV
    heads after col sharding.  Wire bytes use the collective-IR convention
    (a ppermute moves its full operand): K/V blocks travel in the compute
    dtype, the dK/dV accumulator rings in fp32 — the exact per-call counts
    live in core/ring_attention.py::ring_ppermute_{counts,bytes} and the
    shardcheck sweep pins the traced jaxpr to them.

    Overlap: each fwd ring step shifts the next {K, V} block while the
    flash kernel contracts the resident one, so only
    max(0, step_comm - step_compute) is exposed per step (the ring-matmul
    argument of exposed_collective_term).  Striped placement keeps
    per-step causal work equal across ranks, so the per-step compute used
    here is the mean — for contiguous ring shards it is the max rank's
    and the exposure estimate is optimistic by up to 2x.
    """
    if T % seq:
        raise ValueError(f"T={T} not divisible by seq={seq}")
    from ..core.ring_attention import (ring_ppermute_bytes,
                                       ring_ppermute_counts)
    L = T // seq
    kv_block = B * Hkv * L * D * compute_itemsize
    acc_block = B * Hkv * L * D * 4              # fp32 accumulator ring
    counts = ring_ppermute_counts(seq, train=train,
                                  remat_replay=remat_replay)
    per_layer = ring_ppermute_bytes(seq, kv_block_bytes=kv_block,
                                    acc_block_bytes=acc_block, train=train,
                                    remat_replay=remat_replay)
    # one ring step: flash over the resident [L, L] tile (QK^T + PV fwd
    # pairs, x2.5 for the bwd's dQ/dK/dV when counting a train step)
    step_flops = 4.0 * B * Hq * L * L * D * (0.5 if causal else 1.0)
    step_comm_s = 2 * kv_block / link_bw
    step_compute_s = step_flops / PEAK_FLOPS
    exposed_fwd = max(0.0, step_comm_s - step_compute_s) * max(seq - 1, 0)
    return {
        "seq": seq, "shard_len": L,
        "kv_block_bytes": kv_block, "acc_block_bytes": acc_block,
        "ppermute_counts": counts,
        "per_layer_bytes": per_layer,
        "wire_bytes": num_layers * per_layer["total"],
        "wire_bytes_fwd": num_layers * per_layer["fwd"],
        "step_comm_s": step_comm_s, "step_compute_s": step_compute_s,
        "exposed_comm_s_fwd_per_layer": exposed_fwd,
        "comm_hidden": step_comm_s <= step_compute_s,
    }


def paged_decode_traffic(n_slots: int, Hkv: int, D: int, *,
                         pool_positions: int, live_positions: int,
                         block_size: int, itemsize: int = 2) -> dict:
    """Per-step HBM bytes of serve decode attention (DESIGN.md §10).

    ``gather``: paged_gather materializes each slot's full table view
    (pool_positions per slot, live or not) — read the pool, write the
    gathered copy, read it back for the attention contractions.
    ``kernel``: the block-table walk reads only the live pages, once.
    Modeled decode tok/s on the target (HBM_BW) follow from the bytes; the
    BENCH_attention harness records both plus indicative CPU wall-clock.
    """
    kv = 2
    full = n_slots * pool_positions * Hkv * D * itemsize * kv
    live_pages = -(-max(live_positions, 1) // block_size)
    live = n_slots * live_pages * block_size * Hkv * D * itemsize * kv
    gather_bytes = 3 * full
    kernel_bytes = live
    return {
        "gather_bytes": gather_bytes,
        "kernel_bytes": kernel_bytes,
        "gather_tok_s": n_slots / (gather_bytes / HBM_BW),
        "kernel_tok_s": n_slots / (kernel_bytes / HBM_BW),
        "kernel_wins": kernel_bytes < gather_bytes,
    }


def spec_decode_speedup(acceptance: float, k: int, *,
                        draft_cost_ratio: float = 0.0,
                        verify_overhead: float = 1.0) -> dict:
    """Analytic speculative-decoding speedup (DESIGN.md §14).

    With i.i.d. per-token acceptance probability ``acceptance`` and ``k``
    proposals per round, the expected committed tokens per verify round is
    the truncated geometric sum E = (1 - a^(k+1)) / (1 - a) — every prefix
    of accepted proposals plus the correction/bonus token the verify round
    always commits.  Decode is memory-bound on the weight stream, so a
    (k+1)-wide verify costs about one plain decode step (``verify_overhead``
    scales it for the extra KV/activation traffic); a draft step costs
    ``draft_cost_ratio`` of a target step (0 = free, the n-gram proposer).
    Speedup over plain decode = E / (verify_overhead + k * ratio).
    """
    if not 0.0 <= acceptance <= 1.0:
        raise ValueError(f"acceptance {acceptance} outside [0, 1]")
    if k < 0:
        raise ValueError(f"k {k} < 0")
    a = min(acceptance, 1.0 - 1e-12)
    e_tokens = (1.0 - a ** (k + 1)) / (1.0 - a)
    cost = verify_overhead + k * draft_cost_ratio
    return {
        "expected_tokens_per_round": e_tokens,
        "round_cost_decode_steps": cost,
        "speedup": e_tokens / cost,
    }


def model_flops(cfg, shape) -> float:
    """6*N*D training flops (fwd+bwd) or 2*N*D serving flops."""
    n_active = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens
    # decode: one token per sequence (attention over the cache excluded from
    # the 2*N*D parametric-flops convention; noted in EXPERIMENTS.md)
    tokens = shape.global_batch
    return 2.0 * n_active * tokens
