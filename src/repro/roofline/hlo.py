"""Structural analysis of compiled (SPMD-partitioned) HLO text.

Why not ``compiled.cost_analysis()``: XLA's cost analysis visits a while-loop
body ONCE, so anything under ``lax.scan`` (the layer stack, CE chunks,
attention KV blocks) is undercounted by its trip count.  The same applies to
collective bytes.  This module parses the HLO text into computations, builds
a per-computation symbol table (operands are %name references), finds
while-loop trip counts from their condition computations, and aggregates

    flops            — 2 * prod(out_dims) * prod(lhs contracting dims) per dot
    collective bytes — per collective kind: operand bytes + ring-model wire
                       bytes using the parsed replica-group size
    hbm bytes        — outputs + operands of top-level ops (fusion interiors
                       not double-counted)

multiplying every called computation by its trip count.  Elementwise flops
are ignored (these workloads are dot-dominated; noted in EXPERIMENTS.md).
"""
from __future__ import annotations

import re
from collections import defaultdict
from dataclasses import dataclass, field

DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s4": 1, "u4": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "token": 0,
}

COLLECTIVE_KINDS = ("all-gather", "all-reduce", "reduce-scatter",
                    "all-to-all", "collective-permute")

_SHAPE = re.compile(r"\b(\w+?)\[([\d,]*)\]")
_COMP_HEADER = re.compile(r"^(ENTRY\s+)?%?([\w.\-_]+)\s*\(.*\{\s*$")
# output type: tuple "(...)" (may contain /*index=N*/ comments; no nested
# parens in HLO types) or a scalar/array type with optional layout braces
_INSTR = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-_]+)\s*=\s*"
                    r"(\([^)]*\)|[\w\[\],{}]+)\s+([a-z][\w\-]*)\(")
_OPERANDS = re.compile(r"%([\w.\-_]+)")
_CONTRACT = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_CONST = re.compile(r"constant\((\d+)\)")
_GROUPS_BRACKET = re.compile(r"replica_groups=\{?\[(\d+),(\d+)\]")
_GROUPS_LIST = re.compile(r"replica_groups=\{\{([\d,]+)\}")


def _shapes_in(s: str):
    out = []
    for m in _SHAPE.finditer(s):
        dt, dims = m.group(1), m.group(2)
        if dt not in DTYPE_BYTES:
            continue
        d = [int(x) for x in dims.split(",")] if dims else []
        out.append((dt, d))
    return out


def _bytes_of(shapes) -> int:
    total = 0
    for dt, dims in shapes:
        n = 1
        for d in dims:
            n *= d
        total += n * DTYPE_BYTES[dt]
    return total


@dataclass
class CompStats:
    flops: float = 0.0
    hbm_bytes: float = 0.0
    dot_bytes: float = 0.0      # operand+output traffic of dot ops only
    coll: dict = field(default_factory=dict)
    calls: list = field(default_factory=list)   # (callee, cond_or_None, kind)
    constants: list = field(default_factory=list)


def _coll_add(coll, kind, count=0, ob=0.0, wb=0.0, mult=1.0):
    c = coll.setdefault(kind, dict(count=0.0, operand_bytes=0.0,
                                   wire_bytes=0.0))
    c["count"] += mult * count
    c["operand_bytes"] += mult * ob
    c["wire_bytes"] += mult * wb


def split_computations(text: str):
    comps = {}
    name, buf = None, []
    for line in text.splitlines():
        if name is None:
            st = line.strip()
            if st.endswith("{") and ("->" in st or st.startswith("ENTRY")):
                m = _COMP_HEADER.match(st)
                if m:
                    name = m.group(2)
                    buf = []
            continue
        if line.strip() == "}":
            comps[name] = buf
            name = None
        else:
            buf.append(line)
    return comps


def _group_size(line: str, default: int) -> int:
    m = _GROUPS_BRACKET.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_LIST.search(line)
    if m:
        return len(m.group(1).split(","))
    return default


def _analyze_comp(lines, n_devices):
    st = CompStats()
    symtab = {}
    # producing-op kind + pre-convert source shapes: XLA:CPU float
    # normalization promotes bf16 collectives to f32 via converts; on TPU
    # the wire stays bf16, so collectives resolve operands THROUGH converts
    # (one level) to reflect the intended wire dtype.
    conv_src = {}
    for line in lines:
        mo = _INSTR.match(line)
        if not mo:
            for c in _CONST.finditer(line):
                st.constants.append(int(c.group(1)))
            continue
        name, out_s, op = mo.group(1), mo.group(2), mo.group(3)
        out_shapes = _shapes_in(out_s)
        symtab[name] = out_shapes
        rest = line[mo.end():]
        # operand name references (stop before attribute section heuristics)
        opnames = []
        depth = 1
        arglist = []
        for ch_i, ch in enumerate(rest):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    arglist = rest[:ch_i]
                    break
        else:
            arglist = rest
        opnames = _OPERANDS.findall(arglist)
        operand_shapes = []
        for on in opnames:
            operand_shapes.extend(symtab.get(on, []))
        # typed operands (parameters appear typed inline in some dumps)
        if not operand_shapes:
            operand_shapes = _shapes_in(arglist)
        if op == "convert" or (op == "fusion" and "convert" in line):
            src = []
            for on in opnames:
                src.extend(conv_src.get(on, symtab.get(on, [])))
            if src:
                conv_src[name] = src

        for c in _CONST.finditer(line):
            st.constants.append(int(c.group(1)))

        if op == "dot":
            cm = _CONTRACT.search(line)
            lhs = symtab.get(opnames[0], None) if opnames else None
            if lhs is None:
                ls = _shapes_in(arglist)
                lhs = [ls[0]] if ls else None
            if out_shapes and lhs and cm is not None:
                out_elems = 1
                for d in out_shapes[0][1]:
                    out_elems *= d
                k = 1
                for ci in (int(x) for x in cm.group(1).split(",") if x):
                    dims = lhs[0][1]
                    if ci < len(dims):
                        k *= dims[ci]
                st.flops += 2.0 * out_elems * k
            db = _bytes_of(out_shapes) + _bytes_of(operand_shapes)
            st.hbm_bytes += db
            st.dot_bytes += db
            continue

        kind = op.replace("-start", "").replace("-done", "")
        if kind in COLLECTIVE_KINDS and not op.endswith("-done"):
            # wire-dtype intent: resolve operands through converts
            wire_shapes = []
            for on in opnames:
                wire_shapes.extend(conv_src.get(on, symtab.get(on, [])))
            if not wire_shapes:
                wire_shapes = operand_shapes
            ob = min(_bytes_of(operand_shapes), _bytes_of(wire_shapes)) \
                if wire_shapes else _bytes_of(operand_shapes)
            out_b = _bytes_of(out_shapes)
            n = _group_size(line, n_devices)
            frac = (n - 1) / n if n > 1 else 0.0
            if kind == "all-gather":
                wire = out_b * frac
            elif kind == "all-reduce":
                wire = 2 * ob * frac
            elif kind in ("reduce-scatter", "all-to-all"):
                wire = ob * frac
            else:
                wire = ob
            _coll_add(st.coll, kind, 1, ob, wire)
            st.hbm_bytes += out_b + ob
            continue

        if op == "while":
            bm = re.search(r"body=%?([\w.\-_]+)", line)
            cm2 = re.search(r"condition=%?([\w.\-_]+)", line)
            st.calls.append((bm.group(1) if bm else None,
                             cm2.group(1) if cm2 else None, "while"))
            continue
        called = re.findall(r"(?:calls=|to_apply=)%?([\w.\-_]+)", line)
        for c in called:
            st.calls.append((c, None, "call"))
        if op == "conditional":
            for c in re.findall(
                    r"(?:true_computation=|false_computation=|branch_computations=\{)%?([\w.\-_,%\s]+)",
                    line):
                for cc in re.split(r"[,\s]+", c):
                    cc = cc.strip().lstrip("%")
                    if cc:
                        st.calls.append((cc, None, "call"))
        # top-level op HBM traffic (fusion interiors handled via calls only
        # for flops/collectives; bytes use the fusion's own params/outputs)
        if op in ("fusion",):
            st.hbm_bytes += _bytes_of(out_shapes) + _bytes_of(operand_shapes)
        elif op not in ("parameter", "constant", "get-tuple-element",
                        "tuple", "bitcast", "while", "copy"):
            st.hbm_bytes += _bytes_of(out_shapes) + _bytes_of(operand_shapes)
    return st


def _trip_count(comps, raw, cond_name) -> int:
    """Max constant visible in the condition computation (+1 level deep)."""
    if cond_name not in raw:
        return 1
    consts = list(raw[cond_name].constants)
    for callee, _c, _k in raw[cond_name].calls:
        if callee in raw:
            consts.extend(raw[callee].constants)
    return max(consts) if consts else 1


def analyze_hlo(text: str, n_devices: int):
    comps = split_computations(text)
    raw = {n: _analyze_comp(l, n_devices) for n, l in comps.items()}
    memo = {}

    def total(name, stack=()):
        if name not in raw or name in stack:
            return CompStats()
        if name in memo:
            return memo[name]
        st = raw[name]
        agg = CompStats(flops=st.flops, hbm_bytes=st.hbm_bytes,
                        dot_bytes=st.dot_bytes)
        for k, v in st.coll.items():
            _coll_add(agg.coll, k, v["count"], v["operand_bytes"],
                      v["wire_bytes"])
        for callee, cond, kind in st.calls:
            if callee is None:
                continue
            sub = total(callee, stack + (name,))
            mult = _trip_count(comps, raw, cond) if kind == "while" else 1
            agg.flops += mult * sub.flops
            agg.hbm_bytes += mult * sub.hbm_bytes
            agg.dot_bytes += mult * sub.dot_bytes
            for k, v in sub.coll.items():
                _coll_add(agg.coll, k, v["count"], v["operand_bytes"],
                          v["wire_bytes"], mult=mult)
        memo[name] = agg
        return agg

    m = re.search(r"^ENTRY\s+%?([\w.\-_]+)", text, re.M)
    entry = m.group(1) if m else next((n for n in comps if "main" in n), None)
    agg = total(entry) if entry else CompStats()
    return dict(flops=agg.flops, hbm_bytes=agg.hbm_bytes,
                dot_bytes=agg.dot_bytes, collectives=dict(agg.coll))


# ---------------------------------------------------------------------------

def collective_stats(hlo_text: str, n_devices: int):
    return analyze_hlo(hlo_text, n_devices)["collectives"]


def total_collective_bytes(stats) -> tuple:
    ob = sum(s["operand_bytes"] for s in stats.values())
    wb = sum(s["wire_bytes"] for s in stats.values())
    return ob, wb
