"""Render the §Dry-run / §Roofline sections of EXPERIMENTS.md from the
cached dry-run JSONs."""
from __future__ import annotations

import json
from pathlib import Path

RESULTS = Path(__file__).resolve().parents[3] / "benchmarks" / "results" / "dryrun"

HEADER = ("| arch | shape | mode | mesh | compute ms | memory ms | collective ms "
          "| dominant | useful-FLOPs frac | GiB/dev | lever for dominant term |\n"
          "|---|---|---|---|---|---|---|---|---|---|---|")


def lever(d):
    """One sentence: what would move the dominant term down (per harness)."""
    dom, shape, arch = d["dominant"], d["shape"], d["arch"]
    moe = arch in ("llama4-scout-17b-a16e", "deepseek-v2-236b")
    if dom == "compute":
        if d["useful_flops_frac"] < 0.9:
            return "dots remat cuts bwd recompute (measured -18.5%, §Perf A6)"
        return "already ~model-FLOPs bound; next lever is the Pallas tesseract_mm/flash kernels"
    if dom == "collective":
        if "decode" in shape or "500k" in shape:
            return "switch serve layout to 1-D: per-token weight gathers vanish (-99.9%, §Perf B1)"
        if moe:
            return "capacity 1.0 + deferred bf16 grad sync (-11%, §Perf C4); structural: top-k"
        return "deferred bf16 grad sync (-14%, §Perf A8) + overlap behind compute (XLA LHS)"
    # memory
    if "prefill" in shape:
        return "Pallas flash attention keeps score blocks in VMEM (dot traffic down)"
    if "decode" in shape:
        return "weight streaming bound: raise batch per chip or quantize weights"
    return "over-provisioned chips for this model size; shrink TP or raise per-chip batch"


def load_cells(mesh=None, mode=None):
    out = []
    for p in sorted(RESULTS.glob("*.json")):
        d = json.loads(p.read_text())
        if mesh and d["mesh"] != mesh:
            continue
        if mode and d["mode"] != mode:
            continue
        out.append(d)
    return out


def row(d):
    return (f"| {d['arch']} | {d['shape']} | {d['mode']} | {d['mesh']} | "
            f"{d['compute_term_s']*1e3:.2f} | {d['memory_term_s']*1e3:.2f} | "
            f"{d['collective_term_s']*1e3:.2f} | {d['dominant']} | "
            f"{d['useful_flops_frac']:.3f} | "
            f"{d['per_device_bytes']/2**30:.1f} | {lever(d)} |")


def table(mesh="16x16", mode="tesseract"):
    lines = [HEADER]
    for d in load_cells(mesh, mode):
        lines.append(row(d))
    return "\n".join(lines)


def summary():
    cells = load_cells()
    doms = {}
    for d in cells:
        doms.setdefault(d["dominant"], []).append(
            f"{d['arch']}/{d['shape']}/{d['mesh']}")
    return doms


if __name__ == "__main__":
    import sys
    mesh = sys.argv[1] if len(sys.argv) > 1 else "16x16"
    print(table(mesh=mesh))
