"""Production mesh definitions (cluster-facing).

``make_production_mesh`` is a FUNCTION (never a module-level constant) so
importing this module never touches jax device state.
"""
from __future__ import annotations

import jax

from ..core.api import ParallelContext
from ..core.mesh import logical_from_production


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


# How the 16-way "model" axis factorizes per parallelization mode.
MODEL_FACTORIZATIONS = {
    # mode      (rows, cols, depth)
    "tesseract": (2, 2, 4),     # paper's 2.5-D default  [q=2, d=4]
    "summa2d": (4, 4, 1),       # Optimus 2-D baseline   [q=4, d=1]
    "megatron1d": (1, 16, 1),   # Megatron 1-D baseline
    "gspmd": (2, 2, 4),         # auto-partitioner control, tesseract specs
}


def production_context(mode: str = "tesseract", *, multi_pod: bool = False,
                       **overrides) -> ParallelContext:
    rows, cols, depth = MODEL_FACTORIZATIONS[mode]
    data = 32 if multi_pod else 16   # pod axis folds into data (paper §3.4)
    rows = overrides.pop("rows", rows)
    cols = overrides.pop("cols", cols)
    depth = overrides.pop("depth", depth)
    data = overrides.pop("data", data)
    return ParallelContext(mode=mode, data=data, depth=depth, rows=rows,
                           cols=cols, **overrides)


def production_logical_mesh(mode: str = "tesseract", *,
                            multi_pod: bool = False, **overrides):
    ctx = production_context(mode, multi_pod=multi_pod, **overrides)
    prod = make_production_mesh(multi_pod=multi_pod)
    return ctx, logical_from_production(prod, ctx)
