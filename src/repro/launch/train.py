"""Cluster training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch yi-6b --mode tesseract \
        --steps 100 [--reduced] [--data 2 --rows 2 --cols 2 --depth 2] \
        [--seq 256 --batch 8] [--ckpt /path]

On a real pod, jax.distributed.initialize() is called when the usual cluster
env vars are present; on this container it runs single-process.  With
--reduced it trains the reduced config on however many devices exist;
without, it expects the full production mesh (use the dry-run to validate
that first).
"""
from __future__ import annotations

import argparse
import os


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--mode", default="tesseract",
                    choices=("tesseract", "summa2d", "megatron1d"))
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--data", type=int, default=1)
    ap.add_argument("--rows", type=int, default=1)
    ap.add_argument("--cols", type=int, default=1)
    ap.add_argument("--depth", type=int, default=1)
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--zero1", action="store_true")
    ap.add_argument("--zero-stage", type=int, default=0, choices=(0, 1),
                    help="ZeRO stage: 1 shards AdamW state over the "
                         "data/depth replica axes (same as --zero1)")
    ap.add_argument("--param-dtype", default="float32",
                    choices=("float32", "bfloat16", "float16"))
    ap.add_argument("--compute-dtype", default="float32",
                    choices=("float32", "bfloat16", "float16"),
                    help="bf16 compute + fp32 master weights is the "
                         "mixed-precision recipe (DESIGN.md §9)")
    ap.add_argument("--loss-scale", type=float, default=1.0,
                    help="static loss scaling (float16 numerics lever; "
                         "grads are unscaled before clip/optimizer)")
    ap.add_argument("--matmul-schedule", default="fused",
                    choices=("fused", "ring", "auto"))
    ap.add_argument("--attn-impl", default="auto",
                    choices=("jnp", "pallas", "auto"),
                    help="attention data path: fused Pallas kernels, the "
                         "jnp reference, or per-backend auto (DESIGN.md §10)")
    ap.add_argument("--seq-shards", type=int, default=1,
                    help="sequence-axis mesh shards for ring/striped flash "
                         "attention (DESIGN.md §15); seq_len must divide")
    ap.add_argument("--attn-schedule", default=None,
                    choices=("local", "ring", "striped", "auto"),
                    help="attention schedule across seq shards (default: "
                         "'auto' when --seq-shards > 1, else 'local')")
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--pipe", type=int, default=1,
                    help="pipeline stages OUTSIDE the TP group (1F1B)")
    ap.add_argument("--microbatches", type=int, default=0,
                    help="1F1B microbatches per step (0 -> 2*pipe)")
    ap.add_argument("--accum", type=int, default=1,
                    help="gradient-accumulation microsteps per optimizer "
                         "step (elastic re-plans raise this on a shrink)")
    ap.add_argument("--fault-plan", default="",
                    help="deterministic fault schedule (DESIGN.md §11 DSL), "
                         "e.g. 'train.grads@5:nan;ckpt.write@9:corrupt"
                         "(0,bit_flip)'")
    ap.add_argument("--fault-seed", type=int, default=0,
                    help="seed for the fault schedule (replays identically)")
    args = ap.parse_args()

    if "COORDINATOR_ADDRESS" in os.environ:  # multi-host pod
        import jax
        jax.distributed.initialize()

    from ..configs.base import RunConfig, ShapeSpec
    from ..core.api import ParallelContext
    from ..core.mesh import pipeline_mesh
    from ..models.registry import build_model, get_arch, get_reduced
    from ..runtime.train_loop import train

    arch = get_reduced(args.arch) if args.reduced else get_arch(args.arch)
    run = RunConfig(param_dtype=args.param_dtype,
                    compute_dtype=args.compute_dtype,
                    loss_chunk=128, q_chunk=64, kv_chunk=64, lr=args.lr,
                    zero1=args.zero1, zero_stage=args.zero_stage,
                    loss_scale=args.loss_scale,
                    matmul_schedule=args.matmul_schedule,
                    attn_impl=args.attn_impl,
                    seq_shards=args.seq_shards,
                    attn_schedule=(args.attn_schedule or
                                   ("auto" if args.seq_shards > 1
                                    else "local")),
                    pipe_stages=args.pipe,
                    pipeline_microbatches=args.microbatches,
                    accum_steps=args.accum,
                    fault_plan=args.fault_plan, fault_seed=args.fault_seed)
    # RunConfig is the config surface; the per-op dispatch for both knobs
    # lives on ParallelContext (DESIGN.md §2b / §10)
    ctx = ParallelContext(mode=args.mode, data=args.data, depth=args.depth,
                          rows=args.rows, cols=args.cols,
                          seq=run.seq_shards,
                          attn_schedule=run.attn_schedule,
                          matmul_schedule=run.matmul_schedule,
                          attn_impl=run.attn_impl)
    mesh = pipeline_mesh(ctx, run.pipe_stages)
    model = build_model(arch.model, ctx, run)
    shape = ShapeSpec("train", seq_len=args.seq, global_batch=args.batch,
                      kind="train")
    res = train(model, mesh, shape, steps=args.steps, ckpt_dir=args.ckpt,
                log_every=10, accum_steps=args.accum)
    print(f"final loss {res.losses[-1]:.4f} after {len(res.losses)} steps "
          f"({res.restarts} restarts)")
    if args.fault_plan:
        print(f"resilience: nan_skips={res.nan_skips} "
              f"loss_scale_backoffs={res.loss_scale_backoffs} "
              f"ckpt_fallbacks={res.ckpt_fallbacks} "
              f"faults_fired={len(res.fault_log)}")


if __name__ == "__main__":
    main()
