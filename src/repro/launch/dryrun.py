import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512").strip()

"""Multi-pod dry-run: prove the distribution config is coherent without
hardware.  For every (architecture x input shape x mesh) cell:

    jax.jit(step).lower(**abstract inputs) -> .compile()
    print(compiled.memory_analysis())   # proves it fits
    print(compiled.cost_analysis())     # FLOPs/bytes for the roofline

plus collective-byte parsing of the partitioned HLO.  Results are cached as
JSON under benchmarks/results/dryrun/.

Usage:
    python -m repro.launch.dryrun --arch yi-6b --shape train_4k [--multi-pod]
    python -m repro.launch.dryrun --all        # driver: one subprocess/cell
"""
import argparse
import json
import subprocess
import sys
import time
from pathlib import Path

RESULTS = Path(__file__).resolve().parents[3] / "benchmarks" / "results" / "dryrun"


def _parse_kv(pairs):
    out = {}
    for kv in pairs or []:
        k, v = kv.split("=", 1)
        if v in ("true", "True"):
            v = True
        elif v in ("false", "False"):
            v = False
        else:
            try:
                v = int(v)
            except ValueError:
                try:
                    v = float(v)
                except ValueError:
                    pass
        out[k] = v
    return out


def input_specs(arch_name: str, shape_name: str, *, mode: str = "tesseract",
                multi_pod: bool = False):
    """ShapeDtypeStruct stand-ins for every model input of a cell — weak-type
    correct, shardable, no device allocation (the shannon/kernels pattern).

    Returns (abstract_inputs, in_shardings) as fed to ``bundle.fn.lower``.
    """
    from ..configs.base import SHAPES, RunConfig
    from ..core.mesh import logical_from_production
    from ..models.registry import get_arch, build_model
    from ..runtime.steps import (build_decode_step, build_prefill_step,
                                 build_train_step)
    from .mesh import make_production_mesh, production_context

    arch = get_arch(arch_name)
    shape = SHAPES[shape_name]
    ctx = production_context(mode, multi_pod=multi_pod)
    mesh = logical_from_production(make_production_mesh(multi_pod=multi_pod),
                                   ctx)
    run = RunConfig(param_dtype="bfloat16", compute_dtype="bfloat16",
                    remat="full")
    model = build_model(arch.model, ctx, run)
    builder = {"train": build_train_step, "prefill": build_prefill_step,
               "decode": build_decode_step}[shape.kind]
    bundle = builder(model, mesh, shape)
    return bundle.abstract_inputs, bundle.in_shardings


def run_cell(arch_name: str, shape_name: str, multi_pod: bool, mode: str,
             run_overrides=None, ctx_overrides=None, tag: str = ""):
    import jax
    from ..configs.base import SHAPES, RunConfig
    from ..core.mesh import logical_from_production
    from ..models.registry import get_arch, build_model
    from ..roofline import hlo as hlo_mod
    from ..roofline.analysis import Roofline, model_flops
    from ..runtime.steps import (build_decode_step, build_prefill_step,
                                 build_train_step)
    from .mesh import make_production_mesh, production_context

    t0 = time.time()
    arch = get_arch(arch_name)
    shape = SHAPES[shape_name]
    # gspmd mode reuses the tesseract factorization + specs; only the step
    # builder differs (auto-partitioned global einsums)
    ctx_mode = "tesseract" if mode == "gspmd" else mode
    ctx = production_context(ctx_mode, multi_pod=multi_pod,
                             **(ctx_overrides or {}))
    prod_mesh = make_production_mesh(multi_pod=multi_pod)
    mesh = logical_from_production(prod_mesh, ctx)
    n_dev = prod_mesh.devices.size

    run_kw = dict(param_dtype="bfloat16", compute_dtype="bfloat16",
                  remat="full", loss_chunk=512, q_chunk=512, kv_chunk=1024)
    run_kw.update(run_overrides or {})
    run = RunConfig(**run_kw)
    model = build_model(arch.model, ctx, run)

    if mode == "gspmd":
        from ..core.gspmd import build_gspmd_train_step
        assert shape.kind == "train", "gspmd comparison mode: train only"
        bundle = build_gspmd_train_step(model, mesh, shape)
    elif shape.kind == "train":
        bundle = build_train_step(model, mesh, shape)
    elif shape.kind == "prefill":
        bundle = build_prefill_step(model, mesh, shape)
    else:
        bundle = build_decode_step(model, mesh, shape)

    lowered = bundle.fn.lower(*bundle.abstract_inputs)
    t_lower = time.time() - t0
    compiled = lowered.compile()
    t_compile = time.time() - t0 - t_lower

    ma = compiled.memory_analysis()
    print("memory_analysis:", ma)
    ca = compiled.cost_analysis() or {}
    print("cost_analysis: flops=%.3e bytes=%.3e (NOTE: while bodies counted "
          "once; structural analysis below multiplies trip counts)" %
          (ca.get("flops", 0.0), ca.get("bytes accessed", 0.0)))
    text = compiled.as_text()
    struct = hlo_mod.analyze_hlo(text, n_dev)
    stats = struct["collectives"]
    ob, wb = hlo_mod.total_collective_bytes(stats)
    del text
    # cost_analysis undercounts while bodies (counted once) and the raw
    # structural operand+output sum ignores fusion/aliasing (scan carries,
    # converts).  The HBM model used for the memory term is therefore:
    #     dot traffic (operands+outputs of every dot, trip-multiplied)
    #   + 2 x argument bytes (params/optimizer stream: one read + one write
    #     per step; serve steps read-only but keep the same bound)
    # — a defensible per-step traffic floor; see EXPERIMENTS.md §Roofline.
    ca_flops = float(ca.get("flops", 0.0))
    ca_bytes = float(ca.get("bytes accessed", 0.0))
    arg_bytes = float(getattr(ma, "argument_size_in_bytes", 0))
    hbm_bytes = struct["dot_bytes"] + 2.0 * arg_bytes

    per_dev_bytes = int(getattr(ma, "argument_size_in_bytes", 0)
                        + getattr(ma, "temp_size_in_bytes", 0)
                        + getattr(ma, "output_size_in_bytes", 0)
                        - getattr(ma, "alias_size_in_bytes", 0))
    rl = Roofline(
        arch=arch_name, shape=shape_name, mode=mode,
        mesh="2x16x16" if multi_pod else "16x16", chips=n_dev,
        hlo_flops=float(struct["flops"]),
        hlo_bytes=float(hbm_bytes),
        coll_operand_bytes=float(ob), coll_wire_bytes=float(wb),
        model_flops_total=model_flops(arch.model, shape),
        per_device_bytes=per_dev_bytes,
        collectives=stats,
        matmul_schedule=ctx.matmul_schedule,
    ).finalize()
    rl_d = rl.to_dict()
    rl_d["cost_analysis_raw"] = {"flops": ca_flops, "bytes": ca_bytes}
    rl_d["structural_bytes_upper"] = float(struct["hbm_bytes"])
    rl_d["lower_s"] = round(t_lower, 1)
    rl_d["compile_s"] = round(t_compile, 1)
    rl_d["memory_analysis"] = {
        k: int(getattr(ma, k)) for k in dir(ma)
        if k.endswith("_in_bytes") and not k.startswith("host")}

    rl_d["tag"] = tag
    rl_d["run_overrides"] = run_overrides or {}
    rl_d["ctx_overrides"] = ctx_overrides or {}
    RESULTS.mkdir(parents=True, exist_ok=True)
    sfx = f"__{tag}" if tag else ""
    out = RESULTS / f"{arch_name}__{shape_name}__{mode}__{rl_d['mesh']}{sfx}.json"
    out.write_text(json.dumps(rl_d, indent=1))
    print(f"cell OK: {out.name}  compute={rl.compute_term_s*1e3:.2f}ms "
          f"memory={rl.memory_term_s*1e3:.2f}ms "
          f"collective={rl.collective_term_s*1e3:.2f}ms "
          f"dominant={rl.dominant} (lower {t_lower:.0f}s compile {t_compile:.0f}s)")
    return rl_d


def iter_cells(modes=("tesseract",)):
    from ..configs.base import LONG_CONTEXT_OK, SHAPES
    from ..models.registry import ARCH_MODULES, get_arch
    for arch_name in ARCH_MODULES:
        arch = get_arch(arch_name)
        for sh in arch.shape_list():
            for mp in (False, True):
                for mode in modes:
                    yield arch_name, sh.name, mp, mode


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--mode", default="tesseract",
                    choices=("tesseract", "summa2d", "megatron1d", "gspmd"))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--modes", default="tesseract",
                    help="comma list for --all sweeps")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--run-override", action="append", default=[],
                    help="RunConfig overrides k=v (e.g. capacity_factor=1.0)")
    ap.add_argument("--ctx-override", action="append", default=[],
                    help="ParallelContext overrides k=v "
                         "(e.g. cache_act_gather=true rows=4 cols=4 depth=1)")
    ap.add_argument("--tag", default="", help="suffix for the result JSON")
    args = ap.parse_args()

    if args.all:
        failures = []
        for arch_name, shape_name, mp, mode in iter_cells(
                tuple(args.modes.split(","))):
            tag = f"{arch_name}__{shape_name}__{mode}__{'2x16x16' if mp else '16x16'}"
            out = RESULTS / f"{tag}.json"
            if out.exists() and not args.force:
                print(f"skip (cached): {tag}")
                continue
            cmd = [sys.executable, "-m", "repro.launch.dryrun",
                   "--arch", arch_name, "--shape", shape_name, "--mode", mode]
            if mp:
                cmd.append("--multi-pod")
            print(f"=== {tag}", flush=True)
            env = dict(os.environ,
                       PYTHONPATH=str(RESULTS.parents[2] / "src"))
            env.pop("XLA_FLAGS", None)  # child sets its own (512 devices)
            r = subprocess.run(cmd, env=env)
            if r.returncode != 0:
                failures.append(tag)
                print(f"FAILED: {tag}")
        print(f"done; {len(failures)} failures: {failures}")
        sys.exit(1 if failures else 0)

    run_cell(args.arch, args.shape, args.multi_pod, args.mode,
             run_overrides=_parse_kv(args.run_override),
             ctx_overrides=_parse_kv(args.ctx_override), tag=args.tag)


if __name__ == "__main__":
    main()
