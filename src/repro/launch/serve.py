"""Serving launcher: continuous-batching engine over the paged KV cache.

    XLA_FLAGS=--xla_force_host_platform_device_count=8 PYTHONPATH=src \
        python -m repro.launch.serve --arch yi-6b --reduced \
        --depth 2 --rows 2 --cols 2 --requests 8 --n-slots 8 \
        --prompt-lens 8,16 --new-tokens 16

Requests with mixed prompt/output lengths are admitted into a fixed slot
batch, prefilled in buckets, resharded into the mesh-sharded block pool and
decoded one fixed-shape step at a time; finished sequences retire in place
(src/repro/serve/, DESIGN.md §7).

For production decode the 1-D serve layout is the measured winner
(EXPERIMENTS.md §Perf B1): pass --mode megatron1d.  matmul-schedule "auto"
resolves ring-vs-fused per op (ring for prefill-sized token blocks on
q >= 4 grids, fused for decode steps — DESIGN.md §2b).
"""
from __future__ import annotations

import argparse


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--mode", default="tesseract",
                    choices=("tesseract", "summa2d", "megatron1d"))
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--n-slots", type=int, default=4)
    ap.add_argument("--block-size", type=int, default=8)
    ap.add_argument("--num-blocks", type=int, default=128)
    ap.add_argument("--max-seq-len", type=int, default=256)
    ap.add_argument("--prompt-lens", default="8,16",
                    help="comma list cycled over requests (mixed lengths)")
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--top-k", type=int, default=0)
    ap.add_argument("--top-p", type=float, default=1.0)
    ap.add_argument("--data", type=int, default=1)
    ap.add_argument("--rows", type=int, default=1)
    ap.add_argument("--cols", type=int, default=1)
    ap.add_argument("--depth", type=int, default=1)
    ap.add_argument("--matmul-schedule", default="fused",
                    choices=("fused", "ring", "auto"))
    ap.add_argument("--attn-impl", default="auto",
                    choices=("jnp", "pallas", "auto"),
                    help="attention data path: block-table paged decode "
                         "kernel + flash prefill, the jnp gather reference, "
                         "or per-backend auto (DESIGN.md §10)")
    ap.add_argument("--replan-to", type=int, default=0,
                    help="simulate an elastic device-count change after 2 "
                         "steps (rebuild mesh + reshard live KV blocks)")
    ap.add_argument("--fault-plan", default="",
                    help="deterministic fault schedule (DESIGN.md §11 DSL), "
                         "e.g. 'serve.logits@2:nan(1);serve.step@4:"
                         "drop_step'")
    ap.add_argument("--fault-seed", type=int, default=0,
                    help="seed for the fault schedule (replays identically)")
    ap.add_argument("--deadline-s", type=float, default=0.0,
                    help="per-request completion deadline (0 = none)")
    ap.add_argument("--ttft-budget-s", type=float, default=0.0,
                    help="per-request time-to-first-token budget (0 = none)")
    ap.add_argument("--max-waiting", type=int, default=0,
                    help="bound on the admission queue (0 = unbounded)")
    ap.add_argument("--prefix-cache", action="store_true",
                    help="radix prefix cache: shared prompt prefixes reuse "
                         "refcounted pool pages, divergent tails split "
                         "copy-on-write (DESIGN.md §12)")
    ap.add_argument("--prefill-chunk", type=int, default=0,
                    help="chunked prefill width interleaved with decode "
                         "steps (0 = monolithic bucketed prefill; the "
                         "prefix cache auto-chunks when 0)")
    ap.add_argument("--spec-k", type=int, default=0,
                    help="speculative decoding: proposals verified per "
                         "engine step (0 = plain decode; DESIGN.md §14)")
    ap.add_argument("--spec-mode", default="auto",
                    choices=("auto", "draft", "ngram"),
                    help="proposal source: a draft model (--draft-config) "
                         "or the model-free n-gram prompt-lookup fallback; "
                         "auto picks draft when one is configured")
    ap.add_argument("--draft-config", default="",
                    help="arch name of the draft model (e.g. smollm-360m); "
                         "built reduced iff --reduced, on the same mesh, "
                         "with vocab_size aligned to the target")
    args = ap.parse_args()

    import jax
    import numpy as np

    from ..configs.base import RunConfig
    from ..core.api import ParallelContext
    from ..core.mesh import logical_mesh
    from ..models.registry import build_model, get_arch, get_reduced
    from ..serve import EngineConfig, InferenceEngine, SamplingParams

    arch = get_reduced(args.arch) if args.reduced else get_arch(args.arch)
    run = RunConfig(param_dtype="float32", compute_dtype="float32",
                    loss_chunk=64, q_chunk=32, kv_chunk=32,
                    matmul_schedule=args.matmul_schedule,
                    attn_impl=args.attn_impl,
                    fault_plan=args.fault_plan, fault_seed=args.fault_seed)
    # megatron1d + ring/auto raises in ParallelContext, same as launch.train
    ctx = ParallelContext(mode=args.mode, data=args.data, depth=args.depth,
                          rows=args.rows, cols=args.cols,
                          matmul_schedule=run.matmul_schedule,
                          attn_impl=run.attn_impl)
    mesh = logical_mesh(ctx)
    model = build_model(arch.model, ctx, run)
    params = model.init(jax.random.PRNGKey(0))

    draft_model = draft_params = None
    if args.draft_config:
        import dataclasses
        darch = (get_reduced(args.draft_config) if args.reduced
                 else get_arch(args.draft_config))
        # the verify step judges draft proposals in the target's vocab, so
        # the draft head must emit the same token space
        dcfg = dataclasses.replace(darch.model,
                                   vocab_size=model.cfg.vocab_size)
        draft_model = build_model(dcfg, ctx, run)
        draft_params = draft_model.init(jax.random.PRNGKey(7))

    engine = InferenceEngine(model, mesh, params, EngineConfig(
        n_slots=args.n_slots, block_size=args.block_size,
        num_blocks=args.num_blocks, max_seq_len=args.max_seq_len,
        max_waiting=args.max_waiting, prefix_cache=args.prefix_cache,
        prefill_chunk=args.prefill_chunk, spec_k=args.spec_k,
        spec_mode=args.spec_mode),
        draft_model=draft_model, draft_params=draft_params)

    plens = [int(x) for x in args.prompt_lens.split(",")]
    rng = np.random.RandomState(0)
    vocab = min(250, model.cfg.vocab_size)
    reqs = []
    for i in range(args.requests):
        prompt = rng.randint(0, vocab, (plens[i % len(plens)],)).tolist()
        reqs.append(engine.add_request(
            prompt,
            SamplingParams(temperature=args.temperature, top_k=args.top_k,
                           top_p=args.top_p, seed=i,
                           max_new_tokens=args.new_tokens),
            deadline_s=args.deadline_s or None,
            ttft_budget_s=args.ttft_budget_s or None))

    if args.replan_to:
        engine.step()
        engine.step()
        rp = engine.replan_to(args.replan_to)
        print(f"replanned to {rp.n_used} devices: data={rp.ctx.data} "
              f"[q={rp.ctx.rows},{rp.ctx.cols},d={rp.ctx.depth}] "
              f"(idle={rp.n_idle})")

    results = engine.run()
    for r in reqs:
        print(f"req {r.rid} (prompt {r.orig_prompt_len}, "
              f"preempted {r.preemptions}x): {results[r.rid]}")
    s = engine.stats
    lat = s.latency_percentiles()
    ttft, itl = s.ttft_percentiles(), s.itl_percentiles()
    print(f"steps={s.steps} prefills={s.prefills} "
          f"preemptions={s.preemptions} tokens={s.tokens} "
          f"tokens/s={s.tokens_per_s():.1f} "
          f"p50={lat['p50_ms']:.1f}ms p95={lat['p95_ms']:.1f}ms "
          f"p99={lat['p99_ms']:.1f}ms "
          f"attn_impl={engine.attn_impl} "
          f"(CPU wall-clock: indicative only)")
    print(f"slo: health={s.health} "
          f"ttft p50={ttft['p50_ms']:.1f}ms p99={ttft['p99_ms']:.1f}ms "
          f"itl p50={itl['p50_ms']:.1f}ms p99={itl['p99_ms']:.1f}ms "
          f"shed={s.shed} failed={s.failed} "
          f"nan_quarantines={s.nan_quarantines} "
          f"batch_shrinks={s.batch_shrinks} "
          f"dropped_steps={s.dropped_steps}")
    if args.prefix_cache or args.prefill_chunk:
        print(f"prefix: hit_rate={s.cache_hit_rate():.3f} "
              f"hits={s.prefix_hits}/{s.prefix_lookups} "
              f"tokens_reused={s.prefix_tokens_reused}/"
              f"{s.prefix_tokens_total} cow_splits={s.cow_splits} "
              f"evictions={s.cache_evictions} "
              f"prefill_chunks={s.prefill_chunks} "
              f"cached_nodes={len(engine.prefix) if engine.prefix else 0}")
    if args.spec_k:
        print(f"spec: mode={engine.spec_mode} k={args.spec_k} "
              f"rounds={s.spec_rounds} proposed={s.spec_proposed} "
              f"accepted={s.spec_accepted} committed={s.spec_committed} "
              f"acceptance={s.acceptance_rate():.3f} "
              f"tokens/slot-round={s.tokens_per_round():.3f}")


if __name__ == "__main__":
    main()
