"""Serving launcher: batched prefill + greedy decode loop.

    PYTHONPATH=src python -m repro.launch.serve --arch yi-6b --reduced \
        --batch 4 --prompt-len 16 --new-tokens 16

For production decode the 1-D serve layout is the measured winner
(EXPERIMENTS.md §Perf B1): pass --mode megatron1d.
"""
from __future__ import annotations

import argparse


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--mode", default="tesseract",
                    choices=("tesseract", "summa2d", "megatron1d"))
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--data", type=int, default=1)
    ap.add_argument("--rows", type=int, default=1)
    ap.add_argument("--cols", type=int, default=1)
    ap.add_argument("--depth", type=int, default=1)
    ap.add_argument("--matmul-schedule", default="fused",
                    choices=("fused", "ring"))
    args = ap.parse_args()

    import jax
    import jax.numpy as jnp
    import numpy as np

    from ..configs.base import RunConfig, ShapeSpec
    from ..core.api import ParallelContext
    from ..core.mesh import logical_mesh
    from ..models.registry import build_model, get_arch, get_reduced
    from ..runtime.steps import build_decode_step

    arch = get_reduced(args.arch) if args.reduced else get_arch(args.arch)
    ctx = ParallelContext(mode=args.mode, data=args.data, depth=args.depth,
                          rows=args.rows, cols=args.cols,
                          matmul_schedule=args.matmul_schedule)
    mesh = logical_mesh(ctx)
    run = RunConfig(param_dtype="float32", compute_dtype="float32",
                    loss_chunk=64, q_chunk=32, kv_chunk=32,
                    matmul_schedule=args.matmul_schedule)
    model = build_model(arch.model, ctx, run)
    params = model.init(jax.random.PRNGKey(0))

    total = args.prompt_len + args.new_tokens
    dec = build_decode_step(model, mesh,
                            ShapeSpec("d", total, args.batch, "decode"))
    cache_sds, _ = model.cache_abstract(args.batch, total, dec.plan)
    cache = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), cache_sds)
    prompts = jax.random.randint(jax.random.PRNGKey(1),
                                 (args.batch, args.prompt_len), 0,
                                 min(250, model.cfg.vocab_size))
    ids = prompts[:, :1]
    out = []
    for t in range(total - 1):
        nxt, cache = dec.fn(params, cache, ids, jnp.int32(t))
        ids = (prompts[:, t + 1:t + 2] if t + 1 < args.prompt_len else nxt)
        if t + 1 >= args.prompt_len:
            out.append(np.asarray(nxt).ravel())
    print("generated:")
    print(np.stack(out).T)


if __name__ == "__main__":
    main()
