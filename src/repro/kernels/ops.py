"""jit'd public wrappers for the Pallas kernels.

On this CPU container the kernels run in interpret mode (the TPU is the
TARGET, not the runtime); ``repro_kernels_interpret()`` flips automatically
unless a TPU backend is present.  Model code selects the attention data
path via ``attn_impl`` (RunConfig / ParallelContext):

    "jnp"    — the pure-jnp reference paths (blockwise_attention etc.)
    "pallas" — the fused kernels, ALWAYS (interpret mode off-TPU, so the
               kernel data path runs in CPU CI and parity mdchecks)
    "auto"   — resolve per backend: kernels on TPU, jnp elsewhere (the
               attention analogue of matmul_schedule="auto", DESIGN.md §10)
"""
from __future__ import annotations

import jax

from .flash_attention import flash_attention
from .paged_attention import paged_attention
from .ssd import ssd_intra
from .tesseract_mm import tesseract_mm, tesseract_mm_stream


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def effective_attn_impl(impl: str) -> str:
    """Resolve an ``attn_impl`` knob to the executing data path."""
    if impl == "auto":
        return "pallas" if jax.default_backend() == "tpu" else "jnp"
    if impl not in ("jnp", "pallas"):
        raise ValueError(f"attn_impl must be 'jnp', 'pallas' or 'auto', "
                         f"got {impl!r}")
    return impl


def tesseract_mm_op(a, b, **kw):
    return tesseract_mm(a, b, interpret=_interpret(), **kw)


def tesseract_mm_stream_op(a, b, c, **kw):
    """One ring-SUMMA step: c += a @ b with a donated fp32 accumulator.

    Standalone TPU counterpart of matmul_schedule="ring"'s per-step
    contraction (the gathered [T, E, F] operand of the fused kernel never
    materializes).  Not yet wired into core/summa.py — the ring schedule
    currently contracts with jnp.einsum, like the fused path does with
    this module's fused kernel."""
    return tesseract_mm_stream(a, b, c, interpret=_interpret(), **kw)


def flash_attention_op(q, k, v, *, causal=True, **kw):
    """Flash fwd + custom-vjp bwd; q/k/v in [B, H, T, D] kernel layout."""
    return flash_attention(q, k, v, causal=causal, interpret=_interpret(),
                           **kw)


def paged_attention_op(q, pool_k, pool_v, table, pos, kv_map, **kw):
    """Block-table paged decode attention (no pool gather); see
    kernels/paged_attention.py."""
    return paged_attention(q, pool_k, pool_v, table, pos, kv_map,
                           interpret=_interpret(), **kw)


def ssd_intra_op(x, log_a, Bm, Cm, **kw):
    return ssd_intra(x, log_a, Bm, Cm, interpret=_interpret(), **kw)
