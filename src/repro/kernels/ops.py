"""jit'd public wrappers for the Pallas kernels.

On this CPU container the kernels run in interpret mode (the TPU is the
TARGET, not the runtime); ``repro_kernels_interpret()`` flips automatically
unless a TPU backend is present.  Model code gates usage behind
``RunConfig.use_pallas``.
"""
from __future__ import annotations

import jax

from .flash_attention import flash_attention
from .ssd import ssd_intra
from .tesseract_mm import tesseract_mm


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def tesseract_mm_op(a, b, **kw):
    return tesseract_mm(a, b, interpret=_interpret(), **kw)


def flash_attention_op(q, k, v, *, causal=True, **kw):
    return flash_attention(q, k, v, causal=causal, interpret=_interpret(), **kw)


def ssd_intra_op(x, log_a, Bm, Cm, **kw):
    return ssd_intra(x, log_a, Bm, Cm, interpret=_interpret(), **kw)
