"""Pallas TPU kernel: Mamba2 SSD intra-chunk compute.

Per (batch, chunk) the kernel computes the quadratic-within-chunk form

    Y[i] = sum_{j<=i} (C_i . B_j) * L[i,j] * x_j         (per head)
    S_c  = sum_j decay_to_end[j] * x_j (x) B_j           (chunk-end state)

The decay matrix L is built from a cumulative-sum segment trick; all three
contractions are MXU matmuls.  This is the SSD insight (state-space
duality): the recurrence becomes dense matmuls within chunks — exactly the
TPU-native reformulation called for in the hardware-adaptation brief.

Grid: (B, nc, H/bh) with Q-by-Q score tiles in VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, la_ref, b_ref, c_ref, y_ref, s_ref, *, bh):
    # refs: x [1,1,Q,bh,P]; la [1,1,Q,bh]; b/c [1,1,Q,N]
    x = x_ref[0, 0].astype(jnp.float32)          # [Q, bh, P]
    la = la_ref[0, 0].astype(jnp.float32)        # [Q, bh]
    Bm = b_ref[0, 0].astype(jnp.float32)         # [Q, N]
    Cm = c_ref[0, 0].astype(jnp.float32)         # [Q, N]
    Q = x.shape[0]

    cs = jnp.cumsum(la, axis=0)                  # [Q, bh]
    scores = jax.lax.dot_general(Cm, Bm, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)  # [Q,Q]
    rows = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 1)
    tri = rows >= cols

    def per_head(h, _):
        seg = cs[:, h][:, None] - cs[:, h][None, :]          # [Q, Q]
        L = jnp.where(tri, jnp.exp(seg), 0.0)
        W = scores * L                                       # [Q, Q]
        yh = jax.lax.dot_general(W, x[:, h, :], (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        y_ref[0, 0, :, h, :] = yh.astype(y_ref.dtype)
        tail = cs[-1, h] - cs[:, h]                          # [Q]
        xw = x[:, h, :] * jnp.exp(tail)[:, None]
        sh = jax.lax.dot_general(xw, Bm, (((0,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)  # [P,N]
        s_ref[0, 0, h] = sh.astype(s_ref.dtype)
        return 0

    jax.lax.fori_loop(0, bh, per_head, 0)


@functools.partial(jax.jit, static_argnames=("bh", "interpret"))
def ssd_intra(x, log_a, Bm, Cm, *, bh=None, interpret=False):
    """x: [B, nc, Q, H, P]; log_a: [B, nc, Q, H]; Bm/Cm: [B, nc, Q, N].
    Returns (Y [B, nc, Q, H, P] f32, S_c [B, nc, H, P, N] f32)."""
    B, nc, Q, H, P = x.shape
    N = Bm.shape[-1]
    bh = bh or H
    assert H % bh == 0
    grid = (B, nc, H // bh)
    y, s = pl.pallas_call(
        functools.partial(_kernel, bh=bh),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, Q, bh, P), lambda b, c, h: (b, c, 0, h, 0)),
            pl.BlockSpec((1, 1, Q, bh), lambda b, c, h: (b, c, 0, h)),
            pl.BlockSpec((1, 1, Q, N), lambda b, c, h: (b, c, 0, 0)),
            pl.BlockSpec((1, 1, Q, N), lambda b, c, h: (b, c, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, Q, bh, P), lambda b, c, h: (b, c, 0, h, 0)),
            pl.BlockSpec((1, 1, bh, P, N), lambda b, c, h: (b, c, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, nc, Q, H, P), jnp.float32),
            jax.ShapeDtypeStruct((B, nc, H, P, N), jnp.float32),
        ],
        interpret=interpret,
    )(x, log_a, Bm, Cm)
    return y, s
