"""Pure-jnp oracles for the Pallas kernels (the correctness ground truth)."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def tesseract_mm_ref(a, b):
    """t-accumulating SUMMA local matmul: C = sum_t A[t] @ B[t].

    a: [T, E, F]; b: [T, F, G] -> [E, G] (fp32 accumulation).
    This is the per-device compute hot spot of the paper's Algorithm 3 after
    the all-gathers (DESIGN.md §2)."""
    return jnp.einsum("tef,tfg->eg", a, b,
                      preferred_element_type=jnp.float32)


def flash_attention_ref(q, k, v, *, causal=True, scale=None):
    """q: [B, H, Tq, D]; k/v: [B, H, Tk, D] -> [B, H, Tq, D]."""
    Tq, Tk = q.shape[2], k.shape[2]
    scale = scale if scale is not None else 1.0 / math.sqrt(q.shape[-1])
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    if causal:
        mask = jnp.arange(Tq)[:, None] >= jnp.arange(Tk)[None, :]
        s = jnp.where(mask, s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p,
                      v.astype(jnp.float32)).astype(q.dtype)


def ssd_intra_ref(x, log_a, Bm, Cm):
    """Intra-chunk SSD (mamba2): per chunk, quadratic attention-like form.

    x: [B, nc, Q, H, P]; log_a: [B, nc, Q, H]; Bm/Cm: [B, nc, Q, N]
    Returns (Y_intra [B, nc, Q, H, P], S_c [B, nc, H, P, N]).
    """
    Q = x.shape[2]
    cs = jnp.cumsum(log_a, axis=2)
    # seg[b,c,h,i,j] = cs[i] - cs[j]
    cs_t = cs.transpose(0, 1, 3, 2)                  # [B,nc,H,Q]
    seg = cs_t[..., :, None] - cs_t[..., None, :]    # [B,nc,H,Q,Q]
    mask = jnp.tril(jnp.ones((Q, Q), bool))
    L = jnp.where(mask, jnp.exp(seg), 0.0)
    scores = jnp.einsum("bcin,bcjn->bcij", Cm, Bm)
    Y = jnp.einsum("bcij,bchij,bcjhp->bcihp", scores, L, x,
                   preferred_element_type=jnp.float32)
    tail = cs_t[..., -1:] - cs_t                     # [B,nc,H,Q]
    xw = x * jnp.exp(tail).transpose(0, 1, 3, 2)[..., None]
    S_c = jnp.einsum("bcjhp,bcjn->bchpn", xw, Bm,
                     preferred_element_type=jnp.float32)
    return Y.astype(jnp.float32), S_c
