"""Tiny (bq, bk) tile autotuner for the flash-attention kernels.

Hillclimb-style loop (the benchmarks/hillclimb.py discipline scaled down to
one knob): measure the incumbent tiling, try each candidate, commit only
improvements.  Results are cached per shape signature in-process — the hot
path (`flash_tiles`) is a dict lookup, never a measurement — and can be
persisted/reloaded as JSON so `benchmarks/run.py` commits the sweep's
outcome in BENCH_attention.json.
"""
from __future__ import annotations

import json
import pathlib
import time

DEFAULT_TILES = (256, 256)
CANDIDATES = ((128, 128), (128, 256), (256, 128), (256, 256), (256, 512),
              (512, 256), (512, 512))

_CACHE: dict = {}

# Repo-committed tile choices, keyed backend -> "Tq,Tk,D,causal" -> [bq, bk]
# (autotune_cache.json next to this file).  Loaded once at import — pure
# json, no jax — and consulted by flash_tiles() AFTER the in-process cache
# (a fresh measurement on this machine beats the committed sweep) but
# BEFORE DEFAULT_TILES.  Regenerate with commit_cache() after a sweep.
COMMITTED_CACHE_PATH = pathlib.Path(__file__).with_name(
    "autotune_cache.json")
_COMMITTED: dict = {}
_BACKEND = None


def _load_committed(path=COMMITTED_CACHE_PATH) -> int:
    p = pathlib.Path(path)
    if not p.exists():
        return 0
    _COMMITTED.clear()
    for backend, table in json.loads(p.read_text()).items():
        per = _COMMITTED.setdefault(backend, {})
        for ks, v in table.items():
            tq, tk, d, causal = ks.split(",")
            per[(int(tq), int(tk), int(d), causal == "True")] = tuple(v)
    return sum(len(t) for t in _COMMITTED.values())


def _backend_name() -> str:
    global _BACKEND
    if _BACKEND is None:
        try:
            import jax
            _BACKEND = jax.default_backend()
        except Exception:
            _BACKEND = "cpu"
    return _BACKEND


def commit_cache(path=COMMITTED_CACHE_PATH) -> None:
    """Merge the in-process cache into the committed per-backend JSON."""
    p = pathlib.Path(path)
    data = json.loads(p.read_text()) if p.exists() else {}
    table = data.setdefault(_backend_name(), {})
    for k, v in _CACHE.items():
        table[",".join(map(str, k))] = list(v)
    p.write_text(json.dumps(data, indent=2, sort_keys=True) + "\n")
    _load_committed(p)


def _sig(Tq: int, Tk: int, D: int, causal: bool) -> tuple:
    # batch/head counts replicate the per-block work and never change the
    # best tile, so the signature is the per-head shape only
    return (int(Tq), int(Tk), int(D), bool(causal))


def flash_tiles(Tq: int, Tk: int, D: int, *, causal: bool = True) -> tuple:
    """Cached best (bq, bk) for a flash shape; the default when untuned.

    Resolution order: in-process cache (this run's measurements) ->
    committed per-backend autotune_cache.json -> DEFAULT_TILES."""
    sig = _sig(Tq, Tk, D, causal)
    hit = _CACHE.get(sig)
    if hit is not None:
        return hit
    hit = _COMMITTED.get(_backend_name(), {}).get(sig)
    return hit if hit is not None else DEFAULT_TILES


def set_tiles(Tq: int, Tk: int, D: int, causal: bool, tiles) -> None:
    _CACHE[_sig(Tq, Tk, D, causal)] = (int(tiles[0]), int(tiles[1]))


def autotune_flash(B: int, H: int, Tq: int, Tk: int, D: int, *,
                   causal: bool = True, include_bwd: bool = True,
                   candidates=CANDIDATES, iters: int = 3,
                   dtype=None) -> dict:
    """Sweep tile candidates for one shape, cache the winner, return the
    full measurement table {"(bq,bk)": seconds, ...} plus the choice."""
    import jax
    import jax.numpy as jnp
    from .ops import flash_attention_op

    dtype = dtype or jnp.float32
    key = jax.random.PRNGKey(0)
    q = jax.random.normal(key, (B, H, Tq, D), jnp.float32).astype(dtype)
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, H, Tk, D),
                          jnp.float32).astype(dtype)
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, H, Tk, D),
                          jnp.float32).astype(dtype)

    def run(bq, bk):
        if include_bwd:
            f = jax.jit(jax.grad(lambda a, b_, c: jnp.sum(
                flash_attention_op(a, b_, c, causal=causal, bq=bq, bk=bk)
                .astype(jnp.float32)), argnums=(0, 1, 2)))
        else:
            f = jax.jit(lambda a, b_, c: flash_attention_op(
                a, b_, c, causal=causal, bq=bq, bk=bk))
        jax.block_until_ready(f(q, k, v))            # compile
        times = []
        for _ in range(iters):
            t0 = time.perf_counter()
            jax.block_until_ready(f(q, k, v))
            times.append(time.perf_counter() - t0)
        return min(times)

    # hillclimb: incumbent = current cache entry (or default), challengers
    # = the candidate list clipped to the shape; commit improvements only
    best = flash_tiles(Tq, Tk, D, causal=causal)
    seen = {}
    trial = [best] + [c for c in candidates if c != best]
    for bq, bk in trial:
        cq, ck = min(bq, Tq), min(bk, Tk)
        if (cq, ck) in seen:
            continue
        seen[(cq, ck)] = run(cq, ck)
    best = min(seen, key=seen.get)
    set_tiles(Tq, Tk, D, causal, best)
    return {"shape": {"B": B, "H": H, "Tq": Tq, "Tk": Tk, "D": D,
                      "causal": causal},
            "timings_s": {f"{bq}x{bk}": t for (bq, bk), t in seen.items()},
            "best": list(best)}


def autotune_ring_steps(B: int, H: int, T: int, D: int, *,
                        seq_shards=(2, 4, 8), causal: bool = True,
                        candidates=CANDIDATES, iters: int = 3,
                        include_bwd: bool = True, dtype=None) -> list:
    """Sweep the ring-STEP flash shapes of a seq-sharded sequence.

    Each seq shard's ring step runs the flash kernel on its resident
    [L, L] tile (L = T/n), so the signatures that matter are
    (L, L, D, causal) for every shard count n — the per-step entries
    (kernels/flash_attention.flash_{fwd,dq,dkv}_step) resolve their tiles
    through the same flash_tiles() cache this sweep fills.  Returns one
    autotune_flash record per shard count, each tagged with ``seq_shards``
    and the key block ``Tk`` the ring streams per step."""
    out = []
    for n in seq_shards:
        if T % n:
            raise ValueError(f"T={T} not divisible by seq_shards={n}")
        L = T // n
        rec = autotune_flash(B, H, L, L, D, causal=causal,
                             candidates=candidates, iters=iters,
                             include_bwd=include_bwd, dtype=dtype)
        rec["seq_shards"] = n
        rec["ring_step_Tk"] = L
        out.append(rec)
    return out


def save_cache(path) -> None:
    p = pathlib.Path(path)
    p.parent.mkdir(parents=True, exist_ok=True)
    p.write_text(json.dumps(
        {",".join(map(str, k)): list(v) for k, v in _CACHE.items()},
        indent=2) + "\n")


def load_cache(path) -> int:
    p = pathlib.Path(path)
    if not p.exists():
        return 0
    for ks, v in json.loads(p.read_text()).items():
        tq, tk, d, causal = ks.split(",")
        _CACHE[(int(tq), int(tk), int(d), causal == "True")] = tuple(v)
    return len(_CACHE)


_load_committed()
