"""Pallas TPU kernel: the Tesseract per-device SUMMA accumulation matmul.

After the fused all-gathers (DESIGN.md §2) each device computes

    C[e, g] = sum_t A[t, e, f] B[t, f, g]

— the paper's inner SUMMA loop.  The kernel tiles (E, G) onto the MXU with
128-aligned VMEM blocks and walks the (t, f) reduction in the innermost grid
dimensions, accumulating into the output block in fp32 — so the gathered
operands stream HBM->VMEM exactly once and the accumulator never leaves
VMEM.

Grid: (E/be, G/bg, T, F/bf) — XLA guarantees sequential execution of the
trailing grid dims on TPU, making output-block accumulation safe.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


DEFAULT_BE = 256
DEFAULT_BF = 512
DEFAULT_BG = 256


def _kernel(a_ref, b_ref, o_ref, acc_ref, *, n_inner):
    @pl.when(pl.program_id(2) == 0)
    def _zero():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    a = a_ref[0]          # [be, bf]
    b = b_ref[0]          # [bf, bg]
    acc_ref[...] += jnp.dot(a, b, preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(2) == n_inner - 1)
    def _done():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("be", "bf", "bg", "interpret"))
def tesseract_mm(a, b, *, be=DEFAULT_BE, bf=DEFAULT_BF, bg=DEFAULT_BG,
                 interpret=False):
    """a: [T, E, F]; b: [T, F, G] -> [E, G] fp32."""
    T, E, F = a.shape
    G = b.shape[-1]
    be, bf, bg = min(be, E), min(bf, F), min(bg, G)
    assert E % be == 0 and F % bf == 0 and G % bg == 0, (E, F, G, be, bf, bg)
    nf = F // bf
    # fold (t, f) into one inner reduction axis so accumulation order is
    # purely sequential on TPU
    n_inner = T * nf

    def a_index(e, g, i):
        return (i // nf, e, i % nf)

    def b_index(e, g, i):
        return (i // nf, i % nf, g)

    grid = (E // be, G // bg, n_inner)
    out = pl.pallas_call(
        functools.partial(_kernel, n_inner=n_inner),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, be, bf), a_index),
            pl.BlockSpec((1, bf, bg), b_index),
        ],
        out_specs=pl.BlockSpec((be, bg), lambda e, g, i: (e, g)),
        out_shape=jax.ShapeDtypeStruct((E, G), jnp.float32),
        scratch_shapes=[pltpu.VMEM((be, bg), jnp.float32)],
        interpret=interpret,
    )(a, b)
    return out
