"""Pallas TPU kernel: the Tesseract per-device SUMMA accumulation matmul.

After the fused all-gathers (DESIGN.md §2) each device computes

    C[e, g] = sum_t A[t, e, f] B[t, f, g]

— the paper's inner SUMMA loop.  The kernel tiles (E, G) onto the MXU with
128-aligned VMEM blocks and walks the (t, f) reduction in the innermost grid
dimensions, accumulating into the output block in fp32 — so the gathered
operands stream HBM->VMEM exactly once and the accumulator never leaves
VMEM.

Grid: (E/be, G/bg, T, F/bf) — XLA guarantees sequential execution of the
trailing grid dims on TPU, making output-block accumulation safe.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


DEFAULT_BE = 256
DEFAULT_BF = 512
DEFAULT_BG = 256


def check_tiling(name: str, checks) -> None:
    """Raise an actionable ValueError when a dim does not tile into its
    VMEM block (TPU blocks must divide the operand shape).

    ``checks``: iterable of (dim_name, size, block_kwarg, block_size)."""
    bad = [(d, n, kw, b) for (d, n, kw, b) in checks if n % b]
    if bad:
        detail = ", ".join(f"{d}={n} is not a multiple of block {kw}={b}"
                           for d, n, kw, b in bad)
        kwargs = ", ".join(f"{kw}=..." for _, _, kw, _ in bad)
        raise ValueError(
            f"{name}: {detail}. Pad the operands to a multiple of the block "
            f"size (configs.base.round_up) or pass explicit block sizes "
            f"({kwargs}) that divide the shape.")


def _kernel(a_ref, b_ref, o_ref, acc_ref, *, n_inner):
    @pl.when(pl.program_id(2) == 0)
    def _zero():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    a = a_ref[0]          # [be, bf]
    b = b_ref[0]          # [bf, bg]
    acc_ref[...] += jnp.dot(a, b, preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(2) == n_inner - 1)
    def _done():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("be", "bf", "bg", "interpret"))
def tesseract_mm(a, b, *, be=DEFAULT_BE, bf=DEFAULT_BF, bg=DEFAULT_BG,
                 interpret=False):
    """a: [T, E, F]; b: [T, F, G] -> [E, G] fp32."""
    T, E, F = a.shape
    G = b.shape[-1]
    be, bf, bg = min(be, E), min(bf, F), min(bg, G)
    check_tiling("tesseract_mm", [("E", E, "be", be), ("F", F, "bf", bf),
                                  ("G", G, "bg", bg)])
    nf = F // bf
    # fold (t, f) into one inner reduction axis so accumulation order is
    # purely sequential on TPU
    n_inner = T * nf

    def a_index(e, g, i):
        return (i // nf, e, i % nf)

    def b_index(e, g, i):
        return (i // nf, i % nf, g)

    grid = (E // be, G // bg, n_inner)
    out = pl.pallas_call(
        functools.partial(_kernel, n_inner=n_inner),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, be, bf), a_index),
            pl.BlockSpec((1, bf, bg), b_index),
        ],
        out_specs=pl.BlockSpec((be, bg), lambda e, g, i: (e, g)),
        out_shape=jax.ShapeDtypeStruct((E, G), jnp.float32),
        scratch_shapes=[pltpu.VMEM((be, bg), jnp.float32)],
        interpret=interpret,
    )(a, b)
    return out


# --------------------------------------------------------------------------
# Streaming variant: one SUMMA step at a time (matmul_schedule="ring").
#
# The ring schedule never materializes the [T, E, F] gathered operand: each
# ppermute delivers ONE (A_t, W_t) block pair, and this kernel contracts it
# into a persistent fp32 accumulator (C += A_t @ W_t).  The accumulator is
# donated via input_output_aliasing, so across the q ring steps exactly one
# [E, G] fp32 buffer lives in HBM — peak operand memory is O(2 · block)
# instead of the fused kernel's O(q · block).
#
# Standalone for now: core/summa.py's ring schedule contracts with
# jnp.einsum (mirroring the fused path, which likewise does not call the
# fused kernel above); this is the drop-in TPU building block for when the
# per-step contraction is kernelized.
# --------------------------------------------------------------------------

def _stream_kernel(a_ref, b_ref, c_ref, o_ref, acc_ref, *, nf):
    @pl.when(pl.program_id(2) == 0)
    def _load():
        acc_ref[...] = c_ref[...]          # carry in the ring accumulator

    acc_ref[...] += jnp.dot(a_ref[...], b_ref[...],
                            preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(2) == nf - 1)
    def _done():
        o_ref[...] = acc_ref[...]


@functools.partial(jax.jit, static_argnames=("be", "bf", "bg", "interpret"),
                   donate_argnums=(2,))
def tesseract_mm_stream(a, b, c, *, be=DEFAULT_BE, bf=DEFAULT_BF,
                        bg=DEFAULT_BG, interpret=False):
    """One ring step: c + a @ b.  a: [E, F]; b: [F, G]; c: [E, G] fp32."""
    E, F = a.shape
    G = b.shape[-1]
    be, bf, bg = min(be, E), min(bf, F), min(bg, G)
    check_tiling("tesseract_mm_stream",
                 [("E", E, "be", be), ("F", F, "bf", bf), ("G", G, "bg", bg)])
    nf = F // bf
    grid = (E // be, G // bg, nf)
    return pl.pallas_call(
        functools.partial(_stream_kernel, nf=nf),
        grid=grid,
        in_specs=[
            pl.BlockSpec((be, bf), lambda e, g, i: (e, i)),
            pl.BlockSpec((bf, bg), lambda e, g, i: (i, g)),
            pl.BlockSpec((be, bg), lambda e, g, i: (e, g)),
        ],
        out_specs=pl.BlockSpec((be, bg), lambda e, g, i: (e, g)),
        out_shape=jax.ShapeDtypeStruct((E, G), jnp.float32),
        scratch_shapes=[pltpu.VMEM((be, bg), jnp.float32)],
        input_output_aliases={2: 0},
        interpret=interpret,
    )(a, b, c.astype(jnp.float32))
