# Pallas TPU kernel subsystem (DESIGN.md §2b / §10).  Public entry points
# live in kernels/ops.py (interpret-mode fallback off-TPU); ref.py holds the
# pure-jnp oracles the tests compare against.
#   tesseract_mm / tesseract_mm_stream — SUMMA per-device contraction
#   flash_attention                    — fused attention, custom_vjp fwd+bwd
#   paged_attention                    — block-table paged decode attention
#   autotune                           — (bq, bk) tile sweep + cache
