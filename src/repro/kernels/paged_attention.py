"""Pallas TPU kernel: single-step decode attention over a paged KV pool.

The jnp serve path (models/common.py::paged_gather) materializes every
request's ENTIRE block-table view — [B, max_blocks * bs, Hkv, D] per layer
per step — before one softmax over it.  This kernel walks the block table
directly: grid (B, Hq, max_blocks) with the physical page resolved by a
scalar-prefetched table lookup in the K/V index maps, so pages stream
HBM -> VMEM one (bs, D) block at a time and nothing is ever gathered.

Per-request page skipping: pages beyond ``pos[b] // bs`` (and, under a
sliding window, before the window's first page) clamp to the last/first
live page in the index map — the pipeline skips the repeated DMA — and
`pl.when` masks their compute.  Retired slots (whole table pointed at the
group's scratch block, pos = 0) read exactly one page, like the jnp path.

GQA rides a scalar-prefetched ``kv_map`` ([Hq] -> kv head), which also
covers the non-uniform replicated-KV maps (smollm head padding) that the
flash kernel handles by pre-expansion.
"""
from __future__ import annotations

import functools
import math
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30
_M_FLOOR = -1e25


class PagedCfg(NamedTuple):
    bs: int
    nb: int
    window: int
    scale: float
    interpret: bool


def _page_bounds(cfg: PagedCfg, pos_ref, b):
    """[lo, hi) live-page range for request b (jnp scalars)."""
    hi = pos_ref[b] // cfg.bs + 1                  # pos is inclusive
    lo = 0
    if cfg.window > 0:
        lo = jnp.maximum(pos_ref[b] - cfg.window + 1, 0) // cfg.bs
        lo = jnp.minimum(lo, hi - 1)
    return lo, hi


def _kernel(table_ref, pos_ref, kvh_ref, q_ref, k_ref, v_ref, o_ref,
            m_ref, l_ref, acc_ref, *, cfg: PagedCfg):
    b, j = pl.program_id(0), pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    lo, hi = _page_bounds(cfg, pos_ref, b)
    jj = jnp.minimum(lo + j, hi - 1)

    @pl.when(lo + j < hi)
    def _step():
        q = q_ref[0].astype(jnp.float32)                       # [1, D]
        k = k_ref[0, :, 0, :].astype(jnp.float32)              # [bs, D]
        s = lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * cfg.scale
        ppos = jj * cfg.bs + lax.broadcasted_iota(jnp.int32, (1, cfg.bs), 1)
        mask = ppos <= pos_ref[b]
        if cfg.window > 0:
            mask &= ppos > pos_ref[b] - cfg.window
        s = jnp.where(mask, s, NEG_INF)                        # [1, bs]
        m_prev = m_ref[0, 0]
        m_new = jnp.maximum(m_prev, jnp.max(s))
        p = jnp.exp(s - jnp.maximum(m_new, _M_FLOOR))
        corr = jnp.exp(jnp.maximum(m_prev, _M_FLOOR)
                       - jnp.maximum(m_new, _M_FLOOR))
        l_ref[0, 0] = l_ref[0, 0] * corr + jnp.sum(p)
        v = v_ref[0, :, 0, :].astype(jnp.float32)              # [bs, Dv]
        acc_ref[...] = acc_ref[...] * corr + lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[0, 0] = m_new

    @pl.when(j == cfg.nb - 1)
    def _done():
        l = l_ref[0, 0]
        o_ref[0] = (acc_ref[...] / jnp.where(l == 0.0, 1.0, l)).astype(
            o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("local_window", "softmax_scale",
                                             "interpret"))
def paged_attention(q, pool_k, pool_v, table, pos, kv_map, *,
                    local_window: int = 0, softmax_scale=None,
                    interpret=False):
    """One decode step against a paged pool, walking the block table.

    q: [B, Hq, D]; pool_k/pool_v: [P_loc, bs, Hkv, D/Dv]; table: [B, nb]
    LOCAL physical block ids; pos: [B] per-request current position (its
    K/V already written — paged_update-then-attend order); kv_map: [Hq]
    q-head -> kv-head.  Returns [B, Hq, Dv].
    """
    B, Hq, D = q.shape
    bs, Hkv = pool_k.shape[1], pool_k.shape[2]
    Dv = pool_v.shape[-1]
    nb = table.shape[1]
    scale = (softmax_scale if softmax_scale is not None
             else 1.0 / math.sqrt(D))
    cfg = PagedCfg(bs=bs, nb=nb, window=int(local_window),
                   scale=float(scale), interpret=bool(interpret))
    kvpage = lambda b, h, j, tr, pr, hr: (
        tr[b, jnp.minimum(_page_bounds(cfg, pr, b)[0] + j,
                          _page_bounds(cfg, pr, b)[1] - 1)], 0, hr[h], 0)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(B, Hq, nb),
        in_specs=[
            pl.BlockSpec((1, 1, D), lambda b, h, j, tr, pr, hr: (b, h, 0)),
            pl.BlockSpec((1, bs, 1, D), kvpage),
            pl.BlockSpec((1, bs, 1, Dv), kvpage),
        ],
        out_specs=pl.BlockSpec((1, 1, Dv),
                               lambda b, h, j, tr, pr, hr: (b, h, 0)),
        scratch_shapes=[
            pltpu.VMEM((1, 1), jnp.float32),
            pltpu.VMEM((1, 1), jnp.float32),
            pltpu.VMEM((1, Dv), jnp.float32),
        ],
    )
    return pl.pallas_call(
        functools.partial(_kernel, cfg=cfg),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, Hq, Dv), q.dtype),
        interpret=cfg.interpret,
    )(table.astype(jnp.int32), pos.astype(jnp.int32),
      kv_map.astype(jnp.int32), q, pool_k, pool_v)
