"""Pallas TPU kernel: causal flash attention (streaming softmax).

Grid: (B*H, Tq/bq).  Each program holds one query block in VMEM and walks
the KV blocks with a fori_loop, keeping (m, l, acc) in VMEM scratch — the
classic flash schedule adapted to the TPU memory hierarchy (HBM->VMEM block
streaming, MXU for the two dots).  Causal skipping: the loop upper bound is
the query block's last row index / bk + 1, so the upper-triangle blocks are
never visited (this removes the 2x waste of the masked-dense path; §Perf).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


DEFAULT_BQ = 256
DEFAULT_BK = 256
NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, *, bq, bk, scale, causal, tk):
    qi = pl.program_id(1)
    q = q_ref[0].astype(jnp.float32) * scale            # [bq, D]
    D = q.shape[-1]

    def body(j, carry):
        m, l, acc = carry
        k = k_ref[0, pl.ds(j * bk, bk), :].astype(jnp.float32)   # [bk, D]
        v = v_ref[0, pl.ds(j * bk, bk), :].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)  # [bq,bk]
        if causal:
            rows = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
            cols = j * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
            s = jnp.where(rows >= cols, s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + jnp.sum(p, axis=-1)
        acc_new = acc * corr[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
        return m_new, l_new, acc_new

    m0 = jnp.full((bq,), NEG_INF, jnp.float32)
    l0 = jnp.zeros((bq,), jnp.float32)
    a0 = jnp.zeros((bq, D), jnp.float32)
    if causal:
        n_kv = jnp.minimum(((qi + 1) * bq + bk - 1) // bk, tk // bk)
    else:
        n_kv = tk // bk
    m, l, acc = jax.lax.fori_loop(0, n_kv, body, (m0, l0, a0))
    l = jnp.where(l == 0.0, 1.0, l)
    o_ref[0] = (acc / l[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("causal", "bq", "bk", "interpret"))
def flash_attention(q, k, v, *, causal=True, bq=DEFAULT_BQ, bk=DEFAULT_BK,
                    interpret=False):
    """q: [B, H, Tq, D]; k/v: [B, H, Tk, D] -> [B, H, Tq, D]."""
    B, H, Tq, D = q.shape
    Tk = k.shape[2]
    bq, bk = min(bq, Tq), min(bk, Tk)
    from .tesseract_mm import check_tiling
    check_tiling("flash_attention", [("Tq", Tq, "bq", bq),
                                     ("Tk", Tk, "bk", bk)])
    scale = 1.0 / math.sqrt(D)
    qf = q.reshape(B * H, Tq, D)
    kf = k.reshape(B * H, Tk, D)
    vf = v.reshape(B * H, Tk, D)
    grid = (B * H, Tq // bq)
    out = pl.pallas_call(
        functools.partial(_kernel, bq=bq, bk=bk, scale=scale, causal=causal,
                          tk=Tk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, D), lambda bh, i: (bh, i, 0)),
            pl.BlockSpec((1, Tk, D), lambda bh, i: (bh, 0, 0)),
            pl.BlockSpec((1, Tk, D), lambda bh, i: (bh, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, D), lambda bh, i: (bh, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, Tq, D), q.dtype),
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(B, H, Tq, D)
