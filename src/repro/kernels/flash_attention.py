"""Pallas TPU kernels: causal flash attention, forward AND backward.

Forward grid: (B, Hq, Tq/bq, Tk/bk).  The KV walk is the innermost grid
dimension so K/V stream through VMEM one (bk, D) block at a time (TPU
executes trailing grid dims sequentially, so the (m, l, acc) VMEM scratch
carries across the walk) — the classic flash schedule on the Pallas
pipeline, instead of the v1 kernel's whole-[Tk, D] BlockSpec.

Causal / sliding-window block skipping: the K/V index maps clamp the block
index into [lo(i), hi(i)) — out-of-range steps re-request the same block
(the pipeline skips the DMA when the index repeats) and `pl.when` masks
their compute, so the upper triangle costs neither flops nor HBM traffic.
The bounds need the q-row offset statically (``q_start``); seq-sharded
prefill passes traced positions instead and falls back to the full walk
with in-kernel masking.

Backward is the standard two-pass flash bwd (out, logsumexp residuals):

    dQ pass : grid (B, Hq, nq, nk)   — same walk/skipping as forward
    dKV pass: grid (B, Hkv, nk, g, nq) — per KV block, walk the g query
              heads of its GQA group and the (skip-bounded) q blocks,
              accumulating dK/dV in VMEM scratch

GQA: q-head h reads KV head h // g through the K/V index maps — grouped
heads never materialize expanded K/V.  Non-tile-divisible Tq/Tk are
zero-padded and masked (cols >= Tk are dead), so any shape runs.
Fully-masked rows (e.g. a ``local_window`` that excludes every key)
produce EXACT zero output rows, matching models/common.blockwise_attention.
"""
from __future__ import annotations

import functools
import math
from typing import NamedTuple, Optional

import numpy as np

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


DEFAULT_BQ = 256
DEFAULT_BK = 256
NEG_INF = -1e30
# floor for the streaming max: exp(NEG_INF - _M_FLOOR) == 0 exactly, so a
# fully-masked block/row contributes nothing (and l stays 0 -> zero output)
_M_FLOOR = -1e25


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def _msafe(m):
    return jnp.maximum(m, _M_FLOOR)


class FlashCfg(NamedTuple):
    """Static kernel configuration (hashable: rides custom_vjp nondiff)."""
    causal: bool
    window: int            # 0 = unbounded
    scale: float
    g: int                 # q heads per kv head (contiguous GQA)
    bq: int
    bk: int
    nq: int
    nk: int
    q_start: Optional[int]  # static q-row offset; None -> no block skipping
    tk_real: int           # unpadded Tk (cols >= tk_real are masked dead)
    interpret: bool


# ---------------------------------------------------------------------------
# block-skip bounds (shared by the index maps and the kernel predicates)
# ---------------------------------------------------------------------------

def _kv_bounds(cfg: FlashCfg, i):
    """[lo, hi) KV-block range for q block i (jnp scalars)."""
    lo, hi = 0, cfg.nk
    if cfg.q_start is not None and cfg.causal:
        last_q = cfg.q_start + (i + 1) * cfg.bq - 1
        hi = jnp.minimum(last_q // cfg.bk + 1, cfg.nk)
        hi = jnp.maximum(hi, 1)
    if cfg.q_start is not None and cfg.window > 0:
        first_q = cfg.q_start + i * cfg.bq
        lo = jnp.maximum((first_q - cfg.window + 1) // cfg.bk, 0)
        lo = jnp.minimum(lo, hi - 1)
    return lo, hi


def _kv_index(cfg: FlashCfg, i, j):
    lo, hi = _kv_bounds(cfg, i)
    return jnp.minimum(lo + j, hi - 1)


def _q_bounds(cfg: FlashCfg, kb):
    """[lo, hi) q-block range that touches KV block kb (dKV pass)."""
    lo, hi = 0, cfg.nq
    if cfg.q_start is not None and cfg.causal:
        first_kv = kb * cfg.bk
        lo = jnp.maximum((first_kv - cfg.q_start) // cfg.bq, 0)
        lo = jnp.minimum(lo, cfg.nq - 1)
    if cfg.q_start is not None and cfg.window > 0:
        last_kv = kb * cfg.bk + cfg.bk - 1
        hi = jnp.minimum((last_kv + cfg.window - 1 - cfg.q_start) // cfg.bq
                         + 1, cfg.nq)
        hi = jnp.maximum(hi, lo + 1)
    return lo, hi


def _q_index(cfg: FlashCfg, kb, qi):
    lo, hi = _q_bounds(cfg, kb)
    return jnp.minimum(lo + qi, hi - 1)


def _block_mask(cfg: FlashCfg, rows, jj):
    """(bq, bk) validity mask for KV block jj given q-row positions."""
    cols = jj * cfg.bk + lax.broadcasted_iota(jnp.int32, (cfg.bq, cfg.bk), 1)
    mask = cols < cfg.tk_real
    if cfg.causal:
        mask &= rows[:, None] >= cols
    if cfg.window > 0:
        mask &= cols > rows[:, None] - cfg.window
    return mask


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------

def _fwd_kernel(qpos_ref, q_ref, k_ref, v_ref, o_ref, lse_ref,
                m_ref, l_ref, acc_ref, *, cfg: FlashCfg):
    i, j = pl.program_id(2), pl.program_id(3)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    lo, hi = _kv_bounds(cfg, i)
    jj = jnp.minimum(lo + j, hi - 1)

    @pl.when(lo + j < hi)
    def _step():
        q = q_ref[0, 0].astype(jnp.float32)
        k = k_ref[0, 0].astype(jnp.float32)
        s = lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * cfg.scale
        rows = qpos_ref[0]
        s = jnp.where(_block_mask(cfg, rows, jj), s, NEG_INF)
        m_prev = m_ref[:, 0]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        p = jnp.exp(s - _msafe(m_new)[:, None])          # masked entries -> 0
        corr = jnp.exp(_msafe(m_prev) - _msafe(m_new))
        l_ref[:, 0] = l_ref[:, 0] * corr + jnp.sum(p, axis=-1)
        v = v_ref[0, 0].astype(jnp.float32)
        acc_ref[...] = acc_ref[...] * corr[:, None] + lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
        m_ref[:, 0] = m_new

    @pl.when(j == cfg.nk - 1)
    def _done():
        l = l_ref[:, 0]
        ls = jnp.where(l == 0.0, 1.0, l)                 # masked row -> 0 out
        o_ref[0, 0] = (acc_ref[...] / ls[:, None]).astype(o_ref.dtype)
        lse_ref[0, 0] = _msafe(m_ref[:, 0]) + jnp.log(ls)


def _fwd_call(cfg: FlashCfg, q, k, v, q_pos):
    B, Hq, Tq, D = q.shape
    Dv = v.shape[-1]
    grid = (B, Hq, cfg.nq, cfg.nk)
    qmap = lambda b, h, i, j: (b, h, i, 0)
    kvmap = lambda b, h, i, j: (b, h // cfg.g, _kv_index(cfg, i, j), 0)
    out, lse = pl.pallas_call(
        functools.partial(_fwd_kernel, cfg=cfg),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, cfg.bq), lambda b, h, i, j: (0, i)),
            pl.BlockSpec((1, 1, cfg.bq, D), qmap),
            pl.BlockSpec((1, 1, cfg.bk, D), kvmap),
            pl.BlockSpec((1, 1, cfg.bk, Dv), kvmap),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, cfg.bq, Dv), qmap),
            pl.BlockSpec((1, 1, cfg.bq), lambda b, h, i, j: (b, h, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, Hq, Tq, Dv), q.dtype),
            jax.ShapeDtypeStruct((B, Hq, Tq), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((cfg.bq, 1), jnp.float32),
            pltpu.VMEM((cfg.bq, 1), jnp.float32),
            pltpu.VMEM((cfg.bq, Dv), jnp.float32),
        ],
        interpret=cfg.interpret,
    )(q_pos, q, k, v)
    return out, lse


# ---------------------------------------------------------------------------
# backward: dQ pass (same walk as forward)
# ---------------------------------------------------------------------------

def _dq_kernel(qpos_ref, q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref,
               dq_ref, acc_ref, *, cfg: FlashCfg):
    i, j = pl.program_id(2), pl.program_id(3)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    lo, hi = _kv_bounds(cfg, i)
    jj = jnp.minimum(lo + j, hi - 1)

    @pl.when(lo + j < hi)
    def _step():
        q = q_ref[0, 0].astype(jnp.float32)
        k = k_ref[0, 0].astype(jnp.float32)
        v = v_ref[0, 0].astype(jnp.float32)
        do = do_ref[0, 0].astype(jnp.float32)
        s = lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * cfg.scale
        s = jnp.where(_block_mask(cfg, qpos_ref[0], jj), s, NEG_INF)
        p = jnp.exp(s - lse_ref[0, 0][:, None])          # normalized probs
        dp = lax.dot_general(do, v, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)
        ds = p * (dp - delta_ref[0, 0][:, None])
        acc_ref[...] += lax.dot_general(
            ds, k, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32) * cfg.scale

    @pl.when(j == cfg.nk - 1)
    def _done():
        dq_ref[0, 0] = acc_ref[...].astype(dq_ref.dtype)


# ---------------------------------------------------------------------------
# backward: dK/dV pass (grid walks KV blocks; inner dims cover the GQA
# group's q heads and the skip-bounded q blocks)
# ---------------------------------------------------------------------------

def _dkv_kernel(qpos_ref, q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref,
                dk_ref, dv_ref, dk_acc, dv_acc, *, cfg: FlashCfg):
    kb = pl.program_id(2)
    gi, qi = pl.program_id(3), pl.program_id(4)

    @pl.when((gi == 0) & (qi == 0))
    def _init():
        dk_acc[...] = jnp.zeros_like(dk_acc)
        dv_acc[...] = jnp.zeros_like(dv_acc)

    lo, hi = _q_bounds(cfg, kb)
    qq = jnp.minimum(lo + qi, hi - 1)

    @pl.when(lo + qi < hi)
    def _step():
        q = q_ref[0, 0].astype(jnp.float32)
        k = k_ref[0, 0].astype(jnp.float32)
        v = v_ref[0, 0].astype(jnp.float32)
        do = do_ref[0, 0].astype(jnp.float32)
        s = lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * cfg.scale
        s = jnp.where(_block_mask(cfg, qpos_ref[0], kb), s, NEG_INF)
        p = jnp.exp(s - lse_ref[0, 0][:, None])
        dv_acc[...] += lax.dot_general(
            p, do, (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        dp = lax.dot_general(do, v, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)
        ds = p * (dp - delta_ref[0, 0][:, None])
        dk_acc[...] += lax.dot_general(
            ds, q, (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32) * cfg.scale

    @pl.when((gi == cfg.g - 1) & (qi == cfg.nq - 1))
    def _done():
        dk_ref[0, 0] = dk_acc[...].astype(dk_ref.dtype)
        dv_ref[0, 0] = dv_acc[...].astype(dv_ref.dtype)


def _dq_call(cfg: FlashCfg, q, k, v, q_pos, lse, delta, dout):
    B, Hq, Tq, D = q.shape
    Dv = v.shape[-1]
    qmap = lambda b, h, i, j: (b, h, i, 0)
    kvmap = lambda b, h, i, j: (b, h // cfg.g, _kv_index(cfg, i, j), 0)
    rowmap = lambda b, h, i, j: (b, h, i)
    return pl.pallas_call(
        functools.partial(_dq_kernel, cfg=cfg),
        grid=(B, Hq, cfg.nq, cfg.nk),
        in_specs=[
            pl.BlockSpec((1, cfg.bq), lambda b, h, i, j: (0, i)),
            pl.BlockSpec((1, 1, cfg.bq, D), qmap),
            pl.BlockSpec((1, 1, cfg.bk, D), kvmap),
            pl.BlockSpec((1, 1, cfg.bk, Dv), kvmap),
            pl.BlockSpec((1, 1, cfg.bq, Dv), qmap),
            pl.BlockSpec((1, 1, cfg.bq), rowmap),
            pl.BlockSpec((1, 1, cfg.bq), rowmap),
        ],
        out_specs=pl.BlockSpec((1, 1, cfg.bq, D), qmap),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[pltpu.VMEM((cfg.bq, D), jnp.float32)],
        interpret=cfg.interpret,
    )(q_pos, q, k, v, dout, lse, delta)


def _dkv_call(cfg: FlashCfg, q, k, v, q_pos, lse, delta, dout):
    B, Hq, Tq, D = q.shape
    Hkv, Tk = k.shape[1], k.shape[2]
    Dv = v.shape[-1]
    qmap2 = lambda b, h, kb, gi, qi: (b, h * cfg.g + gi, _q_index(cfg, kb, qi), 0)
    rowmap2 = lambda b, h, kb, gi, qi: (b, h * cfg.g + gi, _q_index(cfg, kb, qi))
    kvmap2 = lambda b, h, kb, gi, qi: (b, h, kb, 0)
    dk, dv = pl.pallas_call(
        functools.partial(_dkv_kernel, cfg=cfg),
        grid=(B, Hkv, cfg.nk, cfg.g, cfg.nq),
        in_specs=[
            pl.BlockSpec((1, cfg.bq),
                         lambda b, h, kb, gi, qi: (0, _q_index(cfg, kb, qi))),
            pl.BlockSpec((1, 1, cfg.bq, D), qmap2),
            pl.BlockSpec((1, 1, cfg.bk, D), kvmap2),
            pl.BlockSpec((1, 1, cfg.bk, Dv), kvmap2),
            pl.BlockSpec((1, 1, cfg.bq, Dv), qmap2),
            pl.BlockSpec((1, 1, cfg.bq), rowmap2),
            pl.BlockSpec((1, 1, cfg.bq), rowmap2),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, cfg.bk, D), kvmap2),
            pl.BlockSpec((1, 1, cfg.bk, Dv), kvmap2),
        ],
        out_shape=[
            jax.ShapeDtypeStruct(k.shape, k.dtype),
            jax.ShapeDtypeStruct(v.shape, v.dtype),
        ],
        scratch_shapes=[
            pltpu.VMEM((cfg.bk, D), jnp.float32),
            pltpu.VMEM((cfg.bk, Dv), jnp.float32),
        ],
        interpret=cfg.interpret,
    )(q_pos, q, k, v, dout, lse, delta)
    return dk, dv


def _bwd_call(cfg: FlashCfg, q, k, v, q_pos, out, lse, dout):
    delta = jnp.sum(dout.astype(jnp.float32) * out.astype(jnp.float32),
                    axis=-1)                              # [B, Hq, Tq]
    dq = _dq_call(cfg, q, k, v, q_pos, lse, delta, dout)
    dk, dv = _dkv_call(cfg, q, k, v, q_pos, lse, delta, dout)
    return dq, dk, dv


# ---------------------------------------------------------------------------
# custom_vjp plumbing (operates on tile-padded operands)
# ---------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _flash(cfg: FlashCfg, q, k, v, q_pos):
    out, _ = _fwd_call(cfg, q, k, v, q_pos)
    return out


def _flash_fwd(cfg, q, k, v, q_pos):
    out, lse = _fwd_call(cfg, q, k, v, q_pos)
    return out, (q, k, v, q_pos, out, lse)


def _flash_bwd(cfg, res, dout):
    q, k, v, q_pos, out, lse = res
    dq, dk, dv = _bwd_call(cfg, q, k, v, q_pos, out, lse, dout)
    return dq, dk, dv, np.zeros(q_pos.shape, jax.dtypes.float0)


_flash.defvjp(_flash_fwd, _flash_bwd)


# ---------------------------------------------------------------------------
# public entry
# ---------------------------------------------------------------------------

def flash_attention(q, k, v, *, causal=True, local_window: int = 0,
                    q_pos=None, q_start: Optional[int] = 0,
                    softmax_scale=None, bq=None, bk=None, interpret=False):
    """Fused attention with flash fwd + two-pass bwd.

    q: [B, Hq, Tq, D]; k: [B, Hkv, Tk, D]; v: [B, Hkv, Tk, Dv] with
    Hq = g * Hkv (contiguous GQA groups) -> [B, Hq, Tq, Dv].

    ``q_pos`` ([Tq] int32 global positions, default q_start + arange) drives
    the causal / local_window masks; ``q_start`` is the STATIC row offset
    that enables block skipping — pass None when positions are traced
    (seq-sharded prefill) to fall back to the full masked walk.  KV rows are
    assumed at positions 0..Tk-1.  Non-divisible Tq/Tk are padded+masked.

    The tile lookup runs OUTSIDE the jitted core (which keys on the
    resolved bq/bk), so a later autotune sweep takes effect on the next
    call instead of being pinned by an old trace.
    """
    Tq, Tk, D = q.shape[2], k.shape[2], q.shape[3]
    if bq is None or bk is None:
        from .autotune import flash_tiles
        tq_, tk_ = flash_tiles(Tq, Tk, D, causal=causal)
        bq = bq or tq_
        bk = bk or tk_
    return _flash_jit(q, k, v, q_pos, causal=causal,
                      local_window=local_window, q_start=q_start,
                      softmax_scale=softmax_scale, bq=min(bq, Tq),
                      bk=min(bk, Tk), interpret=interpret)


@functools.partial(jax.jit, static_argnames=(
    "causal", "local_window", "q_start", "softmax_scale", "bq", "bk",
    "interpret"))
def _flash_jit(q, k, v, q_pos, *, causal, local_window, q_start,
               softmax_scale, bq, bk, interpret):
    B, Hq, Tq, D = q.shape
    Hkv, Tk = k.shape[1], k.shape[2]
    Dv = v.shape[-1]
    if Hq % Hkv:
        raise ValueError(f"flash_attention: Hq={Hq} not a multiple of "
                         f"Hkv={Hkv}")
    scale = (softmax_scale if softmax_scale is not None
             else 1.0 / math.sqrt(D))
    Tqp, Tkp = _round_up(Tq, bq), _round_up(Tk, bk)
    if q_pos is None:
        q_pos = (q_start or 0) + jnp.arange(Tqp, dtype=jnp.int32)
    else:
        q_pos = q_pos.astype(jnp.int32)
        if Tqp != Tq:
            # padded rows continue the position sequence (outputs discarded;
            # monotone positions keep the skip bounds consistent)
            q_pos = jnp.concatenate(
                [q_pos, q_pos[-1] + 1 + jnp.arange(Tqp - Tq, dtype=jnp.int32)])
    pad4 = lambda x, t: (x if x.shape[2] == t else
                         jnp.pad(x, ((0, 0), (0, 0), (0, t - x.shape[2]),
                                     (0, 0))))
    qp = pad4(q, Tqp)
    kp, vp = pad4(k, Tkp), pad4(v, Tkp)
    cfg = FlashCfg(causal=bool(causal), window=int(local_window),
                   scale=float(scale), g=Hq // Hkv, bq=bq, bk=bk,
                   nq=Tqp // bq, nk=Tkp // bk,
                   q_start=(None if q_start is None else int(q_start)),
                   tk_real=Tk, interpret=bool(interpret))
    out = _flash(cfg, qp, kp, vp, q_pos[None])
    return out[:, :, :Tq] if Tqp != Tq else out


# ---------------------------------------------------------------------------
# per-ring-step entries (core/ring_attention.py)
#
# One ring step is one flash call on the resident Q shard against one K/V
# shard.  The fwd step exposes the (out, logsumexp) pair — the online-softmax
# carry the ring merges across steps — and defines NO vjp: ring_attention is
# itself a custom_vjp that re-streams K/V and drives these bwd entries with
# the GLOBAL (merged) lse/delta, which is exactly the flash bwd math for a
# partitioned softmax.
# ---------------------------------------------------------------------------

def _step_cfg_pad(q, k, v, q_pos, *, causal, local_window, q_start,
                  softmax_scale, bq, bk, interpret):
    B, Hq, Tq, D = q.shape
    Hkv, Tk = k.shape[1], k.shape[2]
    if Hq % Hkv:
        raise ValueError(f"flash step: Hq={Hq} not a multiple of Hkv={Hkv}")
    scale = (softmax_scale if softmax_scale is not None
             else 1.0 / math.sqrt(D))
    Tqp, Tkp = _round_up(Tq, bq), _round_up(Tk, bk)
    if q_pos is None:
        q_pos = (q_start or 0) + jnp.arange(Tqp, dtype=jnp.int32)
    else:
        q_pos = q_pos.astype(jnp.int32)
        if Tqp != Tq:
            q_pos = jnp.concatenate(
                [q_pos, q_pos[-1] + 1 + jnp.arange(Tqp - Tq, dtype=jnp.int32)])
    pad4 = lambda x, t: (x if x.shape[2] == t else
                         jnp.pad(x, ((0, 0), (0, 0), (0, t - x.shape[2]),
                                     (0, 0))))
    cfg = FlashCfg(causal=bool(causal), window=int(local_window),
                   scale=float(scale), g=Hq // Hkv, bq=bq, bk=bk,
                   nq=Tqp // bq, nk=Tkp // bk,
                   q_start=(None if q_start is None else int(q_start)),
                   tk_real=Tk, interpret=bool(interpret))
    return (cfg, pad4(q, Tqp), pad4(k, Tkp), pad4(v, Tkp), q_pos[None],
            Tq, Tqp)


def _pad_rows(x, t):
    """Zero-pad dim 2 of [B, H, T] / [B, H, T, D] to t rows."""
    if x.shape[2] == t:
        return x
    pad = [(0, 0)] * x.ndim
    pad[2] = (0, t - x.shape[2])
    return jnp.pad(x, pad)


def _resolve_step_tiles(Tq, Tk, D, causal, bq, bk):
    if bq is None or bk is None:
        from .autotune import flash_tiles
        tq_, tk_ = flash_tiles(Tq, Tk, D, causal=causal)
        bq = bq or tq_
        bk = bk or tk_
    return min(bq, Tq), min(bk, Tk)


def flash_fwd_step(q, k, v, *, causal=True, local_window: int = 0,
                   q_pos=None, q_start: Optional[int] = None,
                   softmax_scale=None, bq=None, bk=None, interpret=False):
    """Flash forward on one K/V shard -> (out [B,Hq,Tq,Dv], lse [B,Hq,Tq]).

    ``out`` is already normalized by this shard's partial softmax sum;
    fully-masked rows produce exact-zero out and a finite (floored) lse, so
    the caller's pairwise logsumexp merge is NaN-free.  No vjp is attached.
    """
    bq, bk = _resolve_step_tiles(q.shape[2], k.shape[2], q.shape[3],
                                 causal, bq, bk)
    return _fwd_step_jit(q, k, v, q_pos, causal=causal,
                         local_window=local_window, q_start=q_start,
                         softmax_scale=softmax_scale, bq=bq, bk=bk,
                         interpret=interpret)


@functools.partial(jax.jit, static_argnames=(
    "causal", "local_window", "q_start", "softmax_scale", "bq", "bk",
    "interpret"))
def _fwd_step_jit(q, k, v, q_pos, *, causal, local_window, q_start,
                  softmax_scale, bq, bk, interpret):
    cfg, qp, kp, vp, qpos, Tq, Tqp = _step_cfg_pad(
        q, k, v, q_pos, causal=causal, local_window=local_window,
        q_start=q_start, softmax_scale=softmax_scale, bq=bq, bk=bk,
        interpret=interpret)
    out, lse = _fwd_call(cfg, qp, kp, vp, qpos)
    if Tqp != Tq:
        out, lse = out[:, :, :Tq], lse[:, :, :Tq]
    return out, lse


def flash_dq_step(q, k, v, dout, lse, delta, *, causal=True,
                  local_window: int = 0, q_pos=None,
                  q_start: Optional[int] = None, softmax_scale=None,
                  bq=None, bk=None, interpret=False):
    """dQ contribution of one K/V shard given the GLOBAL lse/delta."""
    bq, bk = _resolve_step_tiles(q.shape[2], k.shape[2], q.shape[3],
                                 causal, bq, bk)
    return _dq_step_jit(q, k, v, dout, lse, delta, q_pos, causal=causal,
                        local_window=local_window, q_start=q_start,
                        softmax_scale=softmax_scale, bq=bq, bk=bk,
                        interpret=interpret)


@functools.partial(jax.jit, static_argnames=(
    "causal", "local_window", "q_start", "softmax_scale", "bq", "bk",
    "interpret"))
def _dq_step_jit(q, k, v, dout, lse, delta, q_pos, *, causal, local_window,
                 q_start, softmax_scale, bq, bk, interpret):
    cfg, qp, kp, vp, qpos, Tq, Tqp = _step_cfg_pad(
        q, k, v, q_pos, causal=causal, local_window=local_window,
        q_start=q_start, softmax_scale=softmax_scale, bq=bq, bk=bk,
        interpret=interpret)
    # padded q rows carry dout = delta = 0 -> ds = 0, so they contribute
    # nothing and the slice below discards their dq
    dq = _dq_call(cfg, qp, kp, vp, qpos, _pad_rows(lse, Tqp),
                  _pad_rows(delta, Tqp), _pad_rows(dout, Tqp))
    return dq[:, :, :Tq] if Tqp != Tq else dq


def flash_dkv_step(q, k, v, dout, lse, delta, *, causal=True,
                   local_window: int = 0, q_pos=None,
                   q_start: Optional[int] = None, softmax_scale=None,
                   bq=None, bk=None, interpret=False):
    """(dK, dV) contribution of one Q shard against the resident K/V."""
    bq, bk = _resolve_step_tiles(q.shape[2], k.shape[2], q.shape[3],
                                 causal, bq, bk)
    return _dkv_step_jit(q, k, v, dout, lse, delta, q_pos, causal=causal,
                         local_window=local_window, q_start=q_start,
                         softmax_scale=softmax_scale, bq=bq, bk=bk,
                         interpret=interpret)


@functools.partial(jax.jit, static_argnames=(
    "causal", "local_window", "q_start", "softmax_scale", "bq", "bk",
    "interpret"))
def _dkv_step_jit(q, k, v, dout, lse, delta, q_pos, *, causal, local_window,
                  q_start, softmax_scale, bq, bk, interpret):
    cfg, qp, kp, vp, qpos, Tq, Tqp = _step_cfg_pad(
        q, k, v, q_pos, causal=causal, local_window=local_window,
        q_start=q_start, softmax_scale=softmax_scale, bq=bq, bk=bk,
        interpret=interpret)
    Tk = k.shape[2]
    dk, dv = _dkv_call(cfg, qp, kp, vp, qpos, _pad_rows(lse, Tqp),
                       _pad_rows(delta, Tqp), _pad_rows(dout, Tqp))
    if dk.shape[2] != Tk:
        dk, dv = dk[:, :, :Tk], dv[:, :, :Tk]
    return dk, dv
