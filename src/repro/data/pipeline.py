"""Deterministic synthetic data pipeline with host sharding + prefetch.

At 1000+ node scale the data layer must be (a) deterministic per (step,
host) so restarts and elastic re-meshes reproduce the same stream, (b)
host-sharded so no host materializes the global batch, and (c) prefetched
so input never serializes against the step.  This module provides all
three for the synthetic LM stream used by the examples/benchmarks; a real
corpus reader would only replace ``_tokens_for``.
"""
from __future__ import annotations

import queue
import threading
import time
import zlib

import numpy as np
import jax
import jax.numpy as jnp


class SyntheticLMStream:
    """Deterministic tokens: tokens[step, i, t] = hash(step, i, t) % vocab."""

    def __init__(self, vocab_size: int, global_batch: int, seq_len: int,
                 *, seed: int = 0, extras: dict | None = None):
        self.vocab = vocab_size
        self.B = global_batch
        self.S = seq_len
        self.seed = seed
        self.extras = extras or {}

    def _tokens_for(self, step: int) -> np.ndarray:
        rng = np.random.default_rng((self.seed, step))
        return rng.integers(0, self.vocab, (self.B, self.S), dtype=np.int32)

    def batch(self, step: int, *, train: bool = True) -> dict:
        tok = self._tokens_for(step)
        out = {"tokens": tok}
        if train:
            out["labels"] = np.roll(tok, -1, axis=1)
        for name, (sds, _spec) in self.extras.items():
            # stable digest, NOT hash(): str hashing is salted per process
            # (PYTHONHASHSEED), which would break the determinism contract
            # across restarts / elastic re-meshes.
            rng = np.random.default_rng(
                (self.seed, step, zlib.crc32(name.encode("utf-8"))))
            out[name] = rng.standard_normal(sds.shape).astype(sds.dtype)
        return out


class Prefetcher:
    """Background-thread prefetch of device-put batches."""

    def __init__(self, stream: SyntheticLMStream, shardings: dict,
                 start_step: int = 0, depth: int = 2, train: bool = True):
        self.stream = stream
        self.shardings = shardings
        self.q: queue.Queue = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self._step = start_step
        self._exc: BaseException | None = None
        self.train = train
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()

    def _run(self):
        step = self._step
        try:
            while not self._stop.is_set():
                host = self.stream.batch(step, train=self.train)
                dev = {k: jax.device_put(v, self.shardings[k])
                       for k, v in host.items() if k in self.shardings}
                try:
                    self.q.put((step, dev), timeout=1.0)
                except queue.Full:
                    if self._stop.is_set():
                        return
                    continue
                step += 1
        except BaseException as e:  # propagate to the consumer, don't die mute
            self._exc = e

    def next(self, timeout: float = 60.0):
        """Blocking get that re-raises a producer-thread failure promptly
        instead of stalling for the full timeout and surfacing queue.Empty."""
        deadline = time.monotonic() + timeout
        while True:
            if self._exc is not None and self.q.empty():
                # sticky: the producer thread is dead, every subsequent
                # next() must surface the same root cause, not a timeout
                raise self._exc
            remaining = deadline - time.monotonic()
            if remaining <= 0:
                raise TimeoutError(
                    f"prefetcher produced no batch within {timeout:.1f}s")
            try:
                return self.q.get(timeout=min(0.2, remaining))
            except queue.Empty:
                continue

    def stop(self):
        self._stop.set()
        try:
            while True:
                self.q.get_nowait()
        except queue.Empty:
            pass
        self._thread.join(timeout=5.0)
