"""Shared model components: RoPE, streaming (flash-style) attention in pure
JAX, decode attention against a KV cache, init helpers, activations.

All attention math takes [B, T, H, D] tensors that are already *local* views
(heads sharded over `col`, tokens/seq per the plan) — no mesh axes here except
what the caller passes in explicitly via gathered KV.
"""
from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp
from jax import lax


# ---------------------------------------------------------------------------
# init helpers
# ---------------------------------------------------------------------------

def winit(key, shape, scale=0.02, dtype=jnp.float32):
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


def winit_padded(key, gen_shape, padded_shape, scale=0.02, dtype=jnp.float32):
    """Generate at the *logical* shape, zero-pad to the sharded shape — keeps
    init values identical across mesh factorizations (padding differs)."""
    w = winit(key, gen_shape, scale, dtype)
    pads = [(0, p - g) for g, p in zip(gen_shape, padded_shape)]
    if any(p != (0, 0) for p in pads):
        w = jnp.pad(w, pads)
    return w


def zinit(_key, shape, dtype=jnp.float32):
    return jnp.zeros(shape, dtype)


# ---------------------------------------------------------------------------
# activations
# ---------------------------------------------------------------------------

def vma_like(x, *refs):
    """Give ``x`` the union of the refs' varying-manifest-axes so it can seed
    a scan carry inside shard_map (numerical no-op; works outside shard_map
    too, unlike an explicit pvary with axis names)."""
    tie = sum((r.reshape(-1)[0] * 0).astype(jnp.float32) for r in refs)
    return x + tie.astype(x.dtype)


def mlp_act(name: str):
    if name == "silu":
        return jax.nn.silu
    if name == "gelu":
        return jax.nn.gelu
    if name == "relu2":  # squared ReLU (nemotron-4)
        return lambda x: jnp.square(jax.nn.relu(x))
    raise ValueError(name)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x, positions, theta: float = 1e4):
    """x: [B, T, H, D]; positions: [T] or [B, T] global position ids."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)                        # [D/2]
    if positions.ndim == 1:
        ang = positions[:, None].astype(jnp.float32) * freqs[None, :]   # [T, D/2]
        ang = ang[None, :, None, :]                     # [1, T, 1, D/2]
    else:
        ang = positions[..., None].astype(jnp.float32) * freqs          # [B, T, D/2]
        ang = ang[:, :, None, :]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    y = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return y.astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention dispatch (DESIGN.md §10): every model-side attention call goes
# through one of the dispatchers below, which route to the fused Pallas
# kernels (kernels/flash_attention.py, kernels/paged_attention.py) or the
# pure-jnp reference paths depending on the resolved ``attn_impl``.
# ---------------------------------------------------------------------------

def attention(q, k, v, *, q_pos, kv_pos, causal: bool = True,
              local_window: int = 0, q_chunk: int = 512,
              kv_chunk: int = 512, softmax_scale=None, impl: str = "jnp",
              q_start=None):
    """Training/prefill attention in the model layout [B, T, H, D].

    Dispatches on ``impl`` (ParallelContext.attn_impl): "pallas" runs the
    fused flash kernel with the causal/window masks driven by ``q_pos``
    (``q_start`` is the static q-row offset enabling block skipping; None
    for traced seq-sharded positions).  The kernel contract assumes KV rows
    sit at positions 0..Tk-1, which every call site satisfies (kv_pos is
    the gathered full-sequence arange; the non-causal cross-attention
    sites pass all-zero positions and no window, where positions are
    inert).  GQA is contiguous Hq = g * Hkv in both paths.
    """
    from ..kernels.ops import effective_attn_impl, flash_attention_op
    if effective_attn_impl(impl) == "pallas":
        out = flash_attention_op(
            q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
            v.transpose(0, 2, 1, 3), causal=causal,
            local_window=local_window,
            q_pos=None if q_start is not None else q_pos,
            q_start=q_start, softmax_scale=softmax_scale)
        return out.transpose(0, 2, 1, 3)
    return blockwise_attention(q, k, v, q_pos=q_pos, kv_pos=kv_pos,
                               causal=causal, local_window=local_window,
                               q_chunk=q_chunk, kv_chunk=kv_chunk,
                               softmax_scale=softmax_scale)


# ---------------------------------------------------------------------------
# Streaming attention (pure-jnp flash): O(block) memory, numerically stable.
# v1 computes every (q-block, kv-block) pair and masks — the causal upper
# triangle is wasted compute; the Pallas kernel removes it (and is wired as
# the default TPU data path via attn_impl, DESIGN.md §10).
# ---------------------------------------------------------------------------

def blockwise_attention(q, k, v, *, q_pos, kv_pos, causal: bool = True,
                        local_window: int = 0, q_chunk: int = 512,
                        kv_chunk: int = 512, softmax_scale=None):
    """q: [B, Tq, Hq, D]; k,v: [B, Tk, Hkv, Dv?]; GQA via Hq = g * Hkv.

    q_pos: [Tq] global positions of queries; kv_pos: [Tk].
    local_window > 0 limits attention to the last `local_window` positions.
    Returns [B, Tq, Hq, Dv].
    """
    B, Tq, Hq, D = q.shape
    Tk, Hkv = k.shape[1], k.shape[2]
    Dv = v.shape[-1]
    g = Hq // Hkv
    scale = softmax_scale if softmax_scale is not None else 1.0 / math.sqrt(D)

    cq = min(q_chunk, Tq)
    while Tq % cq:
        cq -= 1
    ck = min(kv_chunk, Tk)
    while Tk % ck:
        ck -= 1
    nq, nk = Tq // cq, Tk // ck

    qr = q.reshape(B, nq, cq, Hkv, g, D)
    kr = k.reshape(B, nk, ck, Hkv, D)
    vr = v.reshape(B, nk, ck, Hkv, Dv)
    qpr = q_pos.reshape(nq, cq)
    kpr = kv_pos.reshape(nk, ck)

    def q_block(args):
        qb, qp = args                                  # [B, cq, Hkv, g, D], [cq]

        def kv_step(carry, blk):
            m, l, acc = carry
            kb, vb, kp = blk                           # [B, ck, Hkv, D], ...
            s = jnp.einsum("bqhgd,bkhd->bhgqk", qb, kb,
                           preferred_element_type=jnp.float32) * scale
            mask = jnp.ones((cq, ck), bool)
            if causal:
                mask &= qp[:, None] >= kp[None, :]
            if local_window > 0:
                mask &= kp[None, :] > (qp[:, None] - local_window)
            s = jnp.where(mask[None, None, None], s, -jnp.inf)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            # guard: fully-masked rows keep m=-inf; exp(-inf - -inf) -> nan
            m_safe = jnp.where(jnp.isneginf(m_new), 0.0, m_new)
            p = jnp.exp(s - m_safe[..., None])
            corr = jnp.exp(jnp.where(jnp.isneginf(m), -jnp.inf, m - m_safe))
            l = l * corr + jnp.sum(p, axis=-1)
            acc = acc * corr[..., None] + jnp.einsum(
                "bhgqk,bkhd->bhgqd", p.astype(vb.dtype), vb,
                preferred_element_type=jnp.float32)
            return (m_new, l, acc), None

        m0 = vma_like(jnp.full((B, Hkv, g, cq), -jnp.inf, jnp.float32), qb, k, v)
        l0 = vma_like(jnp.zeros((B, Hkv, g, cq), jnp.float32), qb, k, v)
        a0 = vma_like(jnp.zeros((B, Hkv, g, cq, Dv), jnp.float32), qb, k, v)
        (m, l, acc), _ = lax.scan(kv_step, (m0, l0, a0), (kr.swapaxes(0, 1),
                                                          vr.swapaxes(0, 1),
                                                          kpr))
        l = jnp.where(l == 0.0, 1.0, l)
        out = acc / l[..., None]                        # [B, Hkv, g, cq, Dv]
        return out.transpose(0, 3, 1, 2, 4)             # [B, cq, Hkv, g, Dv]

    outs = lax.map(q_block, (qr.swapaxes(0, 1), qpr))   # [nq, B, cq, Hkv, g, Dv]
    out = outs.swapaxes(0, 1).reshape(B, Tq, Hq, Dv)
    return out.astype(q.dtype)


def decode_pos_mask(cur_pos, S: int, local_window: int = 0):
    """[B, 1, S] validity mask for single-step decode attention.

    Position-only (layer-independent), so callers hoist it OUT of the layer
    scan and pass it to every block's decode_attention instead of each
    layer recomputing the arange/compare chain (jnp fallback path)."""
    cur_pos = jnp.asarray(cur_pos)
    if cur_pos.ndim == 0:
        cur_pos = cur_pos[None]
    cur = cur_pos[:, None, None]                         # [B, 1, 1]
    pos = jnp.arange(S)
    mask = pos[None, None, :] <= cur
    if local_window > 0:
        mask &= pos[None, None, :] > (cur - local_window)
    return mask


def _decode_bs(S: int) -> int:
    """Page size used to view a dense cache as a pool (pallas decode)."""
    bs = min(128, S)
    while S % bs:
        bs -= 1
    return bs


def _paged_kernel(q, pool_k, pool_v, table, pos, kv_map, *, local_window,
                  softmax_scale):
    """Shared pallas-decode dispatch: default the GQA map to the contiguous
    grouping and run the block-table kernel (used by decode_attention's
    pool view and paged_attention)."""
    from ..kernels.ops import paged_attention_op
    Hq = q.shape[1]
    if kv_map is None:
        kv_map = jnp.arange(Hq, dtype=jnp.int32) // (Hq // pool_k.shape[2])
    return paged_attention_op(q, pool_k, pool_v, table, pos, kv_map,
                              local_window=local_window,
                              softmax_scale=softmax_scale)


def decode_attention(q, k_cache, v_cache, *, cur_pos, kv_map=None,
                     local_window: int = 0, softmax_scale=None,
                     pos_mask=None, impl: str = "jnp"):
    """Single-step attention against a cache.

    q: [B, Hq, D]; k_cache/v_cache: [B, S, Hkv, D]; cur_pos: scalar int —
    number of valid cache entries (new token's position is cur_pos) — or a
    [B] vector of per-request positions (continuous batching mixes lengths).
    kv_map: optional [Hq] map from q-head to kv-head (non-uniform GQA);
    default uses Hq = g*Hkv contiguous grouping.  ``pos_mask`` is the
    hoisted decode_pos_mask(cur_pos, S, local_window) (jnp path only).
    With impl="pallas" the dense cache is viewed as a contiguous page pool
    and the block-table decode kernel runs on it directly.
    """
    from ..kernels.ops import effective_attn_impl
    B, Hq, D = q.shape
    S, Hkv = k_cache.shape[1], k_cache.shape[2]
    Dv = v_cache.shape[-1]
    if effective_attn_impl(impl) == "pallas":
        bs = _decode_bs(S)
        nb = S // bs
        pool_k = k_cache.reshape(B * nb, bs, Hkv, D)
        pool_v = v_cache.reshape(B * nb, bs, Hkv, Dv)
        table = jnp.arange(B * nb, dtype=jnp.int32).reshape(B, nb)
        pos = (jnp.broadcast_to(cur_pos, (B,)) if jnp.ndim(cur_pos) == 0
               else cur_pos)
        return _paged_kernel(q, pool_k, pool_v, table, pos, kv_map,
                             local_window=local_window,
                             softmax_scale=softmax_scale)
    scale = softmax_scale if softmax_scale is not None else 1.0 / math.sqrt(D)
    if kv_map is not None:
        kc = jnp.take(k_cache, kv_map, axis=2)           # [B, S, Hq, D]
        vc = jnp.take(v_cache, kv_map, axis=2)
        s = jnp.einsum("bhd,bshd->bhs", q, kc,
                       preferred_element_type=jnp.float32) * scale
    else:
        g = Hq // Hkv
        qg = q.reshape(B, Hkv, g, D)
        s = jnp.einsum("bhgd,bshd->bhgs", qg, k_cache,
                       preferred_element_type=jnp.float32) * scale
        s = s.reshape(B, Hq, S)
        vc = None
    if pos_mask is None:
        pos_mask = decode_pos_mask(cur_pos, S, local_window)
    s = jnp.where(pos_mask, s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    if kv_map is not None:
        out = jnp.einsum("bhs,bshd->bhd", p.astype(vc.dtype), vc,
                         preferred_element_type=jnp.float32)
    else:
        g = Hq // Hkv
        pg = p.reshape(B, Hkv, g, S)
        out = jnp.einsum("bhgs,bshd->bhgd", pg.astype(v_cache.dtype), v_cache,
                         preferred_element_type=jnp.float32)
        out = out.reshape(B, Hq, Dv)
    return out.astype(q.dtype)


def cache_update(cache, new_k, new_v, cur_pos):
    """Write one step's K/V into the cache at cur_pos. new_k: [B, 1, Hkv, D]."""
    k = lax.dynamic_update_slice_in_dim(cache["k"], new_k.astype(cache["k"].dtype),
                                        cur_pos, axis=1)
    v = lax.dynamic_update_slice_in_dim(cache["v"], new_v.astype(cache["v"].dtype),
                                        cur_pos, axis=1)
    return dict(cache, k=k, v=v)


# ---------------------------------------------------------------------------
# Paged KV cache primitives (serve/ continuous batching; DESIGN.md §7).
#
# A layer's pool is [P_loc, bs, Hkv, D]: P_loc physical blocks of bs
# positions each.  A block table [B, nb] maps request b's logical block i
# (positions i*bs .. i*bs+bs-1) to a physical block id; ids here are LOCAL
# (the step builder subtracts the device group's offset).  Retired/inactive
# batch slots point every table entry at the group's scratch block and are
# masked by their length, so the math stays fixed-shape across steps.
# ---------------------------------------------------------------------------

def paged_gather(pool_k, pool_v, table, kv_map=None):
    """Gather a request-major contiguous KV view from the block pool.

    pool_k/pool_v: [P_loc, bs, Hkv, D]; table: [B, nb] local block ids.
    Returns k, v: [B, nb*bs, Hkv, D] in logical position order.

    ``kv_map`` ([Hq] q-head -> kv-head) folds the GQA head expansion into
    the SAME gather (one [B, pool, Hq, D] materialization) instead of the
    old gather-then-take chain that built [B, pool, Hkv, D] first and a
    second [B, pool, Hq, D] on top of it.
    """
    B, nb = table.shape
    bs = pool_k.shape[1]
    idx = table.reshape(-1)
    if kv_map is None:
        k = jnp.take(pool_k, idx, axis=0)
        v = jnp.take(pool_v, idx, axis=0)
        sh = (B, nb * bs) + pool_k.shape[2:]
        return k.reshape(sh), v.reshape(sh)
    Hq = kv_map.shape[0]
    # one combined (page, head) gather: [B*nb, Hq, bs, D] -> [B, pool, Hq, D]
    k = pool_k[idx[:, None], :, kv_map[None, :], :]
    v = pool_v[idx[:, None], :, kv_map[None, :], :]
    sh = (B, nb * bs, Hq, pool_k.shape[-1])
    return (k.swapaxes(1, 2).reshape(sh),
            v.swapaxes(1, 2).reshape((sh[:3]) + (pool_v.shape[-1],)))


def paged_step_indices(table, pos, bs: int):
    """(blk, off) scatter coordinates of each request's current position.

    Position-only, so the serve step computes them ONCE and reuses them for
    every layer's paged_update inside the scan instead of re-deriving the
    take_along_axis per layer."""
    blk = jnp.take_along_axis(table, (pos // bs)[:, None], axis=1)[:, 0]
    return blk, pos % bs


def paged_update(pool, table, pos, new_k, new_v, idx=None):
    """Scatter one step's K/V into the pool at each request's position.

    pool: {"k","v": [P_loc, bs, Hkv, D]}; table: [B, nb]; pos: [B] target
    position (count of already-cached tokens); new_k/new_v: [B, 1, Hkv, D].
    ``idx`` is the hoisted paged_step_indices(table, pos, bs).
    """
    bs = pool["k"].shape[1]
    blk, off = idx if idx is not None else paged_step_indices(table, pos, bs)
    k = pool["k"].at[blk, off].set(new_k[:, 0].astype(pool["k"].dtype))
    v = pool["v"].at[blk, off].set(new_v[:, 0].astype(pool["v"].dtype))
    return dict(pool, k=k, v=v)


def paged_attention(q, pool_k, pool_v, table, pos, *, kv_map=None,
                    local_window: int = 0, softmax_scale=None,
                    pos_mask=None, impl: str = "jnp"):
    """Single-step attention against a paged pool.

    q: [B, Hq, D]; pos: [B] per-request current position (the incoming
    token's position; its K/V must already be in the pool — call
    paged_update first, matching the dense cache_update-then-attend order).

    impl="pallas" walks the block table inside the decode kernel — no
    paged_gather materialization at all (kernels/paged_attention.py).  The
    jnp fallback gathers once (kv_map folded in) and reuses the hoisted
    ``pos_mask`` ([B, 1, nb*bs]) across the layer scan.
    """
    from ..kernels.ops import effective_attn_impl
    if effective_attn_impl(impl) == "pallas":
        return _paged_kernel(q, pool_k, pool_v, table, pos, kv_map,
                             local_window=local_window,
                             softmax_scale=softmax_scale)
    k, v = paged_gather(pool_k, pool_v, table, kv_map)
    return decode_attention(q, k, v, cur_pos=pos, kv_map=None,
                            local_window=local_window,
                            softmax_scale=softmax_scale, pos_mask=pos_mask)


# ---------------------------------------------------------------------------
# Chunked-prefill primitives (serve/ prefix cache + chunk interleave;
# DESIGN.md §12).  A chunk step processes C consecutive prompt positions per
# batch slot against the same paged pool the decode step uses; per-slot
# chunk starts differ, so positions/masks carry a [B, C] batch axis that
# the train-path blockwise attention (one shared q_pos vector) cannot
# express.
# ---------------------------------------------------------------------------

def chunk_pos_mask(positions, S: int, local_window: int = 0):
    """[B, C, S] causal validity mask for a prefill chunk.

    positions: [B, C] absolute q positions (garbage in padded rows is fine:
    the attend below keeps every row finite and callers only read rows
    inside their chunk length).  Position-only — hoisted out of the layer
    scan like decode_pos_mask."""
    kv = jnp.arange(S)
    mask = kv[None, None, :] <= positions[:, :, None]
    if local_window > 0:
        mask &= kv[None, None, :] > (positions[:, :, None] - local_window)
    return mask


def paged_chunk_indices(table, positions, bs: int, valid):
    """(blk, off) [B, C] scatter coordinates for a whole prefill chunk.

    Padded entries (``valid`` False) are redirected to the group's scratch
    block (local id 0) at offset 0 — garbage writes land where they are
    masked by construction.  Position-only; hoisted out of the layer scan."""
    nb = table.shape[1]
    blk_i = jnp.clip(positions // bs, 0, nb - 1)
    blk = jnp.take_along_axis(table, blk_i, axis=1)
    blk = jnp.where(valid, blk, 0)
    off = jnp.where(valid, positions % bs, 0)
    return blk, off


def paged_update_chunk(pool, table, positions, new_k, new_v, valid,
                       idx=None):
    """Scatter a C-position chunk of K/V into the pool.

    pool: {"k","v": [P_loc, bs, Hkv, D]}; positions: [B, C] absolute;
    new_k/new_v: [B, C, Hkv, D]; valid: [B, C].  ``idx`` is the hoisted
    paged_chunk_indices.  Invalid entries write garbage into the scratch
    block (masked by contract), exactly like retired decode slots."""
    bs = pool["k"].shape[1]
    blk, off = (idx if idx is not None
                else paged_chunk_indices(table, positions, bs, valid))
    k = pool["k"].at[blk, off].set(new_k.astype(pool["k"].dtype))
    v = pool["v"].at[blk, off].set(new_v.astype(pool["v"].dtype))
    return dict(pool, k=k, v=v)


def chunk_attention(q, k_cache, v_cache, *, mask, softmax_scale=None,
                    kv_map=None):
    """Causal attention of a C-token chunk against a contiguous KV view.

    q: [B, C, Hq, D]; k_cache/v_cache: [B, S, H, D] (H == Hq when the GQA
    map was folded into the gather, else Hkv with contiguous grouping);
    mask: [B, C, S] from chunk_pos_mask.  Full-score fp32 masked softmax —
    no online accumulation — so the result is independent of how the
    prompt was split into chunks (chunked == monolithic prefill
    numerics-for-numerics, which the greedy parity checks lean on).
    Fully-masked rows (padding) come out zero, not NaN."""
    B, C, Hq, D = q.shape
    S, H = k_cache.shape[1], k_cache.shape[2]
    scale = softmax_scale if softmax_scale is not None else 1.0 / math.sqrt(D)
    if kv_map is not None:
        k_cache = jnp.take(k_cache, kv_map, axis=2)
        v_cache = jnp.take(v_cache, kv_map, axis=2)
        H = Hq
    row_has = jnp.any(mask, axis=-1)[:, None, :, None]   # [B, 1, C, 1]
    if H == Hq:
        s = jnp.einsum("bchd,bshd->bhcs", q, k_cache,
                       preferred_element_type=jnp.float32) * scale
        s = jnp.where(mask[:, None], s, -jnp.inf)
        s = jnp.where(row_has, s, 0.0)
        p = jax.nn.softmax(s, axis=-1)
        p = jnp.where(row_has, p, 0.0)
        out = jnp.einsum("bhcs,bshd->bhcd", p.astype(v_cache.dtype), v_cache,
                         preferred_element_type=jnp.float32)
        out = out.transpose(0, 2, 1, 3)                  # [B, C, Hq, Dv]
    else:
        g = Hq // H
        qg = q.reshape(B, C, H, g, D)
        s = jnp.einsum("bchgd,bshd->bhgcs", qg, k_cache,
                       preferred_element_type=jnp.float32) * scale
        s = jnp.where(mask[:, None, None], s, -jnp.inf)
        rh = row_has[:, :, None]                         # [B, 1, 1, C, 1]
        s = jnp.where(rh, s, 0.0)
        p = jax.nn.softmax(s, axis=-1)
        p = jnp.where(rh, p, 0.0)
        out = jnp.einsum("bhgcs,bshd->bhgcd", p.astype(v_cache.dtype),
                         v_cache, preferred_element_type=jnp.float32)
        out = out.transpose(0, 3, 1, 2, 4).reshape(
            B, C, Hq, v_cache.shape[-1])
    return out.astype(q.dtype)
