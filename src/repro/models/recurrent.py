"""RecurrentGemma (Griffin): RG-LRU recurrent blocks + local attention, 1:2.

Applicability note (DESIGN.md §6): the RG-LRU recurrence is elementwise /
diagonal — there is no matmul for Tesseract to split.  The surrounding
projections (W_x, W_y, W_o, MLP, attention QKV/O) are tesseract-sharded; the
recurrence itself shards over features (col) and runs locally over time via
an associative scan.  Sequence sharding chains shard states with the
distributed linear scan.  Gate weights are per-channel (diagonal) — a
documented simplification of the block-diagonal gates in the Griffin code.

Layer pattern: scan over superblocks of (rec, rec, attn); leftover layers
(38 % 3 = 2) run as a trailing stacked scan of rec blocks.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from ..core import collectives as cc
from . import common as cm
from .transformer import DenseLM, maybe_remat, ops_last_token

C_RGLRU = 8.0


class RecurrentLM(DenseLM):
    supports_pipeline = False  # custom loss not stage-decomposed
    supports_seq_shard = False  # LRU recurrence crosses seq-shard bounds

    def __init__(self, cfg, ctx, run):
        super().__init__(cfg, ctx, run)
        if ctx.mode == "megatron1d":
            raise NotImplementedError("hybrid arch runs in tesseract modes")
        self.lru_w = cfg.lru_width or cfg.d_model
        self.n_super = cfg.num_layers // 3
        self.n_rest = cfg.num_layers % 3   # trailing rec blocks

    # ------------------------------------------------------------- params
    def _rec_init(self, key):
        cfg = self.cfg
        h, W = cfg.d_model, self.lru_w
        ks = jax.random.split(key, 6)
        return {
            "ln": jnp.zeros((h,), self.pdt),
            "w_y": cm.winit(ks[0], (h, W), dtype=self.pdt),
            "w_xb": cm.winit(ks[1], (h, W), dtype=self.pdt),
            "conv_w": cm.winit(ks[2], (4, W), 0.2, self.pdt),
            "gate_a_w": jnp.zeros((W,), self.pdt),   # diagonal gates
            "gate_a_b": jnp.zeros((W,), self.pdt),
            "gate_x_w": jnp.zeros((W,), self.pdt),
            "gate_x_b": jnp.zeros((W,), self.pdt),
            "lam": jnp.full((W,), 2.0, self.pdt),    # a = sigmoid(lam)^(c*r)
            "w_o": cm.winit(ks[3], (W, h), dtype=self.pdt),
            "ln2": jnp.zeros((h,), self.pdt),
            "w_gate": cm.winit(ks[4], (h, cfg.d_ff), dtype=self.pdt),
            "w_up": cm.winit(ks[5], (h, cfg.d_ff), dtype=self.pdt),
            "w_down": cm.winit(jax.random.fold_in(key, 9), (cfg.d_ff, h),
                               dtype=self.pdt),
        }

    def _super_init(self, key):
        ks = jax.random.split(key, 3)
        return {
            "rec": jax.vmap(self._rec_init)(ks[:2]),
            "attn": super()._block_init(ks[2]),
        }

    def init(self, key):
        cfg = self.cfg
        k_e, k_h, k_b, k_r = jax.random.split(key, 4)
        p = {
            "embed": cm.winit_padded(k_e, (cfg.vocab_size, cfg.d_model),
                                     (self.v_pad, cfg.d_model), dtype=self.pdt),
            "head": cm.winit_padded(k_h, (cfg.vocab_size, cfg.d_model),
                                    (self.v_pad, cfg.d_model), dtype=self.pdt),
            "ln_f": jnp.zeros((cfg.d_model,), self.pdt),
            "supers": jax.vmap(self._super_init)(
                jax.random.split(k_b, self.n_super)),
        }
        if self.n_rest:
            p["rest"] = jax.vmap(self._rec_init)(
                jax.random.split(k_r, self.n_rest))
        return p

    def _rec_specs(self, ops):
        return {
            "ln": ops.spec_norm(True),
            "w_y": ops.spec_w2d(True), "w_xb": ops.spec_w2d(True),
            # [L, K, W]: channel dim over col
            "conv_w": __import__("jax").sharding.PartitionSpec(None, None, "col"),
            "gate_a_w": ops.spec_vec(True), "gate_a_b": ops.spec_vec(True),
            "gate_x_w": ops.spec_vec(True), "gate_x_b": ops.spec_vec(True),
            "lam": ops.spec_vec(True),
            "w_o": ops.spec_w_down(True),
            "ln2": ops.spec_norm(True),
            "w_gate": ops.spec_w2d(True), "w_up": ops.spec_w2d(True),
            "w_down": ops.spec_w_down(True),
        }

    def specs(self, ops):
        from jax.sharding import PartitionSpec as P
        stack = lambda s: P(*((None,) + tuple(s)))
        rec_stacked = self._rec_specs(ops)        # [n, ...] single stack
        s = {
            "embed": ops.spec_embed(), "head": ops.spec_head(),
            "ln_f": ops.spec_norm(False),
            "supers": {
                # rec leaves are [n_super, 2, ...] -> one extra None
                "rec": {k: stack(v) for k, v in rec_stacked.items()},
                # attn leaves are [n_super, ...] -> stacked specs directly
                "attn": DenseLM._block_specs(self, ops),
            },
        }
        if self.n_rest:
            s["rest"] = rec_stacked               # [n_rest, ...]
        return s

    def tess_weight_names(self):
        names = super().tess_weight_names()
        names.update({"w_y", "w_xb", "w_o"})
        return names

    # ------------------------------------------------------------- RG-LRU
    def _rglru(self, p, xb, ops, h0=None):
        """xb: [B,T,W/q] (post-conv).  Returns (out, h_last)."""
        ctx = self.ctx
        xf = xb.astype(jnp.float32)
        r = jax.nn.sigmoid(xf * p["gate_a_w"].astype(jnp.float32)
                           + p["gate_a_b"].astype(jnp.float32))
        i = jax.nn.sigmoid(xf * p["gate_x_w"].astype(jnp.float32)
                           + p["gate_x_b"].astype(jnp.float32))
        log_lam = jax.nn.log_sigmoid(p["lam"].astype(jnp.float32))
        log_a = C_RGLRU * r * log_lam                        # [B,T,W]
        a = jnp.exp(log_a)
        b = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) * (i * xf)

        def comb(e1, e2):
            a1, b1 = e1
            a2, b2 = e2
            return a1 * a2, a2 * b1 + b2

        A_cum, B_cum = lax.associative_scan(comb, (a, b), axis=1)
        h = B_cum  # h_t assuming h_{-1} = 0
        if ops.plan.seq_sharded:
            axes = (ctx.axis_depth, ctx.axis_row)
            h_in = cc.distributed_linear_scan_carry(
                A_cum[:, -1, :], B_cum[:, -1, :], axes)      # [B,W]
            h = h + A_cum * h_in[:, None, :]
        elif h0 is not None:
            h = h + A_cum * h0[:, None, :].astype(jnp.float32)
        return h.astype(xb.dtype), h[:, -1, :]

    def _rec_block(self, p, x, ops, h0=None, conv_halo=None, want_state=False):
        cfg = self.cfg
        h = self._norm(ops, x, p["ln"])
        y = jax.nn.gelu(ops.linear(h, p["w_y"]))
        xb = ops.linear(h, p["w_xb"])
        xb_raw = xb
        K = p["conv_w"].shape[0]
        if conv_halo is None and ops.plan.seq_sharded:
            conv_halo = cc.halo_exchange_left(
                xb, (self.ctx.axis_depth, self.ctx.axis_row), K - 1, 1)
        if conv_halo is None:
            conv_halo = jnp.zeros((xb.shape[0], K - 1, xb.shape[-1]), xb.dtype)
        xp = jnp.concatenate([conv_halo, xb], axis=1)
        xb = sum(xp[:, K - 1 - j: xp.shape[1] - j, :] * p["conv_w"][K - 1 - j]
                 for j in range(K))
        lru, h_last = self._rglru(p, xb, ops, h0)
        out = ops.linear(lru * y, p["w_o"])
        x = x + out
        h2 = self._norm(ops, x, p["ln2"])
        x = x + self._mlp(p, h2, ops)
        if want_state:
            tail = xb_raw[:, -(K - 1):, :]
            if ops.plan.seq_sharded:
                seq_axes = (self.ctx.axis_depth, self.ctx.axis_row)
                h_last = cc.last_shard_value(h_last, seq_axes)
                tail = cc.last_shard_value(tail, seq_axes)
            return x, (h_last, tail)
        return x

    # -------------------------------------------------------------- train
    def loss(self, params, batch, ops):
        cfg = self.cfg
        x = ops.embed(batch["tokens"], params["embed"]).astype(self.cdt)
        T_loc = x.shape[1]
        n_seq = (self.ctx.depth * self.ctx.rows if ops.plan.seq_sharded else 1)
        full_kv_pos = jnp.arange(T_loc * n_seq)
        cast = lambda t: jax.tree.map(lambda a: a.astype(self.cdt)
                                      if a.dtype == self.pdt else a, t)

        def super_body(xx, sp):
            def rec_body(c, rp):
                return self._rec_block(cast(rp), c, ops), None
            xx, _ = lax.scan(rec_body, xx, sp["rec"])
            xx = DenseLM._block_train(self, cast(sp["attn"]), xx, ops,
                                      full_kv_pos)
            return xx, None

        x, _ = lax.scan(maybe_remat(super_body, self.run), x, params["supers"])
        if self.n_rest:
            def rec_body(c, rp):
                return self._rec_block(cast(rp), c, ops), None
            x, _ = lax.scan(rec_body, x, params["rest"])
        x = self._norm(ops, x, params["ln_f"])
        loss_sum, cnt = ops.ce_loss(
            x, params["head"].astype(self.cdt), batch["labels"],
            vocab_real=cfg.vocab_size, loss_chunk=self.run.loss_chunk,
            label_mask=batch.get("mask"))
        loss_sum = lax.psum(loss_sum, self.ctx.axis_data)
        cnt = lax.psum(cnt, self.ctx.axis_data)
        return loss_sum / jnp.maximum(cnt, 1.0)

    # ------------------------------------------------------------ serving
    def cache_abstract(self, batch_global: int, seq_len: int, plan):
        from jax import ShapeDtypeStruct as Sds
        from jax.sharding import PartitionSpec as P
        cfg = self.cfg
        W = self.lru_w
        n_rec = self.n_super * 2 + self.n_rest
        n_attn = self.n_super
        win = min(cfg.local_window, seq_len)
        tok = (("data", "depth", "row") if plan.kind == "decode"
               else "data" if plan.kind == "decode_dp" else None)
        sds = {
            "lru": Sds((n_rec, batch_global, W), jnp.float32),
            "conv": Sds((n_rec, batch_global, 3, W), self.cdt),
            "k": Sds((n_attn, batch_global, win, cfg.num_kv_heads, self.D),
                     self.cdt),
            "v": Sds((n_attn, batch_global, win, cfg.num_kv_heads, self.D),
                     self.cdt),
        }
        kv_sp = P(None, tok, None, "col" if self.kv_shard else None, None)
        specs = {"lru": P(None, tok, "col"), "conv": P(None, tok, None, "col"),
                 "k": kv_sp, "v": kv_sp}
        return sds, specs

    def decode(self, params, cache, ids, pos, ops):
        """One token; local-attention caches are ring buffers of size window."""
        cfg = self.cfg
        x = ops.embed(ids, params["embed"]).astype(self.cdt)
        cast = lambda t: jax.tree.map(lambda a: a.astype(self.cdt)
                                      if a.dtype == self.pdt else a, t)
        win = cache["k"].shape[2]
        slot = pos % win

        def rec_decode(xx, rp, lru_st, conv_st):
            rp = cast(rp)
            h = self._norm(ops, xx, rp["ln"])
            y = jax.nn.gelu(ops.linear(h, rp["w_y"]))[:, 0]
            xb = ops.linear(h, rp["w_xb"])[:, 0]             # [B,W/q]
            xp = jnp.concatenate([conv_st, xb[:, None, :]], axis=1)  # [B,4,W]
            xc = jnp.einsum("bkc,kc->bc", xp, rp["conv_w"])
            xf = xc.astype(jnp.float32)
            r = jax.nn.sigmoid(xf * rp["gate_a_w"].astype(jnp.float32)
                               + rp["gate_a_b"].astype(jnp.float32))
            i = jax.nn.sigmoid(xf * rp["gate_x_w"].astype(jnp.float32)
                               + rp["gate_x_b"].astype(jnp.float32))
            log_lam = jax.nn.log_sigmoid(rp["lam"].astype(jnp.float32))
            log_a = C_RGLRU * r * log_lam
            a = jnp.exp(log_a)
            bterm = jnp.sqrt(jnp.maximum(1 - jnp.exp(2 * log_a), 1e-12)) * (i * xf)
            hnew = a * lru_st + bterm
            out = ops.linear((hnew.astype(xx.dtype) * y)[:, None, :], rp["w_o"])
            xx = xx + out
            h2 = self._norm(ops, xx, rp["ln2"])
            xx = xx + self._mlp(rp, h2, ops)
            return xx, hnew, xp[:, 1:, :].astype(conv_st.dtype)

        def attn_decode(xx, ap, kc, vc):
            ap = cast(ap)
            h = self._norm(ops, xx, ap["ln1"])
            positions = jnp.full((1,), pos, jnp.int32)
            q, k, v = self._qkv(ap, h, ops, positions)
            kc = lax.dynamic_update_slice_in_dim(kc, k.astype(kc.dtype), slot, 1)
            vc = lax.dynamic_update_slice_in_dim(vc, v.astype(vc.dtype), slot, 1)
            # ring buffer: positions of slots
            base = jnp.arange(win)
            slot_pos = jnp.where(base <= slot, pos - slot + base,
                                 pos - slot + base - win)
            kv_map = None if self.kv_shard else self._kv_map(ops)
            qh = q[:, 0]
            if kv_map is not None:
                kk = jnp.take(kc, kv_map, axis=2)
                vv = jnp.take(vc, kv_map, axis=2)
                s = jnp.einsum("bhd,bshd->bhs", qh, kk,
                               preferred_element_type=jnp.float32)
            else:
                g = qh.shape[1] // kc.shape[2]
                qg = qh.reshape(qh.shape[0], kc.shape[2], g, -1)
                s = jnp.einsum("bhgd,bshd->bhgs", qg, kc,
                               preferred_element_type=jnp.float32)
                s = s.reshape(qh.shape[0], qh.shape[1], win)
                vv = None
            s = s / jnp.sqrt(self.D).astype(jnp.float32)
            valid = (slot_pos[None, None, :] >= 0) & \
                    (slot_pos[None, None, :] <= pos)
            s = jnp.where(valid, s, -jnp.inf)
            pw = jax.nn.softmax(s, axis=-1)
            if kv_map is not None:
                o = jnp.einsum("bhs,bshd->bhd", pw.astype(vv.dtype), vv)
            else:
                g = qh.shape[1] // kc.shape[2]
                pg = pw.reshape(pw.shape[0], kc.shape[2], g, win)
                o = jnp.einsum("bhgs,bshd->bhgd", pg.astype(vc.dtype), vc)
                o = o.reshape(qh.shape[0], qh.shape[1], -1)
            xx = xx + self._attn_out(ap, o[:, None], ops, self._head_mask(ops))
            h2 = self._norm(ops, xx, ap["ln2"])
            xx = xx + self._mlp(ap, h2, ops)
            return xx, kc, vc

        lru_s = cache["lru"].reshape((self.n_super, 2) + cache["lru"].shape[1:]) \
            if self.n_rest == 0 else None
        # generic: walk supers via scan with per-super state slices
        n_s = self.n_super
        lru_super = cache["lru"][: n_s * 2].reshape((n_s, 2) + cache["lru"].shape[1:])
        conv_super = cache["conv"][: n_s * 2].reshape((n_s, 2) + cache["conv"].shape[1:])

        def super_body(xx, xs):
            sp, lru2, conv2, kc, vc = xs

            def rbody(c, ys):
                rp, l1, c1 = ys
                y, nl, ncv = rec_decode(c, rp, l1, c1)
                return y, (nl, ncv)

            xx, (nl2, nc2) = lax.scan(rbody, xx, (sp["rec"], lru2, conv2))
            xx, nk, nv = attn_decode(xx, sp["attn"], kc, vc)
            return xx, (nl2, nc2, nk, nv)

        x, (nl, ncv, nk, nv) = lax.scan(
            super_body, x, (params["supers"], lru_super, conv_super,
                            cache["k"], cache["v"]))
        new_lru = nl.reshape((-1,) + nl.shape[2:])
        new_conv = ncv.reshape((-1,) + ncv.shape[2:])
        if self.n_rest:
            def rbody(c, ys):
                rp, l1, c1 = ys
                y, nl1, nc1 = rec_decode(c, rp, l1, c1)
                return y, (nl1, nc1)
            x, (rl, rc) = lax.scan(rbody, x,
                                   (params["rest"],
                                    cache["lru"][n_s * 2:],
                                    cache["conv"][n_s * 2:]))
            new_lru = jnp.concatenate([new_lru, rl], 0)
            new_conv = jnp.concatenate([new_conv, rc], 0)
        x = self._norm(ops, x, params["ln_f"])
        nids = ops.head_sample(x, params["head"].astype(self.cdt),
                               vocab_real=cfg.vocab_size)
        return nids, {"lru": new_lru, "conv": new_conv, "k": nk, "v": nv}

    def prefill_cache_specs(self, ops):
        from jax.sharding import PartitionSpec as P
        kv_sp = P(None, "data", ("depth", "row"),
                  "col" if self.kv_shard else None, None)
        return {"lru": P(None, "data", "col"),
                "conv": P(None, "data", None, "col"),
                "k": kv_sp, "v": kv_sp}

    def prefill(self, params, batch, ops):
        cfg = self.cfg
        x = ops.embed(batch["tokens"], params["embed"]).astype(self.cdt)
        T_loc = x.shape[1]
        n_seq = (self.ctx.depth * self.ctx.rows if ops.plan.seq_sharded else 1)
        full_kv_pos = jnp.arange(T_loc * n_seq)
        cast = lambda t: jax.tree.map(lambda a: a.astype(self.cdt)
                                      if a.dtype == self.pdt else a, t)

        def super_body(xx, sp):
            def rbody(c, rp):
                y, st = self._rec_block(cast(rp), c, ops, want_state=True)
                return y, st
            xx, rec_states = lax.scan(rbody, xx, sp["rec"])
            xx, kv = DenseLM._block_prefill(self, cast(sp["attn"]), xx, ops,
                                            full_kv_pos)
            return xx, (rec_states, kv)

        x, (rec_states, kvs) = lax.scan(super_body, x, params["supers"])
        rest_states = None
        if self.n_rest:
            def rbody(c, rp):
                y, st = self._rec_block(cast(rp), c, ops, want_state=True)
                return y, st
            x, rest_states = lax.scan(rbody, x, params["rest"])
        x = self._norm(ops, x, params["ln_f"])
        x_last = ops_last_token(ops, x, self.ctx)
        ids = ops.head_sample(x_last, params["head"].astype(self.cdt),
                              vocab_real=cfg.vocab_size, tokens_sharded=False)
        lru = rec_states[0].reshape((-1,) + rec_states[0].shape[2:])
        conv = rec_states[1].reshape((-1,) + rec_states[1].shape[2:])
        if rest_states is not None:
            lru = jnp.concatenate([lru, rest_states[0]], 0)
            conv = jnp.concatenate([conv, rest_states[1]], 0)
        # attn kv is singly stacked [n_super, B, S, kv, D] already
        return ids[:, None], {"lru": lru, "conv": conv,
                              "k": kvs[0], "v": kvs[1]}
