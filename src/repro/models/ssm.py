"""Mamba2 (SSD — state-space duality) LM on Tesseract.

Applicability note (DESIGN.md §6): the SSD state recurrence is sequential —
Tesseract parallelizes the *projection* matmuls (in/out/dt), while the
temporal mixing runs as a chunked scan.  Heads (d_inner) shard over col;
B/C (n_groups=1, shared across heads) stay replicated over col.

Sequence sharding (prefill) passes inter-chunk states across devices with a
distributed linear scan (core/collectives.distributed_linear_scan_carry).
The intra-chunk part is matmul-dominated (MXU-friendly) and is the Pallas
kernel target (kernels/ssd.py).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from ..configs.base import round_up
from ..core import collectives as cc
from . import common as cm
from .transformer import DenseLM, maybe_remat


def segsum(log_a):
    """[..., Q] -> [..., Q, Q] lower-triangular pairwise sums:
    out[i,j] = sum_{j<k<=i} log_a[k] (=-inf above diagonal)."""
    Q = log_a.shape[-1]
    cs = jnp.cumsum(log_a, axis=-1)
    out = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((Q, Q), bool), 0)
    return jnp.where(mask, out, -jnp.inf)


def ssd_chunked(x, log_a, Bm, Cm, chunk: int, h0=None, use_pallas=False):
    """SSD scan.  x: [B,T,H,P]; log_a: [B,T,H]; Bm/Cm: [B,T,N] (G=1).
    h0: optional initial state [B,H,P,N].  Returns (y [B,T,H,P],
    h_last [B,H,P,N], a_prod [B,H], h_contrib) for cross-device chaining.
    """
    Bsz, T, H, P = x.shape
    N = Bm.shape[-1]
    Q = min(chunk, T)
    while T % Q:
        Q -= 1
    nc = T // Q
    xr = x.reshape(Bsz, nc, Q, H, P)
    lar = log_a.reshape(Bsz, nc, Q, H)
    Br = Bm.reshape(Bsz, nc, Q, N)
    Cr = Cm.reshape(Bsz, nc, Q, N)

    if use_pallas:
        from ..kernels.ops import ssd_intra_op
        Yd, S_c = ssd_intra_op(xr, lar, Br, Cr)
    else:
        # intra-chunk (quadratic within chunk, matmul-friendly)
        L = jnp.exp(segsum(lar.transpose(0, 1, 3, 2)))       # [B,nc,H,Q,Q]
        scores = jnp.einsum("bcin,bcjn->bcij", Cr, Br)       # [B,nc,Q,Q]
        Yd = jnp.einsum("bcij,bchij,bcjhp->bcihp",
                        scores, L, xr,
                        preferred_element_type=jnp.float32)  # [B,nc,Q,H,P]
        # chunk-end states S_c = sum_j decay_to_end[j] * x_j (x) B_j
        cum = jnp.cumsum(lar, axis=2)                        # [B,nc,Q,H]
        tail = cum[:, :, -1:, :] - cum                       # [B,nc,Q,H]
        xw = xr * jnp.exp(tail)[..., None]
        S_c = jnp.einsum("bcjhp,bcjn->bchpn", xw, Br,
                         preferred_element_type=jnp.float32)  # [B,nc,H,P,N]
        cum_t = cum

    if not use_pallas:
        cum = cum_t
    else:
        cum = jnp.cumsum(lar, axis=2)

    A_c = jnp.exp(cum[:, :, -1, :])                          # [B,nc,H] chunk decay

    # inter-chunk state scan: H_{c+1} = A_c * H_c + S_c
    def step(h, inputs):
        a_c, s_c = inputs                                    # [B,H], [B,H,P,N]
        h_out = h
        h_new = a_c[..., None, None] * h + s_c
        return h_new, h_out                                  # emit state ENTERING c

    h_init = (jnp.zeros((Bsz, H, P, N), jnp.float32) if h0 is None
              else h0.astype(jnp.float32))
    h_init = cm.vma_like(h_init, x, log_a, Bm)
    h_last, h_ins = lax.scan(step, h_init,
                             (A_c.transpose(1, 0, 2),
                              S_c.transpose(1, 0, 2, 3, 4)))
    h_ins = h_ins.transpose(1, 0, 2, 3, 4)                   # [B,nc,H,P,N]

    # inter-chunk contribution: y_i += C_i . (decay_in[i] * H_in)
    decay_in = jnp.exp(cum)                                  # [B,nc,Q,H]
    Yi = jnp.einsum("bcin,bchpn->bcihp", Cr,
                    h_ins, preferred_element_type=jnp.float32)
    Yi = Yi * decay_in.transpose(0, 1, 2, 3)[..., None]
    y = (Yd + Yi).reshape(Bsz, T, H, P)

    # whole-shard summaries for the cross-device chain
    a_prod_shard = jnp.exp(jnp.sum(log_a, axis=1))           # [B,H]
    return y.astype(x.dtype), h_last, a_prod_shard


class MambaLM(DenseLM):
    supports_pipeline = False  # custom loss not stage-decomposed
    supports_seq_shard = False  # SSM scan crosses seq-shard boundaries

    def __init__(self, cfg, ctx, run):
        # bypass DenseLM head/kv setup that doesn't apply; reuse embed/head
        super().__init__(cfg, ctx, run)
        if ctx.mode == "megatron1d":
            raise NotImplementedError("ssm arch runs in tesseract modes")
        self.d_inner = cfg.ssm_expand * cfg.d_model
        self.n_heads = self.d_inner // cfg.ssm_head_dim
        if self.n_heads % ctx.cols:
            raise ValueError("ssm heads must divide cols")
        self.N = cfg.ssm_state

    # ------------------------------------------------------------- params
    def _block_init(self, key):
        cfg = self.cfg
        h, di, N, H = cfg.d_model, self.d_inner, self.N, self.n_heads
        ks = jax.random.split(key, 8)
        p = {
            "ln": jnp.zeros((h,), self.pdt),
            "w_z": cm.winit(ks[0], (h, di), dtype=self.pdt),
            "w_x": cm.winit(ks[1], (h, di), dtype=self.pdt),
            "w_B": cm.winit(ks[2], (h, N), dtype=self.pdt),
            "w_C": cm.winit(ks[3], (h, N), dtype=self.pdt),
            "w_dt": cm.winit(ks[4], (h, H), dtype=self.pdt),
            "dt_bias": jnp.zeros((H,), self.pdt),
            "A_log": jnp.zeros((H,), self.pdt),      # A = -exp(A_log)
            "Dskip": jnp.ones((H,), self.pdt),
            "conv_x": cm.winit(ks[5], (cfg.ssm_conv, di), 0.2, self.pdt),
            "conv_B": cm.winit(ks[6], (cfg.ssm_conv, N), 0.2, self.pdt),
            "conv_C": cm.winit(ks[7], (cfg.ssm_conv, N), 0.2, self.pdt),
            "ln_y": jnp.zeros((di,), self.pdt),
            "w_out": cm.winit(jax.random.fold_in(key, 9), (di, h),
                              dtype=self.pdt),
        }
        return p

    def init(self, key):
        cfg = self.cfg
        k_e, k_h, k_b = jax.random.split(key, 3)
        blocks = jax.vmap(self._block_init)(jax.random.split(k_b, cfg.num_layers))
        return {
            "embed": cm.winit_padded(k_e, (cfg.vocab_size, cfg.d_model),
                                     (self.v_pad, cfg.d_model), dtype=self.pdt),
            "head": cm.winit_padded(k_h, (cfg.vocab_size, cfg.d_model),
                                    (self.v_pad, cfg.d_model), dtype=self.pdt),
            "ln_f": jnp.zeros((cfg.d_model,), self.pdt),
            "blocks": blocks,
        }

    def _block_specs(self, ops):
        return {
            "ln": ops.spec_norm(True),
            "w_z": ops.spec_w2d(True), "w_x": ops.spec_w2d(True),
            "w_B": ops.spec_w_to_replicated(True),
            "w_C": ops.spec_w_to_replicated(True),
            "w_dt": ops.spec_w2d(True),
            "dt_bias": ops.spec_vec(True), "A_log": ops.spec_vec(True),
            "Dskip": ops.spec_vec(True),
            # [L, K, C]: channel dim over col (or replicated for B/C)
            "conv_x": __import__("jax").sharding.PartitionSpec(None, None, "col"),
            "conv_B": __import__("jax").sharding.PartitionSpec(None, None, None),
            "conv_C": __import__("jax").sharding.PartitionSpec(None, None, None),
            "ln_y": ops.spec_norm(True),
            "w_out": ops.spec_w_down(True),
        }

    def tess_weight_names(self):
        return {"w_z", "w_x", "w_dt", "w_out"}

    # ------------------------------------------------------------- mixer
    def _causal_conv(self, x, w, ops, halo=None):
        """Depthwise causal conv along seq. x: [B,T,C]; w: [K,C].
        halo: [B,K-1,C] tokens from the previous shard (seq-sharded)."""
        K = w.shape[0]
        if halo is None:
            halo = jnp.zeros((x.shape[0], K - 1, x.shape[-1]), x.dtype)
        xp = jnp.concatenate([halo, x], axis=1)
        y = sum(xp[:, K - 1 - j: xp.shape[1] - j, :] * w[K - 1 - j]
                for j in range(K))
        return jax.nn.silu(y)

    def _conv_halo(self, x, ops):
        if not ops.plan.seq_sharded:
            return None
        K = self.cfg.ssm_conv
        return cc.halo_exchange_left(x, (self.ctx.axis_depth,
                                         self.ctx.axis_row), K - 1, 1)

    def _mixer(self, p, x, ops, state=None, conv_state=None, pos=None):
        """x: [B,T,h/q] canonical.  Train/prefill path (T>=1)."""
        cfg, ctx = self.cfg, self.ctx
        B, T = x.shape[:2]
        HL = self.n_heads // ctx.cols
        P_ = cfg.ssm_head_dim
        z = ops.linear(x, p["w_z"])                          # [B,T,di/q]
        xin = ops.linear(x, p["w_x"])
        Bm = ops.linear_to_replicated(x, p["w_B"])           # [B,T,N]
        Cm = ops.linear_to_replicated(x, p["w_C"])
        dt_raw = ops.linear(x, p["w_dt"])                    # [B,T,H/q]
        dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])
        xin = self._causal_conv(xin, p["conv_x"], ops, self._conv_halo(xin, ops))
        Bm = self._causal_conv(Bm, p["conv_B"], ops, self._conv_halo(Bm, ops))
        Cm = self._causal_conv(Cm, p["conv_C"], ops, self._conv_halo(Cm, ops))
        xh = xin.reshape(B, T, HL, P_)
        A = -jnp.exp(p["A_log"].astype(jnp.float32))         # [H/q]
        log_a = dt * A                                       # [B,T,H/q]
        x_dt = xh.astype(jnp.float32) * dt[..., None]
        y, h_last, a_prod = ssd_chunked(x_dt, log_a, Bm.astype(jnp.float32),
                                        Cm.astype(jnp.float32), cfg.ssm_chunk,
                                        use_pallas=self.run.use_pallas)
        if ops.plan.seq_sharded:
            # chain states across sequence shards
            axes = (ctx.axis_depth, ctx.axis_row)
            b_red = h_last                                   # [B,H,P,N]
            a_pr = jnp.broadcast_to(a_prod[..., None, None], b_red.shape)
            h_in = cc.distributed_linear_scan_carry(a_pr, b_red, axes)
            # recompute y correction: y += C_t . decay(0..t) * h_in
            cum = jnp.cumsum(log_a, axis=1)
            corr = jnp.einsum("btn,bhpn->bthp", Cm.astype(jnp.float32), h_in)
            y = (y.astype(jnp.float32)
                 + corr * jnp.exp(cum)[..., None]).astype(y.dtype)
            h_last = (jnp.exp(jnp.sum(log_a, 1))[..., None, None] * h_in
                      + h_last)
        y = y + xh * p["Dskip"].astype(x.dtype)[None, None, :, None]
        y = y.reshape(B, T, HL * P_)
        y = ops.rmsnorm((y * jax.nn.silu(z)).astype(x.dtype), p["ln_y"],
                        cfg.norm_eps)
        return ops.linear(y, p["w_out"]), h_last

    def _block(self, p, x, ops):
        h = self._norm(ops, x, p["ln"])
        y, _ = self._mixer(p, h, ops)
        return x + y

    # -------------------------------------------------------------- steps
    def loss(self, params, batch, ops):
        x = ops.embed(batch["tokens"], params["embed"]).astype(self.cdt)
        cast = lambda t: jax.tree.map(lambda a: a.astype(self.cdt)
                                      if a.dtype == self.pdt else a, t)
        body = maybe_remat(lambda xx, bp: (self._block(cast(bp), xx, ops), None),
                           self.run)
        x, _ = lax.scan(body, x, params["blocks"])
        x = self._norm(ops, x, params["ln_f"])
        loss_sum, cnt = ops.ce_loss(
            x, params["head"].astype(self.cdt), batch["labels"],
            vocab_real=self.cfg.vocab_size, loss_chunk=self.run.loss_chunk,
            label_mask=batch.get("mask"))
        loss_sum = lax.psum(loss_sum, self.ctx.axis_data)
        cnt = lax.psum(cnt, self.ctx.axis_data)
        return loss_sum / jnp.maximum(cnt, 1.0)

    # ------------------------------------------------------------ serving
    def cache_abstract(self, batch_global: int, seq_len: int, plan):
        from jax import ShapeDtypeStruct as Sds
        from jax.sharding import PartitionSpec as P
        cfg = self.cfg
        L = cfg.num_layers
        H, P_, N, K = self.n_heads, cfg.ssm_head_dim, self.N, cfg.ssm_conv
        tok = (("data", "depth", "row") if plan.kind == "decode"
               else "data" if plan.kind == "decode_dp" else None)
        sds = {
            "state": Sds((L, batch_global, H, P_, N), jnp.float32),
            "conv_x": Sds((L, batch_global, K - 1, self.d_inner), self.cdt),
            "conv_B": Sds((L, batch_global, K - 1, N), self.cdt),
            "conv_C": Sds((L, batch_global, K - 1, N), self.cdt),
        }
        specs = {
            "state": P(None, tok, "col", None, None),
            "conv_x": P(None, tok, None, "col"),
            "conv_B": P(None, tok, None, None),
            "conv_C": P(None, tok, None, None),
        }
        return sds, specs

    def prefill_cache_specs(self, ops):
        from jax.sharding import PartitionSpec as P
        return {
            "state": P(None, "data", "col", None, None),
            "conv_x": P(None, "data", None, "col"),
            "conv_B": P(None, "data", None, None),
            "conv_C": P(None, "data", None, None),
        }

    def prefill(self, params, batch, ops):
        from .transformer import ops_last_token
        cfg = self.cfg
        x = ops.embed(batch["tokens"], params["embed"]).astype(self.cdt)
        cast = lambda t: jax.tree.map(lambda a: a.astype(self.cdt)
                                      if a.dtype == self.pdt else a, t)
        K = cfg.ssm_conv

        seq_axes = (self.ctx.axis_depth, self.ctx.axis_row)

        def glob_last(t):
            # seq-sharded: only the last shard holds the true final state/tail
            if ops.plan.seq_sharded:
                return cc.last_shard_value(t, seq_axes)
            return t

        def body(xx, bp):
            bp = cast(bp)
            h = self._norm(ops, xx, bp["ln"])
            # recompute conv inputs to expose tails (cheap linears)
            xin = ops.linear(h, bp["w_x"])
            Bm = ops.linear_to_replicated(h, bp["w_B"])
            Cm = ops.linear_to_replicated(h, bp["w_C"])
            y, h_last = self._mixer(bp, h, ops)
            xx = xx + y
            tails = (glob_last(xin[:, -(K - 1):, :]),
                     glob_last(Bm[:, -(K - 1):, :]),
                     glob_last(Cm[:, -(K - 1):, :]))
            return xx, (glob_last(h_last), tails)

        x, (states, tails) = lax.scan(body, x, params["blocks"])
        x = self._norm(ops, x, params["ln_f"])
        x_last = ops_last_token(ops, x, self.ctx)
        ids = ops.head_sample(x_last, params["head"].astype(self.cdt),
                              vocab_real=cfg.vocab_size, tokens_sharded=False)
        # [L,B,H,P,N] states; conv tails [L,B,K-1,*]
        cache = {"state": states, "conv_x": tails[0].astype(self.cdt),
                 "conv_B": tails[1].astype(self.cdt),
                 "conv_C": tails[2].astype(self.cdt)}
        return ids[:, None], cache

    def _mixer_decode(self, p, x, cache_l, ops):
        """Single-token state update. x: [B,1,h/q]."""
        cfg, ctx = self.cfg, self.ctx
        B = x.shape[0]
        HL = self.n_heads // ctx.cols
        P_ = cfg.ssm_head_dim
        z = ops.linear(x, p["w_z"])[:, 0]
        xin = ops.linear(x, p["w_x"])[:, 0]                  # [B,di/q]
        Bm = ops.linear_to_replicated(x, p["w_B"])[:, 0]
        Cm = ops.linear_to_replicated(x, p["w_C"])[:, 0]
        dt_raw = ops.linear(x, p["w_dt"])[:, 0]
        dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])

        def conv_step(cstate, new, w):
            xp = jnp.concatenate([cstate, new[:, None, :]], axis=1)  # [B,K,C]
            y = jnp.einsum("bkc,kc->bc", xp, w)
            return jax.nn.silu(y), xp[:, 1:, :]

        xin_c, ncx = conv_step(cache_l["conv_x"], xin, p["conv_x"])
        Bc, ncB = conv_step(cache_l["conv_B"], Bm, p["conv_B"])
        Cc, ncC = conv_step(cache_l["conv_C"], Cm, p["conv_C"])
        xh = xin_c.reshape(B, HL, P_).astype(jnp.float32)
        A = -jnp.exp(p["A_log"].astype(jnp.float32))
        a = jnp.exp(dt * A)                                  # [B,HL]
        hprev = cache_l["state"]
        hnew = (a[..., None, None] * hprev
                + jnp.einsum("bhp,bn->bhpn", xh * dt[..., None],
                             Bc.astype(jnp.float32)))
        y = jnp.einsum("bn,bhpn->bhp", Cc.astype(jnp.float32), hnew)
        y = y + xh * p["Dskip"].astype(jnp.float32)[:, None]
        y = y.reshape(B, HL * P_).astype(x.dtype)
        y = ops.rmsnorm((y * jax.nn.silu(z)), p["ln_y"], cfg.norm_eps)
        out = ops.linear(y[:, None, :], p["w_out"])
        new_cache = {"state": hnew, "conv_x": ncx.astype(cache_l["conv_x"].dtype),
                     "conv_B": ncB.astype(cache_l["conv_B"].dtype),
                     "conv_C": ncC.astype(cache_l["conv_C"].dtype)}
        return out, new_cache

    def decode(self, params, cache, ids, pos, ops):
        cfg = self.cfg
        x = ops.embed(ids, params["embed"]).astype(self.cdt)
        cast = lambda t: jax.tree.map(lambda a: a.astype(self.cdt)
                                      if a.dtype == self.pdt else a, t)

        def body(xx, xs):
            bp, st, cx, cb, ccc = xs
            bp = cast(bp)
            h = self._norm(ops, xx, bp["ln"])
            y, nc = self._mixer_decode(bp, h,
                                       {"state": st, "conv_x": cx,
                                        "conv_B": cb, "conv_C": ccc}, ops)
            return xx + y, (nc["state"], nc["conv_x"], nc["conv_B"],
                            nc["conv_C"])

        x, (ns, ncx, ncb, ncc) = lax.scan(
            body, x, (params["blocks"], cache["state"], cache["conv_x"],
                      cache["conv_B"], cache["conv_C"]))
        x = self._norm(ops, x, params["ln_f"])
        nids = ops.head_sample(x, params["head"].astype(self.cdt),
                               vocab_real=cfg.vocab_size)
        return nids, {"state": ns, "conv_x": ncx, "conv_B": ncb,
                      "conv_C": ncc}
