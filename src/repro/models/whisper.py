"""Whisper-base backbone (enc-dec) on Tesseract.

Frontend stub per the harness: ``input_specs()`` supplies precomputed frame
embeddings [B, enc_seq=1500, d_model] (the conv1d+GELU frontend is out of
scope).  Positions are sinusoidal (parameter-free) for both stacks so the
synthetic 32k-sequence shape cells don't need a 448-entry learned table —
a documented deviation from the published checkpoint.

Encoder: bidirectional self-attention, layernorm+bias, GELU MLP.
Decoder: causal self-attention + cross-attention over encoder memory.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from ..core import collectives as cc
from . import common as cm
from .transformer import DenseLM, maybe_remat, ops_last_token


def sinusoid_pos(positions, dim):
    """Whisper-style sinusoidal embeddings. positions: [T] -> [T, dim]."""
    half = dim // 2
    freq = jnp.exp(-jnp.log(10000.0) * jnp.arange(half) / (half - 1))
    ang = positions[:, None].astype(jnp.float32) * freq[None, :]
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


class WhisperModel(DenseLM):
    supports_pipeline = False  # encoder/decoder loss not stage-decomposed
    supports_seq_shard = False  # encoder/decoder trunks not seq-decomposed

    def __init__(self, cfg, ctx, run):
        super().__init__(cfg, ctx, run)
        if ctx.mode == "megatron1d":
            raise NotImplementedError("audio arch runs in tesseract modes")

    # ------------------------------------------------------------- params
    def _cross_init(self, key):
        cfg, D = self.cfg, self.D
        h = cfg.d_model
        ks = jax.random.split(key, 4)
        return {
            "ln": jnp.ones((h,), self.pdt), "lnb": jnp.zeros((h,), self.pdt),
            "wq": cm.winit(ks[0], (h, self.Hp * D), dtype=self.pdt),
            "bq": jnp.zeros((self.Hp * D,), self.pdt),
            "wk": cm.winit(ks[1], (h, cfg.num_kv_heads * D), dtype=self.pdt),
            "wv": cm.winit(ks[2], (h, cfg.num_kv_heads * D), dtype=self.pdt),
            "bv": jnp.zeros((cfg.num_kv_heads * D,), self.pdt),
            "wo": cm.winit(ks[3], (self.Hp * D, h), dtype=self.pdt),
            "bo": jnp.zeros((h,), self.pdt),
        }

    def _dec_block_init(self, key):
        k1, k2 = jax.random.split(key)
        p = super()._block_init(k1)
        p["cross"] = self._cross_init(k2)
        return p

    def init(self, key):
        cfg = self.cfg
        k_e, k_h, k_enc, k_dec = jax.random.split(key, 4)
        enc = jax.vmap(super()._block_init)(
            jax.random.split(k_enc, cfg.enc_layers))
        dec = jax.vmap(self._dec_block_init)(
            jax.random.split(k_dec, cfg.num_layers))
        return {
            "embed": cm.winit_padded(k_e, (cfg.vocab_size, cfg.d_model),
                                     (self.v_pad, cfg.d_model), dtype=self.pdt),
            "head": cm.winit_padded(k_h, (cfg.vocab_size, cfg.d_model),
                                    (self.v_pad, cfg.d_model), dtype=self.pdt),
            "enc_blocks": enc,
            "dec_blocks": dec,
            "ln_enc": jnp.ones((cfg.d_model,), self.pdt),
            "ln_encb": jnp.zeros((cfg.d_model,), self.pdt),
            "ln_f": jnp.ones((cfg.d_model,), self.pdt),
            "ln_fb": jnp.zeros((cfg.d_model,), self.pdt),
        }

    def _cross_specs(self, ops):
        kv_spec = (ops.spec_w2d(True) if self.kv_shard
                   else ops.spec_w_to_replicated(True))
        return {
            "ln": ops.spec_norm(True), "lnb": ops.spec_norm(True),
            "wq": ops.spec_w2d(True), "bq": ops.spec_bias_up(True),
            "wk": kv_spec,
            "wv": kv_spec,
            "bv": (ops.spec_bias_up(True) if self.kv_shard
                   else ops.spec_vec_replicated(True)),
            "wo": ops.spec_w_down(True), "bo": ops.spec_bias_down(True),
        }

    def specs(self, ops):
        dec = dict(DenseLM._block_specs(self, ops))
        dec["cross"] = self._cross_specs(ops)
        return {
            "embed": ops.spec_embed(), "head": ops.spec_head(),
            "enc_blocks": DenseLM._block_specs(self, ops),
            "dec_blocks": dec,
            "ln_enc": ops.spec_norm(False), "ln_encb": ops.spec_norm(False),
            "ln_f": ops.spec_norm(False), "ln_fb": ops.spec_norm(False),
        }

    # ------------------------------------------------------------ encoder
    def batch_extras(self, shape):
        from jax.sharding import PartitionSpec as P
        cfg = self.cfg
        B = shape.global_batch
        sd = jax.ShapeDtypeStruct((B, cfg.enc_seq, cfg.d_model), jnp.float32)
        sp = (P(("data", "depth"), None, None) if shape.kind == "train"
              else P("data", None, None))
        return {"audio": (sd, sp)}

    def shard_audio(self, ops, audio):
        """[B', Te, h] host layout -> [B_loc, Te, h/q]."""
        a = ops.shard_tokens(audio) if ops.plan.kind == "train" else audio
        q = self.ctx.cols
        n = a.shape[-1] // q
        i = lax.axis_index(self.ctx.axis_col)
        return lax.dynamic_slice_in_dim(a, i * n, n, axis=a.ndim - 1)

    def _enc_block(self, p, x, ops):
        """Bidirectional self-attention block (no rope, no seq sharding)."""
        B, T = x.shape[:2]
        h = self._norm(ops, x, p["ln1"], p.get("ln1b"))
        q = ops.linear_up(h, p["wq"], p.get("bq"))
        if self.kv_shard:
            k = ops.linear_up(h, p["wk"])
            v = ops.linear_up(h, p["wv"], p.get("bv"))
        else:
            k = ops.linear_to_replicated(h, p["wk"])
            v = ops.linear_to_replicated(h, p["wv"], p.get("bv"))
        D = self.D
        q = q.reshape(B, T, self._heads_loc(ops), D)
        k = k.reshape(B, T, self._kv_heads_loc(ops), D)
        v = v.reshape(B, T, self._kv_heads_loc(ops), D)
        if not self.kv_shard:
            kv_map = self._kv_map(ops)
            k = jnp.take(k, kv_map, axis=2)
            v = jnp.take(v, kv_map, axis=2)
        pos = jnp.zeros((T,), jnp.int32)
        out = cm.attention(q, k, v, q_pos=pos, kv_pos=pos,
                           causal=False, q_chunk=self.run.q_chunk,
                           kv_chunk=self.run.kv_chunk,
                           impl=self.ctx.attn_impl, q_start=0)
        x = x + self._attn_out(p, out, ops, self._head_mask(ops))
        h2 = self._norm(ops, x, p["ln2"], p.get("ln2b"))
        return x + self._mlp(p, h2, ops)

    def encode(self, params, audio, ops):
        cast = lambda t: jax.tree.map(lambda a: a.astype(self.cdt)
                                      if a.dtype == self.pdt else a, t)
        x = audio.astype(self.cdt)
        Te = x.shape[1]
        pos = sinusoid_pos(jnp.arange(Te), self.cfg.d_model)
        pos = self._slice_features(pos)
        x = x + pos[None].astype(self.cdt)
        body = maybe_remat(
            lambda xx, bp: (self._enc_block(cast(bp), xx, ops), None), self.run)
        x, _ = lax.scan(body, x, params["enc_blocks"])
        return self._norm(ops, x, params["ln_enc"], params["ln_encb"])

    def _slice_features(self, t):
        q = self.ctx.cols
        n = t.shape[-1] // q
        i = lax.axis_index(self.ctx.axis_col)
        return lax.dynamic_slice_in_dim(t, i * n, n, axis=t.ndim - 1)

    # ------------------------------------------------------------ decoder
    def _cross_block(self, p, x, memory, ops):
        cfg, D = self.cfg, self.D
        h = self._norm(ops, x, p["ln"], p.get("lnb"))
        hg = ops.seq_gather_in(h)
        B, T = hg.shape[:2]
        q = ops.linear_up(hg, p["wq"], p.get("bq"))
        q = q.reshape(B, T, self._heads_loc(ops), D)
        if self.kv_shard:
            k = ops.linear_up(memory, p["wk"])
            v = ops.linear_up(memory, p["wv"], p.get("bv"))
        else:
            k = ops.linear_to_replicated(memory, p["wk"])
            v = ops.linear_to_replicated(memory, p["wv"], p.get("bv"))
        Tv = memory.shape[1]
        k = k.reshape(B, Tv, self._kv_heads_loc(ops), D)
        v = v.reshape(B, Tv, self._kv_heads_loc(ops), D)
        if not self.kv_shard:
            kv_map = self._kv_map(ops)
            k = jnp.take(k, kv_map, axis=2)
            v = jnp.take(v, kv_map, axis=2)
        out = cm.attention(
            q, k, v, q_pos=jnp.zeros((T,), jnp.int32),
            kv_pos=jnp.zeros((Tv,), jnp.int32), causal=False,
            q_chunk=self.run.q_chunk, kv_chunk=self.run.kv_chunk,
            impl=self.ctx.attn_impl, q_start=0)
        return x + self._attn_out(p, out, ops, self._head_mask(ops)), (k, v)

    def _dec_block(self, p, x, memory, ops, full_kv_pos):
        x, kv_self = self._block_train_attn(p, x, ops, full_kv_pos)
        x, kv_cross = self._cross_block(p["cross"], x, memory, ops)
        h2 = self._norm(ops, x, p["ln2"], p.get("ln2b"))
        x = x + self._mlp(p, h2, ops)
        return x, (kv_self, kv_cross)

    def _embed_dec(self, params, tokens, ops):
        x = ops.embed(tokens, params["embed"]).astype(self.cdt)
        S_loc = x.shape[1]
        pos = sinusoid_pos(ops.positions(S_loc), self.cfg.d_model)
        return x + self._slice_features(pos)[None].astype(self.cdt)

    # -------------------------------------------------------------- steps
    def loss(self, params, batch, ops):
        cfg = self.cfg
        memory = self.encode(params, self.shard_audio(ops, batch["audio"]), ops)
        x = self._embed_dec(params, batch["tokens"], ops)
        T_loc = x.shape[1]
        n_seq = (self.ctx.depth * self.ctx.rows if ops.plan.seq_sharded else 1)
        full_kv_pos = jnp.arange(T_loc * n_seq)
        cast = lambda t: jax.tree.map(lambda a: a.astype(self.cdt)
                                      if a.dtype == self.pdt else a, t)

        def body(xx, bp):
            y, _ = self._dec_block(cast(bp), xx, memory, ops, full_kv_pos)
            return y, None

        x, _ = lax.scan(maybe_remat(body, self.run), x, params["dec_blocks"])
        x = self._norm(ops, x, params["ln_f"], params["ln_fb"])
        loss_sum, cnt = ops.ce_loss(
            x, params["head"].astype(self.cdt), batch["labels"],
            vocab_real=cfg.vocab_size, loss_chunk=self.run.loss_chunk,
            label_mask=batch.get("mask"))
        loss_sum = lax.psum(loss_sum, self.ctx.axis_data)
        cnt = lax.psum(cnt, self.ctx.axis_data)
        return loss_sum / jnp.maximum(cnt, 1.0)

    # ------------------------------------------------------------ serving
    def cache_abstract(self, batch_global: int, seq_len: int, plan):
        from jax import ShapeDtypeStruct as Sds
        from jax.sharding import PartitionSpec as P
        cfg = self.cfg
        sds, specs = super().cache_abstract(batch_global, seq_len, plan)
        tok = (("data", "depth", "row") if plan.kind == "decode"
               else "data" if plan.kind == "decode_dp" else None)
        cshape = (cfg.num_layers, batch_global, cfg.enc_seq,
                  cfg.num_kv_heads, self.D)
        csp = P(None, tok, None, "col" if self.kv_shard else None, None)
        sds.update(ck=Sds(cshape, self.cdt), cv=Sds(cshape, self.cdt))
        specs.update(ck=csp, cv=csp)
        return sds, specs

    def prefill_cache_specs(self, ops):
        from jax.sharding import PartitionSpec as P
        base = super().prefill_cache_specs(ops)
        csp = P(None, "data", None, "col" if self.kv_shard else None, None)
        base.update(ck=csp, cv=csp)
        return base

    def prefill(self, params, batch, ops):
        cfg = self.cfg
        memory = self.encode(params, self.shard_audio(ops, batch["audio"]), ops)
        x = self._embed_dec(params, batch["tokens"], ops)
        S_loc = x.shape[1]
        n_seq = (self.ctx.depth * self.ctx.rows if ops.plan.seq_sharded else 1)
        full_kv_pos = jnp.arange(S_loc * n_seq)
        cast = lambda t: jax.tree.map(lambda a: a.astype(self.cdt)
                                      if a.dtype == self.pdt else a, t)

        def body(xx, bp):
            y, (kv_self, kv_cross) = self._dec_block(cast(bp), xx, memory, ops,
                                                     full_kv_pos)
            return y, (kv_self, (kv_cross[0].astype(self.cdt),
                                 kv_cross[1].astype(self.cdt)))

        x, (kvs, ckvs) = lax.scan(body, x, params["dec_blocks"])
        x = self._norm(ops, x, params["ln_f"], params["ln_fb"])
        x_last = ops_last_token(ops, x, self.ctx)
        ids = ops.head_sample(x_last, params["head"].astype(self.cdt),
                              vocab_real=cfg.vocab_size, tokens_sharded=False)
        return ids[:, None], {"k": kvs[0], "v": kvs[1],
                              "ck": ckvs[0], "cv": ckvs[1]}

    def _cross_decode(self, p, x, ck, cv, ops):
        D = self.D
        h = self._norm(ops, x, p["ln"], p.get("lnb"))
        B = h.shape[0]
        q = ops.linear_up(h, p["wq"], p.get("bq"))
        q = q.reshape(B, self._heads_loc(ops), D)
        kv_map = None if self.kv_shard else self._kv_map(ops)
        out = cm.decode_attention(q, ck, cv, cur_pos=ck.shape[1] - 1,
                                  kv_map=kv_map, impl=self.ctx.attn_impl)
        return x + self._attn_out(p, out[:, None], ops, self._head_mask(ops))

    def decode(self, params, cache, ids, pos, ops):
        cfg = self.cfg
        x = self._embed_dec_decode(params, ids, pos, ops)
        cast = lambda t: jax.tree.map(lambda a: a.astype(self.cdt)
                                      if a.dtype == self.pdt else a, t)

        def body(xx, xs):
            bp, k1, v1, ck1, cv1 = xs
            bp = cast(bp)
            y, cl = DenseLM._block_decode_attnonly(self, bp, xx,
                                                   {"k": k1, "v": v1}, pos, ops)
            y = self._cross_decode(bp["cross"], y, ck1.astype(self.cdt),
                                   cv1.astype(self.cdt), ops)
            h2 = self._norm(ops, y, bp["ln2"], bp.get("ln2b"))
            y = y + self._mlp(bp, h2, ops)
            return y, (cl["k"], cl["v"])

        x, (nk, nv) = lax.scan(body, x, (params["dec_blocks"], cache["k"],
                                         cache["v"], cache["ck"], cache["cv"]))
        x = self._norm(ops, x, params["ln_f"], params["ln_fb"])
        nids = ops.head_sample(x, params["head"].astype(self.cdt),
                               vocab_real=cfg.vocab_size)
        return nids, dict(cache, k=nk, v=nv)

    def _embed_dec_decode(self, params, ids, pos, ops):
        x = ops.embed(ids, params["embed"]).astype(self.cdt)
        p = sinusoid_pos(jnp.full((1,), pos, jnp.int32), self.cfg.d_model)
        return x + self._slice_features(p)[None].astype(self.cdt)
