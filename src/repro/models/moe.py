"""MoE LMs on Tesseract: llama4-scout (GQA + 16e top-1) and deepseek-v2
(MLA + 160e top-6 + 2 shared experts).

Expert parallelism reuses Tesseract's depth axis: the paper replicates FFN
weights across depth to parallelize the batch; with MoE, the expert dimension
gives depth a better use (DESIGN.md §6).  Each expert's own matmuls stay 2-D
SUMMA-sharded over (row, col):

    expert weights [E, F, G] -> P(depth, row, col)
    dispatch: sort-based (argsort by expert), capacity-bounded
    routing comm: all_to_all over depth, both directions

MLA (deepseek): KV compressed to kv_lora (+ shared rope key); decode uses the
absorbed formulation against the compressed cache (cache = 576 B/token
instead of 2*H*192).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
from jax import lax

from ..configs.base import round_up
from ..core import collectives as cc
from ..core.summa import tesseract_matmul_experts
from . import common as cm
from .transformer import DenseLM, ops_last_token


class MoELM(DenseLM):
    supports_pipeline = False  # custom loss (router aux) not stage-decomposed
    supports_seq_shard = False  # capacity routing depends on token layout

    def __init__(self, cfg, ctx, run):
        super().__init__(cfg, ctx, run)
        self.is_mla = cfg.mla_kv_lora > 0
        if ctx.mode == "megatron1d":
            raise NotImplementedError(
                "MoE archs run in tesseract/summa2d modes (1-D baseline is "
                "benchmarked on the dense families, as in the paper)")
        self.n_exp = cfg.moe_num_experts
        if self.n_exp % ctx.depth:
            raise ValueError(f"experts {self.n_exp} % depth {ctx.depth} != 0")
        self.exp_loc = self.n_exp // ctx.depth
        if self.is_mla:
            self.qk_dim = cfg.qk_nope_dim + cfg.qk_rope_dim
            self.Hp = round_up(cfg.num_heads, ctx.cols)

    # ------------------------------------------------------------- params
    def _mla_init(self, ks):
        cfg = self.cfg
        h = cfg.d_model
        H = cfg.num_heads
        return {
            "w_dq": cm.winit(ks[0], (h, cfg.mla_q_lora), dtype=self.pdt),
            "ln_q": jnp.zeros((cfg.mla_q_lora,), self.pdt),
            "w_uq": cm.winit_padded(ks[1], (cfg.mla_q_lora, H * self.qk_dim),
                                    (cfg.mla_q_lora, self.Hp * self.qk_dim),
                                    dtype=self.pdt),
            "w_dkv": cm.winit(ks[2], (h, cfg.mla_kv_lora), dtype=self.pdt),
            "ln_kv": jnp.zeros((cfg.mla_kv_lora,), self.pdt),
            "w_kr": cm.winit(ks[3], (h, cfg.qk_rope_dim), dtype=self.pdt),
            "w_ukv": cm.winit_padded(
                ks[4], (cfg.mla_kv_lora, H * (cfg.qk_nope_dim + cfg.v_head_dim)),
                (cfg.mla_kv_lora, self.Hp * (cfg.qk_nope_dim + cfg.v_head_dim)),
                dtype=self.pdt),
            "wo": cm.winit_padded(ks[5], (H * cfg.v_head_dim, h),
                                  (self.Hp * cfg.v_head_dim, h), dtype=self.pdt),
            "ln1": jnp.zeros((h,), self.pdt),
        }

    def _moe_init(self, ks):
        cfg = self.cfg
        h, ffe = cfg.d_model, cfg.moe_d_ff
        E = self.n_exp
        p = {
            "w_router": cm.winit(ks[0], (h, E), dtype=self.pdt),
            "we_gate": cm.winit(ks[1], (E, h, ffe), dtype=self.pdt),
            "we_up": cm.winit(ks[2], (E, h, ffe), dtype=self.pdt),
            "we_down": cm.winit(ks[3], (E, ffe, h), dtype=self.pdt),
            "ln2": jnp.zeros((h,), self.pdt),
        }
        if cfg.moe_shared_experts:
            ffs = cfg.moe_shared_experts * ffe
            p["ws_gate"] = cm.winit(ks[4], (h, ffs), dtype=self.pdt)
            p["ws_up"] = cm.winit(ks[5], (h, ffs), dtype=self.pdt)
            p["ws_down"] = cm.winit(ks[6], (ffs, h), dtype=self.pdt)
        return p

    def _block_init(self, key):
        cfg = self.cfg
        ks = jax.random.split(key, 16)
        if self.is_mla:
            p = self._mla_init(ks[:6])
        else:
            dense = super()._block_init(key)
            p = {k: v for k, v in dense.items()
                 if k in ("ln1", "wq", "wk", "wv", "wo")}
        p.update(self._moe_init(ks[6:13]))
        return p

    def _dense_block_init(self, key):
        """First dense layer (deepseek first_dense=1) with its own d_ff."""
        return super()._block_init(key)

    def init(self, key):
        cfg = self.cfg
        k_e, k_h, k_b, k_d = jax.random.split(key, 4)
        n_moe = cfg.num_layers - cfg.first_dense
        blocks = jax.vmap(self._block_init)(jax.random.split(k_b, n_moe))
        params = {
            "embed": cm.winit_padded(k_e, (cfg.vocab_size, cfg.d_model),
                                     (self.v_pad, cfg.d_model), dtype=self.pdt),
            "head": cm.winit_padded(k_h, (cfg.vocab_size, cfg.d_model),
                                    (self.v_pad, cfg.d_model), dtype=self.pdt),
            "ln_f": jnp.zeros((cfg.d_model,), self.pdt),
            "blocks": blocks,
        }
        if cfg.first_dense:
            params["dense_blocks"] = jax.vmap(self._dense_block_init)(
                jax.random.split(k_d, cfg.first_dense))
        return params

    def _block_specs(self, ops):
        cfg = self.cfg
        if self.is_mla:
            s = {
                "w_dq": ops.spec_w2d(True), "ln_q": ops.spec_norm(True),
                "w_uq": ops.spec_w2d(True),
                "w_dkv": ops.spec_w2d(True), "ln_kv": ops.spec_norm(True),
                "w_kr": ops.spec_w_to_replicated(True),
                "w_ukv": ops.spec_w2d(True),
                "wo": ops.spec_w_down(True),
                "ln1": ops.spec_norm(True),
            }
        else:
            kv_spec = (ops.spec_w2d(True) if self.kv_shard
                       else ops.spec_w_to_replicated(True))
            s = {"ln1": ops.spec_norm(True), "wq": ops.spec_w2d(True),
                 "wk": kv_spec, "wv": kv_spec, "wo": ops.spec_w_down(True)}
        if self.run.moe_expert_layout == "local":
            from jax.sharding import PartitionSpec as P
            exp_spec = P(None, "depth", None, None)
        else:
            exp_spec = ops.spec_expert(True)
        s.update({
            "w_router": ops.spec_w_to_replicated(True),
            "we_gate": exp_spec, "we_up": exp_spec, "we_down": exp_spec,
            "ln2": ops.spec_norm(True),
        })
        if cfg.moe_shared_experts:
            s.update(ws_gate=ops.spec_w2d(True), ws_up=ops.spec_w2d(True),
                     ws_down=ops.spec_w_down(True))
        return s

    def specs(self, ops):
        s = {
            "embed": ops.spec_embed(), "head": ops.spec_head(),
            "ln_f": ops.spec_norm(False), "blocks": self._block_specs(ops),
        }
        if self.cfg.first_dense:
            s["dense_blocks"] = DenseLM._block_specs(self, ops)
        return s

    def tess_weight_names(self):
        base = {"wo", "w_dq", "w_uq", "w_dkv", "w_ukv", "ws_gate", "ws_up",
                "ws_down", "wq"}
        # wk/wv are tesseract-sharded in the GQA MoE blocks and in the dense
        # prefix (deepseek first_dense) whenever kv_heads % q == 0
        if self.kv_shard:
            base.update({"wk", "wv"})
        if self.cfg.first_dense:
            base.update({"w_up", "w_gate", "w_down"})
        return base

    # ------------------------------------------------------------- MoE ffn
    def _moe_ffn(self, p, x, ops):
        """Sort-based capacity-bounded top-k routing, EP over depth."""
        cfg, ctx = self.cfg, self.ctx
        B, T, f = x.shape
        N = B * T
        E, k = self.n_exp, cfg.moe_top_k
        xt = x.reshape(N, f)

        logits = ops.linear_to_replicated(xt, p["w_router"]).astype(jnp.float32)
        probs = jax.nn.softmax(logits, axis=-1)                  # [N, E]
        gates, idx = lax.top_k(probs, k)                         # [N, k]

        # ---- aux load-balance loss (switch-style), invariant scalar ----
        f_e = jnp.mean(jax.nn.one_hot(idx, E, dtype=jnp.float32), axis=(0, 1))
        p_e = jnp.mean(probs, axis=0)
        aux = E * jnp.sum(f_e * p_e)
        aux = cc.pmean_v(aux, ("data", "depth", "row", "col"))

        cap = max(1, int(math.ceil(k * N / E * self.run.capacity_factor)))
        cap = ((cap + ctx.cols - 1) // ctx.cols) * ctx.cols  # divisible by q
        # ---- sort-based dispatch ----
        flat_e = idx.reshape(-1)                                  # [N*k]
        flat_t = jnp.repeat(jnp.arange(N), k)
        flat_g = gates.reshape(-1)
        order = jnp.argsort(flat_e, stable=True)
        se, st, sg = flat_e[order], flat_t[order], flat_g[order]
        counts = jnp.zeros((E,), jnp.int32).at[flat_e].add(1)
        starts = jnp.cumsum(counts) - counts
        pos = jnp.arange(N * k) - starts[se]
        keep = pos < cap
        slot = jnp.where(keep, se * cap + pos, E * cap)           # drop -> pad row
        buf = jnp.zeros((E * cap + 1, f), x.dtype).at[slot].set(xt[st])
        buf = buf[:-1].reshape(ctx.depth, self.exp_loc, cap, f)

        # ---- route to expert owners: all_to_all over depth ----
        if ctx.depth > 1:
            buf = lax.all_to_all(buf, ctx.axis_depth, split_axis=0,
                                 concat_axis=0, tiled=False)
        # buf: [d(source), E_loc, cap, f] -> [E_loc, d*cap, f]
        buf = buf.transpose(1, 0, 2, 3).reshape(self.exp_loc,
                                                ctx.depth * cap, f)

        # ---- expert FFN ----
        cdt = self.cdt
        if self.run.moe_expert_layout == "local":
            # beyond-paper layout: expert weights live whole on their depth
            # slice; tokens are gathered to full width and SPLIT over col so
            # each col member computes a disjoint token range (weight gathers
            # -> token gathers; see EXPERIMENTS.md §Perf).
            q = ctx.cols
            Tt = buf.shape[1]
            bufg = cc.all_gather_inv(buf, ctx.axis_col, tiled=True, axis=2)
            jj = lax.axis_index(ctx.axis_col)
            bufj = lax.dynamic_slice_in_dim(bufg, jj * (Tt // q), Tt // q,
                                            axis=1)
            g = jnp.einsum("etf,efg->etg", bufj, p["we_gate"].astype(cdt),
                           preferred_element_type=jnp.float32).astype(cdt)
            u = jnp.einsum("etf,efg->etg", bufj, p["we_up"].astype(cdt),
                           preferred_element_type=jnp.float32).astype(cdt)
            hdn = jax.nn.silu(g) * u
            of = jnp.einsum("etg,egf->etf", hdn, p["we_down"].astype(cdt),
                            preferred_element_type=jnp.float32).astype(cdt)
            og = cc.all_gather_inv(of, ctx.axis_col, tiled=True, axis=1)
            floc = f
            out = lax.dynamic_slice_in_dim(og, jj * floc, floc, axis=2)
            out = cc.pvary(out, (ctx.axis_col,))  # token-slice varies by col
        else:
            # paper-style: each expert's matmuls 2-D SUMMA over (row, col)
            g = tesseract_matmul_experts(ctx, buf, p["we_gate"].astype(cdt))
            u = tesseract_matmul_experts(ctx, buf, p["we_up"].astype(cdt))
            hdn = jax.nn.silu(g) * u
            out = tesseract_matmul_experts(ctx, hdn, p["we_down"].astype(cdt))

        # ---- route back ----
        out = out.reshape(self.exp_loc, ctx.depth, cap, f).transpose(1, 0, 2, 3)
        if ctx.depth > 1:
            out = lax.all_to_all(out, ctx.axis_depth, split_axis=0,
                                 concat_axis=0, tiled=False)
        out = out.reshape(E * cap, f)
        out = jnp.concatenate([out, jnp.zeros((1, f), out.dtype)], axis=0)

        # ---- combine: gather slots back per (token, choice), weight ----
        picked = out[slot]                                        # [N*k, f]
        w = jnp.where(keep, sg, 0.0).astype(jnp.float32)
        y = jnp.zeros((N, f), jnp.float32).at[st].add(
            picked.astype(jnp.float32) * w[:, None])
        y = y.astype(x.dtype).reshape(B, T, f)
        if ops.plan.kind in ("long_decode", "decode_dp") and ctx.depth > 1:
            # small-batch decode: tokens are replicated over depth, so the
            # routed output is too (every depth slice assembles all experts'
            # results) — make the vma reflect it (tiny psum; one token/step).
            y = cc.last_shard_value(y, (ctx.axis_depth,))

        if cfg.moe_shared_experts:
            hg = ops.seq_gather_in(x)
            sg_ = ops.linear_up(hg, p["ws_gate"])
            su = ops.linear_up(hg, p["ws_up"])
            y = y + ops.linear_down(jax.nn.silu(sg_) * su, p["ws_down"])
        return y, aux

    # ------------------------------------------------------------- MLA attn
    def _mla_qkv(self, p, xg, ops, positions):
        cfg = self.cfg
        B, T = xg.shape[:2]
        HL = self.Hp // ops.head_shards
        cq = ops.linear(xg, p["w_dq"])
        cq = ops.rmsnorm(cq, p["ln_q"], cfg.norm_eps)
        q = ops.linear(cq, p["w_uq"]).reshape(B, T, HL, self.qk_dim)
        q_nope, q_rope = jnp.split(q, [cfg.qk_nope_dim], axis=-1)
        q_rope = cm.apply_rope(q_rope, positions, cfg.rope_theta)
        ckv = ops.linear(xg, p["w_dkv"])
        ckv = ops.rmsnorm(ckv, p["ln_kv"], cfg.norm_eps)
        kr = ops.linear_to_replicated(xg, p["w_kr"])[:, :, None, :]  # [B,T,1,r]
        kr = cm.apply_rope(kr, positions, cfg.rope_theta)
        return jnp.concatenate([q_nope, q_rope], -1), ckv, kr

    def _mla_expand(self, p, ckv_full, ops):
        """Expand (gathered) compressed KV to per-head K/V."""
        cfg = self.cfg
        B, S = ckv_full.shape[:2]
        HL = self.Hp // ops.head_shards
        kv = ops.linear(ckv_full, p["w_ukv"])
        kv = kv.reshape(B, S, HL, cfg.qk_nope_dim + cfg.v_head_dim)
        return jnp.split(kv, [cfg.qk_nope_dim], axis=-1)  # k_nope, v

    def _mla_attention(self, p, x, ops, full_kv_pos):
        cfg = self.cfg
        h = self._norm(ops, x, p["ln1"])
        hg = ops.seq_gather_in(h)
        T = hg.shape[1]
        qpos = ops.positions_q(T)
        q, ckv, kr = self._mla_qkv(p, hg, ops, qpos)
        ckv_f = ops.kv_full(ckv, axis=1)       # gather compressed, not expanded
        kr_f = ops.kv_full(kr, axis=1)
        k_nope, v = self._mla_expand(p, ckv_f, ops)
        HL = k_nope.shape[2]
        k = jnp.concatenate(
            [k_nope, jnp.broadcast_to(kr_f, k_nope.shape[:3] + (cfg.qk_rope_dim,))],
            axis=-1)
        q_start = (0 if (not ops.plan.seq_sharded
                         or ops.mode_family == "megatron") else None)
        out = cm.attention(
            q, k, v, q_pos=qpos, kv_pos=full_kv_pos, causal=True,
            q_chunk=self.run.q_chunk, kv_chunk=self.run.kv_chunk,
            softmax_scale=1.0 / math.sqrt(self.qk_dim),
            impl=self.ctx.attn_impl, q_start=q_start)
        return self._attn_out_mla(p, out, ops), (ckv, kr)

    def _attn_out_mla(self, p, out, ops):
        B, T = out.shape[:2]
        hm = self._head_mask(ops)
        if hm is not None:
            out = out * hm[None, None, :, None]
        out = out.reshape(B, T, -1)
        return ops.linear_down(out, p["wo"])

    def _head_mask(self, ops):
        if self.Hp == self.cfg.num_heads:
            return None
        hloc = self.Hp // ops.head_shards
        gidx = lax.axis_index(self.ctx.axis_col) * hloc + jnp.arange(hloc)
        return (gidx < self.cfg.num_heads).astype(self.cdt)

    # ------------------------------------------------------------- blocks
    def _block_train(self, p, x, ops, full_kv_pos, collect_kv=False):
        if self.is_mla:
            attn, kv = self._mla_attention(p, x, ops, full_kv_pos)
            x = x + attn
        else:
            x_new = DenseLM._block_train_attn(self, p, x, ops, full_kv_pos)
            x, kv = x_new
        h2 = self._norm(ops, x, p["ln2"])
        y, aux = self._moe_ffn(p, h2, ops)
        x = x + y
        return (x, aux, kv) if collect_kv else (x, aux)

    def _run_blocks_moe(self, params, x, ops, full_kv_pos, cast):
        from .transformer import maybe_remat

        def dense_body(xx, bp):
            return DenseLM._block_train(self, cast(bp), xx, ops, full_kv_pos), None

        def body(carry, bp):
            xx, aux = carry
            xx, a = self._block_train(cast(bp), xx, ops, full_kv_pos)
            return (xx, aux + a), None

        if self.cfg.first_dense:
            x, _ = lax.scan(maybe_remat(dense_body, self.run), x,
                            params["dense_blocks"])
        aux0 = jnp.float32(0)
        (x, aux), _ = lax.scan(maybe_remat(body, self.run), (x, aux0),
                               params["blocks"])
        return x, aux

    def loss(self, params, batch, ops):
        x = ops.embed(batch["tokens"], params["embed"]).astype(self.cdt)
        T_loc = x.shape[1]
        n_seq = (self.ctx.depth * self.ctx.rows if ops.plan.seq_sharded else 1)
        full_kv_pos = jnp.arange(T_loc * n_seq)
        cast = lambda t: jax.tree.map(lambda a: a.astype(self.cdt), t)
        x, aux = self._run_blocks_moe(params, x, ops, full_kv_pos, cast)
        x = self._norm(ops, x, params["ln_f"])
        loss_sum, cnt = ops.ce_loss(
            x, params["head"].astype(self.cdt), batch["labels"],
            vocab_real=self.cfg.vocab_size, loss_chunk=self.run.loss_chunk,
            label_mask=batch.get("mask"))
        loss_sum = lax.psum(loss_sum, self.ctx.axis_data)
        cnt = lax.psum(cnt, self.ctx.axis_data)
        n_moe = self.cfg.num_layers - self.cfg.first_dense
        return loss_sum / jnp.maximum(cnt, 1.0) + 0.01 * aux / n_moe

    # ------------------------------------------------------------ serving
    def cache_abstract(self, batch_global: int, seq_len: int, plan):
        if not self.is_mla:
            return super().cache_abstract(batch_global, seq_len, plan)
        from jax import ShapeDtypeStruct as Sds
        from jax.sharding import PartitionSpec as P
        cfg = self.cfg
        L = cfg.num_layers - cfg.first_dense
        tok = (("data", "depth", "row") if plan.kind == "decode"
               else "data" if plan.kind == "decode_dp" else None)
        sds = {
            "ckv": Sds((L, batch_global, seq_len, cfg.mla_kv_lora), self.cdt),
            "kr": Sds((L, batch_global, seq_len, cfg.qk_rope_dim), self.cdt),
        }
        specs = {"ckv": P(None, tok, None, None), "kr": P(None, tok, None, None)}
        if cfg.first_dense:
            dshape = (cfg.first_dense, batch_global, seq_len,
                      cfg.num_kv_heads, self.D)
            kv_sp = P(None, tok, None, "col" if self.kv_shard else None, None)
            sds.update(dk=Sds(dshape, self.cdt), dv=Sds(dshape, self.cdt))
            specs.update(dk=kv_sp, dv=kv_sp)
        return sds, specs

    def prefill_cache_specs(self, ops):
        if not self.is_mla:
            return super().prefill_cache_specs(ops)
        from jax.sharding import PartitionSpec as P
        seq = ("depth", "row")
        specs = {"ckv": P(None, "data", seq, "col"),
                 "kr": P(None, "data", seq, None)}
        if self.cfg.first_dense:
            kv_sp = P(None, "data", seq, "col" if self.kv_shard else None, None)
            specs.update(dk=kv_sp, dv=kv_sp)
        return specs

    def prefill(self, params, batch, ops):
        cfg = self.cfg
        x = ops.embed(batch["tokens"], params["embed"]).astype(self.cdt)
        S_loc = x.shape[1]
        n_seq = (self.ctx.depth * self.ctx.rows if ops.plan.seq_sharded else 1)
        full_kv_pos = jnp.arange(S_loc * n_seq)
        cast = lambda t: jax.tree.map(lambda a: a.astype(self.cdt), t)
        cache = {}
        if cfg.first_dense:
            def dbody(xx, bp):
                return DenseLM._block_prefill(self, cast(bp), xx, ops, full_kv_pos)
            x, (dk, dv) = lax.scan(dbody, x, params["dense_blocks"])
            cache.update(dk=dk, dv=dv)

        def body(carry, bp):
            xx, aux = carry
            bp = cast(bp)
            if self.is_mla:
                attn, (ckv, kr) = self._mla_attention(bp, xx, ops, full_kv_pos)
                xx = xx + attn
                kv_out = (ckv.astype(self.cdt), kr[:, :, 0, :].astype(self.cdt))
            else:
                xx, kv_pair = DenseLM._block_prefill_attnonly(self, bp, xx, ops,
                                                              full_kv_pos)
                kv_out = kv_pair
            h2 = self._norm(ops, xx, bp["ln2"])
            y, a = self._moe_ffn(bp, h2, ops)
            return (xx + y, aux + a), kv_out

        (x, _aux), kvs = lax.scan(body, (x, jnp.float32(0)), params["blocks"])
        x = self._norm(ops, x, params["ln_f"])
        x_last = ops_last_token(ops, x, self.ctx)
        ids = ops.head_sample(x_last, params["head"].astype(self.cdt),
                              vocab_real=cfg.vocab_size, tokens_sharded=False)
        if self.is_mla:
            cache.update(ckv=kvs[0], kr=kvs[1])
        else:
            cache.update(k=kvs[0], v=kvs[1])
        return ids[:, None] if ids.ndim == 1 else ids, cache

    def _mla_decode_attn(self, p, x, cache_l, pos, ops):
        """Absorbed MLA decode against the compressed cache."""
        cfg, ctx = self.cfg, self.ctx
        B = x.shape[0]
        HL = self.Hp // ops.head_shards
        h = self._norm(ops, x, p["ln1"])
        positions = jnp.full((1,), pos, jnp.int32)
        q, ckv, kr = self._mla_qkv(p, h, ops, positions)
        q_nope, q_rope = jnp.split(q[:, 0], [cfg.qk_nope_dim], axis=-1)  # [B,HL,*]
        # write compressed entries (ckv concatenated to full width for the
        # cache; vma-invariant over col to satisfy the cache out_spec)
        ckv_full = cc.unvary_concat(ckv, ctx.axis_col, ckv.ndim - 1)
        cache_l = dict(cache_l)
        cache_l["ckv"] = lax.dynamic_update_slice_in_dim(
            cache_l["ckv"], ckv_full.astype(cache_l["ckv"].dtype), pos, axis=1)
        cache_l["kr"] = lax.dynamic_update_slice_in_dim(
            cache_l["kr"], kr[:, :, 0, :].astype(cache_l["kr"].dtype), pos, axis=1)
        # absorb: gather w_ukv rows (full kv_lora) once per step
        wg = cc.all_gather_inv(p["w_ukv"], ctx.axis_row, tiled=True, axis=0)
        wg = wg.reshape(cfg.mla_kv_lora, HL, cfg.qk_nope_dim + cfg.v_head_dim)
        w_uk, w_uv = wg[..., :cfg.qk_nope_dim], wg[..., cfg.qk_nope_dim:]
        q_abs = jnp.einsum("bhd,lhd->bhl", q_nope, w_uk,
                           preferred_element_type=jnp.float32)
        s = jnp.einsum("bhl,bsl->bhs", q_abs,
                       cache_l["ckv"].astype(jnp.float32))
        s = s + jnp.einsum("bhr,bsr->bhs", q_rope.astype(jnp.float32),
                           cache_l["kr"].astype(jnp.float32))
        s = s / math.sqrt(self.qk_dim)
        S = cache_l["ckv"].shape[1]
        mask = jnp.arange(S)[None, None, :] <= pos
        s = jnp.where(mask, s, -jnp.inf)
        pattn = jax.nn.softmax(s, axis=-1)
        lat = jnp.einsum("bhs,bsl->bhl", pattn, cache_l["ckv"].astype(jnp.float32))
        out = jnp.einsum("bhl,lhd->bhd", lat, w_uv.astype(jnp.float32))
        out = out.astype(self.cdt)[:, None]                      # [B,1,HL,vd]
        return self._attn_out_mla(p, out, ops), cache_l

    def decode(self, params, cache, ids, pos, ops):
        cfg = self.cfg
        x = ops.embed(ids, params["embed"]).astype(self.cdt)
        cast = lambda t: jax.tree.map(lambda a: a.astype(self.cdt), t)
        if cfg.first_dense:
            # scan over the dense prefix
            def dbody2(xx, xs):
                bp, ck, cv = xs
                y, cl2 = DenseLM._block_decode(self, cast(bp), xx,
                                               {"k": ck, "v": cv}, pos, ops)
                return y, (cl2["k"], cl2["v"])
            x, (ndk, ndv) = lax.scan(dbody2, x,
                                     (params["dense_blocks"], cache["dk"],
                                      cache["dv"]))
        def body(xx, xs):
            bp, *cl = xs
            bp = cast(bp)
            if self.is_mla:
                attn, cl2 = self._mla_decode_attn(bp, xx,
                                                  {"ckv": cl[0], "kr": cl[1]},
                                                  pos, ops)
                xx = xx + attn
                cl_out = (cl2["ckv"], cl2["kr"])
            else:
                y, cl2 = DenseLM._block_decode_attnonly(self, bp, xx,
                                                        {"k": cl[0], "v": cl[1]},
                                                        pos, ops)
                xx = y
                cl_out = (cl2["k"], cl2["v"])
            h2 = self._norm(ops, xx, bp["ln2"])
            yff, _aux = self._moe_ffn(bp, h2, ops)
            return xx + yff, cl_out

        if self.is_mla:
            x, (nckv, nkr) = lax.scan(body, x,
                                      (params["blocks"], cache["ckv"],
                                       cache["kr"]))
            new_cache = {"ckv": nckv, "kr": nkr}
        else:
            x, (nk, nv) = lax.scan(body, x,
                                   (params["blocks"], cache["k"], cache["v"]))
            new_cache = {"k": nk, "v": nv}
        if cfg.first_dense:
            new_cache.update(dk=ndk, dv=ndv)
        x = self._norm(ops, x, params["ln_f"])
        nids = ops.head_sample(x, params["head"].astype(self.cdt),
                               vocab_real=cfg.vocab_size)
        return nids, new_cache
