"""llama-3.2-vision: dense GQA decoder with interleaved cross-attention
blocks that attend to (stubbed) vision patch embeddings.

Frontend stub per the harness: ``input_specs()`` supplies precomputed patch
embeddings [B, vision_tokens, vision_dim]; the vision encoder itself is out
of scope.  Layer layout: scan over superblocks of (cross_attn_every-1) self
blocks + 1 gated cross block.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from ..core import collectives as cc
from . import common as cm
from .transformer import DenseLM, ops_last_token


class VisionLM(DenseLM):
    supports_pipeline = False  # modality extras not stage-decomposed
    supports_seq_shard = False  # cross-attn reads the full vision seq

    def __init__(self, cfg, ctx, run):
        super().__init__(cfg, ctx, run)
        if cfg.num_layers % cfg.cross_attn_every:
            raise ValueError("num_layers must divide into superblocks")
        self.n_super = cfg.num_layers // cfg.cross_attn_every
        self.n_self = cfg.cross_attn_every - 1

    # ------------------------------------------------------------- params
    def _cross_init(self, key):
        cfg, D = self.cfg, self.D
        h, vd = cfg.d_model, cfg.vision_dim
        ks = jax.random.split(key, 6)
        return {
            "ln": jnp.zeros((h,), self.pdt),
            "wq": cm.winit_padded(ks[0], (h, cfg.num_heads * D),
                                  (h, self.Hp * D), dtype=self.pdt),
            "wk": cm.winit(ks[1], (vd, cfg.num_kv_heads * D), dtype=self.pdt),
            "wv": cm.winit(ks[2], (vd, cfg.num_kv_heads * D), dtype=self.pdt),
            "wo": cm.winit_padded(ks[3], (cfg.num_heads * D, h),
                                  (self.Hp * D, h), dtype=self.pdt),
            "ln2": jnp.zeros((h,), self.pdt),
            "w_gate": cm.winit(ks[4], (h, cfg.d_ff), dtype=self.pdt),
            "w_up": cm.winit(ks[5], (h, cfg.d_ff), dtype=self.pdt),
            "w_down": cm.winit(jax.random.fold_in(key, 7), (cfg.d_ff, h),
                               dtype=self.pdt),
            "attn_gate": jnp.zeros((), self.pdt),
            "mlp_gate": jnp.zeros((), self.pdt),
        }

    def _super_init(self, key):
        ks = jax.random.split(key, self.n_self + 1)
        selfs = jax.vmap(super()._block_init)(ks[: self.n_self])
        return {"selfs": selfs, "cross": self._cross_init(ks[-1])}

    def init(self, key):
        cfg = self.cfg
        k_e, k_h, k_b = jax.random.split(key, 3)
        supers = jax.vmap(self._super_init)(jax.random.split(k_b, self.n_super))
        return {
            "embed": cm.winit_padded(k_e, (cfg.vocab_size, cfg.d_model),
                                     (self.v_pad, cfg.d_model), dtype=self.pdt),
            "head": cm.winit_padded(k_h, (cfg.vocab_size, cfg.d_model),
                                    (self.v_pad, cfg.d_model), dtype=self.pdt),
            "ln_f": jnp.zeros((cfg.d_model,), self.pdt),
            "supers": supers,
        }

    def _cross_specs(self, ops):
        kv_spec = (ops.spec_w2d(True) if self.kv_shard
                   else ops.spec_w_to_replicated(True))
        return {
            "ln": ops.spec_norm(True), "wq": ops.spec_w2d(True),
            "wk": kv_spec, "wv": kv_spec, "wo": ops.spec_w_down(True),
            "ln2": ops.spec_norm(True), "w_gate": ops.spec_w2d(True),
            "w_up": ops.spec_w2d(True), "w_down": ops.spec_w_down(True),
            "attn_gate": jax.sharding.PartitionSpec(None),
            "mlp_gate": jax.sharding.PartitionSpec(None),
        }

    def specs(self, ops):
        from jax.sharding import PartitionSpec as P
        stackone = lambda s: P(*((None,) + tuple(s)))
        return {
            "embed": ops.spec_embed(), "head": ops.spec_head(),
            "ln_f": ops.spec_norm(False),
            "supers": {
                # selfs leaves are [n_super, n_self, ...] -> one extra None
                # over the (already stacked) block specs
                "selfs": jax.tree.map(
                    stackone, DenseLM._block_specs(self, ops),
                    is_leaf=lambda x: isinstance(x, P)),
                # cross leaves are [n_super, ...] -> stacked specs directly
                "cross": self._cross_specs(ops),
            },
        }

    def tess_weight_names(self):
        return super().tess_weight_names()

    # ------------------------------------------------------------ vision
    def batch_extras(self, shape):
        from jax.sharding import PartitionSpec as P
        cfg = self.cfg
        B = shape.global_batch
        sd = jax.ShapeDtypeStruct((B, cfg.vision_tokens, cfg.vision_dim),
                                  jnp.float32)
        sp = (P(("data", "depth"), None, None) if shape.kind == "train"
              else P("data", None, None))
        return {"vision": (sd, sp)}

    def shard_vision(self, ops, vision):
        """[B', Tv, vd] host layout -> [B_loc, Tv, vd/q] canonical."""
        v = ops.shard_tokens(vision) if ops.plan.kind == "train" else vision
        # slice feature dim by col (vision_dim enters tesseract matmuls)
        q = self.ctx.cols
        n = v.shape[-1] // q
        i = lax.axis_index(self.ctx.axis_col)
        return lax.dynamic_slice_in_dim(v, i * n, n, axis=v.ndim - 1)

    def _cross_kv(self, p, vis, ops):
        cfg, D = self.cfg, self.D
        B, Tv = vis.shape[:2]
        if self.kv_shard:
            k = ops.linear_up(vis, p["wk"])
            v = ops.linear_up(vis, p["wv"])
        else:
            k = ops.linear_to_replicated(vis, p["wk"])
            v = ops.linear_to_replicated(vis, p["wv"])
        kvl = self._kv_heads_loc(ops)
        return k.reshape(B, Tv, kvl, D), v.reshape(B, Tv, kvl, D)

    def _cross_block(self, p, x, vis, ops):
        cfg, D = self.cfg, self.D
        h = self._norm(ops, x, p["ln"])
        hg = ops.seq_gather_in(h)
        B, T = hg.shape[:2]
        q = ops.linear_up(hg, p["wq"]).reshape(B, T, self._heads_loc(ops), D)
        k, v = self._cross_kv(p, vis, ops)
        if not self.kv_shard:
            kv_map = self._kv_map(ops)
            k = jnp.take(k, kv_map, axis=2)
            v = jnp.take(v, kv_map, axis=2)
        Tv = k.shape[1]
        out = cm.attention(
            q, k, v, q_pos=jnp.zeros((T,), jnp.int32),
            kv_pos=jnp.zeros((Tv,), jnp.int32), causal=False,
            q_chunk=self.run.q_chunk, kv_chunk=self.run.kv_chunk,
            impl=self.ctx.attn_impl, q_start=0)
        gated = jnp.tanh(p["attn_gate"]) * self._attn_out(
            p, out, ops, self._head_mask(ops))
        x = x + gated
        h2 = self._norm(ops, x, p["ln2"])
        x = x + jnp.tanh(p["mlp_gate"]) * self._mlp(p, h2, ops)
        return x

    def _run_supers(self, params, x, vis, ops, full_kv_pos, self_fn):
        from .transformer import maybe_remat
        cast = lambda t: jax.tree.map(lambda a: a.astype(self.cdt), t)

        def super_body(carry, sp):
            xx, extras = carry

            def self_body(c, bp):
                y, e = self_fn(cast(bp), c[0], ops, full_kv_pos)
                return (y, None), e

            (xx, _), kvs = lax.scan(self_body, (xx, None), sp["selfs"])
            xx = self._cross_block(cast(sp["cross"]), xx, vis, ops)
            return (xx, extras), kvs

        body = maybe_remat(super_body, self.run)
        (x, _), kvs = lax.scan(body, (x, None), params["supers"])
        return x, kvs

    # -------------------------------------------------------------- steps
    def loss(self, params, batch, ops):
        vis = self.shard_vision(ops, batch["vision"]).astype(self.cdt)
        x = ops.embed(batch["tokens"], params["embed"]).astype(self.cdt)
        T_loc = x.shape[1]
        n_seq = (self.ctx.depth * self.ctx.rows if ops.plan.seq_sharded else 1)
        full_kv_pos = jnp.arange(T_loc * n_seq)

        def self_fn(bp, xx, o, pos):
            return DenseLM._block_train(self, bp, xx, o, pos), None

        x, _ = self._run_supers(params, x, vis, ops, full_kv_pos, self_fn)
        x = self._norm(ops, x, params["ln_f"])
        loss_sum, cnt = ops.ce_loss(
            x, params["head"].astype(self.cdt), batch["labels"],
            vocab_real=self.cfg.vocab_size, loss_chunk=self.run.loss_chunk,
            label_mask=batch.get("mask"))
        loss_sum = lax.psum(loss_sum, self.ctx.axis_data)
        cnt = lax.psum(cnt, self.ctx.axis_data)
        return loss_sum / jnp.maximum(cnt, 1.0)

    def cache_abstract(self, batch_global: int, seq_len: int, plan):
        from jax import ShapeDtypeStruct as Sds
        from jax.sharding import PartitionSpec as P
        cfg = self.cfg
        (sds, specs) = super().cache_abstract(batch_global, seq_len, plan)
        # self-attn cache covers only the self blocks
        L_self = self.n_super * self.n_self
        for key in ("k", "v"):
            s = sds[key]
            sds[key] = Sds((L_self,) + s.shape[1:], s.dtype)
        # cross KV cache (computed at prefill, reused each decode step)
        tok = (("data", "depth", "row") if plan.kind == "decode"
               else "data" if plan.kind == "decode_dp" else None)
        cshape = (self.n_super, batch_global, cfg.vision_tokens,
                  cfg.num_kv_heads, self.D)
        csp = P(None, tok, None, "col" if self.kv_shard else None, None)
        sds.update(ck=Sds(cshape, self.cdt), cv=Sds(cshape, self.cdt))
        specs.update(ck=csp, cv=csp)
        return sds, specs

    def prefill_cache_specs(self, ops):
        from jax.sharding import PartitionSpec as P
        base = super().prefill_cache_specs(ops)
        csp = P(None, "data", None, "col" if self.kv_shard else None, None)
        base.update(ck=csp, cv=csp)
        return base

    def prefill(self, params, batch, ops):
        # batch: {"tokens", "vision"}
        tokens, vision = batch["tokens"], batch["vision"]
        vis = self.shard_vision(ops, vision).astype(self.cdt)
        x = ops.embed(tokens, params["embed"]).astype(self.cdt)
        S_loc = x.shape[1]
        n_seq = (self.ctx.depth * self.ctx.rows if ops.plan.seq_sharded else 1)
        if self.ctx.mode == "megatron1d" and ops.plan.seq_sharded:
            n_seq = self.ctx.cols
        full_kv_pos = jnp.arange(S_loc * n_seq)
        cast = lambda t: jax.tree.map(lambda a: a.astype(self.cdt), t)

        def super_body(xx, sp):
            def self_body(c, bp):
                y, kv = DenseLM._block_prefill(self, cast(bp), c, ops,
                                               full_kv_pos)
                return y, kv
            xx, kvs = lax.scan(self_body, xx, sp["selfs"])
            cp = cast(sp["cross"])
            ck, cv = self._cross_kv(cp, vis, ops)
            xx = self._cross_block(cp, xx, vis, ops)
            return xx, (kvs, (ck.astype(self.cdt), cv.astype(self.cdt)))

        x, (kvs, cross_kv) = lax.scan(super_body, x, params["supers"])
        x = self._norm(ops, x, params["ln_f"])
        x_last = ops_last_token(ops, x, self.ctx)
        ids = ops.head_sample(x_last, params["head"].astype(self.cdt),
                              vocab_real=self.cfg.vocab_size,
                              tokens_sharded=False)
        k = kvs[0].reshape((-1,) + kvs[0].shape[2:])
        v = kvs[1].reshape((-1,) + kvs[1].shape[2:])
        return ids[:, None], {"k": k, "v": v, "ck": cross_kv[0],
                              "cv": cross_kv[1]}

    def _cross_decode(self, p, x, ck, cv, ops):
        cfg, D = self.cfg, self.D
        h = self._norm(ops, x, p["ln"])
        B = h.shape[0]
        q = ops.linear_up(h, p["wq"]).reshape(B, 1, self._heads_loc(ops), D)
        kv_map = None if self.kv_shard else self._kv_map(ops)
        out = cm.decode_attention(q[:, 0], ck, cv,
                                  cur_pos=ck.shape[1] - 1, kv_map=kv_map,
                                  impl=self.ctx.attn_impl)
        out = out[:, None]
        x = x + jnp.tanh(p["attn_gate"]) * self._attn_out(
            p, out, ops, self._head_mask(ops))
        h2 = self._norm(ops, x, p["ln2"])
        x = x + jnp.tanh(p["mlp_gate"]) * self._mlp(p, h2, ops)
        return x

    def decode(self, params, cache, ids, pos, ops):
        x = ops.embed(ids, params["embed"]).astype(self.cdt)
        cast = lambda t: jax.tree.map(lambda a: a.astype(self.cdt), t)
        kself = cache["k"].reshape((self.n_super, self.n_self)
                                   + cache["k"].shape[1:])
        vself = cache["v"].reshape((self.n_super, self.n_self)
                                   + cache["v"].shape[1:])

        def super_body(xx, xs):
            sp, ck_s, cv_s, kc, vc = xs

            def self_body(c, ys):
                bp, k1, v1 = ys
                y, cl = DenseLM._block_decode(self, cast(bp), c,
                                              {"k": k1, "v": v1}, pos, ops)
                return y, (cl["k"], cl["v"])

            xx, (nk, nv) = lax.scan(self_body, xx, (sp["selfs"], kc, vc))
            xx = self._cross_decode(cast(sp["cross"]), xx,
                                    ck_s.astype(self.cdt),
                                    cv_s.astype(self.cdt), ops)
            return xx, (nk, nv)

        x, (nk, nv) = lax.scan(super_body, x,
                               (params["supers"], cache["ck"], cache["cv"],
                                kself, vself))
        x = self._norm(ops, x, params["ln_f"])
        nids = ops.head_sample(x, params["head"].astype(self.cdt),
                               vocab_real=self.cfg.vocab_size)
        new_cache = dict(cache,
                         k=nk.reshape((-1,) + nk.shape[2:]),
                         v=nv.reshape((-1,) + nv.shape[2:]))
        return nids, new_cache
