"""Dense decoder-only LM (llama/yi/smollm/nemotron family) on Tesseract.

Covers: GQA (sharded or replicated KV heads), GLU / squared-ReLU MLPs,
rmsnorm/layernorm, RoPE, head padding when num_heads % q != 0.

The same class is the backbone base for the VLM (cross-attention) variant.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax import lax

from ..configs.base import ModelConfig, RunConfig, round_up
from ..core.api import ParallelContext
from ..core.ops import Plan, make_ops
from . import common as cm


def remat_policy(name: str):
    if name == "none":
        return None
    if name == "full":
        return "__full__"
    if name == "dots":
        return jax.checkpoint_policies.dots_with_no_batch_dims_saveable
    raise ValueError(name)


def maybe_remat(fn, run: RunConfig):
    p = remat_policy(run.remat)
    if p is None:
        return fn
    if p == "__full__":
        return jax.checkpoint(fn)
    return jax.checkpoint(fn, policy=p)


class DenseLM:
    def __init__(self, cfg: ModelConfig, ctx: ParallelContext, run: RunConfig):
        self.cfg, self.ctx, self.run = cfg, ctx, run
        q = ctx.cols
        self.Hp = round_up(cfg.num_heads, q)                 # padded q-heads
        self.kv_shard = cfg.num_kv_heads % q == 0
        self.D = cfg.resolved_head_dim
        probe = make_ops(ctx, Plan.for_shape("train"))
        self.v_pad = round_up(cfg.vocab_size, probe.vocab_pad_multiple())
        self.pdt = jnp.dtype(run.param_dtype)
        self.cdt = jnp.dtype(run.compute_dtype)

    # ------------------------------------------------------------- params
    def _block_init(self, key):
        cfg, D = self.cfg, self.D
        h, ff = cfg.d_model, cfg.d_ff
        ks = jax.random.split(key, 8)
        H = cfg.num_heads
        p = {
            "ln1": jnp.zeros((h,), self.pdt),
            "ln2": jnp.zeros((h,), self.pdt),
            "wq": cm.winit_padded(ks[0], (h, H * D), (h, self.Hp * D), dtype=self.pdt),
            "wk": cm.winit(ks[1], (h, cfg.num_kv_heads * D), dtype=self.pdt),
            "wv": cm.winit(ks[2], (h, cfg.num_kv_heads * D), dtype=self.pdt),
            "wo": cm.winit_padded(ks[3], (H * D, h), (self.Hp * D, h), dtype=self.pdt),
            "w_down": cm.winit(ks[6], (ff, h), dtype=self.pdt),
        }
        if cfg.mlp_glu:
            p["w_gate"] = cm.winit(ks[4], (h, ff), dtype=self.pdt)
            p["w_up"] = cm.winit(ks[5], (h, ff), dtype=self.pdt)
        else:
            p["w_up"] = cm.winit(ks[5], (h, ff), dtype=self.pdt)
        if cfg.use_bias:
            p["bq"] = jnp.zeros((self.Hp * D,), self.pdt)
            p["bv"] = jnp.zeros((cfg.num_kv_heads * D,), self.pdt)
            p["bo"] = jnp.zeros((h,), self.pdt)
            p["b_up"] = jnp.zeros((ff,), self.pdt)
            p["b_down"] = jnp.zeros((h,), self.pdt)
        if cfg.norm == "layernorm":
            p["ln1b"] = jnp.zeros((h,), self.pdt)
            p["ln2b"] = jnp.zeros((h,), self.pdt)
            p["ln1"] = jnp.ones((h,), self.pdt)
            p["ln2"] = jnp.ones((h,), self.pdt)
        return p

    def init(self, key):
        cfg = self.cfg
        k_e, k_h, k_b, k_f = jax.random.split(key, 4)
        blocks = jax.vmap(self._block_init)(jax.random.split(k_b, cfg.num_layers))
        params = {
            "embed": cm.winit_padded(k_e, (cfg.vocab_size, cfg.d_model),
                                     (self.v_pad, cfg.d_model), dtype=self.pdt),
            "head": cm.winit_padded(k_h, (cfg.vocab_size, cfg.d_model),
                                    (self.v_pad, cfg.d_model), dtype=self.pdt),
            "ln_f": (jnp.ones((cfg.d_model,), self.pdt)
                     if cfg.norm == "layernorm" else jnp.zeros((cfg.d_model,), self.pdt)),
            "blocks": blocks,
        }
        if cfg.norm == "layernorm":
            params["ln_fb"] = jnp.zeros((cfg.d_model,), self.pdt)
        return params

    def _block_specs(self, ops):
        cfg = self.cfg
        kv_spec = (ops.spec_w2d(True) if self.kv_shard
                   else ops.spec_w_to_replicated(True))
        s = {
            "ln1": ops.spec_norm(True), "ln2": ops.spec_norm(True),
            "wq": ops.spec_w2d(True), "wk": kv_spec, "wv": kv_spec,
            "wo": ops.spec_w_down(True), "w_down": ops.spec_w_down(True),
            "w_up": ops.spec_w2d(True),
        }
        if cfg.mlp_glu:
            s["w_gate"] = ops.spec_w2d(True)
        if cfg.use_bias:
            s.update(bq=ops.spec_bias_up(True),
                     bv=(ops.spec_bias_up(True) if self.kv_shard
                         else ops.spec_vec_replicated(True)),
                     bo=ops.spec_bias_down(True),
                     b_up=ops.spec_bias_up(True),
                     b_down=ops.spec_bias_down(True))
        if cfg.norm == "layernorm":
            s["ln1b"] = ops.spec_norm(True)
            s["ln2b"] = ops.spec_norm(True)
        return s

    def specs(self, ops):
        s = {
            "embed": ops.spec_embed(),
            "head": ops.spec_head(),
            "ln_f": ops.spec_norm(False),
            "blocks": self._block_specs(ops),
        }
        if self.cfg.norm == "layernorm":
            s["ln_fb"] = ops.spec_norm(False)
        return s

    # ------------------------------------------------------------ helpers
    def _norm(self, ops, x, scale, bias=None):
        if self.cfg.norm == "layernorm":
            return ops.layernorm(x, scale, bias, self.cfg.norm_eps)
        return ops.rmsnorm(x, scale, self.cfg.norm_eps)

    def _heads_loc(self, ops):
        return self.Hp // ops.head_shards

    def _kv_heads_loc(self, ops):
        return (self.cfg.num_kv_heads // ops.head_shards if self.kv_shard
                else self.cfg.num_kv_heads)

    def _head_mask(self, ops):
        """[Hq_loc] 1.0 for real heads, 0.0 for padded (smollm 15->16)."""
        if self.Hp == self.cfg.num_heads:
            return None
        hloc = self._heads_loc(ops)
        gidx = lax.axis_index(self.ctx.axis_col) * hloc + jnp.arange(hloc)
        return (gidx < self.cfg.num_heads).astype(self.cdt)

    def _kv_map(self, ops):
        """[Hq_loc] q-head -> kv-head map for the replicated-KV path."""
        cfg = self.cfg
        hloc = self._heads_loc(ops)
        gidx = lax.axis_index(self.ctx.axis_col) * hloc + jnp.arange(hloc)
        group = max(1, cfg.num_heads // cfg.num_kv_heads)
        return jnp.minimum(gidx // group, cfg.num_kv_heads - 1)

    def _qkv(self, p, xg, ops, positions):
        """Project and rope. Returns q [B,T,HqLoc,D], k/v [B,T,KvLoc,D]."""
        cfg, D = self.cfg, self.D
        B, T = xg.shape[:2]
        q = ops.linear_up(xg, p["wq"], p.get("bq"))
        if self.kv_shard:
            k = ops.linear_up(xg, p["wk"])
            v = ops.linear_up(xg, p["wv"], p.get("bv"))
        else:
            k = ops.linear_to_replicated(xg, p["wk"])
            v = ops.linear_to_replicated(xg, p["wv"], p.get("bv"))
        q = q.reshape(B, T, self._heads_loc(ops), D)
        k = k.reshape(B, T, self._kv_heads_loc(ops), D)
        v = v.reshape(B, T, self._kv_heads_loc(ops), D)
        if cfg.use_rope:
            q = cm.apply_rope(q, positions, cfg.rope_theta)
            k = cm.apply_rope(k, positions, cfg.rope_theta)
        return q, k, v

    def _attn_out(self, p, out, ops, head_mask):
        B, T = out.shape[:2]
        if head_mask is not None:
            out = out * head_mask[None, None, :, None]
        out = out.reshape(B, T, self._heads_loc(ops) * self.D)
        return ops.linear_down(out, p["wo"], p.get("bo"))

    def _mlp(self, p, x, ops):
        cfg = self.cfg
        xg = ops.seq_gather_in(x)
        act = cm.mlp_act("silu" if cfg.mlp_act == "silu" else cfg.mlp_act)
        if cfg.mlp_glu:
            g = ops.linear_up(xg, p["w_gate"])
            u = ops.linear_up(xg, p["w_up"], p.get("b_up"))
            h = act(g) * u
        else:
            h = act(ops.linear_up(xg, p["w_up"], p.get("b_up")))
        return ops.linear_down(h, p["w_down"], p.get("b_down"))

    # -------------------------------------------------------------- train
    def _ring_axes(self, ops):
        """Mesh axes to stream K/V around, or None for the local schedule.

        Train with ctx.seq > 1 rings over the dedicated "seq" axis;
        seq-sharded prefill with a non-local attn_schedule rings over the
        existing (depth, row) sequence sharding instead of gathering the
        full K/V (DESIGN.md §15)."""
        ctx = self.ctx
        if ops.mode_family != "tesseract" or ctx.attn_schedule == "local":
            return None
        if ops.plan.kind == "train" and ctx.seq > 1:
            return (ctx.axis_seq,)
        if ops.plan.seq_sharded and ctx.dq > 1:
            return ctx.seq_shard_axes
        return None

    def _ring_attn(self, q, k, v, ops, ring_axes):
        """Seq-sharded attention: ring/striped flash over ``ring_axes``."""
        from ..core.ring_attention import ring_attention
        from ..kernels.ops import _interpret, effective_attn_impl
        ctx = self.ctx
        variant = (ctx.train_attn_schedule() if ops.plan.kind == "train"
                   else "ring")  # prefill prompts are never striped
        if not self.kv_shard:
            kv_map = self._kv_map(ops)
            k = jnp.take(k, kv_map, axis=2)
            v = jnp.take(v, kv_map, axis=2)
        out = ring_attention(
            q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
            v.transpose(0, 2, 1, 3), axes=ring_axes, variant=variant,
            causal=True, local_window=self.cfg.local_window,
            impl=effective_attn_impl(self.ctx.attn_impl),
            interpret=_interpret())
        return out.transpose(0, 2, 1, 3)

    def _block_train_attn(self, p, x, ops, full_kv_pos):
        """Attention sublayer (residual included); returns (x, (k, v) local
        seq-slices for prefill caching)."""
        run = self.run
        h = self._norm(ops, x, p["ln1"], p.get("ln1b"))
        hg = ops.seq_gather_in(h)
        T = hg.shape[1]
        qpos = ops.positions_q(T)
        q, k, v = self._qkv(p, hg, ops, qpos)
        ring_axes = self._ring_axes(ops)
        if ring_axes is not None:
            out = self._ring_attn(q, k, v, ops, ring_axes)
            x = x + self._attn_out(p, out, ops, self._head_mask(ops))
            kv = (ops.kv_local_slice(k, axis=1).astype(self.cdt),
                  ops.kv_local_slice(v, axis=1).astype(self.cdt))
            return x, kv
        # seq-sharded plans gather KV to full length (positions 0..S-1)
        kf = ops.kv_full(k, axis=1)
        vf = ops.kv_full(v, axis=1)
        if not self.kv_shard:
            kv_map = self._kv_map(ops)
            kf = jnp.take(kf, kv_map, axis=2)
            vf = jnp.take(vf, kv_map, axis=2)
        # static q-row offset (enables the flash kernel's causal block
        # skipping) except on the seq-sharded tesseract prefill, whose
        # positions carry a traced shard offset
        q_start = (0 if (not ops.plan.seq_sharded
                         or ops.mode_family == "megatron") else None)
        out = cm.attention(
            q, kf, vf, q_pos=qpos, kv_pos=full_kv_pos,
            causal=True, local_window=self.cfg.local_window,
            q_chunk=run.q_chunk, kv_chunk=run.kv_chunk,
            impl=self.ctx.attn_impl, q_start=q_start)
        x = x + self._attn_out(p, out, ops, self._head_mask(ops))
        kv = (ops.kv_local_slice(k, axis=1).astype(self.cdt),
              ops.kv_local_slice(v, axis=1).astype(self.cdt))
        return x, kv

    def _block_train(self, p, x, ops, full_kv_pos):
        x, _ = self._block_train_attn(p, x, ops, full_kv_pos)
        h2 = self._norm(ops, x, p["ln2"], p.get("ln2b"))
        x = x + self._mlp(p, h2, ops)
        return x

    def _run_blocks(self, params, x, ops, block_fn):
        body = maybe_remat(
            lambda xx, bp: (block_fn(bp, xx), None), self.run)
        if self.run.scan_blocks:
            x, _ = lax.scan(body, x, params["blocks"])
        else:
            L = jax.tree.leaves(params["blocks"])[0].shape[0]
            for i in range(L):
                bp = jax.tree.map(lambda a: a[i], params["blocks"])
                x, _ = body(x, bp)
        return x

    # --- pipeline stage API (runtime/steps pipelined train path) ---
    # The trunk is decomposed so a pipe stage can run embed / its local block
    # slice / the loss head independently: params["blocks"] leaves arrive
    # stage-sharded over the pipe mesh axis, so pipe_blocks naturally applies
    # only this stage's layers.
    supports_pipeline = True
    # Sequence-axis sharding (ring/striped attention, DESIGN.md §15) needs
    # every time-mixing op to be ring-able: true for pure attention trunks,
    # false for SSM/LRU recurrences (state crosses shard boundaries) and for
    # capacity-factor MoE routing (token grouping is layout dependent).
    supports_seq_shard = True

    def pipe_embed(self, params, tokens, ops):
        """Host-layout ids -> canonical activation (stage-0 entry)."""
        return ops.embed(tokens, params["embed"]).astype(self.cdt)

    def pipe_blocks(self, params, x, ops):
        """Apply this stage's (local) block slice to a canonical activation."""
        T_loc = x.shape[1]
        n_seq = ops.token_shards // self.ctx.data if ops.plan.seq_sharded else 1
        full_kv_pos = jnp.arange(T_loc * (n_seq if ops.plan.seq_sharded else 1))
        cast = lambda t: jax.tree.map(lambda a: a.astype(self.cdt)
                                      if a.dtype == self.pdt and a.ndim > 1
                                      else a, t)
        return self._run_blocks(
            params, x, ops,
            lambda bp, xx: self._block_train(cast(bp), xx, ops, full_kv_pos))

    def pipe_loss_sums(self, params, x, labels, ops, label_mask=None):
        """Final norm + chunked CE -> local (loss_sum, count) (last stage)."""
        x = self._norm(ops, x, params["ln_f"], params.get("ln_fb"))
        return ops.ce_loss(
            x, params["head"].astype(self.cdt), labels,
            vocab_real=self.cfg.vocab_size, loss_chunk=self.run.loss_chunk,
            label_mask=label_mask)

    def _trunk(self, params, tokens, ops):
        """embed -> blocks -> final norm (shared by loss and prefill)."""
        x = self.pipe_embed(params, tokens, ops)
        x = self.pipe_blocks(params, x, ops)
        return self._norm(ops, x, params["ln_f"], params.get("ln_fb"))

    def loss(self, params, batch, ops):
        x = self.pipe_embed(params, batch["tokens"], ops)
        x = self.pipe_blocks(params, x, ops)
        loss_sum, cnt = self.pipe_loss_sums(params, x, batch["labels"], ops,
                                            batch.get("mask"))
        # each seq shard holds different tokens, so the seq axis joins the
        # data axis in the final loss reduction
        axes = ((self.ctx.axis_data, self.ctx.axis_seq) if self.ctx.seq > 1
                else (self.ctx.axis_data,))
        loss_sum = lax.psum(loss_sum, axes)
        cnt = lax.psum(cnt, axes)
        return loss_sum / jnp.maximum(cnt, 1.0)

    # ------------------------------------------------------------ serving
    def tess_weight_names(self):
        """Param dict keys that flow exclusively through tesseract_matmul
        (their grads are reduced in-op when reduce_dgrad_in_op=True)."""
        if self.ctx.mode not in ("tesseract", "summa2d"):
            return set()
        names = {"wq", "wo", "w_up", "w_down"}
        if self.cfg.mlp_glu:
            names.add("w_gate")
        if self.kv_shard:
            names.update({"wk", "wv"})
        return names

    def cache_abstract(self, batch_global: int, seq_len: int, plan):
        """Global cache ShapeDtypeStructs + specs (decode layout)."""
        from jax import ShapeDtypeStruct as Sds
        from jax.sharding import PartitionSpec as P
        cfg = self.cfg
        if self.ctx.mode == "megatron1d":
            tok = "data" if plan.kind == "decode" else None
            kv_sp = P(None, tok, None, None, None)
        else:
            tok = (("data", "depth", "row") if plan.kind == "decode"
               else "data" if plan.kind == "decode_dp" else None)
            kv_sp = P(None, tok, None, "col" if self.kv_shard else None, None)
        shp = (cfg.num_layers, batch_global, seq_len, cfg.num_kv_heads, self.D)
        return ({"k": Sds(shp, self.cdt), "v": Sds(shp, self.cdt)},
                {"k": kv_sp, "v": kv_sp})

    def paged_cache_abstract(self, num_blocks: int, block_size: int, plan):
        """Global block-pool ShapeDtypeStructs + specs (paged decode layout).

        The pool is [L, P, bs, Hkv, D]: the physical-block axis P is sharded
        over the plan's KV group axes (serve/kv_cache.py keeps each batch
        slot's pages inside its group shard) and KV heads over col exactly
        like the dense decode cache — so reads stay device-local."""
        from jax import ShapeDtypeStruct as Sds
        from jax.sharding import PartitionSpec as P
        from ..core.ops import kv_group_axes
        cfg = self.cfg
        gaxes = kv_group_axes(self.ctx, plan)
        heads = None
        if self.ctx.mode != "megatron1d" and self.kv_shard:
            heads = "col"
        sp = P(None, gaxes if gaxes else None, None, heads, None)
        shp = (cfg.num_layers, num_blocks, block_size, cfg.num_kv_heads,
               self.D)
        return ({"k": Sds(shp, self.cdt), "v": Sds(shp, self.cdt)},
                {"k": sp, "v": sp})

    def _block_decode_paged(self, p, x, pool_l, table, pos, ops, *,
                            idx=None, pos_mask=None, kv_map=None):
        """Paged analogue of _block_decode: walk K/V pages through the
        block table, scatter the new token's K/V at each request's own
        position (mixed lengths in one fixed-shape batch).  ``idx`` /
        ``pos_mask`` / ``kv_map`` are position-only values hoisted out of
        the layer scan by decode_paged."""
        cfg = self.cfg
        h = self._norm(ops, x, p["ln1"], p.get("ln1b"))
        q, k, v = self._qkv(p, h, ops, pos[:, None])
        pool_l = cm.paged_update(pool_l, table, pos, k, v, idx=idx)
        out = cm.paged_attention(q[:, 0], pool_l["k"], pool_l["v"], table,
                                 pos, kv_map=kv_map,
                                 local_window=cfg.local_window,
                                 pos_mask=pos_mask,
                                 impl=self.ctx.attn_impl)
        x = x + self._attn_out(p, out[:, None], ops, self._head_mask(ops))
        h2 = self._norm(ops, x, p["ln2"], p.get("ln2b"))
        x = x + self._mlp(p, h2, ops)
        return x, pool_l

    def decode_paged(self, params, pool, table, ids, pos, ops):
        """One continuous-batching serve step against the paged block pool.

        ids: [B', 1] host token layout; table: [B_loc, nb] LOCAL block ids;
        pos: [B_loc] per-request positions.  Returns (full-vocab logits
        [B_loc, v_pad] for the serve sampler, updated pool)."""
        x = ops.embed(ids, params["embed"]).astype(self.cdt)
        cast = lambda t: jax.tree.map(lambda a: a.astype(self.cdt)
                                      if a.dtype == self.pdt and a.ndim > 1
                                      else a, t)
        # hoisted position-only work, shared by every layer in the scan
        bs = pool["k"].shape[2]
        idx = cm.paged_step_indices(table, pos, bs)
        pos_mask = cm.decode_pos_mask(pos, table.shape[1] * bs,
                                      self.cfg.local_window)
        kv_map = None if self.kv_shard else self._kv_map(ops)

        def body(xx, xs):
            bp, pl = xs
            y, pl2 = self._block_decode_paged(cast(bp), xx, pl, table, pos,
                                              ops, idx=idx,
                                              pos_mask=pos_mask,
                                              kv_map=kv_map)
            return y, pl2

        x, new_pool = lax.scan(body, x, (params["blocks"], pool))
        x = self._norm(ops, x, params["ln_f"], params.get("ln_fb"))
        logits = ops.head_logits(x, params["head"].astype(self.cdt),
                                 vocab_real=self.cfg.vocab_size)
        return logits, new_pool

    def _block_chunk_paged(self, p, x, pool_l, table, ops, *, positions,
                           valid, idx, mask, kv_map):
        """Chunked-prefill analogue of _block_decode_paged: scatter C new
        positions per slot into the pool, then attend the whole chunk
        against the request's pages (update-then-attend, so a COW donor's
        stale tail is overwritten before it could ever be visible — and the
        causal mask hides whatever this chunk didn't reach)."""
        cfg = self.cfg
        h = self._norm(ops, x, p["ln1"], p.get("ln1b"))
        q, k, v = self._qkv(p, h, ops, positions)
        pool_l = cm.paged_update_chunk(pool_l, table, positions, k, v,
                                       valid, idx=idx)
        kg, vg = cm.paged_gather(pool_l["k"], pool_l["v"], table, kv_map)
        out = cm.chunk_attention(q, kg, vg, mask=mask)
        x = x + self._attn_out(p, out, ops, self._head_mask(ops))
        h2 = self._norm(ops, x, p["ln2"], p.get("ln2b"))
        x = x + self._mlp(p, h2, ops)
        return x, pool_l

    def _chunk_trunk(self, params, pool, table, ids, pos, lens, ops):
        """Shared chunk body for prefill_chunk_paged / verify_chunk_paged:
        scatter up to C positions per slot into the pool and run the layer
        scan, returning the final hidden states [B_loc, C, h] plus the
        updated pool.  The chunk attention is the fp32 full-score jnp path
        regardless of attn_impl (per-slot chunk starts are outside the
        flash kernel's static q_start contract)."""
        x = ops.embed(ids, params["embed"]).astype(self.cdt)
        cast = lambda t: jax.tree.map(lambda a: a.astype(self.cdt)
                                      if a.dtype == self.pdt and a.ndim > 1
                                      else a, t)
        # hoisted position-only work, shared by every layer in the scan
        bs = pool["k"].shape[2]
        C = x.shape[1]
        positions = pos[:, None] + jnp.arange(C, dtype=pos.dtype)
        valid = jnp.arange(C, dtype=lens.dtype)[None, :] < lens[:, None]
        idx = cm.paged_chunk_indices(table, positions, bs, valid)
        mask = cm.chunk_pos_mask(positions, table.shape[1] * bs,
                                 self.cfg.local_window) & valid[:, :, None]
        kv_map = None if self.kv_shard else self._kv_map(ops)

        def body(xx, xs):
            bp, pl = xs
            y, pl2 = self._block_chunk_paged(cast(bp), xx, pl, table, ops,
                                             positions=positions,
                                             valid=valid, idx=idx,
                                             mask=mask, kv_map=kv_map)
            return y, pl2

        x, new_pool = lax.scan(body, x, (params["blocks"], pool))
        x = self._norm(ops, x, params["ln_f"], params.get("ln_fb"))
        return x, new_pool

    def prefill_chunk_paged(self, params, pool, table, ids, pos, lens, ops):
        """Prefill C prompt positions per slot straight into the block pool.

        ids: [B', C] host token layout (chunk tokens, 0-padded); table:
        [B_loc, nb] LOCAL block ids; pos: [B_loc] chunk start positions;
        lens: [B_loc] valid positions this chunk (0 = idle slot).  Returns
        (full-vocab logits [B_loc, v_pad] at each slot's LAST valid chunk
        position — only meaningful for slots whose prompt completes this
        chunk — and the updated pool).  Decode steps keep their configured
        kernel; the chunk trunk is the fp32 jnp path (see _chunk_trunk)."""
        x, new_pool = self._chunk_trunk(params, pool, table, ids, pos, lens,
                                        ops)
        C = x.shape[1]
        last = jnp.clip(lens - 1, 0, C - 1)
        xi = jnp.take_along_axis(x, last[:, None, None], axis=1)
        logits = ops.head_logits(xi, params["head"].astype(self.cdt),
                                 vocab_real=self.cfg.vocab_size)
        return logits, new_pool

    def verify_chunk_paged(self, params, pool, table, ids, pos, lens, ops):
        """Speculative-verify forward: same chunk trunk as
        prefill_chunk_paged, but logits at EVERY chunk position.

        Row c of the output is the target distribution for the token at
        absolute position pos+c+1, i.e. the distribution a plain decode
        step would produce after committing ids[:, :c+1].  Accepted
        proposals' K/V are already committed in-place by the trunk's
        update-then-attend scatter; a rejected suffix needs no cleanup —
        the engine simply does not advance ``num_cached`` past the
        rejection point, so the stale pages beyond it are masked by
        position and overwritten by the next verify/prefill write (the
        same argument that makes COW donors' stale tails and
        eviction-replay safe).  Returns ([B_loc, C, v_pad] logits, pool)."""
        x, new_pool = self._chunk_trunk(params, pool, table, ids, pos, lens,
                                        ops)
        B, C, h = x.shape
        # head_logits expects [B', 1, h]; flatten chunk rows into the batch
        # axis (its token gather + local-batch dynamic-slice are layout-
        # compatible with the flattened batch: b_loc scales by C).
        logits = ops.head_logits(x.reshape(B * C, 1, h),
                                 params["head"].astype(self.cdt),
                                 vocab_real=self.cfg.vocab_size)
        return logits.reshape(B, C, -1), new_pool

    def prefill_cache_specs(self, ops):
        """Cache specs in prefill layout: batch over data, seq sharded over
        the sequence-parallel axes (kept local — no gathered-cache output)."""
        from jax.sharding import PartitionSpec as P
        if self.ctx.mode == "megatron1d":
            kv_sp = P(None, "data", "col", None, None)
        else:
            kv_sp = P(None, "data", ("depth", "row"),
                      "col" if self.kv_shard else None, None)
        return {"k": kv_sp, "v": kv_sp}

    def _block_prefill_attnonly(self, p, x, ops, full_kv_pos):
        return self._block_train_attn(p, x, ops, full_kv_pos)

    def _block_prefill(self, p, x, ops, full_kv_pos):
        """Like _block_train but also emits this block's seq-local K/V
        (prefill cache stays sequence-sharded — see prefill_cache_specs)."""
        x, kv = self._block_train_attn(p, x, ops, full_kv_pos)
        h2 = self._norm(ops, x, p["ln2"], p.get("ln2b"))
        x = x + self._mlp(p, h2, ops)
        return x, kv

    def batch_extras(self, shape):
        """Extra (modality) inputs: {name: (ShapeDtypeStruct, host_spec)}."""
        return {}

    def prefill(self, params, batch, ops):
        """Process a full prompt; returns (next_ids, cache-in-prefill-layout).

        With an optional ``batch["lengths"]`` ([B'] true prompt lengths for
        right-padded prompts — the serve engine's bucketed prefill) the head
        runs at each request's own last position and the first slot of the
        return is full-vocab LOGITS [B, v_pad] for the sampler instead of
        greedy ids."""
        x = ops.embed(batch["tokens"], params["embed"]).astype(self.cdt)
        S_loc = x.shape[1]
        n_seq = (self.ctx.depth * self.ctx.rows if ops.plan.seq_sharded else 1)
        if self.ctx.mode == "megatron1d" and ops.plan.seq_sharded:
            n_seq = self.ctx.cols
        full_kv_pos = jnp.arange(S_loc * n_seq)
        cast = lambda t: jax.tree.map(lambda a: a.astype(self.cdt)
                                      if a.dtype == self.pdt and a.ndim > 1
                                      else a, t)

        def body(xx, bp):
            y, kv = self._block_prefill(cast(bp), xx, ops, full_kv_pos)
            return y, kv

        body = maybe_remat(body, self.run)
        x, (kc, vc) = lax.scan(body, x, params["blocks"])
        x = self._norm(ops, x, params["ln_f"], params.get("ln_fb"))
        if "lengths" in batch:
            x_last = last_token_at(ops, x, self.ctx, batch["lengths"])
            logits = ops.head_logits(x_last,
                                     params["head"].astype(self.cdt),
                                     vocab_real=self.cfg.vocab_size,
                                     tokens_sharded=False)
            return logits, {"k": kc, "v": vc}
        x_last = ops_last_token(ops, x, self.ctx)
        ids = ops.head_sample(x_last, params["head"].astype(self.cdt),
                              vocab_real=self.cfg.vocab_size)
        return ids, {"k": kc, "v": vc}

    def _block_decode_attnonly(self, p, x, cache_l, pos, ops, *,
                               pos_mask=None, kv_map=None):
        cfg = self.cfg
        h = self._norm(ops, x, p["ln1"], p.get("ln1b"))
        positions = jnp.full((1,), pos, jnp.int32)
        q, k, v = self._qkv(p, h, ops, positions)
        cache_l = cm.cache_update(cache_l, k, v, pos)
        if kv_map is None and not self.kv_shard:
            kv_map = self._kv_map(ops)
        out = cm.decode_attention(q[:, 0], cache_l["k"], cache_l["v"],
                                  cur_pos=pos, kv_map=kv_map,
                                  local_window=cfg.local_window,
                                  pos_mask=pos_mask,
                                  impl=self.ctx.attn_impl)
        out = out[:, None]                      # [B, 1, H, D]
        x = x + self._attn_out(p, out, ops, self._head_mask(ops))
        return x, cache_l

    def _block_decode(self, p, x, cache_l, pos, ops, **hoisted):
        x, cache_l = self._block_decode_attnonly(p, x, cache_l, pos, ops,
                                                 **hoisted)
        h2 = self._norm(ops, x, p["ln2"], p.get("ln2b"))
        x = x + self._mlp(p, h2, ops)
        return x, cache_l

    def decode(self, params, cache, ids, pos, ops):
        """One serve step: ids [B', 1] host-layout; returns (new_ids, cache)."""
        x = ops.embed(ids, params["embed"]).astype(self.cdt)
        cast = lambda t: jax.tree.map(lambda a: a.astype(self.cdt)
                                      if a.dtype == self.pdt and a.ndim > 1
                                      else a, t)
        pos_mask = cm.decode_pos_mask(pos, cache["k"].shape[2],
                                      self.cfg.local_window)
        kv_map = None if self.kv_shard else self._kv_map(ops)

        def body(xx, xs):
            bp, cl = xs
            y, cl2 = self._block_decode(cast(bp), xx, cl, pos, ops,
                                        pos_mask=pos_mask, kv_map=kv_map)
            return y, cl2

        x, new_cache = lax.scan(body, x, (params["blocks"], cache))
        x = self._norm(ops, x, params["ln_f"], params.get("ln_fb"))
        nids = ops.head_sample(x, params["head"].astype(self.cdt),
                               vocab_real=self.cfg.vocab_size)
        return nids, new_cache


def ops_last_token(ops, x, ctx):
    """[B, S_loc, f] -> [B, 1, f]: the true last token, replicated over the
    sequence-sharding axes."""
    if not ops.plan.seq_sharded:
        return x[:, -1:]
    from ..core.collectives import all_gather_inv
    lt = x[:, -1:]
    if ctx.mode == "megatron1d":
        g = all_gather_inv(lt, ctx.axis_col)
    else:
        g = all_gather_inv(lt, (ctx.axis_depth, ctx.axis_row))
    return g[-1]


def last_token_at(ops, x, ctx, lengths):
    """[B, S_loc, f] + per-request true lengths -> [B, 1, f] hidden states at
    position lengths-1, invariant over the sequence-sharding axes.

    The bucketed serve prefill right-pads prompts, so "last token" is a
    per-request position, not column -1.  Each seq shard contributes its own
    slice (zeros elsewhere) and one small psum replicates the result."""
    idx = lengths - 1
    if not ops.plan.seq_sharded:
        return jnp.take_along_axis(x, idx[:, None, None], axis=1)
    S_loc = x.shape[1]
    local = idx - ops.seq_shard_index() * S_loc
    valid = (local >= 0) & (local < S_loc)
    safe = jnp.clip(local, 0, S_loc - 1)
    xl = jnp.take_along_axis(x, safe[:, None, None], axis=1)
    xl = jnp.where(valid[:, None, None], xl, jnp.zeros_like(xl))
    return lax.psum(xl, ctx.seq_shard_axes)
