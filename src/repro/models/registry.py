"""Model registry: family -> class, arch id -> config module."""
from __future__ import annotations

import importlib

from ..configs.base import ArchConfig, ModelConfig, RunConfig
from ..core.api import ParallelContext

ARCH_MODULES = {
    "nemotron-4-340b": "nemotron_4_340b",
    "smollm-360m": "smollm_360m",
    "llama3-405b": "llama3_405b",
    "yi-6b": "yi_6b",
    "llama4-scout-17b-a16e": "llama4_scout",
    "deepseek-v2-236b": "deepseek_v2",
    "llama-3.2-vision-11b": "llama32_vision",
    "recurrentgemma-9b": "recurrentgemma_9b",
    "mamba2-1.3b": "mamba2_13b",
    "whisper-base": "whisper_base",
}


def get_arch(name: str) -> ArchConfig:
    mod = importlib.import_module(f"repro.configs.{ARCH_MODULES[name]}")
    return mod.CONFIG


def get_reduced(name: str) -> ArchConfig:
    mod = importlib.import_module(f"repro.configs.{ARCH_MODULES[name]}")
    return mod.reduced()


def build_model(cfg: ModelConfig, ctx: ParallelContext, run: RunConfig):
    # RunConfig.matmul_schedule is the config-surface default for the SUMMA
    # schedule; an explicit non-default ctx.matmul_schedule wins (the per-op
    # dispatch reads ctx, DESIGN.md §2b).
    if run.matmul_schedule != "fused" and ctx.matmul_schedule == "fused" \
            and ctx.mode != "megatron1d":
        ctx = ctx.replace(matmul_schedule=run.matmul_schedule)
    if cfg.family in ("dense",):
        from .transformer import DenseLM
        return DenseLM(cfg, ctx, run)
    if cfg.family == "vlm":
        from .vision import VisionLM
        return VisionLM(cfg, ctx, run)
    if cfg.family == "moe":
        from .moe import MoELM
        return MoELM(cfg, ctx, run)
    if cfg.family == "hybrid":
        from .recurrent import RecurrentLM
        return RecurrentLM(cfg, ctx, run)
    if cfg.family == "ssm":
        from .ssm import MambaLM
        return MambaLM(cfg, ctx, run)
    if cfg.family == "audio":
        from .whisper import WhisperModel
        return WhisperModel(cfg, ctx, run)
    raise ValueError(f"unknown family {cfg.family!r}")
