"""Pallas kernel lint (shardcheck rule e).

Traces the repo's Pallas entry points (flash attention fwd+bwd, paged
decode attention) to jaxprs, finds every ``pallas_call`` eqn, and checks
its ``GridMapping`` statically — no kernel is ever run:

* **index-map bounds** — each BlockSpec index map, evaluated at the corners
  of the grid, must return block indices inside the (padded) array: Pallas
  silently clamps out-of-range blocks on TPU, which turns an off-by-one
  index map into wrong data, not a crash.  Index maps that take
  scalar-prefetch refs (paged attention's block-table walk) cannot be
  evaluated from grid indices alone and are skipped — recorded, not failed.
* **tile divisibility** — block dims must divide the (padded) array dims;
  a partial trailing tile means the kernel reads/writes garbage lanes
  unless it masks, and every kernel in this repo pads instead.
* **VMEM budget** — sum of live block bytes (inputs + outputs, x2 for the
  pipeline's double buffering) per kernel against the ~16 MiB/core VMEM of
  the TPU generations the roofline models; a kernel whose resident tiles
  exceed it would stall on HBM and the flash_tiles autotune table should
  shrink its bq/bk instead.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from jax._src import core as jcore

from .rules import Finding

VMEM_BUDGET = 16 * 2 ** 20      # bytes/core; see /opt roofline + DESIGN §13
DOUBLE_BUFFER = 2               # pallas pipelines blocks in/out


def find_pallas_eqns(closed_jaxpr) -> list:
    """Every pallas_call eqn reachable from a closed jaxpr."""
    out = []

    def walk(jaxpr):
        for eqn in jaxpr.eqns:
            if eqn.primitive.name == "pallas_call":
                out.append(eqn)
            for v in eqn.params.values():
                for vi in (v if isinstance(v, (tuple, list)) else (v,)):
                    if isinstance(vi, jcore.ClosedJaxpr):
                        walk(vi.jaxpr)
                    elif isinstance(vi, jcore.Jaxpr):
                        walk(vi)

    walk(closed_jaxpr.jaxpr)
    return out


def _grid_corners(grid):
    """All corner index tuples of an integer grid (2^ndim points)."""
    pts = [()]
    for g in grid:
        pts = [p + (v,) for p in pts for v in ({0, int(g) - 1})]
    return pts


def lint_grid_mapping(gm, kernel: str = "") -> tuple:
    """(findings, stats) for one pallas_call's GridMapping."""
    findings = []
    grid = tuple(int(g) for g in gm.grid)
    vmem = 0
    n_skipped_maps = 0
    for bi, bm in enumerate(gm.block_mappings):
        arr = bm.array_shape_dtype
        block = tuple(int(b) for b in bm.block_shape)
        vmem += math.prod(block) * arr.dtype.itemsize
        if len(block) != len(arr.shape):
            findings.append(Finding(
                "pallas", kernel,
                f"block #{bi}: block rank {len(block)} != array rank "
                f"{len(arr.shape)} ({block} vs {arr.shape})"))
            continue
        for d, (bs, ad) in enumerate(zip(block, arr.shape)):
            if bs <= 0 or ad % bs:
                findings.append(Finding(
                    "pallas", kernel,
                    f"block #{bi} dim {d}: tile {bs} does not divide "
                    f"array dim {ad} — partial tile would read/write "
                    f"unmasked garbage lanes"))
        imj = bm.index_map_jaxpr
        if len(imj.jaxpr.invars) != len(grid):
            n_skipped_maps += 1     # scalar-prefetch-driven map
            continue
        for pt in _grid_corners(grid):
            try:
                idx = jax.core.eval_jaxpr(
                    imj.jaxpr, imj.consts,
                    *[jnp.int32(v) for v in pt])
            except Exception as e:  # pragma: no cover - diagnostic path
                findings.append(Finding(
                    "pallas", kernel,
                    f"block #{bi}: index map failed to evaluate at grid "
                    f"point {pt}: {e}"))
                break
            for d, (b_idx, bs, ad) in enumerate(zip(idx, block, arr.shape)):
                b_idx = int(b_idx)
                n_blocks = -(-ad // bs)
                if not 0 <= b_idx < n_blocks:
                    findings.append(Finding(
                        "pallas", kernel,
                        f"block #{bi} dim {d}: index map returns block "
                        f"{b_idx} at grid point {pt}, valid range "
                        f"[0, {n_blocks}) for array dim {ad} / tile {bs}"))
    vmem *= DOUBLE_BUFFER
    if vmem > VMEM_BUDGET:
        findings.append(Finding(
            "pallas", kernel,
            f"resident block bytes {vmem} (x{DOUBLE_BUFFER} double-buffer) "
            f"exceed the {VMEM_BUDGET} VMEM budget — shrink bq/bk in "
            f"kernels/autotune.py"))
    stats = {"grid": list(grid), "n_blocks": len(gm.block_mappings),
             "vmem_bytes": int(vmem),
             "scalar_prefetch_maps": n_skipped_maps}
    return findings, stats


def lint_closed_jaxpr(closed_jaxpr, kernel: str = "") -> tuple:
    """(findings, {pallas_call_i: stats}) over one traced entry."""
    findings, stats = [], {}
    for i, eqn in enumerate(find_pallas_eqns(closed_jaxpr)):
        f, s = lint_grid_mapping(eqn.params["grid_mapping"],
                                 f"{kernel}/pallas_call_{i}")
        findings += f
        stats[f"{kernel}/pallas_call_{i}"] = s
    return findings, stats


def lint_default_kernels() -> tuple:
    """Trace + lint the repo's kernels at canonical shapes.

    Shapes mirror tests/test_kernels.py: GQA flash (fwd and the two-pass
    bwd via grad) and the paged decode kernel.  Returns (findings, stats).
    """
    from ..kernels.flash_attention import flash_attention
    from ..kernels.paged_attention import paged_attention

    sds = jax.ShapeDtypeStruct
    findings, stats = [], {}

    q = sds((2, 4, 128, 64), jnp.float32)
    k = sds((2, 2, 128, 64), jnp.float32)
    v = sds((2, 2, 128, 64), jnp.float32)

    def floss(q, k, v):
        return flash_attention(q, k, v, causal=True).sum()

    tr = jax.jit(jax.grad(floss, (0, 1, 2))).trace(q, k, v)
    f, s = lint_closed_jaxpr(tr.jaxpr, "flash_attention")
    findings += f
    stats.update(s)

    qd = sds((2, 4, 64), jnp.float32)
    pool = sds((8, 16, 2, 64), jnp.float32)
    tab = sds((2, 4), jnp.int32)
    pos = sds((2,), jnp.int32)
    kvm = sds((4,), jnp.int32)
    tr = jax.jit(lambda *a: paged_attention(*a)).trace(
        qd, pool, pool, tab, pos, kvm)
    f, s = lint_closed_jaxpr(tr.jaxpr, "paged_attention")
    findings += f
    stats.update(s)
    return findings, stats
