"""Repo-custom AST lint for the footguns this repo has shipped fixes for.

Three rules, each a bug class with a PR number attached:

* ``REPRO001 hash-for-seeding`` — the ``hash()`` builtin is salted per
  process (PYTHONHASHSEED), so seeds/bucket ids derived from it are not
  reproducible across runs.  PR 3 and PR 6 both replaced ``hash()`` with
  ``zlib.crc32``; nothing in this codebase legitimately wants ``hash()``.
* ``REPRO002 mutable-default-arg`` — a mutable default is evaluated once
  and shared across calls (PR 6: the scheduler's ``SamplingParams()``
  default aliased one object across requests).  Any list/dict/set display
  or constructor call in a default is flagged unless the callee is a
  known-immutable constructor (``P``/``PartitionSpec``, ``frozenset``,
  ``tuple``, numeric casts).
* ``REPRO003 bare-except`` — ``except:`` swallows KeyboardInterrupt and
  SystemExit; name the exception (at minimum ``except Exception``).

Run as ``python -m repro.analysis.lint [paths...]`` (default ``src``);
prints ``path:line: CODE message`` per finding and exits 1 if any.
"""
from __future__ import annotations

import ast
import sys
from pathlib import Path

# constructors whose result is immutable: safe as a default argument
IMMUTABLE_DEFAULT_CALLS = {
    "P", "PartitionSpec", "frozenset", "tuple", "int", "float", "bool",
    "str", "bytes", "complex",
}


def _callee_name(call: ast.Call) -> str:
    f = call.func
    while isinstance(f, ast.Attribute):
        f = f.value if isinstance(f.value, ast.Attribute) else f
        if isinstance(f, ast.Attribute):
            f = f.value
        break
    if isinstance(call.func, ast.Attribute):
        return call.func.attr
    if isinstance(call.func, ast.Name):
        return call.func.id
    return ""


def _mutable_default(node: ast.AST) -> bool:
    if isinstance(node, (ast.List, ast.Dict, ast.Set, ast.ListComp,
                         ast.DictComp, ast.SetComp)):
        return True
    if isinstance(node, ast.Call):
        return _callee_name(node) not in IMMUTABLE_DEFAULT_CALLS
    return False


def lint_source(src: str, path: str = "<str>") -> list:
    """Lint one file's source; returns ``(path, line, code, message)``."""
    try:
        tree = ast.parse(src, filename=path)
    except SyntaxError as e:
        return [(path, e.lineno or 0, "REPRO000",
                 f"syntax error: {e.msg}")]
    out = []
    for node in ast.walk(tree):
        if isinstance(node, ast.Call) and \
                isinstance(node.func, ast.Name) and node.func.id == "hash":
            out.append((path, node.lineno, "REPRO001",
                        "hash() is salted per process (PYTHONHASHSEED); "
                        "use zlib.crc32 for stable seeds/bucket ids"))
        elif isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                               ast.Lambda)):
            args = node.args
            for d in list(args.defaults) + [d for d in args.kw_defaults
                                            if d is not None]:
                if _mutable_default(d):
                    name = getattr(node, "name", "<lambda>")
                    out.append((path, d.lineno, "REPRO002",
                                f"mutable default argument in {name}() is "
                                f"evaluated once and shared across calls; "
                                f"default to None and construct inside"))
        elif isinstance(node, ast.ExceptHandler) and node.type is None:
            out.append((path, node.lineno, "REPRO003",
                        "bare 'except:' swallows KeyboardInterrupt/"
                        "SystemExit; catch a named exception"))
    return out


def lint_paths(paths) -> list:
    findings = []
    for root in paths:
        root = Path(root)
        files = sorted(root.rglob("*.py")) if root.is_dir() else [root]
        for f in files:
            findings += lint_source(f.read_text(), str(f))
    return findings


def main(argv=None) -> int:
    argv = list(sys.argv[1:] if argv is None else argv)
    paths = argv or ["src"]
    findings = lint_paths(paths)
    for path, line, code, msg in findings:
        print(f"{path}:{line}: {code} {msg}")
    if findings:
        print(f"{len(findings)} finding(s)", file=sys.stderr)
        return 1
    print(f"lint clean: {', '.join(paths)}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
