"""SHARDCHECK.json baseline: the committed collective contract.

Tracing is deterministic, so the extracted IR summary of every swept entry
point is committed and diffed EXACTLY (the same discipline as the
BENCH_*.json regression gates in benchmarks/run.py): a new collective kind,
a changed count, or changed wire bytes is a contract change that must be
reviewed and re-baselined with ``python -m repro.analysis.shardcheck
--update``, never silently absorbed.

Schema (one entry per swept entry point)::

    {"entries": {
        "<entry>": {
            "axis_sizes": {"data": 2, ...},
            "n_shard_maps": 1,
            "collectives": {"psum@dataxdepth": {"count": 15,
                                                "wire_bytes": 111360}},
            "total_wire_bytes": 872448
        }, ...}}

Entries with no explicit collectives (plain-jit reshard helpers where XLA
inserts the transfers below the jaxpr level) legitimately summarize to an
empty ``collectives`` dict — committing that emptiness is itself the
contract that nothing EXPLICIT was added.
"""
from __future__ import annotations

import json

from .collective_ir import IRProgram


def summarize(prog: IRProgram) -> dict:
    """Canonical, JSON-stable summary of one entry's IR."""
    coll = {k: {"count": int(v["count"]),
                "wire_bytes": int(round(v["wire_bytes"]))}
            for k, v in sorted(prog.by_key().items())}
    return {
        "axis_sizes": {str(k): int(v)
                       for k, v in sorted(prog.axis_sizes.items())},
        "n_shard_maps": len(prog.shard_map_eqns),
        "collectives": coll,
        "total_wire_bytes": int(round(prog.total_wire_bytes())),
    }


def load(path) -> dict:
    with open(path) as f:
        return json.load(f)


def write(path, entries: dict) -> None:
    with open(path, "w") as f:
        json.dump({"entries": entries}, f, indent=1, sort_keys=True)
        f.write("\n")


def diff(baseline: dict, entries: dict) -> list:
    """Exact diff of {entry: summary} against a loaded baseline.

    Returns human-readable drift lines; empty means conformant.  Both
    missing and novel entries/collectives fail — an entry disappearing from
    the sweep is as much drift as a new collective appearing in one.
    """
    old = baseline.get("entries", {})
    out = []
    for name in sorted(set(old) | set(entries)):
        if name not in entries:
            out.append(f"{name}: in baseline but not swept")
            continue
        if name not in old:
            out.append(f"{name}: swept but not in baseline "
                       f"(run --update and review)")
            continue
        o, n = old[name], entries[name]
        for field in ("axis_sizes", "n_shard_maps", "total_wire_bytes"):
            if o.get(field) != n.get(field):
                out.append(f"{name}.{field}: baseline {o.get(field)!r} "
                           f"!= traced {n.get(field)!r}")
        oc, nc = o.get("collectives", {}), n.get("collectives", {})
        for key in sorted(set(oc) | set(nc)):
            if key not in nc:
                out.append(f"{name}: collective {key} vanished "
                           f"(baseline {oc[key]})")
            elif key not in oc:
                out.append(f"{name}: NEW collective {key} {nc[key]} "
                           f"not in baseline")
            elif oc[key] != nc[key]:
                out.append(f"{name}: {key} drifted "
                           f"{oc[key]} -> {nc[key]}")
    return out
