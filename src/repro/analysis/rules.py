"""Rule checks over the extracted collective IR (DESIGN.md §13).

Each rule encodes one bug class this repo has actually shipped a fix for:

* ``check_mesh``       — collectives naming axes that do not exist on the
  declared mesh (caught at trace time for hand-written code, but synthetic /
  re-played IR and future lowering passes are not so protected).
* ``check_layouts``    — reductions or ZeRO partitions over a leaf's OWN
  sharding axes (PR 4: depth-sharded head/expert leaves were flat-sliced
  over ``depth`` again, orphaning chunks), and ZeRO leaves whose deferred
  psum still covers a zaxis (double reduction: the zreduce_scatter would
  re-reduce an already-reduced grad).
* ``check_grad_sync``  — the traced program must contain at least the fused
  grad reductions the step builder promised (StepBundle.shardcheck_meta):
  one psum per leaf per distinct replication axis-set, one reduce_scatter
  per ZeRO leaf.  PR 3's bug — the pipeline ``red()`` dropping ``pipe`` for
  stage-replicated leaves — shows up as the ``(..., 'pipe')`` set counting
  short.  Exact double-psum drift is caught by the SHARDCHECK.json baseline
  diff (counts here are >=: loss/metric psums legitimately share axis sets).
* ``check_replication`` — the taint sanitizer: ``axis_index``-derived or
  input-sharded values flowing to a shard_map output declared replicated
  over an axis they still vary on (collective_ir.replication_taints).

Rules take the IR / meta as plain data so tests can feed deliberately
broken inputs that could never trace (jax rejects unknown axes itself).
"""
from __future__ import annotations

from dataclasses import dataclass

from .collective_ir import IRProgram, replication_taints


@dataclass(frozen=True)
class Finding:
    rule: str        # mesh | layout | gradsync | replication | commmodel
    entry: str       # swept entry-point name
    message: str

    def __str__(self):
        return f"[{self.rule}] {self.entry}: {self.message}"


def check_mesh(prog: IRProgram, mesh_axes, entry: str = "") -> list:
    """Every collective axis must exist on the declared mesh."""
    mesh_axes = set(mesh_axes)
    out = []
    for c in prog.collectives:
        unknown = [a for a in c.axes if a not in mesh_axes]
        if unknown:
            out.append(Finding(
                "mesh", entry,
                f"{c.key()} at {'/'.join(c.path) or '<top>'} names "
                f"axes {unknown} not on mesh {sorted(mesh_axes)}"))
    return out


def check_layouts(meta: dict, entry: str = "") -> list:
    """Per-leaf layout invariants from StepBundle.shardcheck_meta."""
    out = []
    for leaf in meta.get("leaves", ()):
        own = set(leaf["spec_axes"])
        red = set(leaf["reduce_axes"])
        zax = set(leaf["zaxes"])
        bad = red & own
        if bad:
            out.append(Finding(
                "layout", entry,
                f"{leaf['name']}: deferred grad psum over {sorted(bad)} "
                f"but the leaf is SHARDED over those axes (reducing would "
                f"sum distinct shards — PR 4 bug class)"))
        bad = zax & own
        if bad:
            out.append(Finding(
                "layout", entry,
                f"{leaf['name']}: ZeRO zaxes {sorted(bad)} overlap the "
                f"leaf's own sharding axes (flat-slicing a sharded leaf "
                f"over its shard axis orphans chunks — PR 4 bug class)"))
        bad = zax & red
        if bad:
            out.append(Finding(
                "layout", entry,
                f"{leaf['name']}: axes {sorted(bad)} appear in BOTH the "
                f"deferred grad psum and the ZeRO zaxes (double "
                f"reduction: zreduce_scatter re-reduces a reduced grad)"))
    return out


def check_grad_sync(prog: IRProgram, meta: dict, entry: str = "") -> list:
    """Extracted reductions must cover the builder's promised reductions."""
    out = []
    got_psum: dict = {}
    got_rs: dict = {}
    for c in prog.collectives:
        if c.kind == "psum" and c.axes:
            got_psum[c.axes] = got_psum.get(c.axes, 0) + c.mult
        elif c.kind == "psum_scatter" and c.axes:
            got_rs[c.axes] = got_rs.get(c.axes, 0) + c.mult
    for axes, want in meta.get("grad_psum_axes", {}).items():
        axes = tuple(sorted(axes))
        have = got_psum.get(axes, 0)
        if have < want:
            hint = (" — missing 'pipe' on a stage-replicated leaf?"
                    if "pipe" in axes else "")
            out.append(Finding(
                "gradsync", entry,
                f"expected >= {want} grad psum(s) over {axes}, traced "
                f"program has {have}{hint}"))
    for axes, want in meta.get("grad_rs_axes", {}).items():
        axes = tuple(sorted(axes))
        have = got_rs.get(axes, 0)
        if have < want:
            out.append(Finding(
                "gradsync", entry,
                f"expected >= {want} ZeRO reduce_scatter(s) over {axes}, "
                f"traced program has {have}"))
    return out


def check_replication(closed_jaxpr, entry: str = "", *,
                      seed_inputs: bool = True) -> list:
    """Divergence sanitizer over every shard_map in the trace."""
    out = []
    for v in replication_taints(closed_jaxpr, seed_inputs=seed_inputs):
        out.append(Finding(
            "replication", entry,
            f"shard_map output #{v['output']} may vary over "
            f"{v['axes']} but its out_spec only shards {v['declared']} "
            f"(axis_index / sharded-input flow without an intervening "
            f"psum/all_gather)"))
    return out


def run_all(prog: IRProgram, meta: dict, closed_jaxpr=None,
            entry: str = "") -> list:
    """All structural rules for one traced entry point."""
    findings = check_mesh(prog, meta.get("mesh_axes", prog.axis_sizes),
                          entry)
    findings += check_layouts(meta, entry)
    findings += check_grad_sync(prog, meta, entry)
    if closed_jaxpr is not None:
        findings += check_replication(closed_jaxpr, entry)
    return findings
