"""shardcheck: trace every jitted entry point, extract its collective IR,
and enforce the comm model as invariants (DESIGN.md §13).

    python -m repro.analysis.shardcheck --check            # CI gate
    python -m repro.analysis.shardcheck --update           # re-baseline
    python -m repro.analysis.shardcheck --config serve     # subset sweep
    python -m repro.analysis.shardcheck --entry pipe2      # name filter

Per entry: AOT-trace the jitted step on 8 fake CPU devices (no compile, no
execution), walk the jaxpr into the normalized collective IR
(collective_ir.extract_ir), run the rule catalog (rules.run_all: mesh /
layout / grad-sync / replication), and summarize into the committed
SHARDCHECK.json contract (baseline.diff: exact — new or drifted
collectives fail).  Separately, the standalone tesseract_matmul is traced
per schedule and its wire bytes must match core/summa.matmul_comm_bytes
EXACTLY (the implementation-derived model), and the Pallas kernels get the
GridMapping lint (pallas_lint).  Exit is non-zero on any rule finding, any
conformance mismatch, or (--check) any baseline drift.
"""
from __future__ import annotations

import argparse
import os
import sys

if "XLA_FLAGS" not in os.environ:   # before jax initializes the backend
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import jax
import jax.numpy as jnp

DEFAULT_BASELINE = "SHARDCHECK.json"
SEQ, BATCH = 32, 8


def _model_for(ctx, *, attn_impl="jnp", zero=False, pipe_mb=0):
    from ..configs.base import RunConfig
    from ..models.registry import build_model, get_reduced

    run = RunConfig(param_dtype="float32", compute_dtype="float32",
                    loss_chunk=32, q_chunk=16, kv_chunk=16,
                    attn_impl=attn_impl, zero1=zero,
                    pipeline_microbatches=pipe_mb)
    arch = get_reduced("yi-6b")
    return build_model(arch.model, ctx, run)


def _train_entry(*, data=1, depth=1, rows=1, cols=1, schedule="fused",
                 inop=False, attn_impl="jnp", zero=False, pipe=1, seq=1,
                 attn_schedule="local"):
    """Trace one train-step variant -> (closed_jaxpr, meta, bundle, info)."""
    from ..configs.base import ShapeSpec
    from ..core.api import ParallelContext
    from ..core.mesh import logical_mesh, pipeline_mesh
    from ..runtime.steps import build_train_step

    ctx = ParallelContext(mode="tesseract", data=data, depth=depth,
                          rows=rows, cols=cols, reduce_dgrad_in_op=inop,
                          matmul_schedule=schedule, attn_impl=attn_impl,
                          seq=seq, attn_schedule=attn_schedule)
    n = pipe * data * seq * depth * rows * cols
    mesh = (pipeline_mesh(ctx, pipe, jax.devices()[:n]) if pipe > 1
            else logical_mesh(ctx, jax.devices()[:n]))
    model = _model_for(ctx, attn_impl=attn_impl, zero=zero)
    shape = ShapeSpec("t", seq_len=SEQ, global_batch=BATCH, kind="train")
    bundle = build_train_step(model, mesh, shape)
    tr = bundle.fn.trace(*bundle.abstract_inputs)
    return tr.jaxpr, bundle.shardcheck_meta, bundle, dict(ctx=ctx,
                                                          model=model)


def _serve_entries():
    """All serve entry points on one q=2, dp=2 layout."""
    from ..configs.base import ShapeSpec
    from ..core.api import ParallelContext
    from ..core.mesh import logical_mesh
    from ..runtime import steps as rs

    ctx = ParallelContext(mode="tesseract", data=2, depth=1, rows=2, cols=2)
    mesh = logical_mesh(ctx, jax.devices()[:8])
    model = _model_for(ctx)
    meta = {"mesh_axes": tuple(str(a) for a in mesh.axis_names),
            "axis_sizes": dict(zip([str(a) for a in mesh.axis_names],
                                   mesh.devices.shape))}
    B, S_p, bs, num_blocks, nb = 8, 16, 4, 32, 8
    out = {}

    pre = rs.build_prefill_step(model, mesh,
                                ShapeSpec("p", S_p, B, "prefill"))
    out["serve_prefill_q2_dp2"] = (
        pre.fn.trace(*pre.abstract_inputs).jaxpr, dict(meta))

    pdec = rs.build_paged_decode_step(model, mesh, B, num_blocks, bs, nb)
    out["serve_paged_decode_q2_dp2"] = (
        pdec.fn.trace(*pdec.abstract_inputs).jaxpr, dict(meta))

    chk = rs.build_chunk_prefill_step(model, mesh, B, S_p, num_blocks,
                                      bs, nb)
    out["serve_chunk_prefill_q2_dp2"] = (
        chk.fn.trace(*chk.abstract_inputs).jaxpr, dict(meta))

    ver = rs.build_spec_verify_step(model, mesh, B, 4, num_blocks, bs, nb)
    out["serve_spec_verify_q2_dp2"] = (
        ver.fn.trace(*ver.abstract_inputs).jaxpr, dict(meta))

    copy_fn = rs.build_page_copy(model, mesh, num_blocks, bs, pdec.plan)
    pool_sds, _ = model.paged_cache_abstract(num_blocks, bs, pdec.plan)
    ids = jax.ShapeDtypeStruct((4,), jnp.int32)
    out["serve_page_copy_q2_dp2"] = (
        copy_fn.trace(pool_sds, ids, ids).jaxpr, dict(meta))

    resh = rs.build_paged_reshard(model, mesh, B, S_p, num_blocks, bs,
                                  pdec.plan)
    pcache_sds = jax.eval_shape(pre.fn, *pre.abstract_inputs)[1]
    tables = jax.ShapeDtypeStruct((B, S_p // bs), jnp.int32)
    out["serve_paged_reshard_q2_dp2"] = (
        resh.trace(pool_sds, pcache_sds, tables).jaxpr, dict(meta))
    return out


# name -> (group, builder kwargs); q in {1, 2} x {flat, pipe, zero1} plus
# schedule / attn_impl / in-op variants on the richest layouts
TRAIN_SWEEP = {
    "train_flat_q1_dp2": dict(data=2),
    "train_flat_q2_dp2": dict(data=2, rows=2, cols=2),
    "train_flat_q2_dp2_ring": dict(data=2, rows=2, cols=2,
                                   schedule="ring"),
    "train_flat_q2_d2_inop": dict(depth=2, rows=2, cols=2, inop=True),
    "train_flat_q2_dp2_pallas": dict(data=2, rows=2, cols=2,
                                     attn_impl="pallas"),
    "train_zero1_q1_dp4": dict(data=4, zero=True),
    "train_zero1_q2_dp2": dict(data=2, rows=2, cols=2, zero=True),
    "train_pipe2_q1_dp2": dict(data=2, pipe=2),
    "train_pipe2_q2": dict(rows=2, cols=2, pipe=2),
    # ring/striped flash attention over the seq axis (DESIGN.md §15): the
    # seq-axis ppermute count and wire bytes are gated EXACTLY against
    # core/ring_attention.ring_ppermute_{counts,bytes}
    "train_ring_attn_q1_seq2": dict(seq=2, attn_schedule="striped"),
    "train_ring_attn_q2_seq2": dict(rows=2, cols=2, seq=2,
                                    attn_schedule="striped"),
}


def _ring_attn_gate(prog, ctx, model, name):
    """Exact seq-axis ppermute conformance for a ring-attention train entry.

    Prediction mirrors models/transformer._ring_attn: each layer streams
    K/V blocks of the locally resident kv heads (GQA-sharded over col when
    num_kv_heads divides q, else expanded to the local q heads) and fp32
    dK/dV accumulators of the same shape, with counts from
    ring_ppermute_counts (remat="full" replays the fwd ring in the bwd).
    Returns (findings, got_count, got_bytes)."""
    from ..core.ring_attention import (ring_ppermute_bytes,
                                      ring_ppermute_counts)
    from .rules import Finding

    cfg = model.cfg
    n = ctx.seq
    L = SEQ // n
    kv_shard = cfg.num_kv_heads % ctx.q == 0
    h_stream = (cfg.num_kv_heads if kv_shard else cfg.num_heads) // ctx.cols
    b_loc = BATCH // (ctx.data * ctx.depth * ctx.rows)
    dh = cfg.d_model // cfg.num_heads
    # _model_for pins compute_dtype=float32, so K/V blocks and the fp32
    # accumulators are the same 4-byte block
    blk = b_loc * h_stream * L * dh * 4
    counts = ring_ppermute_counts(n, train=True, remat_replay=True)
    per_layer = ring_ppermute_bytes(n, kv_block_bytes=blk,
                                    acc_block_bytes=blk,
                                    train=True, remat_replay=True)
    exp_n = cfg.num_layers * counts["total"]
    exp_b = cfg.num_layers * per_layer["total"]
    seq_pp = [c for c in prog.collectives
              if c.kind == "ppermute" and c.axes == (ctx.axis_seq,)]
    got_n = sum(c.mult for c in seq_pp)
    got_b = int(round(sum(c.total_wire_bytes for c in seq_pp)))
    findings = []
    if got_n != exp_n or got_b != exp_b:
        findings.append(Finding(
            "commmodel", name,
            f"seq-axis ppermutes {got_n} / {got_b}B != ring model "
            f"{exp_n} / {exp_b}B ({cfg.num_layers} layers x "
            f"{counts['total']} permutes x {blk}B blocks)"))
    return findings, got_n, got_b


def matmul_conformance() -> tuple:
    """Trace tesseract_matmul fwd+bwd per schedule; wire bytes must equal
    core/summa.matmul_comm_bytes exactly.  Returns (findings, results)."""
    from jax.sharding import PartitionSpec as P

    from ..core import summa
    from ..core.api import ParallelContext
    from ..core.collectives import shard_map
    from ..core.mesh import logical_mesh
    from ..roofline.analysis import wire_time_s
    from .collective_ir import extract_ir
    from .rules import Finding

    findings, results = [], {}
    B, E, F, G = 2, 64, 64, 64
    for sched in ("fused", "ring"):
        for inop in (False, True):
            name = f"matmul_{sched}{'_inop' if inop else ''}_q2_d2"
            ctx = ParallelContext(mode="tesseract", data=1, depth=2,
                                  rows=2, cols=2, reduce_dgrad_in_op=inop,
                                  matmul_schedule=sched)
            mesh = logical_mesh(ctx, jax.devices()[:8])
            a_spec = P(None, ("data", "depth", "row"), "col")
            w_spec = P("row", "col")

            def local(a, w, s):
                def loss(a_, w_):
                    return jnp.sum(summa.tesseract_matmul(ctx, a_, w_) * s)
                _, gr = jax.value_and_grad(loss, (0, 1))(a, w)
                return gr

            f = shard_map(local, mesh=mesh,
                          in_specs=(a_spec, w_spec, a_spec),
                          out_specs=(a_spec, w_spec))
            sds = jax.ShapeDtypeStruct
            tr = jax.jit(f).trace(sds((B, E, F), jnp.float32),
                                  sds((F, G), jnp.float32),
                                  sds((B, E, G), jnp.float32))
            traced = extract_ir(tr.jaxpr).total_wire_bytes()
            e_loc = E // (ctx.data * ctx.depth * ctx.rows)
            pred = summa.matmul_comm_bytes(
                ctx, e_loc, F // ctx.q, G // ctx.q, batch=B, train=True,
                itemsize=4, schedule=sched)["total"]
            results[name] = {"traced_bytes": int(round(traced)),
                             "predicted_bytes": int(round(pred)),
                             "wire_time_us": round(
                                 wire_time_s(traced) * 1e6, 3)}
            if int(round(traced)) != int(round(pred)):
                findings.append(Finding(
                    "commmodel", name,
                    f"traced wire bytes {traced:.0f} != "
                    f"summa.matmul_comm_bytes prediction {pred:.0f}"))
    return findings, results


def run_sweep(config: str = "all", entry_filter: str = ""):
    """Returns (findings, entries{name: summary}, kernel_stats)."""
    from ..roofline.analysis import wire_time_s
    from ..runtime.pipeline import expected_ring_transfers, schedule_1f1b
    from . import baseline as bl
    from . import pallas_lint, rules
    from .collective_ir import extract_ir

    findings, entries = [], {}

    def want(name):
        return (not entry_filter) or entry_filter in name

    if config in ("all", "train"):
        for name, kw in TRAIN_SWEEP.items():
            if not want(name):
                continue
            jaxpr, meta, bundle, info = _train_entry(**kw)
            prog = extract_ir(jaxpr)
            findings += rules.run_all(prog, meta, jaxpr, entry=name)
            summ = bl.summarize(prog)
            summ["wire_time_us"] = round(
                wire_time_s(prog.total_wire_bytes()) * 1e6, 3)
            if info["ctx"].seq > 1:
                f, got_n, got_b = _ring_attn_gate(prog, info["ctx"],
                                                  info["model"], name)
                findings += f
                summ["seq_ppermutes"] = got_n
                summ["seq_ppermute_bytes"] = got_b
            if bundle.pipe_info is not None:
                info = bundle.pipe_info
                exp = expected_ring_transfers(
                    schedule_1f1b(info["n_micro"], info["n_stages"]))
                got = sum(c.mult for c in prog.collectives
                          if c.kind == "ppermute" and c.axes == ("pipe",))
                if got != exp["ppermutes"]:
                    findings.append(rules.Finding(
                        "commmodel", name,
                        f"pipe-axis ppermutes {got} != 1F1B schedule's "
                        f"{exp['ppermutes']} (2 per tick x "
                        f"{exp['n_ticks']} ticks)"))
                summ["pipe_ppermutes"] = got
            entries[name] = summ

    if config in ("all", "serve"):
        for name, (jaxpr, meta) in _serve_entries().items():
            if not want(name):
                continue
            prog = extract_ir(jaxpr)
            findings += rules.check_mesh(prog, meta["mesh_axes"], name)
            findings += rules.check_replication(jaxpr, name)
            summ = bl.summarize(prog)
            summ["wire_time_us"] = round(
                wire_time_s(prog.total_wire_bytes()) * 1e6, 3)
            entries[name] = summ

    if config in ("all", "matmul"):
        f, results = matmul_conformance()
        findings += f
        for name, r in results.items():
            if want(name):
                entries[name] = r

    kernel_stats = {}
    if config in ("all", "kernels"):
        f, kernel_stats = pallas_lint.lint_default_kernels()
        findings += f
    return findings, entries, kernel_stats


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="repro.analysis.shardcheck", description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("--check", action="store_true",
                    help="diff the sweep against the committed baseline")
    ap.add_argument("--update", action="store_true",
                    help="rewrite the baseline from this sweep")
    ap.add_argument("--config", default="all",
                    choices=("all", "train", "serve", "matmul", "kernels"),
                    help="sweep subset")
    ap.add_argument("--entry", default="",
                    help="only entries whose name contains this substring")
    ap.add_argument("--baseline", default=DEFAULT_BASELINE)
    args = ap.parse_args(argv)

    from . import baseline as bl
    from . import lint

    findings, entries, kernel_stats = run_sweep(args.config, args.entry)
    for name in sorted(entries):
        e = entries[name]
        if "collectives" in e:
            print(f"{name}: {sum(c['count'] for c in e['collectives'].values())} "
                  f"collectives, {e['total_wire_bytes']} wire bytes")
        else:
            print(f"{name}: traced={e['traced_bytes']} "
                  f"predicted={e['predicted_bytes']} bytes")
    for k, s in sorted(kernel_stats.items()):
        print(f"{k}: grid={s['grid']} vmem={s['vmem_bytes']}B")

    rc = 0
    for f in findings:
        print(f"FINDING {f}", file=sys.stderr)
        rc = 1

    payload = dict(entries)
    for k, s in kernel_stats.items():
        payload[f"kernel:{k}"] = s

    if args.update:
        # lint findings still fail an --update run: the baseline is a
        # contract for CONFORMANT programs only
        bl.write(args.baseline, payload)
        print(f"baseline written: {args.baseline} ({len(payload)} entries)")
    elif args.check:
        if args.config != "all" or args.entry:
            print("--check requires the full sweep (no --entry/--config "
                  "subset): partial sweeps always diff as missing entries",
                  file=sys.stderr)
            return 2
        try:
            base = bl.load(args.baseline)
        except FileNotFoundError:
            print(f"no baseline at {args.baseline}; run --update first",
                  file=sys.stderr)
            return 2
        drift = bl.diff(base, payload)
        for line in drift:
            print(f"DRIFT {line}", file=sys.stderr)
            rc = 1
        if not drift:
            print(f"baseline conformant: {len(payload)} entries")

    # the AST lint rides every invocation: it is cheap and the CI job
    # calls this module once
    lint_findings = lint.lint_paths(["src"]) if os.path.isdir("src") else []
    for path, line, code, msg in lint_findings:
        print(f"FINDING [lint] {path}:{line}: {code} {msg}",
              file=sys.stderr)
        rc = 1
    if rc == 0:
        print("shardcheck: OK")
    return rc


if __name__ == "__main__":
    raise SystemExit(main())
