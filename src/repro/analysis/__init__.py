"""Static analysis over the repo's jitted programs and source.

* ``collective_ir`` — jaxpr -> normalized collective IR (+ replication
  taint analysis) for every traced entry point.
* ``rules`` — the bug-class rule catalog run over the IR (DESIGN.md §13).
* ``baseline`` — the committed SHARDCHECK.json collective contract.
* ``shardcheck`` — the sweep driver / CLI gluing the above together.
* ``pallas_lint`` — GridMapping checks for kernels/*.py pallas_calls.
* ``lint`` — repo-custom AST lint (hash() seeding, mutable defaults,
  bare except) run over ``src/`` in CI.
"""
