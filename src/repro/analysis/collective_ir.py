"""Normalized collective IR extracted from closed jaxprs.

``benchmarks/comm_model.py`` and ``core/summa.py`` *predict* what each jitted
step should communicate; this module reads what it *actually* communicates.
Tracing a step builder's jitted fn to a closed jaxpr (``fn.trace(*abstract)``)
happens before XLA ever runs, so the walk is cheap, deterministic, and sees
the program post-AD — exactly the collective schedule the compiler is handed.

The walker descends every sub-jaxpr (``shard_map`` bodies, ``scan``/``while``
loops, ``cond`` branches, ``pjit``/``custom_vjp``/``remat`` calls) and
multiplies loop-body collectives by their trip count.  ``scan`` carries its
trip count in the eqn (``length``); ``while`` trip counts are recovered the
same way ``roofline/hlo.py`` does for HLO while loops — the largest integer
literal visible in the condition computation (one call level deep).

Every ``psum`` / ``psum_scatter`` / ``all_gather`` / ``ppermute`` /
``all_to_all`` (+ ``pmax``/``pmin``, which move all-reduce bytes) becomes one
:class:`Collective` record with named axes, local operand shape, dtype,
enclosing-loop multiplicity, and ring-model wire bytes (same byte formulas as
``roofline/hlo.py`` so jaxpr- and HLO-level accounting agree).

A second pass (:func:`replication_taints`) is a replication checker for
pre-vma jax (where shard_map runs with ``check_rep=False``): values seeded by
``lax.axis_index`` or by a sharded input axis are tracked through the body;
reaching a shard_map *output* whose out_names declare the value replicated
over an axis it still (conservatively) varies on is a divergence violation —
the bug class where per-device state leaks into a tensor the layout promises
is identical everywhere.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field

from jax._src import core as jcore

# primitive name -> normalized collective kind
COLLECTIVE_PRIMS = {
    "psum": "psum",
    "pmax": "pmax",
    "pmin": "pmin",
    "all_gather": "all_gather",
    "reduce_scatter": "psum_scatter",
    "ppermute": "ppermute",
    "all_to_all": "all_to_all",
}

# kinds whose output is invariant over the collective's axes (they erase
# per-device variation; ppermute / all_to_all / psum_scatter do not)
INVARIANT_KINDS = ("psum", "pmax", "pmin", "all_gather")


def _axes_of(eqn) -> tuple:
    """Named mesh axes of a collective eqn, sorted (positional ints dropped)."""
    ax = eqn.params.get("axes", eqn.params.get("axis_name", ()))
    if isinstance(ax, str):
        ax = (ax,)
    return tuple(sorted(a for a in ax if isinstance(a, str)))


def _aval_bytes(aval) -> int:
    try:
        return math.prod(aval.shape) * aval.dtype.itemsize
    except (AttributeError, TypeError):
        return 0


@dataclass(frozen=True)
class Collective:
    """One collective op in the normalized IR."""
    kind: str            # psum | psum_scatter | all_gather | ppermute | ...
    axes: tuple          # sorted named mesh axes
    shape: tuple         # local operand shape (first array operand)
    dtype: str
    mult: int            # product of enclosing loop trip counts
    group: int           # devices participating (prod of axis sizes)
    operand_bytes: int   # all array operands, one occurrence
    path: tuple = ()     # enclosing-context labels, outermost first

    @property
    def wire_bytes(self) -> float:
        """Ring-model wire bytes per device for ONE occurrence (same formulas
        as roofline/hlo.py so jaxpr- and HLO-level accounting agree)."""
        n, ob = self.group, self.operand_bytes
        frac = (n - 1) / n if n > 1 else 0.0
        if self.kind == "all_gather":
            return ob * (n - 1)          # output is n x operand
        if self.kind in ("psum", "pmax", "pmin"):
            return 2 * ob * frac
        if self.kind in ("psum_scatter", "all_to_all"):
            return ob * frac
        return ob                         # ppermute

    @property
    def total_wire_bytes(self) -> float:
        return self.mult * self.wire_bytes

    def key(self) -> str:
        return f"{self.kind}@{'x'.join(self.axes) if self.axes else '-'}"


@dataclass
class IRProgram:
    """Extraction result for one traced entry point."""
    collectives: list = field(default_factory=list)
    axis_sizes: dict = field(default_factory=dict)
    n_axis_index: int = 0
    shard_map_eqns: list = field(default_factory=list)

    def total_wire_bytes(self) -> float:
        return sum(c.total_wire_bytes for c in self.collectives)

    def by_key(self) -> dict:
        """{kind@axes: {count, wire_bytes}} aggregate (multiplicity folded)."""
        out: dict = {}
        for c in self.collectives:
            d = out.setdefault(c.key(), {"count": 0, "wire_bytes": 0.0})
            d["count"] += c.mult
            d["wire_bytes"] += c.total_wire_bytes
        return out

    def psum_axis_counts(self) -> dict:
        """{sorted axes tuple: multiplicity-summed count} of psum reductions
        (psum + psum_scatter), the input to the grad-sync completeness rule."""
        out: dict = {}
        for c in self.collectives:
            if c.kind in ("psum", "psum_scatter") and c.axes:
                out[c.axes] = out.get(c.axes, 0) + c.mult
        return out


# ---------------------------------------------------------------------------
# jaxpr walking
# ---------------------------------------------------------------------------

def _as_jaxpr(v):
    """Unwrap ClosedJaxpr -> Jaxpr; return None for non-jaxpr values."""
    if isinstance(v, jcore.ClosedJaxpr):
        return v.jaxpr
    if isinstance(v, jcore.Jaxpr):
        return v
    return None


def _sub_jaxprs(params: dict):
    """All (name, jaxpr) sub-jaxprs referenced by an eqn's params."""
    out = []
    for k, v in params.items():
        j = _as_jaxpr(v)
        if j is not None:
            out.append((k, j))
        elif isinstance(v, (tuple, list)):
            for i, vi in enumerate(v):
                ji = _as_jaxpr(vi)
                if ji is not None:
                    out.append((f"{k}[{i}]", ji))
    return out


def _int_literals(jaxpr, depth: int = 1) -> list:
    """Integer literals visible in a jaxpr (+ ``depth`` call levels), the
    jaxpr analogue of roofline/hlo.py::_trip_count's constant scan."""
    out = []
    for eqn in jaxpr.eqns:
        for v in eqn.invars:
            if isinstance(v, jcore.Literal):
                try:
                    out.append(int(v.val))
                except (TypeError, ValueError, OverflowError):
                    pass
        if depth > 0:
            for _, sub in _sub_jaxprs(eqn.params):
                out.extend(_int_literals(sub, depth - 1))
    return out


def while_trip_count(eqn) -> int:
    """Trip-count bound for a ``while`` eqn: the largest integer literal in
    its condition computation (roofline/hlo.py discipline), default 1."""
    cond = _as_jaxpr(eqn.params.get("cond_jaxpr"))
    if cond is None:
        return 1
    lits = [l for l in _int_literals(cond) if 0 < l < 2 ** 31]
    return max(lits) if lits else 1


def mesh_axis_sizes(mesh) -> dict:
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def extract_ir(closed_jaxpr, axis_sizes: dict | None = None) -> IRProgram:
    """Walk a closed jaxpr into the normalized collective IR."""
    prog = IRProgram(axis_sizes=dict(axis_sizes or {}))

    def group_size(axes, sizes) -> int:
        n = 1
        for a in axes:
            n *= sizes.get(a, 1)
        return n

    def walk(jaxpr, mult: int, path: tuple, sizes: dict):
        for eqn in jaxpr.eqns:
            name = eqn.primitive.name
            if name == "axis_index":
                prog.n_axis_index += 1
                continue
            if name in COLLECTIVE_PRIMS:
                axes = _axes_of(eqn)
                ob = sum(_aval_bytes(v.aval) for v in eqn.invars
                         if not isinstance(v, jcore.Literal)
                         or hasattr(v.aval, "shape"))
                first = next((v.aval for v in eqn.invars
                              if hasattr(v.aval, "shape")), None)
                prog.collectives.append(Collective(
                    kind=COLLECTIVE_PRIMS[name], axes=axes,
                    shape=tuple(first.shape) if first is not None else (),
                    dtype=str(first.dtype) if first is not None else "?",
                    mult=mult, group=group_size(axes, sizes),
                    operand_bytes=ob, path=path))
                continue
            if name == "shard_map":
                prog.shard_map_eqns.append((eqn, mult, path))
                sub_sizes = dict(sizes)
                mesh = eqn.params.get("mesh")
                if mesh is not None:
                    sub_sizes.update(mesh_axis_sizes(mesh))
                    prog.axis_sizes.update(mesh_axis_sizes(mesh))
                body = _as_jaxpr(eqn.params.get("jaxpr"))
                if body is not None:
                    walk(body, mult, path + ("shard_map",), sub_sizes)
                continue
            if name == "scan":
                length = int(eqn.params.get("length", 1))
                body = _as_jaxpr(eqn.params.get("jaxpr"))
                if body is not None:
                    walk(body, mult * length,
                         path + (f"scan[{length}]",), sizes)
                continue
            if name == "while":
                trips = while_trip_count(eqn)
                body = _as_jaxpr(eqn.params.get("body_jaxpr"))
                if body is not None:
                    walk(body, mult * trips,
                         path + (f"while[{trips}]",), sizes)
                cond = _as_jaxpr(eqn.params.get("cond_jaxpr"))
                if cond is not None:
                    walk(cond, mult * trips,
                         path + (f"while_cond[{trips}]",), sizes)
                continue
            # generic containers: pjit, cond branches, custom_vjp, remat, ...
            for label, sub in _sub_jaxprs(eqn.params):
                walk(sub, mult, path + (f"{name}:{label}",), sizes)

    walk(closed_jaxpr.jaxpr, 1, (), dict(axis_sizes or {}))
    return prog


# ---------------------------------------------------------------------------
# replication-divergence taint analysis (rule c)
# ---------------------------------------------------------------------------

def _names_axes(names) -> set:
    """Axis names appearing anywhere in a shard_map in/out names dict."""
    out: set = set()
    for axes in (names or {}).values():
        if isinstance(axes, str):
            out.add(axes)
        else:
            out.update(axes)
    return out


def _taint_jaxpr(jaxpr, in_taints, env_consts=None) -> list:
    """Propagate per-axis variance taint through a jaxpr's eqns.

    Returns the taint sets of the jaxpr's outvars.  Collectives that make
    values invariant over their axes (psum/pmax/pmin/all_gather) clear those
    axes; ppermute/all_to_all/psum_scatter outputs still vary.  scan/while
    carries run to a fixpoint; every other sub-jaxpr is entered with its
    operand taints.
    """
    env: dict = {}

    def read(v) -> frozenset:
        if isinstance(v, jcore.Literal):
            return frozenset()
        return env.get(v, frozenset())

    def write(v, t: frozenset):
        env[v] = frozenset(t)

    for v, t in zip(jaxpr.invars, in_taints):
        write(v, t)
    for v in jaxpr.constvars:
        write(v, frozenset())

    for eqn in jaxpr.eqns:
        name = eqn.primitive.name
        joined = frozenset().union(*[read(v) for v in eqn.invars]) \
            if eqn.invars else frozenset()
        if name == "axis_index":
            ax = eqn.params.get("axis_name")
            ax = (ax,) if isinstance(ax, str) else tuple(ax or ())
            for ov in eqn.outvars:
                write(ov, frozenset(ax))
            continue
        if name in COLLECTIVE_PRIMS:
            kind = COLLECTIVE_PRIMS[name]
            axes = frozenset(_axes_of(eqn))
            if kind in INVARIANT_KINDS:
                out_t = joined - axes
            else:
                out_t = joined | axes
            for ov in eqn.outvars:
                write(ov, out_t)
            continue
        subs = _sub_jaxprs(eqn.params)
        if name == "scan" and subs:
            # per-position carry fixpoint (a tainted carry can taint itself
            # on the next trip).  scan invars are [consts, init_carry, xs]
            # and outvars [final_carry, ys]; num_consts/num_carry let us
            # thread taints positionally instead of smearing a union over
            # every output (which falsely taints e.g. all grads with the
            # layer body's position-id axis_index).
            body = _as_jaxpr(eqn.params.get("jaxpr")) or subs[-1][1]
            nc = int(eqn.params.get("num_consts", 0))
            ncar = int(eqn.params.get("num_carry", 0))
            in_t = [read(v) for v in eqn.invars]
            if len(body.invars) == len(in_t) and ncar <= len(body.outvars):
                carry = in_t[nc:nc + ncar]
                out_t = _taint_jaxpr(body, in_t)
                for _ in range(16):
                    new = [carry[i] | out_t[i] for i in range(ncar)]
                    if new == carry:
                        break
                    carry = new
                    out_t = _taint_jaxpr(
                        body, in_t[:nc] + carry + in_t[nc + ncar:])
                for ov, t in zip(eqn.outvars, out_t):
                    write(ov, t)
            else:  # unexpected arity: conservative union
                for ov in eqn.outvars:
                    write(ov, joined)
            continue
        if name == "while" and subs:
            # while invars are [cond_consts, body_consts, init_carry]; the
            # body maps [body_consts, carry] -> [carry].
            body = _as_jaxpr(eqn.params.get("body_jaxpr"))
            cn = int(eqn.params.get("cond_nconsts", 0))
            bn = int(eqn.params.get("body_nconsts", 0))
            in_t = [read(v) for v in eqn.invars]
            bconsts, carry = in_t[cn:cn + bn], in_t[cn + bn:]
            if body is not None and len(body.invars) == bn + len(carry) \
                    and len(body.outvars) == len(carry):
                out_t = _taint_jaxpr(body, bconsts + carry)
                for _ in range(16):
                    new = [carry[i] | out_t[i] for i in range(len(carry))]
                    if new == carry:
                        break
                    carry = new
                    out_t = _taint_jaxpr(body, bconsts + carry)
                for ov, t in zip(eqn.outvars, out_t):
                    write(ov, t)
            else:
                for ov in eqn.outvars:
                    write(ov, joined)
            continue
        if subs:
            # generic call-like eqn (pjit / custom_vjp / cond / remat):
            # enter the (first) sub-jaxpr with operand taints when arities
            # line up, else degrade to the conservative union
            handled = False
            if len(subs) == 1:
                sub = subs[0][1]
                in_t = [read(v) for v in eqn.invars]
                if len(sub.invars) == len(in_t):
                    out_t = _taint_jaxpr(sub, in_t)
                    if len(out_t) == len(eqn.outvars):
                        for ov, t in zip(eqn.outvars, out_t):
                            write(ov, t)
                        handled = True
            if not handled:
                sub_union = frozenset()
                for _, sub in subs:
                    in_t = [read(v) for v in eqn.invars]
                    pad = [joined] * max(0, len(sub.invars) - len(in_t))
                    out_t = _taint_jaxpr(sub,
                                         (in_t + pad)[: len(sub.invars)])
                    sub_union |= (frozenset().union(*out_t) if out_t
                                  else frozenset())
                for ov in eqn.outvars:
                    write(ov, joined | sub_union)
            continue
        for ov in eqn.outvars:
            write(ov, joined)

    return [read(v) for v in jaxpr.outvars]


def replication_taints(closed_jaxpr, *, seed_inputs: bool = True) -> list:
    """Run the divergence sanitizer over every shard_map in a closed jaxpr.

    Returns a list of violation dicts: shard_map outputs that (per the
    conservative dataflow) may still vary over an axis their out_names
    declare replicated.  ``seed_inputs=False`` restricts seeding to
    ``axis_index`` (the ISSUE's literal rule c); the default additionally
    seeds each input's sharded axes, which makes the pass a full
    replication checker for ``check_rep=False`` shard_maps.
    """
    prog = extract_ir(closed_jaxpr)
    violations = []
    for eqn, _mult, path in prog.shard_map_eqns:
        body = _as_jaxpr(eqn.params.get("jaxpr"))
        if body is None:
            continue
        mesh = eqn.params.get("mesh")
        sizes = mesh_axis_sizes(mesh) if mesh is not None else {}
        # a size-1 axis cannot diverge (axis_index over it is constant 0)
        mesh_axes = {a for a, n in sizes.items() if n > 1}
        in_names = eqn.params.get("in_names", ())
        out_names = eqn.params.get("out_names", ())
        in_taints = []
        for i, v in enumerate(body.invars):
            if seed_inputs and i < len(in_names):
                in_taints.append(frozenset(_names_axes(in_names[i])))
            else:
                in_taints.append(frozenset())
        out_taints = _taint_jaxpr(body, in_taints)
        for i, t in enumerate(out_taints):
            declared = _names_axes(out_names[i]) if i < len(out_names) \
                else set()
            bad = (set(t) & mesh_axes) - declared
            if bad:
                violations.append({
                    "output": i, "axes": tuple(sorted(bad)),
                    "declared": tuple(sorted(declared)),
                    "path": path,
                })
    return violations
