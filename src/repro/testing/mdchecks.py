"""Multi-device correctness checks, run in a subprocess with
XLA_FLAGS=--xla_force_host_platform_device_count=N (so the main pytest
process keeps a single device).

Usage:  python -m repro.testing.mdchecks <check-name>

Each check asserts and prints "PASS <name>"; nonzero exit on failure.
"""
from __future__ import annotations

import os
import sys

if __name__ == "__main__" and "XLA_FLAGS" not in os.environ:
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import numpy as np  # noqa: E402


def _ref_mesh_ctx():
    """1-device reference context (uses the first of the fake devices)."""
    import jax
    from repro.core.api import ParallelContext
    from repro.core.mesh import logical_mesh
    ctx = ParallelContext(mode="tesseract", data=1, depth=1, rows=1, cols=1)
    return ctx, logical_mesh(ctx, jax.devices()[:1])


PARALLEL_VARIANTS = {
    "tesseract_222": dict(mode="tesseract", data=1, depth=2, rows=2, cols=2),
    "tesseract_221_dp2": dict(mode="tesseract", data=2, depth=2, rows=1, cols=1),
    "summa2d_22_dp2": dict(mode="summa2d", data=2, depth=1, rows=2, cols=2),
    "megatron_dp2": dict(mode="megatron1d", data=2, depth=1, rows=1, cols=4),
}


def check_summa_exact(schedules=("fused", "ring", "auto")):
    """Distributed matmul == dense reference, loss AND grads.

    Grads are computed INSIDE shard_map (the production pattern: the step
    functions run value_and_grad in the local view), with the deferred
    (data, depth) weight reduction supplied by grad_sync — identical
    semantics on vma and pre-vma jax."""
    import jax, jax.numpy as jnp
    from jax import lax
    from jax.sharding import PartitionSpec as P
    from repro.core.api import ParallelContext
    from repro.core.mesh import logical_mesh
    from repro.core.summa import tesseract_matmul, tesseract_matmul_wt
    from repro.core.collectives import grad_sync
    from repro.core.collectives import shard_map

    E, F, G = 24, 8, 12
    A = jax.random.normal(jax.random.PRNGKey(0), (2, E, F), jnp.float32)
    W = jax.random.normal(jax.random.PRNGKey(1), (F, G), jnp.float32)
    Wt = jax.random.normal(jax.random.PRNGKey(3), (G, F), jnp.float32)
    S = jax.random.normal(jax.random.PRNGKey(2), (2, E, G), jnp.float32)

    variants = [("d2q2", dict(depth=2, rows=2, cols=2)),
                ("d1q2dp2", dict(mode="summa2d", data=2, depth=1, rows=2, cols=2))]
    for sched in schedules:
        for name, kw in variants:
            for inop in (True, False):
                for cache_w in (True, False):
                    ctx = ParallelContext(mode=kw.get("mode", "tesseract"),
                                          data=kw.get("data", 1), depth=kw["depth"],
                                          rows=kw["rows"], cols=kw["cols"],
                                          reduce_dgrad_in_op=inop,
                                          cache_weight_gather=cache_w,
                                          matmul_schedule=sched)
                    mesh = logical_mesh(ctx)
                    tok = P(None, ("data", "depth", "row"), "col")

                    def make(op):
                        def local(a, w, s):
                            def loss(a_, w_):
                                if not inop:
                                    w_ = grad_sync(w_, (ctx.axis_data,
                                                        ctx.axis_depth))
                                c = op(ctx, a_, w_)
                                # differentiate the LOCAL contribution: the
                                # cross-device reductions live in the ops'
                                # custom bwds (grad_sync / in-op psum), the
                                # same discipline the train step uses.
                                return jnp.sum(c * s)
                            l, (ga_, gw_) = jax.value_and_grad(
                                loss, argnums=(0, 1))(a, w)
                            l = lax.psum(l, ("data", "depth", "row", "col"))
                            return l, ga_, gw_
                        return shard_map(
                            local, mesh=mesh,
                            in_specs=(tok, P("row", "col"), tok),
                            out_specs=(P(), tok, P("row", "col")))

                    tag = f"{sched}/{name}/inop={inop}/cache_w={cache_w}"
                    l, ga, gw = make(tesseract_matmul)(A, W, S)
                    np.testing.assert_allclose(np.asarray(l),
                                               float(jnp.sum((A @ W) * S)),
                                               rtol=1e-5, err_msg=tag)
                    np.testing.assert_allclose(ga, np.einsum("beg,fg->bef", S, W),
                                               rtol=1e-4, atol=1e-5, err_msg=tag)
                    np.testing.assert_allclose(gw, np.einsum("bef,beg->fg", A, S),
                                               rtol=1e-4, atol=1e-5, err_msg=tag)

                    # A @ Wt^T : Wt [G(row), F(col)]
                    Swt = jax.random.normal(jax.random.PRNGKey(4), (2, E, G),
                                            jnp.float32)
                    l2, ga2, gw2 = make(tesseract_matmul_wt)(A, Wt, Swt)
                    np.testing.assert_allclose(
                        np.asarray(l2),
                        float(jnp.sum((A @ Wt.T) * Swt)), rtol=1e-5, err_msg=tag)
                    np.testing.assert_allclose(ga2, np.einsum("beg,gf->bef", Swt, Wt),
                                               rtol=1e-4, atol=1e-5, err_msg=tag)
                    np.testing.assert_allclose(gw2, np.einsum("beg,bef->gf", Swt, A),
                                               rtol=1e-4, atol=1e-5, err_msg=tag)
    print("PASS summa_exact")


def check_ring_schedule():
    """matmul_schedule="ring" == "fused" == dense reference for q in
    {1, 2, 4} (q=4 needs 16 fake devices), all three op variants, forward
    AND both backward contractions."""
    import jax, jax.numpy as jnp
    from jax import lax
    from jax.sharding import PartitionSpec as P
    from repro.core.api import ParallelContext
    from repro.core.mesh import logical_mesh
    from repro.core.summa import (tesseract_matmul, tesseract_matmul_experts,
                                  tesseract_matmul_wt)
    from repro.core.collectives import grad_sync, shard_map

    ndev = jax.device_count()
    grids = [dict(data=1, depth=1, rows=1, cols=1),
             dict(data=1, depth=2, rows=2, cols=2),
             dict(mode="summa2d", data=2, depth=1, rows=2, cols=2)]
    if ndev >= 16:
        grids.append(dict(data=1, depth=1, rows=4, cols=4))
    else:
        print("  (16 devices unavailable: q=4 grid skipped)")

    E, F, G = 24, 16, 24
    A = jax.random.normal(jax.random.PRNGKey(0), (2, E, F), jnp.float32)
    W = jax.random.normal(jax.random.PRNGKey(1), (F, G), jnp.float32)
    Wt = jax.random.normal(jax.random.PRNGKey(2), (G, F), jnp.float32)
    S = jax.random.normal(jax.random.PRNGKey(3), (2, E, G), jnp.float32)
    N, T = 4, 12
    Ae = jax.random.normal(jax.random.PRNGKey(4), (N, T, F), jnp.float32)
    We = jax.random.normal(jax.random.PRNGKey(5), (N, F, G), jnp.float32)
    Se = jax.random.normal(jax.random.PRNGKey(6), (N, T, G), jnp.float32)

    refs_plain = (float(jnp.sum((A @ W) * S)),
                  np.einsum("beg,fg->bef", S, W),
                  np.einsum("bef,beg->fg", A, S))
    Swt = jax.random.normal(jax.random.PRNGKey(7), (2, E, G), jnp.float32)
    refs_wt = (float(jnp.sum((A @ Wt.T) * Swt)),
               np.einsum("beg,gf->bef", Swt, Wt),
               np.einsum("beg,bef->gf", Swt, A))
    refs_exp = (float(jnp.sum(jnp.einsum("ntf,nfg->ntg", Ae, We) * Se)),
                np.einsum("neg,nfg->nef", Se, We),
                np.einsum("nef,neg->nfg", Ae, Se))

    for g in grids:
        for sched in ("fused", "ring"):
            # deferred dW sync (grad_sync below); in-op mode is covered by
            # check_summa_exact for both schedules.
            ctx = ParallelContext(mode=g.get("mode", "tesseract"),
                                  data=g["data"], depth=g["depth"],
                                  rows=g["rows"], cols=g["cols"],
                                  reduce_dgrad_in_op=False,
                                  matmul_schedule=sched)
            mesh = logical_mesh(ctx, jax.devices()[:ctx.data * ctx.tp])
            tok = P(None, ("data", "depth", "row"), "col")
            wspec = P("row", "col")
            tag = f"ring_schedule q={ctx.q} d={ctx.depth} dp={ctx.data} {sched}"

            def run(op, a, w, s):
                def local(a_l, w_l, s_l):
                    def loss(a_, w_):
                        w_ = grad_sync(w_, (ctx.axis_data, ctx.axis_depth))
                        return jnp.sum(op(ctx, a_, w_) * s_l)
                    l, (ga, gw) = jax.value_and_grad(loss, argnums=(0, 1))(
                        a_l, w_l)
                    return (lax.psum(l, ("data", "depth", "row", "col")),
                            ga, gw)
                sm = shard_map(local, mesh=mesh, in_specs=(tok, wspec, tok),
                               out_specs=(P(), tok, wspec))
                return sm(a, w, s)

            for op, w_in, s_in, refs, nm in (
                    (tesseract_matmul, W, S, refs_plain, "plain"),
                    (tesseract_matmul_wt, Wt, Swt, refs_wt, "wt")):
                l, ga, gw = run(op, A, w_in, s_in)
                np.testing.assert_allclose(np.asarray(l), refs[0], rtol=1e-5,
                                           err_msg=f"{tag}/{nm}/loss")
                np.testing.assert_allclose(ga, refs[1], rtol=1e-4, atol=1e-5,
                                           err_msg=f"{tag}/{nm}/dA")
                np.testing.assert_allclose(gw, refs[2], rtol=1e-4, atol=1e-5,
                                           err_msg=f"{tag}/{nm}/dW")

            if ctx.data == 1:  # experts: EP over depth, no data factor
                espec = P("depth", "row", "col")

                def local_e(a_l, w_l, s_l):
                    def loss(a_, w_):
                        return jnp.sum(
                            tesseract_matmul_experts(ctx, a_, w_) * s_l)
                    l, (ga, gw) = jax.value_and_grad(loss, argnums=(0, 1))(
                        a_l, w_l)
                    return (lax.psum(l, ("data", "depth", "row", "col")),
                            ga, gw)
                sm = shard_map(local_e, mesh=mesh,
                               in_specs=(espec, espec, espec),
                               out_specs=(P(), espec, espec))
                l, ga, gw = sm(Ae, We, Se)
                np.testing.assert_allclose(np.asarray(l), refs_exp[0],
                                           rtol=1e-5,
                                           err_msg=f"{tag}/experts/loss")
                np.testing.assert_allclose(ga, refs_exp[1], rtol=1e-4,
                                           atol=1e-5,
                                           err_msg=f"{tag}/experts/dA")
                np.testing.assert_allclose(gw, refs_exp[2], rtol=1e-4,
                                           atol=1e-5,
                                           err_msg=f"{tag}/experts/dW")
            print(f"  {tag}: plain+wt" +
                  ("+experts ok" if ctx.data == 1 else " ok"))
    print("PASS ring_schedule")


def _build(arch_name, variant, run_kw=None, family_kw=None):
    import jax
    from repro.configs.base import RunConfig
    from repro.core.api import ParallelContext
    from repro.core.mesh import logical_mesh
    from repro.models.registry import get_reduced, build_model
    arch = get_reduced(arch_name)
    kw = dict(param_dtype="float32", compute_dtype="float32",
              loss_chunk=8, q_chunk=8, kv_chunk=8, lr=1e-3)
    kw.update(run_kw or {})
    run = RunConfig(**kw)
    ctx = ParallelContext(**variant)
    mesh = logical_mesh(ctx, jax.devices()[:ctx.data * ctx.seq * ctx.tp])
    model = build_model(arch.model, ctx, run)
    return arch, run, ctx, mesh, model


def _make_batch(model, shape, key, train=True):
    import jax, jax.numpy as jnp
    B, S = shape.global_batch, shape.seq_len
    tok = jax.random.randint(key, (B, S), 0, min(250, model.cfg.vocab_size))
    batch = {"tokens": tok}
    if train:
        batch["labels"] = jnp.roll(tok, -1, 1)
    for name, (sd, _sp) in model.batch_extras(shape).items():
        batch[name] = jax.random.normal(jax.random.fold_in(key, 1),
                                        sd.shape, sd.dtype)
    return batch


def _train_losses(arch_name, variant, batch, n_steps=3, run_kw=None):
    import jax, jax.numpy as jnp
    from repro.configs.base import ShapeSpec
    from repro.runtime.steps import build_train_step
    from repro.optim.adamw import adamw_init
    arch, run, ctx, mesh, model = _build(arch_name, variant, run_kw)
    B, S = batch["tokens"].shape
    shape = ShapeSpec("t", seq_len=S, global_batch=B, kind="train")
    if model.batch_extras(shape):
        batch = dict(batch)
        batch.update({k: v for k, v in
                      _make_batch(model, shape, jax.random.PRNGKey(42)).items()
                      if k not in ("tokens", "labels")})
    bundle = build_train_step(model, mesh, shape)
    params = model.init(jax.random.PRNGKey(0))
    if run.zero_enabled:
        from repro.optim.zero import zero_opt_init
        opt = zero_opt_init(bundle)
    else:
        opt = adamw_init(params, master=run.master_weights)
    losses, gnorms = [], []
    p, o = params, opt
    for _ in range(n_steps):
        p, o, m = bundle.fn(p, o, batch)
        losses.append(float(m["loss"]))
        gnorms.append(float(m["grad_norm"]))
    return np.array(losses), (p, o, model, mesh, ctx, run, np.array(gnorms),
                              bundle)


def check_dense_parity(arch_name="yi-6b"):
    import jax, jax.numpy as jnp
    B, S = 8, 16
    tok = jax.random.randint(jax.random.PRNGKey(7), (B, S), 0, 250)
    batch = {"tokens": tok, "labels": jnp.roll(tok, -1, 1)}

    ref_losses, _ = _train_losses(
        arch_name, dict(mode="tesseract", data=1, depth=1, rows=1, cols=1),
        batch)
    assert np.all(np.isfinite(ref_losses))
    for name, variant in PARALLEL_VARIANTS.items():
        losses, _ = _train_losses(arch_name, variant, batch)
        np.testing.assert_allclose(losses, ref_losses, rtol=2e-4, atol=2e-4,
                                   err_msg=f"{arch_name}/{name}")
        print(f"  {arch_name}/{name}: losses match ref {losses}")
    print(f"PASS dense_parity[{arch_name}]")


def check_inop_matches_deferred():
    import jax, jax.numpy as jnp
    B, S = 8, 16
    tok = jax.random.randint(jax.random.PRNGKey(9), (B, S), 0, 250)
    batch = {"tokens": tok, "labels": jnp.roll(tok, -1, 1)}
    base = dict(mode="tesseract", data=1, depth=2, rows=2, cols=2)
    l_inop, _ = _train_losses("yi-6b", dict(base, reduce_dgrad_in_op=True), batch)
    l_def, _ = _train_losses("yi-6b", dict(base, reduce_dgrad_in_op=False), batch)
    np.testing.assert_allclose(l_inop, l_def, rtol=1e-5, atol=1e-6)
    print("PASS inop_matches_deferred")


def check_decode_parity(arch_name="yi-6b"):
    import jax, jax.numpy as jnp
    from repro.configs.base import ShapeSpec
    from repro.runtime.steps import build_decode_step
    B, S = 8, 32

    def run_variant(variant):
        arch, run, ctx, mesh, model = _build(arch_name, variant)
        shape = ShapeSpec("d", seq_len=S, global_batch=B, kind="decode")
        bundle = build_decode_step(model, mesh, shape)
        params = model.init(jax.random.PRNGKey(0))
        cache_sds, _ = model.cache_abstract(B, S, bundle.plan)
        cache = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), cache_sds)
        ids = jnp.arange(B, dtype=jnp.int32)[:, None] % 100
        out = [np.asarray(ids).ravel()]
        for t in range(3):
            ids, cache = bundle.fn(params, cache, ids, jnp.int32(t))
            out.append(np.asarray(ids).ravel())
        return np.stack(out)

    ref = run_variant(dict(mode="tesseract", data=1, depth=1, rows=1, cols=1))
    for name, variant in PARALLEL_VARIANTS.items():
        got = run_variant(variant)
        np.testing.assert_array_equal(got, ref, err_msg=f"{arch_name}/{name}")
        print(f"  decode {arch_name}/{name}: ids match")
    print(f"PASS decode_parity[{arch_name}]")


def check_prefill_parity(arch_name="yi-6b"):
    import jax, jax.numpy as jnp
    from repro.configs.base import ShapeSpec
    from repro.runtime.steps import build_prefill_step
    B, S = 4, 16

    def run_variant(variant):
        arch, run, ctx, mesh, model = _build(arch_name, variant)
        shape = ShapeSpec("p", seq_len=S, global_batch=B, kind="prefill")
        bundle = build_prefill_step(model, mesh, shape)
        params = model.init(jax.random.PRNGKey(0))
        tok = jax.random.randint(jax.random.PRNGKey(5), (B, S), 0, 250)
        ids, cache = bundle.fn(params, {"tokens": tok})
        return np.asarray(ids), np.asarray(cache["k"]), np.asarray(cache["v"])

    ref = run_variant(dict(mode="tesseract", data=1, depth=1, rows=1, cols=1))
    for name, variant in PARALLEL_VARIANTS.items():
        got = run_variant(variant)
        np.testing.assert_array_equal(got[0], ref[0], err_msg=f"ids {name}")
        np.testing.assert_allclose(got[1], ref[1], rtol=1e-4, atol=1e-5,
                                   err_msg=f"cache-k {name}")
        np.testing.assert_allclose(got[2], ref[2], rtol=1e-4, atol=1e-5,
                                   err_msg=f"cache-v {name}")
        print(f"  prefill {arch_name}/{name}: ids+cache match")
    print(f"PASS prefill_parity[{arch_name}]")


def check_moe_parity():
    """MoE (EP over depth) + MLA parity vs single device.

    capacity_factor is set high enough that no tokens are dropped — with
    drops, routing depends on the per-group token count and parity cannot
    hold bitwise (documented behaviour)."""
    import jax, jax.numpy as jnp
    run_kw = dict(capacity_factor=16.0)
    B, S = 8, 16
    tok = jax.random.randint(jax.random.PRNGKey(11), (B, S), 0, 250)
    batch = {"tokens": tok, "labels": jnp.roll(tok, -1, 1)}
    variants = {
        "tesseract_222": dict(mode="tesseract", data=1, depth=2, rows=2, cols=2),
        "summa2d_22_dp2": dict(mode="summa2d", data=2, depth=1, rows=2, cols=2),
    }
    for arch in ("llama4-scout-17b-a16e", "deepseek-v2-236b"):
        ref, _ = _train_losses(
            arch, dict(mode="tesseract", data=1, depth=1, rows=1, cols=1),
            batch, run_kw=run_kw)
        assert np.all(np.isfinite(ref))
        for name, v in variants.items():
            losses, _ = _train_losses(arch, v, batch, run_kw=run_kw)
            np.testing.assert_allclose(losses, ref, rtol=3e-4, atol=3e-4,
                                       err_msg=f"{arch}/{name}")
            print(f"  {arch}/{name}: losses match ref {losses}")
    print("PASS moe_parity")


def check_moe_decode():
    import jax, jax.numpy as jnp
    from repro.configs.base import ShapeSpec
    from repro.runtime.steps import build_decode_step
    B, S = 8, 32

    def run_variant(arch, variant):
        _, run, ctx, mesh, model = _build(arch, variant,
                                          dict(capacity_factor=16.0))
        shape = ShapeSpec("d", seq_len=S, global_batch=B, kind="decode")
        bundle = build_decode_step(model, mesh, shape)
        params = model.init(jax.random.PRNGKey(0))
        cache_sds, _ = model.cache_abstract(B, S, bundle.plan)
        cache = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), cache_sds)
        ids = jnp.arange(B, dtype=jnp.int32)[:, None] % 100
        out = []
        for t in range(3):
            ids, cache = bundle.fn(params, cache, ids, jnp.int32(t))
            out.append(np.asarray(ids).ravel())
        return np.stack(out)

    for arch in ("llama4-scout-17b-a16e", "deepseek-v2-236b"):
        ref = run_variant(arch, dict(mode="tesseract", data=1, depth=1,
                                     rows=1, cols=1))
        got = run_variant(arch, dict(mode="tesseract", data=1, depth=2,
                                     rows=2, cols=2))
        np.testing.assert_array_equal(got, ref, err_msg=arch)
        print(f"  moe decode {arch}: ids match")
    print("PASS moe_decode")


def check_smollm_padding():
    """Head padding (15->16) + replicated KV (5) parity."""
    check_dense_parity("smollm-360m")
    print("PASS smollm_padding")


def check_families_parity():
    """vision / whisper / ssm / hybrid: train-loss parity vs 1 device."""
    import jax, jax.numpy as jnp
    B, S = 8, 16
    tok = jax.random.randint(jax.random.PRNGKey(13), (B, S), 0, 250)
    batch = {"tokens": tok, "labels": jnp.roll(tok, -1, 1)}
    variants = {
        "tesseract_222": dict(mode="tesseract", data=1, depth=2, rows=2, cols=2),
        "summa2d_22_dp2": dict(mode="summa2d", data=2, depth=1, rows=2, cols=2),
    }
    for arch in ("llama-3.2-vision-11b", "whisper-base", "mamba2-1.3b",
                 "recurrentgemma-9b"):
        ref, _ = _train_losses(
            arch, dict(mode="tesseract", data=1, depth=1, rows=1, cols=1),
            batch)
        assert np.all(np.isfinite(ref)), (arch, ref)
        for name, v in variants.items():
            losses, _ = _train_losses(arch, v, batch)
            np.testing.assert_allclose(losses, ref, rtol=5e-4, atol=5e-4,
                                       err_msg=f"{arch}/{name}")
            print(f"  {arch}/{name}: losses match ref {losses}")
    print("PASS families_parity")


def check_families_serve():
    """prefill (distributed scans!) + decode parity for the new families."""
    import jax, jax.numpy as jnp
    from repro.configs.base import ShapeSpec
    from repro.runtime.steps import build_decode_step, build_prefill_step
    B, S = 4, 16
    archs = ("llama-3.2-vision-11b", "whisper-base", "mamba2-1.3b",
             "recurrentgemma-9b")

    def run_prefill(arch, variant):
        _, run, ctx, mesh, model = _build(arch, variant)
        shape = ShapeSpec("p", seq_len=S, global_batch=B, kind="prefill")
        bundle = build_prefill_step(model, mesh, shape)
        params = model.init(jax.random.PRNGKey(0))
        batch = _make_batch(model, shape, jax.random.PRNGKey(5), train=False)
        ids, cache = bundle.fn(params, batch)
        flat = [np.asarray(x) for x in jax.tree.leaves(cache)]
        return np.asarray(ids), flat

    def run_decode(arch, variant):
        _, run, ctx, mesh, model = _build(arch, variant)
        shape = ShapeSpec("d", seq_len=24, global_batch=8, kind="decode")
        bundle = build_decode_step(model, mesh, shape)
        params = model.init(jax.random.PRNGKey(0))
        cache_sds, _ = model.cache_abstract(8, 24, bundle.plan)
        cache = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), cache_sds)
        ids = jnp.arange(8, dtype=jnp.int32)[:, None] % 100
        out = []
        for t in range(3):
            ids, cache = bundle.fn(params, cache, ids, jnp.int32(t))
            out.append(np.asarray(ids).ravel())
        return np.stack(out)

    one = dict(mode="tesseract", data=1, depth=1, rows=1, cols=1)
    multi = dict(mode="tesseract", data=1, depth=2, rows=2, cols=2)
    for arch in archs:
        ids0, c0 = run_prefill(arch, one)
        ids1, c1 = run_prefill(arch, multi)
        np.testing.assert_array_equal(ids1, ids0, err_msg=f"prefill ids {arch}")
        for a, b in zip(c0, c1):
            np.testing.assert_allclose(b, a, rtol=2e-3, atol=2e-3,
                                       err_msg=f"prefill cache {arch}")
        d0 = run_decode(arch, one)
        d1 = run_decode(arch, multi)
        np.testing.assert_array_equal(d1, d0, err_msg=f"decode {arch}")
        print(f"  serve parity {arch}: ok")
    print("PASS families_serve")


def check_ring_train_parity():
    """Full train steps with matmul_schedule="ring" == "fused" (yi-6b
    reduced, tesseract [2,2,2]) — the schedule swaps transparently under
    jit + remat + custom-vjp + grad clip."""
    import jax, jax.numpy as jnp
    B, S = 8, 16
    tok = jax.random.randint(jax.random.PRNGKey(21), (B, S), 0, 250)
    batch = {"tokens": tok, "labels": jnp.roll(tok, -1, 1)}
    base = dict(mode="tesseract", data=1, depth=2, rows=2, cols=2)
    l_fused, _ = _train_losses("yi-6b", dict(base, matmul_schedule="fused"),
                               batch)
    l_ring, _ = _train_losses("yi-6b", dict(base, matmul_schedule="ring"),
                              batch)
    np.testing.assert_allclose(l_ring, l_fused, rtol=2e-5, atol=2e-5)
    print("PASS ring_train_parity", l_ring)


def _opt_bytes_per_device(bundle):
    """Per-device optimizer-state bytes from the bundle's real shardings."""
    import jax
    abs_opt = bundle.abstract_inputs[1]
    sh_opt = bundle.in_shardings[1]
    total = 0
    for ab, sh in zip(jax.tree.leaves(abs_opt), jax.tree.leaves(sh_opt)):
        loc = sh.shard_shape(tuple(ab.shape))
        n = 1
        for d in loc:
            n *= d
        total += n * ab.dtype.itemsize
    return total


def check_zero1_parity():
    """ZeRO-1 step == replicated-optimizer baseline over 5 steps (params,
    loss, grad norm), per cell: q in {1, 2} x dp in {2, 4} x master off/on
    (param_dtype fp32 / bf16+fp32-master), a depth-sharded-leaf grid
    (head/experts keep state depth-local), deferred grad sync, and the
    [pipe x data x ...] 1F1B mesh.  fp32 cells match to fp32 exactness;
    bf16 cells to bf16-wire accumulation noise.  Per-device opt-state
    bytes must shrink ~dp x on the dp=4 cell."""
    import jax, jax.numpy as jnp
    ndev = jax.device_count()
    B, S = 8, 16
    tok = jax.random.randint(jax.random.PRNGKey(7), (B, S), 0, 250)
    batch = {"tokens": tok, "labels": jnp.roll(tok, -1, 1)}

    grids = [
        ("q1_dp2", dict(mode="tesseract", data=2, depth=1, rows=1, cols=1)),
        ("q1_dp4", dict(mode="tesseract", data=4, depth=1, rows=1, cols=1)),
        ("q2_dp2", dict(mode="tesseract", data=2, depth=1, rows=2, cols=2)),
        ("q1_d2_dp2", dict(mode="tesseract", data=2, depth=2, rows=1,
                           cols=1)),
        ("q2_dp2_deferred", dict(mode="tesseract", data=2, depth=1, rows=2,
                                 cols=2, reduce_dgrad_in_op=False)),
        # fused Pallas attention under ZeRO-1 (both sides run the kernel
        # data path; fp32 exactness must hold like every other cell)
        ("q1_dp2_pallas", dict(mode="tesseract", data=2, depth=1, rows=1,
                               cols=1, attn_impl="pallas")),
        # 16 fake devices (tests/test_zero.py spawns with that count)
        ("q2_dp4", dict(mode="tesseract", data=4, depth=1, rows=2, cols=2)),
    ]
    # tests/test_zero.py runs single cells on bigger fake-device counts
    only = os.environ.get("ZERO1_CELLS")
    if only:
        cells = set(only.split(","))
        grids = [g for g in grids if g[0] in cells]
        assert grids or "pipe" in cells, f"no such cells: {only}"
    masters = [("fp32", dict()),
               ("bf16_master", dict(param_dtype="bfloat16",
                                    compute_dtype="bfloat16"))]

    def compare(tag, ref_pack, got_pack, tol):
        (ref, (pr, *_r)), (got, (pz, *_z)) = ref_pack, got_pack
        gr, gz = _r[5], _z[5]
        np.testing.assert_allclose(got, ref, rtol=tol, atol=tol,
                                   err_msg=f"{tag}: loss")
        np.testing.assert_allclose(gz, gr, rtol=tol, atol=tol,
                                   err_msg=f"{tag}: grad_norm")
        for (ka, a), (kb, b) in zip(
                jax.tree_util.tree_flatten_with_path(pr)[0],
                jax.tree_util.tree_flatten_with_path(pz)[0]):
            np.testing.assert_allclose(
                np.asarray(a, np.float32), np.asarray(b, np.float32),
                rtol=10 * tol, atol=10 * tol,
                err_msg=f"{tag}: param {jax.tree_util.keystr(ka)}")

    for name, variant in grids:
        need = (variant["data"] * variant["depth"] * variant["rows"]
                * variant["cols"])
        if need > ndev:
            print(f"  zero1 {name}: skipped ({need} devices > {ndev})")
            continue
        for mname, mkw in masters:
            tol = 2e-6 if mname == "fp32" else 3e-5
            ref = _train_losses("yi-6b", variant, batch, n_steps=5,
                                run_kw=mkw)
            got = _train_losses("yi-6b", variant, batch, n_steps=5,
                                run_kw=dict(mkw, zero1=True))
            compare(f"{name}/{mname}", ref, got, tol)
            print(f"  zero1 {name}/{mname}: losses/gnorm/params match "
                  f"{got[0][-2:]}")
        if name == "q1_dp4":
            b_ref = ref[1][7]
            b_got = got[1][7]
            ratio = _opt_bytes_per_device(b_ref) / _opt_bytes_per_device(
                b_got)
            assert ratio > 3.2, \
                f"dp=4 opt-state bytes shrank only {ratio:.2f}x"
            print(f"  zero1 q1_dp4: per-device opt state {ratio:.2f}x "
                  f"smaller")

    # ---- 1F1B pipeline mesh: blocks stage-sharded, embed/head shard their
    # state over (data, pipe) ----
    if ndev >= 4 and (not only or "pipe" in only):
        from repro.configs.base import RunConfig, ShapeSpec
        from repro.core.api import ParallelContext
        from repro.models.registry import build_model, get_reduced
        from repro.optim.adamw import adamw_init
        from repro.runtime.steps import build_train_step
        shape = ShapeSpec("t", seq_len=S, global_batch=B, kind="train")
        ctx = ParallelContext(mode="tesseract", data=2, depth=1, rows=1,
                              cols=1)

        def run_pipe(zero):
            run = RunConfig(param_dtype="float32", compute_dtype="float32",
                            loss_chunk=8, q_chunk=8, kv_chunk=8, lr=1e-3,
                            pipeline_microbatches=4, zero1=zero)
            mesh = _mesh5(ctx, 2)
            model = build_model(get_reduced("yi-6b").model, ctx, run)
            bundle = build_train_step(model, mesh, shape)
            p = jax.device_put(model.init(jax.random.PRNGKey(0)),
                               bundle.in_shardings[0])
            if zero:
                from repro.optim.zero import zero_opt_init
                o = jax.device_put(zero_opt_init(bundle),
                                   bundle.in_shardings[1])
            else:
                o = jax.device_put(adamw_init(p), bundle.in_shardings[1])
            out = []
            for _ in range(5):
                p, o, m = bundle.fn(p, o, batch)
                out.append((float(m["loss"]), float(m["grad_norm"])))
            return np.array(out), p

        ref, pr = run_pipe(False)
        got, pz = run_pipe(True)
        np.testing.assert_allclose(got, ref, rtol=2e-6, atol=2e-6,
                                   err_msg="pipe mesh: loss/gnorm")
        for a, b in zip(jax.tree.leaves(pr), jax.tree.leaves(pz)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=2e-5, atol=2e-5,
                                       err_msg="pipe mesh: params")
        print(f"  zero1 pipe2_dp2: 1F1B ZeRO-1 matches replicated "
              f"{got[-1]}")
    print("PASS zero1_parity")


def check_zero1_elastic():
    """ZeRO-1 state survives dp-degree and layout changes:

    (a) checkpoint round-trip — save under dp=4/ZeRO-1, restore onto
        dp=2/ZeRO-1 AND onto a dp=1 replicated-optimizer run (and from the
        replicated run back onto dp=4/ZeRO-1); every resumed trajectory
        matches the uninterrupted dp=4 run (uneven-leaf padding path
        covered by the reduced model's odd-sized norm/ vocab leaves);
    (b) elastic replan — fault at step 5 of a dp=8 ZeRO-1 run, replan onto
        4 devices (accum_steps=2 consumed), trajectory preserved while the
        opt-state shards re-partition 8 -> 4 via the manifest layout.
    """
    import tempfile

    import jax, jax.numpy as jnp
    from repro.checkpoint.ckpt import CheckpointManager
    from repro.configs.base import RunConfig, ShapeSpec
    from repro.core.api import ParallelContext
    from repro.core.mesh import logical_mesh
    from repro.models.registry import build_model, get_reduced
    from repro.optim.adamw import adamw_init
    from repro.optim.zero import make_ckpt_converter
    from repro.runtime.steps import build_train_step

    B, S = 8, 16
    tok = jax.random.randint(jax.random.PRNGKey(7), (B, S), 0, 250)
    batch = {"tokens": tok, "labels": jnp.roll(tok, -1, 1)}
    shape = ShapeSpec("t", seq_len=S, global_batch=B, kind="train")
    arch = get_reduced("yi-6b")

    def build(dp, zero):
        run = RunConfig(param_dtype="float32", compute_dtype="float32",
                        loss_chunk=8, q_chunk=8, kv_chunk=8, lr=1e-3,
                        zero1=zero)
        ctx = ParallelContext(mode="tesseract", data=dp, depth=1, rows=1,
                              cols=1)
        mesh = logical_mesh(ctx, jax.devices()[:dp])
        model = build_model(arch.model, ctx, run)
        bundle = build_train_step(model, mesh, shape)
        p = jax.device_put(model.init(jax.random.PRNGKey(0)),
                           bundle.in_shardings[0])
        if zero:
            from repro.optim.zero import zero_opt_init
            o = jax.device_put(zero_opt_init(bundle),
                               bundle.in_shardings[1])
        else:
            o = jax.device_put(adamw_init(p), bundle.in_shardings[1])
        return bundle, p, o

    def steps_n(bundle, p, o, n):
        out = []
        for _ in range(n):
            p, o, m = bundle.fn(p, o, batch)
            out.append(float(m["loss"]))
        return out, p, o

    def restore_into(mgr, step, bundle):
        abs_p, abs_o, _ = bundle.abstract_inputs
        conv = make_ckpt_converter(bundle.opt_layouts_json())
        return mgr.restore(step, {"params": abs_p, "opt": abs_o},
                           {"params": bundle.in_shardings[0],
                            "opt": bundle.in_shardings[1]}, convert=conv)

    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d)
        b4, p, o = build(4, zero=True)
        _, p, o = steps_n(b4, p, o, 2)
        mgr.save(1, {"params": p, "opt": o}, blocking=True,
                 meta={"opt_layout": b4.opt_layouts_json()})
        ref, _, _ = steps_n(b4, p, o, 3)

        # dp=4 ZeRO -> dp=2 ZeRO (zn 4 -> 2 re-partition)
        b2, _, _ = build(2, zero=True)
        st = restore_into(mgr, 1, b2)
        got2, _, _ = steps_n(b2, st["params"], st["opt"], 3)
        np.testing.assert_allclose(got2, ref, rtol=1e-5, atol=1e-6,
                                   err_msg="dp4 ZeRO ckpt -> dp2 ZeRO")
        print(f"  zero1 ckpt dp4 -> dp2: losses continue {got2}")

        # dp=4 ZeRO -> dp=1 replicated optimizer (unshard path)
        b1, _, _ = build(1, zero=False)
        st1 = restore_into(mgr, 1, b1)
        got1, p1, o1 = steps_n(b1, st1["params"], st1["opt"], 1)
        np.testing.assert_allclose(got1, ref[:1], rtol=1e-5, atol=1e-6,
                                   err_msg="dp4 ZeRO ckpt -> dp1 replicated")

        # ... and BACK: replicated dp=1 ckpt -> dp=4 ZeRO (shard path)
        mgr.save(2, {"params": p1, "opt": o1}, blocking=True,
                 meta={"opt_layout": b1.opt_layouts_json()})
        stb = restore_into(mgr, 2, b4)
        gotb, _, _ = steps_n(b4, stb["params"], stb["opt"], 2)
        np.testing.assert_allclose(gotb, ref[1:], rtol=1e-5, atol=1e-6,
                                   err_msg="replicated ckpt -> dp4 ZeRO")
        print(f"  zero1 ckpt dp4 -> dp1(replicated) -> dp4: losses "
              f"continue {got1 + gotb}")

    # ---- (b) elastic 8 -> 4 replan under ZeRO-1 ----
    from repro.runtime.elastic import replan
    from repro.runtime.train_loop import train
    run = RunConfig(param_dtype="float32", compute_dtype="float32",
                    loss_chunk=8, q_chunk=8, kv_chunk=8, lr=1e-3, zero1=True)
    eshape = ShapeSpec("t", seq_len=16, global_batch=16, kind="train")
    ctx8 = ParallelContext(mode="tesseract", data=8, depth=1, rows=1, cols=1)
    mesh8 = logical_mesh(ctx8, jax.devices()[:8])
    model8 = build_model(arch.model, ctx8, run)

    with tempfile.TemporaryDirectory() as dref, \
            tempfile.TemporaryDirectory() as dft:
        ref = train(model8, mesh8, eshape, steps=8, ckpt_dir=dref,
                    ckpt_every=100, log_every=0)

        def fault(step):
            if step == 5:
                raise RuntimeError("injected: half the fleet lost")

        try:
            train(model8, mesh8, eshape, steps=8, ckpt_dir=dft,
                  ckpt_every=2, log_every=0, fault_hook=fault,
                  max_restarts=0)
            raise AssertionError("fault did not surface")
        except RuntimeError:
            pass

        rp = replan(4, ctx8, global_batch=eshape.global_batch)
        assert rp.ctx.data == 4 and rp.accum_steps == 2, rp
        model4 = build_model(arch.model, rp.ctx, run)
        mesh4 = logical_mesh(rp.ctx, jax.devices()[:rp.n_used])
        res = train(model4, mesh4, eshape, steps=8, ckpt_dir=dft,
                    ckpt_every=100, log_every=0,
                    accum_steps=rp.accum_steps)
        np.testing.assert_allclose(res.losses, ref.losses[4:],
                                   rtol=1e-5, atol=1e-6,
                                   err_msg="post-replan ZeRO trajectory")
    print(f"  zero1 elastic: 8 -> 4 devices, opt shards re-partitioned, "
          f"trajectory preserved {res.losses}")
    print("PASS zero1_elastic")


def check_moe_local_layout():
    """Expert-local (beyond-paper) MoE layout == 2d layout numerics."""
    import jax, jax.numpy as jnp
    B, S = 8, 16
    tok = jax.random.randint(jax.random.PRNGKey(11), (B, S), 0, 250)
    batch = {"tokens": tok, "labels": jnp.roll(tok, -1, 1)}
    for arch in ("llama4-scout-17b-a16e", "deepseek-v2-236b"):
        ref, _ = _train_losses(
            arch, dict(mode="tesseract", data=1, depth=1, rows=1, cols=1),
            batch, run_kw=dict(capacity_factor=16.0))
        got, _ = _train_losses(
            arch, dict(mode="tesseract", data=1, depth=2, rows=2, cols=2),
            batch,
            run_kw=dict(capacity_factor=16.0, moe_expert_layout="local"))
        np.testing.assert_allclose(got, ref, rtol=3e-4, atol=3e-4)
        print(f"  moe local layout {arch}: match")
    print("PASS moe_local_layout")


def _engine_reference(model, mesh, params, prompts, n_new, S=64):
    """The pre-engine static-batch decode loop (prompt replay, fixed batch):
    the bit-parity oracle for the continuous-batching engine."""
    import jax, jax.numpy as jnp
    from repro.configs.base import ShapeSpec
    from repro.runtime.steps import build_decode_step
    B, lens = len(prompts), [len(p) for p in prompts]
    dec = build_decode_step(model, mesh,
                            ShapeSpec("d", S, B, "decode"))
    cache_sds, _ = model.cache_abstract(B, S, dec.plan)
    cache = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), cache_sds)
    ids = np.array([[p[0]] for p in prompts], np.int32)
    out = [[] for _ in range(B)]
    for t in range(max(l + n for l, n in zip(lens, n_new)) - 1):
        nxt, cache = dec.fn(params, cache, jnp.asarray(ids), jnp.int32(t))
        nxt = np.asarray(nxt)
        for b in range(B):
            if t + 1 < lens[b]:
                ids[b, 0] = prompts[b][t + 1]
            else:
                if t + 1 - lens[b] < n_new[b]:
                    out[b].append(int(nxt[b, 0]))
                ids[b, 0] = nxt[b, 0]
    return out


def full_forward_argmax(model, mesh, params, seq, n_new):
    """Greedy oracle with no KV cache at all: full forward over the growing
    sequence each step, argmax at its true last position.  Shared by the
    serve_engine check and tests/test_serve.py."""
    import jax.numpy as jnp
    from repro.configs.base import ShapeSpec
    from repro.runtime.steps import build_prefill_step
    bundles, out, seq = {}, [], list(seq)
    for _ in range(n_new):
        bucket = 8
        while bucket < len(seq):
            bucket *= 2
        if bucket not in bundles:
            bundles[bucket] = build_prefill_step(
                model, mesh, ShapeSpec("p", bucket, 1, "prefill"),
                with_lengths=True)
        toks = np.zeros((1, bucket), np.int32)
        toks[0, :len(seq)] = seq
        logits, _ = bundles[bucket].fn(
            params, {"tokens": jnp.asarray(toks),
                     "lengths": jnp.asarray([len(seq)], jnp.int32)})
        tok = int(np.argmax(np.asarray(logits)[0]))
        out.append(tok)
        seq.append(tok)
    return out


def check_serve_engine():
    """Continuous-batching engine == static-batch decode loop, bit-identical
    greedy tokens, for q in {1, 2} (tesseract + 1-D serve layout), mixed
    prompt lengths in one batch, including a pool-pressure (eviction +
    re-prefill) run."""
    import jax
    from repro.serve import EngineConfig, InferenceEngine, SamplingParams

    rng = np.random.RandomState(3)
    lens = [5, 9, 16, 12, 7, 3, 21, 10]
    n_new = [6, 10, 4, 8, 5, 12, 3, 7]
    prompts = [rng.randint(0, 250, (l,)).tolist() for l in lens]

    grids = [
        ("q1", dict(mode="tesseract", data=1, depth=1, rows=1, cols=1)),
        ("q2_d2", dict(mode="tesseract", data=1, depth=2, rows=2, cols=2)),
        ("q2_dp2", dict(mode="summa2d", data=2, depth=1, rows=2, cols=2)),
        ("megatron_dp2", dict(mode="megatron1d", data=2, depth=1, rows=1,
                              cols=4)),
        # attn_impl="pallas" cells (DESIGN.md §10): flash prefill +
        # block-table paged decode kernel on BOTH the engine and the static
        # reference loop; greedy tokens must stay bit-identical for
        # q in {1, 2}
        ("q1_pallas", dict(mode="tesseract", data=1, depth=1, rows=1,
                           cols=1, attn_impl="pallas")),
        ("q2_d2_pallas", dict(mode="tesseract", data=1, depth=2, rows=2,
                              cols=2, attn_impl="pallas")),
    ]
    for name, variant in grids:
        _, run, ctx, mesh, model = _build("yi-6b", variant)
        params = model.init(jax.random.PRNGKey(0))
        ref = _engine_reference(model, mesh, params, prompts, n_new)

        eng = InferenceEngine(model, mesh, params, EngineConfig(
            n_slots=8, block_size=4, num_blocks=128, max_seq_len=64))
        reqs = [eng.add_request(p, SamplingParams(max_new_tokens=n))
                for p, n in zip(prompts, n_new)]
        res = eng.run()
        got = [res[r.rid] for r in reqs]
        assert got == ref, f"{name}: engine != static loop\n{got}\n{ref}"
        if name in ("q1", "q2_d2", "q1_pallas", "q2_d2_pallas"):
            # the issue's q in {1, 2} criterion, per attn_impl
            for b in (0, 3):
                ffwd = full_forward_argmax(model, mesh, params, prompts[b],
                                           n_new[b])
                assert got[b] == ffwd, \
                    f"{name} req{b}: engine != full-forward argmax" \
                    f"\n{got[b]}\n{ffwd}"
        print(f"  serve engine {name}: bit-identical to static loop "
              f"({eng.stats.tokens} tokens, {eng.stats.steps} steps)")

    # pool pressure: two slots per KV group and per-group freelists too
    # small for both residents at full length -> preemption-by-eviction +
    # re-prefill (slots_per_group must be > 1 for cross-request eviction)
    _, run, ctx, mesh, model = _build(
        "yi-6b", dict(mode="tesseract", data=1, depth=2, rows=2, cols=2))
    params = model.init(jax.random.PRNGKey(0))
    ref = _engine_reference(model, mesh, params, prompts, n_new)
    eng = InferenceEngine(model, mesh, params, EngineConfig(
        n_slots=8, block_size=4, num_blocks=32, max_seq_len=64))
    reqs = [eng.add_request(p, SamplingParams(max_new_tokens=n))
            for p, n in zip(prompts, n_new)]
    res = eng.run()
    got = [res[r.rid] for r in reqs]
    assert got == ref, f"evicted run != static loop\n{got}\n{ref}"
    assert eng.stats.preemptions > 0, "pool pressure never triggered"
    print(f"  serve engine eviction: parity held through "
          f"{eng.stats.preemptions} preemptions")
    print("PASS serve_engine")


def check_engine_elastic():
    """runtime.elastic.replan driven from the engine: drop 8 -> 4 devices
    mid-generation, reshard live KV blocks, finish — tokens must match an
    uninterrupted run."""
    import jax
    from repro.serve import EngineConfig, InferenceEngine, SamplingParams

    rng = np.random.RandomState(5)
    lens = [5, 9, 16, 12, 7, 3, 21, 10]
    n_new = [6, 10, 4, 8, 5, 12, 3, 7]
    prompts = [rng.randint(0, 250, (l,)).tolist() for l in lens]

    _, run, ctx, mesh, model = _build(
        "yi-6b", dict(mode="tesseract", data=2, depth=1, rows=2, cols=2))
    params = model.init(jax.random.PRNGKey(0))
    ref = _engine_reference(model, mesh, params, prompts, n_new)

    eng = InferenceEngine(model, mesh, params, EngineConfig(
        n_slots=8, block_size=4, num_blocks=128, max_seq_len=64))
    reqs = [eng.add_request(p, SamplingParams(max_new_tokens=n))
            for p, n in zip(prompts, n_new)]
    for _ in range(3):
        eng.step()
    rp = eng.replan_to(4)
    assert rp.ctx.data == 1 and rp.n_used == 4, rp
    res = eng.run()
    got = [res[r.rid] for r in reqs]
    assert got == ref, f"post-replan tokens diverged\n{got}\n{ref}"
    print(f"  elastic: 8 -> {rp.n_used} devices mid-run, tokens identical")
    print("PASS engine_elastic")


def check_spec_decode():
    """Speculative decoding (DESIGN.md §14) commits bit-identical greedy
    tokens to plain paged decode for q in {1, 2}, with both the n-gram
    prompt-lookup proposer and a smollm-360m draft model, under pool
    pressure (eviction + re-prefill mid-speculation) and through an
    8 -> 4 elastic replan."""
    import dataclasses

    import jax
    from repro.models.registry import build_model, get_reduced
    from repro.serve import EngineConfig, InferenceEngine, SamplingParams

    rng = np.random.RandomState(9)
    # repetitive prompts give the n-gram proposer something to accept;
    # parity must hold regardless of acceptance
    prompts, n_new = [], []
    for i in range(8):
        base = rng.randint(0, 250, (rng.randint(3, 6),)).tolist()
        prompts.append((base * 6)[:rng.randint(6, 18)])
        n_new.append(int(rng.randint(4, 10)))

    def run_spec(model, mesh, params, cfg_kw, draft=None, dparams=None):
        eng = InferenceEngine(model, mesh, params,
                              EngineConfig(n_slots=8, block_size=4,
                                           max_seq_len=64, **cfg_kw),
                              draft_model=draft, draft_params=dparams)
        reqs = [eng.add_request(p, SamplingParams(max_new_tokens=n))
                for p, n in zip(prompts, n_new)]
        eng.run()
        return [list(r.generated) for r in reqs], eng

    grids = [
        ("q1", dict(mode="tesseract", data=1, depth=1, rows=1, cols=1)),
        ("q2_dp2", dict(mode="summa2d", data=2, depth=1, rows=2, cols=2)),
    ]
    for name, variant in grids:
        _, run, ctx, mesh, model = _build("yi-6b", variant)
        params = model.init(jax.random.PRNGKey(0))
        plain, _ = run_spec(model, mesh, params, dict(num_blocks=128))

        darch = get_reduced("smollm-360m")
        dcfg = dataclasses.replace(darch.model,
                                   vocab_size=model.cfg.vocab_size)
        draft = build_model(dcfg, ctx, run)
        dparams = draft.init(jax.random.PRNGKey(7))

        for mode, dm, dp in (("ngram", None, None),
                             ("draft", draft, dparams)):
            got, eng = run_spec(model, mesh, params,
                                dict(num_blocks=128, spec_k=3,
                                     spec_mode=mode), dm, dp)
            s = eng.stats
            assert got == plain, \
                f"{name}/{mode}: spec != plain\n{got}\n{plain}"
            assert s.spec_rounds > 0 and s.spec_committed > 0
            print(f"  spec {name}/{mode}: bit-identical "
                  f"(acceptance={s.acceptance_rate():.2f}, "
                  f"tokens/slot-round={s.tokens_per_round():.2f})")

        # pool pressure: evictions interleave with speculative rounds;
        # position-keyed replay must keep parity (rollback correctness)
        got, eng = run_spec(model, mesh, params,
                            dict(num_blocks=32, spec_k=3,
                                 spec_mode="ngram"))
        assert eng.stats.preemptions > 0, f"{name}: no eviction triggered"
        assert got == plain, f"{name}: evicted spec run != plain"
        print(f"  spec {name}/evict: parity held through "
              f"{eng.stats.preemptions} preemptions")

    # elastic: speculate, drop 8 -> 4 devices (verify bundle + draft pool
    # rebuilt, draft watermarks reset), finish — tokens identical
    _, run, ctx, mesh, model = _build(
        "yi-6b", dict(mode="tesseract", data=2, depth=1, rows=2, cols=2))
    params = model.init(jax.random.PRNGKey(0))
    plain, _ = run_spec(model, mesh, params, dict(num_blocks=128))
    darch = get_reduced("smollm-360m")
    dcfg = dataclasses.replace(darch.model, vocab_size=model.cfg.vocab_size)
    draft = build_model(dcfg, ctx, run)
    dparams = draft.init(jax.random.PRNGKey(7))
    eng = InferenceEngine(model, mesh, params, EngineConfig(
        n_slots=8, block_size=4, num_blocks=128, max_seq_len=64,
        spec_k=3, spec_mode="draft"), draft_model=draft,
        draft_params=dparams)
    reqs = [eng.add_request(p, SamplingParams(max_new_tokens=n))
            for p, n in zip(prompts, n_new)]
    for _ in range(3):
        eng.step()
    rp = eng.replan_to(4)
    assert rp.ctx.data == 1 and rp.n_used == 4, rp
    eng.run()
    got = [list(r.generated) for r in reqs]
    assert got == plain, f"post-replan spec tokens diverged\n{got}\n{plain}"
    print(f"  spec elastic: 8 -> {rp.n_used} devices mid-speculation, "
          f"tokens identical")
    print("PASS spec_decode")


def _mesh5(ctx, pipe):
    """[pipe x data x depth x row x col] mesh (pipe=1 kept as a real axis so
    the 1-stage baseline runs the same 1F1B code path)."""
    import jax
    from repro.core.mesh import pipeline_mesh
    n = pipe * ctx.data * ctx.tp
    return pipeline_mesh(ctx, pipe, jax.devices()[:n], keep_pipe_axis=True)


def check_pipeline_parity():
    """1F1B pipelined training on a [2-stage pipe x tesseract] mesh matches
    the 1-stage baseline (same code path, pipe=1) to bit precision on the
    loss for q in {1, 2} (grad-norm bitwise at q=2, <= 2 ulp at q=1), and
    the flat non-pipe step within fp-association noise; the measured
    schedule bubble equals the analytic (S-1)/(M+S-1); a checkpoint taken
    at pipe=2 restores onto the pipe=1 mesh (stage re-shard) and continues
    the run."""
    import jax, jax.numpy as jnp
    from repro.configs.base import RunConfig, ShapeSpec
    from repro.core.api import ParallelContext
    from repro.core.mesh import logical_mesh
    from repro.models.registry import build_model, get_reduced
    from repro.optim.adamw import adamw_init
    from repro.runtime.steps import build_train_step

    B, S = 8, 16
    tok = jax.random.randint(jax.random.PRNGKey(7), (B, S), 0, 250)
    batch = {"tokens": tok, "labels": jnp.roll(tok, -1, 1)}
    shape = ShapeSpec("t", seq_len=S, global_batch=B, kind="train")
    run = RunConfig(param_dtype="float32", compute_dtype="float32",
                    loss_chunk=8, q_chunk=8, kv_chunk=8, lr=1e-3,
                    pipeline_microbatches=4)

    def build(ctx, mesh):
        model = build_model(get_reduced("yi-6b").model, ctx, run)
        bundle = build_train_step(model, mesh, shape)
        params = jax.device_put(model.init(jax.random.PRNGKey(0)),
                                bundle.in_shardings[0])
        opt = jax.device_put(adamw_init(params), bundle.in_shardings[1])
        return model, bundle, params, opt

    def run_steps(ctx, mesh, n_steps=5):
        _, bundle, p, o = build(ctx, mesh)
        out = []
        for _ in range(n_steps):
            p, o, m = bundle.fn(p, o, batch)
            out.append((float(m["loss"]), float(m["grad_norm"])))
        return np.array(out), bundle

    grids = [("q1", dict(mode="tesseract", data=1, depth=1, rows=1, cols=1)),
             ("q2", dict(mode="tesseract", data=1, depth=1, rows=2, cols=2)),
             # 1F1B with the fused Pallas attention kernels: the microbatch
             # composition replays the identical kernel op sequence, so the
             # bitwise-loss contract must survive attn_impl="pallas"
             ("q1_pallas", dict(mode="tesseract", data=1, depth=1, rows=1,
                                cols=1, attn_impl="pallas"))]
    for name, kw in grids:
        ctx = ParallelContext(**kw)
        r2, b2 = run_steps(ctx, _mesh5(ctx, 2))
        r1, _ = run_steps(ctx, _mesh5(ctx, 1))
        info = b2.pipe_info
        assert info["n_stages"] == 2 and info["n_micro"] == 4, info
        assert abs(info["measured_bubble"] - info["predicted_bubble"]) \
            < 1e-9, info
        np.testing.assert_array_equal(
            r2[:, 0], r1[:, 0],
            err_msg=f"{name}: pipelined loss != 1-stage baseline (bitwise)")
        np.testing.assert_allclose(
            r2[:, 1], r1[:, 1], rtol=0, atol=3e-7,
            err_msg=f"{name}: grad_norm drifted past ulp noise")
        rf, _ = run_steps(ctx, logical_mesh(ctx, jax.devices()[:ctx.tp]))
        np.testing.assert_allclose(r2, rf, rtol=1e-5, atol=1e-6,
                                   err_msg=f"{name}: vs flat step")
        print(f"  pipeline {name}: 5-step loss bitwise == 1-stage "
              f"(bubble {info['measured_bubble']:.3f})")

    # ---- checkpoint across a pipe-degree change (2 -> 1 stages) ----
    import tempfile
    from repro.checkpoint.ckpt import CheckpointManager
    ctx = ParallelContext(mode="tesseract", data=1, depth=1, rows=2, cols=2)
    _, b2, p, o = build(ctx, _mesh5(ctx, 2))
    ref = []
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d)
        for i in range(4):
            p, o, m = b2.fn(p, o, batch)
            ref.append(float(m["loss"]))
            if i == 1:   # snapshot before donation reuses the buffers
                mgr.save(1, {"params": p, "opt": o}, blocking=True)
        _, b1, _, _ = build(ctx, _mesh5(ctx, 1))
        abs_p, abs_o, _ = b1.abstract_inputs
        st = mgr.restore(1, {"params": abs_p, "opt": abs_o},
                         {"params": b1.in_shardings[0],
                          "opt": b1.in_shardings[1]})
    p1, o1 = st["params"], st["opt"]
    got = []
    for _ in range(2):
        p1, o1, m = b1.fn(p1, o1, batch)
        got.append(float(m["loss"]))
    np.testing.assert_allclose(got, ref[2:], rtol=0, atol=1e-6,
                               err_msg="pipe=2 ckpt -> pipe=1 restore")
    print("  pipeline ckpt: pipe=2 checkpoint restored onto pipe=1, "
          "losses continue")
    print("PASS pipeline_parity")


def check_attn_impl_parity():
    """attn_impl="pallas" (fused flash fwd+bwd, paged decode kernel —
    interpret mode on CPU) == the jnp reference path, end to end:

    - training-loss + grad-norm trajectories for q in {1, 2} over 5 steps
      to fp32 exactness (the issue's trajectory-parity criterion);
    - GQA head padding (smollm 15->16, replicated KV with a non-uniform
      kv_map) on the q=2 grid;
    - greedy decode ids bit-identical through the dense decode step (the
      dense cache viewed as a page pool by the decode kernel).
    """
    import jax, jax.numpy as jnp
    B, S = 8, 16
    tok = jax.random.randint(jax.random.PRNGKey(23), (B, S), 0, 250)
    batch = {"tokens": tok, "labels": jnp.roll(tok, -1, 1)}

    grids = [
        ("q1", dict(mode="tesseract", data=1, depth=1, rows=1, cols=1)),
        ("q2_d2", dict(mode="tesseract", data=1, depth=2, rows=2, cols=2)),
    ]
    for name, variant in grids:
        for arch in (("yi-6b", "smollm-360m") if name == "q2_d2"
                     else ("yi-6b",)):
            ref, (_, _, _, _, _, _, gn_ref, _) = _train_losses(
                arch, variant, batch, n_steps=5)
            got, (_, _, _, _, _, _, gn_got, _) = _train_losses(
                arch, dict(variant, attn_impl="pallas"), batch, n_steps=5)
            np.testing.assert_allclose(got, ref, rtol=2e-5, atol=2e-5,
                                       err_msg=f"{arch}/{name}: loss")
            np.testing.assert_allclose(gn_got, gn_ref, rtol=2e-5, atol=2e-5,
                                       err_msg=f"{arch}/{name}: grad_norm")
            print(f"  attn_impl {arch}/{name}: pallas trajectory == jnp "
                  f"{got[-2:]}")

    # dense decode ids through the paged-view kernel
    from repro.configs.base import ShapeSpec
    from repro.runtime.steps import build_decode_step

    def decode_ids(variant):
        _, run, ctx, mesh, model = _build("yi-6b", variant)
        shape = ShapeSpec("d", seq_len=32, global_batch=8, kind="decode")
        bundle = build_decode_step(model, mesh, shape)
        params = model.init(jax.random.PRNGKey(0))
        cache_sds, _ = model.cache_abstract(8, 32, bundle.plan)
        cache = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), cache_sds)
        ids = jnp.arange(8, dtype=jnp.int32)[:, None] % 100
        out = []
        for t in range(3):
            ids, cache = bundle.fn(params, cache, ids, jnp.int32(t))
            out.append(np.asarray(ids).ravel())
        return np.stack(out)

    for name, variant in grids:
        ref = decode_ids(variant)
        got = decode_ids(dict(variant, attn_impl="pallas"))
        np.testing.assert_array_equal(got, ref, err_msg=f"decode {name}")
        print(f"  attn_impl decode {name}: ids bit-identical")
    print("PASS attn_impl_parity")


def check_ring_attention():
    """Ring/striped flash attention over the seq mesh axis (DESIGN.md §15)
    == the unsharded flash baseline, end to end:

    - training-loss + grad-norm trajectories for q in {1, 2} x seq in
      {2, 4} over 5 steps to fp32 exactness, striped (causal
      load-balanced) AND contiguous-ring schedules, jnp and pallas data
      paths (cells needing more fake devices than available are skipped);
    - seq-sharded PREFILL with attn_schedule="ring": K/V ring over the
      (depth, row) sharding produces bit-identical greedy ids vs the
      gather-full-KV local schedule.
    """
    import jax, jax.numpy as jnp
    B, S = 8, 16
    tok = jax.random.randint(jax.random.PRNGKey(11), (B, S), 0, 250)
    batch = {"tokens": tok, "labels": jnp.roll(tok, -1, 1)}
    ndev = len(jax.devices())

    ref, (_, _, _, _, _, _, gn_ref, _) = _train_losses(
        "yi-6b", dict(mode="tesseract", data=1, depth=1, rows=1, cols=1),
        batch, n_steps=5)
    assert np.all(np.isfinite(ref))

    cells = [
        ("q1_seq2_striped", dict(rows=1, cols=1, seq=2,
                                 attn_schedule="striped")),
        ("q1_seq2_ring", dict(rows=1, cols=1, seq=2, attn_schedule="ring")),
        ("q1_seq4_striped", dict(rows=1, cols=1, seq=4,
                                 attn_schedule="striped")),
        ("q1_seq4_ring", dict(rows=1, cols=1, seq=4, attn_schedule="ring")),
        ("q1_seq2_striped_pallas", dict(rows=1, cols=1, seq=2,
                                        attn_schedule="striped",
                                        attn_impl="pallas")),
        ("q2_seq2_striped", dict(rows=2, cols=2, seq=2,
                                 attn_schedule="striped")),
        ("q2_seq2_ring", dict(rows=2, cols=2, seq=2, attn_schedule="ring")),
        ("q2_seq4_striped", dict(rows=2, cols=2, seq=4,
                                 attn_schedule="striped")),
        ("q2_seq4_ring", dict(rows=2, cols=2, seq=4, attn_schedule="ring")),
    ]
    for name, kw in cells:
        variant = dict(mode="tesseract", data=1, depth=1)
        variant.update(kw)
        need = (variant["rows"] * variant["cols"] * variant["seq"])
        if need > ndev:
            print(f"  ring_attention {name}: ({need} devices unavailable: "
                  f"skipped)")
            continue
        got, (_, _, _, _, _, _, gn_got, _) = _train_losses(
            "yi-6b", variant, batch, n_steps=5)
        np.testing.assert_allclose(got, ref, rtol=2e-5, atol=2e-5,
                                   err_msg=f"{name}: loss")
        np.testing.assert_allclose(gn_got, gn_ref, rtol=2e-5, atol=2e-5,
                                   err_msg=f"{name}: grad_norm")
        print(f"  ring_attention {name}: trajectory == unsharded flash "
              f"{got[-2:]}")

    # ---- op-level fwd+bwd parity incl. sliding window + GQA (no windowed
    # model can seq-shard, so the window path is pinned here) ----
    from jax.sharding import Mesh, PartitionSpec as P
    from repro.core.collectives import shard_map
    from repro.core.ring_attention import ring_attention, stripe_permutation
    n = 4
    if n <= ndev:
        Bq, Hq, Hkv, L, D, W = 2, 4, 2, 8, 16, 8
        T = n * L
        ks = jax.random.split(jax.random.PRNGKey(3), 4)
        q = jax.random.normal(ks[0], (Bq, Hq, T, D), jnp.float32)
        k = jax.random.normal(ks[1], (Bq, Hkv, T, D), jnp.float32)
        v = jax.random.normal(ks[2], (Bq, Hkv, T, D), jnp.float32)
        cot = jax.random.normal(ks[3], (Bq, Hq, T, D), jnp.float32)
        mesh = Mesh(np.array(jax.devices()[:n]), ("s",))
        sp = P(None, None, "s", None)

        def dense_ref(qg, kg, vg, window):
            kk = jnp.repeat(kg, Hq // Hkv, axis=1)
            vv = jnp.repeat(vg, Hq // Hkv, axis=1)
            s = jnp.einsum("bhtd,bhsd->bhts", qg, kk) / np.sqrt(D)
            i = jnp.arange(T)[:, None]
            j = jnp.arange(T)[None, :]
            ok = j <= i
            if window:
                ok &= j > i - window
            s = jnp.where(ok, s, -jnp.inf)
            return jnp.einsum("bhts,bhsd->bhtd", jax.nn.softmax(s, -1), vv)

        for variant, window, impl in (("ring", 0, "jnp"),
                                      ("ring", W, "jnp"),
                                      ("ring", W, "pallas"),
                                      ("striped", 0, "jnp"),
                                      ("striped", 0, "pallas")):
            perm = (stripe_permutation(T, n) if variant == "striped"
                    else np.arange(T))

            def fwd(qa, ka, va):
                f = shard_map(
                    lambda q_, k_, v_: ring_attention(
                        q_, k_, v_, axes=("s",), variant=variant,
                        causal=True, local_window=window, impl=impl,
                        interpret=True),
                    mesh=mesh, in_specs=(sp, sp, sp), out_specs=sp)
                return f(qa[:, :, perm], ka[:, :, perm], va[:, :, perm])

            def obj(args):
                return jnp.sum(fwd(*args) * cot[:, :, perm])

            out = fwd(q, k, v)
            grads = jax.grad(obj)((q, k, v))
            ref_out = dense_ref(q, k, v, window)[:, :, perm]

            def ref_obj(args):
                return jnp.sum(dense_ref(*args, window)[:, :, perm]
                               * cot[:, :, perm])
            ref_grads = jax.grad(ref_obj)((q, k, v))
            np.testing.assert_allclose(
                np.asarray(out), np.asarray(ref_out), rtol=2e-5, atol=2e-5,
                err_msg=f"op {variant}/w{window}/{impl}: out")
            for g, rg, nm in zip(grads, ref_grads, ("dq", "dk", "dv")):
                np.testing.assert_allclose(
                    np.asarray(g), np.asarray(rg), rtol=2e-5, atol=2e-5,
                    err_msg=f"op {variant}/w{window}/{impl}: {nm}")
            print(f"  ring_attention op {variant}/w{window}/{impl}: "
                  f"fwd+grads == dense ref")
    else:
        print("  ring_attention op-level: (4 devices unavailable: skipped)")

    # ---- seq-sharded prefill: (depth, row) K/V ring vs gather-full-KV ----
    from repro.configs.base import ShapeSpec
    from repro.runtime.steps import build_prefill_step

    def prefill_ids(variant):
        _, run, ctx, mesh, model = _build("yi-6b", variant)
        shape = ShapeSpec("p", seq_len=32, global_batch=2, kind="prefill")
        bundle = build_prefill_step(model, mesh, shape)
        params = model.init(jax.random.PRNGKey(0))
        ptok = jax.random.randint(jax.random.PRNGKey(29), (2, 32), 0, 250)
        ids, _cache = bundle.fn(params, {"tokens": ptok})
        return np.asarray(ids)

    grid = dict(mode="tesseract", data=1, depth=2, rows=2, cols=2)
    if 8 <= ndev:
        ref_ids = prefill_ids(grid)
        got_ids = prefill_ids(dict(grid, attn_schedule="ring"))
        np.testing.assert_array_equal(got_ids, ref_ids,
                                      err_msg="prefill ring ids")
        print("  ring_attention prefill d2q2: ring ids == gather-full-KV")
    else:
        print("  ring_attention prefill: (8 devices unavailable: skipped)")
    print("PASS ring_attention")


def check_train_elastic_accum():
    """Fault -> restore -> elastic 8 -> 4 device shrink mid-run: the train
    loop consumes Replan.accum_steps, so the global batch per optimizer
    step is preserved and the loss trajectory continues the uninterrupted
    8-device run under the step-keyed data stream."""
    import tempfile

    import jax
    from repro.configs.base import RunConfig, ShapeSpec
    from repro.core.api import ParallelContext
    from repro.core.mesh import logical_mesh
    from repro.models.registry import build_model, get_reduced
    from repro.runtime.elastic import replan
    from repro.runtime.train_loop import train

    run = RunConfig(param_dtype="float32", compute_dtype="float32",
                    loss_chunk=8, q_chunk=8, kv_chunk=8, lr=1e-3)
    shape = ShapeSpec("t", seq_len=16, global_batch=16, kind="train")
    arch = get_reduced("yi-6b")
    ctx8 = ParallelContext(mode="tesseract", data=8, depth=1, rows=1, cols=1)
    mesh8 = logical_mesh(ctx8, jax.devices()[:8])
    model8 = build_model(arch.model, ctx8, run)

    with tempfile.TemporaryDirectory() as dref, \
            tempfile.TemporaryDirectory() as dft:
        ref = train(model8, mesh8, shape, steps=8, ckpt_dir=dref,
                    ckpt_every=100, log_every=0)

        fired = set()

        def fault(step):
            if step == 5 and step not in fired:
                fired.add(step)
                raise RuntimeError("injected: half the fleet lost")

        try:
            train(model8, mesh8, shape, steps=8, ckpt_dir=dft, ckpt_every=2,
                  log_every=0, fault_hook=fault, max_restarts=0)
            raise AssertionError("fault did not surface")
        except RuntimeError:
            pass

        # driver-level elastic re-plan onto the surviving 4 devices
        rp = replan(4, ctx8, global_batch=shape.global_batch)
        assert rp.ctx.data == 4 and rp.accum_steps == 2 and rp.n_idle == 0, rp
        model4 = build_model(arch.model, rp.ctx, run)
        mesh4 = logical_mesh(rp.ctx, jax.devices()[:rp.n_used])
        res = train(model4, mesh4, shape, steps=8, ckpt_dir=dft,
                    ckpt_every=100, log_every=0,
                    accum_steps=rp.accum_steps)
        # restored from the step-3 checkpoint -> steps 4..7 remain
        assert res.last_step == 7 and len(res.losses) == 4, \
            (res.last_step, len(res.losses))
        np.testing.assert_allclose(res.losses, ref.losses[4:],
                                   rtol=1e-5, atol=1e-6,
                                   err_msg="post-replan trajectory diverged")
    print(f"  elastic train: 8 -> {rp.n_used} devices, accum_steps="
          f"{rp.accum_steps} consumed, trajectory preserved {res.losses}")
    print("PASS train_elastic_accum")


def check_chaos_train():
    """The ISSUE-6 acceptance schedule on the train side: one NaN step, one
    corrupted checkpoint (the newest at crash time), then device loss with
    an 8 -> 4 elastic replan — all from one seeded FaultPlan.  The run must
    recover, rejoin the fault-free 8-device loss trajectory, and the whole
    schedule must replay identically from the same seed."""
    import tempfile

    import jax
    from repro.configs.base import RunConfig, ShapeSpec
    from repro.core.api import ParallelContext
    from repro.core.mesh import logical_mesh
    from repro.models.registry import build_model, get_reduced
    from repro.runtime.elastic import replan
    from repro.runtime.faults import (DeviceLostError, FaultInjector,
                                      FaultPlan)
    from repro.runtime.train_loop import train

    run = RunConfig(param_dtype="float32", compute_dtype="float32",
                    loss_chunk=8, q_chunk=8, kv_chunk=8, lr=1e-3)
    shape = ShapeSpec("t", seq_len=16, global_batch=16, kind="train")
    arch = get_reduced("yi-6b")
    ctx8 = ParallelContext(mode="tesseract", data=8, depth=1, rows=1, cols=1)
    mesh8 = logical_mesh(ctx8, jax.devices()[:8])
    model8 = build_model(arch.model, ctx8, run)

    ref = train(model8, mesh8, shape, steps=10, log_every=0)

    # NaN at 2; corrupt the step-5 checkpoint (newest when the device dies
    # at 6, so recovery MUST fall back to step 3); lose half the fleet at 6
    plan = FaultPlan.parse(
        "train.grads@2:nan;ckpt.write@5:corrupt(0,bit_flip);"
        "train.step@6:device_loss(4)", seed=13)

    def chaos_run():
        inj = FaultInjector(plan)
        with tempfile.TemporaryDirectory() as d:
            try:
                train(model8, mesh8, shape, steps=10, ckpt_dir=d,
                      ckpt_every=2, log_every=0, injector=inj)
                raise AssertionError("device loss did not surface")
            except DeviceLostError as e:
                partial = e.partial_result
                rp = replan(e.n_surviving, ctx8,
                            global_batch=shape.global_batch)
            assert rp.ctx.data == 4 and rp.accum_steps == 2, rp
            model4 = build_model(arch.model, rp.ctx, run)
            mesh4 = logical_mesh(rp.ctx, jax.devices()[:rp.n_used])
            # same injector: spent faults stay spent across the replan
            res = train(model4, mesh4, shape, steps=10, ckpt_dir=d,
                        ckpt_every=100, log_every=0,
                        accum_steps=rp.accum_steps, injector=inj)
            return partial, res, list(inj.fired)

    partial, res, fired = chaos_run()
    assert partial.nan_skips == 1, partial.nan_skips
    assert res.ckpt_fallbacks == 1, res.ckpt_fallbacks   # corrupt step-5
    # restored from step 3 -> the 4-device run covers steps 4..9
    assert res.last_step == 9 and len(res.losses) == 6, \
        (res.last_step, len(res.losses))
    np.testing.assert_allclose(res.losses, ref.losses[4:],
                               rtol=1e-5, atol=1e-6,
                               err_msg="post-recovery trajectory diverged")
    assert fired == [("train.grads", 2, "nan"), ("ckpt.write", 5, "corrupt"),
                     ("train.step", 6, "device_loss")], fired

    partial2, res2, fired2 = chaos_run()
    assert fired2 == fired, "fault schedule did not replay identically"
    np.testing.assert_array_equal(
        np.array(res2.losses), np.array(res.losses),
        err_msg="replay from the same seed diverged")
    print(f"  chaos train: NaN skip + corrupt-ckpt fallback + 8->4 replan, "
          f"trajectory rejoined {res.losses}")
    print("PASS chaos_train")


def check_chaos_serve():
    """ISSUE-6 acceptance, serve side: NaN logits in one slot, a dropped
    engine step, KV pool exhaustion and a device loss (8 -> 4 replan) from
    one seeded plan — every surviving request keeps bit-exact greedy parity
    with the fault-free run, and the schedule replays identically."""
    import jax
    from repro.runtime.faults import FaultInjector, FaultPlan
    from repro.serve import EngineConfig, InferenceEngine, SamplingParams

    rng = np.random.RandomState(7)
    lens = [5, 9, 16, 12, 7, 3, 21, 10]
    n_new = [6, 10, 4, 8, 5, 12, 3, 7]
    prompts = [rng.randint(0, 250, (l,)).tolist() for l in lens]

    _, run, ctx, mesh, model = _build(
        "yi-6b", dict(mode="tesseract", data=2, depth=1, rows=2, cols=2))
    params = model.init(jax.random.PRNGKey(0))
    cfg = EngineConfig(n_slots=8, block_size=4, num_blocks=128,
                       max_seq_len=64)

    eng = InferenceEngine(model, mesh, params, cfg)
    reqs = [eng.add_request(p, SamplingParams(max_new_tokens=n))
            for p, n in zip(prompts, n_new)]
    ref_out = eng.run()
    ref = [ref_out[r.rid] for r in reqs]

    plan = FaultPlan.parse(
        "serve.logits@2:nan(3);serve.step@4:drop_step;"
        "serve.step@5:pool_exhaust(2);serve.step@8:device_loss(4)", seed=17)

    def chaos_run():
        e = InferenceEngine(model, mesh, params, cfg,
                            injector=FaultInjector(plan))
        rs = [e.add_request(p, SamplingParams(max_new_tokens=n))
              for p, n in zip(prompts, n_new)]
        out = e.run()
        return [out[r.rid] for r in rs], e.stats, list(e.injector.fired)

    got, stats, fired = chaos_run()
    assert stats.nan_quarantines >= 1, "NaN guard never fired"
    assert stats.dropped_steps == 1, stats.dropped_steps
    assert stats.pool_exhaust_events == 1, stats.pool_exhaust_events
    assert stats.failed == 0, f"{stats.failed} requests failed (expected " \
                              f"quarantine-and-replay, not shedding)"
    assert got == ref, f"survivor parity broke under chaos\n{got}\n{ref}"

    got2, stats2, fired2 = chaos_run()
    assert fired2 == fired, "fault schedule did not replay identically"
    assert got2 == got, "replay from the same seed diverged"
    print(f"  chaos serve: {stats.nan_quarantines} quarantines, "
          f"{stats.preemptions} preemptions, 8->4 replan — "
          f"bit-exact parity + identical replay")
    print("PASS chaos_serve")


def check_prefix_cache():
    """ISSUE-7 acceptance: overlapping-prefix workloads keep bit-identical
    greedy tokens cache-on vs cache-off — on q=1 and q=2 grids, under
    cache-eviction pressure with forced ``serve.prefix`` faults (eviction
    must respect refcounts: shared pages survive), and across an elastic
    8 -> 4 replan — while measuring a hit rate > 0 and COW splits."""
    import jax
    from repro.runtime.faults import FaultInjector, FaultPlan
    from repro.serve import EngineConfig, InferenceEngine, SamplingParams

    rng = np.random.RandomState(21)
    sys_prompt = rng.randint(0, 250, (10,)).tolist()
    # shared system prompt + per-request suffixes: block_size 4 puts the
    # shared/unique boundary 2 tokens into block 2 (a COW donor on every
    # later hit); an identical twin exercises the whole-prompt-hit clamp
    prompts = [sys_prompt + rng.randint(0, 250, (sl,)).tolist()
               for sl in (5, 9, 2, 13, 5, 7)]
    prompts.append(list(prompts[0]))                        # identical twin
    prompts.append(prompts[1][:12] + rng.randint(0, 250, (6,)).tolist())
    n_new = [6, 4, 8, 5, 7, 3, 6, 5]

    def run_eng(ctx_kw, *, cache_on, num_blocks, n_slots, plan=None,
                replan_to=0):
        _, run, ctx, mesh, model = _build("yi-6b", ctx_kw)
        params = model.init(jax.random.PRNGKey(0))
        cfg = EngineConfig(n_slots=n_slots, block_size=4,
                           num_blocks=num_blocks, max_seq_len=64,
                           prefix_cache=cache_on)
        inj = FaultInjector(plan) if plan is not None else None
        e = InferenceEngine(model, mesh, params, cfg, injector=inj)
        rs = [e.add_request(p, SamplingParams(max_new_tokens=n))
              for p, n in zip(prompts, n_new)]
        if replan_to:
            e.step()
            e.step()
            e.replan_to(replan_to)
        out = e.run()
        return [out[r.rid] for r in rs], e.stats

    grids = ((1, dict(mode="tesseract", data=1, depth=1, rows=1, cols=1),
              2, 64),
             (2, dict(mode="tesseract", data=2, depth=1, rows=2, cols=2),
              4, 128))
    for q, ctx_kw, n_slots, nb in grids:
        ref, _ = run_eng(ctx_kw, cache_on=False, num_blocks=nb,
                         n_slots=n_slots)
        got, st = run_eng(ctx_kw, cache_on=True, num_blocks=nb,
                          n_slots=n_slots)
        assert got == ref, f"q={q}: cache-on diverged\n{got}\n{ref}"
        assert st.cache_hit_rate() > 0 and st.prefix_tokens_reused > 0, \
            "shared-prefix workload never hit the cache"
        assert st.cow_splits >= 1, "mid-block divergence never COW-split"
        print(f"  q={q}: parity ok, hit_rate={st.cache_hit_rate():.3f} "
              f"({st.prefix_hits}/{st.prefix_lookups} admissions, "
              f"{st.prefix_tokens_reused} tokens), cow={st.cow_splits}")

    # tiny pool -> capacity evictions, plus forced serve.prefix faults;
    # only refcount-1 leaves may be reclaimed, so parity must survive
    q1 = grids[0][1]
    ref, _ = run_eng(q1, cache_on=False, num_blocks=16, n_slots=2)
    plan = FaultPlan.parse("serve.prefix@3:evict(2);serve.prefix@6:flush",
                           seed=5)
    got, st = run_eng(q1, cache_on=True, num_blocks=16, n_slots=2,
                      plan=plan)
    assert got == ref, f"eviction/fault parity broke\n{got}\n{ref}"
    assert st.cache_evictions >= 1, "tiny pool never evicted a cache leaf"
    print(f"  eviction: parity ok under {st.cache_evictions} evictions "
          f"+ forced evict/flush faults")

    # elastic 8 -> 4 replan with the cache on (index dies with the old
    # pool; carried residents un-share into private pages)
    q2 = grids[1][1]
    ref, _ = run_eng(q2, cache_on=False, num_blocks=128, n_slots=4)
    got, st = run_eng(q2, cache_on=True, num_blocks=128, n_slots=4,
                      replan_to=4)
    assert got == ref, f"post-replan parity broke\n{got}\n{ref}"
    print("  replan: 8 -> 4 devices, cache flushed, bit-exact parity")
    print("PASS prefix_cache")


def check_shardcheck():
    """Static analyzer end-to-end on real traces (DESIGN.md §13): IR facts
    (mesh capture, scan/while multiplicity), the replication sanitizer
    catching a seeded divergence, a clean verdict on a real train step, and
    byte-exact matmul comm-model conformance."""
    import jax
    import jax.numpy as jnp
    from jax.sharding import Mesh, PartitionSpec as P

    from repro.analysis import rules
    from repro.analysis import shardcheck as sc
    from repro.analysis.collective_ir import extract_ir, replication_taints
    from repro.core.collectives import shard_map

    mesh = Mesh(np.array(jax.devices()[:4]).reshape(2, 2), ("dp", "tp"))
    sds = jax.ShapeDtypeStruct((4, 4), jnp.float32)

    # IR facts: scan multiplies by length, while by its cond bound, and the
    # shard_map records the mesh axis sizes
    def local(x):
        def body(c, _):
            return c + jax.lax.psum(x, "tp"), jax.lax.ppermute(
                c, "dp", [(0, 1), (1, 0)])
        c, ys = jax.lax.scan(body, x, None, length=3)

        def wbody(carry):
            i, v = carry
            return i + 1, v + jax.lax.psum(v, "dp")
        _, v = jax.lax.while_loop(lambda carry: carry[0] < 5, wbody,
                                  (jnp.int32(0), c))
        return v + jnp.sum(ys, axis=0)

    f = shard_map(local, mesh=mesh, in_specs=P("dp", "tp"),
                  out_specs=P("dp", "tp"))
    prog = extract_ir(jax.jit(f).trace(sds).jaxpr)
    by = prog.by_key()
    assert prog.axis_sizes == {"dp": 2, "tp": 2}, prog.axis_sizes
    assert by["psum@tp"]["count"] == 3, by          # scan length
    assert by["ppermute@dp"]["count"] == 3, by
    assert by["psum@dp"]["count"] == 5, by          # while cond bound
    print(f"  ir: mesh {prog.axis_sizes}, scan x3 + while x5 multiplicity ok")

    # replication sanitizer: axis_index flowing to a declared-replicated
    # output is a violation; an intervening psum clears it
    def leaky(x):
        return x + jax.lax.axis_index("dp").astype(x.dtype)

    def synced(x):
        leak = x + jax.lax.axis_index("dp").astype(x.dtype)
        return jax.lax.psum(leak, "dp") / 2.0

    rep = P(None, None)
    bad = jax.jit(shard_map(leaky, mesh=mesh, in_specs=rep,
                            out_specs=rep)).trace(sds).jaxpr
    good = jax.jit(shard_map(synced, mesh=mesh, in_specs=rep,
                             out_specs=rep)).trace(sds).jaxpr
    viols = replication_taints(bad, seed_inputs=False)
    assert any("dp" in v["axes"] for v in viols), viols
    assert replication_taints(good, seed_inputs=False) == [], \
        "psum-synced output flagged as divergent"
    print(f"  replication: leak caught ({len(viols)} violation), sync clean")

    # a real train step traces clean under the full rule catalog, and the
    # builder's meta promises real reductions
    jaxpr, meta, bundle, _ = sc._train_entry(data=2, rows=2, cols=2)
    prog = extract_ir(jaxpr)
    findings = rules.run_all(prog, meta, jaxpr, entry="q2_dp2")
    assert findings == [], "\n".join(map(str, findings))
    assert meta["grad_psum_axes"], meta.keys()
    assert len(meta["leaves"]) > 10, len(meta["leaves"])
    assert bundle.shardcheck_meta is meta
    got = prog.psum_axis_counts()
    for axes, want in meta["grad_psum_axes"].items():
        assert got.get(tuple(sorted(axes)), 0) >= want, (axes, want, got)
    print(f"  train q2_dp2: 0 findings over {len(prog.collectives)} "
          f"collectives, {len(meta['leaves'])} leaves")

    # comm-model conformance: traced wire bytes == summa.matmul_comm_bytes
    # exactly for every schedule x in-op variant
    findings, results = sc.matmul_conformance()
    assert findings == [], "\n".join(map(str, findings))
    for name, r in results.items():
        assert r["traced_bytes"] == r["predicted_bytes"], (name, r)
    print(f"  matmul: {len(results)} variants byte-exact vs comm model")
    print("PASS shardcheck")


CHECKS = {
    "summa_exact": check_summa_exact,
    "ring_schedule": check_ring_schedule,
    "ring_train_parity": check_ring_train_parity,
    "dense_parity": check_dense_parity,
    "inop_matches_deferred": check_inop_matches_deferred,
    "decode_parity": check_decode_parity,
    "prefill_parity": check_prefill_parity,
    "smollm_padding": check_smollm_padding,
    "moe_parity": check_moe_parity,
    "moe_decode": check_moe_decode,
    "families_parity": check_families_parity,
    "families_serve": check_families_serve,
    "zero1_parity": check_zero1_parity,
    "zero1_elastic": check_zero1_elastic,
    "moe_local_layout": check_moe_local_layout,
    "serve_engine": check_serve_engine,
    "engine_elastic": check_engine_elastic,
    "attn_impl_parity": check_attn_impl_parity,
    "ring_attention": check_ring_attention,
    "pipeline_parity": check_pipeline_parity,
    "train_elastic_accum": check_train_elastic_accum,
    "chaos_train": check_chaos_train,
    "chaos_serve": check_chaos_serve,
    "prefix_cache": check_prefix_cache,
    "spec_decode": check_spec_decode,
    "shardcheck": check_shardcheck,
}


def main():
    name = sys.argv[1]
    if name == "all":
        for n, fn in CHECKS.items():
            fn()
    else:
        CHECKS[name]()


if __name__ == "__main__":
    main()
