"""Measured multi-device benchmark bodies, run in a subprocess with fake
devices (like mdchecks).  Prints JSON to stdout.

    python -m repro.testing.benchruns accuracy_equiv
    python -m repro.testing.benchruns strong_scaling
"""
from __future__ import annotations

import json
import os
import sys
import time

if __name__ == "__main__" and "XLA_FLAGS" not in os.environ:
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"


def _train_curve(variant, steps=20, lr=3e-3, seq_len=32):
    import jax
    import jax.numpy as jnp
    from repro.configs.base import RunConfig, ShapeSpec
    from repro.core.api import ParallelContext
    from repro.core.mesh import logical_mesh
    from repro.models.registry import build_model, get_reduced
    from repro.optim.adamw import adamw_init
    from repro.runtime.steps import build_train_step

    run = RunConfig(param_dtype="float32", compute_dtype="float32",
                    loss_chunk=32, q_chunk=16, kv_chunk=16, lr=lr)
    ctx = ParallelContext(**variant)
    mesh = logical_mesh(ctx, jax.devices()[: ctx.data * ctx.seq * ctx.tp])
    arch = get_reduced("yi-6b")
    model = build_model(arch.model, ctx, run)
    shape = ShapeSpec("t", seq_len=seq_len, global_batch=8, kind="train")
    bundle = build_train_step(model, mesh, shape)
    params = model.init(jax.random.PRNGKey(0))
    opt = adamw_init(params)
    losses, times = [], []
    p, o = params, opt
    for s in range(steps):
        tok = jax.random.randint(jax.random.PRNGKey(100 + s), (8, seq_len),
                                 0, 250)
        batch = {"tokens": tok, "labels": jnp.roll(tok, -1, 1)}
        t0 = time.perf_counter()
        p, o, m = bundle.fn(p, o, batch)
        losses.append(float(m["loss"]))   # sync
        times.append(time.perf_counter() - t0)
    return losses, times


def accuracy_equiv():
    """Fig. 7 analogue: identical training curves on 1 device vs Tesseract
    [2,2,1] vs [2,2,2] — 'Tesseract does not introduce any approximations'."""
    out = {}
    for name, variant in [
        ("single", dict(mode="tesseract", data=1, depth=1, rows=1, cols=1)),
        ("tess_221", dict(mode="tesseract", data=1, depth=1, rows=2, cols=2)),
        ("tess_222", dict(mode="tesseract", data=1, depth=2, rows=2, cols=2)),
    ]:
        losses, times = _train_curve(variant, steps=20)
        out[name] = {"losses": losses,
                     "us_per_step": sum(times[2:]) / len(times[2:]) * 1e6}
    print(json.dumps(out))


def strong_scaling():
    """Measured step times for the reduced model across layouts (8 fake CPU
    devices; wall-clock is indicative only — the roofline model is the
    primary Table-1 artifact)."""
    out = {}
    for name, variant in [
        ("megatron_8", dict(mode="megatron1d", data=1, depth=1, rows=1, cols=8)),
        ("summa2d_22_dp2", dict(mode="summa2d", data=2, depth=1, rows=2, cols=2)),
        ("tesseract_222", dict(mode="tesseract", data=1, depth=2, rows=2, cols=2)),
    ]:
        losses, times = _train_curve(variant, steps=8)
        out[name] = {"us_per_step": sum(times[2:]) / len(times[2:]) * 1e6,
                     "final_loss": losses[-1]}
    print(json.dumps(out))


def matmul_schedules():
    """fused vs ring Tesseract matmul (fwd + both grads) on a [2, 2, 2]
    grid of 8 fake CPU devices.  Host wall-clock is indicative only (no
    async collective-permute on CPU); the analytic overlap model in
    benchmarks/comm_model.py is the perf artifact."""
    import jax
    import jax.numpy as jnp
    from jax import lax
    from jax.sharding import PartitionSpec as P
    from repro.core.api import ParallelContext
    from repro.core.collectives import grad_sync, shard_map
    from repro.core.mesh import logical_mesh
    from repro.core.summa import tesseract_matmul

    B, E, F, G = 2, 512, 512, 512
    A = jax.random.normal(jax.random.PRNGKey(0), (B, E, F), jnp.float32)
    W = jax.random.normal(jax.random.PRNGKey(1), (F, G), jnp.float32)
    S = jax.random.normal(jax.random.PRNGKey(2), (B, E, G), jnp.float32)
    out = {}
    for sched in ("fused", "ring"):
        ctx = ParallelContext(mode="tesseract", data=1, depth=2, rows=2,
                              cols=2, reduce_dgrad_in_op=False,
                              matmul_schedule=sched)
        mesh = logical_mesh(ctx, jax.devices()[:8])
        tok = P(None, ("data", "depth", "row"), "col")

        def local(a, w, s):
            def loss(a_, w_):
                w_ = grad_sync(w_, (ctx.axis_data, ctx.axis_depth))
                return jnp.sum(tesseract_matmul(ctx, a_, w_) * s)
            l, grads = jax.value_and_grad(loss, argnums=(0, 1))(a, w)
            return lax.psum(l, ("data", "depth", "row", "col")), grads

        fn = jax.jit(shard_map(local, mesh=mesh,
                               in_specs=(tok, P("row", "col"), tok),
                               out_specs=(P(), (tok, P("row", "col")))))
        l, _ = fn(A, W, S)
        float(l)  # compile + sync
        times = []
        for _ in range(10):
            t0 = time.perf_counter()
            l, g = fn(A, W, S)
            jax.block_until_ready(g)
            times.append(time.perf_counter() - t0)
        out[sched] = {"us_per_call": sum(times[2:]) / len(times[2:]) * 1e6,
                      "loss": float(l)}
    out["losses_match"] = abs(out["fused"]["loss"] - out["ring"]["loss"]) \
        <= 1e-3 * abs(out["fused"]["loss"])
    print(json.dumps(out))


def pipeline_throughput():
    """1F1B [pipe=2 x tesseract q=2] vs the non-PP [q=2 x dp=2] baseline on
    the same 8 fake CPU devices: tokens/s per optimizer step plus the
    measured/predicted schedule bubble.  CPU wall-clock is indicative only
    (the 1F1B backward units pay full-stage rematerialization); the bubble
    numbers are the schedule artifact and must sit within 10% of the
    analytic (S-1)/(M+S-1)."""
    import jax
    import jax.numpy as jnp
    from repro.configs.base import RunConfig, ShapeSpec
    from repro.core.api import ParallelContext
    from repro.core.mesh import logical_mesh, pipeline_mesh
    from repro.models.registry import build_model, get_reduced
    from repro.optim.adamw import adamw_init
    from repro.runtime.pipeline import bubble_fraction
    from repro.runtime.steps import build_train_step

    B, S = 16, 32
    arch = get_reduced("yi-6b")
    shape = ShapeSpec("t", seq_len=S, global_batch=B, kind="train")

    def measure(ctx, mesh, M=0, steps=8):
        run = RunConfig(param_dtype="float32", compute_dtype="float32",
                        loss_chunk=32, q_chunk=16, kv_chunk=16, lr=1e-3,
                        pipeline_microbatches=M)
        model = build_model(arch.model, ctx, run)
        bundle = build_train_step(model, mesh, shape)
        p = jax.device_put(model.init(jax.random.PRNGKey(0)),
                           bundle.in_shardings[0])
        o = jax.device_put(adamw_init(p), bundle.in_shardings[1])
        losses, times = [], []
        for s in range(steps):
            tok = jax.random.randint(jax.random.PRNGKey(100 + s), (B, S),
                                     0, 250)
            batch = {"tokens": tok, "labels": jnp.roll(tok, -1, 1)}
            t0 = time.perf_counter()
            p, o, m = bundle.fn(p, o, batch)
            losses.append(float(m["loss"]))  # sync
            times.append(time.perf_counter() - t0)
        dt = sum(times[2:]) / len(times[2:])
        return {"us_per_step": dt * 1e6, "tokens_per_s": B * S / dt,
                "final_loss": losses[-1]}, bundle, losses

    ctx_pp = ParallelContext(mode="tesseract", data=1, depth=1, rows=2,
                             cols=2)
    pp, bundle_pp, losses_pp = measure(
        ctx_pp, pipeline_mesh(ctx_pp, 2, jax.devices()[:8]), M=4)
    info = bundle_pp.pipe_info
    pp.update(n_stages=info["n_stages"], n_micro=info["n_micro"],
              bubble_measured=info["measured_bubble"],
              bubble_predicted=info["predicted_bubble"])
    assert pp["bubble_measured"] <= pp["bubble_predicted"] + 0.10, pp

    ctx_base = ParallelContext(mode="tesseract", data=2, depth=1, rows=2,
                               cols=2)
    base, _, losses_base = measure(
        ctx_base, logical_mesh(ctx_base, jax.devices()[:8]))
    # both layouts train the same model on the same step-keyed batches
    dev = max(abs(a - b) for a, b in zip(losses_pp, losses_base))
    out = {"pipeline_q2_pipe2": pp, "baseline_q2_dp2": base,
           "bubble_extra": {
               f"M{m}_S{s}": bubble_fraction(m, s)
               for m, s in [(4, 2), (8, 2), (16, 2), (8, 4), (32, 4)]},
           "max_loss_dev_vs_baseline": dev}
    assert dev < 5e-3, out
    print(json.dumps(out))


def zero1_memory():
    """ZeRO-1 vs replicated optimizer state on [data=4, q=1] and
    [data=2, d=2, q=1] grids: measured per-device optimizer-state bytes
    (from the bundles' real NamedShardings), step wall-clock, loss parity,
    and the Eq. 8 + ZeRO memory-model prediction."""
    import jax
    import jax.numpy as jnp
    from repro.configs.base import RunConfig, ShapeSpec
    from repro.core.api import ParallelContext
    from repro.core.mesh import logical_mesh
    from repro.models.registry import build_model, get_reduced
    from repro.optim.adamw import adamw_init
    from repro.roofline.analysis import optimizer_state_bytes
    from repro.runtime.steps import build_train_step

    B, S = 8, 32
    arch = get_reduced("yi-6b")
    shape = ShapeSpec("t", seq_len=S, global_batch=B, kind="train")

    from repro.testing.mdchecks import _opt_bytes_per_device as opt_bytes

    def measure(variant, zero, steps=8):
        run = RunConfig(param_dtype="float32", compute_dtype="float32",
                        loss_chunk=32, q_chunk=16, kv_chunk=16, lr=1e-3,
                        zero1=zero)
        ctx = ParallelContext(**variant)
        mesh = logical_mesh(ctx, jax.devices()[:ctx.data * ctx.tp])
        model = build_model(arch.model, ctx, run)
        bundle = build_train_step(model, mesh, shape)
        p = model.init(jax.random.PRNGKey(0))
        if zero:
            from repro.optim.zero import zero_opt_init
            o = zero_opt_init(bundle)
        else:
            o = adamw_init(p)
        losses, times = [], []
        for s in range(steps):
            tok = jax.random.randint(jax.random.PRNGKey(100 + s), (B, S),
                                     0, 250)
            batch = {"tokens": tok, "labels": jnp.roll(tok, -1, 1)}
            t0 = time.perf_counter()
            p, o, m = bundle.fn(p, o, batch)
            losses.append(float(m["loss"]))  # sync
            times.append(time.perf_counter() - t0)
        return {"us_per_step": sum(times[2:]) / len(times[2:]) * 1e6,
                "opt_state_bytes_per_device": opt_bytes(bundle),
                "losses": losses}

    n_params = arch.model.param_count()
    out = {}
    for name, variant in [
            ("dp4", dict(mode="tesseract", data=4, depth=1, rows=1, cols=1)),
            ("dp2_d2", dict(mode="tesseract", data=2, depth=2, rows=1,
                            cols=1))]:
        base = measure(variant, zero=False)
        z1 = measure(variant, zero=True)
        ratio = (base["opt_state_bytes_per_device"]
                 / z1["opt_state_bytes_per_device"])
        dev = max(abs(a - b) for a, b in zip(base["losses"], z1["losses"]))
        pred_base = optimizer_state_bytes(
            n_params, tp=variant["depth"] * variant["rows"]
            * variant["cols"], data=variant["data"],
            depth=variant["depth"], zero_stage=0, master=False)
        pred_z1 = optimizer_state_bytes(
            n_params, tp=variant["depth"] * variant["rows"]
            * variant["cols"], data=variant["data"],
            depth=variant["depth"], zero_stage=1, master=False)
        out[name] = {
            "replicated": base, "zero1": z1,
            "measured_ratio": ratio,
            "model_pred_ratio": pred_base / pred_z1,
            "model_pred_bytes": {"replicated": pred_base, "zero1": pred_z1},
            "max_loss_dev": dev,
            "losses_match": dev < 1e-5,
        }
        assert out[name]["losses_match"], (name, base["losses"],
                                           z1["losses"])
    # dp=4 must shrink ~4x (flat-index padding costs a few KiB)
    assert out["dp4"]["measured_ratio"] > 3.2, out["dp4"]
    print(json.dumps(out))


def attention():
    """BENCH_attention.json body (DESIGN.md §10):

    (a) train-step wall clock + loss/grad-norm parity, attn_impl jnp vs
        pallas, q in {1, 2} — parity ASSERTED to fp32 tolerance; the
        interpret-mode wall clock is indicative only;
    (b) paged decode kernel vs the gather path: modeled v5e decode tok/s
        from the HBM-traffic roofline (kernel must win — the gather path
        moves 3x the full pool per step, the kernel only the live pages)
        plus measured CPU step times with greedy-argmax parity asserted
        (indicative: the interpreter re-copies full operands per grid
        step, so kernel wall clock does NOT win on this container);
    (c) flash bwd vs jax.vjp(blockwise_attention) max gradient error,
        asserted to fp32 tolerance;
    (d) the (bq, bk) tile autotuner sweep (best tiles recorded).
    """
    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro.kernels import autotune
    from repro.kernels.ops import flash_attention_op
    from repro.models.common import blockwise_attention
    from repro.roofline.analysis import paged_decode_traffic

    out = {}

    # ---- (a) train-step parity + wall clock ----
    train = {}
    for name, variant in [
            ("q1", dict(mode="tesseract", data=1, depth=1, rows=1, cols=1)),
            ("q2_d2", dict(mode="tesseract", data=1, depth=2, rows=2,
                           cols=2))]:
        cells = {}
        for impl in ("jnp", "pallas"):
            losses, times = _train_curve(dict(variant, attn_impl=impl),
                                         steps=6)
            cells[impl] = {"us_per_step": sum(times[2:]) / len(times[2:])
                           * 1e6, "losses": losses}
        dev = max(abs(a - b) for a, b in zip(cells["jnp"]["losses"],
                                             cells["pallas"]["losses"]))
        assert dev < 2e-5, (name, cells)
        cells["max_loss_dev"] = dev
        train[name] = cells
        print(f"  train {name}: pallas==jnp dev={dev:.1e}", file=sys.stderr)
    out["train"] = train

    # ---- (b) paged decode: modeled target tok/s + measured CPU steps ----
    model_big = paged_decode_traffic(64, 8, 128, pool_positions=32768,
                                     live_positions=2048, block_size=64)
    assert model_big["kernel_wins"], model_big

    import time as _t
    from repro.configs.base import RunConfig
    from repro.core.api import ParallelContext
    from repro.core.mesh import logical_mesh
    from repro.models.registry import build_model, get_reduced
    from repro.runtime.steps import build_paged_decode_step

    n_slots, bs, nb_slot = 8, 8, 8
    num_blocks = n_slots * nb_slot + 8

    def measure_decode(impl, steps=8):
        run = RunConfig(param_dtype="float32", compute_dtype="float32",
                        loss_chunk=8, q_chunk=8, kv_chunk=8)
        ctx = ParallelContext(mode="tesseract", data=1, depth=1, rows=1,
                              cols=1, attn_impl=impl)
        mesh = logical_mesh(ctx, jax.devices()[:1])
        model = build_model(get_reduced("yi-6b").model, ctx, run)
        params = model.init(jax.random.PRNGKey(0))
        pdec = build_paged_decode_step(model, mesh, n_slots, num_blocks, bs,
                                       nb_slot)
        pool_sds, _ = model.paged_cache_abstract(num_blocks, bs, pdec.plan)
        pool = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), pool_sds)
        tables = jnp.asarray(np.arange(1, 1 + n_slots * nb_slot,
                                       dtype=np.int32)
                             .reshape(n_slots, nb_slot))
        pos = jnp.full((n_slots,), 40, jnp.int32)
        ids = jnp.ones((n_slots, 1), jnp.int32)
        logits, pool = pdec.fn(params, pool, tables, pos, ids)  # compile
        jax.block_until_ready(logits)
        times = []
        for _ in range(steps):
            t0 = _t.perf_counter()
            logits, pool = pdec.fn(params, pool, tables, pos, ids)
            jax.block_until_ready(logits)
            times.append(_t.perf_counter() - t0)
        dt = sum(times[2:]) / len(times[2:])
        return dt, np.argmax(np.asarray(logits), -1)

    tj, aj = measure_decode("jnp")
    tp, ap = measure_decode("pallas")
    assert (aj == ap).all(), "paged kernel argmax diverged from gather path"
    out["paged_decode"] = {
        "modeled_v5e": {**model_big,
                        "shape": {"n_slots": 64, "Hkv": 8, "D": 128,
                                  "pool_positions": 32768,
                                  "live_positions": 2048, "block_size": 64}},
        "measured_cpu_interpret": {
            "gather_tok_s": n_slots / tj, "kernel_tok_s": n_slots / tp,
            "gather_us_per_step": tj * 1e6, "kernel_us_per_step": tp * 1e6,
            "argmax_parity": True,
            "note": "CPU interpreter re-copies full operands per grid "
                    "step; target-relevant comparison is modeled_v5e"},
        "kernel_wins": bool(model_big["kernel_wins"]),
    }

    # ---- (c) flash bwd vs jax.vjp(blockwise_attention) ----
    key = jax.random.PRNGKey(0)
    B, Hq, Hkv, T, D = 2, 4, 2, 128, 32
    q = jax.random.normal(key, (B, Hq, T, D), jnp.float32)
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, Hkv, T, D),
                          jnp.float32)
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, Hkv, T, D),
                          jnp.float32)
    ct = jax.random.normal(jax.random.fold_in(key, 3), (B, Hq, T, D),
                           jnp.float32)

    def oracle(a, b, c, window):
        o = blockwise_attention(a.transpose(0, 2, 1, 3),
                                b.transpose(0, 2, 1, 3),
                                c.transpose(0, 2, 1, 3),
                                q_pos=jnp.arange(T), kv_pos=jnp.arange(T),
                                causal=True, local_window=window,
                                q_chunk=32, kv_chunk=32)
        return o.transpose(0, 2, 1, 3)

    bwd = {}
    for window in (0, 24):
        _, vjp = jax.vjp(lambda a, b, c: flash_attention_op(
            a, b, c, causal=True, local_window=window, bq=32, bk=32),
            q, k, v)
        _, vjp_ref = jax.vjp(lambda a, b, c: oracle(a, b, c, window), q, k, v)
        errs = {}
        for nm, g, w in zip(("dq", "dk", "dv"), vjp(ct), vjp_ref(ct)):
            errs[nm] = float(np.max(np.abs(np.asarray(g) - np.asarray(w))))
            assert errs[nm] < 5e-5, (window, nm, errs)
        bwd[f"window{window}"] = errs
    out["flash_bwd_vs_jax_vjp"] = {**bwd, "tolerance": 5e-5,
                                   "matches_fp32": True}

    # ---- (d) tile autotuner sweep ----
    sweeps = [autotune.autotune_flash(1, 2, 256, 256, 64, causal=True,
                                      iters=1,
                                      candidates=((128, 128), (256, 256))),
              autotune.autotune_flash(1, 2, 128, 128, 32, causal=True,
                                      iters=1,
                                      candidates=((64, 64), (128, 128)))]
    out["autotuned_tiles"] = sweeps
    print(json.dumps(out))


def longctx():
    """BENCH_longctx.json body (DESIGN.md §15, ring/striped attention):

    (a) train parity + wall clock: striped ring attention at seq in {2, 4}
        vs the single-device flash baseline on the same step-keyed batches
        — fp32 loss parity ASSERTED; CPU wall clock indicative only;
    (b) seq-axis wire conformance: the traced train step's seq-axis
        ppermute count and wire bytes vs roofline.ring_attention_traffic,
        asserted EXACT (byte-for-byte) on q in {1, 2} grids;
    (c) iso-memory context scaling: measured per-device XLA buffer
        assignment (compiled memory_analysis) while the global context
        grows with the seq axis at fixed per-device token count — the
        >= 2x-context-at-iso-memory artifact;
    (d) modeled v5e long-context cells (128k tokens): ring exposed comm
        vs per-step flash compute from the same traffic model;
    (e) the ring-step flash-tile autotune sweep (kernels/autotune.
        autotune_ring_steps) that fills the committed tile cache.
    """
    import jax
    import jax.numpy as jnp
    from repro.analysis.collective_ir import extract_ir
    from repro.analysis.shardcheck import SEQ, _train_entry
    from repro.core.ring_attention import ring_ppermute_counts
    from repro.kernels import autotune
    from repro.roofline.analysis import ring_attention_traffic

    out = {}

    # ---- (a) striped-ring training parity vs single device ----
    train = {}
    T = 64
    ref_losses, ref_times = _train_curve(
        dict(mode="tesseract", data=1, depth=1, rows=1, cols=1),
        steps=6, seq_len=T)
    train["single_T64"] = {
        "losses": ref_losses,
        "us_per_step": sum(ref_times[2:]) / len(ref_times[2:]) * 1e6}
    for name, variant in [
            ("striped_seq2_T64", dict(mode="tesseract", seq=2,
                                      attn_schedule="striped")),
            ("striped_seq4_T64", dict(mode="tesseract", seq=4,
                                      attn_schedule="striped"))]:
        losses, times = _train_curve(variant, steps=6, seq_len=T)
        dev = max(abs(a - b) for a, b in zip(losses, ref_losses))
        assert dev < 2e-5, (name, losses, ref_losses)
        train[name] = {"losses": losses, "max_loss_dev": dev,
                       "us_per_step": sum(times[2:]) / len(times[2:]) * 1e6}
        print(f"  train {name}: striped==local dev={dev:.1e}",
              file=sys.stderr)
    out["train"] = train

    # ---- (b) traced seq-axis ppermutes byte-exact vs the traffic model ----
    conf = {}
    for name, kw in [("q1_seq2", dict(seq=2, attn_schedule="striped")),
                     ("q2_seq2", dict(rows=2, cols=2, seq=2,
                                      attn_schedule="striped"))]:
        jaxpr, _, _, info = _train_entry(**kw)
        ctx, cfg = info["ctx"], info["model"].cfg
        prog = extract_ir(jaxpr)
        seq_pp = [c for c in prog.collectives
                  if c.kind == "ppermute" and c.axes == (ctx.axis_seq,)]
        got_n = sum(c.mult for c in seq_pp)
        got_b = int(round(sum(c.total_wire_bytes for c in seq_pp)))
        # prediction from the per-device attention slice the ring streams
        traffic = ring_attention_traffic(
            8 // (ctx.data * ctx.depth * ctx.rows),          # local batch
            cfg.num_heads // ctx.cols,
            cfg.num_kv_heads // ctx.cols,                    # kv_shard grids
            SEQ, cfg.d_model // cfg.num_heads, seq=ctx.seq,
            num_layers=cfg.num_layers, compute_itemsize=4,   # fp32 compute
            train=True, remat_replay=True)
        exp_n = cfg.num_layers * ring_ppermute_counts(
            ctx.seq, train=True, remat_replay=True)["total"]
        assert (got_n, got_b) == (exp_n, traffic["wire_bytes"]), \
            (name, got_n, got_b, exp_n, traffic["wire_bytes"])
        conf[name] = {"traced_ppermutes": got_n, "traced_wire_bytes": got_b,
                      "model_wire_bytes": traffic["wire_bytes"],
                      "byte_exact": True}
        print(f"  wire {name}: {got_n} ppermutes {got_b}B == model",
              file=sys.stderr)
    out["wire_conformance"] = conf

    # ---- (c) iso-memory: context grows with seq, per-device temp flat ----
    from repro.configs.base import RunConfig, ShapeSpec
    from repro.core.api import ParallelContext
    from repro.core.mesh import logical_mesh
    from repro.models.registry import build_model, get_reduced
    from repro.runtime.steps import build_train_step

    def temp_bytes(seq, T):
        run = RunConfig(param_dtype="float32", compute_dtype="float32",
                        loss_chunk=32, q_chunk=16, kv_chunk=16)
        ctx = ParallelContext(mode="tesseract", seq=seq,
                              attn_schedule="striped" if seq > 1
                              else "local")
        mesh = logical_mesh(ctx, jax.devices()[:seq])
        model = build_model(get_reduced("yi-6b").model, ctx, run)
        bundle = build_train_step(model, mesh,
                                  ShapeSpec("t", T, 8, "train"))
        ma = bundle.fn.lower(*bundle.abstract_inputs).compile() \
            .memory_analysis()
        return {"seq": seq, "context": T,
                "temp_bytes_per_device": int(ma.temp_size_in_bytes),
                "argument_bytes_per_device": int(
                    ma.argument_size_in_bytes)}

    cells = [temp_bytes(1, 32), temp_bytes(2, 64), temp_bytes(4, 128)]
    ratio_ctx = cells[-1]["context"] / cells[0]["context"]
    ratio_mem = (cells[-1]["temp_bytes_per_device"]
                 / cells[0]["temp_bytes_per_device"])
    eff = ratio_ctx / max(1.0, ratio_mem)
    out["iso_memory"] = {
        "cells": cells, "context_ratio": ratio_ctx,
        "temp_bytes_ratio": ratio_mem,
        "context_per_memory_ratio": eff,
        "note": "per-device XLA temp buffers (measured buffer assignment); "
                "context grows with the seq axis at fixed per-device "
                "token count"}
    assert eff >= 2.0, out["iso_memory"]
    print(f"  iso-memory: {ratio_ctx:.0f}x context at {ratio_mem:.2f}x "
          f"temp bytes -> {eff:.2f}x", file=sys.stderr)

    # ---- (d) modeled v5e 128k cells (yi-6b geometry, q=4 col shard) ----
    modeled = {}
    for nm, kw in [("train_128k_seq8", dict(train=True)),
                   ("prefill_128k_seq8", dict(train=False))]:
        t = ring_attention_traffic(1, 8, 1, 131072, 128, seq=8,
                                   num_layers=32, compute_itemsize=2, **kw)
        modeled[nm] = {k: t[k] for k in
                       ("wire_bytes", "step_comm_s", "step_compute_s",
                        "exposed_comm_s_fwd_per_layer", "comm_hidden")}
    out["modeled_v5e"] = {
        **modeled,
        "shape": {"B": 1, "Hq_loc": 8, "Hkv_loc": 1, "T": 131072, "D": 128,
                  "seq": 8, "num_layers": 32, "dtype_bytes": 2}}

    # ---- (e) ring-step tile sweep ----
    out["ring_step_autotune"] = autotune.autotune_ring_steps(
        1, 2, 512, 64, seq_shards=(2, 4, 8), iters=1,
        candidates=((64, 64), (128, 128)))
    print(json.dumps(out))


def serve_throughput():
    """Continuous-batching engine vs the static-batch replay loop on a
    mixed-length workload, per batch size.  Greedy, so the two must emit
    identical tokens; the engine wins wall-clock by retiring finished slots
    in place and admitting queued requests immediately (8 fake CPU devices,
    wall-clock indicative; both paths are warmed before timing)."""
    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro.configs.base import RunConfig, ShapeSpec
    from repro.core.api import ParallelContext
    from repro.core.mesh import logical_mesh
    from repro.models.registry import build_model, get_reduced
    from repro.runtime.steps import build_decode_step
    from repro.serve import EngineConfig, InferenceEngine, SamplingParams
    from repro.serve.engine import EngineStats

    run = RunConfig(param_dtype="float32", compute_dtype="float32",
                    loss_chunk=32, q_chunk=16, kv_chunk=16)
    ctx = ParallelContext(mode="tesseract", data=2, depth=1, rows=2, cols=2)
    mesh = logical_mesh(ctx)
    model = build_model(get_reduced("yi-6b").model, ctx, run)
    params = model.init(jax.random.PRNGKey(0))

    rng = np.random.RandomState(0)
    lens = [6, 12, 24] * 4
    n_new = [4, 16, 8] * 4
    prompts = [rng.randint(0, 250, (l,)).tolist() for l in lens]
    S = 64

    def run_static(n_slots):
        """Batches of n_slots via prompt replay; a batch runs until its
        slowest member finishes (the pre-engine serving shape)."""
        dec = build_decode_step(model, mesh,
                                ShapeSpec("d", S, n_slots, "decode"))
        cache_sds, _ = model.cache_abstract(n_slots, S, dec.plan)
        out = {i: [] for i in range(len(prompts))}
        times = []
        t_start = time.perf_counter()
        for i0 in range(0, len(prompts), n_slots):
            sel = [(i0 + j) % len(prompts) for j in range(n_slots)]
            bl = [len(prompts[i]) for i in sel]
            bn = [n_new[i] for i in sel]
            cache = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                                 cache_sds)
            ids = np.array([[prompts[i][0]] for i in sel], np.int32)
            for t in range(max(l + n for l, n in zip(bl, bn)) - 1):
                t0 = time.perf_counter()
                nxt, cache = dec.fn(params, cache, jnp.asarray(ids),
                                    jnp.int32(t))
                nxt = np.asarray(nxt)
                dt = time.perf_counter() - t0
                emitted = 0
                for j, i in enumerate(sel):
                    if t + 1 < bl[j]:
                        ids[j, 0] = prompts[i][t + 1]
                    else:
                        if t + 1 - bl[j] < bn[j] and i0 + j < len(prompts):
                            out[i].append(int(nxt[j, 0]))
                            emitted += 1
                        ids[j, 0] = nxt[j, 0]
                if emitted:
                    times.extend([dt / emitted] * emitted)
        wall = time.perf_counter() - t_start
        tokens = sum(len(v) for v in out.values())
        return out, {"tokens": tokens, "wall_s": wall,
                     "tokens_per_s": tokens / wall,
                     "p50_ms": float(np.percentile(times, 50) * 1e3),
                     "p95_ms": float(np.percentile(times, 95) * 1e3)}

    out = {"workload": {"prompt_lens": lens, "new_tokens": n_new}}
    for n_slots in (4, 8):
        eng = InferenceEngine(model, mesh, params, EngineConfig(
            n_slots=n_slots, block_size=4, num_blocks=32 * n_slots,
            max_seq_len=S))
        run_static(n_slots)                      # warm the static step
        for warmed in (False, True):             # first pass compiles
            eng.stats = EngineStats()
            reqs = [eng.add_request(p, SamplingParams(max_new_tokens=n))
                    for p, n in zip(prompts, n_new)]
            eng_out = eng.run()
        static_out, static = run_static(n_slots)
        got = [eng_out[r.rid] for r in reqs]
        want = [static_out[i] for i in range(len(prompts))]
        assert got == want, "engine tokens diverged from static loop"
        lat = eng.stats.latency_percentiles()
        out[f"slots{n_slots}"] = {
            "engine": {"tokens": eng.stats.tokens,
                       "wall_s": eng.stats.wall,
                       "tokens_per_s": eng.stats.tokens_per_s(),
                       "steps": eng.stats.steps,
                       "prefills": eng.stats.prefills, **lat,
                       "ttft": eng.stats.ttft_percentiles(),
                       "itl": eng.stats.itl_percentiles()},
            "static": static,
            "engine_wins": eng.stats.tokens_per_s() > static["tokens_per_s"],
        }
    print(json.dumps(out))


def serve_prefix():
    """Shared-system-prompt workload, radix prefix cache on vs off
    (DESIGN.md §12).  Greedy tokens are asserted identical in-run; the
    cache-on engine reuses shared pages (hit rate, reused tokens and COW
    splits are deterministic counters) and prefills only the per-request
    suffix through the chunked path, which is what shrinks TTFT.  Both
    engines are warmed before the measured pass (8 fake CPU devices,
    wall-clock indicative)."""
    import jax
    import numpy as np
    from repro.configs.base import RunConfig
    from repro.core.api import ParallelContext
    from repro.core.mesh import logical_mesh
    from repro.models.registry import build_model, get_reduced
    from repro.serve import EngineConfig, InferenceEngine, SamplingParams
    from repro.serve.engine import EngineStats

    run = RunConfig(param_dtype="float32", compute_dtype="float32",
                    loss_chunk=32, q_chunk=16, kv_chunk=16)
    ctx = ParallelContext(mode="tesseract", data=2, depth=1, rows=2, cols=2)
    mesh = logical_mesh(ctx)
    model = build_model(get_reduced("yi-6b").model, ctx, run)
    params = model.init(jax.random.PRNGKey(0))

    rng = np.random.RandomState(3)
    # 26 = 6 full blocks + 2 tokens: every hit also exercises a COW split
    sys_prompt = rng.randint(0, 250, (26,)).tolist()   # shared prefix
    sfx_lens = [4, 9, 2, 12, 6, 3, 10, 5, 7, 11, 4, 8]
    prompts = [sys_prompt + rng.randint(0, 250, (l,)).tolist()
               for l in sfx_lens]
    n_new = [6, 4, 8, 5, 7, 3, 6, 5, 4, 8, 5, 6]

    def measure(cache_on):
        eng = InferenceEngine(model, mesh, params, EngineConfig(
            n_slots=4, block_size=4, num_blocks=128, max_seq_len=64,
            prefix_cache=cache_on))
        for warmed in (False, True):             # first pass compiles
            eng.stats = EngineStats()
            if cache_on:
                eng.prefix.flush()               # measured pass starts cold
            reqs = [eng.add_request(p, SamplingParams(max_new_tokens=n))
                    for p, n in zip(prompts, n_new)]
            out = eng.run()
        s = eng.stats
        cell = {"tokens": s.tokens, "wall_s": s.wall,
                "tokens_per_s": s.tokens_per_s(),
                "steps": s.steps,
                "ttft": s.ttft_percentiles(), "itl": s.itl_percentiles()}
        if cache_on:
            cell.update({"cache_hit_rate": s.cache_hit_rate(),
                         "prefix_tokens_reused": s.prefix_tokens_reused,
                         "prefix_tokens_total": s.prefix_tokens_total,
                         "cow_splits": s.cow_splits,
                         "cache_evictions": s.cache_evictions,
                         "prefill_chunks": s.prefill_chunks})
        return [out[r.rid] for r in reqs], cell

    ref, off = measure(False)
    got, on = measure(True)
    assert got == ref, "prefix cache broke greedy token parity"
    assert on["cache_hit_rate"] > 0, "shared prompts never hit the cache"
    off_p95 = off["ttft"]["p95_ms"]
    on_p95 = on["ttft"]["p95_ms"]
    out = {"prefix": {
        "workload": {"shared_prefix_len": len(sys_prompt),
                     "suffix_lens": sfx_lens, "new_tokens": n_new},
        "off": off, "on": on,
        "ttft_p95_reduction": (off_p95 - on_p95) / off_p95 if off_p95
        else 0.0,
    }}
    print(json.dumps(out))


def serve_spec():
    """Speculative decoding on the serving engine (DESIGN.md §14).

    Three cells on one greedy long-generation workload, all asserted
    token-identical in-run: plain paged decode, the model-free n-gram
    proposer (realistic acceptance), and an ideal draft (draft == target,
    acceptance 1.0 by construction — the deterministic upper bound).  The
    per-slot decode-step speedup ``speedup_steps`` is an exact counter, not
    wall-clock: every verify round costs one weight-stream like a decode
    step on a memory-bound target, so committed-tokens-per-round IS the
    decode tok/s factor; ``model_*`` maps the recorded acceptance through
    roofline.spec_decode_speedup with a smollm-360m/yi-6b draft cost
    ratio."""
    import jax
    import numpy as np
    from repro.configs.base import RunConfig
    from repro.core.api import ParallelContext
    from repro.core.mesh import logical_mesh
    from repro.models.registry import build_model, get_reduced
    from repro.roofline.analysis import spec_decode_speedup
    from repro.serve import EngineConfig, InferenceEngine, SamplingParams
    from repro.serve.engine import EngineStats

    run = RunConfig(param_dtype="float32", compute_dtype="float32",
                    loss_chunk=32, q_chunk=16, kv_chunk=16)
    ctx = ParallelContext(mode="tesseract", data=2, depth=1, rows=2, cols=2)
    mesh = logical_mesh(ctx)
    model = build_model(get_reduced("yi-6b").model, ctx, run)
    params = model.init(jax.random.PRNGKey(0))

    rng = np.random.RandomState(0)
    prompts = [rng.randint(0, 250, (6,)).tolist() for _ in range(4)]
    n_new, spec_k = 96, 3
    # smollm-360m drafting for yi-6b: per-step cost ratio ~ param ratio
    draft_ratio = 0.36 / 6.0

    def measure(spec_k_, mode, dm=None, dp=None):
        eng = InferenceEngine(model, mesh, params, EngineConfig(
            n_slots=4, block_size=8, num_blocks=128, max_seq_len=256,
            spec_k=spec_k_, spec_mode=mode), draft_model=dm,
            draft_params=dp)
        for warmed in (False, True):             # first pass compiles
            eng.stats = EngineStats()
            reqs = [eng.add_request(p, SamplingParams(max_new_tokens=n_new))
                    for p in prompts]
            eng.run()
        s = eng.stats
        return [list(r.generated) for r in reqs], s

    plain, ps = measure(0, "auto")
    cells = {"plain": {"steps": ps.steps, "tokens": ps.tokens,
                       "wall_s": ps.wall,
                       "tokens_per_s": ps.tokens_per_s()}}
    for cell, mode, dm, dp, ratio in (
            ("ngram", "ngram", None, None, 0.0),
            ("draft_ideal", "draft", model, params, draft_ratio)):
        got, s = measure(spec_k, mode, dm, dp)
        assert got == plain, f"{cell}: speculative tokens != plain decode"
        acc = s.acceptance_rate()
        cells[cell] = {
            "steps": s.steps, "spec_rounds": s.spec_rounds,
            "spec_proposed": s.spec_proposed,
            "spec_accepted": s.spec_accepted,
            "spec_committed": s.spec_committed,
            "acceptance_rate": acc,
            "tokens_per_round": s.tokens_per_round(),
            "speedup_steps": ps.steps / s.steps,
            "wall_s": s.wall, "tokens_per_s": s.tokens_per_s(),
            "model_speedup_at_recorded_acceptance": spec_decode_speedup(
                acc, spec_k, draft_cost_ratio=ratio)["speedup"],
        }
    out = {"spec": {
        "workload": {"prompt_len": 6, "requests": len(prompts),
                     "new_tokens": n_new, "spec_k": spec_k,
                     "draft_cost_ratio": draft_ratio},
        **cells,
        "model_chat_typical": spec_decode_speedup(
            0.8, spec_k, draft_cost_ratio=draft_ratio),
    }}
    print(json.dumps(out))


def resilience():
    """The ISSUE-6 acceptance schedules as measured metrics, persisted to
    BENCH_resilience.json by benchmarks/run.py.  Train side: NaN step +
    corrupted newest checkpoint + 8->4 device loss; the run must rejoin the
    fault-free loss trajectory, and goodput (distinct optimizer steps /
    executed step attempts) quantifies the recovery tax.  Serve side: NaN
    logits + dropped step + KV pool exhaustion from one seeded plan with
    bit-exact survivor parity.  Both schedules must replay identically."""
    import tempfile

    import jax
    import numpy as np
    from repro.configs.base import RunConfig, ShapeSpec
    from repro.core.api import ParallelContext
    from repro.core.mesh import logical_mesh
    from repro.models.registry import build_model, get_reduced
    from repro.runtime.elastic import replan
    from repro.runtime.faults import (DeviceLostError, FaultInjector,
                                      FaultPlan)
    from repro.runtime.train_loop import train
    from repro.serve import EngineConfig, InferenceEngine, SamplingParams

    out = {}
    arch = get_reduced("yi-6b")

    # ---- train: NaN @2, corrupt the step-5 ckpt, lose half the fleet @6 ----
    run = RunConfig(param_dtype="float32", compute_dtype="float32",
                    loss_chunk=8, q_chunk=8, kv_chunk=8, lr=1e-3)
    shape = ShapeSpec("t", seq_len=16, global_batch=16, kind="train")
    ctx8 = ParallelContext(mode="tesseract", data=8, depth=1, rows=1, cols=1)
    mesh8 = logical_mesh(ctx8, jax.devices()[:8])
    model8 = build_model(arch.model, ctx8, run)
    ref = train(model8, mesh8, shape, steps=10, log_every=0)

    plan = FaultPlan.parse(
        "train.grads@2:nan;ckpt.write@5:corrupt(0,bit_flip);"
        "train.step@6:device_loss(4)", seed=13)

    def chaos_train():
        inj = FaultInjector(plan)
        with tempfile.TemporaryDirectory() as d:
            try:
                train(model8, mesh8, shape, steps=10, ckpt_dir=d,
                      ckpt_every=2, log_every=0, injector=inj)
                raise AssertionError("device loss did not surface")
            except DeviceLostError as e:
                partial = e.partial_result
                rp = replan(e.n_surviving, ctx8,
                            global_batch=shape.global_batch)
            model4 = build_model(arch.model, rp.ctx, run)
            mesh4 = logical_mesh(rp.ctx, jax.devices()[:rp.n_used])
            res = train(model4, mesh4, shape, steps=10, ckpt_dir=d,
                        ckpt_every=100, log_every=0,
                        accum_steps=rp.accum_steps, injector=inj)
            return partial, res, list(inj.fired)

    partial, res, fired = chaos_train()
    partial2, res2, fired2 = chaos_train()
    # partial ran steps 0..last_step; the resumed run re-executes everything
    # from the restored checkpoint up to where the crash hit
    resume_from = 10 - len(res.losses)
    recovery_steps = (partial.last_step + 1) - resume_from
    executed = (partial.last_step + 1) + partial.nan_skips + len(res.losses)
    rejoined = bool(np.allclose(res.losses, ref.losses[4:],
                                rtol=1e-5, atol=1e-6))
    out["train"] = {
        "steps": 10,
        "executed_step_attempts": executed,
        "recovery_steps": recovery_steps,
        "goodput": 10 / executed,
        "nan_skips": partial.nan_skips + res.nan_skips,
        "ckpt_fallbacks": res.ckpt_fallbacks,
        "restarts": partial.restarts,
        "faults_fired": len(fired),
        "trajectory_rejoined": rejoined,
        "replay_identical": bool(
            fired2 == fired
            and np.array_equal(np.array(res2.losses), np.array(res.losses))),
    }

    # ---- serve: NaN logits @2, drop @4, pool exhaust @5, device loss @8 ----
    srun = RunConfig(param_dtype="float32", compute_dtype="float32",
                     loss_chunk=32, q_chunk=16, kv_chunk=16)
    sctx = ParallelContext(mode="tesseract", data=2, depth=1, rows=2, cols=2)
    smesh = logical_mesh(sctx)
    smodel = build_model(arch.model, sctx, srun)
    params = smodel.init(jax.random.PRNGKey(0))
    cfg = EngineConfig(n_slots=8, block_size=4, num_blocks=128,
                       max_seq_len=64)

    rng = np.random.RandomState(7)
    lens = [5, 9, 16, 12, 7, 3, 21, 10]
    n_new = [6, 10, 4, 8, 5, 12, 3, 7]
    prompts = [rng.randint(0, 250, (l,)).tolist() for l in lens]
    splan = FaultPlan.parse(
        "serve.logits@2:nan(3);serve.step@4:drop_step;"
        "serve.step@5:pool_exhaust(2);serve.step@8:device_loss(4)", seed=17)

    def serve_run(injector=None):
        e = InferenceEngine(smodel, smesh, params, cfg, injector=injector)
        rs = [e.add_request(p, SamplingParams(max_new_tokens=n))
              for p, n in zip(prompts, n_new)]
        o = e.run()
        return [o[r.rid] for r in rs], e.stats, \
            list(e.injector.fired) if injector is not None else []

    sref, refstats, _ = serve_run()
    got, stats, sfired = serve_run(FaultInjector(splan))
    got2, stats2, sfired2 = serve_run(FaultInjector(splan))
    out["serve"] = {
        "tokens": stats.tokens,
        "steps": stats.steps,
        "ref_steps": refstats.steps,
        "extra_steps": stats.steps - refstats.steps,
        "nan_quarantines": stats.nan_quarantines,
        "preemptions": stats.preemptions,
        "dropped_steps": stats.dropped_steps,
        "pool_exhaust_events": stats.pool_exhaust_events,
        "shed": stats.shed,
        "failed": stats.failed,
        "survivor_parity": got == sref,
        "replay_identical": sfired2 == sfired and got2 == got,
    }
    print(json.dumps(out))


if __name__ == "__main__":
    {"accuracy_equiv": accuracy_equiv,
     "strong_scaling": strong_scaling,
     "matmul_schedules": matmul_schedules,
     "pipeline": pipeline_throughput,
     "zero1_memory": zero1_memory,
     "attention": attention,
     "longctx": longctx,
     "serve_throughput": serve_throughput,
     "serve_prefix": serve_prefix,
     "serve_spec": serve_spec,
     "resilience": resilience}[sys.argv[1]]()
