"""Measured multi-device benchmark bodies, run in a subprocess with fake
devices (like mdchecks).  Prints JSON to stdout.

    python -m repro.testing.benchruns accuracy_equiv
    python -m repro.testing.benchruns strong_scaling
"""
from __future__ import annotations

import json
import os
import sys
import time

if __name__ == "__main__" and "XLA_FLAGS" not in os.environ:
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"


def _train_curve(variant, steps=20, lr=3e-3):
    import jax
    import jax.numpy as jnp
    from repro.configs.base import RunConfig, ShapeSpec
    from repro.core.api import ParallelContext
    from repro.core.mesh import logical_mesh
    from repro.models.registry import build_model, get_reduced
    from repro.optim.adamw import adamw_init
    from repro.runtime.steps import build_train_step

    run = RunConfig(param_dtype="float32", compute_dtype="float32",
                    loss_chunk=32, q_chunk=16, kv_chunk=16, lr=lr)
    ctx = ParallelContext(**variant)
    mesh = logical_mesh(ctx, jax.devices()[: ctx.data * ctx.tp])
    arch = get_reduced("yi-6b")
    model = build_model(arch.model, ctx, run)
    shape = ShapeSpec("t", seq_len=32, global_batch=8, kind="train")
    bundle = build_train_step(model, mesh, shape)
    params = model.init(jax.random.PRNGKey(0))
    opt = adamw_init(params)
    losses, times = [], []
    p, o = params, opt
    for s in range(steps):
        tok = jax.random.randint(jax.random.PRNGKey(100 + s), (8, 32), 0, 250)
        batch = {"tokens": tok, "labels": jnp.roll(tok, -1, 1)}
        t0 = time.perf_counter()
        p, o, m = bundle.fn(p, o, batch)
        losses.append(float(m["loss"]))   # sync
        times.append(time.perf_counter() - t0)
    return losses, times


def accuracy_equiv():
    """Fig. 7 analogue: identical training curves on 1 device vs Tesseract
    [2,2,1] vs [2,2,2] — 'Tesseract does not introduce any approximations'."""
    out = {}
    for name, variant in [
        ("single", dict(mode="tesseract", data=1, depth=1, rows=1, cols=1)),
        ("tess_221", dict(mode="tesseract", data=1, depth=1, rows=2, cols=2)),
        ("tess_222", dict(mode="tesseract", data=1, depth=2, rows=2, cols=2)),
    ]:
        losses, times = _train_curve(variant, steps=20)
        out[name] = {"losses": losses,
                     "us_per_step": sum(times[2:]) / len(times[2:]) * 1e6}
    print(json.dumps(out))


def strong_scaling():
    """Measured step times for the reduced model across layouts (8 fake CPU
    devices; wall-clock is indicative only — the roofline model is the
    primary Table-1 artifact)."""
    out = {}
    for name, variant in [
        ("megatron_8", dict(mode="megatron1d", data=1, depth=1, rows=1, cols=8)),
        ("summa2d_22_dp2", dict(mode="summa2d", data=2, depth=1, rows=2, cols=2)),
        ("tesseract_222", dict(mode="tesseract", data=1, depth=2, rows=2, cols=2)),
    ]:
        losses, times = _train_curve(variant, steps=8)
        out[name] = {"us_per_step": sum(times[2:]) / len(times[2:]) * 1e6,
                     "final_loss": losses[-1]}
    print(json.dumps(out))


if __name__ == "__main__":
    {"accuracy_equiv": accuracy_equiv,
     "strong_scaling": strong_scaling}[sys.argv[1]]()
