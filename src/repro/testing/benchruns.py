"""Measured multi-device benchmark bodies, run in a subprocess with fake
devices (like mdchecks).  Prints JSON to stdout.

    python -m repro.testing.benchruns accuracy_equiv
    python -m repro.testing.benchruns strong_scaling
"""
from __future__ import annotations

import json
import os
import sys
import time

if __name__ == "__main__" and "XLA_FLAGS" not in os.environ:
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"


def _train_curve(variant, steps=20, lr=3e-3):
    import jax
    import jax.numpy as jnp
    from repro.configs.base import RunConfig, ShapeSpec
    from repro.core.api import ParallelContext
    from repro.core.mesh import logical_mesh
    from repro.models.registry import build_model, get_reduced
    from repro.optim.adamw import adamw_init
    from repro.runtime.steps import build_train_step

    run = RunConfig(param_dtype="float32", compute_dtype="float32",
                    loss_chunk=32, q_chunk=16, kv_chunk=16, lr=lr)
    ctx = ParallelContext(**variant)
    mesh = logical_mesh(ctx, jax.devices()[: ctx.data * ctx.tp])
    arch = get_reduced("yi-6b")
    model = build_model(arch.model, ctx, run)
    shape = ShapeSpec("t", seq_len=32, global_batch=8, kind="train")
    bundle = build_train_step(model, mesh, shape)
    params = model.init(jax.random.PRNGKey(0))
    opt = adamw_init(params)
    losses, times = [], []
    p, o = params, opt
    for s in range(steps):
        tok = jax.random.randint(jax.random.PRNGKey(100 + s), (8, 32), 0, 250)
        batch = {"tokens": tok, "labels": jnp.roll(tok, -1, 1)}
        t0 = time.perf_counter()
        p, o, m = bundle.fn(p, o, batch)
        losses.append(float(m["loss"]))   # sync
        times.append(time.perf_counter() - t0)
    return losses, times


def accuracy_equiv():
    """Fig. 7 analogue: identical training curves on 1 device vs Tesseract
    [2,2,1] vs [2,2,2] — 'Tesseract does not introduce any approximations'."""
    out = {}
    for name, variant in [
        ("single", dict(mode="tesseract", data=1, depth=1, rows=1, cols=1)),
        ("tess_221", dict(mode="tesseract", data=1, depth=1, rows=2, cols=2)),
        ("tess_222", dict(mode="tesseract", data=1, depth=2, rows=2, cols=2)),
    ]:
        losses, times = _train_curve(variant, steps=20)
        out[name] = {"losses": losses,
                     "us_per_step": sum(times[2:]) / len(times[2:]) * 1e6}
    print(json.dumps(out))


def strong_scaling():
    """Measured step times for the reduced model across layouts (8 fake CPU
    devices; wall-clock is indicative only — the roofline model is the
    primary Table-1 artifact)."""
    out = {}
    for name, variant in [
        ("megatron_8", dict(mode="megatron1d", data=1, depth=1, rows=1, cols=8)),
        ("summa2d_22_dp2", dict(mode="summa2d", data=2, depth=1, rows=2, cols=2)),
        ("tesseract_222", dict(mode="tesseract", data=1, depth=2, rows=2, cols=2)),
    ]:
        losses, times = _train_curve(variant, steps=8)
        out[name] = {"us_per_step": sum(times[2:]) / len(times[2:]) * 1e6,
                     "final_loss": losses[-1]}
    print(json.dumps(out))


def matmul_schedules():
    """fused vs ring Tesseract matmul (fwd + both grads) on a [2, 2, 2]
    grid of 8 fake CPU devices.  Host wall-clock is indicative only (no
    async collective-permute on CPU); the analytic overlap model in
    benchmarks/comm_model.py is the perf artifact."""
    import jax
    import jax.numpy as jnp
    from jax import lax
    from jax.sharding import PartitionSpec as P
    from repro.core.api import ParallelContext
    from repro.core.collectives import grad_sync, shard_map
    from repro.core.mesh import logical_mesh
    from repro.core.summa import tesseract_matmul

    B, E, F, G = 2, 512, 512, 512
    A = jax.random.normal(jax.random.PRNGKey(0), (B, E, F), jnp.float32)
    W = jax.random.normal(jax.random.PRNGKey(1), (F, G), jnp.float32)
    S = jax.random.normal(jax.random.PRNGKey(2), (B, E, G), jnp.float32)
    out = {}
    for sched in ("fused", "ring"):
        ctx = ParallelContext(mode="tesseract", data=1, depth=2, rows=2,
                              cols=2, reduce_dgrad_in_op=False,
                              matmul_schedule=sched)
        mesh = logical_mesh(ctx, jax.devices()[:8])
        tok = P(None, ("data", "depth", "row"), "col")

        def local(a, w, s):
            def loss(a_, w_):
                w_ = grad_sync(w_, (ctx.axis_data, ctx.axis_depth))
                return jnp.sum(tesseract_matmul(ctx, a_, w_) * s)
            l, grads = jax.value_and_grad(loss, argnums=(0, 1))(a, w)
            return lax.psum(l, ("data", "depth", "row", "col")), grads

        fn = jax.jit(shard_map(local, mesh=mesh,
                               in_specs=(tok, P("row", "col"), tok),
                               out_specs=(P(), (tok, P("row", "col")))))
        l, _ = fn(A, W, S)
        float(l)  # compile + sync
        times = []
        for _ in range(10):
            t0 = time.perf_counter()
            l, g = fn(A, W, S)
            jax.block_until_ready(g)
            times.append(time.perf_counter() - t0)
        out[sched] = {"us_per_call": sum(times[2:]) / len(times[2:]) * 1e6,
                      "loss": float(l)}
    out["losses_match"] = abs(out["fused"]["loss"] - out["ring"]["loss"]) \
        <= 1e-3 * abs(out["fused"]["loss"])
    print(json.dumps(out))


def pipeline_throughput():
    """1F1B [pipe=2 x tesseract q=2] vs the non-PP [q=2 x dp=2] baseline on
    the same 8 fake CPU devices: tokens/s per optimizer step plus the
    measured/predicted schedule bubble.  CPU wall-clock is indicative only
    (the 1F1B backward units pay full-stage rematerialization); the bubble
    numbers are the schedule artifact and must sit within 10% of the
    analytic (S-1)/(M+S-1)."""
    import jax
    import jax.numpy as jnp
    from repro.configs.base import RunConfig, ShapeSpec
    from repro.core.api import ParallelContext
    from repro.core.mesh import logical_mesh, pipeline_mesh
    from repro.models.registry import build_model, get_reduced
    from repro.optim.adamw import adamw_init
    from repro.runtime.pipeline import bubble_fraction
    from repro.runtime.steps import build_train_step

    B, S = 16, 32
    arch = get_reduced("yi-6b")
    shape = ShapeSpec("t", seq_len=S, global_batch=B, kind="train")

    def measure(ctx, mesh, M=0, steps=8):
        run = RunConfig(param_dtype="float32", compute_dtype="float32",
                        loss_chunk=32, q_chunk=16, kv_chunk=16, lr=1e-3,
                        pipeline_microbatches=M)
        model = build_model(arch.model, ctx, run)
        bundle = build_train_step(model, mesh, shape)
        p = jax.device_put(model.init(jax.random.PRNGKey(0)),
                           bundle.in_shardings[0])
        o = jax.device_put(adamw_init(p), bundle.in_shardings[1])
        losses, times = [], []
        for s in range(steps):
            tok = jax.random.randint(jax.random.PRNGKey(100 + s), (B, S),
                                     0, 250)
            batch = {"tokens": tok, "labels": jnp.roll(tok, -1, 1)}
            t0 = time.perf_counter()
            p, o, m = bundle.fn(p, o, batch)
            losses.append(float(m["loss"]))  # sync
            times.append(time.perf_counter() - t0)
        dt = sum(times[2:]) / len(times[2:])
        return {"us_per_step": dt * 1e6, "tokens_per_s": B * S / dt,
                "final_loss": losses[-1]}, bundle, losses

    ctx_pp = ParallelContext(mode="tesseract", data=1, depth=1, rows=2,
                             cols=2)
    pp, bundle_pp, losses_pp = measure(
        ctx_pp, pipeline_mesh(ctx_pp, 2, jax.devices()[:8]), M=4)
    info = bundle_pp.pipe_info
    pp.update(n_stages=info["n_stages"], n_micro=info["n_micro"],
              bubble_measured=info["measured_bubble"],
              bubble_predicted=info["predicted_bubble"])
    assert pp["bubble_measured"] <= pp["bubble_predicted"] + 0.10, pp

    ctx_base = ParallelContext(mode="tesseract", data=2, depth=1, rows=2,
                               cols=2)
    base, _, losses_base = measure(
        ctx_base, logical_mesh(ctx_base, jax.devices()[:8]))
    # both layouts train the same model on the same step-keyed batches
    dev = max(abs(a - b) for a, b in zip(losses_pp, losses_base))
    out = {"pipeline_q2_pipe2": pp, "baseline_q2_dp2": base,
           "bubble_extra": {
               f"M{m}_S{s}": bubble_fraction(m, s)
               for m, s in [(4, 2), (8, 2), (16, 2), (8, 4), (32, 4)]},
           "max_loss_dev_vs_baseline": dev}
    assert dev < 5e-3, out
    print(json.dumps(out))


def zero1_memory():
    """ZeRO-1 vs replicated optimizer state on [data=4, q=1] and
    [data=2, d=2, q=1] grids: measured per-device optimizer-state bytes
    (from the bundles' real NamedShardings), step wall-clock, loss parity,
    and the Eq. 8 + ZeRO memory-model prediction."""
    import jax
    import jax.numpy as jnp
    from repro.configs.base import RunConfig, ShapeSpec
    from repro.core.api import ParallelContext
    from repro.core.mesh import logical_mesh
    from repro.models.registry import build_model, get_reduced
    from repro.optim.adamw import adamw_init
    from repro.roofline.analysis import optimizer_state_bytes
    from repro.runtime.steps import build_train_step

    B, S = 8, 32
    arch = get_reduced("yi-6b")
    shape = ShapeSpec("t", seq_len=S, global_batch=B, kind="train")

    from repro.testing.mdchecks import _opt_bytes_per_device as opt_bytes

    def measure(variant, zero, steps=8):
        run = RunConfig(param_dtype="float32", compute_dtype="float32",
                        loss_chunk=32, q_chunk=16, kv_chunk=16, lr=1e-3,
                        zero1=zero)
        ctx = ParallelContext(**variant)
        mesh = logical_mesh(ctx, jax.devices()[:ctx.data * ctx.tp])
        model = build_model(arch.model, ctx, run)
        bundle = build_train_step(model, mesh, shape)
        p = model.init(jax.random.PRNGKey(0))
        if zero:
            from repro.optim.zero import zero_opt_init
            o = zero_opt_init(bundle)
        else:
            o = adamw_init(p)
        losses, times = [], []
        for s in range(steps):
            tok = jax.random.randint(jax.random.PRNGKey(100 + s), (B, S),
                                     0, 250)
            batch = {"tokens": tok, "labels": jnp.roll(tok, -1, 1)}
            t0 = time.perf_counter()
            p, o, m = bundle.fn(p, o, batch)
            losses.append(float(m["loss"]))  # sync
            times.append(time.perf_counter() - t0)
        return {"us_per_step": sum(times[2:]) / len(times[2:]) * 1e6,
                "opt_state_bytes_per_device": opt_bytes(bundle),
                "losses": losses}

    n_params = arch.model.param_count()
    out = {}
    for name, variant in [
            ("dp4", dict(mode="tesseract", data=4, depth=1, rows=1, cols=1)),
            ("dp2_d2", dict(mode="tesseract", data=2, depth=2, rows=1,
                            cols=1))]:
        base = measure(variant, zero=False)
        z1 = measure(variant, zero=True)
        ratio = (base["opt_state_bytes_per_device"]
                 / z1["opt_state_bytes_per_device"])
        dev = max(abs(a - b) for a, b in zip(base["losses"], z1["losses"]))
        pred_base = optimizer_state_bytes(
            n_params, tp=variant["depth"] * variant["rows"]
            * variant["cols"], data=variant["data"],
            depth=variant["depth"], zero_stage=0, master=False)
        pred_z1 = optimizer_state_bytes(
            n_params, tp=variant["depth"] * variant["rows"]
            * variant["cols"], data=variant["data"],
            depth=variant["depth"], zero_stage=1, master=False)
        out[name] = {
            "replicated": base, "zero1": z1,
            "measured_ratio": ratio,
            "model_pred_ratio": pred_base / pred_z1,
            "model_pred_bytes": {"replicated": pred_base, "zero1": pred_z1},
            "max_loss_dev": dev,
            "losses_match": dev < 1e-5,
        }
        assert out[name]["losses_match"], (name, base["losses"],
                                           z1["losses"])
    # dp=4 must shrink ~4x (flat-index padding costs a few KiB)
    assert out["dp4"]["measured_ratio"] > 3.2, out["dp4"]
    print(json.dumps(out))


def serve_throughput():
    """Continuous-batching engine vs the static-batch replay loop on a
    mixed-length workload, per batch size.  Greedy, so the two must emit
    identical tokens; the engine wins wall-clock by retiring finished slots
    in place and admitting queued requests immediately (8 fake CPU devices,
    wall-clock indicative; both paths are warmed before timing)."""
    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro.configs.base import RunConfig, ShapeSpec
    from repro.core.api import ParallelContext
    from repro.core.mesh import logical_mesh
    from repro.models.registry import build_model, get_reduced
    from repro.runtime.steps import build_decode_step
    from repro.serve import EngineConfig, InferenceEngine, SamplingParams
    from repro.serve.engine import EngineStats

    run = RunConfig(param_dtype="float32", compute_dtype="float32",
                    loss_chunk=32, q_chunk=16, kv_chunk=16)
    ctx = ParallelContext(mode="tesseract", data=2, depth=1, rows=2, cols=2)
    mesh = logical_mesh(ctx)
    model = build_model(get_reduced("yi-6b").model, ctx, run)
    params = model.init(jax.random.PRNGKey(0))

    rng = np.random.RandomState(0)
    lens = [6, 12, 24] * 4
    n_new = [4, 16, 8] * 4
    prompts = [rng.randint(0, 250, (l,)).tolist() for l in lens]
    S = 64

    def run_static(n_slots):
        """Batches of n_slots via prompt replay; a batch runs until its
        slowest member finishes (the pre-engine serving shape)."""
        dec = build_decode_step(model, mesh,
                                ShapeSpec("d", S, n_slots, "decode"))
        cache_sds, _ = model.cache_abstract(n_slots, S, dec.plan)
        out = {i: [] for i in range(len(prompts))}
        times = []
        t_start = time.perf_counter()
        for i0 in range(0, len(prompts), n_slots):
            sel = [(i0 + j) % len(prompts) for j in range(n_slots)]
            bl = [len(prompts[i]) for i in sel]
            bn = [n_new[i] for i in sel]
            cache = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                                 cache_sds)
            ids = np.array([[prompts[i][0]] for i in sel], np.int32)
            for t in range(max(l + n for l, n in zip(bl, bn)) - 1):
                t0 = time.perf_counter()
                nxt, cache = dec.fn(params, cache, jnp.asarray(ids),
                                    jnp.int32(t))
                nxt = np.asarray(nxt)
                dt = time.perf_counter() - t0
                emitted = 0
                for j, i in enumerate(sel):
                    if t + 1 < bl[j]:
                        ids[j, 0] = prompts[i][t + 1]
                    else:
                        if t + 1 - bl[j] < bn[j] and i0 + j < len(prompts):
                            out[i].append(int(nxt[j, 0]))
                            emitted += 1
                        ids[j, 0] = nxt[j, 0]
                if emitted:
                    times.extend([dt / emitted] * emitted)
        wall = time.perf_counter() - t_start
        tokens = sum(len(v) for v in out.values())
        return out, {"tokens": tokens, "wall_s": wall,
                     "tokens_per_s": tokens / wall,
                     "p50_ms": float(np.percentile(times, 50) * 1e3),
                     "p95_ms": float(np.percentile(times, 95) * 1e3)}

    out = {"workload": {"prompt_lens": lens, "new_tokens": n_new}}
    for n_slots in (4, 8):
        eng = InferenceEngine(model, mesh, params, EngineConfig(
            n_slots=n_slots, block_size=4, num_blocks=32 * n_slots,
            max_seq_len=S))
        run_static(n_slots)                      # warm the static step
        for warmed in (False, True):             # first pass compiles
            eng.stats = EngineStats()
            reqs = [eng.add_request(p, SamplingParams(max_new_tokens=n))
                    for p, n in zip(prompts, n_new)]
            eng_out = eng.run()
        static_out, static = run_static(n_slots)
        got = [eng_out[r.rid] for r in reqs]
        want = [static_out[i] for i in range(len(prompts))]
        assert got == want, "engine tokens diverged from static loop"
        lat = eng.stats.latency_percentiles()
        out[f"slots{n_slots}"] = {
            "engine": {"tokens": eng.stats.tokens,
                       "wall_s": eng.stats.wall,
                       "tokens_per_s": eng.stats.tokens_per_s(),
                       "steps": eng.stats.steps,
                       "prefills": eng.stats.prefills, **lat},
            "static": static,
            "engine_wins": eng.stats.tokens_per_s() > static["tokens_per_s"],
        }
    print(json.dumps(out))


if __name__ == "__main__":
    {"accuracy_equiv": accuracy_equiv,
     "strong_scaling": strong_scaling,
     "matmul_schedules": matmul_schedules,
     "pipeline": pipeline_throughput,
     "zero1_memory": zero1_memory,
     "serve_throughput": serve_throughput}[sys.argv[1]]()
