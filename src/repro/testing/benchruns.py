"""Measured multi-device benchmark bodies, run in a subprocess with fake
devices (like mdchecks).  Prints JSON to stdout.

    python -m repro.testing.benchruns accuracy_equiv
    python -m repro.testing.benchruns strong_scaling
"""
from __future__ import annotations

import json
import os
import sys
import time

if __name__ == "__main__" and "XLA_FLAGS" not in os.environ:
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"


def _train_curve(variant, steps=20, lr=3e-3):
    import jax
    import jax.numpy as jnp
    from repro.configs.base import RunConfig, ShapeSpec
    from repro.core.api import ParallelContext
    from repro.core.mesh import logical_mesh
    from repro.models.registry import build_model, get_reduced
    from repro.optim.adamw import adamw_init
    from repro.runtime.steps import build_train_step

    run = RunConfig(param_dtype="float32", compute_dtype="float32",
                    loss_chunk=32, q_chunk=16, kv_chunk=16, lr=lr)
    ctx = ParallelContext(**variant)
    mesh = logical_mesh(ctx, jax.devices()[: ctx.data * ctx.tp])
    arch = get_reduced("yi-6b")
    model = build_model(arch.model, ctx, run)
    shape = ShapeSpec("t", seq_len=32, global_batch=8, kind="train")
    bundle = build_train_step(model, mesh, shape)
    params = model.init(jax.random.PRNGKey(0))
    opt = adamw_init(params)
    losses, times = [], []
    p, o = params, opt
    for s in range(steps):
        tok = jax.random.randint(jax.random.PRNGKey(100 + s), (8, 32), 0, 250)
        batch = {"tokens": tok, "labels": jnp.roll(tok, -1, 1)}
        t0 = time.perf_counter()
        p, o, m = bundle.fn(p, o, batch)
        losses.append(float(m["loss"]))   # sync
        times.append(time.perf_counter() - t0)
    return losses, times


def accuracy_equiv():
    """Fig. 7 analogue: identical training curves on 1 device vs Tesseract
    [2,2,1] vs [2,2,2] — 'Tesseract does not introduce any approximations'."""
    out = {}
    for name, variant in [
        ("single", dict(mode="tesseract", data=1, depth=1, rows=1, cols=1)),
        ("tess_221", dict(mode="tesseract", data=1, depth=1, rows=2, cols=2)),
        ("tess_222", dict(mode="tesseract", data=1, depth=2, rows=2, cols=2)),
    ]:
        losses, times = _train_curve(variant, steps=20)
        out[name] = {"losses": losses,
                     "us_per_step": sum(times[2:]) / len(times[2:]) * 1e6}
    print(json.dumps(out))


def strong_scaling():
    """Measured step times for the reduced model across layouts (8 fake CPU
    devices; wall-clock is indicative only — the roofline model is the
    primary Table-1 artifact)."""
    out = {}
    for name, variant in [
        ("megatron_8", dict(mode="megatron1d", data=1, depth=1, rows=1, cols=8)),
        ("summa2d_22_dp2", dict(mode="summa2d", data=2, depth=1, rows=2, cols=2)),
        ("tesseract_222", dict(mode="tesseract", data=1, depth=2, rows=2, cols=2)),
    ]:
        losses, times = _train_curve(variant, steps=8)
        out[name] = {"us_per_step": sum(times[2:]) / len(times[2:]) * 1e6,
                     "final_loss": losses[-1]}
    print(json.dumps(out))


def matmul_schedules():
    """fused vs ring Tesseract matmul (fwd + both grads) on a [2, 2, 2]
    grid of 8 fake CPU devices.  Host wall-clock is indicative only (no
    async collective-permute on CPU); the analytic overlap model in
    benchmarks/comm_model.py is the perf artifact."""
    import jax
    import jax.numpy as jnp
    from jax import lax
    from jax.sharding import PartitionSpec as P
    from repro.core.api import ParallelContext
    from repro.core.collectives import grad_sync, shard_map
    from repro.core.mesh import logical_mesh
    from repro.core.summa import tesseract_matmul

    B, E, F, G = 2, 512, 512, 512
    A = jax.random.normal(jax.random.PRNGKey(0), (B, E, F), jnp.float32)
    W = jax.random.normal(jax.random.PRNGKey(1), (F, G), jnp.float32)
    S = jax.random.normal(jax.random.PRNGKey(2), (B, E, G), jnp.float32)
    out = {}
    for sched in ("fused", "ring"):
        ctx = ParallelContext(mode="tesseract", data=1, depth=2, rows=2,
                              cols=2, reduce_dgrad_in_op=False,
                              matmul_schedule=sched)
        mesh = logical_mesh(ctx, jax.devices()[:8])
        tok = P(None, ("data", "depth", "row"), "col")

        def local(a, w, s):
            def loss(a_, w_):
                w_ = grad_sync(w_, (ctx.axis_data, ctx.axis_depth))
                return jnp.sum(tesseract_matmul(ctx, a_, w_) * s)
            l, grads = jax.value_and_grad(loss, argnums=(0, 1))(a, w)
            return lax.psum(l, ("data", "depth", "row", "col")), grads

        fn = jax.jit(shard_map(local, mesh=mesh,
                               in_specs=(tok, P("row", "col"), tok),
                               out_specs=(P(), (tok, P("row", "col")))))
        l, _ = fn(A, W, S)
        float(l)  # compile + sync
        times = []
        for _ in range(10):
            t0 = time.perf_counter()
            l, g = fn(A, W, S)
            jax.block_until_ready(g)
            times.append(time.perf_counter() - t0)
        out[sched] = {"us_per_call": sum(times[2:]) / len(times[2:]) * 1e6,
                      "loss": float(l)}
    out["losses_match"] = abs(out["fused"]["loss"] - out["ring"]["loss"]) \
        <= 1e-3 * abs(out["fused"]["loss"])
    print(json.dumps(out))


if __name__ == "__main__":
    {"accuracy_equiv": accuracy_equiv,
     "strong_scaling": strong_scaling,
     "matmul_schedules": matmul_schedules}[sys.argv[1]]()
