"""Table 1 / Table 2 analogues (paper §4.1, §4.2) from the analytic
execution-time model, plus the §1 transmission-ratio validation.

The paper measured A100 wall-clock; this container has no accelerator, so
the table analogues use the v5e roofline model over OUR collective schedule
(comm_model.py).  What must reproduce: the ORDERING and the direction/rough
magnitude of the speedups (2.5-D > 2-D > 1-D at fixed p; deeper d wins at
fixed q).  Measured small-scale wall-clock parity runs live in
accuracy_equiv.py (Fig. 7 analogue).
"""
from __future__ import annotations

from .comm_model import (LayerDims, layer_bytes, modeled_layer_time,
                         paper_ratio_check)

# paper Table 1 (strong scaling): hidden 3072, 64 heads, batch 12
T1_DIMS = dict(b=12, s=512, h=3072, ff=4 * 3072, heads=64, kv_heads=64,
               head_dim=48, glu=False)

T1_ROWS = [
    ("Megatron-LM", "megatron1d", (4,)),
    ("Megatron-LM", "megatron1d", (16,)),
    ("Megatron-LM", "megatron1d", (64,)),
    ("Optimus", "summa2d", (2, 2, 1)),
    ("Optimus", "summa2d", (4, 4, 1)),
    ("Optimus", "summa2d", (8, 8, 1)),
    ("Tesseract", "tesseract", (2, 2, 1)),
    ("Tesseract", "tesseract", (2, 2, 2)),
    ("Tesseract", "tesseract", (4, 4, 1)),
    ("Tesseract", "tesseract", (4, 4, 2)),
    ("Tesseract", "tesseract", (4, 4, 4)),
    ("Tesseract", "tesseract", (8, 8, 1)),
]


def table1_strong():
    rows = []
    d = LayerDims(**T1_DIMS)
    for name, mode, shape in T1_ROWS:
        m = "megatron1d" if mode == "megatron1d" else mode
        t = modeled_layer_time("megatron1d" if m == "megatron1d" else
                               "tesseract", d, shape, train=True)
        comm = layer_bytes("megatron1d" if m == "megatron1d" else "tesseract",
                           d, shape, 1, train=True)
        import math
        p = math.prod(shape)
        rows.append(dict(method=name, shape=list(shape), p=p,
                         layer_time_us=t * 1e6, comm_mb=comm / 2 ** 20))
    return rows


def table1_speedups(rows=None):
    rows = rows or table1_strong()
    by = {(r["method"], tuple(r["shape"])): r for r in rows}
    t444 = by[("Tesseract", (4, 4, 4))]["layer_time_us"]
    return {
        "tesseract[4,4,4]_vs_megatron[64]":
            by[("Megatron-LM", (64,))]["layer_time_us"] / t444,
        "tesseract[4,4,4]_vs_optimus[8,8]":
            by[("Optimus", (8, 8, 1))]["layer_time_us"] / t444,
        "tesseract[4,4,4]_vs_[8,8,1]":
            by[("Tesseract", (8, 8, 1))]["layer_time_us"] / t444,
        "paper_values": {"vs_megatron": 1.3751, "vs_optimus": 1.5293,
                         "vs_881": 2.0702},
    }


# paper Table 2 (weak scaling): per-GPU [b/dq, n/q, h/n] = [24, 16, 192]
T2_ROWS = [
    ("Megatron-LM", (4,), dict(b=60, h=2048, heads=32)),
    ("Megatron-LM", (16,), dict(b=60, h=4096, heads=64)),
    ("Megatron-LM", (64,), dict(b=30, h=8192, heads=128)),
    ("Optimus", (2, 2, 1), dict(b=96, h=2048, heads=32)),
    ("Optimus", (4, 4, 1), dict(b=192, h=4096, heads=64)),
    ("Optimus", (8, 8, 1), dict(b=384, h=8192, heads=128)),
    ("Tesseract", (2, 2, 1), dict(b=96, h=2048, heads=32)),
    ("Tesseract", (2, 2, 2), dict(b=192, h=2048, heads=32)),
    ("Tesseract", (4, 4, 1), dict(b=192, h=4096, heads=64)),
    ("Tesseract", (4, 4, 2), dict(b=384, h=4096, heads=64)),
    ("Tesseract", (4, 4, 4), dict(b=768, h=4096, heads=64)),
    ("Tesseract", (8, 8, 1), dict(b=384, h=8192, heads=128)),
]


def table2_weak():
    rows = []
    import math
    for name, shape, dd in T2_ROWS:
        d = LayerDims(b=dd["b"], s=512, h=dd["h"], ff=4 * dd["h"],
                      heads=dd["heads"], kv_heads=dd["heads"],
                      head_dim=dd["h"] // dd["heads"], glu=False)
        mode = "megatron1d" if name == "Megatron-LM" else "tesseract"
        t = modeled_layer_time(mode, d, shape, train=True)
        p = math.prod(shape)
        # throughput analogue: sequences/sec through one layer stack of 24
        thr = dd["b"] / (24 * t)
        rows.append(dict(method=name, shape=list(shape), p=p, batch=dd["b"],
                         hidden=dd["h"], layer_time_us=t * 1e6,
                         throughput_rel=thr))
    return rows


def table2_speedups(rows=None):
    rows = rows or table2_weak()
    by = {(r["method"], tuple(r["shape"])): r for r in rows}
    t444 = by[("Tesseract", (4, 4, 4))]["throughput_rel"]
    return {
        "throughput_tesseract[4,4,4]_vs_megatron[64]":
            t444 / by[("Megatron-LM", (64,))]["throughput_rel"],
        "throughput_tesseract[4,4,4]_vs_optimus[8,8]":
            t444 / by[("Optimus", (8, 8, 1))]["throughput_rel"],
        "throughput_tesseract[4,4,4]_vs_[8,8,1]":
            t444 / by[("Tesseract", (8, 8, 1))]["throughput_rel"],
        "paper_values": {"vs_megatron": 3.3746, "vs_optimus": 1.7144,
                         "vs_881": 1.5092},
    }
