"""Benchmark harness: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows.

  ratios_p64        — paper §1: transmission ratios vs Cannon / 2.5-D
  table1_strong     — Table 1 analogue (modeled layer times, strong scaling)
  table2_weak       — Table 2 analogue (weak scaling throughput)
  fig7_accuracy     — Fig. 7 analogue (measured: identical training curves
                      single-device vs Tesseract [2,2,1] / [2,2,2])
  measured_strong   — measured step times on 8 fake devices (indicative)
  pipeline          — 1F1B [pipe=2 x q=2] vs non-PP baseline (tokens/s,
                      measured vs analytic bubble) -> BENCH_pipeline.json
  zero1             — ZeRO-1 opt-state sharding vs replicated baseline
                      (per-device opt bytes, parity) -> BENCH_zero1.json
  serve             — continuous batching vs static decode loop
                      (tokens/s, p50/p95 latency) -> BENCH_serve.json
  attention         — fused Pallas attention vs the jnp paths: train-step
                      parity + wall clock, paged-kernel vs gather decode
                      tok/s (modeled v5e + indicative CPU), flash bwd vs
                      jax.vjp, autotuned tiles -> BENCH_attention.json
  longctx           — ring/striped flash attention over the seq axis:
                      striped parity, seq-axis ppermutes byte-exact vs the
                      traffic model, iso-memory context scaling, modeled
                      128k cells, ring-step tiles -> BENCH_longctx.json
  roofline_summary  — dry-run roofline terms for the three hillclimb cells

Run:  PYTHONPATH=src python -m benchmarks.run [--quick | --check]

``--check`` runs only the shardcheck gate: the full static-analysis sweep
diffed against the committed SHARDCHECK.json (nonzero exit on drift, rule
findings, or lint findings — see src/repro/analysis/shardcheck.py).
"""
from __future__ import annotations

import json
import os
import pathlib
import subprocess
import sys

HERE = pathlib.Path(__file__).resolve().parent
SRC = str(HERE.parent / "src")
sys.path.insert(0, SRC)
sys.path.insert(0, str(HERE.parent))

from benchmarks import comm_model, tables  # noqa: E402


def _row(name, us, derived):
    print(f"{name},{us:.2f},{derived}")


def bench_ratios_p64():
    c, d25 = comm_model.paper_ratio_check(64)
    _row("ratios_p64/cannon_vs_tesseract", 0.0,
         f"{c:.2f}x (paper: 31.5x)")
    _row("ratios_p64/2.5d_vs_tesseract", 0.0, f"{d25:.2f}x (paper: 3.75x)")
    assert abs(c - 31.5) < 0.01 and abs(d25 - 3.75) < 0.01


def bench_table1():
    rows = tables.table1_strong()
    for r in rows:
        _row(f"table1/{r['method']}{r['shape']}", r["layer_time_us"],
             f"comm={r['comm_mb']:.2f}MiB p={r['p']}")
    sp = tables.table1_speedups(rows)
    for k, v in sp.items():
        if k != "paper_values":
            _row(f"table1_speedup/{k}", 0.0, f"{v:.3f}x")
    _row("table1_speedup/paper", 0.0, json.dumps(sp["paper_values"]))


def bench_table2():
    rows = tables.table2_weak()
    for r in rows:
        _row(f"table2/{r['method']}{r['shape']}", r["layer_time_us"],
             f"thr={r['throughput_rel']:.2f} b={r['batch']} h={r['hidden']}")
    sp = tables.table2_speedups(rows)
    for k, v in sp.items():
        if k != "paper_values":
            _row(f"table2_speedup/{k}", 0.0, f"{v:.3f}x")
    _row("table2_speedup/paper", 0.0, json.dumps(sp["paper_values"]))


def _sub(check):
    env = dict(os.environ, PYTHONPATH=SRC)
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-m", "repro.testing.benchruns",
                        check], env=env, capture_output=True, text=True,
                       timeout=2400)
    if r.returncode != 0:
        raise RuntimeError(f"{check} failed: {r.stderr[-2000:]}")
    return json.loads(r.stdout.strip().splitlines()[-1])


def bench_fig7_accuracy():
    out = _sub("accuracy_equiv")
    import numpy as np
    ref = np.array(out["single"]["losses"])
    for name in ("tess_221", "tess_222"):
        got = np.array(out[name]["losses"])
        max_dev = float(np.max(np.abs(got - ref)))
        _row(f"fig7/{name}", out[name]["us_per_step"],
             f"max_loss_dev={max_dev:.2e} (exactness claim: ~0)")
        assert max_dev < 5e-3, f"accuracy differs: {max_dev}"
    _row("fig7/single", out["single"]["us_per_step"], "reference")


def bench_measured_strong():
    out = _sub("strong_scaling")
    for name, d in out.items():
        _row(f"measured_strong/{name}", d["us_per_step"],
             f"final_loss={d['final_loss']:.4f}")


def bench_matmul_schedules():
    """Fused vs ring SUMMA schedule: measured host wall-clock (interpret /
    CPU collectives — indicative) + the analytic overlap model, persisted to
    BENCH_matmul.json as the start of the schedule perf trajectory."""
    measured = _sub("matmul_schedules")
    for sched in ("fused", "ring"):
        _row(f"matmul_schedule/{sched}", measured[sched]["us_per_call"],
             f"loss={measured[sched]['loss']:.2f} (8 fake CPU devices)")
    assert measured["losses_match"], measured

    analytic = {}
    big = comm_model.LayerDims(b=256, s=4096, h=16384, ff=53248, heads=128,
                               kv_heads=8, head_dim=128, glu=True)
    for q, depth, data in [(2, 4, 8), (4, 4, 8), (8, 1, 8)]:
        r = comm_model.ring_vs_fused(big, q, depth, data=data, train=True)
        key = f"q{q}_d{depth}_dp{data}"
        analytic[key] = {
            "fused_exposed_comm_ms": r["fused"].exposed_comm_s * 1e3,
            "ring_exposed_comm_ms": r["ring"].exposed_comm_s * 1e3,
            "fused_peak_gathered_mib": r["fused"].peak_gathered_bytes / 2**20,
            "ring_peak_gathered_mib": r["ring"].peak_gathered_bytes / 2**20,
            "ring_wins": r["ring_wins"],
        }
        _row(f"matmul_schedule/analytic/{key}", 0.0,
             f"exposed {r['fused'].exposed_comm_s*1e3:.1f}->"
             f"{r['ring'].exposed_comm_s*1e3:.1f}ms "
             f"peak {r['fused'].peak_gathered_bytes/2**20:.0f}->"
             f"{r['ring'].peak_gathered_bytes/2**20:.0f}MiB "
             f"ring_wins={r['ring_wins']}")

    out = HERE.parent / "BENCH_matmul.json"
    payload = {"measured_cpu_interpret": measured, "analytic_v5e": analytic,
               "note": "measured: 8 fake CPU host devices, wall-clock "
                       "indicative only; analytic: benchmarks/comm_model.py "
                       "overlap model (DESIGN.md §2b)"}
    out.write_text(json.dumps(payload, indent=2) + "\n")
    _row("matmul_schedule/written", 0.0, str(out))


def bench_pipeline():
    """1F1B pipeline composition (paper §3.4): [pipe=2 x tesseract q=2] vs
    the non-PP [q=2 x dp=2] layout on the same 8 fake devices, persisted to
    BENCH_pipeline.json.  The schedule artifact is the bubble fraction —
    measured from the dispatched 1F1B tick tables and required to sit
    within 10% of the analytic (S-1)/(M+S-1); CPU tokens/s is indicative
    only (backward units pay full-stage remat on the host)."""
    out = _sub("pipeline")
    pp, base = out["pipeline_q2_pipe2"], out["baseline_q2_dp2"]
    _row("pipeline/pipe2_q2", pp["us_per_step"],
         f"{pp['tokens_per_s']:.1f} tok/s bubble="
         f"{pp['bubble_measured']:.3f} (pred {pp['bubble_predicted']:.3f}) "
         f"M={pp['n_micro']} S={pp['n_stages']}")
    _row("pipeline/baseline_dp2_q2", base["us_per_step"],
         f"{base['tokens_per_s']:.1f} tok/s")
    # (bubble <= analytic+10% and loss-deviation < 5e-3 are asserted inside
    # the benchruns subprocess; a violation fails _sub before reaching here)
    payload = {**out,
               "note": "8 fake CPU host devices, yi-6b reduced, B=16 S=32; "
                       "wall-clock indicative only (1F1B bwd units remat "
                       "the full stage on host); bubble measured from the "
                       "dispatched schedule tables (runtime/pipeline.py), "
                       "asserted <= analytic (S-1)/(M+S-1) + 10%"}
    path = HERE.parent / "BENCH_pipeline.json"
    path.write_text(json.dumps(payload, indent=2) + "\n")
    _row("pipeline/written", 0.0, str(path))


def bench_zero1():
    """ZeRO-1 optimizer-state sharding vs the replicated baseline
    (tentpole of DESIGN.md §9): measured per-device opt-state bytes from
    the bundles' real NamedShardings (must shrink ~dp x), step wall-clock,
    loss parity, and the Eq. 8 + ZeRO memory-model prediction — persisted
    to BENCH_zero1.json."""
    out = _sub("zero1_memory")
    for name, d in out.items():
        r, z = d["replicated"], d["zero1"]
        _row(f"zero1/{name}/replicated", r["us_per_step"],
             f"opt={r['opt_state_bytes_per_device']/2**20:.2f}MiB/dev")
        _row(f"zero1/{name}/zero1", z["us_per_step"],
             f"opt={z['opt_state_bytes_per_device']/2**20:.2f}MiB/dev "
             f"ratio={d['measured_ratio']:.2f}x "
             f"(model {d['model_pred_ratio']:.2f}x) "
             f"max_loss_dev={d['max_loss_dev']:.1e}")
    # (ratio > 3.2 and loss parity are asserted inside the benchruns
    # subprocess; a violation fails _sub before reaching here)
    payload = {**out,
               "note": "8 fake CPU host devices, yi-6b reduced, B=8 S=32; "
                       "wall-clock indicative only; opt-state bytes are "
                       "exact (NamedSharding shard shapes x itemsize); "
                       "parity max_loss_dev asserted < 1e-5 in-run; "
                       "model_pred_* from roofline.analysis."
                       "optimizer_state_bytes (Eq. 8 + ZeRO term)"}
    path = HERE.parent / "BENCH_zero1.json"
    path.write_text(json.dumps(payload, indent=2) + "\n")
    _row("zero1/written", 0.0, str(path))


def bench_serve():
    """Continuous batching vs the static-batch decode loop on a mixed-length
    workload (tokens/s and p50/p95 per-token latency per batch size), plus
    the radix prefix cache on a shared-system-prompt workload (DESIGN.md
    §12), persisted to BENCH_serve.json.  Greedy tokens are asserted
    identical inside the subprocess; the engine must win tokens/s; the
    cache's deterministic reuse counters are regression-gated exact-match
    against the committed file, its TTFT-p95 reduction against a floor."""
    out = _sub("serve_throughput")
    out.update(_sub("serve_prefix"))
    out.update(_sub("serve_spec"))
    payload = {**out,
               "note": "8 fake CPU host devices, tesseract [2,2,1] x dp2, "
                       "yi-6b reduced; wall-clock indicative only; greedy "
                       "token parity engine==static, prefix-cache-on==off "
                       "and speculative==plain asserted in-run"}
    path = HERE.parent / "BENCH_serve.json"
    # diff the deterministic prefix + speculation counters BEFORE
    # overwriting
    regressions = []
    pf = out["prefix"]
    sp = out["spec"]
    if path.exists():
        old = json.loads(path.read_text())
        if "prefix" in old:
            opf = old["prefix"]
            # same seeds, same greedy workload -> exact counters
            for k in ("cache_hit_rate", "prefix_tokens_reused",
                      "prefix_tokens_total", "cow_splits", "tokens"):
                old_v = opf["on"].get(k)
                if old_v is not None and pf["on"][k] != old_v:
                    regressions.append(
                        f"prefix.on.{k}: {old_v} -> {pf['on'][k]} (exact)")
        # a committed file without a "spec" section predates speculative
        # decoding: re-baseline instead of failing
        if "spec" in old:
            osp = old["spec"]
            for cell in ("ngram", "draft_ideal"):
                for k in ("steps", "spec_rounds", "spec_proposed",
                          "spec_accepted", "spec_committed",
                          "acceptance_rate", "tokens_per_round"):
                    old_v = osp.get(cell, {}).get(k)
                    if old_v is not None and sp[cell][k] != old_v:
                        regressions.append(
                            f"spec.{cell}.{k}: {old_v} -> "
                            f"{sp[cell][k]} (exact)")
    path.write_text(json.dumps(payload, indent=2) + "\n")
    losses = []
    for key, d in out.items():
        if not key.startswith("slots"):
            continue
        e, s = d["engine"], d["static"]
        _row(f"serve/{key}/engine", 0.0,
             f"{e['tokens_per_s']:.1f} tok/s p50={e['p50_ms']:.1f}ms "
             f"p95={e['p95_ms']:.1f}ms "
             f"ttft_p50={e['ttft']['p50_ms']:.1f}ms "
             f"itl_p50={e['itl']['p50_ms']:.1f}ms")
        _row(f"serve/{key}/static", 0.0,
             f"{s['tokens_per_s']:.1f} tok/s p50={s['p50_ms']:.1f}ms "
             f"p95={s['p95_ms']:.1f}ms")
        if not d["engine_wins"]:
            losses.append(key)
    on, off = pf["on"], pf["off"]
    _row("serve/prefix/on", 0.0,
         f"hit_rate={on['cache_hit_rate']:.3f} "
         f"reused={on['prefix_tokens_reused']}/{on['prefix_tokens_total']} "
         f"cow={on['cow_splits']} chunks={on['prefill_chunks']} "
         f"ttft_p95={on['ttft']['p95_ms']:.1f}ms")
    _row("serve/prefix/off", 0.0,
         f"ttft_p95={off['ttft']['p95_ms']:.1f}ms "
         f"(reduction {pf['ttft_p95_reduction'] * 100:+.1f}%)")
    for cell in ("ngram", "draft_ideal"):
        c = sp[cell]
        _row(f"serve/spec/{cell}", 0.0,
             f"acceptance={c['acceptance_rate']:.2f} "
             f"tokens/round={c['tokens_per_round']:.2f} "
             f"steps {sp['plain']['steps']}->{c['steps']} "
             f"({c['speedup_steps']:.2f}x) "
             f"model={c['model_speedup_at_recorded_acceptance']:.2f}x")
    _row("serve/written", 0.0, str(path))
    # persisted first so a noisy wall-clock loss stays diagnosable
    assert not losses, f"continuous batching lost at {losses}: see {path}"
    assert pf["on"]["cache_hit_rate"] > 0, "prefix cache never hit"
    # wall-clock floor, not a point estimate: the cache must never make
    # TTFT materially WORSE than cache-off (CPU jitter tolerance 10%)
    assert pf["ttft_p95_reduction"] > -0.10, \
        f"prefix cache regressed TTFT p95 by " \
        f"{-pf['ttft_p95_reduction'] * 100:.1f}%: see {path}"
    # speculation floors (ISSUE 9): the ideal-draft cell must measure >2x
    # fewer engine decode steps end-to-end, and the recorded acceptance
    # rates must map to >2x modeled decode tok/s on a memory-bound target
    assert sp["draft_ideal"]["speedup_steps"] > 2.0, \
        f"ideal-draft speculation only " \
        f"{sp['draft_ideal']['speedup_steps']:.2f}x in steps: see {path}"
    for cell in ("ngram", "draft_ideal"):
        m = sp[cell]["model_speedup_at_recorded_acceptance"]
        assert m > 2.0, \
            f"spec.{cell}: modeled decode tok/s {m:.2f}x <= 2x at " \
            f"acceptance {sp[cell]['acceptance_rate']:.2f}: see {path}"
    assert not regressions, "; ".join(regressions) + f": see {path}"


def bench_resilience():
    """The ISSUE-6 chaos schedules as regression-gated metrics (DESIGN.md
    §11): train NaN + corrupt-ckpt + 8->4 device loss, serve NaN logits +
    dropped step + pool exhaustion.  Hard invariants (trajectory rejoin,
    bit-exact survivor parity, identical replay) are asserted outright;
    numeric metrics are diffed against the committed BENCH_resilience.json
    with thresholds before the file is refreshed."""
    out = _sub("resilience")
    tr, sv = out["train"], out["serve"]

    # hard invariants — a regression here is a correctness bug, not noise
    assert tr["trajectory_rejoined"], "train did not rejoin fault-free loss"
    assert tr["replay_identical"], "train chaos replay diverged"
    assert sv["survivor_parity"], "serve survivors lost greedy parity"
    assert sv["replay_identical"], "serve chaos replay diverged"
    assert sv["failed"] == 0, f"{sv['failed']} requests failed under chaos"

    path = HERE.parent / "BENCH_resilience.json"
    regressions = []
    if path.exists():
        old = json.loads(path.read_text())
        otr, osv = old["train"], old["serve"]
        # seeded schedule -> these counters are deterministic: exact match
        for side, new, prev, keys in (
                ("train", tr, otr, ("faults_fired", "nan_skips",
                                    "ckpt_fallbacks", "restarts")),
                ("serve", sv, osv, ("nan_quarantines", "dropped_steps",
                                    "pool_exhaust_events", "shed"))):
            for k in keys:
                if new[k] != prev[k]:
                    regressions.append(
                        f"{side}.{k}: {prev[k]} -> {new[k]} (exact)")
        # recovery cost may wobble slightly, never balloon
        if tr["goodput"] < otr["goodput"] - 0.05:
            regressions.append(
                f"train.goodput: {otr['goodput']:.3f} -> "
                f"{tr['goodput']:.3f} (floor {otr['goodput'] - 0.05:.3f})")
        if tr["recovery_steps"] > otr["recovery_steps"] + 1:
            regressions.append(
                f"train.recovery_steps: {otr['recovery_steps']} -> "
                f"{tr['recovery_steps']}")
        if sv["extra_steps"] > osv["extra_steps"] + 2:
            regressions.append(
                f"serve.extra_steps: {osv['extra_steps']} -> "
                f"{sv['extra_steps']}")

    payload = {**out,
               "note": "8 fake CPU host devices; seeded FaultPlan schedules "
                       "(train seed=13, serve seed=17, DESIGN.md §11); "
                       "rejoin/parity/replay asserted in-run; counters are "
                       "deterministic, goodput/recovery thresholds guard "
                       "the recovery tax"}
    path.write_text(json.dumps(payload, indent=2) + "\n")
    _row("resilience/train", 0.0,
         f"goodput={tr['goodput']:.3f} recovery_steps={tr['recovery_steps']} "
         f"nan_skips={tr['nan_skips']} ckpt_fallbacks={tr['ckpt_fallbacks']} "
         f"rejoined={tr['trajectory_rejoined']}")
    _row("resilience/serve", 0.0,
         f"quarantines={sv['nan_quarantines']} "
         f"preemptions={sv['preemptions']} extra_steps={sv['extra_steps']} "
         f"parity={sv['survivor_parity']} replay={sv['replay_identical']}")
    _row("resilience/written", 0.0, str(path))
    # persisted first so a threshold trip stays diagnosable from the file
    assert not regressions, "resilience regressions: " + "; ".join(regressions)


def bench_attention():
    """Fused Pallas attention everywhere (DESIGN.md §10), persisted to
    BENCH_attention.json: q in {1,2} training parity jnp vs pallas
    (asserted in the subprocess), flash bwd vs jax.vjp(blockwise_attention)
    max grad errors (asserted < 5e-5), paged decode kernel vs the gather
    path (modeled v5e tok/s — the kernel must win — plus indicative CPU
    wall clock with greedy-argmax parity asserted), autotuned tiles."""
    out = _sub("attention")
    for name, d in out["train"].items():
        _row(f"attention/train/{name}/jnp", d["jnp"]["us_per_step"],
             f"loss={d['jnp']['losses'][-1]:.4f}")
        _row(f"attention/train/{name}/pallas", d["pallas"]["us_per_step"],
             f"max_loss_dev={d['max_loss_dev']:.1e} (fp32 parity asserted)")
    pd = out["paged_decode"]
    m, c = pd["modeled_v5e"], pd["measured_cpu_interpret"]
    _row("attention/paged_decode/modeled_v5e", 0.0,
         f"kernel {m['kernel_tok_s']:.0f} tok/s vs gather "
         f"{m['gather_tok_s']:.0f} tok/s "
         f"({m['gather_bytes']/m['kernel_bytes']:.1f}x less HBM traffic)")
    _row("attention/paged_decode/cpu_interpret", c["kernel_us_per_step"],
         f"kernel {c['kernel_tok_s']:.1f} vs gather {c['gather_tok_s']:.1f} "
         f"tok/s (interpreter-bound, indicative; argmax parity asserted)")
    for w, errs in out["flash_bwd_vs_jax_vjp"].items():
        if not w.startswith("window"):
            continue
        _row(f"attention/flash_bwd/{w}", 0.0,
             f"dq={errs['dq']:.1e} dk={errs['dk']:.1e} dv={errs['dv']:.1e} "
             f"vs jax.vjp(blockwise_attention)")
    for sweep in out["autotuned_tiles"]:
        sh = sweep["shape"]
        _row(f"attention/autotune/T{sh['Tq']}_D{sh['D']}", 0.0,
             f"best=({sweep['best'][0]},{sweep['best'][1]}) "
             f"from {len(sweep['timings_s'])} candidates")
    payload = {**out,
               "note": "8 fake CPU host devices, yi-6b reduced; kernels run "
                       "in interpret mode (TPU is the target, not the "
                       "runtime), so wall-clock is indicative only — the "
                       "decode win is the HBM-traffic roofline "
                       "(roofline/analysis.paged_decode_traffic); parity "
                       "(train fp32, bwd vs jax.vjp, greedy argmax) is "
                       "asserted in-run"}
    path = HERE.parent / "BENCH_attention.json"
    path.write_text(json.dumps(payload, indent=2) + "\n")
    _row("attention/written", 0.0, str(path))
    assert pd["kernel_wins"], pd


def bench_longctx():
    """Ring/striped flash attention over the seq mesh axis (DESIGN.md §15),
    persisted to BENCH_longctx.json: striped fp32 training parity and the
    byte-exact seq-axis ppermute conformance are asserted inside the
    subprocess; here the deterministic wire counters are exact-match
    regression-gated against the committed file, and the measured
    context-at-iso-memory ratio has a hard >= 2x floor."""
    out = _sub("longctx")
    path = HERE.parent / "BENCH_longctx.json"
    regressions = []
    if path.exists():
        old = json.loads(path.read_text())
        for cell, d in out["wire_conformance"].items():
            prev = old.get("wire_conformance", {}).get(cell, {})
            # same model, same grid, same comm model -> exact counters
            for k in ("traced_ppermutes", "traced_wire_bytes"):
                if prev.get(k) is not None and d[k] != prev[k]:
                    regressions.append(
                        f"wire.{cell}.{k}: {prev[k]} -> {d[k]} (exact)")
    iso = out["iso_memory"]
    for name, d in out["train"].items():
        _row(f"longctx/train/{name}", d["us_per_step"],
             f"max_loss_dev={d.get('max_loss_dev', 0.0):.1e} "
             f"(striped==local asserted)" if "max_loss_dev" in d
             else "reference")
    for cell, d in out["wire_conformance"].items():
        _row(f"longctx/wire/{cell}", 0.0,
             f"{d['traced_ppermutes']} seq-ppermutes "
             f"{d['traced_wire_bytes']}B == ring_attention_traffic "
             f"(byte-exact)")
    _row("longctx/iso_memory", 0.0,
         f"{iso['context_ratio']:.0f}x context at "
         f"{iso['temp_bytes_ratio']:.2f}x per-device temp bytes "
         f"-> {iso['context_per_memory_ratio']:.2f}x")
    m = out["modeled_v5e"]
    for nm in ("train_128k_seq8", "prefill_128k_seq8"):
        _row(f"longctx/modeled/{nm}", 0.0,
             f"wire={m[nm]['wire_bytes']/2**30:.2f}GiB "
             f"exposed_fwd={m[nm]['exposed_comm_s_fwd_per_layer']*1e3:.2f}"
             f"ms/layer hidden={m[nm]['comm_hidden']}")
    for sweep in out["ring_step_autotune"]:
        _row(f"longctx/autotune/seq{sweep['seq_shards']}", 0.0,
             f"L={sweep['ring_step_Tk']} best=({sweep['best'][0]},"
             f"{sweep['best'][1]})")
    payload = {**out,
               "note": "8 fake CPU host devices, yi-6b reduced; wall-clock "
                       "indicative only; striped fp32 parity and byte-exact "
                       "seq-ppermute conformance asserted in-run; iso-memory "
                       "cells are measured XLA buffer assignments (context "
                       "grows with seq at fixed per-device tokens)"}
    path.write_text(json.dumps(payload, indent=2) + "\n")
    _row("longctx/written", 0.0, str(path))
    # persisted first so a threshold trip stays diagnosable from the file
    assert iso["context_per_memory_ratio"] >= 2.0, iso
    assert not regressions, "; ".join(regressions) + f": see {path}"


def bench_shardcheck(mode: str = "--check"):
    """The shardcheck gate (DESIGN.md §13): sweep every traced entry point
    and diff the extracted collective IR against the committed
    SHARDCHECK.json — the same discipline as the BENCH_*.json gates, but
    for the collective CONTRACT rather than measured numbers.  ``--update``
    refreshes the baseline after a reviewed contract change."""
    env = dict(os.environ, PYTHONPATH=SRC)
    env.pop("XLA_FLAGS", None)
    r = subprocess.run(
        [sys.executable, "-m", "repro.analysis.shardcheck", mode,
         "--baseline", str(HERE.parent / "SHARDCHECK.json")],
        env=env, capture_output=True, text=True, timeout=2400,
        cwd=str(HERE.parent))
    tail = "\n".join((r.stdout + r.stderr).strip().splitlines()[-12:])
    _row("shardcheck/gate", 0.0,
         f"rc={r.returncode} ({mode})")
    if r.returncode != 0:
        raise RuntimeError(
            f"shardcheck {mode} failed — collective contract drift or "
            f"rule finding:\n{tail}")


def bench_roofline_summary():
    res = HERE / "results" / "dryrun"
    if not res.exists():
        _row("roofline/missing", 0.0, "run repro.launch.dryrun --all first")
        return
    for p in sorted(res.glob("*__16x16.json")):
        d = json.loads(p.read_text())
        tot = (d["compute_term_s"] + d["memory_term_s"]
               + d["collective_term_s"])
        _row(f"roofline/{d['arch']}/{d['shape']}", tot * 1e6,
             f"dominant={d['dominant']} useful={d['useful_flops_frac']:.2f}")


def main() -> None:
    quick = "--quick" in sys.argv
    if "--check" in sys.argv:
        # drift-gate-only mode for CI: nonzero exit on SHARDCHECK.json
        # drift, rule findings, or lint findings — no measurements
        print("name,us_per_call,derived")
        bench_shardcheck("--check")
        return
    print("name,us_per_call,derived")
    bench_ratios_p64()
    bench_table1()
    bench_table2()
    bench_roofline_summary()
    if not quick:
        bench_matmul_schedules()
        bench_pipeline()
        bench_zero1()
        bench_serve()
        bench_resilience()
        bench_attention()
        bench_longctx()
        bench_fig7_accuracy()
        bench_measured_strong()
        bench_shardcheck("--check")


if __name__ == '__main__':
    main()
