"""Analytic communication model for 1-D / 2-D / 2.5-D tensor parallelism.

Validates the paper's §1 claims (transmission-count ratios vs Cannon and
2.5-D-Cannon at p=64) and provides the per-layer communication volumes that
drive the Table-1/Table-2 analogues.  The byte model mirrors OUR collective
schedule (DESIGN.md §2) and is cross-validated against the dry-run's parsed
HLO collectives (tests/test_comm_model.py).
"""
from __future__ import annotations

import math
from dataclasses import dataclass


# --------------------------------------------------------------------------
# paper §1: transmission counts per matmul (message counts, not bytes)
# --------------------------------------------------------------------------

def cannon_transmissions(p: int) -> float:
    return 2 * p ** 1.5 - 2 * p ** 0.5


def dim25_transmissions(p: int) -> float:
    return 2 * p - 2 * p ** (1 / 3)


def tesseract_transmissions(p: int) -> float:
    # d = q = p^(1/3): 2 * p^(2/3)
    return 2 * p ** (2 / 3)


def paper_ratio_check(p: int = 64):
    t = tesseract_transmissions(p)
    return cannon_transmissions(p) / t, dim25_transmissions(p) / t


# --------------------------------------------------------------------------
# byte volumes of our schedules (per device, per transformer layer)
# --------------------------------------------------------------------------

@dataclass
class LayerDims:
    b: int          # global batch
    s: int          # sequence
    h: int          # d_model
    ff: int         # mlp hidden (glu counted via n_up)
    heads: int
    kv_heads: int
    head_dim: int
    glu: bool = True
    dtype_bytes: int = 2


def _linears(d: LayerDims):
    hd = d.heads * d.head_dim
    kvd = d.kv_heads * d.head_dim
    ls = [(d.h, hd), (d.h, kvd), (d.h, kvd), (hd, d.h), (d.ff, d.h)]
    ls += [(d.h, d.ff)] * (2 if d.glu else 1)
    return ls


def tesseract_layer_bytes(d: LayerDims, q: int, depth: int, data: int,
                          *, cache_w: bool = True, train: bool = True) -> float:
    """Per-device bytes moved by the tesseract collectives for one layer."""
    e_loc = d.b * d.s / (data * depth * q)
    total = 0.0
    for (fin, fout) in _linears(d):
        a_loc = e_loc * fin / q
        w_loc = fin * fout / (q * q)
        ag_a = (q - 1) * a_loc          # gather A over col (fwd)
        ag_w = (q - 1) * w_loc          # gather W over row (fwd)
        total += ag_a + ag_w
        if train:
            rs_da = (q - 1) / q * (e_loc * fin)   # reduce-scatter dA over col
            ag_a_b = (q - 1) * a_loc              # re-gather A in bwd
            ag_w_b = 0.0 if cache_w else (q - 1) * w_loc
            rs_dw = (q - 1) / q * (fin * fout / q)
            ar_dw_depth = 2 * (depth - 1) / depth * w_loc  # depth all-reduce
            total += rs_da + ag_a_b + ag_w_b + rs_dw + ar_dw_depth
    return total * d.dtype_bytes


def megatron_layer_bytes(d: LayerDims, p: int, data: int, *,
                         train: bool = True) -> float:
    """1-D: two output all-reduces of the full activation (attn out, mlp out)
    forward; two more backward."""
    act = d.b * d.s * d.h / data
    n_ar = 2 * (2 if train else 1)
    return n_ar * 2 * (p - 1) / p * act * d.dtype_bytes


def layer_bytes(mode: str, d: LayerDims, shape, data: int,
                train: bool = True) -> float:
    if mode == "megatron1d":
        (p,) = shape
        return megatron_layer_bytes(d, p, data, train=train)
    q, q2, depth = shape
    assert q == q2
    return tesseract_layer_bytes(d, q, depth, data, train=train)


# --------------------------------------------------------------------------
# simple execution-time model (v5e constants) for table analogues
# --------------------------------------------------------------------------

PEAK = 197e12
LINK_BW = 50e9
HOP_LATENCY = 5e-6   # per ring hop (message latency; differentiates large q)


# --------------------------------------------------------------------------
# schedule-aware matmul cost model: fused all-gather vs overlapped ring
# (core/summa.py matmul_schedule, DESIGN.md §2b).
#
# Accounting assumptions (both schedules, stated in DESIGN.md §2b):
#   * WEIGHT movement (W gathers / W ring streams) is prefetchable — weights
#     exist before the step runs, so a double-buffered prefetch hides those
#     bytes behind earlier compute.  Weight-GRADIENT movement is produced
#     in-step and cannot prefetch.
#   * ACTIVATION movement cannot prefetch (produced by the preceding op).
#
# fused : activation gathers / reduce-scatters serialize with the einsums —
#         every activation wire byte is EXPOSED, and the backward holds the
#         re-gathered A and the [q, ...] dA/dW partial stacks concurrently:
#         peak schedule temporaries are O(q · block).
# ring  : per SUMMA step one block pair is in flight while the MXU contracts
#         the resident pair; per-step exposed communication is
#         max(0, t_comm_step - t_compute_step).  Only the Cannon skew /
#         final unskew of activation-sized blocks is unconditionally
#         exposed.  Peak resident schedule temporaries are 2 blocks per
#         operand (resident + in-flight) regardless of q — the two-pass
#         ring backward (core/summa.py) never materializes a [q, ...]
#         stack.
#
# Consequences the model surfaces (and the tests pin):
#   * peak memory: ring < fused for every q >= 2 in training (2·(a+w) vs
#     q·(2a+w)); equal at q=2 for inference-only.
#   * exposed comm: ring wins when per-step arithmetic intensity clears the
#     machine balance (large g_loc — big models / small q) and for q >= 4;
#     at q=2 a ring shift IS the fused exchange plus a skew, so the model
#     honestly recommends fused ("ring_wins": False).
# --------------------------------------------------------------------------

@dataclass
class ScheduleCost:
    schedule: str
    comm_bytes: float           # total wire bytes per device
    compute_s: float            # MXU time per device
    exposed_comm_s: float       # communication time NOT hidden by compute
    peak_gathered_bytes: float  # resident gathered/streamed operand bytes

    @property
    def total_s(self) -> float:
        return self.compute_s + self.exposed_comm_s


def _matmul_cost(e_loc: float, fin: int, fout: int, q: int,
                 *, schedule: str, train: bool, cache_w: bool,
                 dtype_bytes: int, peak: float = PEAK,
                 bw: float = LINK_BW, hop: float = HOP_LATENCY) -> ScheduleCost:
    """Cost of ONE Tesseract matmul (fwd, + both bwd contractions if train)."""
    a_blk = e_loc * fin / q * dtype_bytes            # [E_loc, F_loc]
    w_blk = fin * fout / (q * q) * dtype_bytes       # [F_loc, G_loc]
    step_flops = 2.0 * e_loc * (fin / q) * (fout / q)
    step_comp = step_flops / peak
    fwd_comp = q * step_comp
    bwd_comp = 2.0 * fwd_comp                        # dA + dW contractions

    if schedule == "fused":
        fwd_bytes = (q - 1) * (a_blk + w_blk)        # AG_A(col) + AG_W(row)
        exposed = (q - 1) * a_blk / bw + (q - 1) * hop
        comm = fwd_bytes
        comp = fwd_comp
        if train:
            ag_a = (q - 1) * a_blk                   # re-gather A for dW
            ag_w = 0.0 if cache_w else (q - 1) * w_blk  # prefetchable
            rs_da = (q - 1) * a_blk                  # RS dA(col): act grads
            rs_dw = (q - 1) * w_blk                  # RS dW(row): wgt grads
            comm += ag_a + ag_w + rs_da + rs_dw
            # gradients are produced in-step: nothing to prefetch
            exposed += (ag_a + rs_da + rs_dw) / bw + 3 * (q - 1) * hop
            comp += bwd_comp
        # Peak schedule temporaries: fwd holds the two q-gathered operands;
        # the train bwd holds the re-gathered A and the [q, ...] dA / dW
        # partial stacks concurrently.
        peak_bytes = q * (2 * a_blk + w_blk) if train else q * (a_blk + w_blk)
        return ScheduleCost("fused", comm, comp, exposed, peak_bytes)

    if schedule != "ring":
        raise ValueError(f"unknown schedule {schedule!r}")
    if q == 1:
        comp = fwd_comp + (bwd_comp if train else 0.0)
        return ScheduleCost("ring", 0.0, comp, 0.0, a_blk + w_blk)
    # forward: A skew (pipeline fill) exposed; W skew/stream prefetched;
    # the (q-1) in-flight A shifts overlap with the step contractions.
    comm = q * (a_blk + w_blk)                       # skews + (q-1) shifts
    exposed = a_blk / bw + hop \
        + (q - 1) * max(0.0, a_blk / bw + hop - step_comp)
    comp = fwd_comp
    if train:
        # two-pass bwd: dA pass (W stream prefetched, dA pieces ride the col
        # accumulator ring), then dW pass (A re-streamed, dW pieces ride the
        # row accumulator ring).  Accumulator shifts overlap with the next
        # step's contraction; only the final fixup shifts are exposed.
        comm += 2.0 * q * (a_blk + w_blk)
        exposed += (q - 1) * max(0.0, a_blk / bw + hop - step_comp) \
            + a_blk / bw + hop                       # dA fixup
        exposed += a_blk / bw + hop \
            + (q - 1) * max(0.0, (a_blk + w_blk) / bw + hop - step_comp) \
            + w_blk / bw + hop                       # A skew + dW fixup
        comp += bwd_comp
    # Resident + in-flight block per stream; the two-pass bwd never holds
    # more than one stream + one accumulator ring — O(1) in q.
    peak_bytes = 2 * (a_blk + w_blk)
    return ScheduleCost("ring", comm, comp, exposed, peak_bytes)


def schedule_layer_cost(d: LayerDims, q: int, depth: int, data: int, *,
                        schedule: str, train: bool = True,
                        cache_w: bool = True) -> ScheduleCost:
    """Aggregate ScheduleCost over the transformer layer's matmuls."""
    e_loc = d.b * d.s / (data * depth * q)
    comm = comp = exposed = 0.0
    peak_g = 0.0
    for (fin, fout) in _linears(d):
        c = _matmul_cost(e_loc, fin, fout, q, schedule=schedule, train=train,
                         cache_w=cache_w, dtype_bytes=d.dtype_bytes)
        comm += c.comm_bytes
        comp += c.compute_s
        exposed += c.exposed_comm_s
        peak_g = max(peak_g, c.peak_gathered_bytes)
    return ScheduleCost(schedule, comm, comp, exposed, peak_g)


def ring_vs_fused(d: LayerDims, q: int, depth: int, data: int, *,
                  train: bool = True) -> dict:
    """Side-by-side schedule comparison for a layer; the analytic answer to
    'when does ring beat fused for this (q, depth, shape)?'."""
    fused = schedule_layer_cost(d, q, depth, data, schedule="fused",
                                train=train)
    ring = schedule_layer_cost(d, q, depth, data, schedule="ring",
                               train=train)
    return {
        "fused": fused, "ring": ring,
        "exposed_comm_ratio": (ring.exposed_comm_s / fused.exposed_comm_s
                               if fused.exposed_comm_s else 1.0),
        "peak_memory_ratio": (ring.peak_gathered_bytes
                              / fused.peak_gathered_bytes
                              if fused.peak_gathered_bytes else 1.0),
        "ring_wins": ring.total_s < fused.total_s,
    }


def layer_flops(d: LayerDims, train: bool = True) -> float:
    f = 0.0
    for (fin, fout) in _linears(d):
        f += 2.0 * d.b * d.s * fin * fout
    f += 4.0 * d.b * d.s * d.s * d.heads * d.head_dim  # attention scores+out
    return f * (3.0 if train else 1.0)                  # bwd ~ 2x fwd


def layer_hops(mode: str, shape, train: bool = True) -> float:
    """Ring-hop count per layer: each collective over a group of n costs
    (n-1) serialized hops; bigger q pays more latency (paper's [8,8,1] vs
    [4,4,4] observation)."""
    if mode == "megatron1d":
        (p,) = shape
        return (2 if not train else 4) * (p - 1)
    q, _, depth = shape
    n_lin = 7
    per_lin = 2 * (q - 1)                       # AG_A + AG_W fwd
    if train:
        per_lin += 3 * (q - 1) + 2 * (depth - 1)  # RS_dA, AG_A, RS_dW, AR_d
    return n_lin * per_lin


def modeled_layer_time(mode: str, d: LayerDims, shape, data: int = 1,
                       train: bool = True, schedule: str = "fused") -> float:
    p = math.prod(shape)
    comp = layer_flops(d, train=train) / (p * data * PEAK)
    if mode != "megatron1d" and schedule == "ring":
        q, _, depth = shape
        c = schedule_layer_cost(d, q, depth, data, schedule="ring",
                                train=train)
        # the depth all-reduce of dW is schedule-independent (it reduces
        # over the replicated depth copies, not the [q, q] grid) — charge
        # it exactly as layer_bytes does for the fused path.
        ar_depth_s = 0.0
        if train and depth > 1:
            ar_bytes = sum(2 * (depth - 1) / depth * fin * fout / (q * q)
                           for fin, fout in _linears(d)) * d.dtype_bytes
            ar_depth_s = ar_bytes / LINK_BW + 2 * (depth - 1) * HOP_LATENCY
        return comp + c.exposed_comm_s + ar_depth_s
    comm = layer_bytes(mode, d, shape, data, train=train)
    lat = layer_hops(mode, shape, train) * HOP_LATENCY
    return comp + comm / LINK_BW + lat
