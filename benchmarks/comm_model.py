"""Analytic communication model for 1-D / 2-D / 2.5-D tensor parallelism.

Validates the paper's §1 claims (transmission-count ratios vs Cannon and
2.5-D-Cannon at p=64) and provides the per-layer communication volumes that
drive the Table-1/Table-2 analogues.  The byte model mirrors OUR collective
schedule (DESIGN.md §2) and is cross-validated against the dry-run's parsed
HLO collectives (tests/test_comm_model.py).
"""
from __future__ import annotations

import math
from dataclasses import dataclass


# --------------------------------------------------------------------------
# paper §1: transmission counts per matmul (message counts, not bytes)
# --------------------------------------------------------------------------

def cannon_transmissions(p: int) -> float:
    return 2 * p ** 1.5 - 2 * p ** 0.5


def dim25_transmissions(p: int) -> float:
    return 2 * p - 2 * p ** (1 / 3)


def tesseract_transmissions(p: int) -> float:
    # d = q = p^(1/3): 2 * p^(2/3)
    return 2 * p ** (2 / 3)


def paper_ratio_check(p: int = 64):
    t = tesseract_transmissions(p)
    return cannon_transmissions(p) / t, dim25_transmissions(p) / t


# --------------------------------------------------------------------------
# byte volumes of our schedules (per device, per transformer layer)
# --------------------------------------------------------------------------

@dataclass
class LayerDims:
    b: int          # global batch
    s: int          # sequence
    h: int          # d_model
    ff: int         # mlp hidden (glu counted via n_up)
    heads: int
    kv_heads: int
    head_dim: int
    glu: bool = True
    dtype_bytes: int = 2


def _linears(d: LayerDims):
    hd = d.heads * d.head_dim
    kvd = d.kv_heads * d.head_dim
    ls = [(d.h, hd), (d.h, kvd), (d.h, kvd), (hd, d.h), (d.ff, d.h)]
    ls += [(d.h, d.ff)] * (2 if d.glu else 1)
    return ls


def tesseract_layer_bytes(d: LayerDims, q: int, depth: int, data: int,
                          *, cache_w: bool = True, train: bool = True) -> float:
    """Per-device bytes moved by the tesseract collectives for one layer."""
    e_loc = d.b * d.s / (data * depth * q)
    total = 0.0
    for (fin, fout) in _linears(d):
        a_loc = e_loc * fin / q
        w_loc = fin * fout / (q * q)
        ag_a = (q - 1) * a_loc          # gather A over col (fwd)
        ag_w = (q - 1) * w_loc          # gather W over row (fwd)
        total += ag_a + ag_w
        if train:
            rs_da = (q - 1) / q * (e_loc * fin)   # reduce-scatter dA over col
            ag_a_b = (q - 1) * a_loc              # re-gather A in bwd
            ag_w_b = 0.0 if cache_w else (q - 1) * w_loc
            rs_dw = (q - 1) / q * (fin * fout / q)
            ar_dw_depth = 2 * (depth - 1) / depth * w_loc  # depth all-reduce
            total += rs_da + ag_a_b + ag_w_b + rs_dw + ar_dw_depth
    return total * d.dtype_bytes


def megatron_layer_bytes(d: LayerDims, p: int, data: int, *,
                         train: bool = True) -> float:
    """1-D: two output all-reduces of the full activation (attn out, mlp out)
    forward; two more backward."""
    act = d.b * d.s * d.h / data
    n_ar = 2 * (2 if train else 1)
    return n_ar * 2 * (p - 1) / p * act * d.dtype_bytes


def layer_bytes(mode: str, d: LayerDims, shape, data: int,
                train: bool = True) -> float:
    if mode == "megatron1d":
        (p,) = shape
        return megatron_layer_bytes(d, p, data, train=train)
    q, q2, depth = shape
    assert q == q2
    return tesseract_layer_bytes(d, q, depth, data, train=train)


# --------------------------------------------------------------------------
# simple execution-time model (v5e constants) for table analogues
# --------------------------------------------------------------------------

PEAK = 197e12
LINK_BW = 50e9
HOP_LATENCY = 5e-6   # per ring hop (message latency; differentiates large q)


def layer_flops(d: LayerDims, train: bool = True) -> float:
    f = 0.0
    for (fin, fout) in _linears(d):
        f += 2.0 * d.b * d.s * fin * fout
    f += 4.0 * d.b * d.s * d.s * d.heads * d.head_dim  # attention scores+out
    return f * (3.0 if train else 1.0)                  # bwd ~ 2x fwd


def layer_hops(mode: str, shape, train: bool = True) -> float:
    """Ring-hop count per layer: each collective over a group of n costs
    (n-1) serialized hops; bigger q pays more latency (paper's [8,8,1] vs
    [4,4,4] observation)."""
    if mode == "megatron1d":
        (p,) = shape
        return (2 if not train else 4) * (p - 1)
    q, _, depth = shape
    n_lin = 7
    per_lin = 2 * (q - 1)                       # AG_A + AG_W fwd
    if train:
        per_lin += 3 * (q - 1) + 2 * (depth - 1)  # RS_dA, AG_A, RS_dW, AR_d
    return n_lin * per_lin


def modeled_layer_time(mode: str, d: LayerDims, shape, data: int = 1,
                       train: bool = True) -> float:
    p = math.prod(shape)
    comm = layer_bytes(mode, d, shape, data, train=train)
    comp = layer_flops(d, train=train) / (p * data * PEAK)
    lat = layer_hops(mode, shape, train) * HOP_LATENCY
    return comp + comm / LINK_BW + lat
