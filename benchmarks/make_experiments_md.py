"""Assemble EXPERIMENTS.md from the dry-run JSONs, hillclimb results and
benchmark outputs.  Rerun any time:  PYTHONPATH=src python -m benchmarks.make_experiments_md
"""
from __future__ import annotations

import json
import pathlib
import sys

HERE = pathlib.Path(__file__).resolve().parent
sys.path.insert(0, str(HERE.parent / "src"))
sys.path.insert(0, str(HERE.parent))

from benchmarks import comm_model, tables  # noqa: E402
from repro.roofline import report  # noqa: E402

RESULTS = HERE / "results" / "dryrun"
OUT = HERE.parent / "EXPERIMENTS.md"


def baseline_table(mesh):
    rows = [report.HEADER]
    for d in report.load_cells(mesh, "tesseract"):
        if d.get("tag"):
            continue
        rows.append(report.row(d))
    return "\n".join(rows)


def _cell(arch, shape, mode="tesseract", tag="", mesh="16x16"):
    sfx = f"__{tag}" if tag else ""
    p = RESULTS / f"{arch}__{shape}__{mode}__{mesh}{sfx}.json"
    return json.loads(p.read_text()) if p.exists() else None


def perf_row(eid, label, d, base):
    if d is None:
        return f"| {eid} | {label} | (pending) | | | | |"
    dc = (d["collective_term_s"] - base["collective_term_s"]) / max(
        base["collective_term_s"], 1e-12)
    dk = (d["compute_term_s"] - base["compute_term_s"]) / max(
        base["compute_term_s"], 1e-12)
    return (f"| {eid} | {label} | {d['compute_term_s']:.2f} | "
            f"{d['memory_term_s']:.2f} | {d['collective_term_s']:.2f} | "
            f"{d['useful_flops_frac']:.3f} | comp {dk:+.0%} / coll {dc:+.0%} |")


def skipped_cells():
    from repro.configs.base import LONG_CONTEXT_OK
    from repro.models.registry import ARCH_MODULES
    return [a for a in ARCH_MODULES if a not in LONG_CONTEXT_OK]


def main():
    t1 = tables.table1_speedups()
    t2 = tables.table2_speedups()
    c_ratio, d_ratio = comm_model.paper_ratio_check(64)

    base_A = _cell("llama3-405b", "train_4k")
    base_B = _cell("llama3-405b", "decode_32k")
    base_C = _cell("deepseek-v2-236b", "train_4k")

    perf_A = [
        ("A0", "paper-faithful baseline [2,2,4], per-op depth all-reduce, full remat", base_A),
        ("A1", "cache_act_gather=true (paper 3.2.1 extended to activations)", _cell("llama3-405b", "train_4k", tag="cacheact")),
        ("A2", "grad_compression=bf16 at the grad_sync boundary", _cell("llama3-405b", "train_4k", tag="gradbf16")),
        ("A3", "[4,4,1] factorization (2-D point of the paper)", _cell("llama3-405b", "train_4k", tag="fact441")),
        ("A4", "megatron1d [16] (paper's 1-D baseline)", _cell("llama3-405b", "train_4k", "megatron1d")),
        ("A6", "remat=dots (+A1+A2)", _cell("llama3-405b", "train_4k", tag="dotsremat")),
        ("A7", "dgrad_rs_bf16 (bf16 wire for dW reduce-scatter)", _cell("llama3-405b", "train_4k", tag="rsbf16")),
        ("A8", "deferred fused grad sync (reduce_dgrad_in_op=false)", _cell("llama3-405b", "train_4k", tag="deferred")),
        ("A9", "FINAL: deferred + bf16 wire + dots remat", _cell("llama3-405b", "train_4k", tag="final")),
    ]
    perf_B = [
        ("B0", "paper-faithful tesseract [2,2,4] decode", base_B),
        ("B1", "megatron1d serve layout (weights stationary)", _cell("llama3-405b", "decode_32k", "megatron1d")),
        ("B2", "[4,4,1] (smaller weight-gather fraction)", _cell("llama3-405b", "decode_32k", tag="fact441")),
        ("B3", "summa2d (Optimus) decode", _cell("llama3-405b", "decode_32k", "summa2d")),
    ]
    perf_C = [
        ("C0", "paper-faithful baseline (EP over depth, capacity 1.25)", base_C),
        ("C1", "moe_expert_layout=local (beyond-paper)", _cell("deepseek-v2-236b", "train_4k", tag="moelocal")),
        ("C2", "capacity_factor=1.0", _cell("deepseek-v2-236b", "train_4k", tag="cap10")),
        ("C3", "local layout + deferred + bf16 + dots", _cell("deepseek-v2-236b", "train_4k", tag="best")),
        ("C4", "FINAL: cap 1.0 + deferred + bf16 wire + dots (no local layout)", _cell("deepseek-v2-236b", "train_4k", tag="final")),
    ]

    perf_hdr = ("| id | change | compute s | memory s | collective s | "
                "useful | delta vs baseline |\n|---|---|---|---|---|---|---|")

    def perf_table(base, rows):
        return "\n".join([perf_hdr] + [perf_row(e, l, d, base)
                                       for e, l, d in rows])

    def coll_table(d):
        rows = ["| collective | count | operand GB | ring-wire GB |",
                "|---|---|---|---|"]
        if d is None:
            rows.append("| (pending: run `repro.launch.dryrun --all`) | | | |")
            return "\n".join(rows)
        for k, v in sorted(d["collectives"].items()):
            rows.append(f"| {k} | {int(v['count'])} | "
                        f"{v['operand_bytes']/1e9:.1f} | "
                        f"{v['wire_bytes']/1e9:.1f} |")
        return "\n".join(rows)

    def serve_table():
        p = HERE.parent / "BENCH_serve.json"
        if not p.exists():
            return ("(pending: `PYTHONPATH=src python -m benchmarks.run` "
                    "writes BENCH_serve.json)")
        d = json.loads(p.read_text())
        rows = ["| batch slots | engine tok/s | static tok/s | speedup | "
                "engine p50/p95 ms | static p50/p95 ms |",
                "|---|---|---|---|---|---|"]
        for key in sorted(k for k in d if k.startswith("slots")):
            e, s = d[key]["engine"], d[key]["static"]
            rows.append(
                f"| {key[5:]} | {e['tokens_per_s']:.1f} | "
                f"{s['tokens_per_s']:.1f} | "
                f"{e['tokens_per_s']/s['tokens_per_s']:.2f}x | "
                f"{e['p50_ms']:.1f} / {e['p95_ms']:.1f} | "
                f"{s['p50_ms']:.1f} / {s['p95_ms']:.1f} |")
        if "prefix" in d:
            pf = d["prefix"]
            on, off = pf["on"], pf["off"]
            w = pf["workload"]
            rows.append(
                f"\nRadix prefix cache (DESIGN.md §12), "
                f"{w['shared_prefix_len']}-token shared system prompt x "
                f"{len(w['suffix_lens'])} requests, greedy parity "
                f"cache-on == cache-off asserted in-run:\n\n"
                f"| prefix cache | tok/s | ttft p50/p95 ms | hit rate | "
                f"tokens reused | COW splits |\n|---|---|---|---|---|---|\n"
                f"| off (monolithic prefill) | {off['tokens_per_s']:.1f} | "
                f"{off['ttft']['p50_ms']:.1f} / "
                f"{off['ttft']['p95_ms']:.1f} | — | — | — |\n"
                f"| on (chunked prefill) | {on['tokens_per_s']:.1f} | "
                f"{on['ttft']['p50_ms']:.1f} / {on['ttft']['p95_ms']:.1f} | "
                f"{on['cache_hit_rate']:.3f} | "
                f"{on['prefix_tokens_reused']}/{on['prefix_tokens_total']} |"
                f" {on['cow_splits']} |\n\n"
                f"TTFT p95 reduction cache-on vs off: "
                f"{pf['ttft_p95_reduction'] * 100:+.1f}% (CPU wall-clock, "
                f"indicative).")
        if "spec" in d:
            sp = d["spec"]
            w = sp["workload"]
            rows.append(
                f"\nSpeculative decoding (DESIGN.md §14), "
                f"{w['requests']} requests x {w['new_tokens']} greedy "
                f"tokens, k={w['spec_k']}, token parity speculative == "
                f"plain asserted in-run; `steps` is the exact engine "
                f"decode-step count (deterministic), `model` maps the "
                f"recorded acceptance through "
                f"`roofline.spec_decode_speedup` (draft cost ratio "
                f"{w['draft_cost_ratio']:.2f}):\n\n"
                f"| proposer | acceptance | tokens/round | steps | "
                f"step speedup | modeled decode tok/s |\n"
                f"|---|---|---|---|---|---|\n"
                f"| none (plain decode) | — | 1.00 | "
                f"{sp['plain']['steps']} | 1.00x | 1.00x |")
            for cell, label in (("ngram", "n-gram prompt-lookup"),
                                ("draft_ideal",
                                 "ideal draft (draft == target)")):
                c = sp[cell]
                rows.append(
                    f"| {label} | {c['acceptance_rate']:.2f} | "
                    f"{c['tokens_per_round']:.2f} | {c['steps']} | "
                    f"{c['speedup_steps']:.2f}x | "
                    f"{c['model_speedup_at_recorded_acceptance']:.2f}x |")
            mc = sp["model_chat_typical"]
            rows.append(
                f"\nAt chat-typical acceptance 0.80 the model gives "
                f"{mc['expected_tokens_per_round']:.2f} tokens/round = "
                f"{mc['speedup']:.2f}x decode tok/s with the "
                f"smollm-360m-for-yi-6b draft cost.")
        return "\n".join(rows)

    def pipeline_table():
        p = HERE.parent / "BENCH_pipeline.json"
        if not p.exists():
            return ("(pending: `PYTHONPATH=src python -m benchmarks.run` "
                    "writes BENCH_pipeline.json)")
        d = json.loads(p.read_text())
        pp, base = d["pipeline_q2_pipe2"], d["baseline_q2_dp2"]
        rows = ["| layout | us/step | tok/s | bubble measured | "
                "bubble analytic |", "|---|---|---|---|---|",
                f"| 1F1B [pipe=2 x q=2], M={pp['n_micro']} | "
                f"{pp['us_per_step']:.0f} | {pp['tokens_per_s']:.0f} | "
                f"{pp['bubble_measured']:.3f} | "
                f"{pp['bubble_predicted']:.3f} |",
                f"| non-PP [q=2 x dp=2] | {base['us_per_step']:.0f} | "
                f"{base['tokens_per_s']:.0f} | — | — |"]
        rows.append(f"\nmax per-step loss deviation between the two "
                    f"layouts: {d['max_loss_dev_vs_baseline']:.1e} "
                    f"(same step-keyed batches).")
        return "\n".join(rows)

    def zero1_table():
        p = HERE.parent / "BENCH_zero1.json"
        if not p.exists():
            return ("(pending: `PYTHONPATH=src python -m benchmarks.run` "
                    "writes BENCH_zero1.json)")
        d = json.loads(p.read_text())
        rows = ["| mesh | optimizer state | MiB/dev | shrink | model pred | "
                "us/step | max loss dev |", "|---|---|---|---|---|---|---|"]
        names = {"dp4": "[data=4, q=1]", "dp2_d2": "[data=2, d=2, q=1]"}
        for key, label in names.items():
            if key not in d:
                continue
            c = d[key]
            r, z = c["replicated"], c["zero1"]
            rows.append(
                f"| {label} | replicated | "
                f"{r['opt_state_bytes_per_device']/2**20:.2f} | — | — | "
                f"{r['us_per_step']:.0f} | — |")
            rows.append(
                f"| {label} | ZeRO-1 | "
                f"{z['opt_state_bytes_per_device']/2**20:.2f} | "
                f"{c['measured_ratio']:.2f}x | {c['model_pred_ratio']:.2f}x "
                f"| {z['us_per_step']:.0f} | {c['max_loss_dev']:.1e} |")
        return "\n".join(rows)

    def attention_table():
        p = HERE.parent / "BENCH_attention.json"
        if not p.exists():
            return ("(pending: `PYTHONPATH=src python -m benchmarks.run` "
                    "writes BENCH_attention.json)")
        d = json.loads(p.read_text())
        rows = ["| cell | jnp | pallas | parity |", "|---|---|---|---|"]
        for name, c in d["train"].items():
            rows.append(
                f"| train {name} (us/step, CPU interpret) | "
                f"{c['jnp']['us_per_step']:.0f} | "
                f"{c['pallas']['us_per_step']:.0f} | "
                f"max loss dev {c['max_loss_dev']:.1e} |")
        m = d["paged_decode"]["modeled_v5e"]
        c = d["paged_decode"]["measured_cpu_interpret"]
        rows.append(
            f"| decode tok/s, modeled v5e (32k pool, 2k live) | "
            f"{m['gather_tok_s']:.0f} (gather) | "
            f"{m['kernel_tok_s']:.0f} (kernel) | "
            f"{m['gather_bytes'] / m['kernel_bytes']:.0f}x less HBM "
            f"traffic |")
        rows.append(
            f"| decode tok/s, measured CPU interpret | "
            f"{c['gather_tok_s']:.0f} | {c['kernel_tok_s']:.0f} | "
            f"greedy argmax identical (interpreter-bound wall clock) |")
        bw = d["flash_bwd_vs_jax_vjp"]
        worst = max(v for key, e in bw.items() if key.startswith("window")
                    for v in e.values())
        rows.append(
            f"| flash bwd max grad err vs jax.vjp(blockwise) | — | "
            f"{worst:.1e} | < {bw['tolerance']:.0e} asserted |")
        tiles = ", ".join(
            f"T{t['shape']['Tq']}/D{t['shape']['D']}->"
            f"({t['best'][0]},{t['best'][1]})" for t in d["autotuned_tiles"])
        rows.append(f"| autotuned tiles (bq,bk) | — | {tiles} | hillclimb "
                    f"sweep, cached per shape |")
        return "\n".join(rows)

    def longctx_table():
        p = HERE.parent / "BENCH_longctx.json"
        if not p.exists():
            return ("(pending: `PYTHONPATH=src python -m benchmarks.run` "
                    "writes BENCH_longctx.json)")
        d = json.loads(p.read_text())
        rows = ["| cell | value | gate |", "|---|---|---|"]
        for name, c in d["train"].items():
            if "max_loss_dev" not in c:
                continue
            rows.append(
                f"| train {name} vs single device | max loss dev "
                f"{c['max_loss_dev']:.1e} | < 2e-5 asserted (fp32) |")
        for cell, c in d["wire_conformance"].items():
            rows.append(
                f"| seq-axis ppermutes, {cell} | {c['traced_ppermutes']} "
                f"permutes / {c['traced_wire_bytes']} B traced | == "
                f"`ring_attention_traffic` byte-exact |")
        iso = d["iso_memory"]
        rows.append(
            f"| context at iso-memory (seq 1 -> 4) | "
            f"{iso['context_ratio']:.0f}x context at "
            f"{iso['temp_bytes_ratio']:.2f}x per-device temp bytes | "
            f"{iso['context_per_memory_ratio']:.2f}x >= 2x floor |")
        m = d["modeled_v5e"]
        for nm, label in (("train_128k_seq8", "modeled v5e train 128k"),
                          ("prefill_128k_seq8",
                           "modeled v5e prefill 128k")):
            c = m[nm]
            rows.append(
                f"| {label}, seq=8 | {c['wire_bytes']/2**30:.2f} GiB wire, "
                f"comm/step {c['step_comm_s']*1e3:.2f} ms vs compute "
                f"{c['step_compute_s']*1e3:.2f} ms | comm hidden: "
                f"{c['comm_hidden']} |")
        tiles = ", ".join(
            f"seq{t['seq_shards']}/L{t['ring_step_Tk']}->"
            f"({t['best'][0]},{t['best'][1]})"
            for t in d["ring_step_autotune"])
        rows.append(f"| ring-step autotuned tiles | {tiles} | committed "
                    f"per-backend cache |")
        return "\n".join(rows)

    def gspmd_table():
        rows = [perf_hdr]
        for arch in ("yi-6b", "llama3-405b"):
            b = _cell(arch, "train_4k")
            g = _cell(arch, "train_4k", mode="gspmd", tag="auto")
            if b:
                rows.append(perf_row(f"{arch}/explicit", "tesseract shard_map", b, b))
            if g and b:
                rows.append(perf_row(f"{arch}/gspmd", "auto-partitioned einsums", g, b))
        return "\n".join(rows)

    md = f"""# EXPERIMENTS

All numbers are generated by the committed harnesses:

```
PYTHONPATH=src python -m repro.launch.dryrun --all        # 64-cell grid
PYTHONPATH=src python -m benchmarks.hillclimb             # §Perf variants
PYTHONPATH=src python -m benchmarks.run                   # paper tables
PYTHONPATH=src python -m benchmarks.make_experiments_md   # this file
```

Hardware model (target, per harness): TPU v5e-class — 197 TFLOP/s bf16,
819 GB/s HBM, 50 GB/s/link ICI.  This container is CPU-only: every number
below is derived from `.lower().compile()` artifacts (abstract compilation
with 512 placeholder devices), never from CPU wall-clock.

## §Validation — the paper's own claims

| claim (paper) | ours | verdict |
|---|---|---|
| §1: Cannon needs 31.5x Tesseract's transmissions at p=64 | {c_ratio:.2f}x | exact |
| §1: 2.5-D needs 3.75x Tesseract's transmissions at p=64 | {d_ratio:.2f}x | exact |
| Eq.7-10: M_tess = ab/p + bcd/p + ac/p < M_megatron | verified from real NamedSharding shard shapes | exact (tests/test_memory_model.py) |
| §4.3 / Fig.7: "Tesseract does not introduce any approximations" | train curves identical (measured max deviation < 1e-6 over 20 steps; benchmarks fig7) across 1-device vs [2,2,1] vs [2,2,2], and parity across [8]-1-D / Optimus / DP variants for ALL 10 archs | verified (tests/test_multidevice.py, benchmarks fig7) |
| Table 1 direction: [4,4,4] > 1-D, 2-D, [8,8,1] (strong scaling) | modeled speedups {t1['tesseract[4,4,4]_vs_megatron[64]']:.2f}x / {t1['tesseract[4,4,4]_vs_optimus[8,8]']:.2f}x / {t1['tesseract[4,4,4]_vs_[8,8,1]']:.2f}x (paper 1.38/1.53/2.07) | direction reproduced; magnitudes differ (paper = A100+IB wall clock, ours = v5e roofline model; see benchmarks/tables.py) |
| Table 2 direction: weak-scaling throughput [4,4,4] > 1-D / 2-D | modeled {t2['throughput_tesseract[4,4,4]_vs_megatron[64]']:.2f}x / {t2['throughput_tesseract[4,4,4]_vs_optimus[8,8]']:.2f}x (paper 3.37/1.71) | direction reproduced |
| depth > 1 reduces per-layer comm at fixed p | dry-run measured: [2,2,4] vs [4,4,1] on llama3-405b train: collective 55.1s vs 66.4s (-17%) | verified on compiled HLO (§Perf A3) |
| 1-D has the worst comm at scale | dry-run measured: megatron [16] collective 104.0s vs 55.1s | verified (§Perf A4) |

Additional correctness validation (all in `tests/`): Tesseract matmul
fwd/bwd exact vs jnp for every cache/reduction mode; train/prefill/decode
parity across all modes for all 10 architectures; ZeRO-1 == replicated
optimizer to fp32 exactness over the q x dp x master grid incl. the 1F1B
pipeline mesh, with checkpointed opt shards re-partitioning across dp
changes and to/from the replicated layout (zero1_parity / zero1_elastic);
MoE local-layout numerics exact; distributed linear scans (RG-LRU, SSD)
exact vs naive recurrences; Pallas kernels vs oracles over shape/dtype
sweeps; GPipe pipeline == sequential reference (fwd + grads).

## §Dry-run — multi-pod compilation grid

`make_production_mesh()` per harness spec: single-pod (16,16)=(data,model),
multi-pod (2,16,16)=(pod,data,model); the model axis factorizes to
Tesseract [q=2,q=2,d=4]; pod folds into data (paper §3.4).  **All 64 cells
lower + compile** (32 single-pod + 32 multi-pod; `--all` exits 0, zero
failures): every architecture x shape on both meshes, `memory_analysis()`
and `cost_analysis()` captured per cell under `benchmarks/results/dryrun/`.
long_500k runs for mamba2-1.3b and recurrentgemma-9b (sub-quadratic);
the 8 pure-full-attention archs skip it per the harness instructions:
{', '.join(skipped_cells())}.

Notes on the grid:
- decode_32k multi-pod: global batch 128 < 256 token-shards, so the plan
  auto-downgrades to `decode_dp` (batch over data only) — documented
  adaptive layout, parity-tested.
- per-device bytes (GiB/dev column) are `memory_analysis()`
  argument+temp+output-alias.  Cells whose state exceeds a v5e's 16 GiB
  (e.g. llama3-405b train at 256 chips: 1.77 TiB/dev) are *reported*, not
  hidden: at the paper's own scale assumptions those models train on more
  pods (the multi-pod column halves state per device; real deployments use
  more), and run.zero1 reduces optimizer state by data*depth.

Collective schedule example (llama3-405b / train_4k / 16x16, per device
per step; every cell's full breakdown lives in its JSON):

{coll_table(base_A)}

### Roofline, single-pod 16x16 (baselines, paper-faithful mode)

{baseline_table("16x16")}

### Roofline, multi-pod 2x16x16

{baseline_table("2x16x16")}

## §Roofline — method and reading

- **compute term** = structural HLO dot-FLOPs / 197 TF. `cost_analysis()`
  counts while-loop bodies once, so FLOPs come from a structural HLO parse
  that multiplies scan trip counts (`repro/roofline/hlo.py`; exactness
  tests in tests/test_substrate.py). Elementwise FLOPs are excluded
  (dot-dominated workloads).
- **memory term** = (dot operand+output traffic + 2x argument bytes) /
  819 GB/s — a defensible traffic floor; the raw structural byte sum is
  kept in each JSON as an upper bound (it ignores fusion/aliasing, e.g.
  scan-carry in-place updates, and overestimates ~20x).
- **collective term** = ring-model wire bytes / 50 GB/s, per collective
  kind, replica-group size parsed per op, trip-multiplied.  Wire dtype is
  resolved through converts because XLA:CPU float-normalization promotes
  bf16 collectives to f32 (TPU keeps them native bf16).
- **useful-FLOPs frac** = MODEL_FLOPS / total HLO FLOPs, with MODEL_FLOPS =
  6*N*D (train), 2*N*D (prefill), 2*N_active*tokens (decode; cache
  attention excluded by convention). It exposes remat/dispatch waste.
- `mamba2` fracs slightly exceed 1.0 on decode because param_count() is an
  analytic approximation of the SSD layer; long_500k fracs are ~0 because
  a single token cannot amortize the weight gathers (see §Perf B for the
  fix).

Scaling observation (512 vs 256 chips): compute terms halve while the
per-device collective terms stay ~constant (block gathers don't shrink with
more data-parallel replicas), so at 2x16x16 the big dense trainers flip to
collective-dominant — exactly the regime where the paper's depth axis and
the §Perf A-series levers matter most.

Dominant terms at a glance: large dense training is compute-dominant
(llama3-405b train: 65.4s compute vs 55.1s collective vs 19.3s memory =
77% useful-FLOPs before optimization); decode cells are collective-bound
under 2.5-D (per-token weight gathers); small models are memory/collective
bound (roofline says: don't give smollm 256 chips).

## §Perf — hillclimbing log (hypothesis -> change -> measure -> validate)

Three cells per the harness policy — most paper-representative
(llama3-405b/train_4k), most collective-bound (llama3-405b/decode_32k),
worst useful-FLOPs among large cells (deepseek-v2/train_4k).  The
**paper-faithful baseline is row 0 of each table** (per-op depth
all-reduce, weight-gather caching as in §3.2.1, full remat); every other
row is a hypothesis-driven change measured on recompiled HLO.

### A. llama3-405b / train_4k (the paper's use case)

{perf_table(base_A, perf_A)}

- A1 **refuted**: byte-identical HLO — XLA already CSEs the backward
  re-gather against the remat recompute's gather. Lesson: the paper's
  "store the matrices to avoid waste" is subsumed by the compiler under
  rematerialization.
- A2 **refuted**: grads reaching the sync boundary are already bf16 in
  this config; compression has nothing to squeeze.
- A3/A4 **confirmed the paper**: 2-D (+21% collective) and 1-D (+89%
  collective, +16% compute from replicated-activation waste) are strictly
  worse — the reproduction's central claim, now measured on compiled HLO
  at 405B scale.
- A6 **confirmed**: dots-remat cuts recompute, compute term -18.5%,
  useful-FLOPs 0.774 -> 0.950.
- A7 **masked by the host backend**: XLA:CPU folds the bf16 downcast of
  the f32 dW partials (excess-precision folding), so the dry-run cannot
  show it; analytically the dW reduce-scatter operand (0.8 TB f32/device)
  halves on TPU: expected additional ~ -7s collective.
- A8 **confirmed**: -14.4% collective (stacked bf16 reductions at the
  pvary boundary instead of f32 per-layer all-reduces inside the scan;
  also 126x fewer grad collectives).
- **A9 final: compute 65.4->53.3s, collective 55.1->47.2s, useful 0.77->
  0.95.** Roofline fraction (6ND time / dominant term) rises from
  50.6/65.4 = **0.77** to 50.6/53.3 = **0.95**, with the collective term
  now below compute (overlappable by the TPU latency-hiding scheduler).
  Stopped: next three candidates (A1, A2, A7-on-CPU) measured <5%.

### B. llama3-405b / decode_32k (most collective-bound)

{perf_table(base_B, perf_B)}

- B0: 2.5-D decode re-gathers every weight block each step:
  (q-1)/q^2 x 810 GB/token-batch -> 8.0s/step of wire time vs 2.7ms of
  compute. The paper never measured autoregressive decode (its "inference"
  is a forward pass on training shapes) — this is where its layout loses.
- B1 **confirmed (the big win)**: 1-D serve layout keeps weights
  stationary and all-reduces only [B_loc,1,h] activations: collective
  8.03s -> 0.005s (**~1600x**); the step becomes memory-bound (0.88s
  weight streaming), i.e. at the decode roofline. Serving should flip
  layouts after prefill; training keeps 2.5-D. This mode switch is a
  config flag in this framework.
- B2/B3 **confirmed napkin math exactly**: (3/16)/(1/4) = 0.75 -> -25%.

### B+. Serving engine — continuous batching vs the static decode loop

Measured by `benchmarks/run.py` (serve case; subprocess on 8 fake CPU
devices, tesseract [2,2,1] x dp2, mixed-length workload, greedy token
parity engine == static asserted in-run; wall clock indicative only):

{serve_table()}

The static loop keeps every slot busy until the slowest request in the
batch finishes and replays prompts token by token; the engine retires
finished sequences in place, admits queued requests immediately into the
freed slots and prefills prompts in one bucketed step (DESIGN.md §7).

### B++. Pipeline composition (1F1B x Tesseract, paper §3.4)

Measured by `benchmarks/run.py` (pipeline case; 8 fake CPU devices,
yi-6b reduced, B=16 S=32; losses bit-match the 1-stage baseline per the
`pipeline_parity` mdcheck; CPU wall clock indicative only — the 1F1B
backward units pay full-stage rematerialization on the host, while the
schedule artifact is the measured bubble vs the analytic (S-1)/(M+S-1)):

{pipeline_table()}

### B+++. ZeRO-1 optimizer-state sharding + mixed precision (DESIGN.md §9)

Measured by `benchmarks/run.py` (zero1 case; 8 fake CPU devices, yi-6b
reduced, B=8 S=32).  Per-device optimizer-state bytes are EXACT (summed
NamedSharding shard shapes of the live train-step bundles, not estimates);
the memory-model prediction is `roofline.analysis.optimizer_state_bytes`
(Eq. 8 extended with the opt-state term).  The depth=2 mesh shrinks less
than data*depth because depth-SHARDED leaves (head) only partition their
state over `data` — the per-leaf rule the `zero1_parity` mdcheck locks in.
Loss parity ZeRO-1 vs replicated is asserted in-run (< 1e-5; measured 0.0
— bit-identical on these meshes); bf16 params + fp32 master and the
elastic 8 -> 4 opt-shard re-partition are covered by `zero1_parity` /
`zero1_elastic`:

{zero1_table()}

### B++++. Fused Pallas attention (flash fwd+bwd, paged decode; DESIGN.md §10)

Measured by `benchmarks/run.py` (attention case; 8 fake CPU devices,
yi-6b reduced).  The kernels run in interpret mode on this container, so
wall clock is indicative only (the interpreter re-copies full operands per
grid step); the committed decode claim is the HBM-traffic roofline for the
v5e target (`roofline/analysis.paged_decode_traffic`: the gather path
moves 3x the full pool per step, the block-table kernel only the live
pages).  Parity is asserted in-run: training losses jnp vs pallas to fp32
exactness for q in {{1, 2}}, flash bwd vs `jax.vjp(blockwise_attention)`,
and greedy decode argmax bit-identical — plus the `attn_impl_parity` /
pallas `serve_engine` / `zero1_parity` / `pipeline_parity` mdcheck cells:

{attention_table()}

### B+++++. Ring/striped flash attention over the seq axis (DESIGN.md §15)

Measured by `benchmarks/run.py` (longctx case; 8 fake CPU devices, yi-6b
reduced).  The sequence axis joins the mesh as
`(data, seq, depth, row, col)`: each device keeps its resident Q shard and
ppermutes K/V blocks around the seq ring while the flash kernel consumes
one block per step (logsumexp-merged), so per-device activations scale
with T/seq — context grows with the ring at iso-memory.  `striped`
re-stripes token ownership (`shard r` holds positions `r + seq*arange`) to
balance the causal mask's work across ranks.  Striped fp32 training parity
vs the single-device flash baseline is asserted in-run; the seq-axis
ppermute count and wire bytes of the traced train step must equal
`roofline.ring_attention_traffic` byte-for-byte (also enforced as
`train_ring_attn_*` entries in SHARDCHECK.json); the iso-memory cells are
measured XLA buffer assignments:

{longctx_table()}

### C. deepseek-v2-236b / train_4k (worst useful-FLOPs, MoE)

{perf_table(base_C, perf_C)}

- C1 **refuted** (the most instructive failure): expert-local weights cut
  the forward weight gathers as predicted, but the expert-weight GRADIENTS
  are then replicated over (row,col) and their (data,row,col) reduction in
  f32 (+15% net collective) outweighs the forward saving. The layout IS
  the right choice for inference (no grads) — kept as a serve-time option.
- C2 **confirmed**: capacity 1.25 -> 1.0 trims dispatch/a2a/expert matmul
  bytes ~ -9% collective, -6% compute (drop-rate trade documented).
- C4 **final: compute -15%, collective -11%, useful 0.484 -> 0.568.**
  Remaining gap is structural: top-6-of-160 routing means 6x expert
  traffic per token and the MLA projections (128 heads x 192) keep
  per-layer gathers high; the next lever (not taken: quality-affecting)
  is top-4 routing.

### Appendix: explicit SUMMA vs GSPMD auto-partitioning

The same dense-LM math written as plain global einsums +
`with_sharding_constraint` (identical param specs, `core/gspmd.py`) lets
XLA's auto-partitioner choose the schedule — the control experiment for
implementing the paper explicitly:

{gspmd_table()}

The explicit shard_map SUMMA schedule moves ~2.6x fewer collective bytes
than GSPMD's choices on yi-6b (XLA re-gathers activations around the
attention reshapes instead of keeping the paper's A/W block layout), and
also avoids its extra dot-padding FLOPs. This quantifies why Tesseract is
implemented as explicit collectives rather than sharding hints.

### Cross-cutting outcome

The optimized configuration (deferred fused bf16 grad sync + dots remat +
mode-switched serving) is exposed as flags; the paper-faithful path stays
the default and both are covered by identical-loss tests. Beyond-paper
gains summary: train useful-FLOPs 0.77->0.95 (llama3-405b), decode wire
cost -99.9% (serve-layout switch), MoE step -11% collective / -15%
compute (deepseek-v2).
"""
    OUT.write_text(md)
    print(f"wrote {OUT} ({len(md.splitlines())} lines)")


if __name__ == "__main__":
    main()
