"""§Perf hillclimbing driver: run tagged dry-run variants for the three
chosen cells and print hypothesis -> before -> after rows.

Targets (chosen per the §Roofline baseline table):
  A. llama3-405b / train_4k    — most representative of the paper's technique
  B. llama3-405b / decode_32k  — most collective-bound cell (weight gathers)
  C. deepseek-v2 / train_4k    — worst useful-FLOPs fraction among the large
                                 cells and collective-dominant (MoE)

Run:  PYTHONPATH=src python -m benchmarks.hillclimb [--only A1,B1,...]
"""
from __future__ import annotations

import json
import os
import pathlib
import subprocess
import sys

HERE = pathlib.Path(__file__).resolve().parent
SRC = str(HERE.parent / "src")
RESULTS = HERE / "results" / "dryrun"

# (id, arch, shape, mode, tag, ctx_overrides, run_overrides, hypothesis)
EXPERIMENTS = [
    # ---- A: llama3-405b train_4k --------------------------------------
    ("A1", "llama3-405b", "train_4k", "tesseract", "cacheact",
     {"cache_act_gather": "true"}, {},
     "caching the col-gathered activations as custom-vjp residuals removes "
     "the backward re-gather of A (1 of 3 A-side collectives per linear); "
     "under full remat the residual lives only inside the remat segment, so "
     "memory cost ~0. Expect collective term -20..30%."),
    ("A2", "llama3-405b", "train_4k", "tesseract", "gradbf16",
     {}, {"grad_compression": "bf16"},
     "bf16 wire format for the fused (depth,data) grad reductions halves "
     "those bytes; dW reduction is ~25% of collective bytes -> expect "
     "collective -10..15%."),
    ("A3", "llama3-405b", "train_4k", "tesseract", "fact441",
     {"rows": 4, "cols": 4, "depth": 1}, {},
     "REFUTATION TEST of the paper's claim: [4,4,1] (2-D, d=1) should be "
     "WORSE than [2,2,4] because activation gathers scale with (q-1) while "
     "depth shards the batch for free. Expect collective term UP."),
    ("A4", "llama3-405b", "train_4k", "megatron1d", "",
     {}, {},
     "1-D baseline: all-reduces of full activations (b*s*h) per layer "
     "dwarf tesseract's block gathers at this batch. Expect collective "
     "term >> [2,2,4] (paper's Table 1 direction)."),
    ("A5", "llama3-405b", "train_4k", "tesseract", "best",
     {"cache_act_gather": "true"}, {"grad_compression": "bf16"},
     "compose A1+A2."),
    ("A6", "llama3-405b", "train_4k", "tesseract", "dotsremat",
     {"cache_act_gather": "true"},
     {"grad_compression": "bf16", "remat": "dots"},
     "remat policy 'dots' saves matmul outputs instead of recomputing the "
     "whole layer: recompute flops drop (~8N*D -> ~7N*D) at higher residual "
     "memory. Expect compute term -10..15%, useful-FLOPs frac up."),
    ("A7", "llama3-405b", "train_4k", "tesseract", "rsbf16",
     {"dgrad_rs_bf16": "true"}, {},
     "the dW reduce-scatter + in-op depth/data all-reduce currently move "
     "f32 partials (~1.2TB operand of the 3TB total). Reducing them in bf16 "
     "halves those bytes -> expect collective -15..25%."),
    ("A8", "llama3-405b", "train_4k", "tesseract", "deferred",
     {"reduce_dgrad_in_op": "false"}, {},
     "deferred (pvary-boundary) grad sync reduces the ALREADY-bf16 stacked "
     "dW once per leaf instead of f32 per-layer all-reduces inside the "
     "scan: same RS bytes, all-reduce bytes halve and fuse (126 -> ~8 "
     "collectives). Expect collective -5..10%."),
    ("A9", "llama3-405b", "train_4k", "tesseract", "final",
     {"dgrad_rs_bf16": "true", "reduce_dgrad_in_op": "false"},
     {"remat": "dots"},
     "compose A6+A7+A8: bf16 grad wire + deferred fused sync + dots remat "
     "(saves matmul recompute). Expect collective -20..30% AND compute "
     "-10..20% vs the paper-faithful baseline."),
    # ---- B: llama3-405b decode_32k ------------------------------------
    ("B1", "llama3-405b", "decode_32k", "megatron1d", "",
     {}, {},
     "decode is weight-gather bound under tesseract (every step re-gathers "
     "W over row: ~(q-1)/q^2 * params bytes/token). 1-D keeps weights "
     "stationary and all-reduces only the [B_loc,1,h] activations -> expect "
     "collective term down by ~2-3 orders of magnitude."),
    ("B2", "llama3-405b", "decode_32k", "tesseract", "fact441",
     {"rows": 4, "cols": 4, "depth": 1}, {},
     "within tesseract, [4,4,1] gathers (q-1)/q^2 = 3/16 of W vs 1/4 at "
     "[2,2,4]: expect collective -25% (weight-gather bound)."),
    ("B3", "llama3-405b", "decode_32k", "summa2d", "",
     {}, {},
     "Optimus 2-D baseline = [4,4,1] with its own op set; should match B2."),
    # ---- C: deepseek-v2 train_4k ---------------------------------------
    ("C1", "deepseek-v2-236b", "train_4k", "tesseract", "moelocal",
     {}, {"moe_expert_layout": "local"},
     "expert weights whole per depth slice, tokens split over col: replaces "
     "per-layer expert WEIGHT gathers ((q-1)/q^2 * 7.4GB/layer) with token "
     "gathers (~0.6GB/layer). Expect collective term -30..45%."),
    ("C2", "deepseek-v2-236b", "train_4k", "tesseract", "cap10",
     {}, {"capacity_factor": 1.0},
     "capacity 1.25 -> 1.0 shrinks dispatch buffers and expert matmuls by "
     "20%: expect collective -5..10% and compute -5% (more drops, "
     "documented quality trade)."),
    ("C3", "deepseek-v2-236b", "train_4k", "tesseract", "best",
     {"dgrad_rs_bf16": "true", "reduce_dgrad_in_op": "false"},
     {"moe_expert_layout": "local", "remat": "dots"},
     "compose C1 + A7 + A8 + dots remat."),
    ("C4", "deepseek-v2-236b", "train_4k", "tesseract", "final",
     {"dgrad_rs_bf16": "true", "reduce_dgrad_in_op": "false"},
     {"capacity_factor": 1.0, "remat": "dots"},
     "drop the refuted C1 (expert-local layout loses on training grads); "
     "compose C2 (capacity 1.0) + deferred fused bf16 grad sync + dots "
     "remat. Expect collective -15..20% and compute -10%."),
]


def cell_json(arch, shape, mode, tag, mesh="16x16"):
    sfx = f"__{tag}" if tag else ""
    return RESULTS / f"{arch}__{shape}__{mode}__{mesh}{sfx}.json"


def run_exp(exp, force=False):
    eid, arch, shape, mode, tag, ctx_o, run_o, hyp = exp
    out = cell_json(arch, shape, mode, tag)
    if out.exists() and not force:
        return json.loads(out.read_text())
    cmd = [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch,
           "--shape", shape, "--mode", mode]
    if tag:
        cmd += ["--tag", tag]
    for k, v in ctx_o.items():
        cmd += ["--ctx-override", f"{k}={v}"]
    for k, v in run_o.items():
        cmd += ["--run-override", f"{k}={v}"]
    env = dict(os.environ, PYTHONPATH=SRC)
    env.pop("XLA_FLAGS", None)
    r = subprocess.run(cmd, env=env, capture_output=True, text=True,
                       timeout=3000)
    if r.returncode != 0:
        print(r.stdout[-1500:], r.stderr[-1500:])
        raise RuntimeError(f"{eid} failed")
    return json.loads(out.read_text())


def fmt(d):
    return (f"compute={d['compute_term_s']:.2f}s memory={d['memory_term_s']:.2f}s "
            f"collective={d['collective_term_s']:.2f}s useful={d['useful_flops_frac']:.3f}")


def main():
    only = None
    force = "--force" in sys.argv
    if "--only" in sys.argv:
        only = set(sys.argv[sys.argv.index("--only") + 1].split(","))
    rows = []
    for exp in EXPERIMENTS:
        eid, arch, shape, mode, tag, ctx_o, run_o, hyp = exp
        if only and eid not in only:
            continue
        base = json.loads(cell_json(arch, shape, "tesseract", "").read_text())
        got = run_exp(exp, force=force)
        delta = (got["collective_term_s"] - base["collective_term_s"]) \
            / max(base["collective_term_s"], 1e-12)
        print(f"=== {eid} {arch}/{shape} [{mode}{'+' + tag if tag else ''}]")
        print(f"    hypothesis: {hyp}")
        print(f"    before: {fmt(base)}")
        print(f"    after : {fmt(got)}")
        print(f"    collective delta: {delta:+.1%}")
        rows.append((eid, base, got))
    return rows


if __name__ == "__main__":
    main()
