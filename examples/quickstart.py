"""Quickstart: build a tiny Tesseract-parallel LM, train a few steps, then
decode greedily — all on one device (the same code runs on a [q,q,d] mesh).

    PYTHONPATH=src python examples/quickstart.py
"""
import sys
import pathlib
sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, RunConfig, ShapeSpec
from repro.core.api import ParallelContext
from repro.core.mesh import logical_mesh
from repro.models.registry import build_model
from repro.optim.adamw import adamw_init
from repro.runtime.steps import build_decode_step, build_train_step


def main():
    cfg = ModelConfig(name="quickstart", family="dense", num_layers=4,
                      d_model=128, num_heads=4, num_kv_heads=2, d_ff=256,
                      vocab_size=1024)
    run = RunConfig(param_dtype="float32", compute_dtype="float32",
                    loss_chunk=64, q_chunk=32, kv_chunk=32, lr=3e-3)
    # single device == ParallelContext(1,1,1,1); on a pod use e.g.
    # production_context("tesseract") for the [2,2,4] x 16DP layout.
    ctx = ParallelContext(mode="tesseract", data=1, depth=1, rows=1, cols=1)
    mesh = logical_mesh(ctx)
    model = build_model(cfg, ctx, run)

    shape = ShapeSpec("train", seq_len=64, global_batch=8, kind="train")
    bundle = build_train_step(model, mesh, shape)
    params = model.init(jax.random.PRNGKey(0))
    opt = adamw_init(params)

    print(f"params: {sum(x.size for x in jax.tree.leaves(params)):,}")
    for step in range(10):
        tok = jax.random.randint(jax.random.PRNGKey(step), (8, 64), 0, 1024)
        batch = {"tokens": tok, "labels": jnp.roll(tok, -1, 1)}
        params, opt, m = bundle.fn(params, opt, batch)
        print(f"step {step}: loss={float(m['loss']):.4f} "
              f"gnorm={float(m['grad_norm']):.3f}")

    # greedy decode from a fresh cache
    dshape = ShapeSpec("decode", seq_len=32, global_batch=4, kind="decode")
    dec = build_decode_step(model, mesh, dshape)
    cache_sds, _ = model.cache_abstract(4, 32, dec.plan)
    cache = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), cache_sds)
    ids = jnp.array([[1], [2], [3], [4]], jnp.int32)
    outs = [np.asarray(ids).ravel()]
    for t in range(8):
        ids, cache = dec.fn(params, cache, ids, jnp.int32(t))
        outs.append(np.asarray(ids).ravel())
    print("decoded:", np.stack(outs).T)


if __name__ == "__main__":
    main()
