"""End-to-end training driver: a ~100M-param dense LM on the fault-tolerant
loop (async checkpoints, deterministic restart, straggler monitor).

    PYTHONPATH=src python examples/train_lm.py --steps 300            # full
    PYTHONPATH=src python examples/train_lm.py --steps 20 --small     # quick

On a pod, replace the context with launch.mesh.production_context(...) —
the rest of the script is unchanged (mesh-agnostic by construction).
"""
import argparse
import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

from repro.configs.base import ModelConfig, RunConfig, ShapeSpec
from repro.core.api import ParallelContext
from repro.core.mesh import logical_mesh
from repro.models.registry import build_model
from repro.runtime.train_loop import train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--small", action="store_true",
                    help="~4M params for a quick CPU run")
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--ckpt", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    if args.small:
        cfg = ModelConfig(name="lm-small", family="dense", num_layers=4,
                          d_model=256, num_heads=8, num_kv_heads=4,
                          d_ff=512, vocab_size=4096)
    else:
        # ~100M params (42M embed+head + ~5M/layer x 10)
        cfg = ModelConfig(name="lm-100m", family="dense", num_layers=10,
                          d_model=640, num_heads=10, num_kv_heads=5,
                          d_ff=1792, vocab_size=32768)
    run = RunConfig(param_dtype="float32", compute_dtype="float32",
                    loss_chunk=128, q_chunk=64, kv_chunk=64, lr=3e-4)
    ctx = ParallelContext(mode="tesseract", data=1, depth=1, rows=1, cols=1)
    mesh = logical_mesh(ctx)
    model = build_model(cfg, ctx, run)
    shape = ShapeSpec("train", seq_len=args.seq, global_batch=args.batch,
                      kind="train")
    res = train(model, mesh, shape, steps=args.steps, ckpt_dir=args.ckpt,
                ckpt_every=50, log_every=10)
    print(f"done: {len(res.losses)} steps, final loss {res.losses[-1]:.4f}, "
          f"restarts {res.restarts}, "
          f"mean step {sum(res.step_times)/len(res.step_times)*1e3:.0f} ms")


if __name__ == "__main__":
    main()
