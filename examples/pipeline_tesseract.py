"""Composition demo (paper §3.4 / Fig. 6): pipeline parallelism OUTSIDE a
Tesseract TP group, end-to-end through the training stack — a
[pipe=2, data=1, depth=1, row=2, col=2] mesh on 8 fake devices runs
``build_train_step``'s 1F1B schedule (stage-sharded blocks/opt state,
microbatched flush, measured bubble) and must reproduce the 1-stage
baseline losses bit-for-bit.

    PYTHONPATH=src python examples/pipeline_tesseract.py
"""
import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

import jax
import jax.numpy as jnp

from repro.configs.base import RunConfig, ShapeSpec
from repro.core.api import ParallelContext
from repro.core.mesh import logical_mesh, pipeline_mesh
from repro.models.registry import build_model, get_reduced
from repro.optim.adamw import adamw_init
from repro.runtime.pipeline import bubble_fraction
from repro.runtime.steps import build_train_step

PIPE, M = 2, 4
B, S = 8, 16


def run(mesh, ctx, run_cfg, batch, shape, steps=4):
    model = build_model(get_reduced("yi-6b").model, ctx, run_cfg)
    bundle = build_train_step(model, mesh, shape)
    p = jax.device_put(model.init(jax.random.PRNGKey(0)),
                       bundle.in_shardings[0])
    o = jax.device_put(adamw_init(p), bundle.in_shardings[1])
    losses = []
    for _ in range(steps):
        p, o, m = bundle.fn(p, o, batch)
        losses.append(float(m["loss"]))
    return losses, bundle


def main():
    ctx = ParallelContext(mode="tesseract", data=1, depth=1, rows=2, cols=2)
    cfg = RunConfig(param_dtype="float32", compute_dtype="float32",
                    loss_chunk=16, q_chunk=8, kv_chunk=8, lr=1e-3,
                    pipe_stages=PIPE, pipeline_microbatches=M)
    tok = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, 250)
    batch = {"tokens": tok, "labels": jnp.roll(tok, -1, 1)}
    shape = ShapeSpec("t", seq_len=S, global_batch=B, kind="train")

    mesh_pp = pipeline_mesh(ctx, PIPE, jax.devices()[:8])
    losses_pp, bundle = run(mesh_pp, ctx, cfg, batch, shape)
    info = bundle.pipe_info
    print(f"1F1B [pipe={info['n_stages']} x q={ctx.q}] losses: "
          f"{[f'{l:.6f}' for l in losses_pp]}")
    print(f"schedule: M={info['n_micro']} -> {info['n_ticks']} ticks, "
          f"{info['n_slots']} in-flight slots, bubble "
          f"{info['measured_bubble']:.2%} "
          f"(analytic {bubble_fraction(info['n_micro'], PIPE):.2%})")

    mesh_1 = logical_mesh(ctx, jax.devices()[:4])
    losses_1, _ = run(mesh_1, ctx, cfg, batch, shape)
    dev = max(abs(a - b) for a, b in zip(losses_pp, losses_1))
    print(f"1-stage baseline losses:    {[f'{l:.6f}' for l in losses_1]}")
    print(f"max deviation: {dev:.2e} (paper claim: the composition is exact)")
    assert dev < 1e-5


if __name__ == "__main__":
    main()
