"""Composition demo (paper §3.4 / Fig. 6): pipeline parallelism OUTSIDE a
Tesseract TP group — a [pipe=2, data=1, depth=1, row=1, col=2] mesh on 4
fake devices, GPipe microbatching over a 2-stage MLP stack whose per-stage
matmuls are Tesseract-sharded over col.

    PYTHONPATH=src python examples/pipeline_tesseract.py
"""
import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=4")

import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import Mesh, PartitionSpec as P

from repro.core.collectives import shard_map
from repro.core.mesh import make_mesh
from repro.runtime.pipeline import bubble_fraction, pipeline_apply

S_PIPE, Q = 2, 2
M, MB, D = 8, 4, 64


def main():
    mesh = make_mesh((S_PIPE, 1, 1, 1, Q),
                     ("pipe", "data", "depth", "row", "col"))
    ws = jax.random.normal(jax.random.PRNGKey(0), (S_PIPE, D, D)) * 0.2
    x = jax.random.normal(jax.random.PRNGKey(1), (M, MB, D))
    tgt = jax.random.normal(jax.random.PRNGKey(2), (M, MB, D))

    def stage_fn(w_local, h):
        # h features sharded over col; w [D/?, D/Q]: SUMMA-style local matmul
        hg = lax.all_gather(h, "col", tiled=True, axis=-1)
        y = jnp.tanh(hg @ w_local[0])
        return y

    def loss_fn(ws_l, x_l, tgt_l):
        outs = pipeline_apply(stage_fn, ws_l, x_l, axis="pipe")
        sid = lax.axis_index("pipe")
        tl = lax.dynamic_slice_in_dim(
            tgt_l, lax.axis_index("col") * (D // Q), D // Q, axis=2)
        l = jnp.sum((outs - tl) ** 2) * (sid == S_PIPE - 1)
        return lax.psum(l, ("pipe", "col"))

    sm = shard_map(loss_fn, mesh=mesh,
                       in_specs=(P("pipe", None, "col"),
                                 P(None, None, "col"),
                                 P(None, None, None)),
                       out_specs=P())
    loss, grads = jax.value_and_grad(sm)(ws, x, tgt)
    print(f"pipelined loss: {float(loss):.4f}; grad norm: "
          f"{float(jnp.sqrt(sum(jnp.sum(g**2) for g in jax.tree.leaves(grads)))):.4f}")
    print(f"bubble fraction (M={M}, S={S_PIPE}): "
          f"{bubble_fraction(M, S_PIPE):.2%}")

    # sequential reference
    h = x
    for s in range(S_PIPE):
        h = jnp.tanh(h @ ws[s])
    ref = float(jnp.sum((h - tgt) ** 2))
    print(f"sequential reference loss: {ref:.4f} "
          f"(match: {np.isclose(ref, float(loss), rtol=1e-5)})")


if __name__ == "__main__":
    main()
