"""Serving example: batched prefill + greedy decode with a KV cache.

    PYTHONPATH=src python examples/serve_decode.py
"""
import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import RunConfig, ShapeSpec
from repro.core.api import ParallelContext
from repro.core.mesh import logical_mesh
from repro.models.registry import build_model, get_reduced
from repro.runtime.steps import build_decode_step, build_prefill_step


def main():
    arch = get_reduced("yi-6b")
    run = RunConfig(param_dtype="float32", compute_dtype="float32",
                    loss_chunk=64, q_chunk=32, kv_chunk=32)
    ctx = ParallelContext(mode="tesseract", data=1, depth=1, rows=1, cols=1)
    mesh = logical_mesh(ctx)
    model = build_model(arch.model, ctx, run)
    params = model.init(jax.random.PRNGKey(0))

    B, S_prompt, S_total, n_new = 4, 16, 48, 16
    pre = build_prefill_step(model, mesh,
                             ShapeSpec("p", S_prompt, B, "prefill"))
    prompts = jax.random.randint(jax.random.PRNGKey(1), (B, S_prompt), 0, 250)
    first_ids, pcache = pre.fn(params, {"tokens": prompts})
    print("prefill done; first sampled token per request:",
          np.asarray(first_ids).ravel())

    # decode continues in a fresh (decode-layout) cache re-filled by replaying
    # the prompt; a production server would reshard the prefill cache instead.
    dec = build_decode_step(model, mesh, ShapeSpec("d", S_total, B, "decode"))
    cache_sds, _ = model.cache_abstract(B, S_total, dec.plan)
    cache = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), cache_sds)
    ids = prompts[:, :1]
    generated = []
    for t in range(S_prompt + n_new):
        nxt, cache = dec.fn(params, cache, ids, jnp.int32(t))
        # teacher-force the prompt, then free-run
        ids = prompts[:, t + 1:t + 2] if t + 1 < S_prompt else nxt
        if t + 1 >= S_prompt:
            generated.append(np.asarray(nxt).ravel())
    print("generated tokens:")
    print(np.stack(generated).T)


if __name__ == "__main__":
    main()
