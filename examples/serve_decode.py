"""Serving example: batched prefill + greedy decode with a KV cache.

The prefill cache (sequence-sharded layout) is RESHARDED into the decode
layout with one jitted scatter (`build_dense_cache_reshard`) and decode
continues from position S_prompt — no prompt replay.  The replay path is
kept below as the reference and the two must agree token for token.

    PYTHONPATH=src python examples/serve_decode.py
"""
import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import RunConfig, ShapeSpec
from repro.core.api import ParallelContext
from repro.core.mesh import logical_mesh
from repro.models.registry import build_model, get_reduced
from repro.runtime.steps import (build_decode_step, build_dense_cache_reshard,
                                 build_prefill_step)


def main():
    arch = get_reduced("yi-6b")
    run = RunConfig(param_dtype="float32", compute_dtype="float32",
                    loss_chunk=64, q_chunk=32, kv_chunk=32)
    ctx = ParallelContext(mode="tesseract", data=1, depth=1, rows=1, cols=1)
    mesh = logical_mesh(ctx)
    model = build_model(arch.model, ctx, run)
    params = model.init(jax.random.PRNGKey(0))

    B, S_prompt, S_total, n_new = 4, 16, 48, 16
    pshape = ShapeSpec("p", S_prompt, B, "prefill")
    pre = build_prefill_step(model, mesh, pshape)
    prompts = jax.random.randint(jax.random.PRNGKey(1), (B, S_prompt), 0, 250)
    first_ids, pcache = pre.fn(params, {"tokens": prompts})
    print("prefill done; first sampled token per request:",
          np.asarray(first_ids).ravel())

    # --- reshard path: prefill cache -> decode layout, continue from S_prompt
    dec = build_decode_step(model, mesh, ShapeSpec("d", S_total, B, "decode"))
    reshard, _ = build_dense_cache_reshard(model, mesh, pshape, S_total)
    cache = reshard(pcache)
    ids = np.asarray(first_ids).reshape(B, 1)
    generated = [ids.ravel().copy()]
    for t in range(S_prompt, S_prompt + n_new - 1):
        nxt, cache = dec.fn(params, cache, jnp.asarray(ids), jnp.int32(t))
        ids = np.asarray(nxt)
        generated.append(ids.ravel().copy())
    generated = np.stack(generated).T
    print("generated tokens (reshard path):")
    print(generated)

    # --- reference: the old replay-the-prompt loop
    cache_sds, _ = model.cache_abstract(B, S_total, dec.plan)
    cache_r = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), cache_sds)
    ids = prompts[:, :1]
    replay = []
    for t in range(S_prompt + n_new - 1):
        nxt, cache_r = dec.fn(params, cache_r, ids, jnp.int32(t))
        ids = prompts[:, t + 1:t + 2] if t + 1 < S_prompt else nxt
        if t + 1 >= S_prompt:
            replay.append(np.asarray(nxt).ravel())
    replay = np.stack(replay).T

    assert np.array_equal(generated, replay), \
        f"reshard path diverged from replay:\n{generated}\nvs\n{replay}"
    print("token-level parity with the replay path: OK")


if __name__ == "__main__":
    main()
